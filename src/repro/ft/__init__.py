"""repro.ft — fault tolerance: failure detection, restart, elastic
re-mesh, straggler mitigation."""
from .runtime import (ElasticPlan, FailureDetector, StragglerPolicy,
                      plan_elastic_remesh, run_with_restarts)

__all__ = ["FailureDetector", "StragglerPolicy", "ElasticPlan",
           "plan_elastic_remesh", "run_with_restarts"]
