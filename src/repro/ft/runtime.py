"""Fault-tolerance runtime (the paper-§4.7 'monitor them, and take the
appropriate actions if one of them dies', scaled to pods).

Pieces, each independently testable on CPU:

  FailureDetector   heartbeat bookkeeping; on a real pod this wraps the
                    coordination-service barrier timeout, here it is
                    driven by injected events (tests kill 'nodes')
  run_with_restarts step-loop driver: on failure -> restore latest
                    checkpoint -> rebuild mesh (possibly smaller) ->
                    continue; data position is a pure function of the
                    step counter so no batches are lost or repeated
  plan_elastic_remesh
                    given surviving pod count, produce the new mesh
                    shape + the ParallelCtx changes (dp shrinks, tp is
                    preserved — TP ranks share model shards, so losing a
                    TP peer means losing the whole replica)
  StragglerPolicy   deadline-based step skip accounting: replicas that
                    miss the deadline contribute a zero-weighted
                    gradient for that step (gradient re-weighting keeps
                    the estimator unbiased); repeated misses demote the
                    node to the failure path
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax


@dataclasses.dataclass
class FailureDetector:
    n_nodes: int
    timeout_s: float = 60.0
    _last_beat: dict = dataclasses.field(default_factory=dict)
    _dead: set = dataclasses.field(default_factory=set)

    def heartbeat(self, node: int, t: Optional[float] = None) -> None:
        self._last_beat[node] = time.monotonic() if t is None else t

    def inject_failure(self, node: int) -> None:
        self._dead.add(node)

    def check(self, now: Optional[float] = None) -> list[int]:
        now = time.monotonic() if now is None else now
        dead = set(self._dead)
        for node, beat in self._last_beat.items():
            if now - beat > self.timeout_s:
                dead.add(node)
        return sorted(dead)

    def alive(self, now: Optional[float] = None) -> list[int]:
        dead = set(self.check(now))
        return [n for n in range(self.n_nodes) if n not in dead]


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: tuple
    axis_names: tuple
    dp_size: int
    tp_size: int
    dropped_replicas: int


def plan_elastic_remesh(alive_pods: int, pods: int, data: int, model: int,
                        multi_pod: bool = True) -> ElasticPlan:
    """Shrink the pod axis to the surviving pods.  TP (model axis) is
    never split across pods in our layout, so pod loss removes whole DP
    replicas; batch is re-sharded over the survivors."""
    if alive_pods < 1:
        raise RuntimeError("no pods survive — unrecoverable")
    if multi_pod:
        return ElasticPlan((alive_pods, data, model),
                           ("pod", "data", "model"),
                           dp_size=alive_pods * data, tp_size=model,
                           dropped_replicas=(pods - alive_pods) * data)
    return ElasticPlan((data, model), ("data", "model"),
                       dp_size=data, tp_size=model, dropped_replicas=0)


@dataclasses.dataclass
class StragglerPolicy:
    deadline_s: float = 120.0
    demote_after: int = 3
    _miss_count: dict = dataclasses.field(default_factory=dict)

    def record(self, node: int, step_time_s: float) -> str:
        """Returns 'ok' | 'skip' | 'demote'."""
        if step_time_s <= self.deadline_s:
            self._miss_count[node] = 0
            return "ok"
        self._miss_count[node] = self._miss_count.get(node, 0) + 1
        if self._miss_count[node] >= self.demote_after:
            return "demote"
        return "skip"

    def grad_weight(self, decisions: list[str]) -> float:
        """Re-weighting factor so the mean over contributing replicas
        stays unbiased when some are skipped."""
        n = len(decisions)
        ok = sum(1 for d in decisions if d == "ok")
        if ok == 0:
            return 0.0
        return n / ok


def run_with_restarts(make_step: Callable, init_state: Callable,
                      checkpointer, n_steps: int,
                      failure_schedule: Optional[dict] = None,
                      ckpt_every: int = 10):
    """Generic restart driver used by tests and the launch driver.

    make_step(attempt) -> (step_fn, state_spec_info); init_state(attempt)
    -> state.  ``failure_schedule`` maps step -> exception to inject
    (tests).  On failure: restore from the newest checkpoint and
    continue — the loop never loses more than ckpt_every steps.
    """
    failure_schedule = failure_schedule or {}
    attempt = 0
    step_fn = make_step(attempt)
    state = init_state(attempt)
    step = 0
    restarts = 0
    losses = []
    while step < n_steps:
        try:
            if step in failure_schedule and failure_schedule[step]:
                exc = failure_schedule.pop(step)
                raise exc
            state, metrics = step_fn(state, step)
            losses.append(float(metrics["loss"]))
            step += 1
            if step % ckpt_every == 0:
                checkpointer.save_async(step, state)
        except (RuntimeError, IOError) as e:
            restarts += 1
            attempt += 1
            checkpointer.wait()
            state, restored_step = checkpointer.restore(state)
            step = restored_step
            step_fn = make_step(attempt)
    checkpointer.wait()
    return state, {"losses": losses, "restarts": restarts,
                   "final_step": step}
