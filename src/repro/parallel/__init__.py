"""repro.parallel — sharding rules and the parallel execution context.

The whole framework runs in *manual SPMD* (one shard_map over the full
mesh), so that every collective is an explicit call into ``repro.comm``
— which is how the paper's communication layer becomes the first-class
distribution substrate rather than an afterthought behind XLA's
auto-partitioner.
"""
from .ctx import ParallelCtx, sp_gather, sp_scatter
from .specs import leading_dim_spec, replicated

__all__ = ["ParallelCtx", "sp_gather", "sp_scatter", "replicated",
           "leading_dim_spec"]
