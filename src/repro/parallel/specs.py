"""PartitionSpec helpers for the manual-SPMD parameter trees."""
from __future__ import annotations

from jax.sharding import PartitionSpec as P


def replicated(ndim: int) -> P:
    return P(*([None] * ndim))


def leading_dim_spec(axis_name: str, ndim: int) -> P:
    return P(axis_name, *([None] * (ndim - 1)))


def col_spec(ndim: int, tp_axis: str) -> P:
    """Column-parallel weight: last dim sharded."""
    return P(*([None] * (ndim - 1)), tp_axis)


def row_spec(ndim: int, tp_axis: str) -> P:
    """Row-parallel weight: second-to-last dim sharded."""
    return P(*([None] * (ndim - 2)), tp_axis, None)
