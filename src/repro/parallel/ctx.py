"""ParallelCtx — the static description of how this step is distributed.

Axes:
  dp_axes  batch ("data",) single-pod, ("pod", "data") multi-pod
  tp_axis  tensor/expert/sequence parallelism ("model")

Everything here is trace-time static.  The ctx is threaded through every
layer, and every collective the layers issue goes through one of two
first-class communicators built once at construction:

  ctx.tp_comm   team-bound to ``tp_axis``  — TP/SP/EP collectives
  ctx.dp_comm   team-bound to ``dp_axes``  — gradient/loss reductions

A communicator (``repro.comm.Communicator``) carries the backend
("xla" native collectives | "posh" paper schedules), a size-aware
dispatch table choosing each call's algorithm from payload bytes and
team size (POSH §4.5.4), and per-op instrumentation — so layers just
call ``ctx.tp_comm.psum(x)`` and the policy lives in one object.
``backend=`` selects the transport for both; pass explicit ``tp_comm``/
``dp_comm`` objects to mix transports or tune dispatch per team.  (The
deprecated ``comm=CommConfig(...)`` field was removed with the shim
layer; pin algorithms with ``dispatch=DispatchTable.fixed(...)``.)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro import comm, compat
from repro.comm import Communicator, DispatchTable


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    dp_axes: tuple[str, ...] = ("data",)
    tp_axis: str = "model"
    dp_size: int = 1                    # static sizes (mesh-derived)
    tp_size: int = 1
    backend: str = "xla"                # "xla" | "posh" | any registered
    dispatch: DispatchTable = DispatchTable()
    tp_comm: Optional[Communicator] = None   # built from the fields above
    dp_comm: Optional[Communicator] = None   # when not given explicitly
    sp: bool = True                     # sequence-parallel activations
    remat: bool = True                  # per-layer activation ckpt
    use_pallas: bool = False            # flash kernels (TPU only)
    ce_mode: str = "vocab_parallel"     # | "gathered" (paper-faithful naive)
    moe_dispatch: str = "einsum"        # | "alltoall"
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.bfloat16
    unroll: bool = False                # dry-run flop accounting: unroll
                                        # layer scans so cost_analysis
                                        # counts every trip (XLA counts
                                        # while bodies once)
    attn_block_q: int = 1024
    attn_block_kv: int = 1024
    ce_chunk: int = 4096

    def __post_init__(self):
        backend, dispatch = self.backend, self.dispatch
        if self.tp_comm is None:
            object.__setattr__(self, "tp_comm", comm.make_communicator(
                self.tp_axis, size=self.tp_size, backend=backend,
                dispatch=dispatch, name=f"tp:{backend}"))
        if self.dp_comm is None:
            object.__setattr__(self, "dp_comm", comm.make_communicator(
                self.dp_axes, size=self.dp_size, backend=backend,
                dispatch=dispatch, name=f"dp:{backend}"))

    # --- helpers ---------------------------------------------------
    def tp_rank(self):
        return self.tp_comm.rank()

    def dp_rank(self):
        return self.dp_comm.rank()

    # fields whose change invalidates each auto-built communicator —
    # kept separate so e.g. with_(dp_size=1) preserves the tp_comm
    # object (and the instrumentation already recorded on it)
    _TP_COMM_FIELDS = frozenset({"tp_axis", "tp_size", "backend",
                                 "dispatch"})
    _DP_COMM_FIELDS = frozenset({"dp_axes", "dp_size", "backend",
                                 "dispatch"})

    def with_(self, **kw) -> "ParallelCtx":
        """dataclasses.replace that rebuilds a communicator when any
        field it derives from changes (unless caller passes its own)."""
        if self._TP_COMM_FIELDS & kw.keys():
            kw.setdefault("tp_comm", None)
        if self._DP_COMM_FIELDS & kw.keys():
            kw.setdefault("dp_comm", None)
        return dataclasses.replace(self, **kw)

    @classmethod
    def from_mesh(cls, mesh, *, dp_axes=("data",), tp_axis="model",
                  **kw) -> "ParallelCtx":
        """Build a ctx (and its communicators) once from a mesh — sizes
        are read from the mesh shape; explicit dp_size/tp_size (or any
        other field) in ``kw`` still win."""
        shape = dict(zip(mesh.axis_names, mesh.devices.shape))
        dp_axes = (dp_axes,) if isinstance(dp_axes, str) else tuple(dp_axes)
        dp = 1
        for a in dp_axes:
            dp *= shape[a]
        derived = dict(dp_axes=dp_axes, tp_axis=tp_axis, dp_size=dp,
                       tp_size=shape.get(tp_axis, 1))
        derived.update(kw)
        return cls(**derived)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def grad_sync(w, sync_comm, scale=1.0):
    """Identity in the forward pass; psum (× scale) of the cotangent
    through ``sync_comm`` (a Communicator, e.g. ``ctx.tp_comm``) in the
    backward pass.

    Manual-SPMD necessity: a REPLICATED weight applied to RANK-VARYING
    activations (sequence-parallel attention inputs, sliced receptance,
    per-rank-sliced KV heads) produces per-rank PARTIAL gradients with no
    forward collective whose transpose would sum them.  ``scale``
    corrects over-counting when several ranks compute identical grads
    for the same slice (KV-head replication: scale = n_kv / tp).

    ``sync_comm`` must be a Communicator — the raw-axis spelling was
    removed so every collective goes through the comm layer (where
    dispatch, safety guards and instrumentation live; enforced by
    ``scripts/shmemlint.py``'s raw-collective rule)."""
    return w


def _grad_sync_fwd(w, sync_comm, scale):
    return w, None


def _grad_sync_bwd(sync_comm, scale, res, ct):
    out = jax.tree.map(sync_comm.psum, ct)
    if scale != 1.0:
        out = jax.tree.map(lambda t: t * scale, out)
    return (out,)


grad_sync.defvjp(_grad_sync_fwd, _grad_sync_bwd)


def smap(fn, mesh, in_specs, out_specs):
    """shard_map with VMA (varying-manual-axes) checking disabled: the
    framework's masked POSH schedules and replicated-redundant compute
    (MoE routing, vocab-parallel CE) are invisible to the rep tracker.
    Numerical equivalence DP/TP vs single-device is covered by tests."""
    return compat.shard_map(fn, mesh=mesh, in_specs=in_specs,
                            out_specs=out_specs, check_vma=False)


def sp_gather(x: jax.Array, ctx: ParallelCtx, axis: int = 1) -> jax.Array:
    """Sequence-parallel gather: (b, t/tp, d) -> (b, t, d).  The Megatron
    'g' operator; a no-op when SP is off or tp == 1."""
    if not ctx.sp or ctx.tp_size == 1:
        return x
    return ctx.tp_comm.all_gather(x, axis=axis, tiled=True)


def sp_scatter(x: jax.Array, ctx: ParallelCtx, axis: int = 1) -> jax.Array:
    """Sequence-parallel reduce-scatter: partial (b, t, d) -> reduced
    (b, t/tp, d).  The Megatron 'ḡ' operator.  When SP is off, reduces
    fully (psum) instead."""
    if ctx.tp_size == 1:
        return x
    if not ctx.sp:
        return ctx.tp_comm.psum(x)
    return ctx.tp_comm.psum_scatter(x, axis=axis)
