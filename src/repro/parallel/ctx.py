"""ParallelCtx — the static description of how this step is distributed.

Axes:
  dp_axes  batch ("data",) single-pod, ("pod", "data") multi-pod
  tp_axis  tensor/expert/sequence parallelism ("model")

Everything here is trace-time static; the ctx is threaded through every
layer, and every collective the layers issue goes through ``repro.comm``
with ``ctx.comm`` — the POSH/XLA backend switch.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro import comm


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    dp_axes: tuple[str, ...] = ("data",)
    tp_axis: str = "model"
    dp_size: int = 1                    # static sizes (mesh-derived)
    tp_size: int = 1
    comm: comm.CommConfig = comm.CommConfig()
    sp: bool = True                     # sequence-parallel activations
    remat: bool = True                  # per-layer activation ckpt
    use_pallas: bool = False            # flash kernels (TPU only)
    ce_mode: str = "vocab_parallel"     # | "gathered" (paper-faithful naive)
    moe_dispatch: str = "einsum"        # | "alltoall"
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.bfloat16
    unroll: bool = False                # dry-run flop accounting: unroll
                                        # layer scans so cost_analysis
                                        # counts every trip (XLA counts
                                        # while bodies once)
    attn_block_q: int = 1024
    attn_block_kv: int = 1024
    ce_chunk: int = 4096

    # --- helpers ---------------------------------------------------
    def tp_rank(self):
        if self.tp_size == 1:      # callable outside shard_map too
            return jnp.zeros((), jnp.int32)
        return jax.lax.axis_index(self.tp_axis)

    def dp_rank(self):
        if self.dp_size == 1:
            return jnp.zeros((), jnp.int32)
        ax = self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]
        return jax.lax.axis_index(ax)

    def with_(self, **kw) -> "ParallelCtx":
        return dataclasses.replace(self, **kw)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def grad_sync(w, axis, scale=1.0):
    """Identity in the forward pass; psum (× scale) of the cotangent over
    ``axis`` in the backward pass.

    Manual-SPMD necessity: a REPLICATED weight applied to RANK-VARYING
    activations (sequence-parallel attention inputs, sliced receptance,
    per-rank-sliced KV heads) produces per-rank PARTIAL gradients with no
    forward collective whose transpose would sum them.  ``scale``
    corrects over-counting when several ranks compute identical grads
    for the same slice (KV-head replication: scale = n_kv / tp)."""
    return w


def _grad_sync_fwd(w, axis, scale):
    return w, None


def _grad_sync_bwd(axis, scale, res, ct):
    from repro import comm as _comm
    out = jax.lax.psum(ct, axis)
    if scale != 1.0:
        out = jax.tree.map(lambda t: t * scale, out)
    return (out,)


grad_sync.defvjp(_grad_sync_fwd, _grad_sync_bwd)


def smap(fn, mesh, in_specs, out_specs):
    """shard_map with VMA (varying-manual-axes) checking disabled: the
    framework's masked POSH schedules and replicated-redundant compute
    (MoE routing, vocab-parallel CE) are invisible to the rep tracker.
    Numerical equivalence DP/TP vs single-device is covered by tests."""
    return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)


def sp_gather(x: jax.Array, ctx: ParallelCtx, axis: int = 1) -> jax.Array:
    """Sequence-parallel gather: (b, t/tp, d) -> (b, t, d).  The Megatron
    'g' operator; a no-op when SP is off or tp == 1."""
    if not ctx.sp or ctx.tp_size == 1:
        return x
    return comm.all_gather(x, ctx.tp_axis, ctx.comm, gather_axis=axis,
                           tiled=True)


def sp_scatter(x: jax.Array, ctx: ParallelCtx, axis: int = 1) -> jax.Array:
    """Sequence-parallel reduce-scatter: partial (b, t, d) -> reduced
    (b, t/tp, d).  The Megatron 'ḡ' operator.  When SP is off, reduces
    fully (psum) instead."""
    if ctx.tp_size == 1:
        return x
    if not ctx.sp:
        return comm.psum(x, ctx.tp_axis, ctx.comm)
    return comm.psum_scatter(x, ctx.tp_axis, ctx.comm, scatter_axis=axis)
