"""Whisper-style encoder-decoder backbone (conv frontend is a STUB —
``input_specs`` provides precomputed frame embeddings, per the
assignment).  Sinusoidal positions on the encoder, learned positions on
the decoder, LayerNorm, GELU MLPs, no RoPE — matching [arXiv:2212.04356].
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.ctx import ParallelCtx, sp_gather, sp_scatter

from . import attention as attn
from . import embed as emb
from . import mlp as ff
from .common import (layernorm, ninit, norm_apply, norm_init,
                     norm_sp, norm_specs)
from .lm import _scan, _stack_init, _stack_specs


def _sinusoid(length, d):
    pos = jnp.arange(length)[:, None].astype(jnp.float32)
    i = jnp.arange(d // 2)[None].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ----------------------------------------------------------------------
def _enc_block_init(cfg, ctx):
    def init(key):
        k1, k2 = jax.random.split(key)
        return {"ln1": norm_init("layer", cfg.d_model, ctx.param_dtype),
                "attn": attn.attn_init(k1, cfg, ctx),
                "ln2": norm_init("layer", cfg.d_model, ctx.param_dtype),
                "mlp": ff.mlp_init(k2, cfg, ctx)}
    return init


def _enc_block_specs(cfg, ctx):
    return {"ln1": norm_specs("layer"), "attn": attn.attn_specs(cfg, ctx),
            "ln2": norm_specs("layer"), "mlp": ff.mlp_specs(cfg, ctx)}


def _dec_block_init(cfg, ctx):
    def init(key):
        k1, k2, k3 = jax.random.split(key, 3)
        return {"ln1": norm_init("layer", cfg.d_model, ctx.param_dtype),
                "attn": attn.attn_init(k1, cfg, ctx),
                "lnx": norm_init("layer", cfg.d_model, ctx.param_dtype),
                "xattn": attn.attn_init(k2, cfg, ctx, cross=True),
                "ln2": norm_init("layer", cfg.d_model, ctx.param_dtype),
                "mlp": ff.mlp_init(k3, cfg, ctx)}
    return init


def _dec_block_specs(cfg, ctx):
    return {"ln1": norm_specs("layer"), "attn": attn.attn_specs(cfg, ctx),
            "lnx": norm_specs("layer"),
            "xattn": attn.attn_specs(cfg, ctx, cross=True),
            "ln2": norm_specs("layer"), "mlp": ff.mlp_specs(cfg, ctx)}


def init(key, cfg, ctx: ParallelCtx):
    ks = jax.random.split(key, 6)
    return {
        "embed": emb.embed_init(ks[0], cfg, ctx),
        "pos_dec": ninit(ks[1], (cfg.max_seq * 16, cfg.d_model), scale=0.01,
                         dtype=ctx.param_dtype),
        "enc_blocks": _stack_init(ks[2], cfg.enc_layers,
                                  _enc_block_init(cfg, ctx)),
        "ln_enc": norm_init("layer", cfg.d_model, ctx.param_dtype),
        "dec_blocks": _stack_init(ks[3], cfg.n_layers,
                                  _dec_block_init(cfg, ctx)),
        "ln_f": norm_init("layer", cfg.d_model, ctx.param_dtype),
    }


def specs(cfg, ctx: ParallelCtx):
    return {
        "embed": emb.embed_specs(cfg, ctx),
        "pos_dec": P(None, None),
        "enc_blocks": _stack_specs(_enc_block_specs(cfg, ctx)),
        "ln_enc": norm_specs("layer"),
        "dec_blocks": _stack_specs(_dec_block_specs(cfg, ctx)),
        "ln_f": norm_specs("layer"),
    }


def encode(params, frames, ctx: ParallelCtx, cfg):
    """frames: (b, n_frames, d) stub embeddings -> (b, n_frames, d)."""
    cd = ctx.compute_dtype
    x = frames.astype(cd) + _sinusoid(frames.shape[1],
                                      cfg.d_model).astype(cd)
    # encoder runs with full sequence (no SP: bidirectional, short)
    ctx_e = ctx.with_(sp=False)

    def block(p, h):
        a = attn.self_attention(p["attn"], norm_apply("layer", p["ln1"], h),
                                ctx_e, cfg, causal=False)
        h = h + a
        m = ff.mlp_apply(p["mlp"], norm_apply("layer", p["ln2"], h),
                         ctx_e, cfg)
        return h + m

    x = _scan(params["enc_blocks"], x, block, ctx_e)
    return norm_apply("layer", params["ln_enc"], x)


def decode_train(params, ids, enc_out, ctx: ParallelCtx, cfg):
    """Teacher-forced decoder forward -> seq-sharded hidden states."""
    partial = emb.embed_lookup(params["embed"], ids, ctx, reduce=False)
    x = sp_scatter(partial, ctx, axis=1) if ctx.tp_size > 1 else partial
    tl = x.shape[1]
    if ctx.sp and ctx.tp_size > 1:
        pos0 = ctx.tp_rank() * tl
    else:
        pos0 = 0
    pos_emb = jax.lax.dynamic_slice_in_dim(params["pos_dec"], pos0, tl,
                                           axis=0).astype(x.dtype)
    x = x + pos_emb[None]

    def block(p, h):
        a = attn.self_attention(p["attn"], norm_sp("layer", p["ln1"], h, ctx),
                                ctx, cfg, causal=True)
        h = h + a
        kv = attn.cross_kv(p["xattn"], enc_out, ctx, cfg)
        c = attn.cross_attention(p["xattn"],
                                 norm_sp("layer", p["lnx"], h, ctx),
                                 kv, ctx, cfg)
        h = h + c
        m = ff.mlp_apply(p["mlp"], norm_sp("layer", p["ln2"], h, ctx),
                         ctx, cfg)
        return h + m

    x = _scan(params["dec_blocks"], x, block, ctx)
    return norm_sp("layer", params["ln_f"], x, ctx)


def loss_fn(params, batch, ctx: ParallelCtx, cfg, for_grad: bool = False):
    """batch: {'frames': (b, F, d), 'tokens': (b, t+1)}.  See lm.loss_fn
    for the single-seed for_grad convention."""
    tokens = batch["tokens"]
    ids, targets = tokens[:, :-1], tokens[:, 1:]
    enc_out = encode(params, batch["frames"], ctx, cfg)
    x = decode_train(params, ids, enc_out, ctx, cfg)
    loss = emb.lm_head_loss(params["embed"], x, targets, ctx, cfg)
    if for_grad:
        if ctx.tp_size > 1:
            loss = jnp.where(jax.lax.axis_index(ctx.tp_axis) == 0, loss, 0.0)
        return loss
    loss = ctx.dp_comm.pmean(loss)
    return loss


def init_decode_state(cfg, ctx: ParallelCtx, batch_local: int, max_len: int):
    from .lm import _stack_state
    return {"cache": _stack_state(
                lambda: attn.init_cache(cfg, ctx, batch_local, max_len),
                cfg.n_layers),
            "pos": jnp.zeros((), jnp.int32)}


def decode_step(params, token, state, enc_kv, ctx: ParallelCtx, cfg):
    """enc_kv: per-decoder-layer stacked cross KV (L, b, F, kv, dh)."""
    x = emb.embed_lookup(params["embed"], token[:, None], ctx)[:, 0]
    pos = state["pos"]
    pe = jax.lax.dynamic_index_in_dim(params["pos_dec"],
                                      jnp.minimum(pos, params["pos_dec"].shape[0] - 1),
                                      0, keepdims=False)
    x = x + pe.astype(x.dtype)[None]

    def body(h, inputs):
        p, cache, kv = inputs
        a, nc = attn.decode_self_attention(
            p["attn"], norm_apply("layer", p["ln1"], h), cache, pos, ctx, cfg)
        h = h + a
        c = attn.decode_cross_attention(
            p["xattn"], norm_apply("layer", p["lnx"], h), kv, ctx, cfg)
        h = h + c
        ctx1 = ctx.with_(sp=False)
        m = ff.mlp_apply(p["mlp"],
                         norm_apply("layer", p["ln2"], h)[:, None],
                         ctx1, cfg)[:, 0]
        return h + m, nc

    x, new_cache = jax.lax.scan(body, x,
                                (params["dec_blocks"], state["cache"],
                                 enc_kv),
                                unroll=True if ctx.unroll else 1)
    x = norm_apply("layer", params["ln_f"], x)
    logits_loc = emb.lm_head_logits(params["embed"],
                                    x.astype(ctx.compute_dtype), ctx)
    nxt = emb.tp_argmax(logits_loc, ctx)
    return nxt.astype(jnp.int32), {"cache": new_cache, "pos": pos + 1}


def encoder_cross_kv(params, enc_out, ctx, cfg):
    """Precompute stacked per-layer cross KV for decode."""
    def one(p):
        return attn.cross_kv(p["xattn"], enc_out, ctx, cfg)
    return jax.vmap(one)(params["dec_blocks"])
