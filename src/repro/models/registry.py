"""Model registry: family -> (init, specs, loss_fn, serving fns)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

from . import encdec, lm


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    init: Callable
    specs: Callable
    loss_fn: Callable
    prefill: Optional[Callable] = None
    decode_step: Optional[Callable] = None
    init_decode_state: Optional[Callable] = None


def build(cfg) -> ModelAPI:
    if cfg.family == "encdec":
        return ModelAPI(
            init=encdec.init, specs=encdec.specs, loss_fn=encdec.loss_fn,
            decode_step=encdec.decode_step,
            init_decode_state=encdec.init_decode_state)
    return ModelAPI(
        init=lm.init, specs=lm.specs, loss_fn=lm.loss_fn,
        prefill=lm.prefill, decode_step=lm.decode_step,
        init_decode_state=lm.init_decode_state)
