"""Feed-forward layers: dense (GLU / plain) and MoE (EP over TP axis).

Dense: Megatron column→row parallel with SP boundaries.
MoE: experts sharded over the TP axis (EP).  Two dispatch modes:
  "einsum"   router + dispatch computed redundantly on every TP rank
             from the gathered tokens; each rank scatters only its own
             experts' tokens (no dispatch collective); combine = the
             SP reduce-scatter that the dense path needs anyway.
  "alltoall" tokens stay sequence-sharded; capacity-bucketed all-to-all
             to expert owners and back (the POSH alltoall is the wire).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.ctx import (ParallelCtx, grad_sync, sp_gather,
                                sp_scatter)

from .common import act_fn, ninit


def _is_glu(act: str) -> bool:
    return act in ("swiglu", "geglu")


def _glu_act(act: str):
    return jax.nn.silu if act == "swiglu" else jax.nn.gelu


# ----------------------------------------------------------------------
# dense MLP
# ----------------------------------------------------------------------
def mlp_init(key, cfg, ctx: ParallelCtx, d_ff=None):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"wu": ninit(ks[0], (d, ff), dtype=ctx.param_dtype),
         "wd": ninit(ks[1], (ff, d), dtype=ctx.param_dtype)}
    if _is_glu(cfg.act):
        p["wg"] = ninit(ks[2], (d, ff), dtype=ctx.param_dtype)
    return p


def mlp_specs(cfg, ctx: ParallelCtx):
    tp = ctx.tp_axis
    s = {"wu": P(None, tp), "wd": P(tp, None)}
    if _is_glu(cfg.act):
        s["wg"] = P(None, tp)
    return s


def mlp_apply(p, x_sp, ctx: ParallelCtx, cfg):
    cd = ctx.compute_dtype
    xf = sp_gather(x_sp, ctx, axis=1).astype(cd)
    u = xf @ p["wu"].astype(cd)
    if _is_glu(cfg.act):
        g = _glu_act(cfg.act)(xf @ p["wg"].astype(cd))
        hstate = g * u
    else:
        hstate = act_fn(cfg.act)(u)
    out = hstate @ p["wd"].astype(cd)
    return sp_scatter(out, ctx, axis=1)


# ----------------------------------------------------------------------
# MoE
# ----------------------------------------------------------------------
def moe_init(key, cfg, ctx: ParallelCtx):
    d = cfg.d_model
    m = cfg.moe
    ep = m.experts_padded(ctx.tp_size)
    ks = jax.random.split(key, 6)
    p = {
        "router": ninit(ks[0], (d, ep), scale=0.02, dtype=ctx.param_dtype),
        "wu": ninit(ks[1], (ep, d, m.expert_ff), dtype=ctx.param_dtype),
        "wg": ninit(ks[2], (ep, d, m.expert_ff), dtype=ctx.param_dtype),
        "wd": ninit(ks[3], (ep, m.expert_ff, d), dtype=ctx.param_dtype),
    }
    if m.shared_ff:
        p["shared"] = {
            "wu": ninit(ks[4], (d, m.shared_ff), dtype=ctx.param_dtype),
            "wg": ninit(ks[5], (d, m.shared_ff), dtype=ctx.param_dtype),
            "wd": ninit(jax.random.fold_in(key, 9), (m.shared_ff, d),
                        dtype=ctx.param_dtype),
        }
    return p


def moe_specs(cfg, ctx: ParallelCtx):
    tp = ctx.tp_axis
    s = {"router": P(None, None),
         "wu": P(tp, None, None), "wg": P(tp, None, None),
         "wd": P(tp, None, None)}
    if cfg.moe.shared_ff:
        s["shared"] = {"wu": P(None, tp), "wg": P(None, tp),
                       "wd": P(tp, None)}
    return s


def _route(router_w, xf, cfg, cd):
    """Top-k routing.  Padded experts get -inf logits (zero mass)."""
    m = cfg.moe
    logits = (xf @ router_w.astype(cd)).astype(jnp.float32)  # (n, ep)
    if m.padded_experts and m.padded_experts > m.num_experts:
        pad_mask = jnp.arange(logits.shape[-1]) >= m.num_experts
        logits = jnp.where(pad_mask, -1e30, logits)
    gates_all = jax.nn.softmax(logits, axis=-1)
    gate_k, idx_k = jax.lax.top_k(gates_all, m.top_k)         # (n, k)
    gate_k = gate_k / jnp.maximum(gate_k.sum(-1, keepdims=True), 1e-9)
    # load-balancing auxiliary loss (Switch-style)
    me = gates_all.mean(0)
    ce = jnp.zeros_like(me).at[idx_k.reshape(-1)].add(
        jnp.ones(idx_k.size) / idx_k.size)
    aux = (me * ce).sum() * logits.shape[-1]
    return gate_k, idx_k, aux


def _positions_in_expert(idx_k, n_experts):
    """Cumulative slot of each (token, choice) within its expert."""
    nk = idx_k.size
    flat = idx_k.reshape(-1)
    onehot = jax.nn.one_hot(flat, n_experts, dtype=jnp.int32)  # (nk, E)
    pos = jnp.cumsum(onehot, axis=0) - 1                       # (nk, E)
    return jnp.take_along_axis(pos, flat[:, None], axis=1)[:, 0]


def _expert_ffn(wu, wg, wd, xb, act, cd):
    """xb: (E_loc, C, d) -> (E_loc, C, d)."""
    u = jnp.einsum("ecd,edf->ecf", xb, wu.astype(cd))
    g = _glu_act(act)(jnp.einsum("ecd,edf->ecf", xb, wg.astype(cd)))
    return jnp.einsum("ecf,efd->ecd", g * u, wd.astype(cd))


def moe_apply(p, x_sp, ctx: ParallelCtx, cfg):
    m = cfg.moe
    cd = ctx.compute_dtype
    ep = m.experts_padded(ctx.tp_size)
    e_loc = ep // ctx.tp_size

    if ctx.moe_dispatch == "alltoall" and ctx.tp_size > 1:
        out = _moe_alltoall(p, x_sp, ctx, cfg, ep, e_loc)
    else:
        out = _moe_einsum(p, x_sp, ctx, cfg, ep, e_loc)

    if m.shared_ff:
        sh = p["shared"]
        xf = sp_gather(x_sp, ctx, axis=1).astype(cd)
        u = xf @ sh["wu"].astype(cd)
        g = _glu_act(cfg.act)(xf @ sh["wg"].astype(cd))
        shared_out = sp_scatter((g * u) @ sh["wd"].astype(cd), ctx, axis=1)
        out = out + shared_out
    return out


def _moe_einsum(p, x_sp, ctx, cfg, ep, e_loc):
    """Redundant routing, local-expert scatter, psum/RS combine."""
    m = cfg.moe
    cd = ctx.compute_dtype
    xf = sp_gather(x_sp, ctx, axis=1).astype(cd)            # (b, t, d)
    b, t, d = xf.shape
    n = b * t
    xt = xf.reshape(n, d)
    gate_k, idx_k, aux = _route(p["router"], xt, cfg, cd)
    cap = int(n * m.top_k * m.capacity_factor / ep) + 1

    flat_e = idx_k.reshape(-1)                              # (n·k,)
    pos = _positions_in_expert(idx_k, ep)                   # (n·k,)
    keep = pos < cap
    rank = ctx.tp_rank()
    e_lo = rank * e_loc
    local = (flat_e >= e_lo) & (flat_e < e_lo + e_loc) & keep
    le = jnp.clip(flat_e - e_lo, 0, e_loc - 1)
    lp = jnp.clip(pos, 0, cap - 1)

    xtk = jnp.repeat(xt, m.top_k, axis=0)                   # (n·k, d)
    buf = jnp.zeros((e_loc, cap, d), cd)
    buf = buf.at[le, lp].add(jnp.where(local[:, None], xtk, 0))

    yb = _expert_ffn(p["wu"], p["wg"], p["wd"], buf, cfg.act, cd)

    gathered = yb[le, lp]                                   # (n·k, d)
    gathered = jnp.where(local[:, None], gathered, 0)
    w = gate_k.reshape(-1)[:, None].astype(cd)
    comb = (gathered * w).reshape(n, m.top_k, d).sum(1)     # partial over TP
    out = comb.reshape(b, t, d)
    return sp_scatter(out, ctx, axis=1)


def _moe_alltoall(p, x_sp, ctx, cfg, ep, e_loc):
    """Sequence-sharded tokens; dispatch/return over POSH alltoall."""
    m = cfg.moe
    cd = ctx.compute_dtype
    tp = ctx.tp_size
    xl = x_sp.astype(cd)                                    # (b, t_loc, d)
    b, tl, d = xl.shape
    nloc = b * tl
    xt = xl.reshape(nloc, d)
    gate_k, idx_k, aux = _route(p["router"], xt, cfg, cd)
    cap = int(nloc * m.top_k * m.capacity_factor / ep) + 1

    flat_e = idx_k.reshape(-1)
    pos = _positions_in_expert(idx_k, ep)
    keep = pos < cap
    lp = jnp.clip(pos, 0, cap - 1)

    xtk = jnp.repeat(xt, m.top_k, axis=0)
    send = jnp.zeros((ep, cap, d), cd)
    send = send.at[flat_e, lp].add(jnp.where(keep[:, None], xtk, 0))
    # (ep, cap, d) -> alltoall over expert-owner dim
    send = send.reshape(tp, e_loc * cap, d)
    recv = ctx.tp_comm.all_to_all(send, split_axis=0,
                                  concat_axis=0)             # (tp, e_loc*cap, d)
    xb = recv.reshape(tp, e_loc, cap, d).transpose(1, 0, 2, 3) \
             .reshape(e_loc, tp * cap, d)
    yb = _expert_ffn(p["wu"], p["wg"], p["wd"], xb, cfg.act, cd)
    back = yb.reshape(e_loc, tp, cap, d).transpose(1, 0, 2, 3) \
             .reshape(tp, e_loc * cap, d)
    ret = ctx.tp_comm.all_to_all(back, split_axis=0, concat_axis=0)
    ret = ret.reshape(ep, cap, d)
    gathered = ret[flat_e, lp]
    gathered = jnp.where(keep[:, None], gathered, 0)
    w = gate_k.reshape(-1)[:, None].astype(cd)
    out = (gathered * w).reshape(nloc, m.top_k, d).sum(1).reshape(b, tl, d)
    return out
