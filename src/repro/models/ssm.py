"""Mamba2 / SSD blocks [arXiv:2405.21060] — scalar-per-head decay state
space, chunked-parallel train, O(1)-state decode.  Used by zamba2.

TP: d_inner (= expand·d_model) is head-sharded over the TP axis
(zamba2-7b: 112 heads of 64 → 7 heads/rank at TP=16); B/C projections
(ngroups=1, state 64) are replicated — they are tiny and every head
needs them; output projection is row-parallel.

Chunked SSD is numerically benign: decays are scalar per head and only
i ≤ t pairs appear, so every exponent is ≤ |single-step decay| — no
normalizer tricks needed (contrast rwkv.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.ctx import ParallelCtx, sp_gather, sp_scatter

from .common import ninit, rmsnorm

CHUNK = 64


def _dims(cfg, ctx):
    d_in = cfg.ssm_expand * cfg.d_model
    p = 64                                   # SSD head dim
    nh = d_in // p
    hl = nh // ctx.tp_size if ctx.tp_size > 1 else nh
    return d_in, p, nh, hl


def mamba_init(key, cfg, ctx: ParallelCtx):
    d = cfg.d_model
    d_in, p, nh, _ = _dims(cfg, ctx)
    ds, k = cfg.ssm_state, cfg.ssm_conv
    ks = jax.random.split(key, 8)
    return {
        "wz": ninit(ks[0], (d, d_in), dtype=ctx.param_dtype),
        "wx": ninit(ks[1], (d, d_in), dtype=ctx.param_dtype),
        "wB": ninit(ks[2], (d, ds), dtype=ctx.param_dtype),
        "wC": ninit(ks[3], (d, ds), dtype=ctx.param_dtype),
        "wdt": ninit(ks[4], (d, nh), scale=0.02, dtype=ctx.param_dtype),
        "dt_bias": jnp.zeros((nh,), ctx.param_dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(ctx.param_dtype),
        "D": jnp.ones((nh,), ctx.param_dtype),
        "conv_x": ninit(ks[5], (k, d_in), scale=0.5, dtype=ctx.param_dtype),
        "conv_B": ninit(ks[6], (k, ds), scale=0.5, dtype=ctx.param_dtype),
        "conv_C": ninit(ks[7], (k, ds), scale=0.5, dtype=ctx.param_dtype),
        "norm_scale": jnp.ones((d_in,), ctx.param_dtype),
        "wo": ninit(jax.random.fold_in(key, 11), (d_in, d),
                    dtype=ctx.param_dtype),
    }


def mamba_specs(cfg, ctx: ParallelCtx):
    tp = ctx.tp_axis
    return {
        "wz": P(None, tp), "wx": P(None, tp), "wB": P(None, None),
        "wC": P(None, None), "wdt": P(None, tp), "dt_bias": P(tp),
        "A_log": P(tp), "D": P(tp),
        "conv_x": P(None, tp), "conv_B": P(None, None),
        "conv_C": P(None, None),
        "norm_scale": P(tp), "wo": P(tp, None),
    }


def _sharded_rmsnorm(scale, y, ctx, d_total, eps=1e-6):
    """RMSNorm over the channel dim when channels are TP-sharded: the
    mean of squares is a psum over the axis (matches the unsharded op)."""
    yf = y.astype(jnp.float32)
    ssq = jnp.sum(yf * yf, axis=-1, keepdims=True)
    ssq = ctx.tp_comm.psum(ssq)
    out = yf * jax.lax.rsqrt(ssq / d_total + eps) * \
        scale.astype(jnp.float32)
    return out.astype(y.dtype)


def _causal_conv(x, w):
    """Depthwise causal conv via k shifted adds.  x: (b,t,c); w: (k,c)."""
    k = w.shape[0]
    out = x * w[-1]
    for j in range(1, k):
        shifted = jnp.pad(x, ((0, 0), (j, 0), (0, 0)))[:, :-j]
        out = out + shifted * w[-1 - j]
    return out


def _ssd_chunked(xs, B, C, lw, hl, p, ds):
    """xs: (b,t,hl,p) dt-scaled inputs; B,C: (b,t,ds); lw: (b,t,hl) ≤ 0.
    Returns (b,t,hl,p)."""
    b, t = xs.shape[0], xs.shape[1]
    nc = t // CHUNK
    xsc = xs.reshape(b, nc, CHUNK, hl, p)
    Bc = B.reshape(b, nc, CHUNK, ds)
    Cc = C.reshape(b, nc, CHUNK, ds)
    lwc = lw.reshape(b, nc, CHUNK, hl)

    def body(S, args):
        xj, Bj, Cj, lwj = args
        il = jnp.cumsum(lwj, axis=1)                  # inclusive (b,C,hl)
        diff = il[:, :, None] - il[:, None, :]        # (b, t, i, hl)
        tri = jnp.tril(jnp.ones((CHUNK, CHUNK), bool))
        # mask BEFORE exp: upper-triangle diffs are positive and large —
        # exp would inf and poison the where() gradient
        diff = jnp.where(tri[None, :, :, None], diff, -jnp.inf)
        dmat = jnp.exp(diff)
        cb = jnp.einsum("bts,bis->bti", Cj, Bj)           # (b, t, i)
        y = jnp.einsum("bti,btih,bihp->bthp", cb, dmat, xj)
        y = y + jnp.einsum("bth,bhps,bts->bthp",
                           jnp.exp(il), S, Cj)
        ilc = il[:, -1]                                   # (b, hl)
        kdec = jnp.exp(ilc[:, None] - il)                 # (b, C, hl)
        S_new = S * jnp.exp(ilc)[..., None, None] + \
            jnp.einsum("bih,bihp,bis->bhps", kdec, xj, Bj)
        return S_new, y

    S0 = jnp.zeros((b, hl, p, ds), jnp.float32)
    _, ys = jax.lax.scan(body, S0, tuple(
        jnp.moveaxis(a, 1, 0) for a in (xsc, Bc, Cc, lwc)))
    return jnp.moveaxis(ys, 0, 1).reshape(b, t, hl, p)


def mamba_apply(prm, x_sp, ctx: ParallelCtx, cfg):
    cd = ctx.compute_dtype
    d_in, p, nh, hl = _dims(cfg, ctx)
    ds = cfg.ssm_state
    xf = sp_gather(x_sp, ctx, axis=1).astype(cd)
    b, t, d = xf.shape
    pad = (-t) % CHUNK
    if pad:
        xf = jnp.pad(xf, ((0, 0), (0, pad), (0, 0)))
    z = xf @ prm["wz"].astype(cd)                       # (b,t,d_in/tp)
    xx = jax.nn.silu(_causal_conv(xf @ prm["wx"].astype(cd),
                                  prm["conv_x"].astype(cd)))
    B = jax.nn.silu(_causal_conv(xf @ prm["wB"].astype(cd),
                                 prm["conv_B"].astype(cd))).astype(jnp.float32)
    C = jax.nn.silu(_causal_conv(xf @ prm["wC"].astype(cd),
                                 prm["conv_C"].astype(cd))).astype(jnp.float32)
    dt = jax.nn.softplus((xf @ prm["wdt"].astype(cd)).astype(jnp.float32)
                         + prm["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(prm["A_log"].astype(jnp.float32))      # (hl,) < 0
    lw = a * dt                                          # (b,t,hl)
    tt = xf.shape[1]
    xs = (xx.astype(jnp.float32) * dt[..., None].repeat(p, -1)
          .reshape(b, tt, hl * p)).reshape(b, tt, hl, p)
    y = _ssd_chunked(xs, B, C, lw, hl, p, ds)
    y = y + prm["D"].astype(jnp.float32)[None, None, :, None] * \
        xx.astype(jnp.float32).reshape(b, tt, hl, p)
    y = y.reshape(b, tt, hl * p).astype(cd)
    y = _sharded_rmsnorm(prm["norm_scale"], y, ctx, d_in) * jax.nn.silu(z)
    out = y @ prm["wo"].astype(cd)
    if pad:
        out = out[:, :t]
    return sp_scatter(out, ctx, axis=1)


def mamba_init_state(cfg, ctx: ParallelCtx, batch_local: int):
    d_in, p, nh, hl = _dims(cfg, ctx)
    ds, k = cfg.ssm_state, cfg.ssm_conv
    return {
        "S": jnp.zeros((batch_local, hl, p, ds), jnp.float32),
        "conv_x": jnp.zeros((batch_local, k - 1, hl * p), jnp.bfloat16),
        "conv_B": jnp.zeros((batch_local, k - 1, ds), jnp.bfloat16),
        "conv_C": jnp.zeros((batch_local, k - 1, ds), jnp.bfloat16),
    }


def _conv_step(xin, buf, w):
    """xin: (b, c); buf: (b, k-1, c) past inputs; w: (k, c)."""
    full = jnp.concatenate([buf.astype(xin.dtype), xin[:, None]], axis=1)
    out = (full * w[None]).sum(1)
    return out, full[:, 1:]


def mamba_decode(prm, x, state, ctx: ParallelCtx, cfg):
    cd = ctx.compute_dtype
    d_in, p, nh, hl = _dims(cfg, ctx)
    ds = cfg.ssm_state
    xf = x.astype(cd)
    b = xf.shape[0]
    z = xf @ prm["wz"].astype(cd)
    xraw = xf @ prm["wx"].astype(cd)
    xx, cx = _conv_step(xraw, state["conv_x"], prm["conv_x"].astype(cd))
    xx = jax.nn.silu(xx)
    Braw = xf @ prm["wB"].astype(cd)
    B, cB = _conv_step(Braw, state["conv_B"], prm["conv_B"].astype(cd))
    B = jax.nn.silu(B).astype(jnp.float32)
    Craw = xf @ prm["wC"].astype(cd)
    C, cC = _conv_step(Craw, state["conv_C"], prm["conv_C"].astype(cd))
    C = jax.nn.silu(C).astype(jnp.float32)
    dt = jax.nn.softplus((xf @ prm["wdt"].astype(cd)).astype(jnp.float32)
                         + prm["dt_bias"].astype(jnp.float32))  # (b, hl)
    a = -jnp.exp(prm["A_log"].astype(jnp.float32))
    decay = jnp.exp(a * dt)                                     # (b, hl)
    xsh = (xx.astype(jnp.float32) * dt.repeat(p, -1).reshape(b, hl * p)) \
        .reshape(b, hl, p)
    S = state["S"] * decay[..., None, None] + \
        jnp.einsum("bhp,bs->bhps", xsh, B)
    y = jnp.einsum("bhps,bs->bhp", S, C) + \
        prm["D"].astype(jnp.float32)[None, :, None] * \
        xx.astype(jnp.float32).reshape(b, hl, p)
    y = y.reshape(b, hl * p).astype(cd)
    y = _sharded_rmsnorm(prm["norm_scale"], y, ctx, d_in) * jax.nn.silu(z)
    out = y @ prm["wo"].astype(cd)
    out = ctx.tp_comm.psum(out)
    return out, {"S": S, "conv_x": cx.astype(jnp.bfloat16),
                 "conv_B": cB.astype(jnp.bfloat16),
                 "conv_C": cC.astype(jnp.bfloat16)}
