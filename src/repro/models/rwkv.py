"""RWKV6 "Finch" blocks — data-dependent per-channel decay linear
recurrence [arXiv:2404.05892], chunked-parallel for training, O(1)-state
for decode.

TP: heads are padded (40→48 for rwkv6-3b) so head blocks divide the TP
axis; the decay/receptance/key/value/gate projections are column-
parallel per head, output projection row-parallel.

Numerics: the chunked form needs products of per-channel decays
Π w_l ∈ (0,1).  We work in log space with a chunk-midpoint normalizer
and clamp the log-log decay (w_raw ≤ 1.2 ⇒ per-step decay ≥ e^-3.3) so
the half-chunk exponents stay in f32 range with chunk=16 (documented
deviation; decays faster than 0.037/step are saturated anyway).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.ctx import (ParallelCtx, grad_sync, sp_gather,
                                sp_scatter)

from .common import ninit

LORA_R = 32
DECAY_LORA_R = 64
W_RAW_MAX = 1.2
CHUNK = 16


def _hp(cfg):
    return cfg.rwkv_padded_heads or cfg.n_heads


def timemix_init(key, cfg, ctx: ParallelCtx):
    d = cfg.d_model
    dh = cfg.rwkv_head_dim
    da = _hp(cfg) * dh
    ks = jax.random.split(key, 12)
    return {
        "mu_x": jnp.zeros((d,), ctx.param_dtype),
        "mu5": jnp.zeros((5, d), ctx.param_dtype),
        "lora_w1": ninit(ks[0], (d, 5 * LORA_R), scale=0.02,
                         dtype=ctx.param_dtype),
        "lora_w2": ninit(ks[1], (5, LORA_R, d), scale=0.02,
                         dtype=ctx.param_dtype),
        "wr": ninit(ks[2], (d, da), dtype=ctx.param_dtype),
        "wk": ninit(ks[3], (d, da), dtype=ctx.param_dtype),
        "wv": ninit(ks[4], (d, da), dtype=ctx.param_dtype),
        "wg": ninit(ks[5], (d, da), dtype=ctx.param_dtype),
        "w0": (jnp.linspace(-6.0, 0.0, da)).astype(ctx.param_dtype),
        "ww1": ninit(ks[6], (d, DECAY_LORA_R), scale=0.02,
                     dtype=ctx.param_dtype),
        "ww2": ninit(ks[7], (DECAY_LORA_R, da), scale=0.02,
                     dtype=ctx.param_dtype),
        "u": ninit(ks[8], (da,), scale=1.0, dtype=ctx.param_dtype),
        "gn_scale": jnp.ones((da,), ctx.param_dtype),
        "gn_bias": jnp.zeros((da,), ctx.param_dtype),
        "wo": ninit(ks[9], (da, d), dtype=ctx.param_dtype),
    }


def timemix_specs(cfg, ctx: ParallelCtx):
    tp = ctx.tp_axis
    return {
        "mu_x": P(None), "mu5": P(None, None),
        "lora_w1": P(None, None), "lora_w2": P(None, None, None),
        "wr": P(None, tp), "wk": P(None, tp), "wv": P(None, tp),
        "wg": P(None, tp), "w0": P(tp), "ww1": P(None, None),
        "ww2": P(None, tp), "u": P(tp),
        "gn_scale": P(tp), "gn_bias": P(tp), "wo": P(tp, None),
    }


def _ddlerp(p, xf, cd):
    """RWKV6 data-dependent token-shift mixing -> 5 mixed streams."""
    xprev = jnp.pad(xf, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    dx = xprev - xf
    xxx = xf + dx * p["mu_x"].astype(cd)
    z = jnp.tanh(xxx @ p["lora_w1"].astype(cd))
    b, t, _ = xf.shape
    z = z.reshape(b, t, 5, LORA_R)
    deltas = jnp.einsum("btfr,frd->btfd", z, p["lora_w2"].astype(cd))
    mixed = xf[:, :, None] + dx[:, :, None] * (
        p["mu5"].astype(cd)[None, None] + deltas)
    return [mixed[:, :, i] for i in range(5)], dx


def _group_norm(y, scale, bias, n_heads, eps=64e-5):
    b, t, da = y.shape
    dh = da // n_heads
    yh = y.reshape(b, t, n_heads, dh).astype(jnp.float32)
    mu = yh.mean(-1, keepdims=True)
    var = yh.var(-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + eps)
    out = yh.reshape(b, t, da) * scale.astype(jnp.float32) \
        + bias.astype(jnp.float32)
    return out.astype(y.dtype)


def _wkv_chunked(r, k, v, lw, u, hl, dh):
    """Chunked RWKV6 recurrence.  r,k,v,lw: (b, t, hl, dh) f32 with
    lw = log decay ≤ 0.  Returns (b, t, hl, dh)."""
    b, t = r.shape[0], r.shape[1]
    nc = t // CHUNK
    shp = (b, nc, CHUNK, hl, dh)
    rc, kc, vc, lwc = (a.reshape(shp) for a in (r, k, v, lw))

    def body(S, args):
        rj, kj, vj, lwj = args                       # (b, C, hl, dh)
        el = jnp.cumsum(lwj, axis=1) - lwj           # exclusive cumsum
        elc = el[:, -1] + lwj[:, -1]                 # total chunk decay
        mid = el[:, CHUNK // 2][:, None]             # normalizer
        a_t = jnp.exp(el - mid) * rj
        b_i = jnp.exp(mid - el - lwj) * kj
        s = jnp.einsum("bthc,bihc->bhti", a_t, b_i)  # (b,hl,C,C)
        tri = jnp.tril(jnp.ones((CHUNK, CHUNK), bool), k=-1)
        s = jnp.where(tri[None, None], s, 0.0)
        intra = jnp.einsum("bhti,bihc->bthc", s, vj)
        bonus = (rj * u * kj).sum(-1, keepdims=True) * vj
        inter = jnp.einsum("bthc,bhce->bthe",
                           jnp.exp(el) * rj, S)
        kdec = jnp.exp(elc[:, None] - el - lwj) * kj
        S_new = S * jnp.exp(elc)[..., None] + \
            jnp.einsum("bihc,bihe->bhce", kdec, vj)
        return S_new, intra + bonus + inter

    S0 = jnp.zeros((b, hl, dh, dh), jnp.float32)
    _, ys = jax.lax.scan(body, S0, tuple(
        jnp.moveaxis(a, 1, 0) for a in (rc, kc, vc, lwc)))
    return jnp.moveaxis(ys, 0, 1).reshape(b, t, hl, dh)


def timemix_apply(p, x_sp, ctx: ParallelCtx, cfg):
    cd = ctx.compute_dtype
    dh = cfg.rwkv_head_dim
    hl = (_hp(cfg) // ctx.tp_size) if ctx.tp_size > 1 else _hp(cfg)
    xf = sp_gather(x_sp, ctx, axis=1).astype(cd)
    b, t, d = xf.shape
    pad = (-t) % CHUNK
    if pad:
        xf = jnp.pad(xf, ((0, 0), (0, pad), (0, 0)))
    (mr, mk, mv, mg, mw), _ = _ddlerp(p, xf, cd)
    r = (mr @ p["wr"].astype(cd)).astype(jnp.float32)
    k = (mk @ p["wk"].astype(cd)).astype(jnp.float32)
    v = (mv @ p["wv"].astype(cd)).astype(jnp.float32)
    g = jax.nn.silu(mg @ p["wg"].astype(cd))
    w_raw = p["w0"].astype(jnp.float32) + \
        jnp.tanh(mw @ p["ww1"].astype(cd)).astype(jnp.float32) \
        @ p["ww2"].astype(jnp.float32)
    lw = -jnp.exp(jnp.minimum(w_raw, W_RAW_MAX))
    tt = xf.shape[1]
    shape4 = (b, tt, hl, dh)
    y = _wkv_chunked(r.reshape(shape4), k.reshape(shape4),
                     v.reshape(shape4), lw.reshape(shape4),
                     p["u"].astype(jnp.float32).reshape(hl, dh), hl, dh)
    y = y.reshape(b, tt, hl * dh).astype(cd)
    y = _group_norm(y, p["gn_scale"], p["gn_bias"], hl)
    out = (y * g) @ p["wo"].astype(cd)
    if pad:
        out = out[:, :t]
    return sp_scatter(out, ctx, axis=1)


def timemix_decode(p, x, state, ctx: ParallelCtx, cfg):
    """Single-token step.  x: (b, d); state: {'S': (b,hl,dh,dh),
    'x_prev': (b, d)}.  Returns (out (b,d), new_state)."""
    cd = ctx.compute_dtype
    dh = cfg.rwkv_head_dim
    hl = (_hp(cfg) // ctx.tp_size) if ctx.tp_size > 1 else _hp(cfg)
    xf = x.astype(cd)[:, None]                       # (b, 1, d)
    xprev = state["x_prev"].astype(cd)[:, None]
    dx = xprev - xf
    xxx = xf + dx * p["mu_x"].astype(cd)
    z = jnp.tanh(xxx @ p["lora_w1"].astype(cd)).reshape(-1, 1, 5, LORA_R)
    deltas = jnp.einsum("btfr,frd->btfd", z, p["lora_w2"].astype(cd))
    mixed = xf[:, :, None] + dx[:, :, None] * (
        p["mu5"].astype(cd)[None, None] + deltas)
    mr, mk, mv, mg, mw = (mixed[:, 0, i] for i in range(5))
    b = x.shape[0]
    r = (mr @ p["wr"].astype(cd)).astype(jnp.float32).reshape(b, hl, dh)
    k = (mk @ p["wk"].astype(cd)).astype(jnp.float32).reshape(b, hl, dh)
    v = (mv @ p["wv"].astype(cd)).astype(jnp.float32).reshape(b, hl, dh)
    g = jax.nn.silu(mg @ p["wg"].astype(cd))
    w_raw = p["w0"].astype(jnp.float32) + \
        jnp.tanh(mw @ p["ww1"].astype(cd)).astype(jnp.float32) \
        @ p["ww2"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(jnp.minimum(w_raw, W_RAW_MAX))).reshape(b, hl, dh)
    u = p["u"].astype(jnp.float32).reshape(hl, dh)
    S = state["S"]
    att = S + u[None, :, :, None] * k[..., None] * v[:, :, None, :]
    y = jnp.einsum("bhc,bhce->bhe", r, att).reshape(b, hl * dh)
    S_new = S * w[..., None] + k[..., None] * v[:, :, None, :]
    y = _group_norm(y[:, None].astype(cd), p["gn_scale"], p["gn_bias"],
                    hl)[:, 0]
    out = (y * g) @ p["wo"].astype(cd)
    out = ctx.tp_comm.psum(out)
    return out, {"S": S_new, "x_prev": x}


# ----------------------------------------------------------------------
# channel-mix
# ----------------------------------------------------------------------
def chanmix_init(key, cfg, ctx: ParallelCtx):
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.zeros((d,), ctx.param_dtype),
        "mu_r": jnp.zeros((d,), ctx.param_dtype),
        "wk": ninit(ks[0], (d, ff), dtype=ctx.param_dtype),
        "wv": ninit(ks[1], (ff, d), dtype=ctx.param_dtype),
        "wr": ninit(ks[2], (d, d), dtype=ctx.param_dtype),
    }


def chanmix_specs(cfg, ctx: ParallelCtx):
    tp = ctx.tp_axis
    return {"mu_k": P(None), "mu_r": P(None),
            "wk": P(None, tp), "wv": P(tp, None), "wr": P(None, None)}


def chanmix_apply(p, x_sp, ctx: ParallelCtx, cfg, x_prev=None):
    cd = ctx.compute_dtype
    xf = sp_gather(x_sp, ctx, axis=1).astype(cd)
    xprev = jnp.pad(xf, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    dx = xprev - xf
    mk = xf + dx * p["mu_k"].astype(cd)
    mr = xf + dx * p["mu_r"].astype(cd)
    k = jnp.square(jax.nn.relu(mk @ p["wk"].astype(cd)))
    kv = k @ p["wv"].astype(cd)                      # partial over TP
    kv = sp_scatter(kv, ctx, axis=1)
    # receptance on the sequence-sharded slice (wr replicated)
    if ctx.sp and ctx.tp_size > 1:
        tl = x_sp.shape[1]
        off = ctx.tp_rank() * tl
        mr_loc = jax.lax.dynamic_slice_in_dim(mr, off, tl, axis=1)
    else:
        mr_loc = mr
    r = jax.nn.sigmoid(mr_loc @ p["wr"].astype(cd))
    return r * kv


def chanmix_decode(p, x, state, ctx: ParallelCtx, cfg):
    cd = ctx.compute_dtype
    xf = x.astype(cd)
    xprev = state["x_prev"].astype(cd)
    dx = xprev - xf
    mk = xf + dx * p["mu_k"].astype(cd)
    mr = xf + dx * p["mu_r"].astype(cd)
    k = jnp.square(jax.nn.relu(mk @ p["wk"].astype(cd)))
    kv = k @ p["wv"].astype(cd)
    kv = ctx.tp_comm.psum(kv)
    r = jax.nn.sigmoid(mr @ p["wr"].astype(cd))
    return r * kv, {"x_prev": x}
