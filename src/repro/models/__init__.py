"""repro.models — the assigned architectures, written in manual SPMD.

Every model exposes:
    init(key, cfg, ctx)    -> global (unsharded-logical) param pytree
    specs(cfg, ctx)        -> matching PartitionSpec pytree (shard_map)
    loss_fn(params, batch, ctx) -> scalar loss       (train shapes)
    prefill / decode_step  (serving shapes; LM-family)
"""
__all__ = ["build"]


def build(*args, **kwargs):
    from .registry import build as _build
    return _build(*args, **kwargs)
