"""Vocab-parallel embedding and cross-entropy (Megatron-style).

The embedding table is sharded over the TP axis on the vocab dim; both
lookup and the LM-head cross-entropy never materialize an unsharded
(tokens × vocab) tensor.  ``ce_mode='gathered'`` keeps the naive path
(logits over the full padded vocab) for the §Perf before/after.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.ctx import ParallelCtx, sp_gather

from .common import ninit


def embed_init(key, cfg, ctx: ParallelCtx):
    vp = cfg.padded_vocab(ctx.tp_size)
    return {"table": ninit(key, (vp, cfg.d_model), scale=0.02,
                           dtype=ctx.param_dtype)}


def embed_specs(cfg, ctx: ParallelCtx):
    return {"table": P(ctx.tp_axis, None)}


def embed_lookup(params, ids, ctx: ParallelCtx, reduce: bool = True):
    """ids: (b, t) token ids (identical on every TP rank!); table local
    shard (V/tp, d).  Masked local gather gives a PARTIAL row (only the
    ids in this rank's vocab range hit); ``reduce=True`` psums over TP.
    Sequence-parallel callers pass reduce=False and reduce-scatter the
    partial over the sequence instead (Megatron embedding pattern)."""
    table = params["table"]
    vloc = table.shape[0]
    start = ctx.tp_rank() * vloc
    loc = ids - start
    ok = (loc >= 0) & (loc < vloc)
    rows = jnp.take(table, jnp.clip(loc, 0, vloc - 1), axis=0)
    rows = jnp.where(ok[..., None], rows, 0).astype(ctx.compute_dtype)
    if reduce and ctx.tp_size > 1:
        rows = ctx.tp_comm.psum(rows)
    return rows


def _chunk_ce(logits_f32, targets, vloc, rank, ctx):
    """Vocab-parallel CE over one token chunk.  logits: (n, vloc)."""
    # stability shift is not a function of x for grad purposes; stop the
    # gradient BEFORE pmax (pmax has no JVP rule)
    mx_loc = jax.lax.stop_gradient(logits_f32.max(-1))
    mx = ctx.tp_comm.pmax(mx_loc)
    ssum = jnp.exp(logits_f32 - mx[:, None]).sum(-1)
    if ctx.tp_size > 1:
        ssum = ctx.tp_comm.psum(ssum)
    loc = targets - rank * vloc
    ok = (loc >= 0) & (loc < vloc)
    tl = jnp.take_along_axis(logits_f32, jnp.clip(loc, 0, vloc - 1)[:, None],
                             axis=1)[:, 0]
    tl = jnp.where(ok, tl, 0.0)
    if ctx.tp_size > 1:
        tl = ctx.tp_comm.psum(tl)
    return -(tl - mx - jnp.log(jnp.maximum(ssum, 1e-30)))


def lm_head_loss(params, x_sp, targets, ctx: ParallelCtx, cfg,
                 chunk: int | None = None):
    """x_sp: (b, t_loc, d) sequence-sharded activations; targets (b, t)
    full local-batch targets.  Returns mean CE over local tokens
    (caller averages over DP).

    vocab_parallel: gather tokens over TP, chunked rematted local-vocab
    logits + psum stats.  gathered: the naive full-vocab path.
    """
    table = params["table"]                      # (V/tp, d) local
    vloc = table.shape[0]
    rank = ctx.tp_rank()
    xg = sp_gather(x_sp, ctx, axis=1)            # (b, t, d)
    b, t, d = xg.shape
    xf = xg.reshape(b * t, d)
    tg = targets.reshape(b * t)

    if ctx.ce_mode == "gathered":
        wt = ctx.tp_comm.all_gather(table, axis=0, tiled=True)
        logits = (xf @ wt.astype(ctx.compute_dtype).T).astype(jnp.float32)
        mx = logits.max(-1)
        lse = mx + jnp.log(jnp.exp(logits - mx[:, None]).sum(-1))
        tl = jnp.take_along_axis(logits, tg[:, None], axis=1)[:, 0]
        return (lse - tl).mean()

    wt = table.astype(ctx.compute_dtype)

    def chunk_loss(args):
        xc, tc = args
        logits = (xc @ wt.T).astype(jnp.float32)
        return _chunk_ce(logits, tc, vloc, rank, ctx)

    n = xf.shape[0]
    chunk = min(chunk or ctx.ce_chunk, n)
    losses = []
    for s in range(0, n, chunk):
        xc, tc = xf[s:s + chunk], tg[s:s + chunk]
        losses.append(jax.checkpoint(chunk_loss)((xc, tc)))
    return jnp.concatenate(losses).mean()


def lm_head_logits(params, x, ctx: ParallelCtx):
    """Decode-time logits: (b, d) -> (b, V/tp) local shard (sampling is
    done with a TP-aware argmax: local top then pmax across ranks)."""
    wt = params["table"].astype(ctx.compute_dtype)
    return x @ wt.T


def tp_sample_candidates(logits_loc, ctx: ParallelCtx, k: int):
    """The TP-aware two-phase sampler's candidate selection.

    Phase 1 (per shard): each vocab shard extracts its local top-``k``
    as ``(value, GLOBAL index)`` pairs — a stable descending sort, so
    equal logits within a shard keep ascending-index order.  Phase 2:
    the shards' candidate lists merge through ``ctx.tp_comm
    .top_k_merge`` (one all_gather of k pairs per rank + a replicated
    sort), which applies the same deterministic tie-break: equal values
    resolve to the LOWEST global vocab index on every backend.

    Returns ``(values, indices)`` of shape ``(..., k)``, value-sorted
    descending, IDENTICAL on every TP rank.  Never materializes the
    unsharded vocab.  ``k=1`` is exactly greedy argmax (``tp_argmax``).
    """
    vloc = logits_loc.shape[-1]
    kk = min(int(k), vloc)
    # lax.top_k breaks ties toward the lower index — exactly the
    # contract — in O(V log k) instead of a full-shard sort
    vals, order = jax.lax.top_k(logits_loc, kk)
    gidx = (order + ctx.tp_rank() * vloc).astype(jnp.int32)
    if ctx.tp_size == 1:
        return vals, gidx
    return ctx.tp_comm.top_k_merge(vals, gidx, kk)


def tp_argmax(logits_loc, ctx: ParallelCtx):
    """Greedy sampling across vocab shards without gathering logits —
    the ``k=1`` case of ``tp_sample_candidates``.  Equal-logit ties
    resolve to the lowest global vocab index on EVERY backend (the old
    pmax-of-candidate-index merge let the winning shard decide, so
    xla/posh/pallas parity held only by luck of the weights)."""
    _, gidx = tp_sample_candidates(logits_loc, ctx, 1)
    return gidx[..., 0]
