"""Blocked online-softmax attention in pure jnp with a custom VJP.

This is the model-side attention used for training and prefill on every
transformer-family architecture.  Why not plain softmax(qkᵀ)v: at 32k
prefill the (t × s) score matrix per head is the memory roofline killer;
why not plain lax.scan flash: scan saves per-step residuals, so the
backward would materialize every probability block — the custom VJP
recomputes them per block instead (FlashAttention-2 backward).

The Pallas kernel in repro.kernels is the TPU-native realization of the
same algorithm (used when ctx.use_pallas on hardware); this jnp version
is what the dry-run lowers, keeping cost_analysis faithful to blocked
attention.

Layout: q (b, tq, hkv, g, dh)   — GQA groups explicit, no KV repetition
        k, v (b, s, hkv, dh)
Causal offsets support sequence-parallel (ctx-layout) query shards via
``q_offset`` (may be traced).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG = -1e30
BIG = 3.0e37  # stand-in for +inf in logsumexp of fully-masked rows


def _mask(rows, cols, causal, window, kv_len):
    m = cols[None, :] < kv_len
    if causal:
        m &= cols[None, :] <= rows[:, None]
    if window is not None:
        m &= cols[None, :] > rows[:, None] - window
    return m  # (tq, kvc)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash_chunk(q, k, v, scale, causal, window, kv_len, block_kv, unroll,
                 q_offset=0):
    out, _ = _flash_chunk_fwd_impl(q, k, v, scale, causal, window, kv_len,
                                   block_kv, unroll, q_offset)
    return out


def _flash_chunk_fwd_impl(q, k, v, scale, causal, window, kv_len, block_kv,
                          unroll, q_offset):
    b, tq, hkv, g, dh = q.shape
    s = k.shape[1]
    nblk = -(-s // block_kv)
    sp = nblk * block_kv
    kp = jnp.pad(k, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    kb = kp.reshape(b, nblk, block_kv, hkv, dh)
    vb = vp.reshape(b, nblk, block_kv, hkv, dh)

    rows = q_offset + jnp.arange(tq)
    qf = q.astype(jnp.float32)

    def body(carry, blk):
        m_prev, l_prev, acc = carry
        kj, vj, j = blk
        cols = j * block_kv + jnp.arange(block_kv)
        sc = jnp.einsum("bihgd,bjhd->bhgij", qf, kj.astype(jnp.float32),
                        preferred_element_type=jnp.float32) * scale
        msk = _mask(rows, cols, causal, window, kv_len)
        sc = jnp.where(msk[None, None, None], sc, NEG)
        m_cur = sc.max(-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(sc - m_new[..., None])
        l_new = l_prev * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgij,bjhd->bhgid", p, vj.astype(jnp.float32),
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, hkv, g, tq), NEG, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, tq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, tq, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), jnp.arange(nblk)),
        unroll=True if unroll else 1)
    out = (acc / jnp.maximum(l, 1e-30)[..., None])
    out = jnp.moveaxis(out, -2, 1).astype(q.dtype)    # (b, tq, hkv, g, dh)
    lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-30)), BIG)
    return out, lse


def _flash_chunk_fwd(q, k, v, scale, causal, window, kv_len, block_kv,
                     unroll, q_offset=0):
    out, lse = _flash_chunk_fwd_impl(q, k, v, scale, causal, window, kv_len,
                                     block_kv, unroll, q_offset)
    return out, (q, k, v, out, lse, q_offset)


def _flash_chunk_bwd(scale, causal, window, kv_len, block_kv, unroll, res,
                     dout):
    q, k, v, out, lse, q_offset = res
    b, tq, hkv, g, dh = q.shape
    s = k.shape[1]
    nblk = -(-s // block_kv)
    sp = nblk * block_kv
    kp = jnp.pad(k, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    kb = jnp.moveaxis(kp.reshape(b, nblk, block_kv, hkv, dh), 1, 0)
    vb = jnp.moveaxis(vp.reshape(b, nblk, block_kv, hkv, dh), 1, 0)

    rows = q_offset + jnp.arange(tq)
    qf = q.astype(jnp.float32)
    dof = jnp.moveaxis(dout.astype(jnp.float32), 1, -2)   # (b,hkv,g,tq,dh)
    of = jnp.moveaxis(out.astype(jnp.float32), 1, -2)
    D = (dof * of).sum(-1)                                 # (b,hkv,g,tq)

    def body(dq, blk):
        kj, vj, j = blk
        cols = j * block_kv + jnp.arange(block_kv)
        sc = jnp.einsum("bihgd,bjhd->bhgij", qf, kj.astype(jnp.float32),
                        preferred_element_type=jnp.float32) * scale
        msk = _mask(rows, cols, causal, window, kv_len)
        sc = jnp.where(msk[None, None, None], sc, NEG)
        p = jnp.exp(sc - lse[..., None])                   # (b,hkv,g,i,j)
        dv_j = jnp.einsum("bhgij,bhgid->bjhd", p, dof,
                          preferred_element_type=jnp.float32)
        dp = jnp.einsum("bhgid,bjhd->bhgij", dof, vj.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
        ds = p * (dp - D[..., None]) * scale
        dq = dq + jnp.einsum("bhgij,bjhd->bihgd", ds, kj.astype(jnp.float32),
                             preferred_element_type=jnp.float32)
        dk_j = jnp.einsum("bhgij,bihgd->bjhd", ds, qf,
                          preferred_element_type=jnp.float32)
        return dq, (dk_j, dv_j)

    dq0 = jnp.zeros((b, tq, hkv, g, dh), jnp.float32)
    dq, (dk_b, dv_b) = jax.lax.scan(body, dq0,
                                    (kb, vb, jnp.arange(nblk)),
                                    unroll=True if unroll else 1)
    dk = jnp.moveaxis(dk_b, 0, 1).reshape(b, sp, hkv, dh)[:, :s]
    dv = jnp.moveaxis(dv_b, 0, 1).reshape(b, sp, hkv, dh)[:, :s]
    import numpy as _np
    d_off = _np.zeros(_np.shape(q_offset), dtype=jax.dtypes.float0)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype), d_off)


_flash_chunk.defvjp(_flash_chunk_fwd, _flash_chunk_bwd)


def blocked_attention(q, k, v, *, causal=True, window: Optional[int] = None,
                      scale: Optional[float] = None, q_offset=0,
                      kv_len: Optional[int] = None, block_q: int = 1024,
                      block_kv: int = 1024, unroll: bool = False):
    """q: (b, tq, h, dh); k, v: (b, s, hkv, dh) -> (b, tq, h, dh).

    Queries are chunked with a *Python* loop so causal chunks only see
    the KV prefix they need (flop-exact causal accounting in the HLO);
    each chunk runs the custom-VJP flash over its KV blocks.
    ``q_offset`` is the global position of q row 0 (sequence-parallel
    shards); must be static-or-traced consistently with causal slicing:
    when traced, the full KV range is used and masking does the work.
    """
    b, tq, h, dh = q.shape
    s = k.shape[1]
    hkv = k.shape[2]
    g = h // hkv
    kv_len = s if kv_len is None else kv_len
    scale = dh ** -0.5 if scale is None else scale
    qg = q.reshape(b, tq, hkv, g, dh)

    block_q = min(block_q, tq)
    static_off = isinstance(q_offset, int)
    outs = []
    for qs in range(0, tq, block_q):
        qc = qg[:, qs:qs + block_q]
        off = q_offset + qs
        if causal and static_off:
            hi = min(s, off + qc.shape[1])
            nb = max(1, -(-hi // block_kv))
            k_use, v_use = k[:, :nb * block_kv], v[:, :nb * block_kv]
            kl = min(kv_len, k_use.shape[1])
        else:
            k_use, v_use, kl = k, v, kv_len
        o = _flash_chunk(qc, k_use, v_use, scale, causal, window, kl,
                         block_kv, unroll, off)
        outs.append(o)
    out = jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]
    return out.reshape(b, tq, h, dh)


def decode_attention(q, kcache, vcache, cur_len, *, scale=None,
                     window: Optional[int] = None, pos=None):
    """Single-step attention against a cache.  q: (b, h, dh);
    kcache/vcache: (b, S, hkv, dh); cur_len: tokens valid (traced ok).
    Returns (b, h, dh).  Dense row — S × dh per head is decode-sized."""
    b, h, dh = q.shape
    s, hkv = kcache.shape[1], kcache.shape[2]
    g = h // hkv
    scale = dh ** -0.5 if scale is None else scale
    qg = q.reshape(b, hkv, g, dh).astype(jnp.float32)
    kf = kcache.astype(jnp.float32)
    sc = jnp.einsum("bhgd,bshd->bhgs", qg, kf,
                    preferred_element_type=jnp.float32) * scale
    idx = jnp.arange(s)
    msk = idx[None] < cur_len if jnp.ndim(cur_len) else idx < cur_len
    if window is not None and pos is not None:
        lo = pos - window
        msk = msk & (idx > lo if jnp.ndim(lo) == 0 else idx[None] > lo)
    sc = jnp.where(jnp.broadcast_to(msk, sc.shape[:-1] + (s,))
                   if msk.ndim == 1 else msk[:, None, None, :], sc, NEG)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p, vcache.astype(jnp.float32))
    return o.reshape(b, h, dh).astype(q.dtype)


def decode_attention_partial(q, kcache, vcache, valid_mask, *, scale=None):
    """Distributed decode: this rank holds a slice of the KV sequence;
    returns unnormalized (acc, m, l) for flash-combining across ranks
    via pmax/psum.  q: (b, h, dh); caches (b, s_loc, hkv, dh);
    valid_mask: (b, s_loc) bool."""
    b, h, dh = q.shape
    s, hkv = kcache.shape[1], kcache.shape[2]
    g = h // hkv
    scale = dh ** -0.5 if scale is None else scale
    qg = q.reshape(b, hkv, g, dh).astype(jnp.float32)
    sc = jnp.einsum("bhgd,bshd->bhgs", qg, kcache.astype(jnp.float32),
                    preferred_element_type=jnp.float32) * scale
    sc = jnp.where(valid_mask[:, None, None, :], sc, NEG)
    m = sc.max(-1)                                        # (b,hkv,g)
    p = jnp.exp(sc - m[..., None])
    p = jnp.where(valid_mask[:, None, None, :], p, 0.0)
    l = p.sum(-1)
    acc = jnp.einsum("bhgs,bshd->bhgd", p, vcache.astype(jnp.float32))
    return acc, m, l


def flash_combine(acc, m, l, axis_combine):
    """Combine partial (acc, m, l) across ranks.  ``axis_combine`` is a
    callable tree: {'pmax': f, 'psum': f} supplied by the comm layer."""
    m_glob = axis_combine["pmax"](m)
    corr = jnp.exp(m - m_glob)
    l_glob = axis_combine["psum"](l * corr)
    acc_glob = axis_combine["psum"](acc * corr[..., None])
    return acc_glob / jnp.maximum(l_glob, 1e-30)[..., None]
