"""Attention layer — head-parallel and ctx-parallel (sequence) layouts.

layout "head": query heads divide TP ⇒ Megatron column/row parallel with
    SP activations; KV heads sharded when possible, otherwise each rank
    computes only the KV head(s) its query group needs (GQA replication).
layout "ctx": heads do NOT divide TP (minitron 24H, gemma 8H, whisper 8H)
    ⇒ queries stay sequence-sharded (every rank keeps all heads for its
    token slice), K/V are projected locally and all-gathered over TP.
    Decode then holds the KV cache sequence-sharded with a distributed
    online-softmax combine (flash-combine psum/pmax).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.ctx import (ParallelCtx, grad_sync, sp_gather,
                                sp_scatter)

from .common import apply_rope, ninit, rmsnorm, rmsnorm_init
from .flash import (blocked_attention, decode_attention,
                    decode_attention_partial, flash_combine)


def _sync(w, ctx, scale=1.0):
    if ctx.tp_size == 1:
        return w
    return grad_sync(w, ctx.tp_comm, scale)


def _ctx_varying(ctx):
    """ctx-layout activations are rank-varying only under SP."""
    return ctx.sp and ctx.tp_size > 1


def _layout(cfg, ctx):
    return cfg.attn_layout(ctx.tp_size)


def attn_init(key, cfg, ctx: ParallelCtx, cross: bool = False):
    d, dh = cfg.d_model, cfg.head_dim
    h, hkv = cfg.n_heads, cfg.n_kv
    ks = jax.random.split(key, 6)
    p = {
        "wq": ninit(ks[0], (d, h * dh), dtype=ctx.param_dtype),
        "wk": ninit(ks[1], (d, hkv * dh), dtype=ctx.param_dtype),
        "wv": ninit(ks[2], (d, hkv * dh), dtype=ctx.param_dtype),
        "wo": ninit(ks[3], (h * dh, d), dtype=ctx.param_dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(dh, ctx.param_dtype)
        p["k_norm"] = rmsnorm_init(dh, ctx.param_dtype)
    return p


def attn_specs(cfg, ctx: ParallelCtx, cross: bool = False):
    tp = ctx.tp_axis
    layout = _layout(cfg, ctx)
    if layout == "head":
        kv_spec = P(None, tp) if cfg.n_kv % ctx.tp_size == 0 else P(None, None)
        s = {"wq": P(None, tp), "wk": kv_spec, "wv": kv_spec,
             "wo": P(tp, None)}
    else:
        s = {"wq": P(None, None), "wk": P(None, None), "wv": P(None, None),
             "wo": P(None, None)}
    if cfg.qk_norm:
        s["q_norm"] = {"scale": P(None)}
        s["k_norm"] = {"scale": P(None)}
    return s


def _project_kv_head_layout(p, xf, cfg, ctx):
    """Per-rank K/V in head layout.  When n_kv < tp the weights are
    replicated and each rank slices the single KV head its query group
    reads (compute duplicated tp/n_kv ways — projection flops are
    negligible; KV cache stays 1 head/rank)."""
    dh, hkv, h = cfg.head_dim, cfg.n_kv, cfg.n_heads
    tp = ctx.tp_size
    if hkv % tp == 0:
        k = xf @ p["wk"].astype(xf.dtype)       # (b,t,kvpr*dh) local shard
        v = xf @ p["wv"].astype(xf.dtype)
        kvpr = hkv // tp
    else:
        group = h // hkv
        hpr = h // tp
        my_kv = (ctx.tp_rank() * hpr) // group   # traced
        wk = jax.lax.dynamic_slice_in_dim(p["wk"], my_kv * dh, dh, axis=1)
        wv = jax.lax.dynamic_slice_in_dim(p["wv"], my_kv * dh, dh, axis=1)
        k = xf @ wk.astype(xf.dtype)
        v = xf @ wv.astype(xf.dtype)
        kvpr = 1
    b, t = xf.shape[0], xf.shape[1]
    return (k.reshape(b, t, kvpr, dh), v.reshape(b, t, kvpr, dh), kvpr)


def project_qkv(p, xf, pos, cfg, ctx: ParallelCtx):
    """Head-layout q/k/v projection with qk-norm and rope at ``pos``
    ((t,) or (b, t) positions).  The single definition of the
    projection convention, shared by training/prefill attention below
    and the serving engine (``repro.serve.engine``) — so the two paths
    cannot drift numerically (their token-stream parity is asserted in
    tests/test_serve.py)."""
    dh = cfg.head_dim
    b, t, _ = xf.shape
    hpr = cfg.heads_per_rank(ctx.tp_size)
    q = (xf @ p["wq"].astype(xf.dtype)).reshape(b, t, hpr, dh)
    k, v, _ = _project_kv_head_layout(p, xf, cfg, ctx)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    if cfg.use_rope:
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    return q, k, v


def self_attention(p, x_sp, ctx: ParallelCtx, cfg, *, causal=True,
                   window: Optional[int] = None, pos0: int = 0):
    """x_sp: (b, t_loc, d) sequence-sharded (or full when sp off).
    Returns same sharding."""
    layout = _layout(cfg, ctx)
    dh = cfg.head_dim
    cd = ctx.compute_dtype
    if layout == "head":
        xf = sp_gather(x_sp, ctx, axis=1).astype(cd)      # (b, t, d)
        b, t, _ = xf.shape
        q, k, v = project_qkv(p, xf, pos0 + jnp.arange(t), cfg, ctx)
        o = blocked_attention(q, k, v, causal=causal, window=window,
                              block_q=ctx.attn_block_q,
                              block_kv=ctx.attn_block_kv, unroll=ctx.unroll)
        o = o.reshape(b, t, -1)
        out = o @ p["wo"].astype(cd)                       # partial (b,t,d)
        return sp_scatter(out, ctx, axis=1)
    # --- ctx layout: seq-sharded queries, gathered KV ---
    xl = x_sp.astype(cd)                                   # (b, t_loc, d)
    b, tl, _ = xl.shape
    h, hkv = cfg.n_heads, cfg.n_kv
    q = (xl @ p["wq"].astype(cd)).reshape(b, tl, h, dh)
    k = (xl @ p["wk"].astype(cd)).reshape(b, tl, hkv, dh)
    v = (xl @ p["wv"].astype(cd)).reshape(b, tl, hkv, dh)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    if ctx.sp and ctx.tp_size > 1:
        off = ctx.tp_rank() * tl
    else:
        off = 0
    if cfg.use_rope:
        qpos = pos0 + off + jnp.arange(tl)
        q = apply_rope(q, qpos, cfg.rope_theta)
        k = apply_rope(k, qpos, cfg.rope_theta)
    if ctx.sp and ctx.tp_size > 1:
        kf = ctx.tp_comm.all_gather(k, axis=1)
        vf = ctx.tp_comm.all_gather(v, axis=1)
    else:
        kf, vf = k, v
    o = blocked_attention(q, kf, vf, causal=causal, window=window,
                          q_offset=off, block_q=ctx.attn_block_q,
                          block_kv=ctx.attn_block_kv, unroll=ctx.unroll)
    out = o.reshape(b, tl, h * dh) @ p["wo"].astype(cd)
    return out                                             # stays seq-sharded


def cross_attention(p, x_sp, enc_kv, ctx: ParallelCtx, cfg):
    """enc_kv: precomputed (k, v) each (b, S_enc, hkv_eff, dh) — full
    sequence, replicated (whisper encoder out / vlm patch embeddings).
    In head layout they carry this rank's KV heads only."""
    layout = _layout(cfg, ctx)
    dh = cfg.head_dim
    cd = ctx.compute_dtype
    k, v = enc_kv
    if layout == "head":
        xf = sp_gather(x_sp, ctx, axis=1).astype(cd)
        b, t, _ = xf.shape
        hpr = cfg.heads_per_rank(ctx.tp_size)
        q = (xf @ p["wq"].astype(cd)).reshape(b, t, hpr, dh)
        o = blocked_attention(q, k, v, causal=False,
                              block_q=ctx.attn_block_q,
                              block_kv=ctx.attn_block_kv, unroll=ctx.unroll)
        out = o.reshape(b, t, hpr * dh) @ p["wo"].astype(cd)
        return sp_scatter(out, ctx, axis=1)
    xl = x_sp.astype(cd)
    b, tl, _ = xl.shape
    h = cfg.n_heads
    q = (xl @ p["wq"].astype(cd)).reshape(b, tl, h, dh)
    o = blocked_attention(q, k, v, causal=False,
                          block_q=ctx.attn_block_q,
                          block_kv=ctx.attn_block_kv, unroll=ctx.unroll)
    return o.reshape(b, tl, h * dh) @ p["wo"].astype(cd)


def cross_kv(p, enc, ctx: ParallelCtx, cfg):
    """Project encoder output / image embeddings to this rank's KV."""
    layout = _layout(cfg, ctx)
    dh, hkv = cfg.head_dim, cfg.n_kv
    cd = ctx.compute_dtype
    ef = enc.astype(cd)
    b, s, _ = ef.shape
    if layout == "head":
        k, v, kvpr = _project_kv_head_layout(p, ef, cfg, ctx)
        return k, v
    k = (ef @ p["wk"].astype(cd)).reshape(b, s, hkv, dh)
    v = (ef @ p["wv"].astype(cd)).reshape(b, s, hkv, dh)
    return k, v


# ----------------------------------------------------------------------
# decode
# ----------------------------------------------------------------------
def init_cache(cfg, ctx: ParallelCtx, batch_local: int, max_len: int,
               dtype=jnp.bfloat16):
    """KV cache per rank.  head layout: (b, S, kvpr, dh) with this
    rank's KV heads.  ctx layout: (b, S/tp, n_kv, dh) sequence-sharded.
    SWA ring cache: S is min(max_len, window)."""
    layout = _layout(cfg, ctx)
    dh = cfg.head_dim
    s = max_len if cfg.swa_window is None else min(max_len, cfg.swa_window)
    if layout == "head":
        kvpr = cfg.kv_per_rank(ctx.tp_size)
        shape = (batch_local, s, kvpr, dh)
    else:
        sl = -(-s // ctx.tp_size) if ctx.tp_size > 1 else s
        shape = (batch_local, sl, cfg.n_kv, dh)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_specs(cfg, ctx: ParallelCtx):
    dp = ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]
    return {"k": P(dp, None, None, None), "v": P(dp, None, None, None)}


def decode_self_attention(p, x, cache, pos, ctx: ParallelCtx, cfg):
    """One-token decode.  x: (b, d) replicated over TP; cache per rank;
    pos: scalar current position (traced).  Returns (out (b, d), cache).
    """
    layout = _layout(cfg, ctx)
    dh, h, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv
    cd = ctx.compute_dtype
    xf = x.astype(cd)
    b = xf.shape[0]
    s_cache = cache["k"].shape[1]
    win = cfg.swa_window
    # ring-buffer slot under SWA
    slot = pos % s_cache if win is not None else pos

    if layout == "head":
        hpr = cfg.heads_per_rank(ctx.tp_size)
        q = (xf @ p["wq"].astype(cd)).reshape(b, hpr, dh)
        k, v, kvpr = _project_kv_head_layout(p, xf[:, None], cfg, ctx)
        k, v = k[:, 0], v[:, 0]                            # (b, kvpr, dh)
        if cfg.qk_norm:
            q = rmsnorm(p["q_norm"], q)
            k = rmsnorm(p["k_norm"], k)
        if cfg.use_rope:
            posv = jnp.full((b,), pos)
            q = apply_rope(q[:, None], posv[:, None], cfg.rope_theta)[:, 0]
            k = apply_rope(k[:, None], posv[:, None], cfg.rope_theta)[:, 0]
        ck = jax.lax.dynamic_update_slice(cache["k"],
                                          k[:, None].astype(cache["k"].dtype),
                                          (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"],
                                          v[:, None].astype(cache["v"].dtype),
                                          (0, slot, 0, 0))
        cur = jnp.minimum(pos + 1, s_cache)
        o = decode_attention(q, ck, cv, cur)
        out = o.reshape(b, hpr * dh) @ p["wo"].astype(cd)
        out = ctx.tp_comm.psum(out)
        return out, {"k": ck, "v": cv}

    # --- ctx layout: sequence-sharded cache + flash-combine ---
    q = (xf @ p["wq"].astype(cd)).reshape(b, h, dh)
    k = (xf @ p["wk"].astype(cd)).reshape(b, hkv, dh)
    v = (xf @ p["wv"].astype(cd)).reshape(b, hkv, dh)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    if cfg.use_rope:
        posv = jnp.full((b,), pos)
        q = apply_rope(q[:, None], posv[:, None], cfg.rope_theta)[:, 0]
        k = apply_rope(k[:, None], posv[:, None], cfg.rope_theta)[:, 0]
    sl = cache["k"].shape[1]
    if ctx.tp_size > 1:
        rank = ctx.tp_rank()
        lo = rank * sl
        mine = (slot >= lo) & (slot < lo + sl)
        at = jnp.clip(slot - lo, 0, sl - 1)
        ck = jax.lax.dynamic_update_slice(
            cache["k"], jnp.where(mine, k, jax.lax.dynamic_slice(
                cache["k"], (0, at, 0, 0), (b, 1, hkv, dh))[:, 0]
            )[:, None].astype(cache["k"].dtype), (0, at, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], jnp.where(mine, v, jax.lax.dynamic_slice(
                cache["v"], (0, at, 0, 0), (b, 1, hkv, dh))[:, 0]
            )[:, None].astype(cache["v"].dtype), (0, at, 0, 0))
        cur = jnp.minimum(pos + 1, s_cache)
        gpos = lo + jnp.arange(sl)
        valid = jnp.broadcast_to(gpos[None] < cur, (b, sl))
        acc, m, l = decode_attention_partial(q, ck, cv, valid)
        combine = {
            "pmax": ctx.tp_comm.pmax,
            "psum": ctx.tp_comm.psum,
        }
        o = flash_combine(acc, m, l, combine).astype(cd)
        out = o.reshape(b, h * dh) @ p["wo"].astype(cd)
        return out, {"k": ck, "v": cv}
    ck = jax.lax.dynamic_update_slice(cache["k"],
                                      k[:, None].astype(cache["k"].dtype),
                                      (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"],
                                      v[:, None].astype(cache["v"].dtype),
                                      (0, slot, 0, 0))
    cur = jnp.minimum(pos + 1, s_cache)
    o = decode_attention(q, ck, cv, cur)
    out = o.reshape(b, h * dh) @ p["wo"].astype(cd)
    return out, {"k": ck, "v": cv}


def decode_cross_attention(p, x, enc_kv, ctx: ParallelCtx, cfg):
    """Decode-time cross attention (cache = precomputed enc_kv)."""
    layout = _layout(cfg, ctx)
    dh = cfg.head_dim
    cd = ctx.compute_dtype
    xf = x.astype(cd)
    b = xf.shape[0]
    k, v = enc_kv
    if layout == "head":
        hpr = cfg.heads_per_rank(ctx.tp_size)
        q = (xf @ p["wq"].astype(cd)).reshape(b, hpr, dh)
        o = decode_attention(q, k, v, k.shape[1])
        out = o.reshape(b, hpr * dh) @ p["wo"].astype(cd)
        return ctx.tp_comm.psum(out)
    h = cfg.n_heads
    q = (xf @ p["wq"].astype(cd)).reshape(b, h, dh)
    o = decode_attention(q, k, v, k.shape[1])
    return o.reshape(b, h * dh) @ p["wo"].astype(cd)
