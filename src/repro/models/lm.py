"""Decoder-only LM assembly for every non-enc-dec assigned architecture.

Families:
  dense   minitron-4b, gemma-2b, qwen3-8b, h2o-danube-3-4b
  moe     qwen2-moe-a2.7b, qwen3-moe-30b-a3b
  ssm     rwkv6-3b (time-mix/channel-mix blocks)
  hybrid  zamba2-7b (mamba groups + weight-shared attention block)
  vlm     llama-3.2-vision-90b (cross-attn image layers every 5th)

Homogeneous layers are stacked and scanned (HLO size O(1) in depth);
``ctx.remat`` wraps scan bodies in jax.checkpoint.  All functions run
INSIDE shard_map; batch dims are per-device local.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.ctx import ParallelCtx, sp_gather, sp_scatter

from . import attention as attn
from . import embed as emb
from . import mlp as ff
from . import rwkv as rk
from . import ssm as sm
from .common import norm_apply, norm_init, norm_sp, norm_specs


def _sync1(w, ctx):
    """Identity — replicated-param grad completion is spec-driven at the
    train-step level (see repro/train/step.py)."""
    del ctx
    return w


def _norm_kind(cfg):
    return "layer" if cfg.family == "encdec" else "rms"


def _stack_init(key, n, init_fn):
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def _stack_specs(spec_tree):
    return jax.tree.map(lambda s: P(None, *tuple(s)), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _scan(blocks, x, fn, ctx, length=None):
    def body(carry, layer_params):
        return fn(layer_params, carry), None
    if ctx.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, blocks, length=length,
                        unroll=True if ctx.unroll else 1)
    return x


# ======================================================================
# block definitions
# ======================================================================
def _dense_block_init(cfg, ctx):
    nk = _norm_kind(cfg)

    def init(key):
        k1, k2 = jax.random.split(key)
        p = {"ln1": norm_init(nk, cfg.d_model, ctx.param_dtype),
             "attn": attn.attn_init(k1, cfg, ctx),
             "ln2": norm_init(nk, cfg.d_model, ctx.param_dtype)}
        if cfg.moe:
            p["mlp"] = ff.moe_init(k2, cfg, ctx)
        else:
            p["mlp"] = ff.mlp_init(k2, cfg, ctx)
        return p
    return init


def _dense_block_specs(cfg, ctx):
    nk = _norm_kind(cfg)
    return {"ln1": norm_specs(nk), "attn": attn.attn_specs(cfg, ctx),
            "ln2": norm_specs(nk),
            "mlp": ff.moe_specs(cfg, ctx) if cfg.moe
            else ff.mlp_specs(cfg, ctx)}


def _dense_block_apply(p, x, ctx, cfg, causal=True):
    nk = _norm_kind(cfg)
    h = attn.self_attention(p["attn"], norm_sp(nk, p["ln1"], x, ctx), ctx, cfg,
                            causal=causal, window=cfg.swa_window)
    x = x + h
    m = (ff.moe_apply if cfg.moe else ff.mlp_apply)(
        p["mlp"], norm_sp(nk, p["ln2"], x, ctx), ctx, cfg)
    return x + m


def _cross_block_init(cfg, ctx):
    def init(key):
        k1, k2 = jax.random.split(key)
        return {"xln": norm_init("rms", cfg.d_model, ctx.param_dtype),
                "xattn": attn.attn_init(k1, cfg, ctx, cross=True),
                "xgate": jnp.zeros((1,), ctx.param_dtype),
                "ln2": norm_init("rms", cfg.d_model, ctx.param_dtype),
                "mlp": ff.mlp_init(k2, cfg, ctx),
                "mgate": jnp.zeros((1,), ctx.param_dtype)}
    return init


def _cross_block_specs(cfg, ctx):
    return {"xln": norm_specs("rms"),
            "xattn": attn.attn_specs(cfg, ctx, cross=True),
            "xgate": P(None), "ln2": norm_specs("rms"),
            "mlp": ff.mlp_specs(cfg, ctx), "mgate": P(None)}


def _cross_block_apply(p, x, img_kv, ctx, cfg):
    """llama3.2-style gated cross-attention layer."""
    h = attn.cross_attention(p["xattn"], norm_sp("rms", p["xln"], x, ctx),
                             img_kv, ctx, cfg)
    x = x + jnp.tanh(_sync1(p["xgate"], ctx).astype(h.dtype)) * h
    m = ff.mlp_apply(p["mlp"], norm_sp("rms", p["ln2"], x, ctx), ctx, cfg)
    return x + jnp.tanh(_sync1(p["mgate"], ctx).astype(m.dtype)) * m


def _rwkv_block_init(cfg, ctx):
    def init(key):
        k1, k2 = jax.random.split(key)
        return {"ln1": norm_init("layer", cfg.d_model, ctx.param_dtype),
                "tm": rk.timemix_init(k1, cfg, ctx),
                "ln2": norm_init("layer", cfg.d_model, ctx.param_dtype),
                "cm": rk.chanmix_init(k2, cfg, ctx)}
    return init


def _rwkv_block_specs(cfg, ctx):
    return {"ln1": norm_specs("layer"), "tm": rk.timemix_specs(cfg, ctx),
            "ln2": norm_specs("layer"), "cm": rk.chanmix_specs(cfg, ctx)}


def _rwkv_block_apply(p, x, ctx, cfg):
    x = x + rk.timemix_apply(p["tm"], norm_sp("layer", p["ln1"], x, ctx),
                             ctx, cfg)
    x = x + rk.chanmix_apply(p["cm"], norm_sp("layer", p["ln2"], x, ctx),
                             ctx, cfg)
    return x


def _mamba_block_init(cfg, ctx):
    def init(key):
        return {"ln": norm_init("rms", cfg.d_model, ctx.param_dtype),
                "mamba": sm.mamba_init(key, cfg, ctx)}
    return init


def _mamba_block_specs(cfg, ctx):
    return {"ln": norm_specs("rms"), "mamba": sm.mamba_specs(cfg, ctx)}


def _mamba_block_apply(p, x, ctx, cfg):
    return x + sm.mamba_apply(p["mamba"], norm_sp("rms", p["ln"], x, ctx),
                              ctx, cfg)


# ======================================================================
# model init / specs
# ======================================================================
def init(key, cfg, ctx: ParallelCtx):
    ks = jax.random.split(key, 8)
    params: dict[str, Any] = {"embed": emb.embed_init(ks[0], cfg, ctx),
                              "ln_f": norm_init(_norm_kind(cfg), cfg.d_model,
                                                ctx.param_dtype)}
    fam = cfg.family
    if fam in ("dense", "moe"):
        params["blocks"] = _stack_init(ks[1], cfg.n_layers,
                                       _dense_block_init(cfg, ctx))
    elif fam == "vlm":
        k = cfg.cross_attn_every
        ng = cfg.n_layers // k
        params["blocks"] = _stack_init(ks[1], ng * (k - 1),
                                       _dense_block_init(cfg, ctx))
        params["cross"] = _stack_init(ks[2], ng, _cross_block_init(cfg, ctx))
    elif fam == "ssm":
        params["blocks"] = _stack_init(ks[1], cfg.n_layers,
                                       _rwkv_block_init(cfg, ctx))
    elif fam == "hybrid":
        k = cfg.shared_attn_every
        ng, rem = divmod(cfg.n_layers, k)
        params["blocks"] = _stack_init(ks[1], ng * k,
                                       _mamba_block_init(cfg, ctx))
        if rem:
            params["tail"] = _stack_init(ks[3], rem,
                                         _mamba_block_init(cfg, ctx))
        params["shared"] = _dense_block_init(cfg, ctx)(ks[2])
    else:
        raise ValueError(f"lm.init: unknown family {fam}")
    if not cfg.tie_embeddings:
        params["head"] = emb.embed_init(ks[4], cfg, ctx)
    return params


def specs(cfg, ctx: ParallelCtx):
    s: dict[str, Any] = {"embed": emb.embed_specs(cfg, ctx),
                         "ln_f": norm_specs(_norm_kind(cfg))}
    fam = cfg.family
    if fam in ("dense", "moe"):
        s["blocks"] = _stack_specs(_dense_block_specs(cfg, ctx))
    elif fam == "vlm":
        s["blocks"] = _stack_specs(_dense_block_specs(cfg, ctx))
        s["cross"] = _stack_specs(_cross_block_specs(cfg, ctx))
    elif fam == "ssm":
        s["blocks"] = _stack_specs(_rwkv_block_specs(cfg, ctx))
    elif fam == "hybrid":
        s["blocks"] = _stack_specs(_mamba_block_specs(cfg, ctx))
        if cfg.n_layers % cfg.shared_attn_every:
            s["tail"] = _stack_specs(_mamba_block_specs(cfg, ctx))
        s["shared"] = _dense_block_specs(cfg, ctx)
    if not cfg.tie_embeddings:
        s["head"] = emb.embed_specs(cfg, ctx)
    return s


# ======================================================================
# forward
# ======================================================================
def _embed_sp(params, ids, ctx):
    """ids (b, t) full on every rank -> sequence-sharded (b, t/tp, d).
    Vocab-parallel lookup gives partial rows for ALL tokens; the TP
    reduction and the SP sequence-scatter fuse into one reduce-scatter."""
    partial = emb.embed_lookup(params["embed"], ids, ctx, reduce=False)
    if ctx.tp_size == 1:
        return partial
    return sp_scatter(partial, ctx, axis=1)


def forward(params, ids, ctx: ParallelCtx, cfg,
            img_embeds: Optional[jax.Array] = None):
    """ids: (b, t) -> sequence-sharded hidden states (b, t/tp, d)."""
    x = _embed_sp(params, ids, ctx)
    fam = cfg.family
    if fam in ("dense", "moe"):
        x = _scan(params["blocks"], x,
                  lambda p, h: _dense_block_apply(p, h, ctx, cfg), ctx)
    elif fam == "vlm":
        k = cfg.cross_attn_every
        ng = cfg.n_layers // k
        for g in range(ng):
            blocks_g = jax.tree.map(
                lambda a: jax.lax.dynamic_slice_in_dim(
                    a, g * (k - 1), k - 1, axis=0), params["blocks"])
            x = _scan(blocks_g, x,
                      lambda p, h: _dense_block_apply(p, h, ctx, cfg), ctx)
            cross_g = jax.tree.map(lambda a: a[g], params["cross"])
            kv_g = attn.cross_kv(cross_g["xattn"], img_embeds, ctx, cfg)
            x = _cross_block_apply(cross_g, x, kv_g, ctx, cfg)
    elif fam == "ssm":
        x = _scan(params["blocks"], x,
                  lambda p, h: _rwkv_block_apply(p, h, ctx, cfg), ctx)
    elif fam == "hybrid":
        k = cfg.shared_attn_every
        ng = cfg.n_layers // k
        for g in range(ng):
            blocks_g = jax.tree.map(
                lambda a: jax.lax.dynamic_slice_in_dim(a, g * k, k, axis=0),
                params["blocks"])
            x = _scan(blocks_g, x,
                      lambda p, h: _mamba_block_apply(p, h, ctx, cfg), ctx)
            x = _dense_block_apply(params["shared"], x, ctx, cfg)
        if "tail" in params:
            x = _scan(params["tail"], x,
                      lambda p, h: _mamba_block_apply(p, h, ctx, cfg), ctx)
    return norm_sp(_norm_kind(cfg), params["ln_f"], x, ctx)


def loss_fn(params, batch, ctx: ParallelCtx, cfg, for_grad: bool = False):
    """batch: {'tokens': (b, t+1)} (+ 'img_embeds' for vlm).  Mean CE.

    for_grad=True returns the SINGLE-SEED loss: the replica-local loss
    masked to TP rank 0.  Inside shard_map a replicated scalar output is
    seeded with cotangent 1 on EVERY rank, so differentiating the
    replicated loss multiplies all grads by tp; masking to one rank
    makes jax.grad produce exactly the replica-local gradient, which the
    train step then completes per-spec (see repro/train/step.py).
    """
    tokens = batch["tokens"]
    ids, targets = tokens[:, :-1], tokens[:, 1:]
    x = forward(params, ids, ctx, cfg, img_embeds=batch.get("img_embeds"))
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    loss = emb.lm_head_loss(head, x, targets, ctx, cfg)
    if for_grad:
        if ctx.tp_size > 1:
            loss = jnp.where(jax.lax.axis_index(ctx.tp_axis) == 0, loss, 0.0)
        return loss
    # display value: mean over DP replicas
    loss = ctx.dp_comm.pmean(loss)
    return loss


# ======================================================================
# serving: prefill + decode
# ======================================================================
def init_decode_state(cfg, ctx: ParallelCtx, batch_local: int, max_len: int):
    fam = cfg.family
    if fam in ("dense", "moe"):
        mk = lambda: attn.init_cache(cfg, ctx, batch_local, max_len)
        return {"cache": _stack_state(mk, cfg.n_layers),
                "pos": jnp.zeros((), jnp.int32)}
    if fam == "vlm":
        k = cfg.cross_attn_every
        ng = cfg.n_layers // k
        return {"cache": _stack_state(
                    lambda: attn.init_cache(cfg, ctx, batch_local, max_len),
                    ng * (k - 1)),
                "cross_cache": _stack_state(
                    lambda: attn.init_cache(cfg, ctx, batch_local, max_len),
                    ng),  # replaced by enc kv at prefill
                "pos": jnp.zeros((), jnp.int32)}
    if fam == "ssm":
        d = cfg.d_model
        hl = ((cfg.rwkv_padded_heads or cfg.n_heads) // ctx.tp_size
              if ctx.tp_size > 1 else (cfg.rwkv_padded_heads or cfg.n_heads))
        dh = cfg.rwkv_head_dim
        mk = lambda: {"S": jnp.zeros((batch_local, hl, dh, dh), jnp.float32),
                      "x_prev_tm": jnp.zeros((batch_local, d), jnp.float32),
                      "x_prev_cm": jnp.zeros((batch_local, d), jnp.float32)}
        return {"cache": _stack_state(mk, cfg.n_layers),
                "pos": jnp.zeros((), jnp.int32)}
    if fam == "hybrid":
        k = cfg.shared_attn_every
        ng, rem = divmod(cfg.n_layers, k)
        st = {"cache": _stack_state(
                  lambda: sm.mamba_init_state(cfg, ctx, batch_local), ng * k),
              "shared_cache": attn.init_cache(cfg, ctx, batch_local, max_len),
              "pos": jnp.zeros((), jnp.int32)}
        if rem:
            st["tail_cache"] = _stack_state(
                lambda: sm.mamba_init_state(cfg, ctx, batch_local), rem)
        return st
    raise ValueError(fam)


def _stack_state(mk, n):
    one = mk()
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape).copy()
                        if hasattr(a, "shape") else a, one)


def decode_step(params, token, state, ctx: ParallelCtx, cfg,
                img_kv=None):
    """token: (b,) int32; returns (next_token (b,), new_state).
    One serve step: embed -> blocks (cache update) -> head -> greedy."""
    x = emb.embed_lookup(params["embed"], token[:, None], ctx)[:, 0]
    pos = state["pos"]
    fam = cfg.family
    new_state = dict(state)

    if fam in ("dense", "moe"):
        def body(h, inputs):
            p, cache = inputs
            hh, new_cache = _decode_dense_block(p, h, cache, pos, ctx, cfg)
            return hh, new_cache
        x, new_cache = jax.lax.scan(body, x,
                                    (params["blocks"], state["cache"]),
                                    unroll=True if ctx.unroll else 1)
        new_state["cache"] = new_cache
    elif fam == "vlm":
        k = cfg.cross_attn_every
        ng = cfg.n_layers // k
        caches = state["cache"]
        new_caches = []
        for g in range(ng):
            for i in range(k - 1):
                li = g * (k - 1) + i
                p = jax.tree.map(lambda a: a[li], params["blocks"])
                c = jax.tree.map(lambda a: a[li], caches)
                x, nc = _decode_dense_block(p, x, c, pos, ctx, cfg)
                new_caches.append(nc)
            if img_kv is None:
                raise ValueError("vlm decode_step requires img_kv "
                                 "(precomputed per-cross-layer image KV)")
            cg = jax.tree.map(lambda a: a[g], params["cross"])
            h = attn.decode_cross_attention(
                cg["xattn"], norm_apply("rms", cg["xln"], x),
                jax.tree.map(lambda a: a[g], img_kv), ctx, cfg)
            x = x + jnp.tanh(cg["xgate"].astype(h.dtype)) * h
            m = _decode_mlp(cg["mlp"], norm_apply("rms", cg["ln2"], x),
                            ctx, cfg)
            x = x + jnp.tanh(cg["mgate"].astype(m.dtype)) * m
        new_state["cache"] = jax.tree.map(
            lambda *xs: jnp.stack(xs), *new_caches)
    elif fam == "ssm":
        def body(h, inputs):
            p, cache = inputs
            hin = norm_apply("layer", p["ln1"], h)
            o, tm_new = rk.timemix_decode(
                p["tm"], hin, {"S": cache["S"],
                               "x_prev": cache["x_prev_tm"]}, ctx, cfg)
            h = h + o
            hin2 = norm_apply("layer", p["ln2"], h)
            o2, cm_new = rk.chanmix_decode(
                p["cm"], hin2, {"x_prev": cache["x_prev_cm"]}, ctx, cfg)
            h = h + o2
            return h, {"S": tm_new["S"], "x_prev_tm": tm_new["x_prev"],
                       "x_prev_cm": cm_new["x_prev"]}
        x, new_cache = jax.lax.scan(body, x,
                                    (params["blocks"], state["cache"]),
                                    unroll=True if ctx.unroll else 1)
        new_state["cache"] = new_cache
    elif fam == "hybrid":
        k = cfg.shared_attn_every
        ng, rem = divmod(cfg.n_layers, k)
        shared_cache = state["shared_cache"]
        def mbody(h, inputs):
            p, cache = inputs
            o, nc = sm.mamba_decode(p["mamba"],
                                    norm_apply("rms", p["ln"], h),
                                    cache, ctx, cfg)
            return h + o, nc
        caches = state["cache"]
        new_caches = []
        for g in range(ng):
            grp_p = jax.tree.map(
                lambda a: jax.lax.dynamic_slice_in_dim(a, g * k, k, 0),
                params["blocks"])
            grp_c = jax.tree.map(
                lambda a: jax.lax.dynamic_slice_in_dim(a, g * k, k, 0),
                caches)
            x, nc = jax.lax.scan(mbody, x, (grp_p, grp_c),
                                 unroll=True if ctx.unroll else 1)
            new_caches.append(nc)
            x, shared_cache = _decode_dense_block(
                params["shared"], x, shared_cache, pos, ctx, cfg)
        new_state["cache"] = jax.tree.map(
            lambda *xs: jnp.concatenate(xs, 0), *new_caches)
        new_state["shared_cache"] = shared_cache
        if rem:
            x, tail_c = jax.lax.scan(mbody, x,
                                     (params["tail"], state["tail_cache"]),
                                     unroll=True if ctx.unroll else 1)
            new_state["tail_cache"] = tail_c
    x = norm_apply(_norm_kind(cfg), params["ln_f"], x)
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    logits_loc = emb.lm_head_logits(head, x.astype(ctx.compute_dtype), ctx)
    nxt = emb.tp_argmax(logits_loc, ctx)
    new_state["pos"] = pos + 1
    return nxt.astype(jnp.int32), new_state


def _decode_dense_block(p, x, cache, pos, ctx, cfg):
    nk = "rms"
    h, new_cache = attn.decode_self_attention(
        p["attn"], norm_apply(nk, p["ln1"], x), cache, pos, ctx, cfg)
    x = x + h
    m = _decode_mlp(p["mlp"], norm_apply(nk, p["ln2"], x), ctx, cfg)
    return x + m, new_cache


def _decode_mlp(p, x, ctx, cfg):
    """Single-token MLP/MoE: reuse the seq functions with t=1, sp off."""
    ctx1 = ctx.with_(sp=False)
    if cfg.moe:
        return ff.moe_apply(p, x[:, None], ctx1, cfg)[:, 0]
    return ff.mlp_apply(p, x[:, None], ctx1, cfg)[:, 0]


def prefill(params, ids, ctx: ParallelCtx, cfg,
            img_embeds: Optional[jax.Array] = None):
    """Full-sequence forward for serving: returns last-position hidden
    state (b, d) — cache construction for the subsequent decode is
    benchmarked separately via decode_step on a pre-built cache, which
    is what the decode_* dry-run shapes lower."""
    x = forward(params, ids, ctx, cfg, img_embeds=img_embeds)
    xf = sp_gather(x, ctx, axis=1)
    return xf[:, -1]
