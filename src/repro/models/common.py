"""Shared building blocks: norms, RoPE, initializers, linear helpers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def ninit(key, shape, scale=None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) > 1 else 1
    scale = (1.0 / fan_in) ** 0.5 if scale is None else scale
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ----------------------------------------------------------------------
# norms
# ----------------------------------------------------------------------
def rmsnorm_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * params["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


def layernorm_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


def norm_init(kind, d, dtype=jnp.float32):
    return layernorm_init(d, dtype) if kind == "layer" else rmsnorm_init(d, dtype)


def norm_apply(kind, params, x, eps=1e-6):
    return layernorm(params, x) if kind == "layer" else rmsnorm(params, x, eps)


def norm_sp(kind, params, x, ctx, eps=1e-6):
    """Alias of norm_apply.  Gradient completion for replicated params
    happens uniformly at the train-step level (single-seed loss +
    spec-driven TP psum) — see repro/train/step.py."""
    del ctx
    return norm_apply(kind, params, x, eps)


def norm_specs(kind):
    if kind == "layer":
        return {"scale": P(None), "bias": P(None)}
    return {"scale": P(None)}


# ----------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------
def rope_freqs(head_dim, theta):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta):
    """x: (..., t, h, dh); positions: (..., t) or (t,)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (dh/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., t, dh/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., :, None, :]                          # (..., t, 1, dh/2)
    sin = sin[..., :, None, :]
    x1, x2 = x[..., : dh // 2], x[..., dh // 2:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin,
                           xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# activations
# ----------------------------------------------------------------------
def act_fn(name):
    return {
        "gelu": jax.nn.gelu,
        "silu": jax.nn.silu,
        "relu2": lambda x: jnp.square(jax.nn.relu(x)),
    }[name]
