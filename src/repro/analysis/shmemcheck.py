"""Dynamic happens-before checking for the §3.2 completion model.

The paper proves exactly which delivery orders a correct program may
rely on: nothing between ordering points, per-destination order across
a ``fence``, everything complete at ``quiet``.  The ``CommQueue``
deliberately stresses that freedom (the seeded delivery shuffle), so a
program whose result depends on an *unordered* pair of conflicting
accesses is silently nondeterministic — the exact defect class a
ThreadSanitizer-style happens-before checker catches in shared-memory
code.  This module is that checker for the shmem substrate:

  * every ``put_nbi`` records a write interval (dst PE, symmetric
    object, row range) into the issuing queue's *pending set*;
  * ``fence(dst)`` / ``quiet()`` insert the happens-before edge the
    paper grants: pending intervals covered by the drain are retired —
    later accesses are ordered after them;
  * two overlapping pending writes to the same (dst, object) with no
    drain between them is a **write/write race** (the shuffle decides
    who wins);
  * reading the queue's heap state while a put targeting it is still
    pending is a **write/read race** (the model leaves the target range
    undefined until delivery);
  * the symmetric-heap hooks track object lifetime: a queue op through
    a handle whose extent was freed (or moved by ``realloc``) is a
    **use-after-free / stale handle**, a second ``free`` of a retired
    extent is a **double-free**, and ``compare_heaps`` checks the
    paper's Fact 1 — identically-driven heaps must produce identical
    (name, offset) sequences — reporting the first divergent
    allocation (**offset asymmetry**);
  * a drain re-entered from a drain callback (``fence``/``quiet``
    called while the same queue is draining) is flagged — the
    deadlock analogue of a blocking collective inside completion
    handling;
  * put-with-signal (``core.signals``) adds the per-transfer edge:
    ``signal_wait_until`` retires EXACTLY the pending intervals of
    puts guarding that signal word; reading the payload object while
    its guard is still pending is a **signal-race** (the wait, not the
    issue, is the completion point); and writing a registered signal
    word with a plain ``put_nbi`` is a **raw-signal** (the word's
    payload-before-signal guarantee only holds for signal updates);
  * queue AMOs (``CommQueue.amo_nbi``) add the linearization edge: an
    AMO is its own linearization point, so two pending AMOs on one
    word are NEVER a race (the drain order linearizes them) and
    ``amo_wait`` retires exactly the word's pending AMOs — but a plain
    ``put_nbi`` overlapping a registered ATOMIC word (or an AMO on a
    word with a plain put pending) is an **amo-race**: the shuffle
    decides whether the blind write lands before or after the
    read-modify-write, so the fetched value is undefined;
  * ``signal_reset`` (the queue-visible word-recycling path) is only
    legal on a retired word — resetting while guarded transfers are
    still pending is flagged as a **signal-race**.

Findings are *reports*, not exceptions: each carries the rule, a
message, and the source locations of both conflicting events, so a CI
run can batch and upload them (``tests/conftest.py`` fails the owning
test and writes ``shmemcheck-report.json``).

Zero-cost-when-off: ``repro.core.ordering`` and ``repro.core.heap``
each hold a module-global ``_checker = None`` hook; ``enable()``
installs one checker into both.  Disabled, an instrumented call site
costs one global load and an is-None test — the trace-time analogue of
compiling POSH without ``_SAFE`` (§4.7).

NOTE on gets: this queue satisfies ``get_nbi`` against the *settled*
state at ``quiet`` (the conservative reading the CommQueue documents),
so a get overlapping a pending put is deterministic here and is NOT
flagged; reading the ``NbiValue`` early already raises.
"""
from __future__ import annotations

import contextlib
import dataclasses
import operator
import os
import sys
from collections import Counter
from typing import Optional

MAX_FINDINGS = 1000   # memory bound for long racy replays (the multipe
                      # ordering sweeps deliberately race thousands of
                      # times); `dropped` counts the overflow

_SRC_SKIP = (os.sep + "repro" + os.sep + "core" + os.sep,
             os.sep + "repro" + os.sep + "analysis" + os.sep)


def _loc() -> str:
    """file:line of the first caller outside core/analysis — the
    call site a report should point at."""
    f = sys._getframe(2)
    while f is not None:
        fn = f.f_code.co_filename
        if not any(s in fn for s in _SRC_SKIP):
            return f"{fn}:{f.f_lineno}"
        f = f.f_back
    f = sys._getframe(2)
    return f"{f.f_code.co_filename}:{f.f_lineno}"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One checker report: what rule fired, where, and against what."""

    rule: str                 # "ww-race" | "wr-race" | "use-after-free"
                              # | "double-free" | "stale-handle"
                              # | "offset-asymmetry" | "nested-drain"
                              # | "signal-race" | "raw-signal"
                              # | "amo-race"
    message: str
    loc: str                  # source location of the flagged access
    other_loc: Optional[str] = None   # the conflicting earlier event

    def __str__(self) -> str:
        s = f"{self.loc}: [{self.rule}] {self.message}"
        if self.other_loc:
            s += f" (conflicts with {self.other_loc})"
        return s


@dataclasses.dataclass
class _PendingWrite:
    """One undrained put interval on one destination PE."""

    dst: int
    name: str                 # symmetric object
    lo: Optional[int]         # row range [lo, hi); None = unknown
    hi: Optional[int]         # (traced offset/extent: no overlap check)
    seq: int
    loc: str
    reported_read: bool = False
    sig_key: Optional[tuple] = None   # (sig name, word offset) guarding
                                      # this write; retired by the wait
    is_sig_word: bool = False         # the signal-word update itself
    amo_key: Optional[tuple] = None   # (name, word offset) of a pending
                                      # AMO; retired by amo_wait — AMOs
                                      # never ww-race each other


def _overlap(a: _PendingWrite, lo, hi) -> bool:
    if a.lo is None or lo is None:
        return False          # unknown extent: conservative no-flag
    return a.lo < hi and lo < a.hi


class ShmemChecker:
    """The happens-before state machine.  One instance is installed
    into the core hooks by :func:`enable`; tests may also drive one
    directly (every ``on_*`` method is a plain call)."""

    def __init__(self):
        self.findings: list[Finding] = []
        self.dropped = 0
        # queue id -> list[_PendingWrite] (retired at fence/quiet)
        self._pending: dict[int, list[_PendingWrite]] = {}
        # queue id -> registered signal words {(name, offset)}: a word
        # becomes a signal word at its first put_signal or wait
        self._sig_words: dict[int, set] = {}
        # queue id -> registered atomic words {(name, offset)}: a word
        # becomes atomic at its first amo_nbi/amo_wait; plain puts
        # touching it afterwards are amo-races
        self._amo_words: dict[int, set] = {}
        self._draining: set[int] = set()
        # heap object lifetime, keyed by symmetric NAME: extents are
        # (offset, nbytes) tuples; a Counter because several heaps may
        # legitimately carry the same object (one per engine/test)
        self._live: dict[str, Counter] = {}
        self._freed: dict[str, dict] = {}   # name -> extent -> free loc
        # per-heap allocation log for Fact-1 symmetry comparison
        self._alloc_log: dict[int, list] = {}

    # ------------------------------------------------------------------
    def _report(self, rule: str, message: str, loc: str,
                other_loc: Optional[str] = None) -> None:
        if len(self.findings) >= MAX_FINDINGS:
            self.dropped += 1
            return
        self.findings.append(Finding(rule, message, loc, other_loc))

    def report(self) -> list[Finding]:
        return list(self.findings)

    def reset(self) -> None:
        self.__init__()

    # ------------------------------------------------------------------
    # queue hooks (repro.core.ordering)
    # ------------------------------------------------------------------
    def on_put_nbi(self, queue, handle, data, pairs, offset, seq) -> None:
        loc = _loc()
        self._check_handle_live(handle, "put_nbi", loc)
        lo = hi = None
        try:                  # traced offsets/extents: unknown range
            off = operator.index(offset)
            rows = queue.transport.put_rows(data)
            if rows is not None:
                lo, hi = off, off + int(rows)
        except Exception:
            lo = hi = None
        self._check_raw_signal(queue, handle, lo, hi, seq, loc)
        self._check_amo_word(queue, handle, lo, hi, seq, loc)
        pend = self._pending.setdefault(id(queue), [])
        byte = self._row_bytes(handle)
        for dst in sorted({int(d) for _, d in pairs}):
            for w in pend:
                if w.dst == dst and w.name == handle.name \
                        and not w.is_sig_word and w.amo_key is None \
                        and _overlap(w, lo, hi):
                    olo, ohi = max(w.lo, lo), min(w.hi, hi)
                    brange = (f"bytes [{olo * byte}, {ohi * byte})"
                              if byte else f"rows [{olo}, {ohi})")
                    self._report(
                        "ww-race",
                        f"unordered puts to overlapping range of "
                        f"'{handle.name}' on PE {dst} ({brange}): delivery "
                        f"order is undefined between drains (seqs "
                        f"{w.seq} and {seq}); separate them with "
                        f"fence({dst}) or quiet()", loc, w.loc)
            pend.append(_PendingWrite(dst, handle.name, lo, hi, seq, loc))

    def _check_raw_signal(self, queue, handle, lo, hi, seq,
                          loc: str) -> None:
        """A plain put overlapping a registered signal word bypasses
        the payload-before-signal protocol — a waiter can observe the
        word flip with no payload guarantee behind it."""
        words = self._sig_words.get(id(queue))
        if not words or lo is None:
            return
        for name, off in sorted(words):
            if name == handle.name and lo <= off < hi:
                self._report(
                    "raw-signal",
                    f"plain put_nbi (seq {seq}) writes signal word "
                    f"'{name}'+{off}: signal words carry the "
                    f"payload-before-signal guarantee and must only be "
                    f"written through put_signal_nbi", loc)

    def _check_amo_word(self, queue, handle, lo, hi, seq,
                        loc: str) -> None:
        """A plain put overlapping a registered atomic word races the
        read-modify-write cycle: the shuffle decides whether the blind
        write lands before or after the AMO, so the fetched value (and
        the settled word) is undefined."""
        words = self._amo_words.get(id(queue))
        if not words or lo is None:
            return
        for name, off in sorted(words):
            if name == handle.name and lo <= off < hi:
                self._report(
                    "amo-race",
                    f"plain put_nbi (seq {seq}) writes atomic word "
                    f"'{name}'+{off}: words carrying AMO traffic are "
                    f"linearized by the drain order and must only be "
                    f"updated through amo_nbi", loc)

    def on_amo(self, queue, handle, offset, pairs, seq, op) -> None:
        """One queue AMO issued.  The word becomes a registered atomic
        word; the AMO joins the pending set tagged ``amo_key`` (retired
        by ``amo_wait``).  Pending AMOs on the same word are NOT
        checked against each other — each is its own linearization
        point — but a pending PLAIN put covering the word is an
        amo-race (the mirror of ``_check_amo_word``)."""
        loc = _loc()
        self._check_handle_live(handle, "amo_nbi", loc)
        key = (handle.name, int(offset))
        self._amo_words.setdefault(id(queue), set()).add(key)
        pend = self._pending.setdefault(id(queue), [])
        lo, hi = int(offset), int(offset) + 1
        for dst in sorted({int(d) for _, d in pairs}):
            for w in pend:
                if w.dst == dst and w.name == handle.name \
                        and w.amo_key is None and not w.is_sig_word \
                        and _overlap(w, lo, hi):
                    self._report(
                        "amo-race",
                        f"amo_nbi ({op}, seq {seq}) on '{handle.name}'"
                        f"+{int(offset)} while a plain put (seq {w.seq}) "
                        f"covering the word is pending: the shuffle "
                        f"decides whether the blind write lands before "
                        f"or after the read-modify-write", loc, w.loc)
            pend.append(_PendingWrite(dst, handle.name, lo, hi, seq, loc,
                                      amo_key=key))

    def on_amo_wait(self, queue, handle, offset) -> None:
        """The AMO linearization edge: retire exactly the pending AMOs
        on the named word — everything else stays pending."""
        self._check_reentry(queue,
                            f"amo_wait({handle.name}+{offset})")
        key = (handle.name, int(offset))
        self._amo_words.setdefault(id(queue), set()).add(key)
        pend = self._pending.get(id(queue))
        if pend:
            pend[:] = [w for w in pend if w.amo_key != key]

    def on_signal_reset(self, queue, sig_handle, sig_offset,
                        pairs) -> None:
        """Word recycling through the queue.  Legal only on a RETIRED
        word: pending transfers still guarded by it would have their
        completion evidence wiped before the wait could observe it."""
        loc = _loc()
        self._check_handle_live(sig_handle, "signal_reset", loc)
        key = (sig_handle.name, int(sig_offset))
        self._sig_words.setdefault(id(queue), set()).add(key)
        pend = self._pending.get(id(queue))
        if not pend:
            return
        for w in pend:
            if w.sig_key == key:
                self._report(
                    "signal-race",
                    f"signal_reset of '{key[0]}'+{key[1]} while a "
                    f"transfer guarded by it (seq {w.seq}) is still "
                    f"pending: recycle a word only after its wait "
                    f"retired every guarded put", loc, w.loc)
                break

    def on_put_signal(self, queue, handle, data, pairs, offset,
                      payload_seq, sig_handle, sig_offset,
                      sig_seq) -> None:
        """Record the guarded pair: the payload interval AND the
        signal-word update both join the pending set tagged with the
        word's key, so the matching wait can retire exactly them."""
        loc = _loc()
        self._check_handle_live(handle, "put_signal_nbi", loc)
        self._check_handle_live(sig_handle, "put_signal_nbi", loc)
        key = (sig_handle.name, int(sig_offset))
        self._sig_words.setdefault(id(queue), set()).add(key)
        lo = hi = None
        try:
            off = operator.index(offset)
            rows = queue.transport.put_rows(data)
            if rows is not None:
                lo, hi = off, off + int(rows)
        except Exception:
            lo = hi = None
        pend = self._pending.setdefault(id(queue), [])
        byte = self._row_bytes(handle)
        for dst in sorted({int(d) for _, d in pairs}):
            for w in pend:
                if w.dst == dst and w.name == handle.name \
                        and not w.is_sig_word and _overlap(w, lo, hi):
                    olo, ohi = max(w.lo, lo), min(w.hi, hi)
                    brange = (f"bytes [{olo * byte}, {ohi * byte})"
                              if byte else f"rows [{olo}, {ohi})")
                    self._report(
                        "ww-race",
                        f"unordered puts to overlapping range of "
                        f"'{handle.name}' on PE {dst} ({brange}): delivery "
                        f"order is undefined between drains (seqs "
                        f"{w.seq} and {payload_seq}); separate them with "
                        f"fence({dst}) or quiet()", loc, w.loc)
            pend.append(_PendingWrite(dst, handle.name, lo, hi,
                                      payload_seq, loc, sig_key=key))
            pend.append(_PendingWrite(dst, sig_handle.name,
                                      int(sig_offset), int(sig_offset) + 1,
                                      sig_seq, loc, sig_key=key,
                                      is_sig_word=True))

    def on_signal_wait(self, queue, sig_handle, sig_offset) -> None:
        """The per-transfer happens-before edge: retire EXACTLY the
        pending intervals guarded by this signal word (payloads and the
        word itself) — everything else stays pending."""
        self._check_reentry(
            queue, f"signal_wait_until({sig_handle.name}+{sig_offset})")
        key = (sig_handle.name, int(sig_offset))
        self._sig_words.setdefault(id(queue), set()).add(key)
        pend = self._pending.get(id(queue))
        if pend:
            pend[:] = [w for w in pend if w.sig_key != key]

    def on_get_nbi(self, queue, handle, pairs, offset, size, seq) -> None:
        self._check_handle_live(handle, "get_nbi", _loc())

    def on_fence(self, queue, dst) -> None:
        self._check_reentry(queue, f"fence({dst})")
        pend = self._pending.get(id(queue))
        if not pend:
            return
        if dst is None:
            pend.clear()
        else:
            pend[:] = [w for w in pend if w.dst != int(dst)]

    def on_quiet(self, queue) -> None:
        self._check_reentry(queue, "quiet()")
        self._pending.pop(id(queue), None)

    def on_state_read(self, queue) -> None:
        """The queue's heap state was read.  Any pending put's target
        range is undefined until its drain — flag each once."""
        pend = self._pending.get(id(queue))
        if not pend:
            return
        loc = _loc()
        for w in pend:
            if w.reported_read:
                continue
            w.reported_read = True
            if w.sig_key is not None:
                name, off = w.sig_key
                self._report(
                    "signal-race",
                    f"heap state read while a put-with-signal to "
                    f"'{w.name}' on PE {w.dst} (seq {w.seq}) guarded by "
                    f"'{name}'+{off} is pending: the payload is only "
                    f"defined once signal_wait_until on that word "
                    f"returns", loc, w.loc)
            else:
                self._report(
                    "wr-race",
                    f"heap state read while a put to '{w.name}' on PE "
                    f"{w.dst} (seq {w.seq}) is pending: the target range "
                    f"is undefined until fence/quiet", loc, w.loc)

    @contextlib.contextmanager
    def draining(self, queue):
        self._draining.add(id(queue))
        try:
            yield
        finally:
            self._draining.discard(id(queue))

    def _check_reentry(self, queue, what: str) -> None:
        if id(queue) in self._draining:
            self._report(
                "nested-drain",
                f"{what} re-entered from a drain callback of the same "
                f"CommQueue: completion handling must not block on "
                f"another drain", _loc())

    # ------------------------------------------------------------------
    # heap hooks (repro.core.heap)
    # ------------------------------------------------------------------
    def on_alloc(self, heap, handle) -> None:
        loc = _loc()
        ext = (handle.offset, handle.nbytes)
        self._live.setdefault(handle.name, Counter())[ext] += 1
        self._freed.get(handle.name, {}).pop(ext, None)
        self._alloc_log.setdefault(id(heap), []).append(
            (handle.name, handle.offset, handle.nbytes, loc))

    def on_free(self, heap, name, handle) -> None:
        loc = _loc()
        if handle is None:
            # the heap will raise KeyError; if WE retired this name it
            # is a double free, otherwise it was never tracked (manual
            # handles) and stays the heap's plain error
            freed = self._freed.get(name)
            if freed and not self._live_count(name):
                self._report(
                    "double-free",
                    f"free of symmetric object '{name}' which was "
                    f"already freed", loc, next(iter(freed.values())))
            return
        ext = (handle.offset, handle.nbytes)
        live = self._live.get(name)
        if live and live[ext] > 0:
            live[ext] -= 1
        self._freed.setdefault(name, {})[ext] = loc

    def on_realloc(self, heap, old, new) -> None:
        """In-place resize: the old extent dies, the new one is live.
        (The move path goes through free + alloc and is already
        covered.)"""
        loc = _loc()
        oext, next_ = (old.offset, old.nbytes), (new.offset, new.nbytes)
        if oext != next_:
            live = self._live.get(old.name)
            if live and live[oext] > 0:
                live[oext] -= 1
            self._freed.setdefault(old.name, {})[oext] = loc
        self._live.setdefault(new.name, Counter())[next_] += 1
        self._freed.get(new.name, {}).pop(next_, None)
        self._alloc_log.setdefault(id(heap), []).append(
            (new.name, new.offset, new.nbytes, loc))

    def _live_count(self, name: str) -> int:
        return sum(self._live.get(name, Counter()).values())

    def _check_handle_live(self, handle, op: str, loc: str) -> None:
        name = handle.name
        live = self._live.get(name)
        freed = self._freed.get(name)
        if not live and not freed:
            return            # never heap-tracked (manual SymHandle)
        ext = (handle.offset, handle.nbytes)
        if live is not None and live[ext] > 0:
            return
        if freed and ext in freed:
            kind = ("use-after-free" if not self._live_count(name)
                    else "stale-handle")
            self._report(
                kind,
                f"{op} through handle of '{name}' (offset "
                f"{handle.offset}, {handle.nbytes}B) whose extent was "
                f"freed or moved by realloc", loc, freed[ext])

    # ------------------------------------------------------------------
    # Fact 1 — cross-PE offset symmetry
    # ------------------------------------------------------------------
    def compare_heaps(self, *heaps) -> list[Finding]:
        """Check that identically-driven heaps produced identical
        allocation sequences (name, offset, nbytes).  SPMD makes this
        true by construction for a correct program; a PE-dependent
        branch around an alloc breaks it — the checker reports the
        first divergent allocation with both source locations."""
        out: list[Finding] = []
        logs = [self._alloc_log.get(id(h), []) for h in heaps]
        for i, (a, b) in enumerate(zip(heaps, heaps[1:])):
            la, lb = logs[i], logs[i + 1]
            for j, (ea, eb) in enumerate(zip(la, lb)):
                if ea[:3] != eb[:3]:
                    f = Finding(
                        "offset-asymmetry",
                        f"allocation #{j} diverges across PEs: "
                        f"{ea[0]!r}@{ea[1]} ({ea[2]}B) vs "
                        f"{eb[0]!r}@{eb[1]} ({eb[2]}B) — symmetric "
                        f"allocation must be the same call sequence on "
                        f"every PE (Fact 1)", eb[3], ea[3])
                    out.append(f)
                    break
            else:
                if len(la) != len(lb):
                    k = min(len(la), len(lb))
                    longer = la if len(la) > len(lb) else lb
                    f = Finding(
                        "offset-asymmetry",
                        f"allocation counts diverge across PEs "
                        f"({len(la)} vs {len(lb)}): first unmatched "
                        f"alloc is {longer[k][0]!r}@{longer[k][1]}",
                        longer[k][3])
                    out.append(f)
        for f in out:
            self._report(f.rule, f.message, f.loc, f.other_loc)
        return out

    # ------------------------------------------------------------------
    @staticmethod
    def _row_bytes(handle) -> int:
        shape = getattr(handle, "shape", ())
        if shape and int(shape[0]) > 0:
            return int(handle.nbytes) // int(shape[0])
        return 0


# ======================================================================
# module-level installation — the zero-cost-when-off switch
# ======================================================================
_CHECKER: Optional[ShmemChecker] = None


def _install(checker: Optional[ShmemChecker]) -> None:
    from repro.core import heap as _heap
    from repro.core import ordering as _ordering
    _ordering._checker = checker
    _heap._checker = checker
    # An explicit install supersedes the REPRO_SHMEMCHECK one-shot arm;
    # otherwise the first CommQueue/SymmetricHeap constructed after a
    # private _install() would re-enable the global checker over it.
    _ordering._AUTOENV = False
    _heap._AUTOENV = False


def enable() -> ShmemChecker:
    """Install (or return the already-installed) checker into the core
    hooks.  Idempotent; safe to call per-test."""
    global _CHECKER
    if _CHECKER is None:
        _CHECKER = ShmemChecker()
    _install(_CHECKER)
    return _CHECKER


def disable() -> None:
    """Uninstall the hooks (findings are kept until ``reset``)."""
    _install(None)


def is_enabled() -> bool:
    from repro.core import ordering as _ordering
    return _ordering._checker is not None


def get_checker() -> Optional[ShmemChecker]:
    return _CHECKER


def report() -> list[Finding]:
    return _CHECKER.report() if _CHECKER is not None else []


def reset() -> None:
    if _CHECKER is not None:
        _CHECKER.reset()


def compare_heaps(*heaps) -> list[Finding]:
    if _CHECKER is None:
        return []
    return _CHECKER.compare_heaps(*heaps)


@contextlib.contextmanager
def suspended():
    """Temporarily uninstall the hooks (for code that deliberately
    explores racy interleavings, e.g. the ordering property tests)."""
    was = is_enabled()
    disable()
    try:
        yield
    finally:
        if was:
            enable()


@contextlib.contextmanager
def session():
    """enable + fresh state; yields the checker, uninstalls after."""
    chk = enable()
    chk.reset()
    try:
        yield chk
    finally:
        disable()
