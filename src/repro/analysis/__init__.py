"""repro.analysis — correctness tooling for the shmem memory model.

Two complementary checkers over the paper's §3.2 completion model
(puts complete locally at issue; delivery is unordered until ``fence``
— per destination — or ``quiet`` — full barrier):

  shmemcheck   dynamic happens-before race detection instrumented into
               ``repro.core.ordering.CommQueue`` and
               ``repro.core.heap.SymmetricHeap`` behind a
               zero-cost-when-off hook (the §4.7 ``_SAFE`` philosophy:
               disabled, the hot path pays one global load + is-None
               test).  Enable with ``REPRO_SHMEMCHECK=1`` or
               ``shmemcheck.enable()``.

  lint         a static AST pass over ``src/`` enforcing the comm-API
               invariants that hold by convention: every ``*_nbi``
               issue drained on all paths (or annotated
               ``# shmem: deferred-drain``), no raw ``jax.lax``
               collectives outside the comm substrate, no
               ``SymHandle`` used past its ``free``, no drain inside a
               drain callback.  CLI: ``python scripts/shmemlint.py``.
"""
from . import lint, shmemcheck
from .lint import LintError, lint_paths, lint_source
from .shmemcheck import (Finding, ShmemChecker, compare_heaps, disable,
                         enable, get_checker, is_enabled, report, reset,
                         suspended)

__all__ = [
    "shmemcheck", "lint",
    "Finding", "ShmemChecker", "enable", "disable", "is_enabled",
    "get_checker", "report", "reset", "suspended", "compare_heaps",
    "LintError", "lint_paths", "lint_source",
]
