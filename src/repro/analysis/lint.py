"""Static AST lint for the comm-API invariants.

Four rules, each encoding a convention the substrate's correctness
arguments lean on but that nothing enforced mechanically until now:

  nbi-drain           every ``*_nbi`` issue must be dominated by a
                      ``fence``/``quiet``/``signal_wait_until``/
                      ``amo_wait`` on all
                      paths to the end of its function: a function that
                      issues and returns with the op still pending has
                      silently widened its contract to "caller must
                      drain" (``put_signal_nbi`` ends in ``_nbi`` and
                      so is covered; its paired wait is the drain the
                      rule accepts for it).  Explicitly
                      deferred drains are annotated
                      ``# shmem: deferred-drain`` on the call line or
                      the enclosing ``def`` line (the CommQueue wrapper
                      functions themselves, proposer-style pipelines).

  raw-collective      no raw ``jax.lax`` collectives outside
                      ``repro/comm/``, ``repro/core/`` and the version
                      shim ``repro/compat.py`` — every collective goes
                      through a ``Communicator`` so backend dispatch,
                      instrumentation and the safety guard see it.
                      (``jax.lax.axis_index`` is a rank query, not a
                      collective, and stays legal everywhere.)

  handle-after-free   a ``SymHandle`` variable must not be used after
                      being passed to ``free`` — the CommQueue would
                      happily deliver through the stale name (the
                      static twin of shmemcheck's use-after-free).

  drain-callback      a callback handed to ``allreduce_nbi`` runs
                      inside the drain; calling ``fence``/``quiet``/
                      ``barrier*`` there re-enters completion handling
                      (the deadlock analogue shmemcheck flags
                      dynamically as ``nested-drain``).

The analysis is deliberately conservative and function-local: loops
may run zero times (a drain inside one does not dominate), ``raise``
is an accepted exit (exceptional paths abandon the queue), and traced
or dynamic control flow falls back to "not drained".
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Optional

DEFER_ANNOTATION = "shmem: deferred-drain"

# paths (normalized, '/'-separated) where raw jax.lax collectives are
# the implementation, not a bypass
RAW_COLLECTIVE_ALLOWED = ("repro/comm/", "repro/core/", "repro/compat.py")

# jax.lax collective primitives (axis_index excluded: rank query)
LAX_COLLECTIVES = frozenset({
    "psum", "pmean", "pmax", "pmin", "all_gather", "all_to_all",
    "ppermute", "pshuffle", "psum_scatter", "axis_size",
})

# signal_wait_until is the put-with-signal extension's per-transfer
# drain point (core.signals): it validly completes the guarded
# put_signal_nbi, so the nbi-drain walk accepts it next to fence/quiet
# — and, being a drain, it is just as illegal inside a drain callback.
# amo_wait is the same per-word completion point for atomics
# (core.atomics): amo_nbi issues retire under it without a fence.
DRAIN_NAMES = frozenset({"fence", "quiet", "signal_wait_until",
                         "amo_wait"})
DRAIN_CALLBACK_FORBIDDEN = frozenset(
    {"fence", "quiet", "barrier", "barrier_all", "signal_wait_until",
     "amo_wait"})

# path-status lattice for the post-dominator scan
_DRAINED, _BAD, _CONT = "drained", "bad", "continue"


@dataclasses.dataclass(frozen=True)
class LintError:
    path: str
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


# ======================================================================
# shared AST helpers
# ======================================================================
def _call_name(call: ast.Call) -> Optional[str]:
    f = call.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return None


def _is_drain_call(node: ast.AST) -> bool:
    return (isinstance(node, ast.Call)
            and _call_name(node) in DRAIN_NAMES)


def _contains_drain(node: ast.AST) -> bool:
    """A drain call anywhere in this expression/statement, excluding
    nested function bodies (their execution is deferred)."""
    for sub in _walk_no_nested_defs(node):
        if _is_drain_call(sub):
            return True
    return False


def _walk_no_nested_defs(node: ast.AST):
    stack = [node]
    while stack:
        n = stack.pop()
        yield n
        for child in ast.iter_child_nodes(n):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)) and child is not node:
                continue
            stack.append(child)


def _annotated(lines: list[str], lineno: int) -> bool:
    if 1 <= lineno <= len(lines):
        return DEFER_ANNOTATION in lines[lineno - 1]
    return False


# ======================================================================
# rule: nbi-drain — post-dominating drain on all paths
# ======================================================================
def _path_status(stmts: list[ast.stmt]) -> str:
    """Walk a statement list: does every path through it reach an
    unconditional drain before leaving the function normally?

    _DRAINED  every path hits a drain inside this list
    _BAD      some path returns (function exit) without a drain
    _CONT     control can fall off the end of this list undrained
    """
    for s in stmts:
        if isinstance(s, ast.Return):
            return _DRAINED if _contains_drain(s) else _BAD
        if isinstance(s, ast.Raise):
            return _DRAINED          # exceptional exit: queue abandoned
        if isinstance(s, (ast.Break, ast.Continue)):
            return _CONT             # loop-local jump: resolved upward
        if isinstance(s, ast.If):
            sb = _path_status(s.body)
            so = _path_status(s.orelse) if s.orelse else _CONT
            if _BAD in (sb, so):
                return _BAD
            if sb == so == _DRAINED:
                return _DRAINED
            continue                 # some branch falls through: scan on
        if isinstance(s, (ast.For, ast.AsyncFor, ast.While)):
            body = _path_status(s.body)
            if body == _BAD or (s.orelse
                                and _path_status(s.orelse) == _BAD):
                return _BAD
            continue                 # zero iterations possible: no drain
        if isinstance(s, (ast.With, ast.AsyncWith)):
            sw = _path_status(s.body)
            if sw != _CONT:
                return sw
            continue
        if isinstance(s, ast.Try):
            parts = [s.body] + [h.body for h in s.handlers]
            if s.orelse:
                parts.append(s.orelse)
            if any(_path_status(p) == _BAD for p in parts):
                return _BAD
            if s.finalbody:
                sf = _path_status(s.finalbody)
                if sf != _CONT:
                    return sf
            if all(_path_status(p) == _DRAINED
                   for p in [s.body] + [h.body for h in s.handlers]):
                return _DRAINED
            continue
        if _contains_drain(s):
            return _DRAINED
    return _CONT


class _NbiDrainRule(ast.NodeVisitor):
    def __init__(self, path: str, lines: list[str]):
        self.path = path
        self.lines = lines
        self.errors: list[LintError] = []

    def visit_FunctionDef(self, node):
        self._check_function(node)
        self.generic_visit(node)     # nested defs checked on their own

    visit_AsyncFunctionDef = visit_FunctionDef

    def _check_function(self, fn) -> None:
        if _annotated(self.lines, fn.lineno):
            return
        for call, chain in _nbi_calls_with_chain(fn):
            if _annotated(self.lines, call.lineno):
                continue
            if not self._drained(chain):
                name = _call_name(call)
                self.errors.append(LintError(
                    self.path, call.lineno, "nbi-drain",
                    f"'{name}' is not followed by a fence/quiet on all "
                    f"paths of '{fn.name}' — drain before returning, or "
                    f"annotate the call '# {DEFER_ANNOTATION}' if the "
                    f"caller owns the drain"))

    @staticmethod
    def _drained(chain) -> bool:
        """chain: [(stmt_list, index), ...] innermost block last.  The
        issue is covered if, at some enclosing level, everything after
        it drains on all paths (and no level exposes an undrained
        return first)."""
        for stmts, idx in reversed(chain):
            status = _path_status(stmts[idx + 1:])
            if status == _DRAINED:
                return True
            if status == _BAD:
                return False
        return False


def _nbi_calls_with_chain(fn):
    """Yield (call, enclosing-block chain) for every ``*_nbi`` call in
    ``fn``, excluding nested function bodies."""
    out = []

    def walk_block(stmts, chain):
        for i, s in enumerate(stmts):
            here = chain + [(stmts, i)]
            for sub in _walk_no_nested_defs_stmt(s):
                if isinstance(sub, ast.Call):
                    name = _call_name(sub)
                    if name and name.endswith("_nbi") \
                            and not name.startswith("on_"):
                        # on_*_nbi are observer hooks, not issue APIs
                        out.append((sub, here))
            for blk in _child_blocks(s):
                walk_block(blk, here)

    walk_block(fn.body, [])
    return out


def _child_blocks(stmt):
    blocks = []
    for field in ("body", "orelse", "finalbody"):
        val = getattr(stmt, field, None)
        if val and isinstance(val, list) \
                and all(isinstance(x, ast.stmt) for x in val):
            blocks.append(val)
    for h in getattr(stmt, "handlers", []) or []:
        blocks.append(h.body)
    return blocks


def _walk_no_nested_defs_stmt(stmt):
    """Expressions of one statement only: neither nested statement
    blocks (walked separately) nor deferred function bodies."""
    todo = [stmt]
    while todo:
        n = todo.pop()
        yield n
        for child in ast.iter_child_nodes(n):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda, ast.stmt)):
                continue
            todo.append(child)


# ======================================================================
# rule: raw-collective
# ======================================================================
def _lax_collective(call: ast.Call) -> Optional[str]:
    f = call.func
    if not isinstance(f, ast.Attribute) or f.attr not in LAX_COLLECTIVES:
        return None
    v = f.value
    if isinstance(v, ast.Name) and v.id == "lax":
        return f.attr
    if isinstance(v, ast.Attribute) and v.attr == "lax" \
            and isinstance(v.value, ast.Name) and v.value.id == "jax":
        return f.attr
    return None


def _raw_collective_errors(tree, path: str, relpath: str):
    rel = relpath.replace(os.sep, "/")
    if any(a in rel for a in RAW_COLLECTIVE_ALLOWED):
        return []
    errors = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            name = _lax_collective(node)
            if name:
                errors.append(LintError(
                    path, node.lineno, "raw-collective",
                    f"raw jax.lax.{name} outside repro/comm|core: route "
                    f"it through a Communicator (ctx.tp_comm/dp_comm) so "
                    f"dispatch, instrumentation and the safety guard "
                    f"see it"))
    return errors


# ======================================================================
# rule: handle-after-free
# ======================================================================
ALLOC_METHODS = frozenset({"alloc", "align_alloc", "realloc"})


class _HandleAfterFreeRule(ast.NodeVisitor):
    def __init__(self, path: str):
        self.path = path
        self.errors: list[LintError] = []

    def visit_FunctionDef(self, node):
        self._check(node)
        self.generic_visit(node)

    visit_AsyncFunctionDef = visit_FunctionDef

    def _check(self, fn) -> None:
        allocated: set[str] = set()
        freed: dict[str, int] = {}          # var -> line of the free
        for node in _walk_in_lineno_order(fn):
            if isinstance(node, ast.Assign):
                v = node.value
                if isinstance(v, ast.Call) \
                        and _call_name(v) in ALLOC_METHODS:
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            allocated.add(t.id)
                            freed.pop(t.id, None)
                else:
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            freed.pop(t.id, None)  # rebound: new object
            elif isinstance(node, ast.Call) and _call_name(node) == "free":
                for a in node.args:
                    if isinstance(a, ast.Name) and a.id in allocated:
                        freed[a.id] = node.lineno
            elif isinstance(node, ast.Name) \
                    and isinstance(node.ctx, ast.Load) \
                    and node.id in freed and node.lineno > freed[node.id]:
                self.errors.append(LintError(
                    self.path, node.lineno, "handle-after-free",
                    f"SymHandle '{node.id}' used after free "
                    f"(freed at line {freed[node.id]}) — the queue would "
                    f"deliver through the stale symmetric name"))
                freed.pop(node.id)          # one report per free


def _walk_in_lineno_order(fn):
    nodes = [n for n in _walk_no_nested_defs(fn)
             if hasattr(n, "lineno")]
    seen_free_args = set()
    # the free(...) call's own argument is a legal (last) use
    for n in nodes:
        if isinstance(n, ast.Call) and _call_name(n) == "free":
            for a in n.args:
                seen_free_args.add(id(a))
    nodes.sort(key=lambda n: (n.lineno, n.col_offset))
    for n in nodes:
        if id(n) in seen_free_args:
            continue
        yield n


# ======================================================================
# rule: drain-callback
# ======================================================================
class _DrainCallbackRule(ast.NodeVisitor):
    def __init__(self, path: str, tree):
        self.path = path
        self.errors: list[LintError] = []
        self._defs = {}
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._defs.setdefault(node.name, []).append(node)

    def visit_Call(self, node):
        if _call_name(node) == "allreduce_nbi" and node.args:
            cb = node.args[-1]
            body = None
            if isinstance(cb, ast.Lambda):
                body = cb.body
            elif isinstance(cb, ast.Name) \
                    and len(self._defs.get(cb.id, [])) == 1:
                body = self._defs[cb.id][0]
            if body is not None:
                self._scan_callback(body, node.lineno)
        self.generic_visit(node)

    def _scan_callback(self, body, issue_line: int) -> None:
        for sub in ast.walk(body):
            if isinstance(sub, ast.Call):
                name = _call_name(sub)
                if name in DRAIN_CALLBACK_FORBIDDEN:
                    self.errors.append(LintError(
                        self.path, sub.lineno, "drain-callback",
                        f"'{name}' inside a drain callback (allreduce_nbi "
                        f"at line {issue_line}): completion handling must "
                        f"not block on another drain or barrier"))


# ======================================================================
# driver
# ======================================================================
def lint_source(src: str, path: str, relpath: Optional[str] = None
                ) -> list[LintError]:
    relpath = relpath if relpath is not None else path
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [LintError(path, e.lineno or 0, "parse-error", str(e))]
    lines = src.splitlines()
    nbi = _NbiDrainRule(path, lines)
    nbi.visit(tree)
    haf = _HandleAfterFreeRule(path)
    haf.visit(tree)
    dcb = _DrainCallbackRule(path, tree)
    dcb.visit(tree)
    errors = (nbi.errors + _raw_collective_errors(tree, path, relpath)
              + haf.errors + dcb.errors)
    return sorted(errors, key=lambda e: (e.path, e.line, e.rule))


def lint_paths(paths) -> list[LintError]:
    """Lint every ``.py`` file under the given files/directories."""
    errors: list[LintError] = []
    for root in paths:
        if os.path.isfile(root):
            files = [root]
            base = os.path.dirname(root)
        else:
            files = []
            base = root
            for dirpath, _, names in os.walk(root):
                files.extend(os.path.join(dirpath, f)
                             for f in sorted(names) if f.endswith(".py"))
        for f in files:
            with open(f, encoding="utf-8") as fh:
                src = fh.read()
            errors.extend(lint_source(src, f, os.path.relpath(f, base)
                                      if base else f))
    return errors
