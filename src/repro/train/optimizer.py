"""AdamW with two state layouts:

  zero=0  m/v mirror the param layout (replicated over DP, sharded over
          TP exactly like the param) — the simple baseline.
  zero=1  ZeRO-1/2: per-leaf flat chunking over DP.  Gradients are
          reduce-scattered over DP (each rank owns 1/dp of every leaf),
          Adam updates only the owned chunk (+ f32 master when params
          are bf16), and updated chunks are all-gathered back.  Both the
          reduce-scatter and the all-gather go through repro.comm — the
          POSH ring is literally the optimizer's wire.

All functions run inside shard_map.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.ctx import ParallelCtx


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    zero: int = 0               # 0 | 1


AdamWState = dict  # {"m": tree, "v": tree, "master": tree|None, "count": i32}


def _chunk(leaf, dp):
    """Pad+reshape a local leaf to (dp, c) for DP chunk ownership."""
    flat = leaf.ravel()
    c = -(-flat.size // dp)
    return jnp.pad(flat, (0, dp * c - flat.size)).reshape(dp, c)


def _my_chunk(leaf, ctx: ParallelCtx):
    ch = _chunk(leaf, ctx.dp_size)
    return jax.lax.dynamic_index_in_dim(ch, ctx.dp_rank(), 0, keepdims=False)


def adamw_init(params: Any, ctx: ParallelCtx, opt_cfg: AdamWConfig) -> AdamWState:
    if opt_cfg.zero == 0:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        st = {"m": jax.tree.map(zeros, params),
              "v": jax.tree.map(zeros, params),
              "count": jnp.zeros((), jnp.int32)}
        if params and jax.tree.leaves(params)[0].dtype == jnp.bfloat16:
            st["master"] = jax.tree.map(
                lambda p: p.astype(jnp.float32), params)
        return st
    # zero-1: own 1/dp of every leaf, f32
    def chunk0(p):
        c = -(-p.size // ctx.dp_size)
        return jnp.zeros((c,), jnp.float32)
    st = {"m": jax.tree.map(chunk0, params),
          "v": jax.tree.map(chunk0, params),
          "master": jax.tree.map(lambda p: _my_chunk(p, ctx)
                                 .astype(jnp.float32), params),
          "count": jnp.zeros((), jnp.int32)}
    return st


def adamw_state_specs(params_specs: Any, ctx: ParallelCtx,
                      opt_cfg: AdamWConfig, has_master: bool = True):
    """Opt-state PartitionSpecs.  zero=0 mirrors params; zero=1 chunks
    are per-device-distinct over BOTH mesh axes (manual layout) — they
    are declared fully sharded over the whole mesh on dim 0 by packing:
    the global view is (n_dev * c,) with spec P((dp..., tp))."""
    if opt_cfg.zero == 0:
        st = {"m": params_specs, "v": params_specs, "count": P()}
        if has_master:
            st["master"] = params_specs
        return st
    all_axes = tuple(ctx.dp_axes) + (ctx.tp_axis,)
    chunk_spec = jax.tree.map(lambda s: P(all_axes),
                              params_specs,
                              is_leaf=lambda x: isinstance(x, P))
    return {"m": chunk_spec, "v": chunk_spec, "master": chunk_spec,
            "count": P()}


def adamw_update(params: Any, grads: Any, state: AdamWState,
                 ctx: ParallelCtx, opt_cfg: AdamWConfig,
                 grad_already_meaned: bool = True):
    """Returns (new_params, new_state).  zero=1 expects grads that have
    been TP-completed but NOT dp-reduced (pass bucket_bytes=0,
    dp_reduce=False to combine_grads) — the reduce-scatter happens here.
    """
    cnt = state["count"] + 1
    b1, b2 = opt_cfg.b1, opt_cfg.b2
    bc1 = 1 - b1 ** cnt.astype(jnp.float32)
    bc2 = 1 - b2 ** cnt.astype(jnp.float32)

    if opt_cfg.zero == 0:
        def upd(p, g, m, v, master):
            gf = g.astype(jnp.float32)
            m2 = b1 * m + (1 - b1) * gf
            v2 = b2 * v + (1 - b2) * gf * gf
            mh = m2 / bc1
            vh = v2 / bc2
            base = master if master is not None else p.astype(jnp.float32)
            step = opt_cfg.lr * (mh / (jnp.sqrt(vh) + opt_cfg.eps)
                                 + opt_cfg.weight_decay * base)
            newf = base - step
            return newf.astype(p.dtype), m2, v2, newf

        has_master = "master" in state
        masters = state["master"] if has_master else jax.tree.map(
            lambda p: None, params, is_leaf=lambda x: False)
        flat_p, tdef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state["m"])
        flat_v = jax.tree.leaves(state["v"])
        flat_ma = jax.tree.leaves(state["master"]) if has_master \
            else [None] * len(flat_p)
        outs = [upd(p, g, m, v, ma) for p, g, m, v, ma in
                zip(flat_p, flat_g, flat_m, flat_v, flat_ma)]
        new_params = jax.tree.unflatten(tdef, [o[0] for o in outs])
        new_state = {"m": jax.tree.unflatten(tdef, [o[1] for o in outs]),
                     "v": jax.tree.unflatten(tdef, [o[2] for o in outs]),
                     "count": cnt}
        if has_master:
            new_state["master"] = jax.tree.unflatten(
                tdef, [o[3] for o in outs])
        return new_params, new_state

    # ---------------- zero-1 ----------------
    dp = ctx.dp_size

    def upd1(p, g, m, v, master):
        gch = _chunk(g.astype(jnp.float32), dp)          # (dp, c)
        if dp > 1:
            gmine = ctx.dp_comm.psum_scatter(gch, axis=0)
            gmine = gmine.reshape(-1) / dp               # mean
        else:
            gmine = gch[0]
        m2 = b1 * m + (1 - b1) * gmine
        v2 = b2 * v + (1 - b2) * gmine * gmine
        mh = m2 / bc1
        vh = v2 / bc2
        step = opt_cfg.lr * (mh / (jnp.sqrt(vh) + opt_cfg.eps)
                             + opt_cfg.weight_decay * master)
        new_master = master - step
        if dp > 1:
            full = ctx.dp_comm.all_gather(new_master, axis=0, tiled=True)
        else:
            full = new_master
        newp = full[: p.size].reshape(p.shape).astype(p.dtype)
        return newp, m2, v2, new_master

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_ma = jax.tree.leaves(state["master"])
    outs = [upd1(p, g, m, v, ma) for p, g, m, v, ma in
            zip(flat_p, flat_g, flat_m, flat_v, flat_ma)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in outs])
    new_state = {"m": jax.tree.unflatten(tdef, [o[1] for o in outs]),
                 "v": jax.tree.unflatten(tdef, [o[2] for o in outs]),
                 "master": jax.tree.unflatten(tdef, [o[3] for o in outs]),
                 "count": cnt}
    return new_params, new_state
