"""repro.train — optimizer, gradient combine rules, train-step factory."""
from .grad import combine_grads, loss_and_grad
from .optimizer import AdamWState, adamw_init, adamw_update
from .step import TrainState, make_train_step, train_state_specs

__all__ = ["combine_grads", "loss_and_grad", "AdamWState", "adamw_init",
           "adamw_update", "TrainState", "make_train_step",
           "train_state_specs"]
