"""Train-step factory: loss -> single-seed grad -> spec combine ->
AdamW (ZeRO-0/1) -> new state.  Microbatching (gradient accumulation)
via lax.scan over microbatches.

DP gradient reduction has two equivalent schedules:

  blocking    tree_pmean / bucketed_psum — each reduction completes
              where it is issued;
  overlapped  ``overlap_grad_sync=True`` — reductions are issued
              nonblocking (``allreduce_nbi`` on a ``CommQueue``) in
              backward-walk order and drained by a single ``quiet()``
              immediately before the optimizer apply, the paper's §3.2
              compute/comm-overlap pattern.  Bit-identical loss
              trajectory to the blocking path (same bucket plan, same
              reduction order at the drain) — asserted by
              ``tests/multipe/run_ordering.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.ctx import ParallelCtx

from .grad import combine_grads, overlapped_grad_sync
from .optimizer import (AdamWConfig, adamw_init, adamw_state_specs,
                        adamw_update)

TrainState = dict  # {"params", "opt", "step"}


def init_train_state(key, cfg, ctx: ParallelCtx, model_api,
                     opt_cfg: AdamWConfig):
    params = model_api.init(key, cfg, ctx)
    return {"params": params, "opt": adamw_init(params, ctx, opt_cfg),
            "step": jnp.zeros((), jnp.int32)}


def train_state_specs(cfg, ctx: ParallelCtx, model_api,
                      opt_cfg: AdamWConfig, has_master=None):
    pspecs = model_api.specs(cfg, ctx)
    if has_master is None:
        has_master = ctx.param_dtype == jnp.bfloat16 or opt_cfg.zero == 1
    return {"params": pspecs,
            "opt": adamw_state_specs(pspecs, ctx, opt_cfg,
                                     has_master=has_master),
            "step": P()}


def make_train_step(cfg, ctx: ParallelCtx, model_api,
                    opt_cfg: AdamWConfig, *, microbatches: int = 1,
                    bucket_bytes: int = 0, compress: str = "none",
                    overlap_grad_sync: bool = False,
                    clip_norm: Optional[float] = 1.0):
    """Returns step(state, batch) -> (new_state, metrics), to be run
    inside shard_map.  batch leaves have a local batch dim divisible by
    ``microbatches``."""
    pspecs = model_api.specs(cfg, ctx)

    def one_grad(params, mb):
        lmask, grads = jax.value_and_grad(
            lambda p: model_api.loss_fn(p, mb, ctx, cfg, for_grad=True)
        )(params)
        return lmask, grads

    def step(state, batch):
        params = state["params"]
        if microbatches > 1:
            def split(x):
                b = x.shape[0]
                return x.reshape((microbatches, b // microbatches)
                                 + x.shape[1:])
            mbs = jax.tree.map(split, batch)

            def body(carry, mb):
                acc_l, acc_g = carry
                lmask, grads = one_grad(params, mb)
                return (acc_l + lmask,
                        jax.tree.map(jnp.add, acc_g, grads)), None

            zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                  params)
            (lmask, grads), _ = jax.lax.scan(
                body, (jnp.zeros(()), zero_g), mbs)
            lmask = lmask / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        else:
            lmask, grads = one_grad(params, batch)

        # TP completion by spec; DP handling depends on ZeRO mode
        if ctx.tp_size > 1:
            grads, _ = combine_grads(grads, pspecs,
                                     ctx.with_(dp_size=1), )
        if opt_cfg.zero == 0 and ctx.dp_size > 1:
            if compress != "none":
                grads, _ = ctx.dp_comm.compressed_psum(
                    grads, scheme=compress, mean=True)
            elif overlap_grad_sync:
                # nonblocking bucketed reductions, issued in backward-
                # walk order; ONE quiet() drains them all right here —
                # before the optimizer apply, nothing earlier blocks
                grads = overlapped_grad_sync(grads, ctx.dp_comm,
                                             bucket_bytes=bucket_bytes,
                                             mean=True)
            elif bucket_bytes:
                grads = ctx.dp_comm.bucketed_psum(
                    grads, bucket_bytes=bucket_bytes)
                grads = jax.tree.map(lambda g: g / ctx.dp_size, grads)
            else:
                grads = ctx.dp_comm.tree_pmean(grads)
        # zero=1: adamw_update reduce-scatters over DP internally

        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        if opt_cfg.zero == 1 and ctx.dp_size > 1:
            # per-replica grads: the norm shown is the replica-local one
            pass
        if clip_norm is not None:
            scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-12))
            grads = jax.tree.map(lambda g: g * scale, grads)

        new_params, new_opt = adamw_update(params, grads, state["opt"],
                                           ctx, opt_cfg)

        loss = ctx.dp_comm.pmean(ctx.tp_comm.psum(lmask))
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "step": state["step"] + 1}
        return ({"params": new_params, "opt": new_opt,
                 "step": state["step"] + 1}, metrics)

    return step
