"""Gradient computation + the spec-driven combine rule.

Convention (see lm.loss_fn for_grad docstring): jax.grad of the
single-seed loss yields, on every device, the *replica-local partial*
gradient.  Completion rules, derived purely from each param's
PartitionSpec:

  * spec mentions the TP axis  -> the param is sharded; each rank's grad
    is already complete for its shard.  No TP combine.
  * spec does NOT mention TP   -> the param is replicated; per-rank
    grads are disjoint partials (each rank saw its share of heads /
    tokens / vocab).  psum over TP completes them.
  * every param                -> pmean over DP (classic DDP), optionally
    bucketed and/or compressed (repro.comm), and optionally OVERLAPPED:
    reductions issued nonblocking during the backward walk and drained
    by one ``quiet()`` (paper §3.2 — see ``overlapped_grad_sync``).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.comm.bucketing import leaf_metas, plan_buckets, unpack_bucket
from repro.comm.communicator import Communicator
from repro.parallel.ctx import ParallelCtx


def _spec_has_axis(spec: P, axis: str) -> bool:
    for entry in tuple(spec):
        if entry == axis or (isinstance(entry, tuple) and axis in entry):
            return True
    return False


def overlapped_grad_sync(grads: Any, comm: Communicator, *,
                         bucket_bytes: int = 0, mean: bool = True) -> Any:
    """DP gradient reduction through the paper's nonblocking pipeline.

    The reductions are issued ``allreduce_nbi`` onto a ``CommQueue`` in
    **reverse leaf order** — the order the backward walk produces
    gradients (output layer first) — and nothing completes until the
    single ``quiet()`` right before the caller applies the optimizer.
    Between issue and drain the reductions are pending, mutually
    independent ops; at the drain they materialize as a batch of
    collectives with no serializing dependencies between buckets, which
    is the freedom XLA's scheduler needs to overlap them with the
    remaining backward compute (under jax.grad the whole cotangent tree
    exists before the first issue, so the interleaving is expressed at
    the schedule level — the honest SPMD reading of the paper's
    put-completes-locally overlap).

    Bucketing follows the SAME plan as the blocking
    ``bucketed_allreduce`` (``repro.comm.bucketing.plan_buckets``) and
    reductions deliver in issue order at the drain, so the result is
    bit-identical to the blocking path with equal ``bucket_bytes`` —
    asserted by ``tests/multipe/run_ordering.py``.
    """
    leaves, treedef = jax.tree.flatten(grads)
    if not leaves or comm.size == 1:
        return grads
    q = comm.queue()
    reduced = [None] * len(leaves)
    if bucket_bytes:
        metas = leaf_metas(leaves)
        plan = plan_buckets(metas, bucket_bytes)
        pending = []
        for bucket in reversed(plan):            # backward-walk order
            flat = jnp.concatenate([leaves[i].ravel() for i in bucket])
            pending.append((bucket, q.allreduce_nbi(flat, comm.psum)))
        q.quiet()                                # the single drain point
        for bucket, res in pending:
            unpack_bucket(res.value(), bucket, metas, reduced)
    else:
        pending = [q.allreduce_nbi(l, comm.psum) for l in reversed(leaves)]
        q.quiet()                                # the single drain point
        for i, res in zip(reversed(range(len(leaves))), pending):
            reduced[i] = res.value()
    out = jax.tree.unflatten(treedef, reduced)
    if mean:
        out = jax.tree.map(lambda g: g / comm.size, out)
    return out


def combine_grads(grads: Any, specs: Any, ctx: ParallelCtx, *,
                  bucket_bytes: int = 0, compress: str = "none",
                  comp_state=None, overlap: bool = False):
    """Complete replica-local grads per the spec rule, then DP-mean
    (overlapped through the nonblocking pipeline when ``overlap``)."""
    if ctx.tp_size > 1:
        def tp_fix(g, s):
            if _spec_has_axis(s, ctx.tp_axis):
                return g
            return ctx.tp_comm.psum(g)
        grads = jax.tree.map(tp_fix, grads, specs,
                             is_leaf=lambda x: isinstance(x, P))
    if ctx.dp_size > 1:
        if compress != "none":
            grads, comp_state = ctx.dp_comm.compressed_psum(
                grads, scheme=compress, state=comp_state, mean=True)
        elif overlap:
            grads = overlapped_grad_sync(grads, ctx.dp_comm,
                                         bucket_bytes=bucket_bytes,
                                         mean=True)
        elif bucket_bytes:
            grads = ctx.dp_comm.bucketed_psum(grads,
                                              bucket_bytes=bucket_bytes)
            grads = jax.tree.map(lambda g: g / ctx.dp_size, grads)
        else:
            grads = ctx.dp_comm.tree_pmean(grads)
    return grads, comp_state


def loss_and_grad(loss_fn, params, batch, ctx: ParallelCtx, cfg, specs,
                  **combine_kw):
    """value_and_grad with the single-seed + spec-combine convention.
    Returns (display_loss, grads, comp_state)."""
    lmask, grads = jax.value_and_grad(
        lambda p: loss_fn(p, batch, ctx, cfg, for_grad=True))(params)
    # reconstruct the display value from the masked scalar
    loss = ctx.dp_comm.pmean(ctx.tp_comm.psum(lmask))
    grads, comp_state = combine_grads(grads, specs, ctx, **combine_kw)
    return loss, grads, comp_state
