"""Gradient computation + the spec-driven combine rule.

Convention (see lm.loss_fn for_grad docstring): jax.grad of the
single-seed loss yields, on every device, the *replica-local partial*
gradient.  Completion rules, derived purely from each param's
PartitionSpec:

  * spec mentions the TP axis  -> the param is sharded; each rank's grad
    is already complete for its shard.  No TP combine.
  * spec does NOT mention TP   -> the param is replicated; per-rank
    grads are disjoint partials (each rank saw its share of heads /
    tokens / vocab).  psum over TP completes them.
  * every param                -> pmean over DP (classic DDP), optionally
    bucketed and/or compressed (repro.comm).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.ctx import ParallelCtx


def _spec_has_axis(spec: P, axis: str) -> bool:
    for entry in tuple(spec):
        if entry == axis or (isinstance(entry, tuple) and axis in entry):
            return True
    return False


def combine_grads(grads: Any, specs: Any, ctx: ParallelCtx, *,
                  bucket_bytes: int = 0, compress: str = "none",
                  comp_state=None):
    """Complete replica-local grads per the spec rule, then DP-mean."""
    if ctx.tp_size > 1:
        def tp_fix(g, s):
            if _spec_has_axis(s, ctx.tp_axis):
                return g
            return ctx.tp_comm.psum(g)
        grads = jax.tree.map(tp_fix, grads, specs,
                             is_leaf=lambda x: isinstance(x, P))
    if ctx.dp_size > 1:
        if compress != "none":
            grads, comp_state = ctx.dp_comm.compressed_psum(
                grads, scheme=compress, state=comp_state, mean=True)
        elif bucket_bytes:
            grads = ctx.dp_comm.bucketed_psum(grads,
                                              bucket_bytes=bucket_bytes)
            grads = jax.tree.map(lambda g: g / ctx.dp_size, grads)
        else:
            grads = ctx.dp_comm.tree_pmean(grads)
    return grads, comp_state


def loss_and_grad(loss_fn, params, batch, ctx: ParallelCtx, cfg, specs,
                  **combine_kw):
    """value_and_grad with the single-seed + spec-combine convention.
    Returns (display_loss, grads, comp_state)."""
    lmask, grads = jax.value_and_grad(
        lambda p: loss_fn(p, batch, ctx, cfg, for_grad=True))(params)
    # reconstruct the display value from the masked scalar
    loss = ctx.dp_comm.pmean(ctx.tp_comm.psum(lmask))
    grads, comp_state = combine_grads(grads, specs, ctx, **combine_kw)
    return loss, grads, comp_state
