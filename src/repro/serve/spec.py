"""Speculative decoding — pluggable draft proposers for the paged
serving engine.

Draft-then-verify turns N sequential decode ticks into one batched
verify pass: a cheap PROPOSER guesses ``k`` tokens per running
sequence, the target model scores the pending token plus all drafts in
ONE ``(B, k+1)`` forward through the chunked-prefill machinery
(``engine.make_verify`` over ``ops.paged_prefill_attention``), and the
engine accepts the longest prefix of drafts that matches what the
target itself generates.

**Losslessness.**  The target's draw at a position is a pure function
of its counter-RNG key ``(rid, position)`` (``serve.sampling``), i.e. a
DETERMINISTIC point distribution once the key is fixed.  Leviathan-
style rejection sampling (accept draft ``d`` with probability
``min(1, p_target(d) / p_draft(d))``, resample the residual otherwise)
therefore collapses: the proposers here make point proposals (one-hot
draft distributions) and the target's counter draw is one-hot too, so
the accept test degenerates to EXACT MATCHING and the residual
resample IS the target's own draw — which is what makes accepted
streams bit-identical to non-speculative decoding on every
communicator backend (xla / posh / pallas), greedy and sampled alike.
Proposers can therefore never change WHAT is generated, only how many
ticks it takes: a bad proposer costs verify compute, a good one emits
``m + 1`` tokens per tick.

Proposers are host-side objects with three hooks:

    propose(reqs, allow) -> list[list[int]]   up to allow[i] drafts per
                                              decoding sequence
    rewind(rid, n_valid)                      verify rejected a suffix;
                                              tokens past ``n_valid``
                                              never happened
    drop(rid)                                 sequence finished or was
                                              preempted (all state gone)

Included proposers:

  * :class:`NgramProposer` — prompt-lookup self-drafting (no second
    model): propose the continuation of the most recent earlier
    occurrence of the context's longest matching suffix n-gram.  Free,
    and strong exactly where speculation pays: repeated prompts,
    greedy repetition loops, copy-heavy decoding.
  * :class:`DraftModelProposer` — a registry-backed SMALL draft model
    sharing the target's TP mesh (its collectives route through the
    same ``ctx.tp_comm``) and the target's page geometry: the draft
    keeps its own page pool shaped by its own layer/head counts but
    indexed by the SAME block tables, so one allocator (and one
    ``truncate`` rewind) governs both caches.
  * :class:`ReplayProposer` — oracle drafts from known streams (tests
    and benchmark upper bounds: accept-rate 1, ``k+1`` tokens/tick).
  * :class:`FixedProposer` — a constant (usually wrong) proposal, the
    adversarial case pinning the rewind path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import sampling
from .engine import ServeConfig, make_decode_step, make_prefill
from .kv_cache import PagedKVCache


class SpecProposer:
    """Protocol base: a proposer that never proposes (spec decode with
    this degenerates to plain decode through the verify window)."""

    def propose(self, reqs, allow) -> list:
        return [[] for _ in reqs]

    def rewind(self, rid, n_valid: int) -> None:
        pass

    def drop(self, rid) -> None:
        pass


class NgramProposer(SpecProposer):
    """Prompt-lookup self-drafting (n-gram speculation).

    For each sequence, take the longest suffix n-gram of its full
    history (prompt + generated tokens), find its most recent EARLIER
    occurrence, and propose the tokens that followed it.  Matches are
    tried from ``max_n`` down to ``min_n``; no match -> no drafts (the
    verify window then carries just the pending token, i.e. a plain
    decode step).  Host-side and deterministic, so it cannot perturb
    the scheduler's backend-invariant decisions."""

    def __init__(self, min_n: int = 1, max_n: int = 3):
        if not 1 <= min_n <= max_n:
            raise ValueError(f"need 1 <= min_n <= max_n, got "
                             f"({min_n}, {max_n})")
        self.min_n, self.max_n = int(min_n), int(max_n)

    def propose(self, reqs, allow):
        return [self._one(r, a) for r, a in zip(reqs, allow)]

    def _one(self, req, k: int) -> list:
        if k <= 0:
            return []
        hist = list(req.prompt) + list(req.out)
        for n in range(self.max_n, self.min_n - 1, -1):
            if len(hist) <= n:
                continue
            suffix = hist[-n:]
            # most recent occurrence strictly before the suffix itself
            for j in range(len(hist) - n - 1, -1, -1):
                if hist[j:j + n] == suffix:
                    return [int(t) for t in hist[j + n:j + n + k]]
        return []


class ReplayProposer(SpecProposer):
    """Oracle drafts replayed from known output streams (``rid ->
    token list``).  Every draft is accepted by construction, so it
    measures the verify path's ``k+1`` tokens-per-tick ceiling — the
    tests' deterministic multi-accept case."""

    def __init__(self, streams: dict):
        self.streams = {int(rid): [int(t) for t in toks]
                        for rid, toks in streams.items()}

    def propose(self, reqs, allow):
        out = []
        for r, a in zip(reqs, allow):
            stream = self.streams.get(r.rid, [])
            out.append(stream[len(r.out):len(r.out) + max(a, 0)])
        return out


class FixedProposer(SpecProposer):
    """Always proposes the same tokens — the adversarial case: every
    draft the target disagrees with is rejected and rewound."""

    def __init__(self, tokens):
        self.tokens = [int(t) for t in tokens]

    def propose(self, reqs, allow):
        return [self.tokens[:max(a, 0)] for _, a in zip(reqs, allow)]


class DraftModelProposer(SpecProposer):
    """A small registry-backed draft model drafting greedily on the
    target's mesh and page geometry.

    The draft keeps its OWN page pool — shaped by the draft config's
    ``(n_layers, kv_heads, head_dim)`` but with the target pool's
    ``(n_pages, page_tokens)`` — indexed by the SAME block tables the
    target uses, so page allocation, eviction and speculative rewind
    are decided once (by the shared :class:`PagedKVCache`) for both
    caches.  Per tick the proposer (a) CATCHES UP: chunk-prefills any
    history tokens the draft has not processed (accepted tokens it
    drafted itself re-feed idempotently — same pages, same slots), the
    final window's sample being the first draft; then (b) DRAFTS:
    ``allow - 1`` greedy single-token decode steps.  Both step
    functions are the engine's own (``make_prefill`` /
    ``make_decode_step``) built from the draft config, so every draft
    collective routes through ``ctx.tp_comm`` like the target's.

    The draft's token ids must mean the same thing as the target's:
    construction requires matching vocabularies."""

    def __init__(self, params, cfg, ctx, scfg: ServeConfig,
                 kv: PagedKVCache, *, target_vocab: int | None = None,
                 jit=jax.jit):
        if target_vocab is not None and cfg.vocab != target_vocab:
            raise ValueError(
                f"draft model vocab {cfg.vocab} != target vocab "
                f"{target_vocab}: draft tokens would be meaningless")
        self.params, self.cfg, self.scfg, self.kv = params, cfg, scfg, kv
        self.ctx = ctx
        self._prefill = jit(make_prefill(cfg, ctx, scfg))
        self._decode = jit(make_decode_step(cfg, ctx, scfg))
        self.pool = jnp.zeros(
            (kv.n_pages, 2, cfg.n_layers, kv.page_tokens,
             cfg.kv_per_rank(ctx.tp_size), cfg.head_dim), scfg.kv_dtype)
        # drafts are the draft model's GREEDY continuations: argmax
        # needs no RNG, so drafting is deterministic by construction
        self._greedy = sampling.batch_state([], scfg.max_batch, 0)
        self.seen: dict = {}           # rid -> history tokens processed

    def rewind(self, rid, n_valid: int) -> None:
        if rid in self.seen:
            self.seen[rid] = min(self.seen[rid], int(n_valid))

    def drop(self, rid) -> None:
        self.seen.pop(rid, None)

    # ------------------------------------------------------------------
    def _tables(self, reqs, live) -> np.ndarray:
        """Block tables with non-participating rows nulled, so their
        placeholder writes land in the null page instead of scribbling
        over a live sequence's draft K/V."""
        B = self.scfg.max_batch
        ids = [r.rid if i in live else None for i, r in enumerate(reqs)]
        return self.kv.block_table(ids + [None] * (B - len(reqs)),
                                   self.scfg.table_slots)

    def propose(self, reqs, allow):
        B, C = self.scfg.max_batch, self.scfg.prefill_chunk
        hist = {r.rid: list(r.prompt) + list(r.out) for r in reqs}
        first: dict = {}
        # --- catch-up: feed unseen history in prefill-chunk windows
        while True:
            pend = [i for i, r in enumerate(reqs) if allow[i] > 0
                    and self.seen.get(r.rid, 0) < len(hist[r.rid])]
            if not pend:
                break
            ids = np.zeros((B, C), np.int32)
            start = np.zeros((B,), np.int32)
            n_tok = np.zeros((B,), np.int32)
            for i in pend:
                h, s = hist[reqs[i].rid], self.seen.get(reqs[i].rid, 0)
                n = min(C, len(h) - s)
                ids[i, :n] = h[s:s + n]
                start[i], n_tok[i] = s, n
            toks, self.pool = self._prefill(
                self.params, self.pool, ids, start, n_tok,
                self._tables(reqs, set(pend)), self._greedy)
            toks = np.asarray(toks)
            for i in pend:
                rid = reqs[i].rid
                self.seen[rid] = int(start[i] + n_tok[i])
                if self.seen[rid] == len(hist[rid]):
                    first[rid] = int(toks[i])    # the first draft token
        # --- draft: allow-1 further greedy decode steps
        drafts = [[first[r.rid]] if allow[i] > 0 and r.rid in first
                  else [] for i, r in enumerate(reqs)]
        for t in range(1, max(allow, default=0)):
            live = {i for i, r in enumerate(reqs)
                    if allow[i] > t and drafts[i]}
            if not live:
                break
            tokens = np.zeros((B,), np.int32)
            pos = np.zeros((B,), np.int32)
            lens = np.zeros((B,), np.int32)
            for i in live:
                tokens[i] = drafts[i][-1]
                p = len(hist[reqs[i].rid]) + t - 1
                pos[i], lens[i] = p, p + 1
            toks, self.pool = self._decode(
                self.params, self.pool, tokens, pos,
                self._tables(reqs, live), lens, self._greedy)
            toks = np.asarray(toks)
            for i in live:
                drafts[i].append(int(toks[i]))
        return drafts


PROPOSERS = ("ngram",)


def make_proposer(name: str) -> SpecProposer:
    """Build a parameterless proposer by name (``ServeConfig.draft``).
    Model-backed proposers need params/config and are constructed by
    the caller (see ``launch/serve.py``)."""
    if name == "ngram":
        return NgramProposer()
    raise ValueError(
        f"unknown draft proposer '{name}' (parameterless: {PROPOSERS}; "
        f"model-backed drafting: construct serve.spec.DraftModelProposer "
        f"and pass it as ServeEngine(..., proposer=...))")
