"""Disaggregated prefill/decode serving cells with put-with-signal
page handoff.

Colocated continuous batching (``ServeEngine``) makes every decode
tick share its batch with prefill chunks — chunking bounds the damage,
but a prefill-heavy trace still steals decode budget.  Disaggregation
(DistServe / Splitwise / Mooncake in PAPERS.md) splits the mesh into
PREFILL cells and DECODE cells: prompts burn their compute on cells
that decode never sees, and finished prefills migrate their KV pages
to a decode cell once.

The migration is where POSH earns its keep.  The colocated engine
drains page moves with ONE ``quiet()`` per tick — a full completion
barrier every cell would pay on every handoff.  Here each handoff is a
*ticket*: the producer streams the sequence's pages into the consumer
cell's mailbox with ``put_signal_nbi`` (every page guarded by the
ticket's signal word, one word per ticket carved from the symmetric
heap by :class:`~repro.core.signals.SignalPad`), and the consumer
adopts the sequence the moment ``signal_wait_until`` on that word
returns — a per-transfer drain that retires ONLY this ticket's pages.
No cell ever issues a tick-global quiet for handoff traffic
(``handoff_quiets == 0`` is asserted by the bench gate), and a decode
cell consumes a sequence on signal fire instead of at a barrier shared
with unrelated producers.

Topology is host-side and explicit:

  * :class:`CellRouter` — admits each prompt to the least-loaded
    prefill cell (queued prompt tokens) and owns each handoff to the
    least-loaded decode cell (live + inbound sequences);
  * :class:`DisaggEngine` — one ``ServeEngine`` per cell
    (``role="prefill"`` / ``role="decode"``), cell PE ids carved from
    the flat PE space with :class:`repro.core.teams.ActiveSet`, and
    ONE persistent handoff ``CommQueue`` over the cell space whose
    stats expose ``handoff_signals`` / ``handoff_quiets``.

Token streams are unchanged by construction: sampling is keyed
``(rid, position)`` off ``ServeConfig.sample_seed``, so a sequence
decoded on a different cell — in whatever batch composition — draws
the exact tokens the colocated engine draws (the parity tests pin
this, greedy and sampled, speculation on and off).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional, Sequence

import numpy as np

from repro.core.heap import SymmetricHeap
from repro.core.ordering import CommQueue, LocalTransport
from repro.core.signals import CMP_EQ, SignalPad
from repro.core.teams import ActiveSet

from .engine import ServeConfig, ServeEngine
from .scheduler import Request


@dataclasses.dataclass(frozen=True)
class CellSpec:
    """One serving cell: its index in the cell space, its role, and
    the PE ids (flat mesh numbering) it owns — an OpenSHMEM active
    set, so a 2-PE tensor-parallel cell is ``stride 1, size 2``."""

    cell: int
    role: str                      # "prefill" | "decode"
    pes: tuple[int, ...]


def make_cells(n_prefill: int, n_decode: int,
               pes_per_cell: int = 1) -> list[CellSpec]:
    """Carve ``n_prefill + n_decode`` cells out of the flat PE space,
    prefill cells first, each owning ``pes_per_cell`` consecutive PEs
    (``ActiveSet(start=cell * pes_per_cell, size=pes_per_cell)``)."""
    if n_prefill < 1 or n_decode < 1:
        raise ValueError("need at least one prefill and one decode cell")
    cells = []
    for c in range(n_prefill + n_decode):
        aset = ActiveSet(start=c * pes_per_cell, size=pes_per_cell)
        role = "prefill" if c < n_prefill else "decode"
        cells.append(CellSpec(c, role, tuple(aset.pes())))
    return cells


@dataclasses.dataclass
class HandoffTicket:
    """One in-flight prefill->decode page handoff."""

    ticket: int                    # unique id; signal value = ticket + 1
    req: Request
    src_cell: int
    dst_cell: int
    src_pages: list                # producer-pool page ids (resident)
    dst_pages: list                # consumer-pool landing page ids
    word: int                      # SignalPad offset guarding the ticket


class CellRouter:
    """Host-side admission + handoff routing across cells.

    Least-loaded placement on both sides: prompts go to the prefill
    cell with the fewest QUEUED PROMPT TOKENS still to compute
    (waiting + running prefill remainders), handoffs go to the decode
    cell with the fewest LIVE + INBOUND sequences.  Ties break to the
    lowest cell index, so routing is deterministic for a given trace —
    the property every parity test leans on."""

    def __init__(self, engines: Sequence[ServeEngine],
                 cells: Sequence[CellSpec]):
        self.engines = list(engines)
        self.cells = list(cells)
        self.prefill = [c.cell for c in cells if c.role == "prefill"]
        self.decode = [c.cell for c in cells if c.role == "decode"]
        self.inbound = {c: 0 for c in self.decode}   # undelivered tickets

    def prefill_load(self, cell: int) -> int:
        e = self.engines[cell]
        return (sum(r.n_prompt for r in e.sched.waiting)
                + sum(r.n_prompt - r.n_done for r in e.sched.running
                      if r.is_prefilling()))

    def decode_load(self, cell: int) -> int:
        return len(self.engines[cell].sched.running) + self.inbound[cell]

    def route_prompt(self, req: Request) -> int:
        return min(self.prefill, key=lambda c: (self.prefill_load(c), c))

    def route_handoff(self, req: Request) -> Optional[int]:
        """The decode cell that will own ``req`` — None when every
        decode cell's batch (live + inbound) is full (backpressure:
        the producer keeps the sequence parked, pages resident)."""
        c = min(self.decode, key=lambda c: (self.decode_load(c), c))
        if self.decode_load(c) >= self.engines[c].scfg.max_batch:
            return None
        return c

    def inbound_add(self, cell: int, delta: int) -> None:
        """Inbound-ticket accounting hook (the AMO router overrides
        this with a ``fadd`` on the cell's inbound word)."""
        self.inbound[cell] += delta


class DisaggEngine:
    """P prefill + D decode ``ServeEngine`` cells behind one submit/run
    interface, handing sequences off through a put-with-signal mailbox.

    The mailbox is a persistent :class:`CommQueue` over the CELL space
    (``LocalTransport(n_cells)``): one symmetric ``kv_mail`` object
    mirroring the page-pool geometry plus a :class:`SignalPad` of
    ticket words.  Producers ``put_signal_nbi`` each exported page into
    the consumer's mailbox rows at the LANDING page ids (the consumer
    carved them with ``PagedKVCache.adopt_seq`` — that is the
    block-table remap); the consumer drains with ONE
    ``signal_wait_until`` per ticket, copies the landed rows into its
    pool, and acknowledges so the producer frees its source pages.
    ``stats()["handoff_quiets"]`` stays 0 — the per-transfer drain IS
    the point."""

    def __init__(self, params, cfg, ctx, scfg: ServeConfig, *,
                 n_prefill: int = 1, n_decode: int = 1,
                 pes_per_cell: int = 1, engines=None,
                 delivery_seed: Optional[int] = 0,
                 n_ticket_words: Optional[int] = None,
                 router: str = "host"):
        if router not in ("host", "amo"):
            raise ValueError(f"router must be 'host' or 'amo', "
                             f"got {router!r}")
        self.scfg = scfg
        self.router_mode = router
        self.cells = make_cells(n_prefill, n_decode, pes_per_cell)
        n_cells = len(self.cells)
        if engines is None:
            engines = [
                ServeEngine(params, cfg, ctx, scfg, role=c.role,
                            my_pe=c.pes[0])
                for c in self.cells
            ]
        if len(engines) != n_cells:
            raise ValueError(f"{len(engines)} engines for {n_cells} cells")
        for e, c in zip(engines, self.cells):
            if e.role != c.role:
                raise ValueError(f"cell {c.cell} is {c.role} but its "
                                 f"engine is {e.role}")
        self.engines = list(engines)
        self.pools: list = []
        if router == "amo":
            # the whole control plane goes lock-free: CAS-arbitrated
            # admission/handoff routing AND a symmetric page pool
            # behind every cell's allocator (identical grant order, so
            # token streams cannot move)
            from .amo_router import AmoCellRouter
            from .page_pool import SymmetricPagePool
            self.router = AmoCellRouter(self.engines, self.cells,
                                        delivery_seed=delivery_seed)
            for i, e in enumerate(self.engines):
                pool = SymmetricPagePool(e.kv.n_pages,
                                         delivery_seed=delivery_seed,
                                         name=f"pool_words_{i}")
                e.kv.attach_pool(pool)
                self.pools.append(pool)
        else:
            self.router = CellRouter(self.engines, self.cells)

        # the handoff mailbox: symmetric objects over the cell space.
        # The page-row shape comes from the exec substrate (a mesh cell
        # hands off its pages as stacked per-TP-rank shards), so the
        # mailbox works for any pool layout.
        kv0 = self.engines[0].kv
        e0 = self.engines[0]
        row0 = np.asarray(e0.exec.read_pages(e0.pool, [0]))
        mail_heap = SymmetricHeap(("cells",))
        self._kv_mail = mail_heap.alloc(
            "kv_mail", (kv0.n_pages,) + row0.shape[1:], row0.dtype)
        n_words = n_ticket_words or max(2 * scfg.max_batch, 4)
        self.pad = SignalPad(mail_heap, n_words)
        # mailbox-slot claim words (same carve as the signal pad): in
        # AMO mode a producer owns word w of a consumer's pad iff it
        # won cswap(claim[w], 0 -> ticket+1) on that cell
        self._claim = SignalPad(mail_heap, n_words, name="mail_claim")
        self._mail_state = {
            "kv_mail": np.zeros((n_cells,) + self._kv_mail.shape,
                                self._kv_mail.dtype),
            self.pad.handle.name:
                np.zeros((n_cells, self.pad.n), self.pad.handle.dtype),
            self._claim.handle.name:
                np.zeros((n_cells, self._claim.n),
                         self._claim.handle.dtype),
        }
        self.hq = CommQueue("cells", self._mail_state,
                            transport=LocalTransport(n_cells),
                            delivery_seed=delivery_seed)
        # a ticket word is reused only after its ticket was adopted —
        # per consumer cell, so concurrent handoffs never share a word
        self._free_words = {c: deque(range(self.pad.n))
                            for c in self.router.decode}
        self._inbox = {c: deque() for c in self.router.decode}
        self._tickets = 0
        self.ticks = 0
        self.handoff = {"handoff_tickets": 0, "handoff_pages": 0,
                        "handoff_deferred": 0}
        # weight hot-swap: ONE streamer spans the cell space, so every
        # cell flips to the new generation on the same topology tick
        self._swap = None
        self.swap_stats = {"generation": 0, "flips": 0, "swap_ticks": 0,
                           "swap_batches": 0, "swap_bytes": 0,
                           "swap_extra_quiets": 0}

    # ------------------------------------------------------------------
    @property
    def finished(self) -> list:
        out = []
        for e in self.engines:
            out.extend(e.finished)
        return out

    def has_work(self) -> bool:
        return (any(e.sched.has_work() for e in self.engines)
                or any(e.handoff_ready for e in self.engines)
                or any(self._inbox.values())
                or self._swap is not None
                or (self.router_mode == "amo"
                    and self.router.pending() > 0))

    def begin_hot_swap(self, new_params, *, chunk_rows: int = 4,
                       **kw) -> None:
        """Zero-downtime weight swap across the whole topology: one
        :class:`repro.ckpt.hotswap.WeightStreamer` over the CELL space
        streams the new generation between topology ticks; on the flip
        tick every cell's weights switch together."""
        if self._swap is not None:
            raise RuntimeError("a weight hot-swap is already in flight")
        from repro.ckpt.hotswap import WeightStreamer
        self.swap_stats["generation"] += 1
        self._swap = WeightStreamer(
            new_params, n_pe=len(self.cells),
            generation=self.swap_stats["generation"],
            chunk_rows=chunk_rows, **kw)

    def _swap_step(self) -> None:
        st = self._swap
        if not st.step():
            return
        params = st.result()
        for e in self.engines:           # same tick, every cell
            e.exec.set_params(params)
        self.swap_stats["flips"] += st.stats["flips"]
        self.swap_stats["swap_ticks"] += st.stats["swap_ticks"]
        self.swap_stats["swap_batches"] += st.stats["batches"]
        self.swap_stats["swap_bytes"] += st.stats["bytes"]
        self.swap_stats["swap_extra_quiets"] += st.extra_global_drains()
        self._swap = None

    def submit(self, req: Request) -> None:
        if self.router_mode == "amo":
            # publish into an admission ring; a cell claims it by CAS
            # at the next tick (same-tick admission, like host mode)
            self.router.submit(req)
        else:
            self.engines[self.router.route_prompt(req)].submit(req)

    # ------------------------------------------------------------------
    def tick(self, now: float = 0.0) -> None:
        """One topology tick: prefill cells advance, finished prefills
        ticket out (put-with-signal per page), decode cells drain their
        inbox on signal fire, adopt, acknowledge, then advance."""
        self.ticks += 1
        if self._swap is not None:
            self._swap_step()
        if self.router_mode == "amo":
            self.router.admit()
        for c in self.router.prefill:
            e = self.engines[c]
            if e.sched.has_work():
                e.tick(now)
        for c in self.router.prefill:
            self._issue_handoffs(c)
        for c in self.router.decode:
            self._drain_inbox(c, now)
            e = self.engines[c]
            if e.sched.has_work():
                e.tick(now)
        if self.router_mode == "amo":
            self.router.publish_loads()

    def _claim_word(self, cell: int) -> Optional[int]:
        """Claim a free mailbox word on ``cell``.  Host mode pops the
        FIFO recycle deque; AMO mode scans the claim words and owns the
        first one it wins with ``cswap(0 -> ticket+1)``."""
        if self.router_mode != "amo":
            fw = self._free_words[cell]
            return fw.popleft() if fw else None
        for w in range(self._claim.n):
            old = self.hq.amo_nbi(  # shmem: deferred-drain
                self._claim.handle, "cswap", [(cell, cell)],
                value=self._tickets + 1, cond=0, offset=w)
            self.hq.amo_wait(self._claim.handle, offset=w)
            if int(old.value()) == 0:
                return w
        return None

    def _release_word(self, cell: int, word: int, *,
                      to_front: bool = False) -> None:
        """Return a mailbox word: AMO mode clears the claim word (an
        atomic swap, so shmemcheck sees it); host mode requeues —
        ``to_front`` restores a claim that was rolled back before use."""
        if self.router_mode == "amo":
            self.hq.amo_nbi(  # shmem: deferred-drain
                self._claim.handle, "swap", [(cell, cell)], value=0,
                offset=word)
            self.hq.amo_wait(self._claim.handle, offset=word)
        elif to_front:
            self._free_words[cell].appendleft(word)
        else:
            self._free_words[cell].append(word)

    def _issue_handoffs(self, src_cell: int) -> None:
        src = self.engines[src_cell]
        parked = []
        while src.handoff_ready:
            req = src.handoff_ready.pop(0)
            dst_cell = self.router.route_handoff(req)
            word = None if dst_cell is None else self._claim_word(dst_cell)
            if word is None:
                # backpressure: every decode batch (or the word pad) is
                # full; the sequence stays parked, its pages resident
                parked.append(req)
                self.handoff["handoff_deferred"] += 1
                continue
            src_pages = src.kv.export_seq(req.rid)
            dst_pages = self.engines[dst_cell].kv.adopt_seq(
                req.rid, len(src_pages))
            if dst_pages is None:            # consumer pool dry
                src.kv.attach_seq(req.rid, src_pages)
                src.kv.stats["exported_pages"] -= len(src_pages)
                self._release_word(dst_cell, word, to_front=True)
                parked.append(req)
                self.handoff["handoff_deferred"] += 1
                continue
            t = HandoffTicket(self._tickets, req, src_cell, dst_cell,
                              src_pages, dst_pages, word)
            self._tickets += 1
            self._put_pages(t)
            self.router.inbound_add(dst_cell, 1)
            self._inbox[dst_cell].append(t)
            self.handoff["handoff_tickets"] += 1
            self.handoff["handoff_pages"] += len(src_pages)
        src.handoff_ready.extend(parked)

    def _put_pages(self, t: HandoffTicket) -> None:
        """Stream one ticket's pages: every page is a put-with-signal
        into the consumer's mailbox at its LANDING page id, all guarded
        by the ticket's word (SIGNAL_SET of ``ticket + 1`` — the same
        value per page, so the settled word is shuffle-invariant)."""
        src = self.engines[t.src_cell]
        rows = np.asarray(src.exec.read_pages(src.pool, t.src_pages))
        n_cells = len(self.cells)
        pairs = [(t.src_cell, t.dst_cell)]
        for row, dp in zip(rows, t.dst_pages):
            data = np.zeros((n_cells, 1) + row.shape, row.dtype)
            data[t.src_cell, 0] = row
            # drained per-transfer by _drain_inbox's signal_wait_until
            self.hq.put_signal_nbi(  # shmem: deferred-drain
                self._kv_mail, data, pairs, self.pad.handle,
                t.ticket + 1, offset=dp, sig_offset=t.word)

    def _drain_inbox(self, cell: int, now: float) -> None:
        """Adopt every deliverable ticket: ONE ``signal_wait_until`` on
        the ticket's word retires exactly its pages (never a quiet),
        then the landed rows are copied into the cell pool and the
        producer is acknowledged (frees its source pages, recycles the
        word)."""
        dst = self.engines[cell]
        inbox = self._inbox[cell]
        while inbox:
            t = inbox[0]
            st = self.hq.signal_wait_until(
                self.pad.handle, CMP_EQ, t.ticket + 1,
                sig_offset=t.word, pe=cell)
            inbox.popleft()
            rows = st["kv_mail"][cell][np.asarray(t.dst_pages)]
            dst.pool = dst.exec.write_pages(dst.pool, t.dst_pages, rows)
            dst.adopt_request(t.req, dst.kv.tables.pop(t.req.rid), now)
            # ack: the producer's copy served its purpose
            self.engines[t.src_cell].kv.release_pages(t.src_pages)
            self.router.inbound_add(cell, -1)
            # the word only recycles once its ticket is fully retired —
            # zeroed THROUGH the queue (signal_reset), so the recycle
            # write is part of the traced protocol shmemcheck verifies,
            # not a host-side mutation behind its back
            self.hq.signal_reset(self.pad.handle, [(cell, cell)],
                                 sig_offset=t.word)
            self._release_word(cell, t.word)

    # ------------------------------------------------------------------
    def run(self, requests: Sequence[Request], *, clock: str = "tick",
            max_ticks: int = 100_000) -> list:
        """Replay an arrival trace to completion across the cells
        (``clock`` as in ``ServeEngine.run``; the deterministic "tick"
        clock is the default — it is what the parity suites compare)."""
        import time
        pending = sorted(requests, key=lambda r: r.t_arrive)
        t0 = time.monotonic()
        skipped = 0.0
        for _ in range(max_ticks):
            now = (self.ticks if clock == "tick"
                   else time.monotonic() - t0 + skipped)
            while pending and pending[0].t_arrive <= now:
                self.submit(pending.pop(0))
            if not self.has_work():
                if not pending:
                    return self.finished
                if clock == "wall":
                    skipped += pending[0].t_arrive - now
                    now = time.monotonic() - t0 + skipped
                self.submit(pending.pop(0))
            self.tick(now)
        raise RuntimeError(f"disagg loop did not converge in {max_ticks} "
                           f"ticks ({len(self.finished)} finished)")

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        """Handoff-path counters.  ``handoff_signals`` counts
        put-with-signal transfers and per-transfer waits on the mailbox
        queue; ``handoff_quiets`` counts tick-global barriers on it —
        the disagg contract is that it stays ZERO.  In ``--router amo``
        mode the router/allocator counters ride along: ``router_quiets``
        (barriers on the router queue AND every cell's pool queue — the
        lock-free contract pins it to zero too), ``steals``, and
        ``alloc_cas_retries``."""
        hs = self.hq.stats()
        out = dict(self.handoff)
        out["handoff_signals"] = hs["signal_puts"]
        out["handoff_waits"] = hs["signal_waits"]
        out["handoff_quiets"] = hs["quiets"] + hs["fences"]
        out["handoff_amos"] = hs["amos"]
        if self.router_mode == "amo":
            rs = self.router.queue_stats()
            out["router_amos"] = rs["amos"]
            out["router_quiets"] = rs["quiets"] + rs["fences"]
            out["steals"] = self.router.stats["steals"]
            out["router_cas_retries"] = self.router.stats["cas_retries"]
            out["alloc_cas_retries"] = sum(p.stats["cas_retries"]
                                           for p in self.pools)
            for p in self.pools:
                ps = p.queue_stats()
                out["router_quiets"] += ps["quiets"] + ps["fences"]
        else:
            out["router_amos"] = 0
            out["router_quiets"] = 0
            out["steals"] = 0
            out["router_cas_retries"] = 0
            out["alloc_cas_retries"] = 0
        return out

    def reset_metrics(self) -> None:
        for e in self.engines:
            e.reset_metrics()
        self.ticks = 0
        for k in self.handoff:
            self.handoff[k] = 0
        for k in self.swap_stats:
            if k != "generation":        # generations keep counting up
                self.swap_stats[k] = 0
        for k in self.hq._stats:
            self.hq._stats[k] = 0
        if self.router_mode == "amo":
            for k in self.router.q._stats:
                self.router.q._stats[k] = 0
            for k in self.router.stats:
                self.router.stats[k] = 0
            for p in self.pools:
                for k in p.q._stats:
                    p.q._stats[k] = 0
                for k in p.stats:
                    p.stats[k] = 0

    def metrics(self) -> dict:
        """The colocated engine's summary shape, aggregated over cells,
        plus the handoff counters and a per-cell breakdown."""
        done = self.finished
        lat = np.array([r.t_finish - r.t_arrive for r in done])
        ttft = np.array([r.t_first - r.t_arrive for r in done
                         if r.t_first is not None])
        dec = np.asarray([g for e in self.engines for g in e.itl])
        toks = sum(len(r.out) for r in done)
        span = max((r.t_finish for r in done), default=0.0) \
            - min((r.t_arrive for r in done), default=0.0)
        pct = (lambda a, p: float(np.percentile(a, p)) if a.size else 0.0)

        def agg(dicts):
            out: dict = {}
            for d in dicts:
                for k, v in d.items():
                    out[k] = out.get(k, 0) + v
            return out

        sched = agg(e.sched.stats for e in self.engines)
        kv = agg(e.kv.stats for e in self.engines)
        sp = agg(e.spec_stats for e in self.engines)
        sp["accept_rate"] = (sp["accepted"] / sp["drafted"]
                             if sp.get("drafted") else 0.0)
        sp["tokens_per_tick"] = (sp["emitted"] / sp["verify_seqs"]
                                 if sp.get("verify_seqs") else 0.0)
        from .engine import slo_summary
        shed = [r for e in self.engines for r in e.shed]
        pol = agg(e.slo.stats for e in self.engines
                  if e.slo is not None) or None
        return {
            "requests": len(done),
            "tokens_out": int(toks),
            "span_s": float(span),
            "throughput_tok_s": toks / span if span > 0 else 0.0,
            "latency_p50_s": pct(lat, 50), "latency_p99_s": pct(lat, 99),
            "ttft_p50_s": pct(ttft, 50), "ttft_p99_s": pct(ttft, 99),
            "decode_p50_s": pct(dec, 50), "decode_p99_s": pct(dec, 99),
            "ticks": self.ticks,
            "sched": sched,
            "kv": kv,
            "spec": sp,
            "slo": slo_summary(done, shed, pol),
            "swap": dict(self.swap_stats),
            "handoff": self.stats(),
            "cells": [{"cell": c.cell, "role": c.role, "pes": list(c.pes),
                       "sched": dict(e.sched.stats),
                       "kv": dict(e.kv.stats)}
                      for c, e in zip(self.cells, self.engines)],
        }
