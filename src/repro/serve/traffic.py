"""Seeded synthetic serving traffic: Poisson arrivals, mixed lengths.

The generator is deliberately simple and fully determined by its seed —
the same trace drives the benchmark, the CLI and the parity suites, so
"identical token streams across backends" is a meaningful assertion.
Prompt/output lengths are drawn from a short/long mixture (the bimodal
shape real serving traffic has: chat turns vs document prompts).

Every request draws from its OWN RNG stream, seeded by ``(seed, rid)``:
request ``i`` is a pure function of the config and ``i``, never of
``n_requests``.  Traces are therefore PREFIX-STABLE — growing a
benchmark from 16 to 64 requests extends the trace instead of
reshuffling every prompt — which is what makes rows at different scales
comparable.  (The old generator drew all arrival gaps in one
``size=n_requests`` call before the per-request draws, so changing
``n_requests`` shifted the RNG stream under every request.)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .sampling import SamplingParams
from .scheduler import Request

# stream-splitting constant for the SLO attribute draws: a separate
# per-request RNG so enabling classes/tenants never shifts the classic
# prompt/length draws
_SLO_STREAM = 0x510


def _slo_attrs(tcfg: "TrafficConfig", rid: int) -> tuple:
    """(priority, deadline, tenant) for request ``rid`` — drawn from
    the derived ``(seed ^ _SLO_STREAM, rid)`` stream, or the all-
    interactive defaults when the config requests no SLO traffic."""
    plain = (tcfg.interactive_frac >= 1.0 and tcfg.batch_frac <= 0.0
             and tcfg.n_tenants <= 1)
    if plain:
        return "interactive", tcfg.deadline_interactive, 0
    rng = _request_rng(tcfg.seed ^ _SLO_STREAM, rid)
    u = rng.rand()
    if u < tcfg.interactive_frac:
        prio, dl = "interactive", tcfg.deadline_interactive
    elif u < tcfg.interactive_frac + tcfg.batch_frac:
        prio, dl = "batch", tcfg.deadline_batch
    else:
        prio, dl = "best_effort", tcfg.deadline_best_effort
    tenant = int(rng.randint(0, max(tcfg.n_tenants, 1)))
    return prio, dl, tenant


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    n_requests: int = 16
    rate: float = 8.0                 # mean arrivals per second (Poisson)
    vocab: int = 128
    seed: int = 0
    # [lo, hi) token ranges; defaults keep prompt+output <= 32 (the
    # smoke configs' max_seq) so any engine bound >= 32 admits the trace
    prompt_short: tuple = (2, 10)
    prompt_long: tuple = (12, 24)
    long_frac: float = 0.25
    out_short: tuple = (2, 8)
    out_long: tuple = (6, 9)
    # per-request sampling policy (defaults: greedy, matching the old
    # traffic); greedy_frac forces that fraction of requests to greedy
    # regardless, so one trace can mix sampled and greedy streams
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    greedy_frac: float = 0.0
    # SLO traffic mix (serve.slo): class draw per request —
    # ``interactive_frac`` then ``batch_frac``, remainder best_effort —
    # relative TTFT deadlines per class (None = no SLO), and a tenant
    # id drawn uniformly from ``n_tenants`` for the fairness buckets.
    # Defaults (all interactive, no deadlines, one tenant) keep the
    # classic traces BYTE-IDENTICAL: the SLO draws come from a separate
    # derived RNG stream, so enabling them never shifts prompts.
    interactive_frac: float = 1.0
    batch_frac: float = 0.0
    deadline_interactive: Optional[float] = None
    deadline_batch: Optional[float] = None
    deadline_best_effort: Optional[float] = None
    n_tenants: int = 1


def _request_rng(seed: int, rid: int) -> np.random.RandomState:
    """One independent, reproducible stream per request id."""
    root = np.random.SeedSequence([int(seed), int(rid)])
    return np.random.RandomState(root.generate_state(1)[0])


def make_requests(tcfg: TrafficConfig) -> list:
    """The arrival trace: ``n_requests`` Requests with exponential
    inter-arrival gaps (rate ``rate``) and mixed prompt/output lengths.
    All of request ``i``'s draws (its gap included) come from the
    ``(seed, i)`` stream, interleaved per request — prefix-stable in
    ``n_requests``."""
    reqs = []
    t = 0.0
    for i in range(tcfg.n_requests):
        rng = _request_rng(tcfg.seed, i)
        gap = rng.exponential(1.0 / tcfg.rate)
        if i > 0:                                 # first request at t=0
            t += gap
        long = rng.rand() < tcfg.long_frac
        plen = rng.randint(*(tcfg.prompt_long if long
                             else tcfg.prompt_short))
        olen = rng.randint(*(tcfg.out_long if long else tcfg.out_short))
        prompt = rng.randint(0, tcfg.vocab, size=plen).tolist()
        greedy = rng.rand() < tcfg.greedy_frac
        sp = SamplingParams() if greedy else SamplingParams(
            temperature=tcfg.temperature, top_k=tcfg.top_k,
            top_p=tcfg.top_p)
        prio, deadline, tenant = _slo_attrs(tcfg, i)
        reqs.append(Request(rid=i, prompt=prompt, max_new=int(olen),
                            t_arrive=float(t), sampling=sp,
                            priority=prio, deadline=deadline,
                            tenant=tenant))
    return reqs
