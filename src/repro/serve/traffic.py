"""Seeded synthetic serving traffic: Poisson arrivals, mixed lengths.

The generator is deliberately simple and fully determined by its seed —
the same trace drives the benchmark, the CLI and the parity suites, so
"identical token streams across backends" is a meaningful assertion.
Prompt/output lengths are drawn from a short/long mixture (the bimodal
shape real serving traffic has: chat turns vs document prompts).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .scheduler import Request


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    n_requests: int = 16
    rate: float = 8.0                 # mean arrivals per second (Poisson)
    vocab: int = 128
    seed: int = 0
    # [lo, hi) token ranges; defaults keep prompt+output <= 32 (the
    # smoke configs' max_seq) so any engine bound >= 32 admits the trace
    prompt_short: tuple = (2, 10)
    prompt_long: tuple = (12, 24)
    long_frac: float = 0.25
    out_short: tuple = (2, 8)
    out_long: tuple = (6, 9)


def make_requests(tcfg: TrafficConfig) -> list:
    """The arrival trace: ``n_requests`` Requests with exponential
    inter-arrival gaps (rate ``rate``) and mixed prompt/output lengths."""
    rng = np.random.RandomState(tcfg.seed)
    gaps = rng.exponential(1.0 / tcfg.rate, size=tcfg.n_requests)
    arrivals = np.cumsum(gaps) - gaps[0]          # first request at t=0
    reqs = []
    for i in range(tcfg.n_requests):
        long = rng.rand() < tcfg.long_frac
        plen = rng.randint(*(tcfg.prompt_long if long
                             else tcfg.prompt_short))
        olen = rng.randint(*(tcfg.out_long if long else tcfg.out_short))
        prompt = rng.randint(0, tcfg.vocab, size=plen).tolist()
        reqs.append(Request(rid=i, prompt=prompt, max_new=int(olen),
                            t_arrive=float(arrivals[i])))
    return reqs
