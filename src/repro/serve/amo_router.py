"""AMO-arbitrated cell routing: admission rings + load words on the
symmetric heap.

:class:`~repro.serve.disagg.CellRouter` is host-serial — every routing
decision reads every cell's scheduler through Python object graphs, the
exact host round-trip POSH §4.6 exists to remove.  This router moves
the routing STATE onto symmetric counter words (carved
``SignalPad``-style, one word row per cell rank) and every transition
onto queue AMOs, so placement is decided by fetch-&-op arbitration on
the ``router`` CommQueue:

  * **admission** — each prefill cell owns a CAS head/tail ticket ring:
    ``submit`` publishes a request id into the least-loaded cell's ring
    (``fadd`` the tail ticket, ``swap`` the slot, ``fadd`` the load
    word by the prompt tokens); each tick the cell CAS-claims from its
    own head up to its admission capacity;
  * **work stealing** — a cell with spare capacity and a dry ring
    CAS-claims from the most-backlogged victim's head (the same
    ``cswap`` pop — ownership is whoever wins the CAS, counted in
    ``stats['steals']``);
  * **handoff routing** — decode cells publish their live-sequence
    count to a load word at the end of each tick; producers pick the
    decode cell by fetching load + inbound words, and inbound tracking
    is ``fadd`` on ticket issue / adopt.

Placement parity: with no stealing, the word values a ``submit`` or
``route_handoff`` fetches equal exactly what the host router reads from
the schedulers at the same point in the tick (loads republish at tick
end; unclaimed ring entries carry their own ``fadd`` contributions), so
the two routers place identically.  Stealing may move a request between
cells — and token streams STILL match, because sampling is keyed
``(rid, position, sample_seed)`` (placement-invariant by construction;
the ``--router`` parity suites pin it).

Completion discipline matches the page pool: every AMO drains by
``amo_wait`` on its own word — ``stats()['quiets'] == 0`` on the router
queue is part of the no-global-barrier contract.
"""
from __future__ import annotations

from collections import deque
from typing import Optional, Sequence

import numpy as np

from repro.core.heap import SymmetricHeap
from repro.core.ordering import CommQueue, LocalTransport
from repro.core.signals import SignalPad

from .disagg import CellRouter, CellSpec
from .engine import ServeEngine
from .scheduler import Request

# per-cell word layout (one row of the router words object per rank)
W_ADM_HEAD = 0       # ring consume ticket (CAS-claimed)
W_ADM_TAIL = 1       # ring publish ticket (fetch-add)
W_ADM_LOAD = 2       # queued prompt tokens (routing key, prefill cells)
W_DEC_LOAD = 3       # live decode sequences (republished per tick)
W_DEC_INBOUND = 4    # issued-but-unadopted handoff tickets
W_RING = 5           # ring slots: rid + 1 (0 = empty)


class AmoCellRouter(CellRouter):
    """Work-stealing admission + handoff routing on symmetric words.

    Drop-in for :class:`CellRouter` inside ``DisaggEngine`` — same
    ``route_handoff`` surface — plus the AMO admission path
    (``submit`` / ``admit``) the engine drives in ``--router amo``
    mode."""

    def __init__(self, engines: Sequence[ServeEngine],
                 cells: Sequence[CellSpec], *, delivery_seed=0,
                 n_ring: Optional[int] = None):
        super().__init__(engines, cells)
        mb = max(e.scfg.max_batch for e in self.engines)
        self.n_ring = int(n_ring or max(4 * mb, 32))
        n_cells = len(self.cells)
        heap = SymmetricHeap(("router",))
        self.pad = SignalPad(heap, W_RING + self.n_ring,
                             name="router_words")
        state = {self.pad.handle.name:
                 np.zeros((n_cells, self.pad.n), np.int64)}
        self.q = CommQueue("router", state,
                           transport=LocalTransport(n_cells),
                           delivery_seed=delivery_seed)
        self._reqs: dict = {}              # rid -> unclaimed Request
        self._spill = {c: deque() for c in self.prefill}
        self._pub_load = {c: 0 for c in self.prefill}
        self.stats = {"steals": 0, "adm_published": 0, "adm_claimed": 0,
                      "adm_spilled": 0, "cas_retries": 0}

    # ------------------------------------------------------------------
    # AMO primitives
    # ------------------------------------------------------------------
    def _amo(self, op: str, word: int, cell: int, value=None,
             cond=None) -> int:
        v = self.q.amo_nbi(  # shmem: deferred-drain
            self.pad.handle, op, [(int(cell), int(cell))], value=value,
            cond=cond, offset=int(word))
        self.q.amo_wait(self.pad.handle, offset=int(word))
        return int(v.value())

    # ------------------------------------------------------------------
    # admission: publish -> (per-tick) claim + steal
    # ------------------------------------------------------------------
    def adm_load(self, cell: int) -> int:
        return self._amo("fetch", W_ADM_LOAD, cell)

    def submit(self, req: Request) -> None:
        """Publish ``req`` into the least-loaded prefill cell's ring.
        The request stays host-resident keyed by rid; the ring carries
        only the id — whichever cell wins the claim CAS owns it."""
        c = min(self.prefill, key=lambda c: (self.adm_load(c), c))
        self._reqs[req.rid] = req
        if not self._ring_push(c, req):
            self._spill[c].append(req)     # ring full: host-side spill,
            self.stats["adm_spilled"] += 1  # re-published next tick

    def _ring_push(self, cell: int, req: Request) -> bool:
        head = self._amo("fetch", W_ADM_HEAD, cell)
        tail = self._amo("fetch", W_ADM_TAIL, cell)
        if tail - head >= self.n_ring:
            return False
        t = self._amo("fadd", W_ADM_TAIL, cell, 1)
        self._amo("swap", W_RING + t % self.n_ring, cell, req.rid + 1)
        self._amo("fadd", W_ADM_LOAD, cell, req.n_prompt)
        self.stats["adm_published"] += 1
        return True

    def _ring_pop(self, cell: int) -> Optional[Request]:
        """CAS-claim one request off ``cell``'s ring head (the claim
        and the steal are the same operation — only the caller
        differs)."""
        while True:
            head = self._amo("fetch", W_ADM_HEAD, cell)
            tail = self._amo("fetch", W_ADM_TAIL, cell)
            if head == tail:
                return None
            old = self._amo("cswap", W_ADM_HEAD, cell, value=head + 1,
                            cond=head)
            if old != head:
                self.stats["cas_retries"] += 1
                continue
            rid = self._amo("swap", W_RING + head % self.n_ring, cell,
                            0) - 1
            req = self._reqs.pop(rid)
            self._amo("fadd", W_ADM_LOAD, cell, -req.n_prompt)
            return req

    def _capacity(self, cell: int) -> int:
        e = self.engines[cell]
        return max(0, e.scfg.max_batch
                   - len(e.sched.running) - len(e.sched.waiting))

    def admit(self) -> None:
        """One admission round (engine tick start): each cell re-publishes
        its spill, claims from its own ring up to capacity, then cells
        with spare capacity steal from the most-backlogged ring."""
        for c in self.prefill:
            spill = self._spill[c]
            while spill and self._ring_push(c, spill[0]):
                spill.popleft()
            cap = self._capacity(c)
            while cap > 0:
                req = self._ring_pop(c)
                if req is None:
                    break
                self.engines[c].submit(req)
                self.stats["adm_claimed"] += 1
                cap -= 1
        # steal pass: spare capacity drains someone else's backlog
        for c in self.prefill:
            cap = self._capacity(c)
            while cap > 0:
                victims = [v for v in self.prefill if v != c
                           and self._backlog(v) > 0]
                if not victims:
                    break
                v = max(victims, key=lambda v: (self._backlog(v), -v))
                req = self._ring_pop(v)
                if req is None:
                    break
                self.engines[c].submit(req)
                self.stats["steals"] += 1
                self.stats["adm_claimed"] += 1
                cap -= 1

    def _backlog(self, cell: int) -> int:
        return (self._amo("fetch", W_ADM_TAIL, cell)
                - self._amo("fetch", W_ADM_HEAD, cell))

    def pending(self) -> int:
        """Published-but-unclaimed requests (run loops must not stop
        while any remain)."""
        return len(self._reqs)

    # ------------------------------------------------------------------
    # load republication (tick end) + handoff routing
    # ------------------------------------------------------------------
    def publish_loads(self) -> None:
        """Fold each cell's local scheduler state into its word: the
        prefill load word tracks local-load delta (unclaimed ring
        entries keep their own fadd contributions); the decode load
        word is a plain republish."""
        for c in self.prefill:
            local = super().prefill_load(c)
            delta = local - self._pub_load[c]
            if delta:
                self._amo("fadd", W_ADM_LOAD, c, delta)
                self._pub_load[c] = local
        for c in self.decode:
            self._amo("swap", W_DEC_LOAD, c,
                      len(self.engines[c].sched.running))

    def decode_load(self, cell: int) -> int:
        return (self._amo("fetch", W_DEC_LOAD, cell)
                + self._amo("fetch", W_DEC_INBOUND, cell))

    def inbound_add(self, cell: int, delta: int) -> None:
        self.inbound[cell] += delta        # keep the host view coherent
        self._amo("fadd", W_DEC_INBOUND, cell, delta)

    def route_handoff(self, req: Request) -> Optional[int]:
        c = min(self.decode, key=lambda c: (self.decode_load(c), c))
        if self.decode_load(c) >= self.engines[c].scfg.max_batch:
            return None
        return c

    def queue_stats(self) -> dict:
        """Router-queue counters — ``quiets == 0`` pinned."""
        return self.q.stats()
