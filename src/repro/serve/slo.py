"""SLO-aware admission control: priority classes, deadlines, fairness.

Fleet traffic is not uniform: a chat turn (``interactive``) has a
tight time-to-first-token SLO, an offline eval (``batch``) just wants
throughput, and background refills (``best_effort``) exist to soak up
idle capacity.  This module is the policy layer the ``FCFSScheduler``
consults when a :class:`SLOPolicy` is attached:

  * **priority admission** — waiting requests admit in
    (class rank, arrival) order instead of globally FCFS, so an
    interactive arrival never queues behind a best-effort backlog;
  * **inverse-priority preemption** — when the page pool runs dry the
    eviction victim is the lowest class first (best_effort, then
    batch, then interactive), youngest within a class, so load sheds
    *down* the priority ladder ("evict last" for interactive);
  * **deadline shedding** — a waiting best-effort request whose
    deadline has already passed is dropped outright (it could only
    burn pool pages producing an answer nobody will read), BEFORE any
    interactive request is degraded;
  * **degradation under pressure** — while higher classes have unmet
    demand (or the pool is nearly dry), best-effort sequences lose
    their speculative draft allowance and prefill in smaller chunks:
    they keep trickling forward but stop competing for the tick
    budget that protects interactive p99;
  * **per-tenant token-rate fairness** — admission charges a token
    bucket per tenant (refilled ``tenant_rate`` tokens per tick, burst
    capped), so one tenant's flood defers ITS OWN later requests
    instead of starving everyone else's.

The policy is deterministic host-side state, like the scheduler it
advises: the same trace yields the same shed/degrade/admit decisions
on every backend, which keeps the cross-backend stream-parity suites
meaningful under SLO scheduling too.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

PRIORITIES = ("interactive", "batch", "best_effort")
PRIO_RANK = {p: i for i, p in enumerate(PRIORITIES)}


def rank(priority: str) -> int:
    """Admission/eviction rank of a class (lower admits first,
    higher evicts first)."""
    try:
        return PRIO_RANK[priority]
    except KeyError:
        raise ValueError(f"unknown priority class {priority!r} "
                         f"(want one of {PRIORITIES})") from None


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """Policy knobs.  Deadlines/rates are in the engine's clock units
    (ticks under ``clock="tick"``, seconds under ``"wall"``)."""

    # default relative TTFT deadline per class, applied by the traffic
    # generator when a request does not carry its own (None = no SLO)
    ttft_interactive: Optional[float] = None
    ttft_batch: Optional[float] = None
    ttft_best_effort: Optional[float] = None
    # degradation: best-effort prefill chunk cap under pressure, and
    # whether pressure strips best-effort draft allowances
    degrade_chunk: int = 2
    degrade_spec: bool = True
    # pressure = unmet higher-class demand OR free-page fraction below
    # this floor
    pressure_free_frac: float = 0.25
    # per-tenant admission token bucket: ``tenant_rate`` tokens
    # (prompt + decode budget of admitted requests) per tick, holding
    # at most ``tenant_burst`` (0 disables fairness)
    tenant_rate: float = 0.0
    tenant_burst: float = 0.0

    def ttft_target(self, priority: str) -> Optional[float]:
        rank(priority)                    # validate the class name
        return {"interactive": self.ttft_interactive,
                "batch": self.ttft_batch,
                "best_effort": self.ttft_best_effort}[priority]


class SLOPolicy:
    """Mutable per-engine policy state the scheduler consults each
    tick.  All counters live in ``stats`` so the engine's metrics (and
    the bench rows the CI gate checks) can report them."""

    def __init__(self, cfg: Optional[SLOConfig] = None):
        self.cfg = cfg or SLOConfig()
        self.pressure = False
        self._buckets: dict = {}          # tenant -> available tokens
        self.stats = {"shed": 0, "rate_deferred": 0,
                      "degraded_chunks": 0, "degraded_drafts": 0}

    # ------------------------------------------------------------------
    # ordering
    # ------------------------------------------------------------------
    def admit_key(self, req, arrive_seq: int):
        """Sort key for the waiting line: class rank, then arrival."""
        return (rank(req.priority), arrive_seq)

    def evict_key(self, req, admit_idx: int):
        """Sort key for eviction (max wins): lowest class first —
        strictly inverse-priority — youngest within a class."""
        return (rank(req.priority), admit_idx)

    # ------------------------------------------------------------------
    # shedding and degradation
    # ------------------------------------------------------------------
    def should_shed(self, req, now: float) -> bool:
        """Drop a WAITING request whose deadline already passed.  Only
        best-effort traffic sheds — higher classes keep their place
        (a missed deadline there shows up in attainment, the signal
        the operator actually pages on)."""
        return (req.priority == "best_effort"
                and req.deadline is not None
                and now - req.t_arrive > req.deadline)

    def note_shed(self, req) -> None:
        self.stats["shed"] += 1

    def update_pressure(self, waiting, running, kv) -> bool:
        """Recompute the tick's pressure signal: any waiting request of
        a class above best_effort (unmet higher-class demand), or a
        nearly-dry page pool."""
        hi = any(rank(r.priority) < PRIO_RANK["best_effort"]
                 for r in waiting)
        free_frac = kv.n_free() / max(kv.n_pages - 1, 1)
        self.pressure = bool(hi or free_frac < self.cfg.pressure_free_frac)
        return self.pressure

    def chunk_cap(self, req, prefill_chunk: int) -> int:
        """Prefill chunk for ``req`` this tick: best-effort shrinks to
        ``degrade_chunk`` under pressure, everyone else keeps the
        configured chunk."""
        if self.pressure and req.priority == "best_effort" \
                and self.cfg.degrade_chunk < prefill_chunk:
            self.stats["degraded_chunks"] += 1
            return max(int(self.cfg.degrade_chunk), 1)
        return prefill_chunk

    def strip_drafts(self, req) -> bool:
        """Under pressure a best-effort sequence loses its speculative
        draft allowance (its verify window collapses to plain decode),
        returning that tick budget to interactive traffic."""
        if self.pressure and self.cfg.degrade_spec \
                and req.priority == "best_effort":
            self.stats["degraded_drafts"] += 1
            return True
        return False

    # ------------------------------------------------------------------
    # per-tenant token-rate fairness
    # ------------------------------------------------------------------
    @property
    def fairness_on(self) -> bool:
        return self.cfg.tenant_rate > 0

    def tick_refill(self) -> None:
        if not self.fairness_on:
            return
        burst = self.cfg.tenant_burst or self.cfg.tenant_rate
        for t in list(self._buckets):
            self._buckets[t] = min(self._buckets[t] + self.cfg.tenant_rate,
                                   burst)

    def _bucket(self, tenant) -> float:
        burst = self.cfg.tenant_burst or self.cfg.tenant_rate
        return self._buckets.setdefault(tenant, burst)

    def admit_charge(self, req) -> bool:
        """Charge ``req``'s token footprint (prompt + decode budget) to
        its tenant's bucket; False defers the request this tick WITHOUT
        blocking other tenants behind it."""
        if not self.fairness_on:
            return True
        cost = req.n_prompt + req.max_new
        if self._bucket(req.tenant) < cost:
            self.stats["rate_deferred"] += 1
            return False
        self._buckets[req.tenant] -= cost
        return True

    def admit_refund(self, req) -> None:
        """Undo an ``admit_charge`` whose admission then failed on
        pages/slots (the tokens were never served)."""
        if self.fairness_on:
            self._buckets[req.tenant] = \
                self._bucket(req.tenant) + req.n_prompt + req.max_new

    def reset(self) -> None:
        for k in self.stats:
            self.stats[k] = 0
        self._buckets.clear()
        self.pressure = False
