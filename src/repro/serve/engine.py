"""Continuous-batching inference engine over the paged symmetric-heap
KV cache.

The engine is split in two layers:

  * **pure step functions** (``make_prefill`` / ``make_decode_step``) —
    trace-friendly, built from the same model weights AND the same
    projection convention the registry's train/decode paths use
    (``attention.project_qkv``, ``embed``, ``mlp``), tensor-parallel
    through ``ctx.tp_comm`` so all registered communicator backends
    (xla / posh / pallas) serve traffic.  Both steps read/write K/V
    through the block table (``ops.paged_attention``), and both end in
    the TP-aware two-phase sampler (``serve.sampling``): per-shard
    top-k candidates merged via ``ctx.tp_comm.top_k_merge``, then a
    per-sequence counter-RNG draw keyed ``(rid, position)`` — token
    streams are backend- and batch-composition-invariant by
    construction.  ``make_prefill`` consumes prompt CHUNKS: a
    ``(B, prefill_chunk)`` window of each prompt, attending through the
    pages written so far, so prefill progress is metered by the
    scheduler's token budget instead of monopolizing a tick.

    ``make_verify`` is the SPECULATIVE-DECODE twin of the prefill
    window: the same trunk over a ``(B, k+1)`` window of pending token
    + proposed drafts, sampling at EVERY position with the
    non-speculative counter keys, so exact prefix-match acceptance
    reproduces the sequential stream bit-for-bit (``serve.spec`` holds
    the draft proposers).

  * a **host-side driver** (``ServeEngine``) — owns the
    ``FCFSScheduler`` + ``PagedKVCache``, executes each tick's plan
    (migrate -> chunk-prefill -> decode/verify), and drains every tick's
    planned page migrations with ``put_nbi`` + ONE ``quiet()`` on a
    ``CommQueue`` before the step functions run.  The execution
    substrate is pluggable (``LocalExec`` jits on one device; the mesh
    suite supplies a shard_map-wrapped equivalent), so the same
    scheduler drives a single CPU process and an 8-PE TP mesh.

Batch slots are fixed (``ServeConfig.max_batch``): empty slots carry
the null page table and length 0, which zeroes their attention output
and routes their KV writes to the null page — no branches in the traced
step.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.heap import SymmetricHeap
from repro.core.ordering import CommQueue, LocalTransport
from repro.kernels import ops
from repro.models import attention as attn
from repro.models import embed as emb
from repro.models import lm
from repro.models import mlp as ff
from repro.models.common import norm_apply
from repro.parallel.ctx import ParallelCtx

from . import sampling
from .kv_cache import NULL_PAGE, PagedKVCache
from .scheduler import FCFSScheduler, Request
from .slo import PRIORITIES, SLOConfig, SLOPolicy


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Trace-time serving shape: page geometry, batch and sequence
    bounds, prefill chunking, attention implementation, KV precision,
    sampler bounds."""

    page_tokens: int = 8
    n_pages: int = 64
    max_batch: int = 4
    max_seq: int = 64                 # prompt + decode budget per seq
    max_prompt: int = 32              # retired: prompts now stream
                                      # through chunked prefill (kept
                                      # for config compatibility)
    prefill_chunk: int = 8            # prompt tokens per seq per tick
    tick_tokens: int = 0              # shared decode+prefill budget per
                                      # tick (0 -> max_batch + chunk)
    attn_impl: str = "kernel"         # "kernel" (Pallas) | "ref" (jnp);
                                      # governs decode AND the
                                      # prefill/verify window trunk
    kv_dtype: jnp.dtype = jnp.float32
    prefix_keep: bool = False         # pin finished prompts' full pages
                                      # as migratable prefix cache
    sample_candidates: int = 8        # static top-k bound per shard
    sample_seed: int = 0              # RNG stream root for sampling
    spec_k: int = 0                   # draft tokens verified per seq per
                                      # tick (0 = speculation off)
    draft: str = "ngram"              # default proposer when none is
                                      # passed ("ngram" self-draft; a
                                      # model-backed proposer is built
                                      # by the caller, see serve.spec)
    slo: Optional[SLOConfig] = None   # SLO policy (serve.slo): priority
                                      # admission, deadline shedding,
                                      # best-effort degradation, tenant
                                      # fairness (None = plain FCFS)

    @property
    def table_slots(self) -> int:
        return -(-self.max_seq // self.page_tokens)


def _check_supported(cfg, ctx: ParallelCtx) -> None:
    if cfg.family not in ("dense", "moe"):
        raise NotImplementedError(
            f"repro.serve drives dense/moe decoders; got {cfg.family}")
    if cfg.attn_layout(ctx.tp_size) != "head":
        raise NotImplementedError(
            "repro.serve requires the head-parallel attention layout "
            f"({cfg.n_heads} heads, tp={ctx.tp_size})")
    if cfg.swa_window is not None:
        raise NotImplementedError("sliding-window + paged cache: not yet")


# ======================================================================
# pure step functions
# ======================================================================
def _write_pages(pool, li, k, v, bt, pos, page_tokens):
    """Scatter one-token-per-sequence K/V into the page pool.
    pool: (n_pages, 2, L, P, kvh, dh); k/v: (b, kvh, dh); pos: (b,).
    Inactive slots carry the null block table -> rows land in page 0."""
    page = jnp.take_along_axis(bt, (pos // page_tokens)[:, None],
                               axis=1)[:, 0]
    slot = pos % page_tokens
    dt = pool.dtype
    pool = pool.at[page, 0, li, slot].set(k.astype(dt))
    pool = pool.at[page, 1, li, slot].set(v.astype(dt))
    return pool


def make_decode_step(cfg, ctx: ParallelCtx, scfg: ServeConfig):
    """One serving tick: (params, pool, tokens, pos, bt, lens, samp) ->
    (next_tokens, pool).

    tokens (b,) int32 input token per slot; pos (b,) its position;
    bt (b, table_slots) int32 block tables; lens (b,) valid tokens
    AFTER this write (pos+1 for live slots, 0 for empty ones); samp the
    ``sampling.batch_state`` pytree (per-slot sampling params + rid).
    """
    _check_supported(cfg, ctx)
    P = scfg.page_tokens

    def step(params, pool, tokens, pos, bt, lens, samp):
        cd = ctx.compute_dtype
        x = emb.embed_lookup(params["embed"], tokens[:, None], ctx)[:, 0]
        b = x.shape[0]

        def body(carry, inputs):
            x, pool = carry
            p, li = inputs
            h = norm_apply("rms", p["ln1"], x).astype(cd)
            q, k, v = attn.project_qkv(p["attn"], h[:, None],
                                       pos[:, None], cfg, ctx)
            q, k, v = q[:, 0], k[:, 0], v[:, 0]
            pool = _write_pages(pool, li, k, v, bt, pos, P)
            kp = jax.lax.dynamic_index_in_dim(pool[:, 0], li, axis=1,
                                              keepdims=False)
            vp = jax.lax.dynamic_index_in_dim(pool[:, 1], li, axis=1,
                                              keepdims=False)
            o = ops.paged_attention(q, kp, vp, bt, lens,
                                    impl=scfg.attn_impl)
            out = o.reshape(b, -1).astype(cd) @ p["attn"]["wo"].astype(cd)
            out = ctx.tp_comm.psum(out)
            x = x + out
            m = lm._decode_mlp(p["mlp"], norm_apply("rms", p["ln2"], x),
                               ctx, cfg)
            return (x + m, pool), None

        (x, pool), _ = jax.lax.scan(
            body, (x, pool),
            (params["blocks"], jnp.arange(cfg.n_layers)))
        x = norm_apply("rms" if cfg.family != "encdec" else "layer",
                       params["ln_f"], x)
        head = params["embed"] if cfg.tie_embeddings else params["head"]
        logits = emb.lm_head_logits(head, x.astype(cd), ctx)
        nxt = sampling.sample_tokens(logits, ctx, samp, pos + 1,
                                     n_candidates=scfg.sample_candidates)
        return nxt.astype(jnp.int32), pool

    return step


def _make_window_forward(cfg, ctx: ParallelCtx, scfg: ServeConfig):
    """The shared chunk-window trunk: (params, pool, ids, start, n_tok,
    bt) -> (x, pool) where ``x`` is the final-norm hidden state at every
    window position.

    ids (b, C) a token window per sequence, right-padded; start (b,)
    the absolute position of ids[:, 0]; n_tok (b,) valid tokens in the
    window (0 = inactive slot).  Writes every valid position's K/V into
    the pages through the block table and attends each position against
    the pages written so far (position j sees ``start + j + 1`` tokens
    — the paged analogue of the causal mask).  Chunked prefill and
    speculative verify are BOTH this trunk — they differ only in which
    positions they sample (prefill: the last; verify: all of them), so
    the verify pass cannot numerically drift from the prefill path the
    chunking-invariance tests pin."""
    _check_supported(cfg, ctx)
    P = scfg.page_tokens

    def window(params, pool, ids, start, n_tok, bt):
        cd = ctx.compute_dtype
        x = emb.embed_lookup(params["embed"], ids, ctx)
        b, t = ids.shape
        pos = start[:, None] + jnp.arange(t)[None]           # (b, t)
        valid = jnp.arange(t)[None] < n_tok[:, None]

        def body(carry, inputs):
            x, pool = carry
            p, li = inputs
            h = norm_apply("rms", p["ln1"], x).astype(cd)
            q, k, v = attn.project_qkv(p["attn"], h, pos, cfg, ctx)
            # page writes: token (b, j) -> page bt[b, pos//P] slot
            # pos%P; the invalid window tail lands in the null page
            sidx = jnp.clip(pos // P, 0, bt.shape[1] - 1)
            page = jnp.take_along_axis(bt, sidx, axis=1)     # (b, t)
            page = jnp.where(valid, page, NULL_PAGE)
            slot = pos % P
            dt = pool.dtype
            pool = pool.at[page, 0, li, slot].set(k.astype(dt))
            pool = pool.at[page, 1, li, slot].set(v.astype(dt))
            kp = jax.lax.dynamic_index_in_dim(pool[:, 0], li, axis=1,
                                              keepdims=False)
            vp = jax.lax.dynamic_index_in_dim(pool[:, 1], li, axis=1,
                                              keepdims=False)
            # whole-window paged attention in one fused call: position
            # j attends to its first start+j+1 paged tokens (the
            # chunk's K/V were just written above)
            o = ops.paged_prefill_attention(q, kp, vp, bt, start, n_tok,
                                            impl=scfg.attn_impl)
            out = o.reshape(b, t, -1).astype(cd) @ p["attn"]["wo"].astype(cd)
            out = ctx.tp_comm.psum(out)
            x = x + out
            ctx1 = ctx.with_(sp=False)
            mlp = (ff.moe_apply if cfg.moe else ff.mlp_apply)(
                p["mlp"], norm_apply("rms", p["ln2"], x), ctx1, cfg)
            return (x + mlp, pool), None

        (x, pool), _ = jax.lax.scan(
            body, (x, pool),
            (params["blocks"], jnp.arange(cfg.n_layers)))
        return norm_apply("rms", params["ln_f"], x), pool

    return window


def make_prefill(cfg, ctx: ParallelCtx, scfg: ServeConfig):
    """Chunked prefill: (params, pool, ids, start, n_tok, bt, samp) ->
    (next_tokens, pool).

    ids (b, C) the next window of each prompt, right-padded
    (C = ``scfg.prefill_chunk``); start (b,) the absolute position of
    ids[:, 0]; n_tok (b,) valid tokens in the window (0 = inactive
    slot).  Writes every chunk position's K/V into the pages, attends
    each position against the pages written so far (the shared window
    trunk), and returns the token sampled after position
    ``start + n_tok - 1`` with RNG counter ``start + n_tok`` —
    meaningful only for slots whose chunk completes the prompt; the
    engine discards the rest.
    """
    window = _make_window_forward(cfg, ctx, scfg)

    def prefill(params, pool, ids, start, n_tok, bt, samp):
        cd = ctx.compute_dtype
        x, pool = window(params, pool, ids, start, n_tok, bt)
        t = ids.shape[1]
        last = jnp.clip(n_tok - 1, 0, t - 1)
        xl = jnp.take_along_axis(x, last[:, None, None], axis=1)[:, 0]
        head = params["embed"] if cfg.tie_embeddings else params["head"]
        logits = emb.lm_head_logits(head, xl.astype(cd), ctx)
        nxt = sampling.sample_tokens(logits, ctx, samp, start + n_tok,
                                     n_candidates=scfg.sample_candidates)
        return nxt.astype(jnp.int32), pool

    return prefill


def make_verify(cfg, ctx: ParallelCtx, scfg: ServeConfig):
    """Speculative verify: (params, pool, ids, start, n_tok, bt, samp)
    -> (target_tokens, pool) — ONE batched forward over a (b, k+1)
    window through the chunked-prefill machinery, sampling at EVERY
    position.

    ids[:, 0] is the sequence's pending last token (its K/V unwritten,
    exactly what a decode step would feed) and ids[:, 1:] the proposed
    draft tokens; start (b,) the absolute position of ids[:, 0]; n_tok
    (b,) = 1 + drafts (1 = a plain decode through the verify window).
    Row j of the output is the token the TARGET model generates at
    position ``start + j + 1`` — drawn with the non-speculative
    counter-RNG key ``(rid, start + j + 1)`` — so the engine's exact
    prefix-match acceptance reproduces the sequential stream
    bit-for-bit: row 0 IS the non-speculative next token, and row j is
    what the (j+1)-th sequential step would have produced given that
    all j fed drafts matched.  K/V of every fed position is written
    through the block table; rejected positions are rewound by
    ``PagedKVCache.truncate`` (page-granular) + length bookkeeping.
    """
    window = _make_window_forward(cfg, ctx, scfg)

    def verify(params, pool, ids, start, n_tok, bt, samp):
        cd = ctx.compute_dtype
        x, pool = window(params, pool, ids, start, n_tok, bt)
        b, t = ids.shape
        head = params["embed"] if cfg.tie_embeddings else params["head"]
        logits = emb.lm_head_logits(head, x.astype(cd), ctx)  # (b,t,V/tp)
        pos = start[:, None] + jnp.arange(t)[None] + 1        # counters
        nxt = sampling.sample_window_tokens(
            logits, ctx, samp, pos,
            n_candidates=scfg.sample_candidates)
        return nxt.astype(jnp.int32), pool

    return verify


# ======================================================================
# execution substrates
# ======================================================================
class LocalExec:
    """Single-device execution: jitted step functions over the per-PE
    pool, a loopback CommQueue (LocalTransport, 1 PE) for the migration
    drain — the same ``put_nbi`` + one ``quiet()`` path the mesh runs,
    minus the wire."""

    def __init__(self, params, cfg, ctx, scfg: ServeConfig,
                 kv: PagedKVCache):
        self.params = params
        self.kv = kv
        self._prefill = jax.jit(make_prefill(cfg, ctx, scfg))
        self._decode = jax.jit(make_decode_step(cfg, ctx, scfg))
        self._verify = jax.jit(make_verify(cfg, ctx, scfg))
        self._team = ctx.tp_comm.team

    def init_pool(self):
        return self.kv.zeros()

    def prefill(self, pool, ids, start, n_tok, bt, samp):
        return self._prefill(self.params, pool, jnp.asarray(ids),
                             jnp.asarray(start), jnp.asarray(n_tok),
                             jnp.asarray(bt), samp)

    def decode(self, pool, tokens, pos, bt, lens, samp):
        return self._decode(self.params, pool, jnp.asarray(tokens),
                            jnp.asarray(pos), jnp.asarray(bt),
                            jnp.asarray(lens), samp)

    def verify(self, pool, ids, start, n_tok, bt, samp):
        return self._verify(self.params, pool, jnp.asarray(ids),
                            jnp.asarray(start), jnp.asarray(n_tok),
                            jnp.asarray(bt), samp)

    def set_params(self, params) -> None:
        """Swap the served weights (weight hot-swap flip): the jitted
        step functions take ``params`` as an explicit argument, so the
        next tick's forwards run the new generation with no re-trace."""
        self.params = params

    def migrate(self, pool, migrations):
        # whole-system view with one PE: state rows carry the PE axis
        state = {self.kv.handle.name: np.asarray(pool)[None]}
        q = CommQueue(self._team, state, transport=LocalTransport(1))
        out = self.kv.issue_migrations(q, state[self.kv.handle.name],
                                       migrations, system=True)
        return jnp.asarray(out[self.kv.handle.name][0])

    # pool-layout hooks for the disaggregated handoff (serve.disagg):
    # a page "row" here is the plain pool row; mesh substrates override
    # these to expose their (replica, tp) layout as (page, tp-shard)
    def read_pages(self, pool, pages):
        """Host copies of pool rows ``pages`` — the handoff payload."""
        return np.asarray(pool)[np.asarray(pages, np.int64)]

    def write_pages(self, pool, pages, rows):
        """Land handed-off ``rows`` at pool rows ``pages``."""
        return pool.at[jnp.asarray(np.asarray(pages, np.int64))].set(
            jnp.asarray(rows))


# ======================================================================
# the driver
# ======================================================================
class ServeEngine:
    """Continuous-batching driver: token-budgeted ticks (one decode
    token per decoding sequence + chunked prefill), FCFS admission,
    preempt-by-eviction, migration drain first."""

    def __init__(self, params, cfg, ctx: ParallelCtx, scfg: ServeConfig,
                 *, heap: Optional[SymmetricHeap] = None,
                 kv: Optional[PagedKVCache] = None, exec_=None,
                 proposer=None, my_pe: int = 0, role: str = "both"):
        if role not in ("both", "prefill", "decode"):
            raise ValueError(f"unknown engine role {role!r}")
        self.cfg, self.ctx, self.scfg = cfg, ctx, scfg
        # disaggregated cells (serve.disagg): a "prefill" engine stops
        # at the first token and parks the finished sequence on
        # ``handoff_ready``; a "decode" engine receives sequences via
        # ``adopt_request`` (it can still re-prefill its own preemption
        # victims — the counter-RNG sampler keeps streams identical
        # wherever a position is recomputed)
        self.role = role
        self.handoff_ready: list = []
        if kv is None:
            heap = heap or SymmetricHeap(
                (ctx.tp_axis,) if ctx.tp_size > 1 else ("data",))
            kv = PagedKVCache(
                heap, n_layers=cfg.n_layers,
                kv_heads=cfg.kv_per_rank(ctx.tp_size),
                head_dim=cfg.head_dim, n_pages=scfg.n_pages,
                page_tokens=scfg.page_tokens, dtype=scfg.kv_dtype)
        self.kv = kv
        self.slo = SLOPolicy(scfg.slo) if scfg.slo is not None else None
        self.sched = FCFSScheduler(kv, max_batch=scfg.max_batch,
                                   max_seq=scfg.max_seq, my_pe=my_pe,
                                   prefill_chunk=scfg.prefill_chunk,
                                   tick_tokens=scfg.tick_tokens,
                                   spec_k=scfg.spec_k, slo=self.slo)
        self.exec = exec_ or LocalExec(params, cfg, ctx, scfg, kv)
        self.proposer = proposer
        if scfg.spec_k > 0 and proposer is None:
            from . import spec                 # engine <-> spec cycle
            self.proposer = spec.make_proposer(scfg.draft)
        self.spec_stats = {"drafted": 0, "accepted": 0, "emitted": 0,
                           "verify_ticks": 0, "verify_seqs": 0}
        self.pool = self.exec.init_pool()
        self.finished: list = []
        self.shed: list = []             # deadline-shedded, never served
        # weight hot-swap (repro.ckpt.hotswap): the in-flight streamer
        # and its lifetime accounting
        self._swap = None
        self.swap_stats = {"generation": 0, "flips": 0, "swap_ticks": 0,
                           "swap_batches": 0, "swap_bytes": 0,
                           "swap_extra_quiets": 0}
        self.ticks = 0
        # inter-token gaps of decoding sequences (the serving ITL/TPOT
        # metric): a gap spans the full tick(s) between two of a
        # request's tokens, so a batch-mate's prefill stall lands here
        self.itl: list = []
        self._last_tok: dict = {}        # rid -> time of last token

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        # greedy requests ignore top_k (SamplingParams contract), so
        # the candidate bound only constrains sampled ones
        if req.sampling.temperature > 0 \
                and req.sampling.top_k > self.scfg.sample_candidates:
            raise ValueError(
                f"request {req.rid}: top_k {req.sampling.top_k} exceeds "
                f"the sampler's candidate bound "
                f"{self.scfg.sample_candidates} "
                f"(raise ServeConfig.sample_candidates)")
        self.sched.submit(req)

    def begin_hot_swap(self, new_params, *, chunk_rows: int = 4,
                       n_pe: Optional[int] = None, **kw) -> None:
        """Start streaming a new weight generation (zero-downtime swap,
        ``repro.ckpt.hotswap``): subsequent ticks each advance the
        stream by one put-with-signal batch, and the generation flips
        atomically at a tick boundary once everything has landed —
        serving never pauses and the swap queue never pays a global
        drain."""
        if self._swap is not None:
            raise RuntimeError("a weight hot-swap is already in flight")
        from repro.ckpt.hotswap import WeightStreamer
        if n_pe is None:
            n_pe = max(self.ctx.dp_size * self.ctx.tp_size, 1)
        self.swap_stats["generation"] += 1
        self._swap = WeightStreamer(
            new_params, n_pe=n_pe,
            generation=self.swap_stats["generation"],
            chunk_rows=chunk_rows, **kw)

    def swap_in_flight(self) -> bool:
        return self._swap is not None

    def _swap_step(self) -> None:
        """The per-tick hot-swap hook: one streaming step; on the flip
        tick the reassembled generation replaces the served weights
        BEFORE this tick's forwards, so every PE (and every cell
        sharing the streamer) switches on the same tick."""
        st = self._swap
        if not st.step():
            return
        self.exec.set_params(st.result())
        self.swap_stats["flips"] += st.stats["flips"]
        self.swap_stats["swap_ticks"] += st.stats["swap_ticks"]
        self.swap_stats["swap_batches"] += st.stats["batches"]
        self.swap_stats["swap_bytes"] += st.stats["bytes"]
        self.swap_stats["swap_extra_quiets"] += st.extra_global_drains()
        self._swap = None

    def tick(self, now: float = 0.0) -> None:
        """One engine tick: hot-swap stream step (when one is in
        flight) -> schedule -> migrate (one quiet) -> chunked prefill
        for every prefilling sequence's quota -> one decode token for
        every decoding sequence -> retire finished."""
        self.ticks += 1
        if self._swap is not None:
            self._swap_step()
        plan = self.sched.tick(now)
        for r in plan.shed:              # deadline drops: never served
            self.shed.append(r)
            self._last_tok.pop(r.rid, None)
            if self.proposer is not None:
                self.proposer.drop(r.rid)
        for r in plan.preempted:         # progress resets, gaps with it
            self._last_tok.pop(r.rid, None)
            if self.proposer is not None:
                self.proposer.drop(r.rid)
        if plan.migrations:
            self.pool = self.exec.migrate(self.pool,
                                          tuple(plan.migrations))
        skip_rids = set()
        if plan.prefill:
            skip_rids = self._chunk_prefill(plan.prefill, now)
        self._decode_tick(skip_rids=skip_rids, now=now)

    def _samp_state(self, reqs) -> dict:
        return sampling.batch_state(reqs, self.scfg.max_batch,
                                    self.scfg.sample_seed)

    def _chunk_prefill(self, assignments, now):
        """Feed every (req, n) chunk assignment through the prefill
        step.  Returns the rids that COMPLETED prefill this tick (their
        first output token came from the chunk — they must not also
        decode)."""
        B, C = self.scfg.max_batch, self.scfg.prefill_chunk
        reqs = [r for r, _ in assignments]
        ids = np.zeros((B, C), np.int32)
        start = np.zeros((B,), np.int32)
        n_tok = np.zeros((B,), np.int32)
        for i, (r, n) in enumerate(assignments):
            ids[i, :n] = r.prompt[r.n_done:r.n_done + n]
            start[i] = r.n_done
            n_tok[i] = n
        bt = self.kv.block_table(
            [r.rid for r in reqs] + [None] * (B - len(reqs)),
            self.scfg.table_slots)
        toks, self.pool = self.exec.prefill(self.pool, ids, start, n_tok,
                                            bt, self._samp_state(reqs))
        toks = np.asarray(toks)
        done = set()
        for i, (r, n) in enumerate(assignments):
            self.sched.note_chunk(r, n, int(toks[i]), now)
            if not r.is_prefilling():
                done.add(r.rid)
                if self.role == "prefill" and not r.finished():
                    # prefill cell: this sequence's life here ends with
                    # its first token — park it for the page handoff
                    # (pages stay resident as the put-signal payload
                    # source until the decode cell acknowledges)
                    self.sched.release(r)
                    self.handoff_ready.append(r)
                    continue
                self._last_tok[r.rid] = now
                self._maybe_finish(r, now)
        return done

    def adopt_request(self, req: Request, pages, now: float = 0.0) -> None:
        """Decode-cell half of a disaggregated handoff: attach the
        landing pages (already filled by the producer's put-with-signal
        stream, drained by the router's ``signal_wait_until``) and enter
        the sequence into this cell's scheduler mid-life."""
        if self.role == "prefill":
            raise RuntimeError("a prefill cell cannot adopt sequences")
        self.kv.attach_seq(req.rid, pages)
        self.sched.adopt(req)
        # its first token was emitted on the producer cell; the next
        # inter-token gap is measured from adoption
        self._last_tok[req.rid] = now

    def _decode_tick(self, skip_rids, now):
        if self.role == "prefill":
            return
        batch = [r for r in self.sched.running
                 if not r.is_prefilling() and r.rid not in skip_rids]
        if not batch:
            return
        if self.scfg.spec_k > 0:
            return self._spec_tick(batch, now)
        B = self.scfg.max_batch
        tokens = np.zeros((B,), np.int32)
        pos = np.zeros((B,), np.int32)
        lens = np.zeros((B,), np.int32)
        for i, r in enumerate(batch):
            tokens[i] = r.next_input()
            p = r.n_prompt + len(r.out) - 1
            pos[i] = p
            lens[i] = p + 1
        bt = self.kv.block_table(
            [r.rid for r in batch] + [None] * (B - len(batch)),
            self.scfg.table_slots)
        toks, self.pool = self.exec.decode(self.pool, tokens, pos, bt,
                                           lens, self._samp_state(batch))
        toks = np.asarray(toks)
        for i, r in enumerate(batch):
            self.sched.advance(r, int(toks[i]), now)
            prev = self._last_tok.get(r.rid)
            if prev is not None:
                self.itl.append(now - prev)
            self._last_tok[r.rid] = now
            self._maybe_finish(r, now)

    def _spec_tick(self, batch, now):
        """Draft -> verify -> accept -> rewind, one batched verify
        forward for every decoding sequence.

        The proposer supplies up to ``draft_allowance(r)`` draft tokens
        per sequence (the scheduler already budgeted and paged them);
        ONE verify pass scores the pending token plus all drafts; then
        exact prefix matching against the target's own counter-RNG
        draws accepts ``m`` drafts and emits ``m + 1`` tokens — the
        Leviathan accept test collapses to exact matching here because
        the drafts are point proposals (one-hot draft distributions)
        and the target's draw at a position is a deterministic function
        of its counter key, which is what makes accepted streams
        BIT-IDENTICAL to non-speculative decoding on every backend.
        Rejected positions rewind: page-granular ``kv.truncate`` plus
        the length bookkeeping the scheduler already keeps."""
        B, K = self.scfg.max_batch, self.scfg.spec_k
        allow = [self.sched.draft_allowance(r) for r in batch]
        drafts = self.proposer.propose(batch, allow)
        ids = np.zeros((B, K + 1), np.int32)
        start = np.zeros((B,), np.int32)
        n_tok = np.zeros((B,), np.int32)
        for i, r in enumerate(batch):
            d = drafts[i][:allow[i]]
            drafts[i] = d
            ids[i, 0] = r.next_input()
            if d:
                ids[i, 1:1 + len(d)] = d
            start[i] = r.n_prompt + len(r.out) - 1
            n_tok[i] = 1 + len(d)
        bt = self.kv.block_table(
            [r.rid for r in batch] + [None] * (B - len(batch)),
            self.scfg.table_slots)
        toks, self.pool = self.exec.verify(self.pool, ids, start, n_tok,
                                           bt, self._samp_state(batch))
        toks = np.asarray(toks)
        self.spec_stats["verify_ticks"] += 1
        self.spec_stats["verify_seqs"] += len(batch)
        for i, r in enumerate(batch):
            d = drafts[i]
            m = 0
            while m < len(d) and int(toks[i, m]) == int(d[m]):
                m += 1
            # the allowance already caps drafts at the output budget,
            # so emitting every accepted token can never overshoot
            emit = min(m + 1, r.max_new - len(r.out))
            self.spec_stats["drafted"] += len(d)
            self.spec_stats["accepted"] += m
            self.spec_stats["emitted"] += emit
            prev = self._last_tok.get(r.rid)
            for j in range(emit):
                self.sched.advance(r, int(toks[i, j]), now)
                if prev is not None:
                    # tokens of one verify pass arrive together: the
                    # first closes the inter-token gap, the rest are
                    # free (that IS the latency win)
                    self.itl.append(now - prev if j == 0 else 0.0)
            self._last_tok[r.rid] = now
            if r.finished():
                self._maybe_finish(r, now)
                continue
            if not d:
                continue      # nothing speculative was written: the
                              # allowance pages stay attached for the
                              # next window (no alloc/free churn)
            # rewind: K/V is valid through the last ACCEPTED position
            # (the newest sampled token's K/V is written when it is fed
            # next tick, same as non-speculative decode)
            self.kv.truncate(r.rid, r.n_prompt + len(r.out) - 1)
            self.proposer.rewind(r.rid, r.n_prompt + len(r.out) - 1)

    def _maybe_finish(self, r, now):
        if not r.is_prefilling() and r.finished():
            self.sched.finish(r, now,
                              register_prefix=self.scfg.prefix_keep)
            self.finished.append(r)
            # a reused rid (fresh trace on a live engine) must not see
            # this request's last-token time as its previous gap
            self._last_tok.pop(r.rid, None)
            if self.proposer is not None:
                self.proposer.drop(r.rid)

    # ------------------------------------------------------------------
    def run(self, requests: Sequence[Request], *, clock: str = "wall",
            max_ticks: int = 100_000) -> list:
        """Replay an arrival trace to completion.  ``clock="wall"``
        admits by elapsed wall time (benchmarking); ``"tick"`` admits by
        tick count (deterministic, what the parity suites use)."""
        pending = sorted(requests, key=lambda r: r.t_arrive)
        t0 = time.monotonic()
        skipped = 0.0          # idle time fast-forwarded past
        for _ in range(max_ticks):
            now = (self.ticks if clock == "tick"
                   else time.monotonic() - t0 + skipped)
            while pending and pending[0].t_arrive <= now:
                self.submit(pending.pop(0))
            if not self.sched.has_work():
                if not pending:
                    if self._swap is None:
                        return self.finished
                    self.tick(now)       # drain the in-flight hot swap
                    continue
                if clock == "wall":      # fast-forward idle gaps
                    skipped += pending[0].t_arrive - now
                    now = time.monotonic() - t0 + skipped
                self.submit(pending.pop(0))
            self.tick(now)
        raise RuntimeError(f"serve loop did not converge in {max_ticks} "
                           f"ticks ({len(self.finished)} finished)")

    def reset_metrics(self) -> None:
        """Forget finished requests and counters (page/pool state
        stays).  Benchmarks warm the jit caches with a throwaway trace,
        reset, then measure a clean run on the SAME engine — so the
        measured rows reflect engine/scheduler structure, not XLA
        compile time."""
        self.finished.clear()
        self.shed.clear()
        self.ticks = 0
        self.itl.clear()
        self._last_tok.clear()
        for k in self.sched.stats:
            self.sched.stats[k] = 0
        for k in self.kv.stats:
            self.kv.stats[k] = 0
        for k in self.spec_stats:
            self.spec_stats[k] = 0
        for k in self.swap_stats:
            if k != "generation":        # generations keep counting up
                self.swap_stats[k] = 0
        if self.slo is not None:
            self.slo.reset()

    # ------------------------------------------------------------------
    def metrics(self) -> dict:
        """Throughput/latency summary over finished requests."""
        lat = np.array([r.t_finish - r.t_arrive for r in self.finished])
        ttft = np.array([r.t_first - r.t_arrive for r in self.finished
                         if r.t_first is not None])
        # decode latency = inter-token gaps (ITL/TPOT): the per-token
        # quantity chunked prefill protects — a batch-mate's monolithic
        # prompt admission stretches the tick every decoding neighbour
        # waits on, and that stretch lands in these gaps
        dec = np.asarray(self.itl)
        toks = sum(len(r.out) for r in self.finished)
        span = max((r.t_finish for r in self.finished), default=0.0) \
            - min((r.t_arrive for r in self.finished), default=0.0)
        pct = (lambda a, p: float(np.percentile(a, p)) if a.size else 0.0)
        sp = dict(self.spec_stats)
        sp["accept_rate"] = (sp["accepted"] / sp["drafted"]
                             if sp["drafted"] else 0.0)
        # tokens one sequence's verify pass emits (> 1 = speculation is
        # beating one-token-per-tick decode)
        sp["tokens_per_tick"] = (sp["emitted"] / sp["verify_seqs"]
                                 if sp["verify_seqs"] else 0.0)
        slo = slo_summary(self.finished, self.shed,
                          self.slo.stats if self.slo is not None else None)
        return {
            "requests": len(self.finished),
            "tokens_out": int(toks),
            "span_s": float(span),
            "throughput_tok_s": toks / span if span > 0 else 0.0,
            "latency_p50_s": pct(lat, 50), "latency_p99_s": pct(lat, 99),
            "ttft_p50_s": pct(ttft, 50), "ttft_p99_s": pct(ttft, 99),
            "decode_p50_s": pct(dec, 50), "decode_p99_s": pct(dec, 99),
            "ticks": self.ticks,
            "sched": dict(self.sched.stats),
            "kv": dict(self.kv.stats),
            "spec": sp,
            "slo": slo,
            "swap": dict(self.swap_stats),
        }


def slo_summary(finished, shed, policy_stats=None) -> dict:
    """Per-class SLO attainment and shed counts over a served trace.

    Attainment is TTFT against each request's own ``deadline``
    (requests without one count as attained — vacuously in-SLO); shed
    requests count against their class's shed bucket, never against
    attainment (they were refused, not served late)."""
    out: dict = {"attained": {}, "finished": {}, "shed": {}}
    for p in PRIORITIES:
        done = [r for r in finished if r.priority == p]
        ok = [r for r in done
              if r.deadline is None
              or (r.t_first is not None
                  and r.t_first - r.t_arrive <= r.deadline)]
        out["finished"][p] = len(done)
        out["attained"][p] = (len(ok) / len(done)) if done else 1.0
        out["shed"][p] = sum(1 for r in shed if r.priority == p)
    if policy_stats is not None:
        out["policy"] = dict(policy_stats)
    return out
