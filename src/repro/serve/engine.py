"""Continuous-batching inference engine over the paged symmetric-heap
KV cache.

The engine is split in two layers:

  * **pure step functions** (``make_prefill`` / ``make_decode_step``) —
    trace-friendly, built from the same model weights AND the same
    projection convention the registry's train/decode paths use
    (``attention.project_qkv``, ``embed``, ``mlp``), tensor-parallel
    through ``ctx.tp_comm`` so all
    registered communicator backends (xla / posh / pallas) serve
    traffic.  Attention in the decode step is the paged kernel
    (``ops.paged_attention``) reading K/V through the block table.

  * a **host-side driver** (``ServeEngine``) — owns the
    ``FCFSScheduler`` + ``PagedKVCache``, runs one token per running
    sequence per tick, and drains every tick's planned page migrations
    with ``put_nbi`` + ONE ``quiet()`` on a ``CommQueue`` before the
    decode step runs.  The execution substrate is pluggable
    (``LocalExec`` jits on one device; the mesh suite supplies a
    shard_map-wrapped equivalent), so the same scheduler drives a
    single CPU process and an 8-PE TP mesh.

Batch slots are fixed (``ServeConfig.max_batch``): empty slots carry
the null page table and length 0, which zeroes their attention output
and routes their KV writes to the null page — no branches in the traced
step.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.heap import SymmetricHeap
from repro.core.ordering import CommQueue, LocalTransport
from repro.kernels import ops
from repro.models import attention as attn
from repro.models import embed as emb
from repro.models import lm
from repro.models import mlp as ff
from repro.models.common import norm_apply
from repro.parallel.ctx import ParallelCtx

from .kv_cache import PagedKVCache
from .scheduler import FCFSScheduler, Request


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Trace-time serving shape: page geometry, batch and sequence
    bounds, attention implementation, KV precision."""

    page_tokens: int = 8
    n_pages: int = 64
    max_batch: int = 4
    max_seq: int = 64                 # prompt + decode budget per seq
    max_prompt: int = 32              # prefill pad length
    attn_impl: str = "kernel"         # "kernel" (Pallas) | "ref" (jnp)
    kv_dtype: jnp.dtype = jnp.float32
    prefix_keep: bool = False         # pin finished prompts' full pages
                                      # as migratable prefix cache

    @property
    def table_slots(self) -> int:
        return -(-self.max_seq // self.page_tokens)


def _check_supported(cfg, ctx: ParallelCtx) -> None:
    if cfg.family not in ("dense", "moe"):
        raise NotImplementedError(
            f"repro.serve drives dense/moe decoders; got {cfg.family}")
    if cfg.attn_layout(ctx.tp_size) != "head":
        raise NotImplementedError(
            "repro.serve requires the head-parallel attention layout "
            f"({cfg.n_heads} heads, tp={ctx.tp_size})")
    if cfg.swa_window is not None:
        raise NotImplementedError("sliding-window + paged cache: not yet")


# ======================================================================
# pure step functions
# ======================================================================
def _write_pages(pool, li, k, v, bt, pos, page_tokens):
    """Scatter one-token-per-sequence K/V into the page pool.
    pool: (n_pages, 2, L, P, kvh, dh); k/v: (b, kvh, dh); pos: (b,).
    Inactive slots carry the null block table -> rows land in page 0."""
    page = jnp.take_along_axis(bt, (pos // page_tokens)[:, None],
                               axis=1)[:, 0]
    slot = pos % page_tokens
    dt = pool.dtype
    pool = pool.at[page, 0, li, slot].set(k.astype(dt))
    pool = pool.at[page, 1, li, slot].set(v.astype(dt))
    return pool


def make_decode_step(cfg, ctx: ParallelCtx, scfg: ServeConfig):
    """One serving tick: (params, pool, tokens, pos, bt, lens) ->
    (next_tokens, pool).

    tokens (b,) int32 input token per slot; pos (b,) its position;
    bt (b, table_slots) int32 block tables; lens (b,) valid tokens
    AFTER this write (pos+1 for live slots, 0 for empty ones).
    """
    _check_supported(cfg, ctx)
    P = scfg.page_tokens

    def step(params, pool, tokens, pos, bt, lens):
        cd = ctx.compute_dtype
        x = emb.embed_lookup(params["embed"], tokens[:, None], ctx)[:, 0]
        b = x.shape[0]

        def body(carry, inputs):
            x, pool = carry
            p, li = inputs
            h = norm_apply("rms", p["ln1"], x).astype(cd)
            q, k, v = attn.project_qkv(p["attn"], h[:, None],
                                       pos[:, None], cfg, ctx)
            q, k, v = q[:, 0], k[:, 0], v[:, 0]
            pool = _write_pages(pool, li, k, v, bt, pos, P)
            kp = jax.lax.dynamic_index_in_dim(pool[:, 0], li, axis=1,
                                              keepdims=False)
            vp = jax.lax.dynamic_index_in_dim(pool[:, 1], li, axis=1,
                                              keepdims=False)
            o = ops.paged_attention(q, kp, vp, bt, lens,
                                    impl=scfg.attn_impl)
            out = o.reshape(b, -1).astype(cd) @ p["attn"]["wo"].astype(cd)
            out = ctx.tp_comm.psum(out)
            x = x + out
            m = lm._decode_mlp(p["mlp"], norm_apply("rms", p["ln2"], x),
                               ctx, cfg)
            return (x + m, pool), None

        (x, pool), _ = jax.lax.scan(
            body, (x, pool),
            (params["blocks"], jnp.arange(cfg.n_layers)))
        x = norm_apply("rms" if cfg.family != "encdec" else "layer",
                       params["ln_f"], x)
        head = params["embed"] if cfg.tie_embeddings else params["head"]
        logits = emb.lm_head_logits(head, x.astype(cd), ctx)
        nxt = emb.tp_argmax(logits, ctx)
        return nxt.astype(jnp.int32), pool

    return step


def make_prefill(cfg, ctx: ParallelCtx, scfg: ServeConfig):
    """Batched full-prompt prefill: (params, pool, ids, lens, bt) ->
    (first_tokens, pool).

    ids (b, t) right-padded prompts; lens (b,) true lengths (0 = empty
    slot).  Writes every prompt position's K/V into the pages and
    returns the greedy token following each prompt.  Attention is the
    contiguous blocked flash (prompt K/V are in registers anyway); the
    pages are written for the decode steps that follow.
    """
    _check_supported(cfg, ctx)
    P = scfg.page_tokens
    from repro.models.flash import blocked_attention

    def prefill(params, pool, ids, lens, bt):
        cd = ctx.compute_dtype
        x = emb.embed_lookup(params["embed"], ids, ctx)
        b, t = ids.shape
        pos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))

        def body(carry, inputs):
            x, pool = carry
            p, li = inputs
            h = norm_apply("rms", p["ln1"], x).astype(cd)
            q, k, v = attn.project_qkv(p["attn"], h, pos, cfg, ctx)
            # page writes: token (b, j) -> page bt[b, j//P] slot j%P
            page = jnp.take_along_axis(bt, pos // P, axis=1)     # (b, t)
            slot = pos % P
            dt = pool.dtype
            pool = pool.at[page, 0, li, slot].set(k.astype(dt))
            pool = pool.at[page, 1, li, slot].set(v.astype(dt))
            o = blocked_attention(q, k, v, causal=True,
                                  block_q=ctx.attn_block_q,
                                  block_kv=ctx.attn_block_kv,
                                  unroll=ctx.unroll)
            out = o.reshape(b, t, -1).astype(cd) @ p["attn"]["wo"].astype(cd)
            out = ctx.tp_comm.psum(out)
            x = x + out
            ctx1 = ctx.with_(sp=False)
            mlp = (ff.moe_apply if cfg.moe else ff.mlp_apply)(
                p["mlp"], norm_apply("rms", p["ln2"], x), ctx1, cfg)
            return (x + mlp, pool), None

        (x, pool), _ = jax.lax.scan(
            body, (x, pool),
            (params["blocks"], jnp.arange(cfg.n_layers)))
        x = norm_apply("rms", params["ln_f"], x)
        last = jnp.clip(lens - 1, 0, t - 1)
        xl = jnp.take_along_axis(x, last[:, None, None], axis=1)[:, 0]
        head = params["embed"] if cfg.tie_embeddings else params["head"]
        logits = emb.lm_head_logits(head, xl.astype(cd), ctx)
        nxt = emb.tp_argmax(logits, ctx)
        return nxt.astype(jnp.int32), pool

    return prefill


# ======================================================================
# execution substrates
# ======================================================================
class LocalExec:
    """Single-device execution: jitted step functions over the per-PE
    pool, a loopback CommQueue (LocalTransport, 1 PE) for the migration
    drain — the same ``put_nbi`` + one ``quiet()`` path the mesh runs,
    minus the wire."""

    def __init__(self, params, cfg, ctx, scfg: ServeConfig,
                 kv: PagedKVCache):
        self.params = params
        self.kv = kv
        self._prefill = jax.jit(make_prefill(cfg, ctx, scfg))
        self._decode = jax.jit(make_decode_step(cfg, ctx, scfg))
        self._team = ctx.tp_comm.team

    def init_pool(self):
        return self.kv.zeros()

    def prefill(self, pool, ids, lens, bt):
        return self._prefill(self.params, pool, jnp.asarray(ids),
                             jnp.asarray(lens), jnp.asarray(bt))

    def decode(self, pool, tokens, pos, bt, lens):
        return self._decode(self.params, pool, jnp.asarray(tokens),
                            jnp.asarray(pos), jnp.asarray(bt),
                            jnp.asarray(lens))

    def migrate(self, pool, migrations):
        # whole-system view with one PE: state rows carry the PE axis
        state = {self.kv.handle.name: np.asarray(pool)[None]}
        q = CommQueue(self._team, state, transport=LocalTransport(1))
        out = self.kv.issue_migrations(q, state[self.kv.handle.name],
                                       migrations, system=True)
        return jnp.asarray(out[self.kv.handle.name][0])


# ======================================================================
# the driver
# ======================================================================
class ServeEngine:
    """Continuous-batching driver: one token per running sequence per
    tick, FCFS admission, preempt-by-eviction, migration drain first."""

    def __init__(self, params, cfg, ctx: ParallelCtx, scfg: ServeConfig,
                 *, heap: Optional[SymmetricHeap] = None,
                 kv: Optional[PagedKVCache] = None, exec_=None,
                 my_pe: int = 0):
        self.cfg, self.ctx, self.scfg = cfg, ctx, scfg
        if kv is None:
            heap = heap or SymmetricHeap(
                (ctx.tp_axis,) if ctx.tp_size > 1 else ("data",))
            kv = PagedKVCache(
                heap, n_layers=cfg.n_layers,
                kv_heads=cfg.kv_per_rank(ctx.tp_size),
                head_dim=cfg.head_dim, n_pages=scfg.n_pages,
                page_tokens=scfg.page_tokens, dtype=scfg.kv_dtype)
        self.kv = kv
        self.sched = FCFSScheduler(kv, max_batch=scfg.max_batch,
                                   max_seq=scfg.max_seq, my_pe=my_pe)
        self.exec = exec_ or LocalExec(params, cfg, ctx, scfg, kv)
        self.pool = self.exec.init_pool()
        self.finished: list = []
        self.ticks = 0

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        if req.n_prompt > self.scfg.max_prompt:
            raise ValueError(
                f"request {req.rid}: prompt of {req.n_prompt} exceeds "
                f"max_prompt {self.scfg.max_prompt}")
        self.sched.submit(req)

    def tick(self, now: float = 0.0) -> None:
        """One engine tick: schedule -> migrate (one quiet) -> batched
        prefill for fresh admissions -> one decode token for every
        other running sequence -> retire finished."""
        self.ticks += 1
        plan = self.sched.tick()
        if plan.migrations:
            self.pool = self.exec.migrate(self.pool,
                                          tuple(plan.migrations))
        fresh = []
        if plan.admitted:
            fresh = self._batch_prefill(plan.admitted, now)
        self._decode_tick(skip=fresh, now=now)

    def _batch_prefill(self, reqs, now):
        B, T = self.scfg.max_batch, self.scfg.max_prompt
        reqs = list(reqs)
        ids = np.zeros((B, T), np.int32)
        lens = np.zeros((B,), np.int32)
        for i, r in enumerate(reqs):
            if r.n_prompt > T:
                raise ValueError(f"prompt {r.n_prompt} > max_prompt {T}")
            ids[i, :r.n_prompt] = r.prompt
            lens[i] = r.n_prompt
        bt = self.kv.block_table(
            [r.rid for r in reqs] + [None] * (B - len(reqs)),
            self.scfg.table_slots)
        toks, self.pool = self.exec.prefill(self.pool, ids, lens, bt)
        toks = np.asarray(toks)
        for i, r in enumerate(reqs):
            self.sched.note_prefilled(r, int(toks[i]), now)
            self._maybe_finish(r, now)
        return reqs

    def _decode_tick(self, skip, now):
        batch = [r for r in self.sched.running if r not in skip]
        if not batch:
            return
        B = self.scfg.max_batch
        tokens = np.zeros((B,), np.int32)
        pos = np.zeros((B,), np.int32)
        lens = np.zeros((B,), np.int32)
        for i, r in enumerate(batch):
            tokens[i] = r.next_input()
            p = r.n_done if r.is_prefilling() \
                else r.n_prompt + len(r.out) - 1
            pos[i] = p
            lens[i] = p + 1
        bt = self.kv.block_table(
            [r.rid for r in batch] + [None] * (B - len(batch)),
            self.scfg.table_slots)
        toks, self.pool = self.exec.decode(self.pool, tokens, pos, bt,
                                           lens)
        toks = np.asarray(toks)
        for i, r in enumerate(batch):
            self.sched.advance(r, int(toks[i]), now)
            self._maybe_finish(r, now)

    def _maybe_finish(self, r, now):
        if not r.is_prefilling() and r.finished():
            self.sched.finish(r, now,
                              register_prefix=self.scfg.prefix_keep)
            self.finished.append(r)

    # ------------------------------------------------------------------
    def run(self, requests: Sequence[Request], *, clock: str = "wall",
            max_ticks: int = 100_000) -> list:
        """Replay an arrival trace to completion.  ``clock="wall"``
        admits by elapsed wall time (benchmarking); ``"tick"`` admits by
        tick count (deterministic, what the parity suites use)."""
        pending = sorted(requests, key=lambda r: r.t_arrive)
        t0 = time.monotonic()
        skipped = 0.0          # idle time fast-forwarded past
        for _ in range(max_ticks):
            now = (self.ticks if clock == "tick"
                   else time.monotonic() - t0 + skipped)
            while pending and pending[0].t_arrive <= now:
                self.submit(pending.pop(0))
            if not self.sched.has_work():
                if not pending:
                    return self.finished
                if clock == "wall":      # fast-forward idle gaps
                    skipped += pending[0].t_arrive - now
                    now = time.monotonic() - t0 + skipped
                self.submit(pending.pop(0))
            self.tick(now)
        raise RuntimeError(f"serve loop did not converge in {max_ticks} "
                           f"ticks ({len(self.finished)} finished)")

    # ------------------------------------------------------------------
    def metrics(self) -> dict:
        """Throughput/latency summary over finished requests."""
        lat = np.array([r.t_finish - r.t_arrive for r in self.finished])
        ttft = np.array([r.t_first - r.t_arrive for r in self.finished
                         if r.t_first is not None])
        toks = sum(len(r.out) for r in self.finished)
        span = max((r.t_finish for r in self.finished), default=0.0) \
            - min((r.t_arrive for r in self.finished), default=0.0)
        pct = (lambda a, p: float(np.percentile(a, p)) if a.size else 0.0)
        return {
            "requests": len(self.finished),
            "tokens_out": int(toks),
            "span_s": float(span),
            "throughput_tok_s": toks / span if span > 0 else 0.0,
            "latency_p50_s": pct(lat, 50), "latency_p99_s": pct(lat, 99),
            "ttft_p50_s": pct(ttft, 50), "ttft_p99_s": pct(ttft, 99),
            "ticks": self.ticks,
            "sched": dict(self.sched.stats),
            "kv": dict(self.kv.stats),
        }
