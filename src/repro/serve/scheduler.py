"""FCFS continuous batching with preempt-by-eviction and token-budgeted
chunked prefill.

Classic continuous batching (Orca/vLLM style) over the paged KV cache:

  * requests queue FCFS; a request is ADMITTED when a batch slot is
    free and the pool can cover its prompt + one decode page;
  * every engine tick decodes ONE token for every decoding sequence,
    and assigns every PREFILLING sequence (fresh admission, preemption
    re-prefill, or a prefix-cache resume's uncovered suffix) up to
    ``prefill_chunk`` prompt tokens, all under one shared per-tick
    token budget (``tick_tokens``) — decode claims its tokens first,
    so a long prompt can never stall the decodes sharing its batch;
  * when a decode step needs a page and the pool is dry, the YOUNGEST
    running sequence is preempted by eviction: its pages are freed, it
    re-queues at the head of the waiting line (FCFS order preserved —
    it is still ahead of everything that arrived after it) and will
    re-prefill on re-admission.

``Request`` identity is OBJECT identity (``eq=False``): two requests
holding equal field values are still distinct schedulable entities, so
plan membership (``plan.preempted``) and batch-skip bookkeeping can
never conflate them; cross-object bookkeeping uses rid sets.

The scheduler is host-side and deterministic: given the same arrival
trace it makes the same decisions regardless of communicator backend,
which is what lets the mesh test demand bit-identical token streams
across xla/posh/pallas.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Optional

import numpy as np

from .kv_cache import PagedKVCache, PageMigration
from .sampling import GREEDY, SamplingParams


@dataclasses.dataclass(eq=False)
class Request:
    """One inference request.  ``prompt`` is a list of token ids;
    ``max_new`` the decode budget; ``sampling`` the per-request
    sampling policy (default greedy).

    ``eq=False``: requests compare and hash by OBJECT identity, never
    by field values — the scheduler tracks live entities, and two
    requests with identical parameters must stay distinguishable in
    membership tests (``running.remove``, ``in plan.preempted``)."""

    rid: int
    prompt: list
    max_new: int
    t_arrive: float = 0.0
    sampling: SamplingParams = GREEDY
    # SLO attributes (serve.slo): class rank orders admission and
    # (inversely) eviction; ``deadline`` is the relative TTFT budget
    # (engine clock units) attainment is measured against — and the
    # shed trigger for best-effort traffic; ``tenant`` keys the
    # token-rate fairness bucket
    priority: str = "interactive"
    deadline: Optional[float] = None
    tenant: int = 0

    # runtime (engine-owned)
    out: list = dataclasses.field(default_factory=list)
    n_done: int = 0          # prompt tokens whose KV is in pages
    prefill_chunks: list = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    t_first: Optional[float] = None
    t_finish: Optional[float] = None
    preemptions: int = 0
    shed: bool = False       # dropped by deadline shedding, never served

    @property
    def n_prompt(self) -> int:
        return len(self.prompt)

    def next_input(self) -> int:
        """The token this sequence feeds next: the prompt while it is
        still being consumed, the last sampled token afterwards."""
        if self.n_done < self.n_prompt:
            return int(self.prompt[self.n_done])
        return int(self.out[-1])

    def is_prefilling(self) -> bool:
        return self.n_done < self.n_prompt

    def finished(self) -> bool:
        return len(self.out) >= self.max_new

    def reset(self) -> None:
        """Preemption: all progress is rebuilt from scratch."""
        self.out.clear()
        self.prefill_chunks.clear()
        self.n_done = 0
        self.slot = None
        self.preemptions += 1


@dataclasses.dataclass
class TickPlan:
    """What one scheduler tick decided (the engine executes it)."""

    admitted: list = dataclasses.field(default_factory=list)   # fresh
    resumed: list = dataclasses.field(default_factory=list)    # prefix-attached
    preempted: list = dataclasses.field(default_factory=list)
    migrations: list = dataclasses.field(default_factory=list)  # PageMigration
    prefill: list = dataclasses.field(default_factory=list)    # (req, n_tokens)
    shed: list = dataclasses.field(default_factory=list)       # deadline drops


class FCFSScheduler:
    """First-come-first-served admission over a PagedKVCache.

    ``prefill_chunk`` caps the prompt tokens one sequence consumes per
    tick; ``tick_tokens`` is the per-tick token budget shared by decode
    (one token per decoding sequence, claimed first) and prefill chunks
    (handed out FCFS in admission order).  The oldest prefilling
    sequence is always guaranteed one token, so prefill can never
    starve outright.  With speculation on (``spec_k > 0``) a decoding
    sequence's claim is its whole verify window — one pending token
    plus ``draft_allowance`` drafts — in both the token budget and the
    page demand, so spec decode composes with chunked prefill and
    preempt-by-eviction instead of silently overcommitting the tick.
    ``tick_tokens=0`` resolves to
    ``max_batch * (1 + spec_k) + prefill_chunk``."""

    def __init__(self, kv: PagedKVCache, *, max_batch: int,
                 max_seq: int, my_pe: int = 0, prefill_chunk: int = 8,
                 tick_tokens: int = 0, spec_k: int = 0, slo=None):
        self.kv = kv
        self.max_batch = int(max_batch)
        self.max_seq = int(max_seq)
        self.my_pe = int(my_pe)
        self.prefill_chunk = max(int(prefill_chunk), 1)
        self.spec_k = max(int(spec_k), 0)
        # under speculation a decoding sequence's tick claim is its
        # whole verify window (pending token + drafts), so the default
        # budget scales with it
        self.tick_tokens = int(tick_tokens) or (
            self.max_batch * (1 + self.spec_k) + self.prefill_chunk)
        # SLO policy (serve.slo.SLOPolicy): None keeps plain FCFS —
        # every decision below is bit-identical to the pre-SLO
        # scheduler in that case
        self.slo = slo
        self.waiting: deque = deque()
        self.running: list = []          # admission order (oldest first)
        self._decode_refund = 0          # unspent decode claims of
                                         # sequences evicted this tick
        self._admit_seq = itertools.count()
        self._admit_idx: dict = {}       # rid -> admission ticket
        self._arrive_seq = itertools.count()
        self._arrive_idx: dict = {}      # rid -> submission ticket
        self.stats = {"admitted": 0, "resumed": 0, "preempted": 0,
                      "finished": 0, "ticks": 0, "prefill_tokens": 0,
                      "released": 0, "adopted": 0, "shed": 0,
                      "rate_deferred": 0}

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        if req.n_prompt + req.max_new > self.max_seq:
            raise ValueError(
                f"request {req.rid}: {req.n_prompt}+{req.max_new} tokens "
                f"exceed max_seq {self.max_seq}")
        self._arrive_idx.setdefault(req.rid, next(self._arrive_seq))
        self.waiting.append(req)

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # ------------------------------------------------------------------
    def tick(self, now: float = 0.0) -> TickPlan:
        """One scheduling round: budget the tick's tokens (decode
        first, then prefill chunks FCFS), grow running sequences
        (preempting by eviction when the pool is dry), then admit FCFS
        while slots, pages and budget last.  Prefix-cache hits admit as
        RESUMED sequences whose first pages arrive by migration instead
        of recompute.  With an SLO policy attached: expired best-effort
        waiters shed first, admission runs in priority order, eviction
        inverse-priority, and best-effort traffic degrades (chunk cap,
        draft strip) while higher classes have unmet demand."""
        self.stats["ticks"] += 1
        plan = TickPlan()
        if self.slo is not None:
            self._shed_expired(now, plan)
            self.slo.update_pressure(self.waiting, self.running, self.kv)
            self.slo.tick_refill()
        quotas: dict = {}                # rid -> prompt tokens this tick
        budget = self.tick_tokens
        # decode claims first: one token per decoding sequence PLUS its
        # draft allowance — a verify window spends real forward tokens,
        # so speculation composes with (never starves) chunked prefill
        budget -= sum(1 + self.draft_allowance(r) for r in self.running
                      if not r.is_prefilling())
        for req in self.running:         # admission order = FCFS
            if req.is_prefilling():
                budget = self._grant(req, quotas, budget,
                                     guarantee=not quotas)
        self._decode_refund = 0
        self._ensure_running(plan, quotas)
        # tokens granted to (or claimed by) sequences that eviction
        # just removed are unspent — hand them to this tick's admissions
        for r in plan.preempted:
            budget += quotas.pop(r.rid, 0)
        budget += self._decode_refund
        self._admit(plan, quotas, budget)
        plan.prefill = [(r, quotas[r.rid]) for r in self.running
                        if r.rid in quotas]
        self.stats["prefill_tokens"] += sum(n for _, n in plan.prefill)
        return plan

    def draft_allowance(self, req: Request) -> int:
        """Draft tokens a decoding sequence may carry into this tick's
        verify window: ``spec_k`` capped by the output budget — a
        request with ``m`` tokens left to emit can accept at most
        ``m - 1`` drafts (the verify pass itself emits one), so pages
        and budget are never reserved for tokens that cannot exist."""
        if self.spec_k == 0 or req.is_prefilling():
            return 0
        if self.slo is not None and self.slo.strip_drafts(req):
            return 0          # degraded: plain one-token decode
        return max(0, min(self.spec_k,
                          req.max_new - len(req.out) - 1))

    def _grant(self, req: Request, quotas: dict, budget: int, *,
               guarantee: bool) -> int:
        """Assign ``req`` its chunk for this tick out of ``budget``.
        ``guarantee`` forces at least one token (the oldest prefilling
        sequence and fresh admissions always make progress)."""
        chunk = self.prefill_chunk
        if self.slo is not None:
            chunk = self.slo.chunk_cap(req, chunk)
        q = min(chunk, max(budget, 0))
        if guarantee:
            q = max(q, 1)
        q = min(q, req.n_prompt - req.n_done)
        if q > 0:
            quotas[req.rid] = q
        return budget - q

    def _ensure_running(self, plan: TickPlan, quotas: dict) -> None:
        """Every running sequence needs page room for the tokens this
        tick writes.  Out of pages -> evict the youngest until it fits
        (never evicting the sequence we are growing unless it IS the
        youngest — then it preempts itself and waits)."""
        for req in list(self.running):
            if req not in self.running:
                continue                     # evicted by an earlier loop turn
            # exact demand for THIS tick's writes: prefill covers its
            # chunk quota; decode writes the last sampled token at
            # position n_prompt + len(out) - 1 PLUS one slot per draft
            # its verify window will score.  Asking for one more would
            # preempt a neighbour for a page the final token of a
            # finishing sequence never writes.
            need = req.n_done + quotas.get(req.rid, 0) \
                if req.is_prefilling() \
                else req.n_prompt + len(req.out) + self.draft_allowance(req)
            while not self.kv.ensure(req.rid, max(need, 1)):
                victim = self._youngest()
                self._preempt(victim, plan)
                if victim is req:
                    break

    def _youngest(self) -> Request:
        """The eviction victim.  FCFS: the youngest admission.  SLO:
        strictly inverse-priority — the lowest class goes first
        (best_effort, then batch, then interactive), youngest within a
        class — so interactive sequences evict LAST."""
        if self.slo is not None:
            return max(self.running,
                       key=lambda r: self.slo.evict_key(
                           r, self._admit_idx[r.rid]))
        return max(self.running, key=lambda r: self._admit_idx[r.rid])

    def _shed_expired(self, now: float, plan: TickPlan) -> None:
        """Deadline shedding, BEFORE any admission or degradation this
        tick: waiting best-effort requests whose deadline passed are
        dropped — they leave the system without ever holding pages."""
        for req in [r for r in self.waiting
                    if self.slo.should_shed(r, now)]:
            self.waiting.remove(req)     # identity (eq=False)
            req.shed = True
            req.t_finish = now
            plan.shed.append(req)
            self.stats["shed"] += 1
            self.slo.note_shed(req)

    def _preempt(self, req: Request, plan: TickPlan) -> None:
        if not req.is_prefilling():
            # its decode claim (token + draft window) is unspent
            self._decode_refund += 1 + self.draft_allowance(req)
        self.kv.free_seq(req.rid)
        self.running.remove(req)             # identity (eq=False)
        req.reset()
        # back to the head of the line: still ahead of later arrivals
        self.waiting.appendleft(req)
        plan.preempted.append(req)
        self.stats["preempted"] += 1

    def _admission_order(self) -> list:
        """Admission candidates.  FCFS: the waiting deque as-is.  SLO:
        (class rank, arrival) — a preemption victim keeps its original
        arrival ticket, so it stays ahead of later arrivals WITHIN its
        class, and interactive arrivals jump the best-effort backlog."""
        if self.slo is None:
            return list(self.waiting)
        return sorted(self.waiting,
                      key=lambda r: self.slo.admit_key(
                          r, self._arrive_idx.setdefault(
                              r.rid, next(self._arrive_seq))))

    def _admit(self, plan: TickPlan, quotas: dict, budget: int) -> None:
        preempted_rids = {r.rid for r in plan.preempted}
        for req in self._admission_order():
            if len(self.running) >= self.max_batch:
                break
            if req.rid in preempted_rids:
                # evicted THIS tick to let an older sequence breathe —
                # re-admitting immediately would thrash prefill
                break
            if self.slo is not None and not self.slo.admit_charge(req):
                # tenant over its token rate: ITS request defers, the
                # line behind it does not (the fairness property)
                self.stats["rate_deferred"] += 1
                continue
            hit = self.kv.lookup_prefix(req.prompt)
            if hit is not None:
                # remote owner: pages arrive by one-sided migration;
                # same-PE owner: the identical put_nbi path with
                # self-pairs — a 0-hop page copy into fresh pages, so
                # the pinned originals stay in the index
                if not self._admit_resumed(req, hit, plan):
                    if self.slo is not None:
                        self.slo.admit_refund(req)
                    break
            else:
                # prompt + the first decode page, all or nothing
                if not self.kv.alloc_seq(req.rid, req.n_prompt + 1):
                    if self.slo is not None:
                        self.slo.admit_refund(req)
                    break
                self.waiting.remove(req)     # identity (eq=False)
                self._start(req)
                plan.admitted.append(req)
                self.stats["admitted"] += 1
            budget = self._grant(req, quotas, budget, guarantee=True)

    def _admit_resumed(self, req: Request, hit, plan: TickPlan) -> bool:
        """Prefix pages live on another PE: take landing pages, plan the
        migrations, and admit with the prefix marked done — the rest of
        the prompt streams through the chunked-prefill path."""
        owner_pe, src_pages = hit
        landing = self.kv.take_pages(len(src_pages))
        if landing is None:
            return False
        self.kv.attach_seq(req.rid, landing)
        if not self.kv.ensure(req.rid, req.n_prompt + 1):
            self.kv.free_seq(req.rid)
            return False
        plan.migrations.extend(
            PageMigration(owner_pe, self.my_pe, s, d)
            for s, d in zip(src_pages, landing))
        self.waiting.remove(req)             # identity (eq=False)
        self._start(req)
        # leave >= 1 prompt token to feed: re-feeding the boundary token
        # rewrites identical KV (idempotent) and yields the next logits
        covered = len(landing) * self.kv.page_tokens
        req.n_done = min(covered, req.n_prompt - 1)
        plan.resumed.append(req)
        self.stats["resumed"] += 1
        self.kv.stats["prefix_hits"] += 1
        return True

    def _start(self, req: Request) -> None:
        self.running.append(req)
        self._admit_idx[req.rid] = next(self._admit_seq)

    # ------------------------------------------------------------------
    # disaggregated handoff (serve.disagg): a sequence leaves one cell's
    # scheduler mid-life and joins another's
    # ------------------------------------------------------------------
    def release(self, req: Request) -> None:
        """Hand a sequence off: remove it from this cell's running set
        WITHOUT freeing pages or resetting progress (contrast
        ``_preempt``) — its KV stays resident as the handoff payload
        source until the consumer cell acknowledges adoption."""
        self.running.remove(req)             # identity (eq=False)
        self.stats["released"] += 1

    def adopt(self, req: Request) -> None:
        """Receive a handed-off sequence: it enters this cell's running
        set mid-life (prompt consumed, first token emitted), youngest in
        eviction order like any fresh admission.  The caller has already
        attached its landing pages (``PagedKVCache.adopt_seq``)."""
        if len(self.running) >= self.max_batch:
            raise RuntimeError(
                f"adopt of {req.rid}: cell batch is full "
                f"({self.max_batch}) — the router must gate on slots")
        self._start(req)
        self.stats["adopted"] += 1

    # ------------------------------------------------------------------
    def advance(self, req: Request, token: int, now: float = 0.0) -> None:
        """Record the outcome of one decode step for ``req``: a sampled
        token appended (a still-prefilling sequence routes through
        ``note_chunk`` as a 1-token chunk, so the chunk bookkeeping
        stays the single source of truth).  The caller removes finished
        sequences via ``finish``."""
        if req.is_prefilling():
            self.note_chunk(req, 1, token, now)
        else:
            req.out.append(int(token))

    def note_chunk(self, req: Request, n: int, token: int,
                   now: float = 0.0) -> None:
        """Chunked prefill consumed ``n`` prompt tokens for ``req``;
        when the chunk completes the prompt, ``token`` (sampled after
        the last prompt position) is the first output token."""
        req.n_done += int(n)
        assert req.n_done <= req.n_prompt, (req.rid, req.n_done)
        req.prefill_chunks.append(int(n))
        if not req.is_prefilling():
            req.out.append(int(token))
            req.t_first = now

    def note_prefilled(self, req: Request, first_token: int,
                       now: float = 0.0) -> None:
        """A single chunk consumed the whole remaining prompt at once."""
        self.note_chunk(req, req.n_prompt - req.n_done, first_token, now)

    def finish(self, req: Request, now: float = 0.0,
               register_prefix: bool = True) -> None:
        req.t_finish = now
        self.running.remove(req)             # identity (eq=False)
        if register_prefix:
            pages = self.kv.tables[req.rid]
            n_full = min(len(pages),
                         req.n_prompt // self.kv.page_tokens)
            if n_full and self.kv.register_prefix(req.prompt, self.my_pe,
                                                  pages[:n_full]):
                # the registered pages stay resident (owned by the
                # prefix index, not the free list) so they remain
                # migratable; the rest return to the pool
                self.kv.tables[req.rid] = pages[n_full:]
        self.kv.free_seq(req.rid)
        self.stats["finished"] += 1
