"""Paged KV cache on the symmetric heap (the serving analogue of Fact 1).

The page pool is ONE symmetric allocation: a ``(n_pages, 2, n_layers,
page_tokens, kv_heads, head_dim)`` array carved from ``SymmetricHeap``,
so every PE holds the pool at the same offset with the same page
geometry.  That is what makes a *block table* — a plain array of page
ids — valid on every PE: page ``p`` of any sequence is rows
``[p:p+1]`` of the pool object on whichever PE you address (Corollary
1: the page id IS the remote address).  Cross-PE page migration is
therefore a one-sided ``put_nbi`` of one pool row — no handshake, no
collective — drained by the engine's single ``quiet()`` per scheduler
tick.

Page 0 is reserved as the *null page*: block tables are padded with it,
and writes for masked-out batch slots land there.  Real allocations
hand out ids 1..n_pages-1 from a free list (LIFO, so freshly freed
pages are reused while still warm in cache).

Host-side bookkeeping (free list, per-sequence tables, prefix index) is
plain Python — trace-time in the same sense as the heap allocator.  The
page *contents* live in the functional heap state dict and flow through
jit/shard_map like any other symmetric object.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.heap import SymHandle, SymmetricHeap
from repro.core.ordering import CommQueue

NULL_PAGE = 0


@dataclasses.dataclass(frozen=True)
class PageMigration:
    """One planned cross-PE page move: pool row ``src_page`` on PE
    ``src_pe`` -> pool row ``dst_page`` on PE ``dst_pe``."""

    src_pe: int
    dst_pe: int
    src_page: int
    dst_page: int


class PagedKVCache:
    """Fixed-size KV pages carved from the symmetric heap.

    ``kv_heads`` is the per-PE KV head count (``cfg.kv_per_rank(tp)``
    under tensor parallelism) — the pool is the per-PE shard, identical
    in shape on every PE like any symmetric object.
    """

    def __init__(self, heap: SymmetricHeap, *, n_layers: int,
                 kv_heads: int, head_dim: int, n_pages: int,
                 page_tokens: int, dtype=jnp.float32,
                 name: str = "kv_pages"):
        if n_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is the null page)")
        self.heap = heap
        self.page_tokens = int(page_tokens)
        self.n_layers = int(n_layers)
        self.kv_heads = int(kv_heads)
        self.head_dim = int(head_dim)
        self.dtype = jnp.dtype(dtype)
        self.handle: SymHandle = heap.alloc(
            name, (n_pages, 2, n_layers, page_tokens, kv_heads, head_dim),
            dtype)
        # LIFO free list over real pages (1..n-1); page 0 stays null.
        # ``attach_pool`` swaps this host list for a lock-free
        # SymmetricPagePool with the identical grant order.
        self._free: list[int] = list(range(n_pages - 1, 0, -1))
        self._pool = None                 # SymmetricPagePool when attached
        self.tables: dict = {}            # seq id -> list[int] page ids
        # prefix index: tuple(prompt tokens of k full pages) ->
        # (owner_pe, [page ids on the owner]) — the migration source.
        # Registered pages are PINNED (out of circulation) so they stay
        # migratable; pinning is capped at a quarter of the pool so the
        # cache cannot starve admissions.
        self._prefix: dict = {}
        self.pin_budget = max((n_pages - 1) // 4, 2)
        self.pinned_pages = 0
        self.stats = {"page_allocs": 0, "page_frees": 0, "migrations": 0,
                      "prefix_hits": 0, "rewound_pages": 0,
                      "exported_pages": 0, "adopted_pages": 0}

    # ------------------------------------------------------------------
    # geometry
    # ------------------------------------------------------------------
    @property
    def n_pages(self) -> int:
        return self.handle.shape[0]

    def n_free(self) -> int:
        return self._pool.n_free() if self._pool is not None \
            else len(self._free)

    def pages_for(self, n_tokens: int) -> int:
        return -(-int(n_tokens) // self.page_tokens)

    # ------------------------------------------------------------------
    # free-list backend — host list, or an attached SymmetricPagePool
    # ------------------------------------------------------------------
    def attach_pool(self, pool) -> None:
        """Swap the host free list for a lock-free
        :class:`~repro.serve.page_pool.SymmetricPagePool`.  Legal only
        on a pristine cache (no tables, full free list): the pool
        starts from its own virgin state and the two free-list
        implementations grant identical page-id sequences ONLY from the
        same starting point."""
        if self.tables or len(self._free) != self.n_pages - 1:
            raise ValueError(
                "attach_pool needs a pristine cache (no live tables, "
                "full free list)")
        if pool.n_pages != self.n_pages:
            raise ValueError(
                f"pool covers {pool.n_pages} pages, cache has "
                f"{self.n_pages}")
        self._pool = pool
        self._free = []

    def _pop_page(self) -> Optional[int]:
        if self._pool is not None:
            return self._pool.pop_page()
        return self._free.pop() if self._free else None

    def _pop_pages(self, n: int) -> Optional[list[int]]:
        """All-or-nothing claim; restores the free list on shortfall."""
        if self._pool is not None:
            return self._pool.pop_pages(n)
        if n > len(self._free):
            return None
        return [self._free.pop() for _ in range(n)]

    def _push_pages(self, pages: Sequence[int]) -> None:
        """LIFO return: ``pages[0]`` ends on top on either backend."""
        if self._pool is not None:
            self._pool.push_pages(list(pages))
        else:
            self._free.extend(reversed(list(pages)))

    # ------------------------------------------------------------------
    # allocation — trace-time, host side
    # ------------------------------------------------------------------
    def alloc_seq(self, seq_id, n_tokens: int) -> bool:
        """Reserve pages covering ``n_tokens`` for a new sequence.
        All-or-nothing; False when the pool cannot cover it."""
        need = max(self.pages_for(n_tokens), 1)
        if seq_id in self.tables:
            raise ValueError(f"sequence {seq_id!r} already has pages")
        pages = self._pop_pages(need)
        if pages is None:
            return False
        self.tables[seq_id] = pages
        self.stats["page_allocs"] += need
        return True

    def ensure(self, seq_id, n_tokens: int) -> bool:
        """Grow a live sequence's table to cover ``n_tokens`` (decode
        crossing a page boundary).  False when out of pages — the
        scheduler then preempts someone."""
        table = self.tables[seq_id]
        while len(table) * self.page_tokens < n_tokens:
            page = self._pop_page()
            if page is None:
                return False
            table.append(page)
            self.stats["page_allocs"] += 1
        return True

    def truncate(self, seq_id, n_tokens: int) -> int:
        """Speculative-decode rewind: shrink a live sequence's block
        table to the pages covering its first ``n_tokens`` tokens.

        Page-granular: fully-rejected tail pages return to the free
        list (LIFO, like ``free_seq``); the partially-valid final page
        stays in the table and its slots past ``n_tokens`` are DEAD by
        length bookkeeping — every reader masks by sequence length, and
        the next write at a position lands in the same (page, slot), so
        stale K/V is overwritten before it can ever be attended to.
        ``n_tokens == 0`` rewinds the whole sequence (all pages freed,
        the empty table stays attached).  The null page is never in a
        table, so it is never freed here.  Returns the pages freed."""
        table = self.tables[seq_id]
        keep = self.pages_for(n_tokens)
        freed = table[keep:]
        if freed:
            del table[keep:]
            self._push_pages(freed)
            self.stats["page_frees"] += len(freed)
            self.stats["rewound_pages"] += len(freed)
        return len(freed)

    def free_seq(self, seq_id) -> None:
        pages = self.tables.pop(seq_id)
        self.stats["page_frees"] += len(pages)
        # LIFO, most-recently-used first
        self._push_pages(pages)

    def attach_seq(self, seq_id, pages: Sequence[int]) -> None:
        """Adopt already-filled pages (e.g. migrated prefix pages) as
        the head of a new sequence's block table."""
        if seq_id in self.tables:
            raise ValueError(f"sequence {seq_id!r} already has pages")
        self.tables[seq_id] = list(pages)

    def take_pages(self, n: int) -> Optional[list[int]]:
        """Pop ``n`` pages ownerless (migration landing zone)."""
        pages = self._pop_pages(n)
        if pages is None:
            return None
        self.stats["page_allocs"] += n
        return pages

    # ------------------------------------------------------------------
    # cross-pool handoff (disaggregated prefill/decode cells)
    # ------------------------------------------------------------------
    def export_seq(self, seq_id) -> list[int]:
        """Detach a sequence's block table for a cross-cell handoff:
        the pages leave the table but NOT the pool — they stay resident
        (and readable as the put-with-signal payload source) until the
        consumer cell acknowledges adoption, at which point the
        producer returns them with ``release_pages``.  Returns the page
        ids in table order."""
        pages = self.tables.pop(seq_id)
        self.stats["exported_pages"] += len(pages)
        return pages

    def adopt_seq(self, seq_id, n: int) -> Optional[list[int]]:
        """The consumer half of a handoff: carve ``n`` landing pages
        from this pool and attach them as ``seq_id``'s block table.
        The LANDING ids are this pool's own — the block-table remap a
        cross-cell move needs happens here, not in the payload (page
        contents are position-independent rows).  All-or-nothing: None
        when the pool cannot cover the sequence (the router keeps the
        ticket pending)."""
        pages = self.take_pages(n)
        if pages is None:
            return None
        self.attach_seq(seq_id, pages)
        self.stats["adopted_pages"] += n
        return pages

    def release_pages(self, pages: Sequence[int]) -> None:
        self.stats["page_frees"] += len(pages)
        self._push_pages(list(pages))

    # ------------------------------------------------------------------
    # block tables as arrays (what the step functions consume)
    # ------------------------------------------------------------------
    def block_table(self, seq_ids, n_slots: int) -> np.ndarray:
        """(B, n_slots) int32, padded with the null page.  ``None``
        entries in ``seq_ids`` (empty batch slots) become all-null."""
        out = np.full((len(seq_ids), n_slots), NULL_PAGE, np.int32)
        for i, sid in enumerate(seq_ids):
            if sid is None:
                continue
            pages = self.tables[sid]
            if len(pages) > n_slots:
                raise ValueError(
                    f"sequence {sid!r} has {len(pages)} pages > "
                    f"{n_slots} table slots")
            out[i, :len(pages)] = pages
        return out

    # ------------------------------------------------------------------
    # prefix cache (the migration source)
    # ------------------------------------------------------------------
    def register_prefix(self, tokens, owner_pe: int,
                        pages: Sequence[int]) -> bool:
        """Publish ``len(pages)`` FULL pages holding the KV of
        ``tokens[:len(pages)*page_tokens]`` as migratable from
        ``owner_pe``.  Block-table offsets are symmetric, so the entry
        is meaningful on every PE without translation (Fact 1).
        Returns False (caller keeps page ownership) when the prefix is
        already published — pinned pages must have exactly one owner —
        or when pinning would exceed the pin budget (the cache must
        never starve admissions)."""
        k = len(pages)
        key = tuple(int(t) for t in tokens[:k * self.page_tokens])
        if not key or key in self._prefix \
                or self.pinned_pages + k > self.pin_budget:
            return False
        self._prefix[key] = (int(owner_pe), list(pages))
        self.pinned_pages += k
        return True

    def lookup_prefix(self, tokens):
        """Longest registered full-page prefix of ``tokens``.  Returns
        (owner_pe, pages) or None.  (The ``prefix_hits`` stat counts
        successful RESUMES, not lookups — a blocked head-of-line
        request re-looks-up every tick; the scheduler records the hit
        once admission actually succeeds.)"""
        n_full = len(tokens) // self.page_tokens
        for k in range(n_full, 0, -1):
            hit = self._prefix.get(tuple(int(t)
                                         for t in tokens[:k * self.page_tokens]))
            if hit is not None:
                return hit
        return None

    # ------------------------------------------------------------------
    # migration — put_nbi per page, ONE quiet() per call (per tick)
    # ------------------------------------------------------------------
    def issue_migrations(self, queue: CommQueue, pool,
                         migrations: Sequence[PageMigration], *,
                         system: bool = False, pairs_of=None):
        """Issue every planned page move as a nonblocking one-sided put
        and drain with a single ``quiet()`` — the engine calls this once
        per scheduler tick, so however many pages move, the tick pays
        one completion barrier (§3.2's whole point).

        ``pool`` is the pool array the payload rows are sliced from:
        the per-PE shard under ``PermuteTransport`` (inside shard_map),
        or the whole (n_pe, n_pages, ...) system state under
        ``LocalTransport`` (``system=True``).  ``pairs_of`` maps one
        migration to its (src, dst) pair list — defaults to the single
        ``(src_pe, dst_pe)`` pair; a tensor-parallel serving cell
        expands it to one pair per TP rank (each rank's page shard
        moves to its counterpart in one permute round).  Returns the
        drained heap state.
        """
        for m in migrations:
            if system:
                data = pool[:, m.src_page:m.src_page + 1]
            else:
                data = jax.lax.dynamic_slice_in_dim(pool, m.src_page, 1,
                                                    axis=0)
            pairs = pairs_of(m) if pairs_of else [(m.src_pe, m.dst_pe)]
            queue.put_nbi(self.handle, data, pairs, offset=m.dst_page)
        self.stats["migrations"] += len(migrations)
        return queue.quiet()

    # ------------------------------------------------------------------
    # pool state + growth
    # ------------------------------------------------------------------
    def zeros(self) -> jax.Array:
        return jnp.zeros(self.handle.shape, self.handle.dtype)

    def grow(self, extra_pages: int, pool: Optional[jax.Array] = None):
        """Extend the pool by ``extra_pages`` via ``heap.realloc`` —
        in place when the heap has room next door, moved otherwise (the
        offset stays symmetric either way).  Returns the new pool array
        with existing page contents carried over (when given)."""
        old_shape = self.handle.shape
        new_n = old_shape[0] + int(extra_pages)
        self.handle = self.heap.realloc(self.handle,
                                        (new_n,) + old_shape[1:])
        if self._pool is not None:
            self._pool.grow_pages(range(old_shape[0], new_n))
        else:
            self._free.extend(range(new_n - 1, old_shape[0] - 1, -1))
        if pool is None:
            return self.zeros()
        pad = [(0, new_n - old_shape[0])] + [(0, 0)] * (pool.ndim - 1)
        return jnp.pad(pool, pad)
