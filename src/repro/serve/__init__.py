"""repro.serve — continuous-batching inference on the symmetric heap.

The first end-to-end serving workload on top of the framework's POSH
substrate: a paged KV cache whose pages are fixed-size blocks carved
from the ``SymmetricHeap`` (so block tables are plain offset arrays
valid on every PE — Fact 1 applied to serving), FCFS continuous
batching with preempt-by-eviction and TOKEN-BUDGETED CHUNKED PREFILL,
prefill/decode step functions that issue every collective through
``ctx.tp_comm`` (any registered backend: xla / posh / pallas), paged
decode attention via the Pallas block-table kernel, per-request
sampling (greedy / temperature / top-k / top-p) through the TP-aware
two-phase sampler with counter-based per-(rid, position) RNG streams,
cross-PE KV page migration as ``put_nbi`` one-sided writes drained
by one ``quiet()`` per scheduler tick, and LOSSLESS speculative
decoding (``serve.spec``): pluggable draft proposers verified through
a ``(B, k+1)`` prefill-machinery window with exact counter-RNG prefix
acceptance and page-granular rewind, so spec streams are bit-identical
to sequential decoding on every backend.  ``serve.disagg`` splits the
mesh into prefill/decode CELLS: finished prefills stream their pages
to a decode cell with ``put_signal_nbi`` (one signal word per handoff
ticket) and the consumer adopts on ``signal_wait_until`` — per-transfer
completion, zero tick-global quiets on the handoff path.

    from repro import serve
    eng = serve.ServeEngine(params, cfg, ctx, serve.ServeConfig())
    done = eng.run(serve.make_requests(serve.TrafficConfig()))
    eng.metrics()
"""
from .amo_router import AmoCellRouter
from .disagg import (CellRouter, CellSpec, DisaggEngine, HandoffTicket,
                     make_cells)
from .engine import LocalExec, ServeConfig, ServeEngine, make_decode_step, \
    make_prefill, make_verify
from .kv_cache import NULL_PAGE, PagedKVCache, PageMigration
from .page_pool import SymmetricPagePool
from .sampling import (GREEDY, SamplingParams, batch_state,
                       sample_from_candidates, sample_tokens,
                       sample_window_tokens)
from .scheduler import FCFSScheduler, Request, TickPlan
from .slo import PRIORITIES, SLOConfig, SLOPolicy
from .spec import (DraftModelProposer, FixedProposer, NgramProposer,
                   ReplayProposer, SpecProposer, make_proposer)
from .traffic import TrafficConfig, make_requests

__all__ = [
    "ServeConfig", "ServeEngine", "LocalExec",
    "DisaggEngine", "CellRouter", "AmoCellRouter", "CellSpec",
    "HandoffTicket", "make_cells",
    "make_decode_step", "make_prefill", "make_verify",
    "PagedKVCache", "PageMigration", "NULL_PAGE", "SymmetricPagePool",
    "FCFSScheduler", "Request", "TickPlan",
    "SLOConfig", "SLOPolicy", "PRIORITIES",
    "TrafficConfig", "make_requests",
    "SamplingParams", "GREEDY", "batch_state",
    "sample_from_candidates", "sample_tokens", "sample_window_tokens",
    "SpecProposer", "NgramProposer", "DraftModelProposer",
    "ReplayProposer", "FixedProposer", "make_proposer",
]
