"""Per-request batched sampling for the serving engine.

The sampler is **TP-aware and two-phase** (the device-resident
selection-over-partitioned-data operation GPU-aware OpenSHMEM work
singles out as the divergence magnet):

  phase 1  every vocab shard computes its local top-k
           ``(value, global-index)`` candidates
           (``repro.models.embed.tp_sample_candidates``);
  phase 2  candidate lists merge through ``ctx.tp_comm.top_k_merge``
           — one all_gather of ``k`` pairs per rank plus a replicated
           sort with a deterministic tie-break (equal values -> the
           LOWEST global vocab index), so every rank holds the same
           candidate set.  Greedy (``temperature == 0``) is exactly the
           ``k = 1`` special case (``emb.tp_argmax``).

The draw itself is a **counter-based RNG stream per sequence**: the key
is ``fold_in(fold_in(PRNGKey(seed), rid), position)``, a pure function
of the request id and the absolute position of the token being
generated.  No RNG state threads through the engine, so token streams
are invariant to

  * the communicator backend (xla / posh / pallas — asserted on the
    8-PE mesh, same style as the greedy parity suite),
  * batch composition (a request sampled alone draws the same stream
    as the same request packed in a full batch),
  * the prefill path (a chunk-completing prompt and a decode step
    sample position ``n_prompt + i`` with the same key).

Truncation (top-k / top-p) happens over the merged candidate list, so
per-request ``top_k`` must be ≤ the engine's static candidate bound
(``ServeConfig.sample_candidates``); top-p renormalizes over the
candidates, which carry all of the head mass that matters at the
temperatures serving uses.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.paged_attention import NEG_INF
from repro.models import embed as emb
from repro.parallel.ctx import ParallelCtx


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """One request's sampling policy.  ``temperature == 0`` is greedy
    (top_k/top_p are then ignored); ``top_k == 0`` disables the top-k
    cut; ``top_p == 1`` disables the nucleus cut."""

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")


GREEDY = SamplingParams()


def batch_state(reqs, max_batch: int, seed: int) -> dict:
    """Pack per-request :class:`SamplingParams` + RNG stream ids into
    the array pytree the traced step functions consume.  Host-side;
    empty batch slots sample greedily (their tokens are discarded)."""
    st = {
        "temperature": np.zeros((max_batch,), np.float32),
        "top_k": np.zeros((max_batch,), np.int32),
        "top_p": np.ones((max_batch,), np.float32),
        "rid": np.zeros((max_batch,), np.int32),
        "seed": np.int32(seed),
    }
    for i, r in enumerate(reqs):
        sp = r.sampling
        st["temperature"][i] = sp.temperature
        st["top_k"][i] = sp.top_k
        st["top_p"][i] = sp.top_p
        st["rid"][i] = r.rid
    return st


def sample_from_candidates(vals, idxs, state: dict, pos):
    """Draw one token per row from merged candidates.

    vals/idxs: (b, k) value-sorted-descending global candidates
    (identical on every TP rank after ``top_k_merge``); ``state`` the
    ``batch_state`` pytree; ``pos`` (b,) the absolute position of the
    token being GENERATED (the RNG counter).  Greedy rows take
    candidate 0 — the argmax with the lowest-index tie-break.
    """
    b, k = vals.shape
    temp = state["temperature"]
    greedy = temp <= 0.0
    t = jnp.where(greedy, 1.0, jnp.maximum(temp, 1e-6))
    logit = vals.astype(jnp.float32) / t[:, None]

    j = jnp.arange(k)[None, :]
    top_k = state["top_k"][:, None]
    logit = jnp.where((top_k > 0) & (j >= top_k), NEG_INF, logit)

    # nucleus cut on the (descending) candidate probabilities: keep the
    # smallest prefix with mass >= top_p (the first candidate always
    # survives: its preceding mass is 0)
    p = jax.nn.softmax(logit, axis=-1)
    mass_before = jnp.cumsum(p, axis=-1) - p
    logit = jnp.where(mass_before >= state["top_p"][:, None], NEG_INF, logit)

    def draw(seed, rid, position, lg):
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(seed), rid), position)
        return jax.random.categorical(key, lg)

    choice = jax.vmap(draw, in_axes=(None, 0, 0, 0))(
        state["seed"], state["rid"], pos.astype(jnp.int32), logit)
    choice = jnp.where(greedy, 0, choice)
    return jnp.take_along_axis(idxs, choice[:, None], axis=-1)[:, 0]


def sample_tokens(logits_loc, ctx: ParallelCtx, state: dict, pos,
                  n_candidates: int = 8):
    """The full two-phase sampler: local shard candidates -> merged
    global candidates -> per-sequence counter-RNG draw.  ``logits_loc``
    is the (b, V/tp) LOCAL logits shard; the returned (b,) tokens are
    identical on every rank."""
    vals, idxs = emb.tp_sample_candidates(logits_loc, ctx, n_candidates)
    return sample_from_candidates(vals, idxs, state, pos)


def sample_window_tokens(logits_loc, ctx: ParallelCtx, state: dict, pos,
                         n_candidates: int = 8):
    """The window form of :func:`sample_tokens` — what speculative
    verify uses: one draw per (sequence, window position).

    ``logits_loc`` is (b, C, V/tp) — the local logits shard at every
    position of a (b, C) token window; ``pos`` (b, C) the absolute
    position of the token being GENERATED at each window row (the RNG
    counter).  Row ``(i, j)`` draws with exactly the key a
    non-speculative decode step at that position would use —
    ``fold_in(fold_in(PRNGKey(seed), rid), pos[i, j])`` — so a verified
    window reproduces the sequential stream bit-for-bit wherever the
    fed tokens match.  Returns (b, C) tokens, identical on every rank
    (phase 2 merges through ``ctx.tp_comm.top_k_merge`` like the
    single-position path)."""
    vals, idxs = emb.tp_sample_candidates(logits_loc, ctx, n_candidates)
    b, c, k = vals.shape
    flat_state = {
        "temperature": jnp.repeat(state["temperature"], c),
        "top_k": jnp.repeat(state["top_k"], c),
        "top_p": jnp.repeat(state["top_p"], c),
        "rid": jnp.repeat(state["rid"], c),
        "seed": state["seed"],
    }
    toks = sample_from_candidates(vals.reshape(b * c, k),
                                  idxs.reshape(b * c, k),
                                  flat_state, pos.reshape(b * c))
    return toks.reshape(b, c)
