"""Lock-free symmetric page allocator (POSH §4.6 put to work).

The host-side ``PagedKVCache`` free list is a Python ``list`` — correct,
but host-serial: every cell's alloc/free funnels through one loop, the
fleet-scale bottleneck the ROADMAP names.  POSH builds its atomics and
locks directly on the shared segment; the serving analogue is this
pool: the free-list STATE moves onto symmetric counter words (carved
``SignalPad``-style from a :class:`~repro.core.heap.SymmetricHeap`) and
every transition is a queue AMO (``CommQueue.amo_nbi``), so any actor —
any PE, any cell — claims or returns pages by fetch-&-op arbitration
instead of a host round-trip.

Word layout (one ``(3 + n_pages)``-word symmetric object):

    word 0   BUMP    count of pages ever taken from the virgin region;
                     page id = 1 + fetch_add(BUMP, 1) while < n_pages
    word 1   TOP     free-stack head, tag-encoded: ``(tag << 32) | page``
                     (page 0 = empty — the null page is never free).
                     The tag increments on every successful CAS, which
                     is the classic ABA guard: a slow actor whose
                     snapshot head was popped and pushed back must fail
                     its CAS and retry (``tests/test_page_pool.py``
                     builds that exact interleaving).
    word 2   NAVAIL  frees minus allocs; ``n_free = (n_pages-1) + NAVAIL``
    word 3+p NEXT[p] stack link: the page below ``p`` (0 terminates)

Equivalence to the host LIFO list (the linearizability oracle): from a
fresh pool the stack is empty and the bump pointer grants 1, 2, 3, … —
exactly what popping ``list(range(n-1, 0, -1))`` yields; ``free(pages)``
pushes in reversed order so ``pages[0]`` lands on top — exactly
``extend(reversed(pages))`` + ``pop()``.  A single-actor op sequence is
therefore **bit-identical** to the host free list, which is what lets
``PagedKVCache.attach_pool`` swap the implementation under the serving
stack without moving a single page id.

Completion discipline: every AMO is drained by ``amo_wait`` on its own
word — the per-word linearization edge — never by a queue-global
``quiet``.  ``stats()['quiets'] == 0`` on the pool queue is a pinned
invariant (the allocator never serializes unrelated traffic).
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.core.heap import SymmetricHeap
from repro.core.ordering import CommQueue, LocalTransport
from repro.core.signals import SignalPad

W_BUMP = 0
W_TOP = 1
W_NAVAIL = 2
W_NEXT = 3

_TAG_SHIFT = 32
_PAGE_MASK = (1 << _TAG_SHIFT) - 1


class SymmetricPagePool:
    """CAS-arbitrated page free list on symmetric counter words.

    ``n_actors`` sizes the actor space (``LocalTransport`` ranks): every
    AMO targets the pool words on rank ``owner`` and actors are the
    issuing side of the pair, so concurrent actors' AMOs linearize in
    the queue's seeded delivery shuffle — the property
    ``tests/test_page_pool.py`` checks against the host-LIFO oracle.
    """

    def __init__(self, n_pages: int, *, n_actors: int = 1, owner: int = 0,
                 heap: Optional[SymmetricHeap] = None, delivery_seed=0,
                 name: str = "pool_words"):
        if n_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is the null page)")
        self.n_pages = int(n_pages)
        self._limit = int(n_pages)     # bump ceiling — grow() never
                                       # raises it (grown ids enter via
                                       # the stack, or they'd double-grant)
        self.owner = int(owner)
        self.heap = heap or SymmetricHeap(("pool",))
        # SignalPad is the word-carving path (one symmetric allocation,
        # Fact 1 offsets) — these are atomic words, not signal words,
        # but the carve is identical
        self.pad = SignalPad(self.heap, W_NEXT + self.n_pages, name=name)
        self._state = {self.pad.handle.name:
                       np.zeros((int(n_actors), self.pad.n), np.int64)}
        self.q = CommQueue("pool", self._state,
                           transport=LocalTransport(int(n_actors)),
                           delivery_seed=delivery_seed)
        self.stats = {"allocs": 0, "frees": 0, "cas_retries": 0,
                      "bump_allocs": 0, "stack_allocs": 0}

    # ------------------------------------------------------------------
    # AMO primitives — issue + per-word drain (never quiet)
    # ------------------------------------------------------------------
    def amo_issue(self, op: str, word: int, value=None, cond=None, *,
                  actor: int = 0):
        """Issue one pool-word AMO without draining (the multi-actor
        property tests interleave issues before the drain linearizes
        them).  Returns the pending :class:`NbiValue`."""
        return self.q.amo_nbi(  # shmem: deferred-drain
            self.pad.handle, op, [(int(actor), self.owner)],
            value=value, cond=cond, offset=int(word))

    def amo_drain(self, word: int) -> None:
        """Drain one word — ``amo_wait``, the AMO linearization edge."""
        self.q.amo_wait(self.pad.handle, offset=int(word))

    def _amo(self, op: str, word: int, value=None, cond=None, *,
             actor: int = 0) -> int:
        v = self.amo_issue(op, word, value, cond, actor=actor)
        self.amo_drain(word)
        return int(v.value())

    # ------------------------------------------------------------------
    # pop / push — tagged Treiber stack over bump fallback
    # ------------------------------------------------------------------
    def pop_page(self, *, actor: int = 0) -> Optional[int]:
        """Claim one page, or None when the pool is exhausted."""
        while True:
            top = self._amo("fetch", W_TOP, actor=actor)
            page, tag = top & _PAGE_MASK, top >> _TAG_SHIFT
            if page == 0:
                # stack empty: bump the virgin region.  Reserve-then-
                # undo keeps the counter conservative under contention.
                k = self._amo("fadd", W_BUMP, 1, actor=actor)
                fresh = 1 + k
                if fresh >= self._limit:
                    self._amo("fadd", W_BUMP, -1, actor=actor)
                    return None
                self._amo("fadd", W_NAVAIL, -1, actor=actor)
                self.stats["allocs"] += 1
                self.stats["bump_allocs"] += 1
                return fresh
            nxt = self._amo("fetch", W_NEXT + page, actor=actor)
            new = ((tag + 1) << _TAG_SHIFT) | nxt
            old = self._amo("cswap", W_TOP, value=new, cond=top,
                            actor=actor)
            if old == top:
                self._amo("fadd", W_NAVAIL, -1, actor=actor)
                self.stats["allocs"] += 1
                self.stats["stack_allocs"] += 1
                return page
            self.stats["cas_retries"] += 1

    def _push(self, page: int, *, actor: int = 0) -> None:
        page = int(page)
        if not 0 < page < self.n_pages:
            raise ValueError(f"page {page} outside pool [1, {self.n_pages})")
        while True:
            top = self._amo("fetch", W_TOP, actor=actor)
            # link first, THEN publish: next[page] must be settled
            # before any actor can pop through it
            self._amo("swap", W_NEXT + page, top & _PAGE_MASK,
                      actor=actor)
            new = ((top >> _TAG_SHIFT) + 1) << _TAG_SHIFT | page
            old = self._amo("cswap", W_TOP, value=new, cond=top,
                            actor=actor)
            if old == top:
                self._amo("fadd", W_NAVAIL, 1, actor=actor)
                self.stats["frees"] += 1
                return
            self.stats["cas_retries"] += 1

    def push_pages(self, pages: Sequence[int], *, actor: int = 0) -> None:
        """Return pages LIFO: ``pages[0]`` ends on top (the host list's
        ``extend(reversed(pages))`` order)."""
        for p in reversed(list(pages)):
            self._push(p, actor=actor)

    def pop_pages(self, n: int, *, actor: int = 0) -> Optional[list[int]]:
        """All-or-nothing claim of ``n`` pages.  On shortfall the taken
        pages are pushed back in pop order, restoring the pool to the
        exact pre-call state (the host list's check-then-pop)."""
        taken: list[int] = []
        for _ in range(int(n)):
            p = self.pop_page(actor=actor)
            if p is None:
                self.push_pages(taken, actor=actor)
                return None
            taken.append(p)
        return taken

    # ------------------------------------------------------------------
    # accounting
    # ------------------------------------------------------------------
    def n_free(self, *, actor: int = 0) -> int:
        """Free-page count: ``(n_pages - 1) + NAVAIL`` (NAVAIL is the
        frees-minus-allocs delta, read atomically)."""
        delta = self._amo("fetch", W_NAVAIL, actor=actor)
        return (self.n_pages - 1) + delta

    def grow_pages(self, new_ids: Sequence[int], *, actor: int = 0) -> None:
        """Admit freshly grown page ids.  The words object is
        realloc'd to cover their NEXT links, then they enter through
        the STACK (descending push so the lowest id pops first,
        matching the host ``extend(range(new_n-1, old-1, -1))``), never
        through the bump region — the ceiling stays put, or a grown id
        could be granted twice."""
        ids = sorted(int(p) for p in new_ids)
        if not ids:
            return
        self.n_pages += len(ids)
        new_len = W_NEXT + self.n_pages
        if new_len > self.pad.n:
            self.pad.handle = self.heap.realloc(self.pad.handle,
                                                (new_len,))
            self.pad.n = new_len
            # the pool drains every AMO at issue, so the queue is idle
            # here and its settled state can be widened in place
            arr = self.q._state[self.pad.handle.name]
            self.q._state[self.pad.handle.name] = np.pad(
                arr, [(0, 0), (0, new_len - arr.shape[1])])
        for p in reversed(ids):
            if not 0 < p:
                raise ValueError(f"page {p} outside pool")
            self._push(p, actor=actor)
        # the pushes bumped NAVAIL, but growth already widened the
        # n_free base (n_pages - 1): cancel one or the count inflates
        self._amo("fadd", W_NAVAIL, -len(ids), actor=actor)
        self.stats["frees"] -= len(ids)   # grow is not a free

    def queue_stats(self) -> dict:
        """The pool queue's counters — ``quiets == 0`` is the pinned
        no-global-barrier invariant."""
        return self.q.stats()
