"""repro.ckpt — sharded checkpointing with integrity hashes.

The fault-tolerance substrate (§4.7 run-time environment adaptation):
checkpoint/restart is how a TPU-pod job survives node failures.
``hotswap`` streams a new checkpoint generation into a live serving
engine between ticks (put-with-signal batches, an atomic generation
flip, zero global drains).
"""
from .checkpoint import (Checkpointer, load_checkpoint, save_checkpoint)
from .hotswap import WeightStreamer

__all__ = ["Checkpointer", "save_checkpoint", "load_checkpoint",
           "WeightStreamer"]
