"""repro.ckpt — sharded checkpointing with integrity hashes.

The fault-tolerance substrate (§4.7 run-time environment adaptation):
checkpoint/restart is how a TPU-pod job survives node failures.
"""
from .checkpoint import (Checkpointer, load_checkpoint, save_checkpoint)

__all__ = ["Checkpointer", "save_checkpoint", "load_checkpoint"]
