"""Sharded checkpoint save/restore.

Layout: <dir>/step_<n>/
    manifest.json       tree structure, shapes, dtypes, per-leaf sha256,
                        mesh shape it was saved under
    leaf_<i>.npy        one array per leaf (host-local shards on a real
                        multi-host pod; full arrays in this container)

Features needed at 1000-node scale, realized here at container scale:
  * integrity: per-leaf sha256 checked on load (detects torn writes)
  * atomicity: write to step_<n>.tmp, fsync, rename
  * async save: a background thread serializes a host snapshot while
    the step loop continues (device->host copy happens synchronously,
    which is the same contract as real async checkpointing)
  * elastic restore: a checkpoint saved under one mesh can be loaded
    under another (arrays are stored unsharded-logical; resharding is
    the caller's in_specs) — this is what elastic re-meshing uses.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _tree_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    extra: Optional[dict] = None) -> str:
    """Synchronous atomic save.  Returns the final directory path."""
    final = os.path.join(ckpt_dir, f"step_{step}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    leaves, treedef = jax.tree.flatten(tree)
    manifest = {"step": step, "n_leaves": len(leaves),
                "treedef": str(treedef), "extra": extra or {}, "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        path = os.path.join(tmp, f"leaf_{i}.npy")
        np.save(path, arr)
        with open(path, "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()
        manifest["leaves"].append({
            "idx": i, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "sha256": digest})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def load_checkpoint(ckpt_dir: str, tree_like: Any,
                    step: Optional[int] = None) -> tuple[Any, int]:
    """Load the latest (or given) step into the structure of
    ``tree_like``.  Verifies integrity; raises on corruption."""
    if step is None:
        steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                 if d.startswith("step_") and not d.endswith(".tmp")]
        if not steps:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
        step = max(steps)
    path = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = jax.tree.flatten(tree_like)
    if len(leaves) != manifest["n_leaves"]:
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, tree_like has "
            f"{len(leaves)} — structure mismatch")
    out = []
    for i, meta in enumerate(manifest["leaves"]):
        fpath = os.path.join(path, f"leaf_{i}.npy")
        with open(fpath, "rb") as f:
            raw = f.read()
        digest = hashlib.sha256(raw).hexdigest()
        if digest != meta["sha256"]:
            raise IOError(f"checkpoint leaf {i} corrupt "
                          f"(sha mismatch) in {path}")
        arr = np.load(fpath)
        out.append(arr)
    return jax.tree.unflatten(treedef, out), step


class Checkpointer:
    """Async checkpointer: snapshot to host, save on a worker thread."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(ckpt_dir, exist_ok=True)

    def save_async(self, step: int, tree: Any,
                   extra: Optional[dict] = None) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # sync snapshot

        def work():
            save_checkpoint(self.ckpt_dir, step, host_tree, extra)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.ckpt_dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s}"),
                          ignore_errors=True)

    def restore(self, tree_like: Any, step: Optional[int] = None):
        self.wait()
        return load_checkpoint(self.ckpt_dir, tree_like, step)
