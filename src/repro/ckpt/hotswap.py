"""Zero-downtime weight hot-swap over the symmetric heap.

Serving fleets roll checkpoints continuously; taking the engine down
to reload weights forfeits exactly the overlap POSH exists to prove
out (§3.2: one-sided puts complete locally and drain lazily, so data
motion rides UNDER compute).  The swap protocol here:

  1. **Stage** — the new checkpoint generation is flattened to raw
     bytes and carved into fixed-size row batches over a SECOND
     symmetric allocation (``wstage_g<N>``), leaving the serving
     weights untouched.
  2. **Stream** — each serving tick issues ONE batch as a
     ``put_signal_nbi`` to every PE and retires the PREVIOUS batch
     with a per-transfer ``signal_wait_until`` — so batch ``i`` is in
     flight while the tick that followed batch ``i-1`` computes.  No
     ``fence``/``quiet`` is ever issued on the swap queue: the whole
     stream is wrapped in a ``CommQueue.phase("swap")`` window and
     ``extra_global_drains()`` (the bench row's ``swap_extra_quiets``)
     reports the phase's fences+quiets, pinned to ZERO by the CI gate.
  3. **Flip** — once every batch has landed on every PE, a generation
     pointer word flips via ``atomic_cswap_nbi`` (one CAS per owner
     PE, drained by ``amo_wait`` on the word — still no global drain).
     The engine applies the reassembled weights at the next tick
     boundary, so ALL PEs switch generations on the same tick.

Because the sampler draws from counter-RNG streams keyed only by
``(sample_seed, rid, position)`` and the step functions take ``params``
as an explicit argument, token streams emitted after the flip are
bit-identical to a cold start on the new weights — the property
``tests/test_slo.py`` and the 8-PE ``run_slo.py`` worker pin across
xla/posh/pallas.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np

from repro.core.atomics import amo_wait, atomic_cswap_nbi
from repro.core.heap import SymmetricHeap
from repro.core.ordering import CommQueue, LocalTransport
from repro.core.signals import CMP_GE, SignalPad, signal_wait_until


def _pack(params) -> tuple:
    """Flatten a parameter pytree to one byte payload + leaf specs."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    specs, chunks = [], []
    for leaf in leaves:
        arr = np.asarray(leaf)
        specs.append((arr.shape, arr.dtype))
        chunks.append(arr.tobytes())
    payload = b"".join(chunks)
    return payload, specs, treedef


def _unpack(payload: bytes, specs, treedef):
    """Rebuild the pytree from staged bytes — the exact inverse of
    ``_pack`` (byte-exact for every dtype, which is what makes the
    post-flip streams provably cold-start-identical)."""
    leaves, off = [], 0
    for shape, dtype in specs:
        n = int(np.prod(shape, dtype=np.int64)) * dtype.itemsize
        arr = np.frombuffer(payload[off:off + n],
                            dtype=dtype).reshape(shape)
        leaves.append(jax.numpy.asarray(arr))
        off += n
    return jax.tree_util.tree_unflatten(treedef, leaves)


class WeightStreamer:
    """One in-flight hot swap: stages a new parameter generation,
    streams it between serving ticks, flips the generation pointer.

    ``step()`` is the per-tick hook (``ServeEngine.tick`` calls it
    before scheduling): it advances the stream by one batch and
    returns True on the tick the flip lands — ``result()`` then yields
    the params REASSEMBLED FROM THE STAGED SYMMETRIC BYTES (not the
    tree handed in), so what the engine serves after the flip is
    literally what crossed the wire."""

    def __init__(self, new_params, *, n_pe: int = 1, generation: int = 1,
                 chunk_rows: int = 4, row_bytes: int = 1 << 14,
                 delivery_seed: Optional[int] = 0):
        self.n_pe = max(int(n_pe), 1)
        self.generation = int(generation)
        payload, self._specs, self._treedef = _pack(new_params)
        self._nbytes = len(payload)
        self.row_bytes = int(row_bytes)
        n_rows = max(-(-self._nbytes // self.row_bytes), 1)
        buf = np.zeros((n_rows, self.row_bytes), np.uint8)
        buf.reshape(-1)[:self._nbytes] = np.frombuffer(payload, np.uint8)
        self._rows = buf

        heap = SymmetricHeap(
            ("data",), capacity_bytes=max(4 * n_rows * self.row_bytes,
                                          1 << 20))
        self.handle = heap.alloc(f"wstage_g{self.generation}",
                                 (n_rows, self.row_bytes), np.uint8)
        self.gen = heap.alloc("wgen", (1,), np.int64)
        # at most 2 batches are ever in flight (issue i, retire i-1),
        # so a small recycled pad suffices; sig values strictly grow
        # per word, waits use CMP_GE — no resets needed
        self.pad = SignalPad(heap, 4, name="wswap_sig")
        state = {
            self.handle.name: np.zeros((self.n_pe,) + self.handle.shape,
                                       np.uint8),
            self.gen.name: np.full((self.n_pe, 1), self.generation - 1,
                                   np.int64),
            self.pad.handle.name: np.zeros((self.n_pe, self.pad.n),
                                           np.int64),
        }
        self.q = CommQueue(("data",), state,
                           transport=LocalTransport(self.n_pe),
                           delivery_seed=delivery_seed)
        chunk = max(int(chunk_rows), 1)
        self._batches = [(r, min(chunk, n_rows - r))
                         for r in range(0, n_rows, chunk)]
        self._issued = 0
        self._waited = 0
        self.flipped = False
        self.stats = {"batches": len(self._batches), "bytes": self._nbytes,
                      "swap_ticks": 0, "flips": 0}

    # ------------------------------------------------------------------
    def step(self) -> bool:
        """Advance the swap by one serving tick: issue the next batch,
        retire the previous one (per-transfer wait, never a quiet),
        and — once everything has landed — flip the generation word.
        Returns True exactly once, on the flip tick."""
        if self.flipped:
            return False
        self.stats["swap_ticks"] += 1
        with self.q.phase("swap"):
            if self._issued < len(self._batches):
                self._issue(self._issued)
                self._issued += 1
                # overlap: retire only the PREVIOUS batch — the one
                # just issued stays in flight under the serving tick
                if self._waited < self._issued - 1:
                    self._wait(self._waited)
                    self._waited += 1
                return False
            while self._waited < self._issued:
                self._wait(self._waited)
                self._waited += 1
            self._flip()
        self.flipped = True
        self.stats["flips"] += 1
        return True

    def _issue(self, i: int) -> None:
        row0, n = self._batches[i]
        data = np.zeros((self.n_pe, n, self.row_bytes), np.uint8)
        data[0] = self._rows[row0:row0 + n]
        pairs = [(0, d) for d in range(self.n_pe)]
        # drained per-transfer by _wait's signal_wait_until
        self.q.put_signal_nbi(  # shmem: deferred-drain
            self.handle, data, pairs, self.pad.handle, i + 1,
            offset=row0, sig_offset=self.pad.word(i))

    def _wait(self, i: int) -> None:
        for pe in range(self.n_pe):
            signal_wait_until(self.q, self.pad.handle, CMP_GE, i + 1,
                              sig_offset=self.pad.word(i), pe=pe)

    def _flip(self) -> None:
        """CAS the generation pointer on every PE and drain the word —
        the pre-op values prove each PE flipped exactly once, from the
        old generation."""
        old = self.generation - 1
        seen = [atomic_cswap_nbi(self.q, self.gen, old, self.generation,
                                 [(0, d)])
                for d in range(self.n_pe)]
        amo_wait(self.q, self.gen, offset=0)
        for d, v in enumerate(seen):
            got = int(np.asarray(v.value()).reshape(-1)[0])
            if got != old:
                raise RuntimeError(
                    f"hot-swap flip on PE {d}: generation word was "
                    f"{got}, expected {old} — concurrent swap?")

    # ------------------------------------------------------------------
    def result(self):
        """The new parameter tree, reassembled from the STAGED bytes of
        PE 0's heap (after checking every PE staged identical bytes and
        flipped its generation word)."""
        if not self.flipped:
            raise RuntimeError("hot-swap result read before the flip")
        staged = self.q.state[self.handle.name]
        genw = self.q.state[self.gen.name]
        for pe in range(self.n_pe):
            if int(genw[pe, 0]) != self.generation:
                raise RuntimeError(f"PE {pe} generation word is "
                                   f"{int(genw[pe, 0])}, expected "
                                   f"{self.generation}")
            if pe and not np.array_equal(staged[pe], staged[0]):
                raise RuntimeError(f"PE {pe} staged bytes diverge")
        payload = staged[0].reshape(-1)[:self._nbytes].tobytes()
        return _unpack(payload, self._specs, self._treedef)

    def extra_global_drains(self) -> int:
        """Fences + quiets attributed to the swap phase — the
        ``swap_extra_quiets`` pin (contract: 0; the stream completes on
        per-transfer signal waits and the flip on a per-word amo_wait)."""
        ph = self.q.phase_stats("swap")
        return int(ph["quiets"] + ph["fences"])
