"""Serving CLI: continuous batching over the paged symmetric-heap KV
cache with seeded synthetic traffic.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b \\
        --requests 16 --rate 8 --page-tokens 8 \\
        --temperature 0.8 --top-p 0.9

Per-request sampling params ride on every Request (greedy by default;
``--temperature/--top-k/--top-p`` set the trace-wide policy, drawn
through the TP-aware two-phase sampler), and long prompts prefill in
``--prefill-chunk``-token chunks under the ``--tick-tokens`` budget so
they never stall concurrent decodes.  ``--spec-k N`` turns on
speculative decoding (N drafts verified per sequence per tick;
``--draft`` picks the proposer — the n-gram self-draft or a registry
arch as a small draft model) without changing a single output token:
acceptance is exact matching against the engine's counter-RNG draws,
so speculation only shrinks tick counts.  Prints per-request decode
traces
when --trace is set, then the throughput/latency summary.  Smoke-size
configs run on CPU; the same driver scales to a TPU mesh by
constructing the ctx from ``launch.mesh.make_ctx`` and tensor-parallel
step functions (see tests/multipe/run_serve.py for the mesh wiring).
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro import configs, serve
from repro.models import registry
from repro.parallel.ctx import ParallelCtx


def parse_slo(spec: str) -> tuple[float, float]:
    """``--slo I+B`` class-mix spec -> (interactive_frac, batch_frac);
    the remainder of the trace is best_effort."""
    try:
        i, b = spec.split("+")
        ifrac, bfrac = float(i), float(b)
    except ValueError:
        raise SystemExit(
            f"--slo wants I+B fractions (e.g. 0.5+0.25), got "
            f"{spec!r}") from None
    if ifrac < 0 or bfrac < 0 or ifrac + bfrac > 1.0 + 1e-9:
        raise SystemExit(f"--slo {spec}: fractions must be >= 0 and sum "
                         f"to <= 1")
    return ifrac, bfrac


def parse_disagg(spec: str) -> tuple[int, int]:
    """``--disagg P+D`` topology spec -> (n_prefill, n_decode)."""
    try:
        p, d = spec.split("+")
        n_prefill, n_decode = int(p), int(d)
    except ValueError:
        raise SystemExit(
            f"--disagg wants P+D (e.g. 2+2), got {spec!r}") from None
    if n_prefill < 1 or n_decode < 1:
        raise SystemExit(f"--disagg {spec}: both cell counts must be >= 1")
    return n_prefill, n_decode


def build_engine(arch: str, *, backend: str = "xla", page_tokens: int = 8,
                 n_pages: int = 64, max_batch: int = 4,
                 attn_impl: str = "ref", prefix_keep: bool = False,
                 prefill_chunk: int = 8, tick_tokens: int = 0,
                 sample_seed: int = 0, seed: int = 0, spec_k: int = 0,
                 draft: str = "ngram", disagg: str = "",
                 router: str = "host", slo=None):
    cfg = configs.get_smoke(arch)
    ctx = ParallelCtx(dp_size=1, tp_size=1, sp=False, remat=False,
                      backend=backend, param_dtype=jnp.float32,
                      compute_dtype=jnp.float32)
    api = registry.build(cfg)
    params = api.init(jax.random.PRNGKey(seed), cfg, ctx)
    scfg = serve.ServeConfig(
        page_tokens=page_tokens, n_pages=n_pages, max_batch=max_batch,
        max_seq=cfg.max_seq, prefill_chunk=prefill_chunk,
        tick_tokens=tick_tokens, attn_impl=attn_impl,
        prefix_keep=prefix_keep, sample_seed=sample_seed,
        # scfg.draft only names parameterless proposers; a draft ARCH
        # becomes an explicit DraftModelProposer below
        spec_k=spec_k, draft="ngram", slo=slo)
    if router not in ("host", "amo"):
        raise SystemExit(f"--router wants 'host' or 'amo', got {router!r}")
    if disagg:
        n_prefill, n_decode = parse_disagg(disagg)
        return serve.DisaggEngine(params, cfg, ctx, scfg,
                                  n_prefill=n_prefill,
                                  n_decode=n_decode, router=router), cfg
    if spec_k > 0 and draft != "ngram":
        # --draft <arch>: a registry-backed small draft model on the
        # same mesh and page geometry (vocabularies must match); the
        # shared PagedKVCache is built first so draft and target index
        # their pools through the same block tables
        from repro.core.heap import SymmetricHeap
        kv = serve.PagedKVCache(
            SymmetricHeap(("data",)), n_layers=cfg.n_layers,
            kv_heads=cfg.kv_per_rank(1), head_dim=cfg.head_dim,
            n_pages=n_pages, page_tokens=page_tokens)
        dcfg = configs.get_smoke(draft)
        dparams = registry.build(dcfg).init(
            jax.random.PRNGKey(seed + 1), dcfg, ctx)
        proposer = serve.DraftModelProposer(dparams, dcfg, ctx, scfg, kv,
                                            target_vocab=cfg.vocab)
        eng = serve.ServeEngine(params, cfg, ctx, scfg, kv=kv,
                                proposer=proposer)
    else:
        eng = serve.ServeEngine(params, cfg, ctx, scfg)
    if router == "amo":
        # colocated 'amo' means the page allocator: the engine's free
        # list moves onto symmetric counter words (identical page-id
        # grants, so token streams cannot move)
        eng.kv.attach_pool(serve.SymmetricPagePool(eng.kv.n_pages))
    return eng, cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--backend", default="xla",
                    help="communicator backend (xla | posh | pallas)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=8.0,
                    help="Poisson arrival rate (req/s)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--page-tokens", type=int, default=8)
    ap.add_argument("--n-pages", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--prefill-chunk", type=int, default=8,
                    help="max prompt tokens one sequence prefills per tick")
    ap.add_argument("--tick-tokens", type=int, default=0,
                    help="per-tick token budget shared by decode+prefill "
                         "(0 = max_batch + prefill_chunk)")
    ap.add_argument("--attn-impl", default="ref",
                    choices=["ref", "kernel"],
                    help="paged attention impl for decode AND the "
                         "prefill/verify windows: 'kernel' (Pallas "
                         "grid kernels; compiled on TPU, interpret "
                         "elsewhere) or 'ref' (fused jnp)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="per-request sampling temperature (0 = greedy)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="per-request top-k cut (0 = off)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="per-request nucleus cut (1 = off)")
    ap.add_argument("--sample-seed", type=int, default=0,
                    help="root of the per-(rid, position) RNG streams")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: draft tokens verified "
                         "per sequence per tick (0 = off); token "
                         "streams are unchanged, only ticks shrink")
    ap.add_argument("--draft", default="ngram",
                    help="draft proposer: 'ngram' (prompt-lookup "
                         "self-draft) or a registry arch name for a "
                         "small draft model (e.g. gemma-2b)")
    ap.add_argument("--disagg", default="",
                    help="disaggregated topology 'P+D' (e.g. 2+2): P "
                         "prefill cells + D decode cells with "
                         "put-with-signal page handoff (empty = "
                         "colocated single engine)")
    ap.add_argument("--router", default="host", choices=["host", "amo"],
                    help="scheduling control plane: 'host' (Python-loop "
                         "admission/handoff routing and page free list) "
                         "or 'amo' (lock-free: CAS-arbitrated admission "
                         "rings, claim-word mailbox slots, and a "
                         "symmetric fetch-add/CAS page pool — token "
                         "streams are bit-identical across both)")
    ap.add_argument("--slo", default="",
                    help="SLO traffic mix 'I+B' (e.g. 0.5+0.25): "
                         "fractions of interactive and batch requests, "
                         "remainder best_effort; turns on priority "
                         "admission, deadline shedding, best-effort "
                         "degradation and (with --tenant-rate) per-"
                         "tenant fairness (empty = plain FCFS)")
    ap.add_argument("--ttft", type=float, default=0.25,
                    help="interactive TTFT deadline in seconds (batch "
                         "gets 4x, best_effort 8x; 0 = no deadlines)")
    ap.add_argument("--tenants", type=int, default=1,
                    help="tenant ids drawn per request for the "
                         "fairness buckets")
    ap.add_argument("--tenant-rate", type=float, default=0.0,
                    help="per-tenant admission token-bucket refill "
                         "(tokens/tick; 0 = fairness off)")
    ap.add_argument("--hot-swap", action="store_true",
                    help="stream a second weight generation (fresh "
                         "init from seed+1000) into the live engine "
                         "during the run and flip atomically mid-"
                         "serve; swap accounting lands in metrics()"
                         "['swap']")
    ap.add_argument("--trace", action="store_true",
                    help="print the per-request decode trace")
    args = ap.parse_args()

    slo_cfg, slo_tkw = None, {}
    if args.slo:
        ifrac, bfrac = parse_slo(args.slo)
        ttft = args.ttft if args.ttft > 0 else None
        slo_cfg = serve.SLOConfig(
            ttft_interactive=ttft,
            ttft_batch=4 * ttft if ttft else None,
            ttft_best_effort=8 * ttft if ttft else None,
            tenant_rate=args.tenant_rate,
            tenant_burst=2 * args.tenant_rate)
        slo_tkw = dict(interactive_frac=ifrac, batch_frac=bfrac,
                       deadline_interactive=slo_cfg.ttft_interactive,
                       deadline_batch=slo_cfg.ttft_batch,
                       deadline_best_effort=slo_cfg.ttft_best_effort,
                       n_tenants=args.tenants)

    eng, cfg = build_engine(
        args.arch, backend=args.backend, page_tokens=args.page_tokens,
        n_pages=args.n_pages, max_batch=args.max_batch,
        attn_impl=args.attn_impl, prefill_chunk=args.prefill_chunk,
        tick_tokens=args.tick_tokens, sample_seed=args.sample_seed,
        seed=args.seed, spec_k=args.spec_k, draft=args.draft,
        disagg=args.disagg, router=args.router, slo=slo_cfg)
    tcfg = serve.TrafficConfig(n_requests=args.requests, rate=args.rate,
                               vocab=cfg.vocab, seed=args.seed,
                               temperature=args.temperature,
                               top_k=args.top_k, top_p=args.top_p,
                               **slo_tkw)
    reqs = serve.make_requests(tcfg)
    if args.hot_swap:
        ctx = getattr(eng, "ctx", None) or eng.engines[0].ctx
        new_params = registry.build(cfg).init(
            jax.random.PRNGKey(args.seed + 1000), cfg, ctx)
        eng.begin_hot_swap(new_params)
    print(f"arch={cfg.name} backend={args.backend} "
          f"pages={args.n_pages}x{args.page_tokens} "
          f"batch={args.max_batch} chunk={args.prefill_chunk} "
          f"sampling=(T={args.temperature} k={args.top_k} "
          f"p={args.top_p}) spec=(k={args.spec_k} "
          f"draft={args.draft}) "
          f"topology={args.disagg or 'colocated'} router={args.router} "
          f"requests={len(reqs)}")
    done = eng.run(reqs)
    if args.trace:
        for r in sorted(done, key=lambda r: r.rid):
            print(f"  req{r.rid}: prompt[{r.n_prompt}] "
                  f"chunks={r.prefill_chunks} -> "
                  f"{r.out[:10]}{'...' if len(r.out) > 10 else ''} "
                  f"({len(r.out)} tokens, {r.preemptions} preemptions)")
    print(json.dumps(eng.metrics(), indent=2))


if __name__ == "__main__":
    main()
