import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell
on the production mesh and extract roofline terms.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b \
      --shape train_4k [--multi-pod] [--backend xla|posh] [--json out]

The XLA_FLAGS line above MUST run before any other import (jax locks
the device count at first init) — this is the only entry point that
sees 512 placeholder devices.
"""
import argparse
import dataclasses
import json
import sys
import time
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.launch import roofline, shapes
from repro.launch.mesh import make_ctx, make_production_mesh
from repro.models import registry
from repro.parallel.ctx import smap
from repro.train.grad import combine_grads
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_state_specs
from repro.train.step import make_train_step, train_state_specs


def _sharded_sds(tree_sds, specs, mesh):
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
        tree_sds, specs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               backend: str = "xla", ce_mode: str = "vocab_parallel",
               moe_dispatch: str = "einsum", zero: int = 1,
               microbatches: int | None = None, verbose: bool = True,
               unroll: bool = False, attn_block: int | None = None,
               cfg_override=None):
    cfg = cfg_override if cfg_override is not None else configs.get(arch)
    ok, why = shapes.runs_shape(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skip", "why": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    info0 = shapes.SHAPES[shape_name]
    if attn_block is None:
        attn_block = 8192 if (unroll and info0["seq"] >= 32768) else 1024
    ctx = make_ctx(mesh, backend=backend, ce_mode=ce_mode,
                   moe_dispatch=moe_dispatch, unroll=unroll,
                   attn_block_q=attn_block, attn_block_kv=attn_block,
                   ce_chunk=16384 if unroll else 4096)
    api = registry.build(cfg)
    info = shapes.SHAPES[shape_name]
    kind = info["kind"]
    n_dev = mesh.devices.size
    mesh_name = "2x16x16" if multi_pod else "16x16"

    pspecs = api.specs(cfg, ctx)
    params_sds = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0),
                                                 cfg, ctx))
    params_in = _sharded_sds(params_sds, pspecs, mesh)
    t0 = time.time()

    if kind == "train":
        mb = 1 if unroll else (microbatches or shapes.microbatches_for(arch))
        opt_cfg = AdamWConfig(zero=zero)
        step = make_train_step(cfg, ctx, api, opt_cfg, microbatches=mb)
        sspecs = train_state_specs(cfg, ctx, api, opt_cfg)
        # adamw_init uses collectives (zero-1 chunking) -> eval under smap
        state_sds = jax.eval_shape(
            smap(lambda p: {"params": p, "opt": adamw_init(p, ctx, opt_cfg),
                            "step": jnp.zeros((), jnp.int32)},
                 mesh, (pspecs,), sspecs), params_in)
        state_in = _sharded_sds(state_sds, sspecs, mesh)
        batch_in, bspecs = shapes.train_inputs(cfg, mesh, shape_name)
        fn = smap(step, mesh, (sspecs, bspecs),
                  (sspecs, {"loss": P(), "grad_norm": P(), "step": P()}))
        lowered = jax.jit(fn, donate_argnums=(0,)).lower(state_in, batch_in)
    elif kind == "prefill":
        batch_in, bspecs = shapes.prefill_inputs(cfg, mesh, shape_name)
        dpa = shapes.dp_axes_of(mesh)

        def prefill_fn(params, batch):
            if cfg.family == "encdec":
                from repro.models import encdec
                enc = encdec.encode(params, batch["frames"], ctx, cfg)
                x = encdec.decode_train(params, batch["tokens"], enc, ctx, cfg)
                from repro.parallel.ctx import sp_gather
                return sp_gather(x, ctx, axis=1)[:, -1]
            return api.prefill(params, batch["tokens"], ctx, cfg,
                               img_embeds=batch.get("img_embeds"))

        fn = smap(prefill_fn, mesh, (pspecs, bspecs), P(dpa, None))
        lowered = jax.jit(fn).lower(params_in, batch_in)
    else:  # decode
        b_loc, max_len, replicated = shapes.decode_batch_info(
            cfg, mesh, shape_name)
        dpa = shapes.dp_axes_of(mesh)
        bspec = P(None) if replicated else P(dpa)

        state_sds = jax.eval_shape(
            lambda: api.init_decode_state(cfg, ctx, b_loc, max_len))
        # decode-state specs: batch dim sharded over dp (or replicated)
        def dspec(sd):
            nd = len(sd.shape)
            return P(*([None] * nd))
        dstate_specs = jax.tree.map(dspec, state_sds,
                                    is_leaf=lambda x: isinstance(
                                        x, jax.ShapeDtypeStruct))
        state_in = _sharded_sds(state_sds, dstate_specs, mesh)
        gb = info["global_batch"]
        tok_global = gb if not replicated else b_loc
        token_in = jax.ShapeDtypeStruct(
            (tok_global,), jnp.int32, sharding=NamedSharding(mesh, bspec))

        extra = {}
        if cfg.family == "vlm":
            ng = cfg.n_layers // cfg.cross_attn_every
            kvpr = cfg.kv_per_rank(ctx.tp_size)
            kv_sds = jax.ShapeDtypeStruct(
                (ng, b_loc, cfg.img_tokens, kvpr, cfg.head_dim),
                jnp.bfloat16)
            img_kv_specs = (P(*([None] * 5)), P(*([None] * 5)))
            img_kv_in = tuple(
                jax.ShapeDtypeStruct(kv_sds.shape, kv_sds.dtype,
                                     sharding=NamedSharding(
                                         mesh, P(*([None] * 5))))
                for _ in range(2))

            def dec_fn(params, token, state, img_kv):
                return api.decode_step(params, token, state, ctx, cfg,
                                       img_kv=img_kv)
            fn = smap(dec_fn, mesh,
                      (pspecs, bspec, dstate_specs, img_kv_specs),
                      (bspec, dstate_specs))
            lowered = jax.jit(fn, donate_argnums=(2,)).lower(
                params_in, token_in, state_in, img_kv_in)
        elif cfg.family == "encdec":
            kvpr = cfg.n_kv if cfg.attn_layout(ctx.tp_size) == "ctx" \
                else cfg.kv_per_rank(ctx.tp_size)
            enc_kv_in = tuple(
                jax.ShapeDtypeStruct(
                    (cfg.n_layers, b_loc, cfg.enc_frames, kvpr,
                     cfg.head_dim), jnp.bfloat16,
                    sharding=NamedSharding(mesh, P(*([None] * 5))))
                for _ in range(2))
            enc_kv_specs = (P(*([None] * 5)), P(*([None] * 5)))

            def dec_fn(params, token, state, enc_kv):
                from repro.models import encdec
                return encdec.decode_step(params, token, state, enc_kv,
                                          ctx, cfg)
            fn = smap(dec_fn, mesh,
                      (pspecs, bspec, dstate_specs, enc_kv_specs),
                      (bspec, dstate_specs))
            lowered = jax.jit(fn, donate_argnums=(2,)).lower(
                params_in, token_in, state_in, enc_kv_in)
        else:
            def dec_fn(params, token, state):
                return api.decode_step(params, token, state, ctx, cfg)
            fn = smap(dec_fn, mesh, (pspecs, bspec, dstate_specs),
                      (bspec, dstate_specs))
            lowered = jax.jit(fn, donate_argnums=(2,)).lower(
                params_in, token_in, state_in)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    rf = roofline.analyse(arch, shape_name, mesh_name, compiled, cfg,
                          n_dev, kind, info)
    ma = compiled.memory_analysis()
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "backend": backend, "status": "ok", "unroll": unroll,
        "t_lower_s": round(t_lower, 2), "t_compile_s": round(t_compile, 2),
        "flops_dev": rf.flops_dev, "bytes_dev": rf.bytes_dev,
        "coll_bytes_dev": rf.coll_bytes_dev,
        "compute_ms": rf.compute_s * 1e3, "memory_ms": rf.memory_s * 1e3,
        "collective_ms": rf.collective_s * 1e3, "dominant": rf.dominant,
        "model_flops": rf.model_flops, "useful_ratio": rf.useful_ratio,
        "peak_gib_dev": rf.peak_bytes_dev / 2**30,
        "temp_gib_dev": ma.temp_size_in_bytes / 2**30,
        "arg_gib_dev": ma.argument_size_in_bytes / 2**30,
        "coll_counts": rf.coll_counts,
    }
    if verbose:
        print(json.dumps({k: v for k, v in result.items()
                          if k != "coll_counts"}))
        print("  collectives:", dict(rf.coll_counts))
    return result


def _depth_points(cfg):
    """(cfg_l1, cfg_l2, units_l1, units_l2, units_full): two reduced-
    depth configs and the unit (layers or groups) counts for linear
    extrapolation.  Scan guarantees identical bodies, so flops/bytes/
    collective counts are affine in depth."""
    if cfg.family == "vlm":
        k = cfg.cross_attn_every
        return (dataclasses.replace(cfg, n_layers=k),
                dataclasses.replace(cfg, n_layers=2 * k),
                1, 2, cfg.n_layers / k)
    if cfg.family == "hybrid":
        k = cfg.shared_attn_every
        return (dataclasses.replace(cfg, n_layers=k),
                dataclasses.replace(cfg, n_layers=2 * k),
                1, 2, cfg.n_layers / k)         # 81/6 = 13.5 groups
    if cfg.family == "encdec":
        return (dataclasses.replace(cfg, n_layers=2, enc_layers=2),
                dataclasses.replace(cfg, n_layers=4, enc_layers=4),
                2, 4, cfg.n_layers)
    return (dataclasses.replace(cfg, n_layers=2),
            dataclasses.replace(cfg, n_layers=4), 2, 4, cfg.n_layers)


_EXTRAP_KEYS = ("flops_dev", "bytes_dev", "coll_bytes_dev")


def run_cell(arch, shape_name, *, multi_pod=False, backend="xla",
             ce_mode="vocab_parallel", moe_dispatch="einsum", zero=1,
             microbatches=None, verbose=False, pad_heads=None):
    """Triple dry-run:
      * two reduced-depth ACCOUNTING passes (unrolled scans, mb=1) —
        XLA cost analysis counts while bodies once, so the depth-affine
        extrapolation F(L) = F(l1) + (L-l1)·(F(l2)-F(l1))/(l2-l1)
        recovers full-depth FLOPs / bytes / collective traffic exactly
        (scan bodies are identical by construction);
      * one full-depth MEMORY pass (production scans/microbatching) —
        true peak bytes per device.
    """
    cfg = configs.get(arch)
    if pad_heads:
        cfg = dataclasses.replace(cfg, n_heads=pad_heads)
    ok, why = shapes.runs_shape(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skip", "why": why}
    c1, c2, u1, u2, u_full = _depth_points(cfg)
    kw = dict(multi_pod=multi_pod, backend=backend, ce_mode=ce_mode,
              moe_dispatch=moe_dispatch, zero=zero,
              microbatches=microbatches, verbose=False)
    if pad_heads:
        c1 = dataclasses.replace(c1, n_heads=pad_heads)
        c2 = dataclasses.replace(c2, n_heads=pad_heads)
    a1 = lower_cell(arch, shape_name, unroll=True, cfg_override=c1, **kw)
    if a1.get("status") != "ok":
        return a1
    a2 = lower_cell(arch, shape_name, unroll=True, cfg_override=c2, **kw)
    kind0 = shapes.SHAPES[shape_name]["kind"]
    # memory pass only where the production config differs structurally
    # from the accounting passes (train: microbatching).  decode/prefill
    # peaks are depth-affine (params + caches scale with L, transients
    # constant) and extrapolate from the accounting passes.
    mem = lower_cell(arch, shape_name, unroll=False, **kw)         if kind0 == "train" else None

    out = dict(a1)
    scale = (u_full - u1) / (u2 - u1)
    for key in _EXTRAP_KEYS:
        out[key] = a1[key] + (a2[key] - a1[key]) * scale
    cc = {}
    for k in set(a1["coll_counts"]) | set(a2["coll_counts"]):
        v1 = a1["coll_counts"].get(k, 0)
        v2 = a2["coll_counts"].get(k, 0)
        cc[k] = int(round(v1 + (v2 - v1) * scale))
    out["coll_counts"] = cc
    out["compute_ms"] = out["flops_dev"] / roofline.PEAK_FLOPS * 1e3
    out["memory_ms"] = out["bytes_dev"] / roofline.HBM_BW * 1e3
    out["collective_ms"] = out["coll_bytes_dev"] / roofline.LINK_BW * 1e3
    out["dominant"] = max(
        [("compute", out["compute_ms"]), ("memory", out["memory_ms"]),
         ("collective", out["collective_ms"])], key=lambda kv: kv[1])[0]
    # model flops with the FULL config
    info = shapes.SHAPES[shape_name]
    gb, t = info["global_batch"], info["seq"]
    if info["kind"] == "train":
        mf = roofline.model_flops_train(cfg, gb * t)
    elif info["kind"] == "prefill":
        mf = 2.0 * cfg.active_param_count() * gb * t
    else:
        mf = roofline.model_flops_decode(cfg, gb, t)
    n_dev = 512 if multi_pod else 256
    out["model_flops"] = mf
    out["useful_ratio"] = mf / max(out["flops_dev"] * n_dev, 1.0)
    if mem is not None:
        out["peak_gib_dev"] = mem["peak_gib_dev"]
        out["temp_gib_dev"] = mem["temp_gib_dev"]
        out["arg_gib_dev"] = mem["arg_gib_dev"]
        out["t_compile_mem_s"] = mem["t_compile_s"]
    else:
        for key in ("peak_gib_dev", "temp_gib_dev", "arg_gib_dev"):
            out[key] = a1[key] + (a2[key] - a1[key]) * scale
        out["t_compile_mem_s"] = 0.0
    out["extrapolated_from"] = [u1, u2, u_full]
    if verbose:
        print(json.dumps({k: v for k, v in out.items()
                          if k != "coll_counts"}))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True,
                    choices=list(shapes.SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--backend", default="xla", choices=["xla", "posh"])
    ap.add_argument("--ce-mode", default="vocab_parallel",
                    choices=["vocab_parallel", "gathered"])
    ap.add_argument("--moe-dispatch", default="einsum",
                    choices=["einsum", "alltoall"])
    ap.add_argument("--zero", type=int, default=1, choices=[0, 1])
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--json", default=None, help="append result JSONL here")
    ap.add_argument("--pad-heads", type=int, default=None,
                    help="pad query heads to this count (zero-padded heads "
                         "are function-preserving; switches ctx->head "
                         "attention layout when divisible by TP)")
    ap.add_argument("--single", action="store_true",
                    help="single accounting-only pass (no memory pass)")
    args = ap.parse_args()
    if args.single:
        res = lower_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                         backend=args.backend, ce_mode=args.ce_mode,
                         moe_dispatch=args.moe_dispatch, zero=args.zero,
                         microbatches=args.microbatches, unroll=True)
    else:
        res = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                       backend=args.backend, ce_mode=args.ce_mode,
                       moe_dispatch=args.moe_dispatch, zero=args.zero,
                       microbatches=args.microbatches, verbose=True,
                       pad_heads=args.pad_heads)
    if args.json:
        with open(args.json, "a") as f:
            f.write(json.dumps(res) + "\n")


if __name__ == "__main__":
    main()
