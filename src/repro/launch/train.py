"""Production training driver: mesh from the available devices, POSH
backend, ZeRO-1 optimizer, checkpoint/restart, straggler accounting.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b \
        --smoke --steps 50 --ckpt-dir /tmp/ck

On a real pod this runs under one process per host with
jax.distributed.initialize(); in this container it runs single-device
(the step function is IDENTICAL — only the mesh differs).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat, configs
from repro.ckpt import Checkpointer
from repro.data import SyntheticLM, batch_specs
from repro.ft import StragglerPolicy
from repro.models import registry
from repro.parallel.ctx import ParallelCtx, smap
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.step import make_train_step, train_state_specs


def build_mesh():
    n = len(jax.devices())
    # squarest (data, model) factorization of the available devices
    best = (n, 1)
    for m in range(1, int(n ** 0.5) + 1):
        if n % m == 0:
            best = (n // m, m)
    return compat.make_mesh(best, ("data", "model"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced-config variant")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--backend", default="posh", choices=["posh", "xla"])
    ap.add_argument("--zero", type=int, default=0, choices=[0, 1])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--bucket-bytes", type=int, default=0,
                    help="DP grad bucketing (0 = per-leaf reductions)")
    ap.add_argument("--overlap-grad-sync", action="store_true",
                    help="issue DP reductions nonblocking and drain "
                         "with one quiet() before the optimizer "
                         "(paper §3.2 overlap; bit-identical losses)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke \
        else configs.get(args.arch)
    mesh = build_mesh()
    dp, tp = mesh.devices.shape
    ctx = ParallelCtx.from_mesh(mesh, sp=tp > 1, remat=True,
                                backend=args.backend,
                                param_dtype=jnp.float32,
                                compute_dtype=jnp.float32)
    api = registry.build(cfg)
    opt = AdamWConfig(lr=args.lr, zero=args.zero)
    sspecs = train_state_specs(cfg, ctx, api, opt)

    params = api.init(jax.random.PRNGKey(0), cfg, ctx)
    opt_state = smap(lambda p: adamw_init(p, ctx, opt), mesh,
                     (api.specs(cfg, ctx),), sspecs["opt"])(params)
    state = {"params": params, "opt": opt_state,
             "step": jnp.zeros((), jnp.int32)}
    ck = Checkpointer(args.ckpt_dir, keep=3)
    start = 0
    if args.resume:
        state, start = ck.restore(state)
        print(f"resumed at step {start}")

    step_fn = jax.jit(smap(
        make_train_step(cfg, ctx, api, opt, microbatches=args.microbatches,
                        bucket_bytes=args.bucket_bytes,
                        overlap_grad_sync=args.overlap_grad_sync),
        mesh, (sspecs, {"tokens": P("data")}),
        (sspecs, {"loss": P(), "grad_norm": P(), "step": P()})))
    data = SyntheticLM(vocab=cfg.vocab, seq_len=cfg.max_seq,
                       global_batch=args.global_batch)
    straggler = StragglerPolicy(deadline_s=600.0)
    print(f"mesh {mesh.devices.shape} backend={args.backend} "
          f"zero={args.zero} arch={cfg.name}")
    for s in range(start, args.steps):
        t0 = time.time()
        state, m = step_fn(state, data.batch(s, dp_rank=0, dp_size=1))
        jax.block_until_ready(m["loss"])
        dt = time.time() - t0
        straggler.record(0, dt)
        if s % 5 == 0 or s == args.steps - 1:
            print(f"step {s:4d}  loss {float(m['loss']):.4f}  {dt:.2f}s")
        if (s + 1) % args.ckpt_every == 0:
            ck.save_async(s + 1, state)
    ck.wait()
    print("training complete")


if __name__ == "__main__":
    main()
