"""Roofline-term extraction from a compiled dry-run artifact.

Terms (TPU v5e constants; per-device quantities over per-chip rates):

  compute_s    = HLO_FLOPs_per_device / 197e12      (bf16 MXU peak)
  memory_s     = HLO_bytes_per_device / 819e9       (HBM bw)
  collective_s = collective_bytes_per_device / 50e9 (per-link ICI bw)

``cost_analysis()`` is per-device (verified empirically in DESIGN.md
§10).  collective bytes are parsed from the compiled HLO text: the sum
of OUTPUT buffer bytes of every all-gather / all-reduce / reduce-scatter
/ all-to-all / collective-permute op (a per-device received-bytes upper
bound; ring decompositions make their round traffic explicit).
"""
from __future__ import annotations

import dataclasses
import re
from collections import Counter

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\][^\s]*))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[-a-z]*\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> tuple[int, Counter]:
    """Sum output-buffer bytes of collective ops; also per-op counts."""
    total = 0
    counts: Counter = Counter()
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, op = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        total += b
        counts[op] += 1
        counts[op + "_bytes"] += b
    return total, counts


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    flops_dev: float
    bytes_dev: float
    coll_bytes_dev: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_ratio: float           # MODEL_FLOPS / (flops_dev * n_dev)
    peak_bytes_dev: float         # memory_analysis temp+args
    coll_counts: dict

    def row(self) -> str:
        return (f"| {self.arch} | {self.shape} | {self.mesh} | "
                f"{self.compute_s*1e3:.2f} | {self.memory_s*1e3:.2f} | "
                f"{self.collective_s*1e3:.2f} | **{self.dominant}** | "
                f"{self.useful_ratio:.2f} | "
                f"{self.peak_bytes_dev/2**30:.2f} |")


def model_flops_train(cfg, tokens: int) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE)."""
    n = cfg.active_param_count()
    return 6.0 * n * tokens


def model_flops_decode(cfg, new_tokens: int, context: int) -> float:
    n = cfg.active_param_count()
    flops = 2.0 * n * new_tokens
    # attention against cache
    if not cfg.rwkv_head_dim and not (cfg.ssm_state and
                                      not cfg.shared_attn_every):
        eff_ctx = min(context, cfg.swa_window or context)
        n_att = cfg.n_layers if not cfg.shared_attn_every else \
            cfg.n_layers // cfg.shared_attn_every
        flops += (2.0 * n_att * cfg.n_heads * cfg.head_dim * 2 * eff_ctx
                  * new_tokens)
    return flops


def analyse(arch, shape, mesh_name, compiled, cfg, n_dev, kind,
            shape_info) -> Roofline:
    ca = compiled.cost_analysis()
    flops_dev = float(ca.get("flops", 0.0))
    bytes_dev = float(ca.get("bytes accessed", 0.0))
    txt = compiled.as_text()
    coll_b, counts = collective_bytes(txt)
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_b / LINK_BW
    dom = max([("compute", compute_s), ("memory", memory_s),
               ("collective", collective_s)], key=lambda kv: kv[1])[0]
    gb, t = shape_info["global_batch"], shape_info["seq"]
    if kind == "train":
        mf = model_flops_train(cfg, gb * t)  # 6ND counts fwd+bwd
    elif kind == "prefill":
        mf = 2.0 * cfg.active_param_count() * gb * t
    else:
        mf = model_flops_decode(cfg, gb, t)
    ma = compiled.memory_analysis()
    # donated buffers alias their outputs — don't double count
    peak = float(ma.temp_size_in_bytes + ma.argument_size_in_bytes +
                 ma.output_size_in_bytes - ma.alias_size_in_bytes)
    useful = mf / max(flops_dev * n_dev, 1.0)
    return Roofline(arch=arch, shape=shape, mesh=mesh_name,
                    flops_dev=flops_dev, bytes_dev=bytes_dev,
                    coll_bytes_dev=float(coll_b), compute_s=compute_s,
                    memory_s=memory_s, collective_s=collective_s,
                    dominant=dom, model_flops=mf, useful_ratio=useful,
                    peak_bytes_dev=peak,
                    coll_counts={k: v for k, v in counts.items()})
