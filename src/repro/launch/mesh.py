"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single-pod: 16×16 = 256 chips (data, model);
multi-pod: 2×16×16 = 512 chips (pod, data, model) — the pod axis is the
outer DP axis (ICI within a pod, DCI across pods).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_ctx(mesh, *, comm_cfg=None, **overrides):
    """ParallelCtx derived from a mesh built by make_production_mesh
    (or any mesh whose last axis is 'model')."""
    import jax.numpy as jnp

    from repro import comm as comm_mod
    from repro.parallel.ctx import ParallelCtx

    names = mesh.axis_names
    tp_axis = names[-1]
    dp_axes = tuple(n for n in names if n != tp_axis)
    sizes = dict(zip(names, mesh.devices.shape))
    dp_size = 1
    for n in dp_axes:
        dp_size *= sizes[n]
    kw = dict(dp_axes=dp_axes, tp_axis=tp_axis, dp_size=dp_size,
              tp_size=sizes[tp_axis],
              comm=comm_cfg or comm_mod.CommConfig(),
              sp=True, remat=True,
              param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16)
    kw.update(overrides)
    return ParallelCtx(**kw)
