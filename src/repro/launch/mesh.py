"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single-pod: 16×16 = 256 chips (data, model);
multi-pod: 2×16×16 = 512 chips (pod, data, model) — the pod axis is the
outer DP axis (ICI within a pod, DCI across pods).
"""
from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_ctx(mesh, *, backend: str = "xla", **overrides):
    """ParallelCtx (and its tp/dp communicators) derived from a mesh
    built by make_production_mesh (or any mesh whose last axis is
    'model').  ``backend`` selects the communicator transport; pin
    algorithms with ``dispatch=DispatchTable.fixed(...)``."""
    import jax.numpy as jnp

    from repro.parallel.ctx import ParallelCtx

    names = mesh.axis_names
    tp_axis = overrides.pop("tp_axis", names[-1])
    dp_axes = overrides.pop("dp_axes",
                            tuple(n for n in names if n != tp_axis))
    kw = dict(backend=backend, sp=True, remat=True,
              param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16)
    kw.update(overrides)
    return ParallelCtx.from_mesh(mesh, dp_axes=dp_axes, tp_axis=tp_axis,
                                 **kw)
