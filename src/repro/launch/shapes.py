"""Assigned input-shape sets and ShapeDtypeStruct builders for the
dry-run (weak-type-correct, shardable, no device allocation).

LM shapes:   train_4k (train_step), prefill_32k (prefill),
             decode_32k (serve_step: 1 new token, 32k cache),
             long_500k (serve_step, 512k context; sub-quadratic only)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq=524288, global_batch=1),
}

# archs with quadratic full attention skip long_500k (DESIGN.md §5)
LONG_OK = {"h2o-danube-3-4b", "rwkv6-3b", "zamba2-7b"}

# per-(arch, shape) microbatch counts for train_4k (activation memory)
TRAIN_MICROBATCH = {
    "llama-3.2-vision-90b": 8,
    "qwen3-8b": 4,
    "zamba2-7b": 4,
    "default": 2,
}


def microbatches_for(arch: str) -> int:
    return TRAIN_MICROBATCH.get(arch, TRAIN_MICROBATCH["default"])


def runs_shape(cfg, shape_name: str) -> tuple[bool, str]:
    if shape_name == "long_500k" and cfg.name not in LONG_OK:
        return False, "full quadratic attention at 512k — documented skip"
    return True, ""


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def dp_axes_of(mesh):
    return tuple(n for n in mesh.axis_names if n != "model")


def train_inputs(cfg, mesh, shape_name: str):
    """(batch_sds, batch_specs) for a training step."""
    info = SHAPES[shape_name]
    dpa = dp_axes_of(mesh)
    gb, t = info["global_batch"], info["seq"]
    batch = {"tokens": _sds((gb, t + 1), jnp.int32, mesh, P(dpa))}
    specs = {"tokens": P(dpa)}
    if cfg.family == "vlm":
        batch["img_embeds"] = _sds((gb, cfg.img_tokens, cfg.d_model),
                                   jnp.bfloat16, mesh, P(dpa, None, None))
        specs["img_embeds"] = P(dpa, None, None)
    if cfg.family == "encdec":
        batch["frames"] = _sds((gb, cfg.enc_frames, cfg.d_model),
                               jnp.bfloat16, mesh, P(dpa, None, None))
        specs["frames"] = P(dpa, None, None)
    return batch, specs


def prefill_inputs(cfg, mesh, shape_name: str):
    info = SHAPES[shape_name]
    dpa = dp_axes_of(mesh)
    gb, t = info["global_batch"], info["seq"]
    batch = {"tokens": _sds((gb, t), jnp.int32, mesh, P(dpa))}
    specs = {"tokens": P(dpa)}
    if cfg.family == "vlm":
        batch["img_embeds"] = _sds((gb, cfg.img_tokens, cfg.d_model),
                                   jnp.bfloat16, mesh, P(dpa, None, None))
        specs["img_embeds"] = P(dpa, None, None)
    if cfg.family == "encdec":
        batch["frames"] = _sds((gb, cfg.enc_frames, cfg.d_model),
                               jnp.bfloat16, mesh, P(dpa, None, None))
        specs["frames"] = P(dpa, None, None)
    return batch, specs


def decode_batch_info(cfg, mesh, shape_name: str):
    """(b_local, max_len, batch_replicated) for decode state building."""
    info = SHAPES[shape_name]
    dpa = dp_axes_of(mesh)
    dp = 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for n in dpa:
        dp *= sizes[n]
    gb = info["global_batch"]
    if gb >= dp:
        return gb // dp, info["seq"], False
    return gb, info["seq"], True  # replicate small batches (long_500k b=1)
