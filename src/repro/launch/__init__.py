"""repro.launch — mesh construction, dry-run, drivers."""
