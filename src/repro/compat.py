"""repro.compat — version-portable wrappers over the handful of jax
APIs that moved between jax 0.4.x and 0.5+/0.6+.

The framework is written against the modern surface (``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``, ``jax.lax.axis_size``); this
module backfills those names on older installs so one codebase runs on
both.  Everything else in the repo imports from here instead of
hand-rolling try/except at each call site.

    make_mesh(shape, names)      jax.make_mesh, dropping axis_types when
                                 the install predates them
    shard_map(fn, mesh, ...)     jax.shard_map | experimental shard_map
                                 (check_vma= maps onto check_rep=)
    axis_size(axis) -> int       static team size inside shard_map
    axis_index(axis)             traced rank (stable, re-exported for
                                 symmetry)
"""
from __future__ import annotations

from typing import Optional, Sequence, Union

import jax

Axis = Union[str, Sequence[str]]

_HAS_NATIVE_SHARD_MAP = hasattr(jax, "shard_map")
_HAS_AXIS_TYPES = hasattr(jax.sharding, "AxisType")
_HAS_AXIS_SIZE = hasattr(jax.lax, "axis_size")


def _canon(axis: Axis):
    return axis if isinstance(axis, str) else tuple(axis)


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              devices=None, explicit: bool = False) -> "jax.sharding.Mesh":
    """``jax.make_mesh`` with Auto axis types when the install supports
    them (newer jax defaults to Explicit, which breaks shard_map-with-
    manual-collectives code written for Auto)."""
    kw = {}
    if devices is not None:
        kw["devices"] = devices
    if _HAS_AXIS_TYPES:
        at = (jax.sharding.AxisType.Explicit if explicit
              else jax.sharding.AxisType.Auto)
        kw["axis_types"] = (at,) * len(axis_names)
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kw)


def shard_map(fn, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` when available, else the 0.4.x experimental one
    (whose replication checker is called ``check_rep``)."""
    if _HAS_NATIVE_SHARD_MAP:
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


def axis_size(axis: Axis) -> int:
    """Static size of a (possibly multi-) mesh axis, callable inside
    shard_map at trace time.  On old jax ``lax.axis_size`` does not
    exist; ``psum(1, axis)`` constant-folds to the same static int."""
    ax = _canon(axis)
    if _HAS_AXIS_SIZE:
        return int(jax.lax.axis_size(ax))
    return int(jax.lax.psum(1, ax))


def axis_index(axis: Axis):
    return jax.lax.axis_index(_canon(axis))
