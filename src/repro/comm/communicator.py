"""First-class communicators: team-bound collective objects with
size-aware algorithm dispatch and per-op instrumentation.

This is the POSH §4.5 story made explicit in the API: a
``Communicator`` binds a *team* (an ordered set of mesh axes, flattened
to one PE space), a *backend* (how collectives are realized), and a
*dispatch table* (which algorithm each call uses, chosen per call from
payload bytes and team size — the paper's tuned algorithm selection,
§4.5.4, promoted from a compile-flag to a first-class object).  Every
call records what it did, so tests and benchmarks can read back call
counts, bytes moved, and chosen algorithms as a plain-dict pytree.

Backends are pluggable through a registry::

    register_backend("my_backend", MyBackendClass)
    comm = Communicator("model", size=8, backend="my_backend")

Three ship in-tree (the backend matrix; see ROADMAP.md):

    "xla"    native lax collectives — the GASNet/UPC role from the
             paper's §5.3 comparison and the beyond-paper baseline.
             Dispatch always resolves to the single "xla" algorithm.
    "posh"   the paper's put/get-based schedules from ``repro.core``,
             with the algorithm chosen per call by the dispatch table
             (eager/latency-optimal below the size threshold,
             chunked-ring/bandwidth-optimal above it).
    "pallas" the posh schedules with the Pallas ``symm_copy`` engine as
             the payload transport: every p2p round's payload moves
             through a grid-pipelined tiled kernel copy; with a bound
             heap the staged chunks belong to the schedules' Lemma-1
             symmetric scratch (``repro.comm.pallas_backend``,
             registered on package import).

Construction is trace-time-static: ``size`` must be the static team
size (mesh-derived).  Methods are called *inside* ``shard_map`` like
the free functions they replace.  Instrumentation is trace-time too —
counts reflect collectives baked into the traced program (the quantity
that matters for schedule accounting), not per-step executions.
"""
from __future__ import annotations

import copy
import dataclasses
from typing import Callable, Dict, Optional, Type

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro import core as posh
from repro.core.teams import Team, TeamAxes

# ======================================================================
# dispatch table — (op, payload bytes, team size) -> algorithm
# ======================================================================

# Default size thresholds.  benchmarks/comm_microbench.py sweeps every
# (op, algo, size) cell and writes the measured crossovers to
# BENCH_comm.json; on the 8-fake-PE CPU sim the latest sweep measured
# the eager/chunked crossover at 256 KiB (allreduce) and 4 KiB
# (allgather) — but fake-device links are not bandwidth-limited, which
# flatters the O(log n · B) eager schedules.  The defaults below keep
# the paper's bandwidth-model crossover (§4.5.4: ring wins once the
# 2(n-1)/n·B wire term dominates the per-round latency, i.e. tens of
# KiB on real links); deployments tune with
# DispatchTable.tuned_from_bench(json.load(open("BENCH_comm.json"))).
_ALLREDUCE_SMALL_BYTES = 16 << 10     # ≤ 16 KiB/PE -> eager (tree/rd)
_ALLGATHER_SMALL_BYTES = 32 << 10     # ≤ 32 KiB/PE -> recursive doubling


@dataclasses.dataclass(frozen=True)
class DispatchTable:
    """Maps (op, payload nbytes, team size) to a schedule name.

    Two regimes per sized op, the paper's §4.5.4 split:

      eager    latency-optimal: O(log n) rounds of full payloads
               (binomial tree / recursive doubling).  Wins when the
               per-round launch latency dominates, i.e. small payloads
               or tiny teams.
      chunked  bandwidth-optimal: ring schedules moving 2(n-1)/n of the
               payload per PE in 1/n-size chunks.  Wins at large
               payloads.

    ``small_team_max`` short-circuits to eager for teams at or below
    that size regardless of bytes (a 2-PE "ring" is just a worse tree).
    Thresholds are payload bytes *per PE* at the call site.
    """

    allreduce_small_bytes: int = _ALLREDUCE_SMALL_BYTES
    allgather_small_bytes: int = _ALLGATHER_SMALL_BYTES
    small_team_max: int = 2
    allreduce_eager: str = "tree"
    allreduce_chunked: str = "ring"
    allgather_eager: str = "recursive_doubling"
    allgather_chunked: str = "ring"
    reducescatter_algo: str = "ring"
    alltoall_algo: str = "pairwise"
    broadcast_algo: str = "binomial"

    def choose(self, op: str, nbytes: int, team_size: int) -> str:
        """Schedule for one call.  Static (trace-time) decision."""
        pow2 = team_size & (team_size - 1) == 0
        if op in ("psum", "pmax"):
            eager = (team_size <= self.small_team_max
                     or nbytes <= self.allreduce_small_bytes)
            algo = self.allreduce_eager if eager else self.allreduce_chunked
            if algo == "recursive_doubling" and not pow2:
                # rd needs a power-of-two team; fall back to the chunked
                # ring like repro.core itself does, so stats stay honest
                algo = self.allreduce_chunked
                if algo == "recursive_doubling":   # chunked pinned to rd
                    algo = "ring"
            return algo
        if op == "all_gather":
            eager = (team_size <= self.small_team_max
                     or nbytes <= self.allgather_small_bytes)
            algo = self.allgather_eager if eager else self.allgather_chunked
            if algo == "recursive_doubling" and not pow2:
                algo = self.allgather_chunked   # rd fallback, honestly
                if algo == "recursive_doubling":
                    algo = "ring"
            return algo
        if op == "psum_scatter":
            return self.reducescatter_algo
        if op == "all_to_all":
            return self.alltoall_algo
        if op == "pbroadcast":
            return self.broadcast_algo
        if op == "top_k_merge":
            # candidate merge = an all_gather of the per-rank candidate
            # lists + a replicated local sort; route by the gather rule
            return self.choose("all_gather", nbytes, team_size)
        raise KeyError(f"no dispatch rule for op '{op}'")

    @classmethod
    def fixed(cls, allreduce: str = "ring", allgather: str = "ring",
              reducescatter: str = "ring", alltoall: str = "pairwise",
              broadcast: str = "binomial") -> "DispatchTable":
        """A table pinned to one algorithm per op regardless of size —
        the old run-wide ``CommConfig`` semantics, for callers that
        want to pin a schedule (benchmarks, ablations)."""
        return cls(allreduce_eager=allreduce, allreduce_chunked=allreduce,
                   allgather_eager=allgather, allgather_chunked=allgather,
                   reducescatter_algo=reducescatter, alltoall_algo=alltoall,
                   broadcast_algo=broadcast)

    @classmethod
    def tuned_from_bench(cls, bench: dict) -> "DispatchTable":
        """Build a table whose thresholds are the measured eager/chunked
        crossover from a ``BENCH_comm.json`` dict (as written by
        benchmarks/comm_microbench.py): the largest measured size at
        which the eager schedule still wins, 0 if it never wins (all
        sizes go chunked), and the op's default when the bench has no
        row with both algorithms."""
        def crossover(op, eager, chunked, default):
            rows = [r for r in bench.get("results", [])
                    if r["op"] == op and r["algo"] in (eager, chunked)]
            by_size: dict[int, dict[str, float]] = {}
            for r in rows:
                by_size.setdefault(r["nbytes"], {})[r["algo"]] = r["us_per_call"]
            measured = [nb for nb, t in by_size.items()
                        if eager in t and chunked in t]
            if not measured:
                return default
            best = 0                       # eager never wins -> all chunked
            for nb in sorted(measured):
                t = by_size[nb]
                if t[eager] <= t[chunked]:
                    best = nb              # largest size where eager wins
            return best
        return cls(
            allreduce_small_bytes=crossover(
                "psum", "tree", "ring", _ALLREDUCE_SMALL_BYTES),
            allgather_small_bytes=crossover(
                "all_gather", "recursive_doubling", "ring",
                _ALLGATHER_SMALL_BYTES))


# ======================================================================
# backend registry
# ======================================================================
class CommBackend:
    """Interface a communicator backend implements.

    All array arguments are per-PE shards inside ``shard_map``; ``team``
    is a ``repro.core.Team``; ``algo`` is the dispatch table's choice
    (backends may interpret or ignore it).  Implementations must match
    the lax collective semantics documented on ``Communicator``.
    """

    name: str = "?"

    def select(self, op: str, nbytes: int, team_size: int,
               table: DispatchTable) -> str:
        return table.choose(op, nbytes, team_size)

    # -- collectives ---------------------------------------------------
    def psum(self, x, team: Team, algo: str, heap=None):
        raise NotImplementedError

    def pmax(self, x, team: Team, algo: str):
        raise NotImplementedError

    def all_gather(self, x, team: Team, algo: str, *, gather_axis: int,
                   tiled: bool):
        raise NotImplementedError

    def psum_scatter(self, x, team: Team, algo: str, *, scatter_axis: int):
        raise NotImplementedError

    def all_to_all(self, x, team: Team, algo: str, *, split_axis: int,
                   concat_axis: int, team_size: int):
        raise NotImplementedError

    def pbroadcast(self, x, root: int, team: Team, algo: str):
        raise NotImplementedError


class XlaBackend(CommBackend):
    """Native lax collectives — the §5.3 'vendor library' role."""

    name = "xla"

    def select(self, op, nbytes, team_size, table):
        return "xla"

    def psum(self, x, team, algo, heap=None):
        return jax.lax.psum(x, team.axis_name)

    def pmax(self, x, team, algo):
        return jax.lax.pmax(x, team.axis_name)

    def all_gather(self, x, team, algo, *, gather_axis, tiled):
        return jax.lax.all_gather(x, team.axis_name, axis=gather_axis,
                                  tiled=tiled)

    def psum_scatter(self, x, team, algo, *, scatter_axis):
        return jax.lax.psum_scatter(x, team.axis_name,
                                    scatter_dimension=scatter_axis,
                                    tiled=True)

    def all_to_all(self, x, team, algo, *, split_axis, concat_axis,
                   team_size):
        return jax.lax.all_to_all(x, team.axis_name, split_axis=split_axis,
                                  concat_axis=concat_axis, tiled=True)

    def pbroadcast(self, x, root, team, algo):
        return posh.broadcast(x, root, team.axes, "xla")


class PoshBackend(CommBackend):
    """The paper's put/get schedules (repro.core), algorithm per call."""

    name = "posh"

    def psum(self, x, team, algo, heap=None):
        return posh.allreduce(x, "sum", team.axes, algo, heap=heap)

    def pmax(self, x, team, algo):
        return posh.allreduce(x, "max", team.axes, algo)

    def all_gather(self, x, team, algo, *, gather_axis, tiled):
        if not tiled:
            out = posh.fcollect(x, team.axes, algo)       # (n, *x.shape)
            return jnp.moveaxis(out, 0, gather_axis)
        moved = jnp.moveaxis(x, gather_axis, 0)
        out = posh.fcollect(moved, team.axes, algo)
        out = out.reshape((-1,) + moved.shape[1:])
        return jnp.moveaxis(out, 0, gather_axis)

    def psum_scatter(self, x, team, algo, *, scatter_axis):
        moved = jnp.moveaxis(x, scatter_axis, 0)
        out = posh.reduce_scatter(moved, "sum", team.axes, algo)
        return jnp.moveaxis(out, 0, scatter_axis)

    def all_to_all(self, x, team, algo, *, split_axis, concat_axis,
                   team_size):
        n = team_size
        moved = jnp.moveaxis(x, split_axis, 0)
        blocks = moved.reshape((n, moved.shape[0] // n) + moved.shape[1:])
        recv = posh.alltoall(blocks, team.axes, algo)
        parts = [jnp.moveaxis(recv[j], 0, split_axis) for j in range(n)]
        return jnp.concatenate(parts, axis=concat_axis)

    def pbroadcast(self, x, root, team, algo):
        return posh.broadcast(x, root, team.axes, algo)


_REGISTRY: Dict[str, Type[CommBackend]] = {}


def register_backend(name: str, backend_cls: Type[CommBackend], *,
                     overwrite: bool = False) -> None:
    """Register a communicator backend class under ``name`` — the hook a
    future pallas ``symm_copy`` backend (or any out-of-tree transport)
    uses to become constructible via ``Communicator(..., backend=name)``."""
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"comm backend '{name}' already registered")
    _REGISTRY[name] = backend_cls


def get_backend(name: str) -> CommBackend:
    try:
        return _REGISTRY[name]()
    except KeyError:
        raise ValueError(
            f"unknown comm backend '{name}' "
            f"(registered: {sorted(_REGISTRY)})") from None


def available_backends() -> tuple:
    return tuple(sorted(_REGISTRY))


register_backend("xla", XlaBackend)
register_backend("posh", PoshBackend)


# ======================================================================
# the communicator
# ======================================================================
def _nbytes(x) -> int:
    return int(np.prod(jnp.shape(x), dtype=np.int64)
               * jnp.dtype(jnp.result_type(x)).itemsize)


def merge_candidates(vals, idxs, k: int):
    """Merge ``(value, global-index)`` candidate lists along the last
    axis into the top ``k`` by value descending, ties broken toward the
    LOWEST global index (the tie-break every backend must agree on for
    sampled token streams to be backend-invariant).

    Pure function of its inputs — the merge kernel of ``top_k_merge``.
    (The per-shard phase, ``repro.models.embed.tp_sample_candidates``,
    gets the same tie-break from ``jax.lax.top_k``'s documented
    lower-index-first behavior; the mesh parity suite pins both against
    each other, ``tests/multipe/run_serve.py``.)
    """
    k = min(int(k), vals.shape[-1])
    # lexicographic (-value, index) via two stable argsorts: index
    # ascending first, then value descending preserves index order
    # among equal values
    o0 = jnp.argsort(idxs, axis=-1, stable=True)
    v = jnp.take_along_axis(vals, o0, axis=-1)
    i = jnp.take_along_axis(idxs, o0, axis=-1)
    o1 = jnp.argsort(-v, axis=-1, stable=True)
    return (jnp.take_along_axis(v, o1, axis=-1)[..., :k],
            jnp.take_along_axis(i, o1, axis=-1)[..., :k])


_LEAF_DEF = jax.tree.structure(0)


def _is_single(x) -> bool:
    """True when ``x`` is one array/scalar, not a pytree of them."""
    return jax.tree.structure(x) == _LEAF_DEF


class Communicator:
    """A team-bound collective endpoint.

    Method semantics match the lax collectives they replace:

        psum(x) / pmax(x)                 full allreduce over the team
        pmean(x)                          psum / team size
        all_gather(x, axis=0, tiled)      tiled concatenates along
                                          ``axis``; tiled=False inserts
                                          a new stacked axis at ``axis``
                                          (exactly lax.all_gather)
        psum_scatter(x, axis=0)           reduce + scatter chunks of
                                          ``axis`` (lax tiled semantics)
        all_to_all(x, split_axis, concat_axis)
                                          lax.all_to_all(tiled=True)
        pbroadcast(x, root)               root's value to all members
        rank() / size                     traced rank / static team size

    A team of one PE short-circuits every op to the identity (recorded
    in stats under the "identity" algorithm), so unconditional calls are
    free on degenerate axes — call sites need no ``if tp > 1`` guards.

    Mutable state is instrumentation only; everything the traced program
    depends on (team, size, backend, dispatch) is frozen, and equality/
    hashing covers exactly that static part so communicators can ride in
    ``jax.custom_vjp`` nondiff arguments.
    """

    def __init__(self, team: TeamAxes, *, size: int, backend: str = "xla",
                 dispatch: Optional[DispatchTable] = None,
                 heap: Optional[posh.SymmetricHeap] = None,
                 name: Optional[str] = None):
        self.team = Team.of(team)
        self.size = int(size)
        if self.size < 1:
            raise ValueError(f"communicator team size must be ≥1, got {size}")
        self.backend_name = backend
        self.backend = get_backend(backend)
        self.dispatch = dispatch or DispatchTable()
        self.heap = heap
        self.name = name or f"{backend}:{'x'.join(self.team.axes)}"
        self._stats: dict = {}

    # -- identity / hashing (static part only; the heap participates by
    #    identity because its allocations are baked into the trace) ----
    def _key(self):
        return (self.backend_name, self.team.axes, self.size, self.dispatch,
                id(self.heap) if self.heap is not None else None)

    def __eq__(self, other):
        return isinstance(other, Communicator) and self._key() == other._key()

    def __hash__(self):
        return hash(self._key())

    def __repr__(self):
        return (f"Communicator({self.name!r}, axes={self.team.axes}, "
                f"size={self.size}, backend={self.backend_name!r})")

    # -- instrumentation ----------------------------------------------
    def _record(self, op: str, nbytes: int, algo: str) -> None:
        s = self._stats.setdefault(
            op, {"calls": 0, "bytes": 0, "algos": {}})
        s["calls"] += 1
        s["bytes"] += nbytes
        s["algos"][algo] = s["algos"].get(algo, 0) + 1

    def stats(self) -> dict:
        """Per-op instrumentation as a plain-dict pytree:
        ``{op: {"calls": int, "bytes": int, "algos": {algo: count}}}``."""
        return copy.deepcopy(self._stats)

    def reset_stats(self) -> None:
        self._stats.clear()

    def _begin(self, op: str, x) -> Optional[str]:
        """Dispatch + record; returns the algorithm, or None for the
        1-PE identity short-circuit."""
        nbytes = _nbytes(x)
        if self.size == 1:
            self._record(op, nbytes, "identity")
            return None
        algo = self.backend.select(op, nbytes, self.size, self.dispatch)
        self._record(op, nbytes, algo)
        return algo

    # -- collectives ---------------------------------------------------
    def psum(self, x):
        if not _is_single(x):            # pytree: reduce (and record)
            return jax.tree.map(self.psum, x)   # each leaf by its size
        algo = self._begin("psum", x)
        if algo is None:
            return x
        return self.backend.psum(x, self.team, algo, heap=self.heap)

    def pmax(self, x):
        if not _is_single(x):
            return jax.tree.map(self.pmax, x)
        algo = self._begin("pmax", x)
        if algo is None:
            return x
        return self.backend.pmax(x, self.team, algo)

    def pmean(self, x):
        out = self.psum(x)
        if self.size == 1:
            return out
        return jax.tree.map(lambda t: t / self.size, out)

    def all_gather(self, x, axis: int = 0, *, tiled: bool = True):
        if not _is_single(x):
            return jax.tree.map(
                lambda t: self.all_gather(t, axis, tiled=tiled), x)
        algo = self._begin("all_gather", x)
        if algo is None:
            return x if tiled else jnp.expand_dims(x, axis)
        return self.backend.all_gather(x, self.team, algo,
                                       gather_axis=axis, tiled=tiled)

    def psum_scatter(self, x, axis: int = 0):
        if not _is_single(x):
            return jax.tree.map(lambda t: self.psum_scatter(t, axis), x)
        if jnp.shape(x)[axis] % self.size:
            raise ValueError(
                f"psum_scatter axis {axis} (len {jnp.shape(x)[axis]}) not "
                f"divisible by team size {self.size}")
        algo = self._begin("psum_scatter", x)
        if algo is None:
            return x
        return self.backend.psum_scatter(x, self.team, algo,
                                         scatter_axis=axis)

    def all_to_all(self, x, *, split_axis: int, concat_axis: int):
        if not _is_single(x):
            return jax.tree.map(
                lambda t: self.all_to_all(t, split_axis=split_axis,
                                          concat_axis=concat_axis), x)
        if jnp.shape(x)[split_axis] % self.size:
            raise ValueError(
                f"all_to_all split axis {split_axis} "
                f"(len {jnp.shape(x)[split_axis]}) not divisible by team "
                f"size {self.size}")
        algo = self._begin("all_to_all", x)
        if algo is None:
            return x
        return self.backend.all_to_all(x, self.team, algo,
                                       split_axis=split_axis,
                                       concat_axis=concat_axis,
                                       team_size=self.size)

    def top_k_merge(self, vals, idxs, k: int):
        """Merge per-rank ``(value, global-index)`` candidate lists
        (``(..., k_loc)``, values sorted descending per rank) into the
        global top ``k``, replicated on every rank.

        The payload moves as ONE all_gather (algorithm from the
        dispatch table's gather rule): the f32 values and the bitcast
        int32 indices ride in a single packed ``(..., 2k)`` array, so a
        sampled decode step costs one collective launch and the
        recorded bytes cover the whole payload.  The merge itself is a
        replicated local sort with the deterministic lowest-global-index
        tie-break (``merge_candidates``).  This is the phase-2 collective
        of the TP-aware sampler: phase 1 (per-shard local top-k) lives in
        ``repro.models.embed.tp_sample_candidates``.  Values come back
        as float32 (the packing width)."""
        k = int(k)
        kk = vals.shape[-1]
        packed = jnp.concatenate(
            [vals.astype(jnp.float32),
             jax.lax.bitcast_convert_type(idxs.astype(jnp.int32),
                                          jnp.float32)], axis=-1)
        algo = self._begin("top_k_merge", packed)
        if algo is None:
            return vals[..., :k], idxs[..., :k]
        # (n, ..., 2kk) stacked rank-major, then (..., n*kk) per list:
        # concat order is rank-major, so global indices stay ascending
        # among a rank's equal-valued candidates (the merge re-sorts)
        g = self.backend.all_gather(packed, self.team, algo,
                                    gather_axis=0, tiled=False)
        g = jnp.moveaxis(g, 0, -2)                   # (..., n, 2kk)
        flat = vals.shape[:-1] + (self.size * kk,)
        gv = g[..., :kk].reshape(flat)
        gi = jax.lax.bitcast_convert_type(g[..., kk:],
                                          jnp.int32).reshape(flat)
        return merge_candidates(gv, gi, k)

    def pbroadcast(self, x, root: int = 0):
        if not _is_single(x):
            return jax.tree.map(lambda t: self.pbroadcast(t, root), x)
        if not (0 <= root < self.size):
            raise ValueError(f"broadcast root {root} out of range "
                             f"for team of {self.size}")
        algo = self._begin("pbroadcast", x)
        if algo is None:
            return x
        return self.backend.pbroadcast(x, root, self.team, algo)

    # -- ordered nonblocking pipeline ----------------------------------
    def queue(self, state=None, *, delivery_seed=None, transport=None):
        """A :class:`repro.core.CommQueue` bound to this communicator's
        team: the entry point to the paper's §3.2 nonblocking model —
        ``put_nbi``/``get_nbi``/``allreduce_nbi`` enqueue,
        ``fence``/``quiet`` drain.  Pass the heap ``state`` dict
        explicitly when using ``put_nbi``/``get_nbi`` (the queue does
        not pull state off ``self.heap``); ``allreduce_nbi`` needs no
        state.  Used by the overlapped gradient path
        (``repro.train.grad.overlapped_grad_sync``)."""
        from repro.core.ordering import CommQueue
        return CommQueue(self.team, state, transport=transport,
                         delivery_seed=delivery_seed)

    # -- topology ------------------------------------------------------
    def rank(self):
        """Traced rank in the flattened team (0 on degenerate teams)."""
        if self.size == 1:
            return jnp.zeros((), jnp.int32)
        return self.team.my_pe()

    @property
    def axis_name(self):
        return self.team.axis_name

    # -- tree-level reductions (delegates; kept as methods so call
    #    sites stay on the communicator surface) -----------------------
    def tree_psum(self, tree):
        return jax.tree.map(self.psum, tree)

    def tree_pmean(self, tree):
        return jax.tree.map(self.pmean, tree)

    def bucketed_psum(self, tree, *, bucket_bytes: int = 4 << 20,
                      heap: Optional[posh.SymmetricHeap] = None):
        from .bucketing import bucketed_allreduce
        return bucketed_allreduce(tree, self, bucket_bytes=bucket_bytes,
                                  heap=heap if heap is not None else self.heap)

    def compressed_psum(self, tree, *, scheme: str = "bf16", state=None,
                        mean: bool = True):
        from .compress import compressed_allreduce
        return compressed_allreduce(tree, self, scheme=scheme, state=state,
                                    mean=mean)


def make_communicator(team: TeamAxes, *, size: Optional[int] = None,
                      backend: str = "xla",
                      dispatch: Optional[DispatchTable] = None,
                      heap: Optional[posh.SymmetricHeap] = None,
                      name: Optional[str] = None) -> Communicator:
    """Build a communicator for a team.  ``size`` is the static team
    size; omit it only when calling from inside ``shard_map``, where it
    is derived from the mesh axes."""
    if size is None:
        size = compat.axis_size(Team.of(team).axis_name)
    return Communicator(team, size=size, backend=backend, dispatch=dispatch,
                        heap=heap, name=name)
