"""The Pallas ``symm_copy`` communicator backend — the registry's third
slot, filled.

POSH's collectives bottom out in its memcpy engine: every put/get copies
the payload between private and symmetric memory through the variant
selected at compile time (§4.4).  This backend reproduces that layering
on the kernel side: it reuses the posh put/get *schedules* (ring, tree,
recursive doubling — ``repro.core.collectives``) unchanged, but installs
the grid-pipelined Pallas copy engine (``repro.kernels.symm_copy``) as
the payload stager for the duration of each collective, so **every
payload move of every p2p round goes HBM→VMEM→HBM through a tiled
kernel copy** rather than an anonymous XLA move.  The variant is chosen
per round from the round's actual payload bytes and dtype tiling
(``choose_variant``) — the paper's compile-time selection, applied at
the granularity the schedule actually moves data.

Symmetric-heap addressing rides along unchanged: when the communicator
carries a :class:`~repro.core.SymmetricHeap`, the posh ring schedule
allocates its chunk buffer as a Lemma-1 temporary symmetric allocation
(``_allreduce_ring``), so the staged payloads are chunks *of a real
symmetric object* and the registry fingerprint is unchanged after the
collective — the property the parity suite pins down.  (An actual
kernel write to the symmetric offset needs the TPU remote-DMA path;
that is the ROADMAP item, not this CPU-verifiable layer.)

Numerically the stager is an identity copy, so this backend is
bit-exact with "posh" (and parity-checked against "xla" in
``tests/multipe/run_comm_parity.py``).
"""
from __future__ import annotations

import contextlib

from repro.core import p2p

from .communicator import PoshBackend


class PallasBackend(PoshBackend):
    """posh schedules + Pallas symm_copy payload transport."""

    name = "pallas"

    def __init__(self, variant: str = "auto"):
        # "auto": per-round size/dtype dispatch; a named variant pins
        # the block shape for every round (POSH's -D flag)
        self.variant = variant

    # -- the memcpy seam ----------------------------------------------
    def _stager(self):
        from repro.kernels import ops  # deferred: pallas import is heavy
        variant = self.variant
        # "auto" resolves per payload inside the engine (size + dtype)
        return lambda payload: ops.symm_copy(payload, variant)

    @contextlib.contextmanager
    def _staged(self):
        """Scope a collective: every p2p payload through the copy
        engine (heap addressing, when a heap is bound, comes from the
        schedules' own Lemma-1 scratch — see module docstring)."""
        with p2p.staged_payloads(self._stager()):
            yield

    # -- collectives: schedules inherited, transport swapped ----------
    def psum(self, x, team, algo, heap=None):
        with self._staged():
            return super().psum(x, team, algo, heap=heap)

    def pmax(self, x, team, algo):
        with self._staged():
            return super().pmax(x, team, algo)

    def all_gather(self, x, team, algo, *, gather_axis, tiled):
        with self._staged():
            return super().all_gather(x, team, algo, gather_axis=gather_axis,
                                      tiled=tiled)

    def psum_scatter(self, x, team, algo, *, scatter_axis):
        with self._staged():
            return super().psum_scatter(x, team, algo,
                                        scatter_axis=scatter_axis)

    def all_to_all(self, x, team, algo, *, split_axis, concat_axis,
                   team_size):
        with self._staged():
            return super().all_to_all(x, team, algo, split_axis=split_axis,
                                      concat_axis=concat_axis,
                                      team_size=team_size)

    def pbroadcast(self, x, root, team, algo):
        with self._staged():
            return super().pbroadcast(x, root, team, algo)
