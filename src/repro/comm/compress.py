"""Gradient compression for DP reductions (distributed-optimization).

Two schemes, both with optional error feedback (EF-SGD style residual
accumulation so compression error does not bias the optimizer):

  * "bf16"  — cast f32 gradients to bf16 for the wire, reduce, cast back.
              Halves the collective term at <1 ulp-of-bf16 noise per step.
  * "int8"  — per-bucket affine quantization; reduction happens on the
              dequantized values after an allgather of scales (sum of
              int8 payloads would overflow, so int8 uses reduce-by-
              gather for small team sizes and falls back to bf16 for
              large ones — the tradeoff is documented in EXPERIMENTS.md).

All wire traffic routes through a ``Communicator`` (``comm.psum`` /
``comm.all_gather``); a bare axis name is accepted and builds a
default-dispatch communicator (inside shard_map only).  State is a
pytree of residuals matching the gradient tree.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from .bucketing import CommLike, as_communicator


@dataclasses.dataclass
class CompressionState:
    residual: Any  # pytree matching grads (or None)

    @classmethod
    def init(cls, grads_like: Any, enabled: bool) -> "CompressionState":
        if not enabled:
            return cls(residual=None)
        return cls(residual=jax.tree.map(jnp.zeros_like, grads_like))


def compressed_allreduce(grads: Any, comm_or_axis: CommLike, *,
                         scheme: str = "bf16",
                         state: Optional[CompressionState] = None,
                         mean: bool = True):
    """Returns (reduced_grads, new_state)."""
    comm = as_communicator(comm_or_axis)

    def _mean(x):
        return x / comm.size if mean else x

    if scheme == "none":
        out = jax.tree.map(lambda g: _mean(comm.psum(g)), grads)
        return out, state

    use_ef = state is not None and state.residual is not None

    def compress_one(g, r):
        gin = g + r if r is not None else g
        if scheme == "bf16":
            wire = gin.astype(jnp.bfloat16)
            err = gin - wire.astype(gin.dtype)
            red = comm.psum(wire).astype(gin.dtype)
            return _mean(red), err
        if scheme == "int8":
            scale = jnp.maximum(jnp.abs(gin).max(), 1e-30) / 127.0
            q = jnp.clip(jnp.round(gin / scale), -127, 127).astype(jnp.int8)
            deq = q.astype(gin.dtype) * scale
            err = gin - deq
            # gather int8 payloads + scales, combine locally
            qs = comm.all_gather(q[None], axis=0, tiled=True)
            ss = comm.all_gather(scale[None], axis=0, tiled=True)
            red = jnp.einsum("n...,n->...", qs.astype(gin.dtype), ss)
            return _mean(red), err
        raise ValueError(f"unknown compression scheme '{scheme}'")

    gl, tdef = jax.tree.flatten(grads)
    rl = jax.tree.leaves(state.residual) if use_ef else [None] * len(gl)
    pairs = [compress_one(g, r) for g, r in zip(gl, rl)]
    out = jax.tree.unflatten(tdef, [p[0] for p in pairs])
    if use_ef:
        new_res = jax.tree.unflatten(tdef, [p[1] for p in pairs])
        return out, CompressionState(residual=new_res)
    return out, state
