"""Gradient bucketing — collective-count reduction for DP reductions.

Distributed-optimization substrate: instead of one allreduce per
parameter tensor (hundreds of small latency-bound collectives), gradient
leaves are packed into fixed-size *buckets allocated in the symmetric
heap* and reduced bucket-by-bucket.  Bucketed reduction both amortizes
collective launch latency and gives XLA independent collectives it can
overlap with the backward computation (compute/comm overlap happens at
the XLA scheduling level; bucket granularity is what makes it possible).

Bucketing composes with the communicator's size-aware dispatch: packing
turns many small (eager-regime) reductions into few large ones, which
the dispatch table then routes to the chunked ring — so the two layers
tune the same knob from opposite ends.

Reductions route through a ``Communicator`` (``comm.psum``).  A bare
axis name (or axis tuple) is also accepted and builds a default-dispatch
communicator for that team — the team size is read from the enclosing
shard_map, so the bare-axis form is only valid inside one.

The bucket buffers are symmetric-heap allocations — same shape on every
PE — so the paper's Fact 1 is what guarantees the flat offsets used for
pack/unpack agree across PEs.
"""
from __future__ import annotations

from typing import Any, Optional, Union

import jax
import jax.numpy as jnp

from repro import compat
from repro import core as posh

from .communicator import Communicator, DispatchTable

CommLike = Union[Communicator, str, tuple]


def as_communicator(comm_or_axis: CommLike,
                    dispatch: Optional[DispatchTable] = None) -> Communicator:
    """Accept either a Communicator or a bare team-axis spec (the
    latter builds one per call; must run inside shard_map)."""
    if isinstance(comm_or_axis, Communicator):
        return comm_or_axis
    axis = comm_or_axis if isinstance(comm_or_axis, str) \
        else tuple(comm_or_axis)
    return Communicator(axis, size=compat.axis_size(axis),
                        dispatch=dispatch, name=f"axis:{axis}")


def leaf_metas(leaves):
    """(shape, dtype, size) per leaf — the packing metadata both the
    blocking and overlapped reduction paths derive buckets from."""
    return [(l.shape, l.dtype, l.size) for l in leaves]


def _flatten_with_meta(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef, leaf_metas(leaves)


def unpack_bucket(out, bucket, metas, reduced) -> None:
    """Split a reduced flat bucket back into its leaves (into
    ``reduced`` at the bucket's indices).  Shared by the blocking and
    overlapped paths so their pack/unpack cannot drift — the
    bit-identity the ordering suite asserts depends on it."""
    off = 0
    for i in bucket:
        shape, _, size = metas[i]
        reduced[i] = out[off:off + size].reshape(shape)
        off += size


def plan_buckets(metas, bucket_bytes: int) -> list[list[int]]:
    """The bucket plan: leaf indices grouped by dtype (order-preserving)
    and packed greedily up to ``bucket_bytes`` per bucket.  Shared by
    the blocking path below and the overlapped nonblocking path
    (``repro.train.grad.overlapped_grad_sync``) so the two issue
    byte-identical reductions — the bit-identity the ordering suite
    asserts depends on both walking this exact plan."""
    by_dtype: dict = {}
    for i, (_, dtype, _) in enumerate(metas):
        by_dtype.setdefault(jnp.dtype(dtype), []).append(i)
    plan: list[list[int]] = []
    for dtype, idxs in by_dtype.items():
        cap = max(bucket_bytes // dtype.itemsize, 1)
        bucket: list[int] = []
        cur = 0
        for i in idxs:
            if cur + metas[i][2] > cap and bucket:
                plan.append(bucket)
                bucket, cur = [], 0
            bucket.append(i)
            cur += metas[i][2]
        if bucket:
            plan.append(bucket)
    return plan


def tree_allreduce(tree: Any, comm_or_axis: CommLike):
    """Naive per-leaf allreduce (the unbucketed baseline)."""
    comm = as_communicator(comm_or_axis)
    return jax.tree.map(comm.psum, tree)


def bucketed_allreduce(tree: Any, comm_or_axis: CommLike, *,
                       bucket_bytes: int = 4 << 20,
                       heap: posh.SymmetricHeap | None = None) -> Any:
    """Pack leaves into ≤bucket_bytes flat buffers (per dtype), allreduce
    each bucket through the communicator, unpack.  Returns a tree of the
    same structure."""
    comm = as_communicator(comm_or_axis)
    leaves, treedef, metas = _flatten_with_meta(tree)
    if not leaves:
        return tree

    reduced = [None] * len(leaves)
    for bucket in plan_buckets(metas, bucket_bytes):
        flat = jnp.concatenate([leaves[i].ravel() for i in bucket])
        if heap is not None:
            with heap.scratch(flat.shape, flat.dtype, tag="grad_bucket"):
                out = comm.psum(flat)
        else:
            out = comm.psum(flat)
        unpack_bucket(out, bucket, metas, reduced)

    return jax.tree.unflatten(treedef, reduced)
