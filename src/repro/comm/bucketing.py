"""Gradient bucketing — collective-count reduction for DP reductions.

Distributed-optimization substrate: instead of one allreduce per
parameter tensor (hundreds of small latency-bound collectives), gradient
leaves are packed into fixed-size *buckets allocated in the symmetric
heap* and reduced bucket-by-bucket.  Bucketed reduction both amortizes
collective launch latency and gives XLA independent collectives it can
overlap with the backward computation (compute/comm overlap happens at
the XLA scheduling level; bucket granularity is what makes it possible).

Bucketing composes with the communicator's size-aware dispatch: packing
turns many small (eager-regime) reductions into few large ones, which
the dispatch table then routes to the chunked ring — so the two layers
tune the same knob from opposite ends.

Reductions route through a ``Communicator`` (``comm.psum``).  The old
``(tree, axis, cfg)`` calling convention is still accepted and builds a
shim communicator, like ``repro.comm.api``.

The bucket buffers are symmetric-heap allocations — same shape on every
PE — so the paper's Fact 1 is what guarantees the flat offsets used for
pack/unpack agree across PEs.
"""
from __future__ import annotations

from typing import Any, Optional, Union

import jax
import jax.numpy as jnp

from repro import core as posh

from .api import CommConfig, _shim_comm
from .communicator import Communicator

CommLike = Union[Communicator, str, tuple]


def as_communicator(comm_or_axis: CommLike,
                    cfg: Optional[CommConfig] = None) -> Communicator:
    """Accept either a Communicator (new API) or (axis, cfg) (deprecated)."""
    if isinstance(comm_or_axis, Communicator):
        return comm_or_axis
    return _shim_comm(comm_or_axis, cfg or CommConfig())


def _flatten_with_meta(tree):
    leaves, treedef = jax.tree.flatten(tree)
    metas = [(l.shape, l.dtype, l.size) for l in leaves]
    return leaves, treedef, metas


def tree_allreduce(tree: Any, comm_or_axis: CommLike,
                   cfg: Optional[CommConfig] = None):
    """Naive per-leaf allreduce (the unbucketed baseline)."""
    comm = as_communicator(comm_or_axis, cfg)
    return jax.tree.map(comm.psum, tree)


def bucketed_allreduce(tree: Any, comm_or_axis: CommLike,
                       cfg: Optional[CommConfig] = None, *,
                       bucket_bytes: int = 4 << 20,
                       heap: posh.SymmetricHeap | None = None) -> Any:
    """Pack leaves into ≤bucket_bytes flat buffers (per dtype), allreduce
    each bucket through the communicator, unpack.  Returns a tree of the
    same structure."""
    comm = as_communicator(comm_or_axis, cfg)
    leaves, treedef, metas = _flatten_with_meta(tree)
    if not leaves:
        return tree

    # group leaf indices by dtype, preserving order
    by_dtype: dict = {}
    for i, l in enumerate(leaves):
        by_dtype.setdefault(jnp.dtype(l.dtype), []).append(i)

    reduced = [None] * len(leaves)
    for dtype, idxs in by_dtype.items():
        itemsize = dtype.itemsize
        cap = max(bucket_bytes // itemsize, 1)
        bucket: list[int] = []
        cur = 0

        def flush(bucket):
            if not bucket:
                return
            flat = jnp.concatenate([leaves[i].ravel() for i in bucket])
            if heap is not None:
                with heap.scratch(flat.shape, flat.dtype, tag="grad_bucket"):
                    out = comm.psum(flat)
            else:
                out = comm.psum(flat)
            off = 0
            for i in bucket:
                shape, dt, size = metas[i]
                reduced[i] = out[off:off + size].reshape(shape)
                off += size

        for i in idxs:
            if cur + metas[i][2] > cap and bucket:
                flush(bucket)
                bucket, cur = [], 0
            bucket.append(i)
            cur += metas[i][2]
        flush(bucket)

    return jax.tree.unflatten(treedef, reduced)
