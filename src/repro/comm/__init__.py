"""repro.comm — framework-facing collective API.

Every collective issued anywhere in the framework (DP gradient
reduction, TP activation collectives, EP dispatch, SP gathers, vocab-
parallel cross-entropy) routes through this module, which dispatches to
either the paper's POSH schedules (``repro.core``) or native XLA
collectives.  The backend string is trace-time — algorithm selection
specializes the program, the paper's §4.5.4 compile-time switch.
"""
from .api import (CommConfig, all_gather, all_to_all, axis_index, axis_size,
                  pbroadcast, pmax, psum, psum_scatter)
from .bucketing import bucketed_allreduce, tree_allreduce
from .compress import CompressionState, compressed_allreduce

__all__ = [
    "CommConfig", "psum", "pmax", "all_gather", "psum_scatter", "all_to_all",
    "pbroadcast", "axis_index", "axis_size", "bucketed_allreduce", "tree_allreduce",
    "compressed_allreduce", "CompressionState",
]
