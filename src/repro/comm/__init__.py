"""repro.comm — framework-facing collective API, organised around the
first-class ``Communicator``.

A ``Communicator`` is a team-bound collective endpoint: it binds an
ordered set of mesh axes (the team), a backend from the registry
("xla" native collectives | "posh" the paper's put/get schedules |
"pallas" posh schedules over the Pallas symm_copy payload transport |
anything added via ``register_backend``), a ``DispatchTable`` that
picks each call's algorithm from (op, payload bytes, team size) — the
paper's §4.5.4 tuned selection, per call instead of per run — and
per-op instrumentation (calls, bytes, chosen algorithms) readable as a
stats pytree.

Every collective issued anywhere in the framework (DP gradient
reduction, TP activation collectives, EP dispatch, SP gathers, vocab-
parallel cross-entropy, serving decode) goes through a communicator
method::

    comm = make_communicator("model", size=8, backend="posh")
    y = comm.psum(x)                    # algorithm chosen by size
    g = comm.all_gather(x, axis=1)      # tiled concat, lax semantics
    comm.stats()                        # {"psum": {"calls": 1, ...}, ...}

Model/training code holds them on the parallel context as
``ctx.tp_comm`` / ``ctx.dp_comm`` (see ``repro.parallel.ctx``).
Selection is trace-time — the chosen algorithm specializes the program,
so there are zero run-time branches.

The pre-Communicator free functions (``psum(x, axis, cfg)``, ...) and
``CommConfig`` were deprecated when the Communicator landed (PR 1) and
DELETED two PRs later as scheduled: hold a communicator (or pass a bare
axis name to ``as_communicator``/``bucketed_allreduce``, which builds a
default-dispatch one inside shard_map).  A pinned-algorithm run is
``DispatchTable.fixed(...)``, the old ``CommConfig`` semantics.
"""
from .bucketing import as_communicator, bucketed_allreduce, tree_allreduce
from .communicator import (CommBackend, Communicator, DispatchTable,
                           available_backends, get_backend,
                           make_communicator, merge_candidates,
                           register_backend)
from .compress import CompressionState, compressed_allreduce
from .pallas_backend import PallasBackend

register_backend("pallas", PallasBackend, overwrite=True)

__all__ = [
    # first-class API
    "Communicator", "DispatchTable", "make_communicator", "as_communicator",
    "CommBackend", "PallasBackend",
    "register_backend", "get_backend", "available_backends",
    "merge_candidates",
    # tree-level reductions
    "bucketed_allreduce", "tree_allreduce",
    "compressed_allreduce", "CompressionState",
]
