"""Backend-dispatched collectives (called inside shard_map).

backend = "xla"        native lax collectives — the GASNet/UPC role from
                       the paper's §5.3 comparison, and the beyond-paper
                       performance baseline
backend = "posh"       the paper's algorithms from repro.core, with the
                       per-op algorithm chosen by this config (§4.5.4)
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Union

import jax
import jax.numpy as jnp

from repro import core as posh

Axis = Union[str, Sequence[str]]


@dataclasses.dataclass(frozen=True)
class CommConfig:
    backend: str = "xla"                 # "xla" | "posh"
    allreduce_algo: str = "ring"         # ring | tree | recursive_doubling
    allgather_algo: str = "ring"         # ring | ring_pull | recursive_doubling
    reducescatter_algo: str = "ring"
    alltoall_algo: str = "pairwise"
    broadcast_algo: str = "binomial"

    def tag(self) -> str:
        if self.backend == "xla":
            return "xla"
        return (f"posh[ar={self.allreduce_algo},ag={self.allgather_algo},"
                f"rs={self.reducescatter_algo},a2a={self.alltoall_algo}]")


XLA = CommConfig(backend="xla")
POSH_RING = CommConfig(backend="posh")
POSH_TREE = CommConfig(backend="posh", allreduce_algo="tree",
                       allgather_algo="recursive_doubling",
                       broadcast_algo="binomial")


def _axis(axis: Axis):
    return axis if isinstance(axis, str) else tuple(axis)


def psum(x, axis: Axis, cfg: CommConfig = XLA):
    if cfg.backend == "xla":
        return jax.lax.psum(x, _axis(axis))
    return posh.allreduce(x, "sum", _axis(axis), cfg.allreduce_algo)


def pmax(x, axis: Axis, cfg: CommConfig = XLA):
    if cfg.backend == "xla":
        return jax.lax.pmax(x, _axis(axis))
    return posh.allreduce(x, "max", _axis(axis), cfg.allreduce_algo)


def all_gather(x, axis: Axis, cfg: CommConfig = XLA, *, gather_axis: int = 0,
               tiled: bool = True):
    """Gather shards along ``gather_axis``.  tiled=True concatenates
    (matching lax.all_gather(tiled=True)); else stacks a new axis."""
    if cfg.backend == "xla":
        return jax.lax.all_gather(x, _axis(axis), axis=gather_axis, tiled=tiled)
    moved = jnp.moveaxis(x, gather_axis, 0)
    out = posh.fcollect(moved, _axis(axis), cfg.allgather_algo)  # (n, ...)
    if tiled:
        out = out.reshape((-1,) + moved.shape[1:])
        return jnp.moveaxis(out, 0, gather_axis)
    out = jnp.moveaxis(out, 1, 0)  # restore original leading dim first
    return jnp.moveaxis(out, 0, gather_axis)  # best-effort stack placement


def psum_scatter(x, axis: Axis, cfg: CommConfig = XLA, *, scatter_axis: int = 0):
    if cfg.backend == "xla":
        return jax.lax.psum_scatter(x, _axis(axis),
                                    scatter_dimension=scatter_axis, tiled=True)
    moved = jnp.moveaxis(x, scatter_axis, 0)
    out = posh.reduce_scatter(moved, "sum", _axis(axis), cfg.reducescatter_algo)
    return jnp.moveaxis(out, 0, scatter_axis)


def all_to_all(x, axis: Axis, cfg: CommConfig = XLA, *, split_axis: int,
               concat_axis: int):
    """lax.all_to_all(tiled) semantics: split along ``split_axis`` into n
    blocks, block j to PE j; received blocks concatenated along
    ``concat_axis``."""
    if cfg.backend == "xla":
        return jax.lax.all_to_all(x, _axis(axis), split_axis=split_axis,
                                  concat_axis=concat_axis, tiled=True)
    n = posh.team_size(_axis(axis))
    if x.shape[split_axis] % n:
        raise ValueError(
            f"all_to_all split axis {split_axis} (len {x.shape[split_axis]}) "
            f"not divisible by team size {n}")
    moved = jnp.moveaxis(x, split_axis, 0)
    blocks = moved.reshape((n, moved.shape[0] // n) + moved.shape[1:])
    recv = posh.alltoall(blocks, _axis(axis), cfg.alltoall_algo)
    parts = [jnp.moveaxis(recv[j], 0, split_axis) for j in range(n)]
    return jnp.concatenate(parts, axis=concat_axis)


def pbroadcast(x, root: int, axis: Axis, cfg: CommConfig = XLA):
    if cfg.backend == "xla":
        return posh.broadcast(x, root, _axis(axis), "xla")
    return posh.broadcast(x, root, _axis(axis), cfg.broadcast_algo)


def axis_index(axis: Axis):
    return jax.lax.axis_index(_axis(axis))


def axis_size(axis: Axis):
    return jax.lax.axis_size(_axis(axis))
