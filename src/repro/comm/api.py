"""DEPRECATED free-function collectives — thin shims over Communicator.

This was the framework's collective surface before the first-class
``Communicator`` API (see ``repro.comm.communicator``); it is kept for
one release so external examples that do ``comm.psum(x, axis, cfg)``
keep working.  Each call builds a team-bound communicator for
``(axis, cfg)`` (team size read from the enclosing shard_map) and
delegates to the corresponding method.  New code should hold a
``Communicator`` — e.g. ``ctx.tp_comm`` / ``ctx.dp_comm`` — and call
``comm.psum(x)`` directly.

``CommConfig`` survives as the shim's description of the old fixed
per-run algorithm choice; it converts to a pinned ``DispatchTable``
(``DispatchTable.fixed``), i.e. the old behaviour of one algorithm for
all sizes.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Union

from repro import compat

from .communicator import Communicator, DispatchTable

Axis = Union[str, Sequence[str]]


@dataclasses.dataclass(frozen=True)
class CommConfig:
    """DEPRECATED run-wide backend + fixed algorithm strings.  Use a
    ``Communicator`` with a ``DispatchTable`` instead."""

    backend: str = "xla"                 # "xla" | "posh"
    allreduce_algo: str = "ring"         # ring | tree | recursive_doubling
    allgather_algo: str = "ring"         # ring | ring_pull | recursive_doubling
    reducescatter_algo: str = "ring"
    alltoall_algo: str = "pairwise"
    broadcast_algo: str = "binomial"

    def tag(self) -> str:
        if self.backend == "xla":
            return "xla"
        return (f"posh[ar={self.allreduce_algo},ag={self.allgather_algo},"
                f"rs={self.reducescatter_algo},a2a={self.alltoall_algo}]")

    def dispatch_table(self) -> DispatchTable:
        """The old fixed-algorithm behaviour as a pinned table."""
        return DispatchTable.fixed(
            allreduce=self.allreduce_algo, allgather=self.allgather_algo,
            reducescatter=self.reducescatter_algo,
            alltoall=self.alltoall_algo, broadcast=self.broadcast_algo)


XLA = CommConfig(backend="xla")
POSH_RING = CommConfig(backend="posh")
POSH_TREE = CommConfig(backend="posh", allreduce_algo="tree",
                       allgather_algo="recursive_doubling",
                       broadcast_algo="binomial")


def _axis(axis: Axis):
    return axis if isinstance(axis, str) else tuple(axis)


def _shim_comm(axis: Axis, cfg: CommConfig) -> Communicator:
    """Per-call communicator for the deprecated path.  Must run inside
    shard_map (team size is read from the mesh axis)."""
    return Communicator(_axis(axis), size=compat.axis_size(_axis(axis)),
                        backend=cfg.backend, dispatch=cfg.dispatch_table(),
                        name=f"shim:{cfg.tag()}")


def psum(x, axis: Axis, cfg: CommConfig = XLA):
    return _shim_comm(axis, cfg).psum(x)


def pmax(x, axis: Axis, cfg: CommConfig = XLA):
    return _shim_comm(axis, cfg).pmax(x)


def all_gather(x, axis: Axis, cfg: CommConfig = XLA, *, gather_axis: int = 0,
               tiled: bool = True):
    """Gather shards along ``gather_axis``.  tiled=True concatenates;
    tiled=False inserts a new stacked axis at ``gather_axis`` — both
    exactly matching ``lax.all_gather``."""
    return _shim_comm(axis, cfg).all_gather(x, axis=gather_axis, tiled=tiled)


def psum_scatter(x, axis: Axis, cfg: CommConfig = XLA, *, scatter_axis: int = 0):
    return _shim_comm(axis, cfg).psum_scatter(x, axis=scatter_axis)


def all_to_all(x, axis: Axis, cfg: CommConfig = XLA, *, split_axis: int,
               concat_axis: int):
    """lax.all_to_all(tiled) semantics: split along ``split_axis`` into n
    blocks, block j to PE j; received blocks concatenated along
    ``concat_axis``."""
    return _shim_comm(axis, cfg).all_to_all(x, split_axis=split_axis,
                                            concat_axis=concat_axis)


def pbroadcast(x, root: int, axis: Axis, cfg: CommConfig = XLA):
    return _shim_comm(axis, cfg).pbroadcast(x, root)


def axis_index(axis: Axis):
    return compat.axis_index(_axis(axis))


def axis_size(axis: Axis):
    return compat.axis_size(_axis(axis))
