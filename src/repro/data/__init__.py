"""repro.data — deterministic synthetic token pipeline, shard-aware."""
from .pipeline import SyntheticLM, batch_specs, input_specs_for

__all__ = ["SyntheticLM", "batch_specs", "input_specs_for"]
