"""Deterministic synthetic data: structured Zipf-ish token streams with
an injected learnable n-gram pattern, so a few hundred steps show a
clearly decreasing loss (the quickstart/e2e-train examples assert it).

The pipeline is shard-aware: every host/device derives its batch slice
from (step, dp_rank) alone — restart-safe (fault tolerance needs the
data position to be a pure function of the step counter) and identical
regardless of how many hosts feed the pod.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    pattern_order: int = 2   # learnable bigram structure

    def _trans(self) -> np.ndarray:
        rng = np.random.default_rng(self.seed)
        # sparse-ish bigram transition table: each token has 8 likely successors
        succ = rng.integers(0, self.vocab, size=(self.vocab, 8))
        return succ

    def batch(self, step: int, dp_rank: int = 0, dp_size: int = 1,
              extra: int = 1) -> dict:
        """Local batch for this DP replica at ``step``.  extra=1 yields
        (b, seq_len+1) for next-token targets."""
        b_loc = self.global_batch // dp_size
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + dp_rank)
        succ = self._trans()
        t = self.seq_len + extra
        out = np.empty((b_loc, t), np.int32)
        cur = rng.integers(0, self.vocab, size=b_loc)
        out[:, 0] = cur
        for i in range(1, t):
            pick = rng.integers(0, 8, size=b_loc)
            noise = rng.random(b_loc) < 0.1
            nxt = succ[cur, pick]
            nxt = np.where(noise, rng.integers(0, self.vocab, size=b_loc), nxt)
            out[:, i] = nxt
            cur = nxt
        return {"tokens": jnp.asarray(out)}


def batch_specs(batch: dict, dp_axes) -> dict:
    dp = dp_axes if isinstance(dp_axes, str) else tuple(dp_axes)
    out = {}
    for k, v in batch.items():
        out[k] = P(dp, *([None] * (v.ndim - 1)))
    return out


def input_specs_for(cfg, shape_name: str, mesh_dp: int, ctx=None):
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation).
    Returns (kind, specs_dict) where kind is 'train' or 'decode'."""
    raise NotImplementedError("moved to repro.launch.shapes")
