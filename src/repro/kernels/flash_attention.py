"""Blocked (flash) attention kernel — causal / sliding-window, GQA.

This is the compute hot spot of the transformer-family architectures the
framework serves.  TPU-native design: the KV sequence is the innermost
*arbitrary* grid dimension, with the online-softmax running statistics
(m, l) and the f32 accumulator held in VMEM scratch across KV steps;
Q/K/V blocks are MXU-aligned (block_q × head_dim, block_kv × head_dim).

GQA is handled in the index maps: query head h reads KV head
h // (H // H_kv) — no KV replication in HBM.

Sliding-window attention (h2o-danube, zamba2 long-context) masks
per-element and, for fully-out-of-window KV blocks, skips the matmul via
``pl.when`` — the blocked analogue of never touching those bytes.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  sm_scale: float, causal: bool, window: int | None,
                  block_q: int, block_kv: int, n_kv: int, kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = ki * block_kv

    # Block-level relevance: causal ⇒ KV block must not start after the
    # last query row; window ⇒ KV block must not end before the window;
    # padded KV blocks (entirely ≥ kv_len) are skipped outright.
    relevant = k_start < kv_len
    if causal:
        relevant &= k_start <= q_start + block_q - 1
    if window is not None:
        relevant &= (k_start + block_kv - 1) >= (q_start - window + 1)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0].astype(jnp.float32)          # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s *= sm_scale                              # (bq, bk)

        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = cols < kv_len                       # padded KV columns
        if causal:
            mask &= cols <= rows
        if window is not None:
            mask &= cols > rows - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                        # (bq, 128) lane-replicated
        m_cur = jnp.max(s, axis=1, keepdims=True)  # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
        alpha = jnp.exp(m_prev[:, :1] - m_new[:, :1])           # (bq, 1)
        p = jnp.exp(s - m_new[:, :1])                           # (bq, bk)
        l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ki == n_kv - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    sm_scale: float | None = None, block_q: int = 128,
                    block_kv: int = 128, interpret: bool = True) -> jax.Array:
    """q: (B, H, T, D); k, v: (B, H_kv, S, D) with H % H_kv == 0.

    Returns (B, H, T, D).  T and S are padded to block multiples
    internally; padded KV columns are masked, padded Q rows sliced off.
    """
    b, h, t, d = q.shape
    _, hkv, s, _ = k.shape
    if h % hkv:
        raise ValueError(f"GQA requires H % H_kv == 0, got {h} % {hkv}")
    group = h // hkv
    sm_scale = 1.0 / math.sqrt(d) if sm_scale is None else sm_scale

    block_q = min(block_q, max(t, 8))
    block_kv = min(block_kv, max(s, 8))
    tp = -(-t // block_q) * block_q
    sp = -(-s // block_kv) * block_kv
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, tp - t), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, sp - s), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, sp - s), (0, 0)))

    qf = qp.reshape(b * h, tp, d)
    kf = kp.reshape(b * hkv, sp, d)
    vf = vp.reshape(b * hkv, sp, d)

    n_q = tp // block_q
    n_kv = sp // block_kv

    kernel = functools.partial(
        _flash_kernel, sm_scale=sm_scale, causal=causal, window=window,
        block_q=block_q, block_kv=block_kv, n_kv=n_kv, kv_len=s)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        b_idx = bh // h
        kvh = (bh % h) // group
        return (b_idx * hkv + kvh, ki, 0)

    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b * h, tp, d), q.dtype),
        grid=(b * h, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, d), q_map),
            pl.BlockSpec((1, block_kv, d), kv_map),
            pl.BlockSpec((1, block_kv, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), q_map),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, tp, d)[:, :, :t, :]
