"""Pure-jnp oracles for every kernel in this package."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

_OPS = {
    "sum": lambda a, b: a + b,
    "prod": lambda a, b: a * b,
    "max": jnp.maximum,
    "min": jnp.minimum,
}


def copy_ref(x: jax.Array) -> jax.Array:
    return jnp.array(x, copy=True)


def combine_ref(a: jax.Array, b: jax.Array, op: str = "sum") -> jax.Array:
    return _OPS[op](a, b)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int | None = None,
                  sm_scale: float | None = None) -> jax.Array:
    """Dense softmax attention with GQA broadcast — the oracle for
    flash_attention.  q: (B,H,T,D); k,v: (B,Hkv,S,D)."""
    b, h, t, d = q.shape
    _, hkv, s, _ = k.shape
    group = h // hkv
    sm_scale = 1.0 / math.sqrt(d) if sm_scale is None else sm_scale
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    scores = jnp.einsum("bhtd,bhsd->bhts", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) * sm_scale
    rows = jnp.arange(t)[:, None]
    cols = jnp.arange(s)[None, :]
    mask = jnp.ones((t, s), bool)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhts,bhsd->bhtd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)
