"""jit'd public wrappers over the Pallas kernels.

``interpret`` defaults to True in this container (CPU validation); real
TPU deployments set ``repro.kernels.ops.INTERPRET = False`` at startup
(trace-time constant — POSH's compile-time selection, once more).
"""
from __future__ import annotations

import functools

import jax

from . import flash_attention as _fa
from . import reduce_combine as _rc
from . import symm_copy as _sc

INTERPRET = True  # flipped off on real TPU


@functools.partial(jax.jit, static_argnames=("variant",))
def symm_copy(x, variant: str = _sc.DEFAULT_VARIANT):
    if variant == "stock":
        return _sc.copy_stock(x)
    return _sc.copy_blocked(x, variant, interpret=INTERPRET)


@functools.partial(jax.jit, static_argnames=("op", "variant"))
def combine(a, b, op: str = "sum", variant: str = _rc.DEFAULT_VARIANT):
    return _rc.combine_blocked(a, b, op, variant, interpret=INTERPRET)


@functools.partial(jax.jit, static_argnames=("causal", "window", "sm_scale",
                                             "block_q", "block_kv"))
def attention(q, k, v, causal: bool = True, window: int | None = None,
              sm_scale: float | None = None, block_q: int = 128,
              block_kv: int = 128):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               sm_scale=sm_scale, block_q=block_q,
                               block_kv=block_kv, interpret=INTERPRET)


COPY_VARIANTS = tuple(["stock"] + list(_sc.VARIANTS))
COMBINE_VARIANTS = tuple(_rc.VARIANTS)
