"""jit'd public wrappers over the Pallas kernels.

``INTERPRET = None`` (the default) resolves per call from the actual
platform — compiled kernels on TPU, the interpreter everywhere else
(``symm_copy.default_interpret``).  Deployments can still pin it either
way at startup (trace-time constant — POSH's compile-time selection,
once more).
"""
from __future__ import annotations

import functools

import jax

from . import flash_attention as _fa
from . import paged_attention as _pa
from . import reduce_combine as _rc
from . import symm_copy as _sc

INTERPRET: bool | None = None   # None -> platform default (TPU: compiled)


def _interpret() -> bool:
    return _sc.default_interpret() if INTERPRET is None else INTERPRET


@functools.partial(jax.jit, static_argnames=("variant",))
def symm_copy(x, variant: str = _sc.DEFAULT_VARIANT):
    """The copy engine: ``variant`` may be a VMEM block name, "stock"
    (bare XLA copy) or "auto" (size/dtype dispatch)."""
    return _sc.copy(x, variant, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("op", "variant"))
def combine(a, b, op: str = "sum", variant: str = _rc.DEFAULT_VARIANT):
    return _rc.combine_blocked(a, b, op, variant, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("causal", "window", "sm_scale",
                                             "block_q", "block_kv"))
def attention(q, k, v, causal: bool = True, window: int | None = None,
              sm_scale: float | None = None, block_q: int = 128,
              block_kv: int = 128):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               sm_scale=sm_scale, block_q=block_q,
                               block_kv=block_kv, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("sm_scale", "impl"))
def paged_attention(q, k_pages, v_pages, block_tables, lengths,
                    sm_scale: float | None = None, impl: str = "kernel"):
    """Paged decode attention (serving hot path): K/V gathered through a
    block table of symmetric-heap pages.  ``impl="kernel"`` runs the
    Pallas kernel (compiled on TPU, interpret elsewhere); ``"ref"`` the
    jnp oracle — numerically interchangeable (tier-1 parity test)."""
    if impl == "ref":
        return _pa.paged_decode_attention_ref(q, k_pages, v_pages,
                                              block_tables, lengths,
                                              sm_scale=sm_scale)
    if impl != "kernel":
        raise ValueError(
            f"paged_attention impl='{impl}' "
            f"(choose from {PAGED_ATTN_IMPLS})")
    return _pa.paged_decode_attention(q, k_pages, v_pages, block_tables,
                                      lengths, sm_scale=sm_scale,
                                      interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("sm_scale", "impl", "block_q"))
def paged_prefill_attention(q, k_pages, v_pages, block_tables, start,
                            n_tok, sm_scale: float | None = None,
                            impl: str = "ref", block_q: int | None = None):
    """Chunk-window attention through a block table: query row ``j`` of
    sequence ``b`` (absolute position ``start[b] + j``) attends to its
    first ``start[b]+j+1`` paged tokens; padded rows (``j >= n_tok``)
    return zeros.  This is BOTH the chunked-prefill window and the
    speculative-decode verify window (a ``(B, k+1)`` window of pending
    token + drafts — ``serve.make_verify``): numerically the same
    per-position reduction as ``paged_attention(impl="ref")``, which is
    what lets verify-path token streams match sequential decoding.

    ``impl="kernel"`` runs the prefill-window Pallas grid kernel — a
    ``(batch, q-block, page)`` grid whose scalar-prefetched block table
    drives the HBM→VMEM K/V DMA, online softmax across pages (compiled
    on TPU, interpret elsewhere); ``"ref"`` the fused jnp gather +
    masked f32 softmax.  ``block_q`` (kernel only) overrides the
    ``choose_block`` size/dtype dispatch; windows are padded to a block
    multiple and sliced back."""
    if impl == "ref":
        return _pa.paged_prefill_attention_ref(q, k_pages, v_pages,
                                               block_tables, start, n_tok,
                                               sm_scale=sm_scale)
    if impl != "kernel":
        raise ValueError(
            f"paged_prefill_attention impl='{impl}' "
            f"(choose from {PAGED_PREFILL_IMPLS})")
    return _pa.paged_prefill_attention(q, k_pages, v_pages, block_tables,
                                       start, n_tok, sm_scale=sm_scale,
                                       block_q=block_q,
                                       interpret=_interpret())


COPY_VARIANTS = tuple(["stock", "auto"] + list(_sc.VARIANTS))
COMBINE_VARIANTS = tuple(_rc.VARIANTS)
PAGED_ATTN_IMPLS = ("kernel", "ref")
PAGED_PREFILL_IMPLS = ("kernel", "ref")
