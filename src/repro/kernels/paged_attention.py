"""Paged attention — K/V gathered through a block table.

The serving engine (``repro.serve``) keeps the KV cache as fixed-size
pages carved from the symmetric heap; a sequence's cache is a *block
table* of page ids, not a contiguous buffer.  The kernels here compute
attention directly against that layout — the gather happens in the
BlockSpec index map via scalar prefetch (the block table is available
before the kernel body runs, so the page id drives the HBM→VMEM DMA
itself; no gather materializes in HBM).

Two grid kernels share the same machinery:

  * ``paged_decode_attention`` — one decode step: the grid walks
    (sequence, table slot) and the KV block for slot ``j`` of sequence
    ``i`` is DMA'd from page ``block_table[i, j]``.
  * ``paged_prefill_attention`` — a whole prefill/verify WINDOW: the
    grid walks (sequence, q block, table slot), so one launch computes
    every window position's causal attention against the pages written
    so far.  This is the serving hot path's trunk — every
    chunked-prefill tick and every speculative-verify window runs it.

Online softmax runs exactly like the contiguous flash kernel
(``flash_attention._flash_kernel``): per-sequence running (m, l) and an
f32 accumulator live in VMEM scratch across table slots
(``_online_block_update`` below — the piece both kernels share), so a
paged sequence produces the same reduction tree as a contiguous one
with ``block_kv == page_tokens``.

GQA is handled by a static loop over KV heads (query rows grouped by
the KV head they read), matching the cache layout: pages store
``kv_per_rank`` heads, queries ``heads_per_rank``.

``choose_block(window, dtype)`` picks the prefill q-block rows from the
window length and the dtype's sublane tiling — the §4.5.4 compile-time
size dispatch, same philosophy as ``symm_copy.choose_variant``; the
ladder is cross-checked by ``benchmarks/attn_microbench.py``.

``interpret=None`` resolves from the platform like every other kernel
here: compiled on TPU, interpreter elsewhere (``ops.INTERPRET``).
``paged_decode_attention_ref`` / ``paged_prefill_attention_ref`` are
the jnp oracles (dense masked softmax over the gathered pages) used by
tests and as the fast CPU paths in the engine.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import symm_copy as _sc

NEG_INF = -1e30

# q-block ladder for choose_block: (window cap, f32 block rows) — small
# windows (a spec-verify (B, k+1) slab) take one minimal tile, larger
# chunked-prefill windows take wider blocks so the kv pipeline has more
# MXU work per DMA.  Rows round up to the dtype's sublane multiple.
_QBLOCK_LADDER = (
    (16, 8),       # ≤ 16-token windows: one minimal f32 tile
    (64, 16),      # chunked-prefill defaults
    (256, 32),     # long resume suffixes
)
_QBLOCK_TOP = 64


def choose_block(window: int, dtype=jnp.float32) -> int:
    """Size/dtype dispatch for the prefill-window q block (POSH §4.5.4:
    per-call compile-time selection).  Returns block rows that (a) meet
    the dtype's sublane multiple (f32 8, bf16 16, int8 32) and (b)
    never exceed the sublane-padded window — a 3-row verify window
    under f32 gets an 8-row block, not a 64-row one."""
    sub = _sc._SUBLANE.get(jnp.dtype(dtype).itemsize, 8)
    for cap, blk in _QBLOCK_LADDER:
        if window <= cap:
            break
    else:
        blk = _QBLOCK_TOP
    blk = -(-blk // sub) * sub                 # dtype sublane multiple
    padded = -(-max(window, 1) // sub) * sub   # window rounded up
    return min(blk, padded)


# ======================================================================
# shared machinery: scratch init / online-softmax update / finalize
# ======================================================================
def _init_scratch(acc_ref, m_ref, l_ref):
    acc_ref[...] = jnp.zeros_like(acc_ref)
    m_ref[...] = jnp.full_like(m_ref, NEG_INF)
    l_ref[...] = jnp.zeros_like(l_ref)


def _online_block_update(s, valid, vh, rows, acc_ref, m_ref, l_ref):
    """One online-softmax accumulation step over a KV block for the
    scratch rows ``rows``: fold the masked score block ``s`` (NEG_INF
    where ``~valid``) and its values ``vh`` into the running
    (acc, m, l).  ``p`` is re-masked after the exp so rows with NO
    valid column yet (m still NEG_INF: exp(0) = 1) contribute exactly
    zero — the property that lets the window kernel zero padded rows
    without a separate pass."""
    m_prev = m_ref[rows, :]                    # (r, 128) lane-replicated
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
    alpha = jnp.exp(m_prev[:, :1] - m_new[:, :1])
    p = jnp.exp(s - m_new[:, :1])
    p = jnp.where(valid, p, 0.0)
    l_new = alpha * l_ref[rows, :1] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[rows, :] = acc_ref[rows, :] * alpha + jax.lax.dot_general(
        p, vh, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[rows, :] = m_new
    l_ref[rows, :] = jnp.broadcast_to(l_new, l_ref[rows, :].shape)


def _normalized(acc_ref, l_ref, rows):
    denom = jnp.maximum(l_ref[rows, :1], 1e-30)
    return acc_ref[rows, :] / denom


# ======================================================================
# decode kernel: one query per sequence, grid (sequence, table slot)
# ======================================================================
def _paged_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, sm_scale: float,
                  page_tokens: int, n_slots: int, hkv: int, group: int):
    i = pl.program_id(0)          # sequence
    j = pl.program_id(1)          # block-table slot

    @pl.when(j == 0)
    def _init():
        _init_scratch(acc_ref, m_ref, l_ref)

    length = len_ref[i]
    base = j * page_tokens

    @pl.when(base < length)
    def _compute():
        q = q_ref[0].astype(jnp.float32)              # (H, D)
        cols = base + jax.lax.broadcasted_iota(jnp.int32,
                                               (group, page_tokens), 1)
        valid = cols < length
        for h in range(hkv):                          # static GQA loop
            qh = q[h * group:(h + 1) * group]         # (g, D)
            kh = k_ref[0, :, h, :].astype(jnp.float32)   # (P, D)
            vh = v_ref[0, :, h, :].astype(jnp.float32)
            s = jax.lax.dot_general(qh, kh, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
            s = jnp.where(valid, s * sm_scale, NEG_INF)   # (g, P)
            rows = slice(h * group, (h + 1) * group)
            _online_block_update(s, valid, vh, rows, acc_ref, m_ref,
                                 l_ref)

    @pl.when(j == n_slots - 1)
    def _finalize():
        o_ref[0] = _normalized(acc_ref, l_ref,
                               slice(None)).astype(o_ref.dtype)


def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, block_tables: jax.Array,
                           lengths: jax.Array, *,
                           sm_scale: float | None = None,
                           interpret: bool | None = None) -> jax.Array:
    """One decode step of attention through a block table.

    q:            (B, H, D) this step's queries
    k/v_pages:    (n_pages, P, H_kv, D) the page pool (H % H_kv == 0)
    block_tables: (B, n_slots) int32 page ids (unused slots: any valid id)
    lengths:      (B,) int32 tokens valid per sequence (0 = inactive ->
                  zero output)

    Returns (B, H, D).  Token t of sequence b lives in page
    ``block_tables[b, t // P]`` at slot ``t % P``.
    """
    if interpret is None:
        interpret = _sc.default_interpret()
    b, h, d = q.shape
    n_pages, page_tokens, hkv, _ = k_pages.shape
    if h % hkv:
        raise ValueError(f"GQA requires H % H_kv == 0, got {h} % {hkv}")
    group = h // hkv
    n_slots = block_tables.shape[1]
    sm_scale = 1.0 / math.sqrt(d) if sm_scale is None else sm_scale

    kernel = functools.partial(
        _paged_kernel, sm_scale=sm_scale, page_tokens=page_tokens,
        n_slots=n_slots, hkv=hkv, group=group)

    bt_flat = block_tables.reshape(-1).astype(jnp.int32)
    lens = lengths.astype(jnp.int32)

    def q_map(i, j, bt, ln):
        return (i, 0, 0)

    def kv_map(i, j, bt, ln):
        return (bt[i * n_slots + j], 0, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, n_slots),
        in_specs=[
            pl.BlockSpec((1, h, d), q_map),
            pl.BlockSpec((1, page_tokens, hkv, d), kv_map),
            pl.BlockSpec((1, page_tokens, hkv, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, h, d), q_map),
        scratch_shapes=[
            pltpu.VMEM((h, d), jnp.float32),
            pltpu.VMEM((h, 128), jnp.float32),
            pltpu.VMEM((h, 128), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        grid_spec=grid_spec,
        interpret=interpret,
    )(bt_flat, lens, q, k_pages, v_pages)


# ======================================================================
# prefill-window kernel: grid (sequence, q block, table slot)
# ======================================================================
def _prefill_kernel(bt_ref, start_ref, ntok_ref, q_ref, k_ref, v_ref,
                    o_ref, acc_ref, m_ref, l_ref, *, sm_scale: float,
                    page_tokens: int, n_slots: int, block_q: int,
                    hkv: int, group: int, head_dim: int):
    i = pl.program_id(0)          # sequence
    qi = pl.program_id(1)         # q block inside the window
    jk = pl.program_id(2)         # block-table slot

    @pl.when(jk == 0)
    def _init():
        _init_scratch(acc_ref, m_ref, l_ref)

    start = start_ref[i]
    ntok = ntok_ref[i]
    q_base = qi * block_q
    kv_base = jk * page_tokens

    # Block relevance: the q block must hold >= 1 valid window row, and
    # the KV page must not start past the LAST valid row's absolute
    # position (causality trims the kv walk per q block, the paged
    # analogue of the flash kernel's block-level causal skip).
    last_pos = start + jnp.minimum(ntok, q_base + block_q) - 1
    relevant = (q_base < ntok) & (kv_base <= last_pos)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # (block_q, H, D)
        r = block_q * group
        # score row r -> window row j = q_base + r // group; position
        # start + j attends to cols <= start + j of the paged sequence
        jrow = q_base + jax.lax.broadcasted_iota(
            jnp.int32, (r, page_tokens), 0) // group
        cols = kv_base + jax.lax.broadcasted_iota(
            jnp.int32, (r, page_tokens), 1)
        valid = (cols <= start + jrow) & (jrow < ntok)
        for h in range(hkv):                      # static GQA loop
            qh = q[:, h * group:(h + 1) * group, :].reshape(r, head_dim)
            kh = k_ref[0, :, h, :].astype(jnp.float32)   # (P, D)
            vh = v_ref[0, :, h, :].astype(jnp.float32)
            s = jax.lax.dot_general(qh, kh, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
            s = jnp.where(valid, s * sm_scale, NEG_INF)  # (r, P)
            rows = slice(h * r, (h + 1) * r)
            _online_block_update(s, valid, vh, rows, acc_ref, m_ref,
                                 l_ref)

    @pl.when(jk == n_slots - 1)
    def _finalize():
        r = block_q * group
        for h in range(hkv):
            rows = slice(h * r, (h + 1) * r)
            out = _normalized(acc_ref, l_ref, rows)      # (r, D)
            o_ref[0, :, h * group:(h + 1) * group, :] = out.reshape(
                block_q, group, head_dim).astype(o_ref.dtype)


def paged_prefill_attention(q: jax.Array, k_pages: jax.Array,
                            v_pages: jax.Array, block_tables: jax.Array,
                            start: jax.Array, n_tok: jax.Array, *,
                            sm_scale: float | None = None,
                            block_q: int | None = None,
                            interpret: bool | None = None) -> jax.Array:
    """Chunk-window prefill/verify attention through the block table —
    the Pallas grid kernel behind ``ops.paged_prefill_attention
    (impl="kernel")``.

    q:            (B, C, H, D) one prefill CHUNK (or spec-verify
                  window) of queries; row j of sequence b sits at
                  absolute position ``start[b] + j``
    k/v_pages:    (n_pages, P, H_kv, D) the page pool
    block_tables: (B, n_slots) int32 page ids (null-padded past the
                  live pages)
    start:        (B,) absolute position of q[:, 0]
    n_tok:        (B,) valid rows per window (0 = inactive -> zeros);
                  rows ``j >= n_tok`` produce exactly zero output

    Returns (B, C, H, D).  Row j attends to the first
    ``start[b] + j + 1`` paged tokens (the chunk's K/V must already be
    scattered into the pages) — numerically the per-position reduction
    of ``paged_decode_attention``, which is what keeps verify-path
    token streams bit-identical to sequential decode.

    ``block_q=None`` resolves via ``choose_block`` (size/dtype
    dispatch); windows are padded to a block multiple and sliced back,
    so block sizes that don't divide the window are fine.
    """
    if interpret is None:
        interpret = _sc.default_interpret()
    b, c, h, d = q.shape
    n_pages, page_tokens, hkv, _ = k_pages.shape
    if h % hkv:
        raise ValueError(f"GQA requires H % H_kv == 0, got {h} % {hkv}")
    group = h // hkv
    n_slots = block_tables.shape[1]
    sm_scale = 1.0 / math.sqrt(d) if sm_scale is None else sm_scale
    if block_q is None:
        block_q = choose_block(c, q.dtype)
    cp = -(-c // block_q) * block_q
    qp = jnp.pad(q, ((0, 0), (0, cp - c), (0, 0), (0, 0)))
    n_q = cp // block_q

    kernel = functools.partial(
        _prefill_kernel, sm_scale=sm_scale, page_tokens=page_tokens,
        n_slots=n_slots, block_q=block_q, hkv=hkv, group=group,
        head_dim=d)

    bt_flat = block_tables.reshape(-1).astype(jnp.int32)
    starts = start.astype(jnp.int32)
    ntoks = n_tok.astype(jnp.int32)

    def q_map(i, qi, jk, bt, st, nt):
        return (i, qi, 0, 0)

    def kv_map(i, qi, jk, bt, st, nt):
        return (bt[i * n_slots + jk], 0, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, n_q, n_slots),
        in_specs=[
            pl.BlockSpec((1, block_q, h, d), q_map),
            pl.BlockSpec((1, page_tokens, hkv, d), kv_map),
            pl.BlockSpec((1, page_tokens, hkv, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, h, d), q_map),
        scratch_shapes=[
            pltpu.VMEM((block_q * h, d), jnp.float32),
            pltpu.VMEM((block_q * h, 128), jnp.float32),
            pltpu.VMEM((block_q * h, 128), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b, cp, h, d), q.dtype),
        grid_spec=grid_spec,
        interpret=interpret,
    )(bt_flat, starts, ntoks, qp, k_pages, v_pages)
    return out[:, :c]


# ======================================================================
# jnp oracles
# ======================================================================
def paged_prefill_attention_ref(q, k_pages, v_pages, block_tables,
                                start, n_tok, *,
                                sm_scale: float | None = None):
    """Chunk-window prefill attention through the block table.

    q: (B, C, H, D) — the queries of one prefill CHUNK, where row j of
    sequence b sits at absolute position ``start[b] + j``; it attends
    to the first ``start[b] + j + 1`` tokens of its sequence's pages
    (the paged analogue of the causal mask, assuming the chunk's K/V
    have already been scattered into the pages).  Rows ``j >= n_tok``
    (the right-padding of a short chunk) produce exactly zero output.

    One gather + one masked softmax for the whole window — the fused
    form of C ``paged_decode_attention_ref`` calls (same mask, same
    scale, same f32 math), so chunked prefill costs one einsum per
    layer instead of C unrolled attention graphs.  The jnp oracle for
    ``paged_prefill_attention`` (the grid kernel above) and the fast
    CPU path in the engine.
    """
    b, c, h, d = q.shape
    _, page_tokens, hkv, _ = k_pages.shape
    group = h // hkv
    n_slots = block_tables.shape[1]
    s_max = n_slots * page_tokens
    sm_scale = 1.0 / math.sqrt(d) if sm_scale is None else sm_scale

    kc = k_pages[block_tables].reshape(b, s_max, hkv, d)
    vc = v_pages[block_tables].reshape(b, s_max, hkv, d)
    qg = q.reshape(b, c, hkv, group, d).astype(jnp.float32)
    sc = jnp.einsum("bchgd,bshd->bchgs", qg, kc.astype(jnp.float32),
                    preferred_element_type=jnp.float32) * sm_scale
    pos = start[:, None] + jnp.arange(c)[None]             # (B, C)
    lens = jnp.where(jnp.arange(c)[None] < n_tok[:, None], pos + 1, 0)
    valid = jnp.arange(s_max)[None, None] < lens[:, :, None]
    vmask = valid[:, :, None, None, :]                     # (B,C,1,1,S)
    sc = jnp.where(vmask, sc, NEG_INF)
    m = sc.max(-1)
    p = jnp.exp(sc - m[..., None])
    p = jnp.where(vmask, p, 0.0)
    l = p.sum(-1)
    acc = jnp.einsum("bchgs,bshd->bchgd", p, vc.astype(jnp.float32))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, c, h, d).astype(q.dtype)


def paged_decode_attention_ref(q, k_pages, v_pages, block_tables, lengths,
                               *, sm_scale: float | None = None):
    """jnp oracle: gather the pages, dense masked softmax in f32.
    Mathematically identical to the kernel (same mask, same scale);
    the fast path off-TPU."""
    b, h, d = q.shape
    _, page_tokens, hkv, _ = k_pages.shape
    group = h // hkv
    n_slots = block_tables.shape[1]
    s_max = n_slots * page_tokens
    sm_scale = 1.0 / math.sqrt(d) if sm_scale is None else sm_scale

    # (B, n_slots, P, hkv, d) -> (B, S, hkv, d)
    kc = k_pages[block_tables].reshape(b, s_max, hkv, d)
    vc = v_pages[block_tables].reshape(b, s_max, hkv, d)
    qg = q.reshape(b, hkv, group, d).astype(jnp.float32)
    sc = jnp.einsum("bhgd,bshd->bhgs", qg, kc.astype(jnp.float32),
                    preferred_element_type=jnp.float32) * sm_scale
    valid = jnp.arange(s_max)[None, :] < lengths[:, None]      # (B, S)
    sc = jnp.where(valid[:, None, None, :], sc, NEG_INF)
    m = sc.max(-1)
    p = jnp.exp(sc - m[..., None])
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    l = p.sum(-1)
    acc = jnp.einsum("bhgs,bshd->bhgd", p, vc.astype(jnp.float32))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, h, d).astype(q.dtype)
