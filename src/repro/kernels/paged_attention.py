"""Paged decode attention — K/V gathered through a block table.

The serving engine (``repro.serve``) keeps the KV cache as fixed-size
pages carved from the symmetric heap; a sequence's cache is a *block
table* of page ids, not a contiguous buffer.  This kernel computes one
decode step of attention directly against that layout: the grid walks
(sequence, table slot) and the KV block for slot ``j`` of sequence ``i``
is DMA'd from page ``block_table[i, j]`` — the gather happens in the
BlockSpec index map via scalar prefetch (the block table is available
before the kernel body runs, so the page id drives the HBM→VMEM DMA
itself; no gather materializes in HBM).

Online softmax runs exactly like the contiguous flash kernel
(``flash_attention._flash_kernel``): per-sequence running (m, l) and an
f32 accumulator live in VMEM scratch across table slots, so a paged
sequence produces the same reduction tree as a contiguous one with
``block_kv == page_tokens`` — the parity the tier-1 test pins against
``ops.attention``.

GQA is handled by a static loop over KV heads (query rows grouped by
the KV head they read), matching the cache layout: pages store
``kv_per_rank`` heads, queries ``heads_per_rank``.

``interpret=None`` resolves from the platform like every other kernel
here: compiled on TPU, interpreter elsewhere (``ops.INTERPRET``).
``paged_decode_attention_ref`` is the jnp oracle (dense masked softmax
over the gathered pages) used by tests and as the fast CPU path in the
engine.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import symm_copy as _sc

NEG_INF = -1e30


def _paged_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, sm_scale: float,
                  page_tokens: int, n_slots: int, hkv: int, group: int):
    i = pl.program_id(0)          # sequence
    j = pl.program_id(1)          # block-table slot

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[i]
    base = j * page_tokens

    @pl.when(base < length)
    def _compute():
        q = q_ref[0].astype(jnp.float32)              # (H, D)
        cols = base + jax.lax.broadcasted_iota(jnp.int32,
                                               (group, page_tokens), 1)
        valid = cols < length
        for h in range(hkv):                          # static GQA loop
            qh = q[h * group:(h + 1) * group]         # (g, D)
            kh = k_ref[0, :, h, :].astype(jnp.float32)   # (P, D)
            vh = v_ref[0, :, h, :].astype(jnp.float32)
            s = jax.lax.dot_general(qh, kh, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
            s = jnp.where(valid, s * sm_scale, NEG_INF)   # (g, P)
            rows = slice(h * group, (h + 1) * group)
            m_prev = m_ref[rows, :]                   # (g, 128) lane-repl
            m_cur = jnp.max(s, axis=1, keepdims=True)
            m_new = jnp.maximum(m_prev,
                                jnp.broadcast_to(m_cur, m_prev.shape))
            alpha = jnp.exp(m_prev[:, :1] - m_new[:, :1])
            p = jnp.exp(s - m_new[:, :1])
            l_new = alpha * l_ref[rows, :1] + jnp.sum(p, axis=1,
                                                      keepdims=True)
            acc_ref[rows, :] = acc_ref[rows, :] * alpha + jax.lax.dot_general(
                p, vh, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            m_ref[rows, :] = m_new
            l_ref[rows, :] = jnp.broadcast_to(l_new, (group, 128))

    @pl.when(j == n_slots - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, block_tables: jax.Array,
                           lengths: jax.Array, *,
                           sm_scale: float | None = None,
                           interpret: bool | None = None) -> jax.Array:
    """One decode step of attention through a block table.

    q:            (B, H, D) this step's queries
    k/v_pages:    (n_pages, P, H_kv, D) the page pool (H % H_kv == 0)
    block_tables: (B, n_slots) int32 page ids (unused slots: any valid id)
    lengths:      (B,) int32 tokens valid per sequence (0 = inactive ->
                  zero output)

    Returns (B, H, D).  Token t of sequence b lives in page
    ``block_tables[b, t // P]`` at slot ``t % P``.
    """
    if interpret is None:
        interpret = _sc.default_interpret()
    b, h, d = q.shape
    n_pages, page_tokens, hkv, _ = k_pages.shape
    if h % hkv:
        raise ValueError(f"GQA requires H % H_kv == 0, got {h} % {hkv}")
    group = h // hkv
    n_slots = block_tables.shape[1]
    sm_scale = 1.0 / math.sqrt(d) if sm_scale is None else sm_scale

    kernel = functools.partial(
        _paged_kernel, sm_scale=sm_scale, page_tokens=page_tokens,
        n_slots=n_slots, hkv=hkv, group=group)

    bt_flat = block_tables.reshape(-1).astype(jnp.int32)
    lens = lengths.astype(jnp.int32)

    def q_map(i, j, bt, ln):
        return (i, 0, 0)

    def kv_map(i, j, bt, ln):
        return (bt[i * n_slots + j], 0, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, n_slots),
        in_specs=[
            pl.BlockSpec((1, h, d), q_map),
            pl.BlockSpec((1, page_tokens, hkv, d), kv_map),
            pl.BlockSpec((1, page_tokens, hkv, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, h, d), q_map),
        scratch_shapes=[
            pltpu.VMEM((h, d), jnp.float32),
            pltpu.VMEM((h, 128), jnp.float32),
            pltpu.VMEM((h, 128), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        grid_spec=grid_spec,
        interpret=interpret,
    )(bt_flat, lens, q, k_pages, v_pages)


def paged_prefill_attention_ref(q, k_pages, v_pages, block_tables,
                                start, n_tok, *,
                                sm_scale: float | None = None):
    """Chunk-window prefill attention through the block table.

    q: (B, C, H, D) — the queries of one prefill CHUNK, where row j of
    sequence b sits at absolute position ``start[b] + j``; it attends
    to the first ``start[b] + j + 1`` tokens of its sequence's pages
    (the paged analogue of the causal mask, assuming the chunk's K/V
    have already been scattered into the pages).  Rows ``j >= n_tok``
    (the right-padding of a short chunk) produce exactly zero output.

    One gather + one masked softmax for the whole window — the fused
    form of C ``paged_decode_attention_ref`` calls (same mask, same
    scale, same f32 math), so chunked prefill costs one einsum per
    layer instead of C unrolled attention graphs.  The decode hot path
    keeps the Pallas kernel; a prefill-window grid kernel is the
    natural TPU follow-up.
    """
    b, c, h, d = q.shape
    _, page_tokens, hkv, _ = k_pages.shape
    group = h // hkv
    n_slots = block_tables.shape[1]
    s_max = n_slots * page_tokens
    sm_scale = 1.0 / math.sqrt(d) if sm_scale is None else sm_scale

    kc = k_pages[block_tables].reshape(b, s_max, hkv, d)
    vc = v_pages[block_tables].reshape(b, s_max, hkv, d)
    qg = q.reshape(b, c, hkv, group, d).astype(jnp.float32)
    sc = jnp.einsum("bchgd,bshd->bchgs", qg, kc.astype(jnp.float32),
                    preferred_element_type=jnp.float32) * sm_scale
    pos = start[:, None] + jnp.arange(c)[None]             # (B, C)
    lens = jnp.where(jnp.arange(c)[None] < n_tok[:, None], pos + 1, 0)
    valid = jnp.arange(s_max)[None, None] < lens[:, :, None]
    vmask = valid[:, :, None, None, :]                     # (B,C,1,1,S)
    sc = jnp.where(vmask, sc, NEG_INF)
    m = sc.max(-1)
    p = jnp.exp(sc - m[..., None])
    p = jnp.where(vmask, p, 0.0)
    l = p.sum(-1)
    acc = jnp.einsum("bchgs,bshd->bchgd", p, vc.astype(jnp.float32))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, c, h, d).astype(q.dtype)


def paged_decode_attention_ref(q, k_pages, v_pages, block_tables, lengths,
                               *, sm_scale: float | None = None):
    """jnp oracle: gather the pages, dense masked softmax in f32.
    Mathematically identical to the kernel (same mask, same scale);
    the fast path off-TPU."""
    b, h, d = q.shape
    _, page_tokens, hkv, _ = k_pages.shape
    group = h // hkv
    n_slots = block_tables.shape[1]
    s_max = n_slots * page_tokens
    sm_scale = 1.0 / math.sqrt(d) if sm_scale is None else sm_scale

    # (B, n_slots, P, hkv, d) -> (B, S, hkv, d)
    kc = k_pages[block_tables].reshape(b, s_max, hkv, d)
    vc = v_pages[block_tables].reshape(b, s_max, hkv, d)
    qg = q.reshape(b, hkv, group, d).astype(jnp.float32)
    sc = jnp.einsum("bhgd,bshd->bhgs", qg, kc.astype(jnp.float32),
                    preferred_element_type=jnp.float32) * sm_scale
    valid = jnp.arange(s_max)[None, :] < lengths[:, None]      # (B, S)
    sc = jnp.where(valid[:, None, None, :], sc, NEG_INF)
    m = sc.max(-1)
    p = jnp.exp(sc - m[..., None])
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    l = p.sum(-1)
    acc = jnp.einsum("bhgs,bshd->bhgd", p, vc.astype(jnp.float32))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, h, d).astype(q.dtype)
