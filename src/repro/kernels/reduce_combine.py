"""reduce_combine — fused local combine for ring reduction steps.

The ring reduce-scatter inner loop is ``chunk = op(chunk, incoming)``:
a pure elementwise combine that on TPU should be one VMEM-resident
pass (read both operands block-by-block, write the result), not a
separate load/compute/store round-trip.  This is the collective-side
hot spot exactly as the memcpy is the p2p hot spot in the paper.

Block shape is selectable like symm_copy's variants; the op is a
trace-time string (compile-time specialization, §4.5.4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_OPS = {
    "sum": lambda a, b: a + b,
    "prod": lambda a, b: a * b,
    "max": jnp.maximum,
    "min": jnp.minimum,
}

VARIANTS: dict[str, tuple[int, int]] = {
    "vmem_8x128": (8, 128),
    "vmem_64x256": (64, 256),
    "vmem_256x256": (256, 256),
}
DEFAULT_VARIANT = "vmem_64x256"


def _combine_kernel(a_ref, b_ref, o_ref, *, op):
    o_ref[...] = _OPS[op](a_ref[...], b_ref[...])


def combine_blocked(a: jax.Array, b: jax.Array, op: str = "sum",
                    variant: str = DEFAULT_VARIANT,
                    interpret: bool = True) -> jax.Array:
    """Elementwise ``op(a, b)`` as a blocked VMEM kernel."""
    if a.shape != b.shape or a.dtype != b.dtype:
        raise ValueError(f"operand mismatch: {a.shape}/{a.dtype} vs "
                         f"{b.shape}/{b.dtype}")
    if op not in _OPS:
        raise ValueError(f"unknown combine op '{op}'")
    r, c = VARIANTS[variant]
    flat_a, flat_b = a.ravel(), b.ravel()
    n = flat_a.size
    rows = -(-n // c)
    rows = -(-rows // r) * r
    pad = rows * c - n

    def panel(f):
        return jnp.pad(f, (0, pad)).reshape(rows, c)

    import functools
    out = pl.pallas_call(
        functools.partial(_combine_kernel, op=op),
        out_shape=jax.ShapeDtypeStruct((rows, c), a.dtype),
        grid=(rows // r,),
        in_specs=[pl.BlockSpec((r, c), lambda i: (i, 0)),
                  pl.BlockSpec((r, c), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((r, c), lambda i: (i, 0)),
        interpret=interpret,
    )(panel(flat_a), panel(flat_b))
    return out.ravel()[:n].reshape(a.shape)
