"""symm_copy — the POSH memory-copy engine (paper §4.4, Table 1) on TPU.

POSH ships several ``memcpy`` implementations (stock / MMX / MMX2 / SSE)
and selects one at compile time, because the copy between private and
symmetric memory is the hot spot of every put/get.  The TPU analogue of
"which SIMD ISA moves the bytes" is **which VMEM tiling moves the
bytes**: HBM→VMEM DMA efficiency is set by the block shape (sublane ×
lane alignment: multiples of (8, 128) for f32, (16, 128) for bf16,
(32, 128) for int8), and the trade-off between few-large-blocks (DMA
efficiency, VMEM pressure) and many-small-blocks (pipelining) mirrors
the paper's per-platform memcpy differences.

The engine is grid-pipelined: the flat payload is panelized into a
(rows, cols) tile matrix and the copy runs over a 2-D grid of VMEM
blocks, so the Pallas pipeline double-buffers the HBM↔VMEM DMAs of
consecutive blocks — the "overlap the loads of copy i+1 with the
stores of copy i" structure the paper gets from wide SIMD moves.

Selection is trace-time, POSH's compile-time ``-D`` flag, at two
levels:

  * ``choose_variant(nbytes, dtype)`` picks the block shape from the
    payload size (§4.4: "selecting one particular implementation is
    made at compile-time") — small payloads take small blocks (launch
    latency), large payloads take 1 MiB blocks (DMA bandwidth).
  * ``default_interpret()`` resolves the interpret flag from the
    actual platform: compiled kernels on TPU, the interpreter
    everywhere else — so the same call site runs in CI and on a pod.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# name -> (sublane rows, lane cols) of the VMEM block (f32 baseline;
# narrower dtypes round rows up to their sublane multiple, below)
VARIANTS: dict[str, tuple[int, int]] = {
    "vmem_8x128": (8, 128),        # minimal aligned tile ("MMX": small regs)
    "vmem_32x128": (32, 128),      # 16 KiB f32 blocks
    "vmem_64x256": (64, 256),      # 64 KiB
    "vmem_256x256": (256, 256),    # 256 KiB ("SSE": wide moves)
    "vmem_512x512": (512, 512),    # 1 MiB — few, large DMAs
}
DEFAULT_VARIANT = "vmem_256x256"

# dtype itemsize -> minimum sublane multiple of a VMEM tile
_SUBLANE = {8: 8, 4: 8, 2: 16, 1: 32}

# payload-size ladder for choose_variant: largest block whose working
# set the payload can actually fill (paper Table 1: the best memcpy
# depends on the buffer size, not just the ISA)
_SIZE_LADDER = (
    (32 << 10, "vmem_8x128"),      # ≤ 32 KiB
    (256 << 10, "vmem_32x128"),    # ≤ 256 KiB
    (1 << 20, "vmem_64x256"),      # ≤ 1 MiB
    (8 << 20, "vmem_256x256"),     # ≤ 8 MiB
)
_LADDER_TOP = "vmem_512x512"

# column panels per grid row for large payloads — widens the grid to
# 2-D so the pipeline has independent DMAs in both dimensions
_MAX_COL_PANELS = 8


def default_interpret() -> bool:
    """Platform-aware interpret default: compiled on TPU, interpreter
    elsewhere (CPU CI, GPU hosts).  Trace-time constant."""
    return jax.default_backend() != "tpu"


def block_shape(variant: str, dtype) -> tuple[int, int]:
    """The (rows, cols) VMEM block for ``variant`` under ``dtype``'s
    tiling constraint — rows rounded up to the dtype's sublane
    multiple (f32 8, bf16 16, int8 32)."""
    r, c = VARIANTS[variant]
    sub = _SUBLANE.get(jnp.dtype(dtype).itemsize, 8)
    r = -(-r // sub) * sub
    return r, c


def choose_variant(nbytes: int, dtype=jnp.float32) -> str:
    """Size/dtype dispatch: the variant whose block ladder the payload
    fills.  Tiny payloads (< one minimal tile) short-circuit to
    "stock" — a bare XLA copy beats a kernel launch."""
    sub = _SUBLANE.get(jnp.dtype(dtype).itemsize, 8)
    if nbytes < sub * 128 * jnp.dtype(dtype).itemsize:
        return "stock"
    for cap, name in _SIZE_LADDER:
        if nbytes <= cap:
            return name
    return _LADDER_TOP


def _copy_kernel(src_ref, dst_ref):
    dst_ref[...] = src_ref[...]


def copy_blocked(x: jax.Array, variant: str = DEFAULT_VARIANT,
                 interpret: bool | None = None) -> jax.Array:
    """Grid-pipelined VMEM copy of an arbitrary array.

    The array is flattened and padded (``jnp.pad`` — the pad is
    materialized once by XLA's pad op, not by rewriting a zero panel)
    to a (rows, cols) panel tiled exactly by the variant's block; the
    grid is 2-D for payloads wide enough to fill several column panels.
    On real TPU the pad is at most one block.  ``interpret=None``
    resolves from the platform (``default_interpret``).
    """
    if interpret is None:
        interpret = default_interpret()
    r, c = block_shape(variant, x.dtype)
    flat = x.ravel()
    n = flat.size

    # 2-D panelization: enough column panels to keep the grid square-ish
    # for big payloads, one panel otherwise
    row_blocks = -(-n // (r * c))
    col_panels = min(_MAX_COL_PANELS, max(1, row_blocks // _MAX_COL_PANELS))
    cols = c * col_panels
    rows = -(-n // cols)
    rows = -(-rows // r) * r
    panel = jnp.pad(flat, (0, rows * cols - n)).reshape(rows, cols)
    out = pl.pallas_call(
        _copy_kernel,
        out_shape=jax.ShapeDtypeStruct(panel.shape, panel.dtype),
        grid=(rows // r, col_panels),
        in_specs=[pl.BlockSpec((r, c), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((r, c), lambda i, j: (i, j)),
        interpret=interpret,
    )(panel)
    return out.ravel()[:n].reshape(x.shape)


def copy_stock(x: jax.Array) -> jax.Array:
    """The 'stock memcpy': whatever XLA emits for an identity copy."""
    return jnp.copy(x)


def copy(x: jax.Array, variant: str = "auto",
         interpret: bool | None = None) -> jax.Array:
    """The engine's front door: ``variant="auto"`` dispatches by payload
    size and dtype tiling (``choose_variant``); explicit variants pin
    the block shape like POSH's ``-D`` flag pins the ISA."""
    if variant == "auto":
        variant = choose_variant(x.size * jnp.dtype(x.dtype).itemsize,
                                 x.dtype)
    if variant == "stock":
        return copy_stock(x)
    return copy_blocked(x, variant, interpret=interpret)


@functools.lru_cache(maxsize=None)
def vmem_bytes(variant: str, dtype_str: str = "float32") -> int:
    """Working-set estimate for a variant: in-block + out-block bytes
    (double-buffered by the pipeline ⇒ ×2).  Used by the benchmark
    harness to reason about VMEM pressure without hardware."""
    r, c = block_shape(variant, dtype_str)
    item = jnp.dtype(dtype_str).itemsize
    return 2 * 2 * r * c * item
