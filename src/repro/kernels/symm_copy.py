"""symm_copy — the POSH memory-copy engine (paper §4.4, Table 1) on TPU.

POSH ships several ``memcpy`` implementations (stock / MMX / MMX2 / SSE)
and selects one at compile time, because the copy between private and
symmetric memory is the hot spot of every put/get.  The TPU analogue of
"which SIMD ISA moves the bytes" is **which VMEM tiling moves the
bytes**: HBM→VMEM DMA efficiency is set by the block shape (sublane ×
lane alignment: multiples of (8, 128) for f32, (16, 128) for bf16), and
the trade-off between few-large-blocks (DMA efficiency, VMEM pressure)
and many-small-blocks (pipelining) mirrors the paper's per-platform
memcpy differences.

The variant is chosen by a trace-time string — POSH's compile-time
``-D`` flag, same mechanism, same reason (§4.4: "in order to minimize
the number of conditional branches, selecting one particular
implementation is made at compile-time").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# name -> (sublane rows, lane cols) of the VMEM block
VARIANTS: dict[str, tuple[int, int]] = {
    "vmem_8x128": (8, 128),        # minimal aligned tile ("MMX": small regs)
    "vmem_32x128": (32, 128),      # 16 KiB f32 blocks
    "vmem_64x256": (64, 256),      # 64 KiB
    "vmem_256x256": (256, 256),    # 256 KiB ("SSE": wide moves)
    "vmem_512x512": (512, 512),    # 1 MiB — few, large DMAs
}
DEFAULT_VARIANT = "vmem_256x256"


def _copy_kernel(src_ref, dst_ref):
    dst_ref[...] = src_ref[...]


def copy_blocked(x: jax.Array, variant: str = DEFAULT_VARIANT,
                 interpret: bool = True) -> jax.Array:
    """Blocked VMEM copy of an arbitrary array.

    The array is flattened and padded to a (rows, cols) panel that the
    grid tiles exactly; the pad is stripped afterwards.  On real TPU the
    pad is at most one block.
    """
    r, c = VARIANTS[variant]
    flat = x.ravel()
    n = flat.size
    rows = -(-n // c)
    rows = -(-rows // r) * r
    panel = jnp.zeros((rows * c,), x.dtype).at[:n].set(flat).reshape(rows, c)
    out = pl.pallas_call(
        _copy_kernel,
        out_shape=jax.ShapeDtypeStruct(panel.shape, panel.dtype),
        grid=(rows // r,),
        in_specs=[pl.BlockSpec((r, c), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((r, c), lambda i: (i, 0)),
        interpret=interpret,
    )(panel)
    return out.ravel()[:n].reshape(x.shape)


def copy_stock(x: jax.Array) -> jax.Array:
    """The 'stock memcpy': whatever XLA emits for an identity copy."""
    return jnp.copy(x)


@functools.lru_cache(maxsize=None)
def vmem_bytes(variant: str, dtype_str: str = "float32") -> int:
    """Working-set estimate for a variant: in-block + out-block bytes
    (double-buffered by the pipeline ⇒ ×2).  Used by the benchmark
    harness to reason about VMEM pressure without hardware."""
    r, c = VARIANTS[variant]
    item = jnp.dtype(dtype_str).itemsize
    return 2 * 2 * r * c * item
