"""Atomics and locks on the symmetric heap (paper §4.6), owner-computes.

POSH gets atomics from Boost's atomic functors and mutual exclusion from
named mutexes on the shm segment.  TPU ICI exposes no cross-chip CAS, so
the faithful-by-insight adaptation is **deterministic owner-side
serialization**: every requesting PE contributes its operand; requests
are linearized in PE-rank order; each requester receives the value the
cell held *just before its own operation* (the fetch-&-op return value),
and the owner's cell ends at the value after all operations.

This preserves exactly the observable semantics of a linearizable
fetch-&-op sequence with a deterministic order — stronger than POSH's
mutex (which linearizes in an arbitrary order).  Locks, which exist to
*create* an order under preemptive scheduling, are meaningless in
deterministic SPMD; `TicketLock` is provided for API parity and as the
reference model in tests.

All functions run inside shard_map; `owner` is a static virtual rank.

A second, host-side family rides on the :class:`~repro.core.ordering
.CommQueue` pipeline: ``atomic_*_nbi`` below wrap ``CommQueue.amo_nbi``
— nonblocking fetch-&-op records drained like signals (``amo_wait`` on
the word, or any covering fence/quiet), each AMO its own linearization
point inside the delivery shuffle.  This is the POSH §4.6 lock-free
substrate the serving control plane builds on (symmetric page
allocator, cell router, handoff mailbox): arbitration happens on
symmetric counter words instead of a host-serial Python loop.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from . import collectives, safety
from .heap import HeapState, SymHandle
from .ordering import CommQueue, NbiValue, Pairs
from .teams import ActiveSet, Team, TeamAxes


def _gather_requests(value, mask, team, aset, algo):
    """fcollect the (value, participating?) pair from every PE.  Atomic
    operands are scalars (one heap cell), canonicalized here."""
    value = jnp.asarray(value).reshape(())
    mask = jnp.asarray(mask, jnp.bool_).reshape(())
    vals = collectives.fcollect(value, team, algo, aset)
    masks = collectives.fcollect(mask, team, algo, aset)
    return vals, masks


def atomic_fadd(state: HeapState, handle: SymHandle, index, value,
                team: TeamAxes, participate=True, owner: int = 0,
                active_set: Optional[ActiveSet] = None, algo: str = "ring"):
    """``shmem_<type>_fadd`` to cell ``handle[index]`` on PE ``owner``.

    Returns (new_state, old_value_seen_by_me).  Linearization order is
    PE rank; requester i's old value = cell + Σ_{j<i, participating} v_j
    (an exclusive prefix sum — computed redundantly on every PE, which
    is cheaper than a second round-trip on TPU).
    """
    t = Team.of(team)
    aset = (active_set or ActiveSet()).resolve(t.size())
    with safety.collective_guard(t.axes, "atomic_fadd"):
        member, vr = collectives._member_mask(t, aset)
        vals, masks = _gather_requests(value, participate & member, t, aset, algo)
        contrib = jnp.where(masks, vals, 0).astype(vals.dtype)
        prefix = jnp.cumsum(contrib) - contrib          # exclusive scan
        total = contrib.sum()

        buf = state[handle.name]
        cell = jax.lax.dynamic_index_in_dim(buf.ravel(), index, 0, keepdims=False)
        # every PE knows the owner's cell value must be broadcast first
        cell0 = collectives.broadcast(cell, owner, t, "binomial", aset)
        old_mine = cell0 + jax.lax.dynamic_index_in_dim(prefix, vr, 0,
                                                        keepdims=False)
        is_owner = member & (vr == owner)
        newcell = jnp.where(is_owner, cell + total.astype(buf.dtype), cell)
        flat = buf.ravel()
        flat = jax.lax.dynamic_update_index_in_dim(flat, newcell.astype(buf.dtype),
                                                   index, 0)
        out = dict(state)
        out[handle.name] = jnp.where(is_owner, flat, buf.ravel()).reshape(buf.shape)
        return out, jnp.where(participate & member, old_mine, jnp.zeros_like(old_mine))


def atomic_swap(state: HeapState, handle: SymHandle, index, value,
                team: TeamAxes, participate=True, owner: int = 0,
                active_set: Optional[ActiveSet] = None, algo: str = "ring"):
    """``shmem_swap``: rank-ordered; requester i sees the value written
    by the last participating requester before it (or the original)."""
    t = Team.of(team)
    aset = (active_set or ActiveSet()).resolve(t.size())
    with safety.collective_guard(t.axes, "atomic_swap"):
        member, vr = collectives._member_mask(t, aset)
        vals, masks = _gather_requests(value, participate & member, t, aset, algo)
        buf = state[handle.name]
        cell = jax.lax.dynamic_index_in_dim(buf.ravel(), index, 0, keepdims=False)
        cell0 = collectives.broadcast(cell, owner, t, "binomial", aset)

        n = aset.size
        # seq[i] = value of the cell just before requester i acts
        idxs = jnp.arange(n)
        def before(i):
            earlier = (idxs < i) & masks
            # last participating writer before i, else original
            last = jnp.where(earlier, idxs, -1).max()
            return jnp.where(last >= 0, vals[jnp.maximum(last, 0)], cell0)
        seq = jax.vmap(before)(idxs)
        old_mine = jax.lax.dynamic_index_in_dim(seq, vr, 0, keepdims=False)
        any_req = masks.any()
        last_all = jnp.where(masks, idxs, -1).max()
        final = jnp.where(any_req, vals[jnp.maximum(last_all, 0)], cell0)

        is_owner = member & (vr == owner)
        flat = buf.ravel()
        flat = jax.lax.dynamic_update_index_in_dim(flat, final.astype(buf.dtype),
                                                   index, 0)
        out = dict(state)
        out[handle.name] = jnp.where(is_owner, flat, buf.ravel()).reshape(buf.shape)
        return out, jnp.where(participate & member, old_mine,
                              jnp.zeros_like(old_mine))


def atomic_cswap(state: HeapState, handle: SymHandle, index, cond, value,
                 team: TeamAxes, participate=True, owner: int = 0,
                 active_set: Optional[ActiveSet] = None, algo: str = "ring"):
    """``shmem_cswap``: rank-ordered compare-and-swap chain.  Requester i
    succeeds iff the cell (after requesters j<i) equals its ``cond``."""
    t = Team.of(team)
    aset = (active_set or ActiveSet()).resolve(t.size())
    with safety.collective_guard(t.axes, "atomic_cswap"):
        member, vr = collectives._member_mask(t, aset)
        vals, masks = _gather_requests(value, participate & member, t, aset, algo)
        conds = collectives.fcollect(jnp.asarray(cond).reshape(()), t, algo, aset)
        buf = state[handle.name]
        cell = jax.lax.dynamic_index_in_dim(buf.ravel(), index, 0, keepdims=False)
        cur = collectives.broadcast(cell, owner, t, "binomial", aset)

        n = aset.size
        def step(carry, i):
            cur = carry
            ok = masks[i] & (cur == conds[i])
            old = cur
            cur = jnp.where(ok, vals[i].astype(cur.dtype), cur)
            return cur, old
        final, olds = jax.lax.scan(step, cur, jnp.arange(n))
        old_mine = jax.lax.dynamic_index_in_dim(olds, vr, 0, keepdims=False)

        is_owner = member & (vr == owner)
        flat = buf.ravel()
        flat = jax.lax.dynamic_update_index_in_dim(flat, final.astype(buf.dtype),
                                                   index, 0)
        out = dict(state)
        out[handle.name] = jnp.where(is_owner, flat, buf.ravel()).reshape(buf.shape)
        return out, jnp.where(participate & member, old_mine,
                              jnp.zeros_like(old_mine))


# ======================================================================
# queue-integrated AMOs — nonblocking fetch-&-op on the CommQueue
# ======================================================================
def atomic_fetch_nbi(queue: CommQueue, handle: SymHandle, pairs: Pairs,
                     offset=0) -> NbiValue:
    """``shmem_atomic_fetch_nbi`` — read one symmetric word atomically.
    Readable after ``amo_wait`` on the word (or fence/quiet)."""
    return queue.amo_nbi(handle, "fetch", pairs, offset=offset)  # shmem: deferred-drain


def atomic_fadd_nbi(queue: CommQueue, handle: SymHandle, value,
                    pairs: Pairs, offset=0) -> NbiValue:
    """``shmem_atomic_fetch_add_nbi`` — fetch-&-add on one word."""
    return queue.amo_nbi(handle, "fadd", pairs, value=value,  # shmem: deferred-drain
                         offset=offset)


def atomic_swap_nbi(queue: CommQueue, handle: SymHandle, value,
                    pairs: Pairs, offset=0) -> NbiValue:
    """``shmem_atomic_swap_nbi`` — unconditional fetch-&-write."""
    return queue.amo_nbi(handle, "swap", pairs, value=value,  # shmem: deferred-drain
                         offset=offset)


def atomic_cswap_nbi(queue: CommQueue, handle: SymHandle, cond, value,
                     pairs: Pairs, offset=0) -> NbiValue:
    """``shmem_atomic_compare_swap_nbi`` — write ``value`` iff the word
    equals ``cond``; the fetched pre-op value tells whether it won."""
    return queue.amo_nbi(handle, "cswap", pairs, value=value,  # shmem: deferred-drain
                         cond=cond, offset=offset)


def amo_wait(queue: CommQueue, handle: SymHandle, *, offset=0):
    """The AMO drain point — delivers exactly the pending AMOs on the
    named word (see ``CommQueue.amo_wait``)."""
    return queue.amo_wait(handle, offset=offset)


@dataclasses.dataclass
class TicketLock:
    """API-parity lock (paper §4.6 named mutexes).  In deterministic
    SPMD the 'critical section' is the owner-computes serialization
    above; the ticket lock exists as the reference linearization model:
    ``acquire`` returns each PE's ticket (= its turn), which tests
    compare against the atomics' rank-order semantics."""

    team: TeamAxes

    def acquire_order(self, participate=True,
                      active_set: Optional[ActiveSet] = None):
        t = Team.of(self.team)
        aset = (active_set or ActiveSet()).resolve(t.size())
        member, vr = collectives._member_mask(t, aset)
        masks = collectives.fcollect(jnp.asarray(participate & member),
                                     t, "ring", aset)
        # ticket = number of participating PEs with smaller rank
        idxs = jnp.arange(aset.size)
        tickets = jnp.cumsum(masks.astype(jnp.int32)) - masks.astype(jnp.int32)
        return jax.lax.dynamic_index_in_dim(tickets, vr, 0, keepdims=False)
