"""Teams and active sets — the PE-addressing layer of POSH-on-TPU.

In the paper a PE is an OS process and the "team" is implicitly all PEs
(OpenSHMEM 1.0 collectives address subsets through ``(PE_start,
logPE_stride, PE_size)`` active sets).  Here a PE is a mesh device and a
*team* is an ordered tuple of mesh axis names; the flattened product of
those axes is the PE numbering, identical on every device (this is the
SPMD analogue of POSH building segment names from ranks, §4.7 "contact
information").

Everything in this module is trace-time static except ``my_pe`` — the
schedules built from a Team are Python data, which is what lets XLA bake
them into collective-permute ops (the analogue of POSH caching remote
segment handles at startup, §4.1.2).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Union

import jax

TeamAxes = Union[str, Sequence[str]]


def _canon(team: TeamAxes) -> tuple[str, ...]:
    if isinstance(team, str):
        return (team,)
    return tuple(team)


@dataclasses.dataclass(frozen=True)
class ActiveSet:
    """OpenSHMEM 1.0 active set: PEs ``start + i * 2**log2_stride``.

    ``size == 0`` means "the whole team" (resolved against the team size
    at schedule-construction time).
    """

    start: int = 0
    log2_stride: int = 0
    size: int = 0

    def resolve(self, team_size: int) -> "ActiveSet":
        size = self.size
        stride = 1 << self.log2_stride
        if size == 0:
            size = (team_size - self.start + stride - 1) // stride
        last = self.start + (size - 1) * stride
        if not (0 <= self.start and last < team_size):
            raise ValueError(
                f"active set {self} does not fit in team of {team_size} PEs"
            )
        return ActiveSet(self.start, self.log2_stride, size)

    def pe(self, virtual_rank: int) -> int:
        """Physical PE id of a virtual rank inside the set (static)."""
        return self.start + virtual_rank * (1 << self.log2_stride)

    def pes(self) -> list[int]:
        return [self.pe(v) for v in range(self.size)]


@dataclasses.dataclass(frozen=True)
class Team:
    """An ordered set of mesh axes addressed as one flat PE space."""

    axes: tuple[str, ...]

    @classmethod
    def of(cls, team: TeamAxes) -> "Team":
        if isinstance(team, Team):
            return team
        return cls(_canon(team))

    # --- trace-time queries (require being inside shard_map over axes) ---
    def size(self) -> int:
        """Number of PEs in the team (static int)."""
        from repro import compat
        return compat.axis_size(self.axes if len(self.axes) > 1 else self.axes[0])

    def my_pe(self):
        """This PE's rank in the flattened team (traced scalar)."""
        return jax.lax.axis_index(self.axes if len(self.axes) > 1 else self.axes[0])

    @property
    def axis_name(self):
        return self.axes if len(self.axes) > 1 else self.axes[0]


def team_size(team: TeamAxes) -> int:
    return Team.of(team).size()


def my_pe(team: TeamAxes):
    return Team.of(team).my_pe()
