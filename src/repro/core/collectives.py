"""Collective operations built from one-sided put/get rounds (paper §4.5).

Every collective here is composed ONLY of the p2p layer's permute rounds
plus local combines — the paper's design point ("collective
communications rely on point-to-point communications that perform the
actual inter-process data movements").  Each collective offers several
algorithms, selected by a trace-time string — the exact analogue of
POSH's compile-time algorithm switching (§4.5.4): the choice specializes
the jaxpr, so there are zero run-time branches.

Algorithms (put-based = push schedule, get-based = pull schedule):

  barrier_all     dissemination (log n rounds)
  broadcast       binomial (push tree) | binomial_pull | linear | xla
  fcollect        ring | ring_pull | recursive_doubling | xla      (allgather)
  reduce          binomial reduce-to-root (building block)
  allreduce       ring (RS+AG, bandwidth-optimal) | tree (reduce+bcast,
                  latency-optimal at small sizes) | recursive_doubling | xla
  reduce_scatter  ring | xla
  alltoall        pairwise | xla

All collectives accept an OpenSHMEM 1.0 active set ``(PE_start,
logPE_stride, PE_size)``; PEs outside the set pass their input through
untouched.  ``root`` and the active set must be static (trace-time) —
schedules are baked into collective-permute pairs, mirroring POSH's
startup-time handle caching.

Functions are called INSIDE shard_map; array args are per-PE shards.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from . import p2p, safety
from .heap import SymmetricHeap
from .teams import ActiveSet, Team, TeamAxes

_OPS: dict[str, Callable] = {
    "sum": jnp.add,
    "prod": jnp.multiply,
    "max": jnp.maximum,
    "min": jnp.minimum,
}

_OP_INIT = {"sum": 0.0, "prod": 1.0, "max": -jnp.inf, "min": jnp.inf}


def _resolve(team: TeamAxes, active_set: Optional[ActiveSet]):
    t = Team.of(team)
    n_team = t.size()
    aset = (active_set or ActiveSet()).resolve(n_team)
    return t, aset


def _member_mask(t: Team, aset: ActiveSet):
    rank = t.my_pe()
    stride = 1 << aset.log2_stride
    off = rank - aset.start
    vr = off // stride
    member = (off >= 0) & (off % stride == 0) & (vr < aset.size)
    return member, jnp.where(member, vr, 0)


def _vpairs(aset: ActiveSet, pairs_v):
    """Map virtual-rank pairs to physical PE pairs (static)."""
    return [(aset.pe(s), aset.pe(d)) for s, d in pairs_v]


def _masked(member, new, old):
    """Select per-PE between collective result and passthrough."""
    return jnp.where(member, new.ravel(), old.ravel()).reshape(old.shape)


# ======================================================================
# barrier
# ======================================================================
def barrier_all(team: TeamAxes, active_set: Optional[ActiveSet] = None):
    """Dissemination barrier: log2(n) rounds of token pushes.

    Under SPMD a barrier is semantically vacuous (all PEs sit at the
    same program point), but the schedule is kept faithful for safe-mode
    auditing and for the §Dry-run collective-schedule accounting.
    Returns the token count (== 2^ceil(log2 n) for every member).
    """
    t, aset = _resolve(team, active_set)
    n = aset.size
    with safety.collective_guard(t.axes, "barrier_all"):
        tok = jnp.ones((), jnp.int32)
        if n == 1:
            return tok
        for k in range(math.ceil(math.log2(n))):
            shift = 1 << k
            pairs = _vpairs(aset, [(v, (v + shift) % n) for v in range(n)])
            recv = p2p.put(tok, pairs, t)
            tok = tok + recv
        return tok


# ======================================================================
# broadcast (shmem_broadcast, §4.5)
# ======================================================================
def broadcast(x: jax.Array, root: int, team: TeamAxes, algo: str = "binomial",
              active_set: Optional[ActiveSet] = None) -> jax.Array:
    """Root's value delivered to every member PE.  ``root`` is a virtual
    rank in the active set and must be static."""
    t, aset = _resolve(team, active_set)
    n = aset.size
    if not (0 <= root < n):
        raise ValueError(f"broadcast root {root} out of range for set size {n}")
    safety.check_symmetric_arg(x, "broadcast")
    with safety.collective_guard(t.axes, f"broadcast[{algo}]"):
        if n == 1:
            return x
        if algo == "xla":
            member, vr = _member_mask(t, aset)
            sel = jnp.where(member & (vr == root), x, jnp.zeros_like(x))
            out = jax.lax.psum(sel, t.axis_name)
            return _masked(member, out.astype(x.dtype), x)
        if algo in ("binomial", "binomial_pull"):
            return _bcast_binomial(x, root, t, aset, pull=algo.endswith("pull"))
        if algo == "linear":
            return _bcast_linear(x, root, t, aset)
        raise ValueError(f"unknown broadcast algo '{algo}'")


def _bcast_binomial(x, root, t: Team, aset: ActiveSet, pull: bool):
    """Binomial tree: round k doubles the informed set.  Push and pull
    build the same pair set; pull reverses who *constructs* the round
    (receiver-driven), which we record via the schedule builder — the
    data motion is identical, per the SPMD adaptation in DESIGN.md."""
    n = aset.size
    member, vr = _member_mask(t, aset)
    vrel = (vr - root) % n
    out = x
    for k in range(math.ceil(math.log2(n))):
        shift = 1 << k
        if pull:
            # receiver v (in [shift, 2*shift)) pulls from v - shift
            pv = [((v - shift + root) % n, (v + root) % n)
                  for v in range(shift, min(2 * shift, n))]
        else:
            # sender v (< shift) pushes to v + shift
            pv = [((v + root) % n, (v + shift + root) % n)
                  for v in range(shift) if v + shift < n]
        incoming = p2p.get(out, _vpairs(aset, pv), t) if pull \
            else p2p.put(out, _vpairs(aset, pv), t)
        got_now = member & (vrel >= shift) & (vrel < 2 * shift)
        out = _masked(got_now, incoming.astype(out.dtype), out)
    return _masked(member, out, x)


def _bcast_linear(x, root, t: Team, aset: ActiveSet):
    """Flat put-based broadcast: root pushes to one PE per round (n-1
    rounds).  Deliberately latency-poor — exists to make the paper's
    compile-time algorithm-selection benchmark (§4.5.4) meaningful."""
    n = aset.size
    member, vr = _member_mask(t, aset)
    vrel = (vr - root) % n
    out = x
    for s in range(1, n):
        pv = [(root, (root + s) % n)]
        incoming = p2p.put(out, _vpairs(aset, pv), t)
        out = _masked(member & (vrel == s), incoming.astype(out.dtype), out)
    return _masked(member, out, x)


# ======================================================================
# fcollect (allgather, §4.5)
# ======================================================================
def fcollect(x: jax.Array, team: TeamAxes, algo: str = "ring",
             active_set: Optional[ActiveSet] = None) -> jax.Array:
    """Concatenate every member's ``x`` along a new leading axis ->
    (n, *x.shape).  Non-members receive zeros in foreign slots."""
    t, aset = _resolve(team, active_set)
    n = aset.size
    safety.check_symmetric_arg(x, "fcollect")
    with safety.collective_guard(t.axes, f"fcollect[{algo}]"):
        if n == 1:
            return x[None]
        if algo == "xla":
            return jax.lax.all_gather(x, t.axis_name, axis=0)
        if algo in ("ring", "ring_pull"):
            return _fcollect_ring(x, t, aset, pull=algo.endswith("pull"))
        if algo == "recursive_doubling":
            if n & (n - 1):
                # non-power-of-two: documented fallback
                return _fcollect_ring(x, t, aset, pull=False)
            return _fcollect_rd(x, t, aset)
        raise ValueError(f"unknown fcollect algo '{algo}'")


def _fcollect_ring(x, t: Team, aset: ActiveSet, pull: bool):
    """Ring allgather: n-1 rounds, each PE forwards the chunk it
    received last round.  Push ring moves data +1; pull ring drives the
    schedule from the reader and moves data -1."""
    n = aset.size
    member, vr = _member_mask(t, aset)
    out = jnp.zeros((n,) + x.shape, x.dtype)
    out = jax.lax.dynamic_update_index_in_dim(out, x, vr, 0)
    cur = x
    step_dir = 1 if not pull else -1
    for s in range(1, n):
        if pull:
            pv = [((v + 1) % n, v) for v in range(n)]   # reader v pulls from v+1
        else:
            pv = [(v, (v + 1) % n) for v in range(n)]   # owner v pushes to v+1
        cur = (p2p.get if pull else p2p.put)(cur, _vpairs(aset, pv), t)
        slot = (vr - s * step_dir) % n
        out = jax.lax.dynamic_update_index_in_dim(out, cur, slot, 0)
    return _masked(member, out, jnp.broadcast_to(x, out.shape) * 0 + out)


def _fcollect_rd(x, t: Team, aset: ActiveSet):
    """Recursive doubling (power-of-two n): log2 n rounds of doubling
    exchanges.  Buffer stays ordered by virtual-rank low bits so the
    final (n, ...) block is rank-ordered."""
    n = aset.size
    member, vr = _member_mask(t, aset)
    buf = x[None]
    for k in range(int(math.log2(n))):
        shift = 1 << k
        pv = [(v, v ^ shift) for v in range(n)]
        recv = p2p.put(buf, _vpairs(aset, pv), t)
        bit = (vr >> k) & 1
        lo = jnp.concatenate([buf, recv], axis=0)
        hi = jnp.concatenate([recv, buf], axis=0)
        buf = jnp.where(bit == 0, lo, hi)
    return _masked(member, buf, jnp.zeros_like(buf) + buf)


# ======================================================================
# reductions (§4.5: shmem_<op>_to_all)
# ======================================================================
def reduce(x: jax.Array, root: int, op: str, team: TeamAxes,
           active_set: Optional[ActiveSet] = None) -> jax.Array:
    """Binomial reduce-to-root (building block for 'tree' allreduce)."""
    t, aset = _resolve(team, active_set)
    n = aset.size
    combine = _OPS[op]
    with safety.collective_guard(t.axes, f"reduce[{op}]"):
        if n == 1:
            return x
        member, vr = _member_mask(t, aset)
        vrel = (vr - root) % n
        acc = x
        rounds = math.ceil(math.log2(n))
        for k in range(rounds):
            shift = 1 << k
            # senders: vrel with bit k set and lower bits clear
            pv = [((v + root) % n, (v - shift + root) % n)
                  for v in range(shift, n, 2 * shift)]
            incoming = p2p.put(acc, _vpairs(aset, pv), t)
            receives = member & (vrel % (2 * shift) == 0) & (vrel + shift < n)
            acc = _masked(receives, combine(acc, incoming.astype(acc.dtype)), acc)
        return _masked(member & (vrel == 0), acc, x)


def allreduce(x: jax.Array, op: str = "sum", team: TeamAxes = "data",
              algo: str = "ring", active_set: Optional[ActiveSet] = None,
              heap: Optional[SymmetricHeap] = None) -> jax.Array:
    """All-members reduction.  ``algo``:

      ring                reduce-scatter + allgather rings; 2(n-1)/n · B
                          bytes per PE — bandwidth-optimal (put-based)
      tree                binomial reduce + binomial broadcast; 2·B·log n
                          but log-latency — wins at tiny sizes
      recursive_doubling  log n rounds of full-B exchanges (pow2 only,
                          ring fallback otherwise)
      xla                 jax.lax.psum — the native-library baseline the
                          paper compares against (§5.3 UPC/GASNet role)
    """
    t, aset = _resolve(team, active_set)
    n = aset.size
    if op not in _OPS:
        raise ValueError(f"unknown reduce op '{op}'")
    safety.check_symmetric_arg(x, "allreduce")
    with safety.collective_guard(t.axes, f"allreduce[{algo},{op}]"):
        if n == 1:
            return x
        if algo == "xla":
            if op == "sum":
                return jax.lax.psum(x, t.axis_name)
            if op == "max":
                return jax.lax.pmax(x, t.axis_name)
            if op == "min":
                return jax.lax.pmin(x, t.axis_name)
            # prod via log-sum workaround is lossy; use gather+reduce
            return _OPS[op].reduce(fcollect(x, t, "xla", aset), axis=0) \
                if hasattr(_OPS[op], "reduce") else jnp.prod(
                    fcollect(x, t, "xla", aset), axis=0)
        if algo == "tree":
            r = reduce(x, 0, op, t, aset)
            return broadcast(r, 0, t, "binomial", aset)
        if algo == "recursive_doubling":
            if n & (n - 1):
                return _allreduce_ring(x, op, t, aset, heap)
            return _allreduce_rd(x, op, t, aset)
        if algo == "ring":
            return _allreduce_ring(x, op, t, aset, heap)
        raise ValueError(f"unknown allreduce algo '{algo}'")


def _pad_chunks(x, n):
    flat = x.ravel()
    c = -(-flat.size // n)
    flat = jnp.pad(flat, (0, c * n - flat.size))
    return flat.reshape(n, c), c


def _allreduce_rd(x, op, t: Team, aset: ActiveSet):
    n = aset.size
    member, _ = _member_mask(t, aset)
    combine = _OPS[op]
    acc = x
    for k in range(int(math.log2(n))):
        shift = 1 << k
        pv = [(v, v ^ shift) for v in range(n)]
        recv = p2p.put(acc, _vpairs(aset, pv), t)
        acc = combine(acc, recv.astype(acc.dtype))
    return _masked(member, acc, x)


def _allreduce_ring(x, op, t: Team, aset: ActiveSet,
                    heap: Optional[SymmetricHeap]):
    """Ring reduce-scatter followed by ring allgather, both built from
    put rounds.  When a heap is supplied, the chunk buffer is a Lemma-1
    temporary symmetric allocation (alloc'd and freed inside the
    collective; the property test checks registry invariance)."""
    n = aset.size
    member, vr = _member_mask(t, aset)
    combine = _OPS[op]
    data, c = _pad_chunks(x, n)

    def body(data):
        # --- reduce-scatter phase: after n-1 rounds PE v owns chunk v
        d = data
        for s in range(n - 1):
            send_idx = (vr - s - 1) % n
            payload = jax.lax.dynamic_index_in_dim(d, send_idx, 0, keepdims=False)
            pv = [(v, (v + 1) % n) for v in range(n)]
            recv = p2p.put(payload, _vpairs(aset, pv), t)
            acc_idx = (vr - s - 2) % n
            cur = jax.lax.dynamic_index_in_dim(d, acc_idx, 0, keepdims=False)
            d = jax.lax.dynamic_update_index_in_dim(
                d, combine(cur, recv.astype(cur.dtype)), acc_idx, 0)
        # --- allgather phase: circulate the owned chunk
        for s in range(n - 1):
            send_idx = (vr - s) % n
            payload = jax.lax.dynamic_index_in_dim(d, send_idx, 0, keepdims=False)
            pv = [(v, (v + 1) % n) for v in range(n)]
            recv = p2p.put(payload, _vpairs(aset, pv), t)
            set_idx = (vr - s - 1) % n
            d = jax.lax.dynamic_update_index_in_dim(d, recv.astype(d.dtype),
                                                    set_idx, 0)
        return d

    if heap is not None:
        with heap.scratch((n, c), x.dtype, tag="ring_allreduce"):
            data = body(data)
    else:
        data = body(data)
    out = data.ravel()[: x.size].reshape(x.shape)
    return _masked(member, out, x)


def reduce_scatter(x: jax.Array, op: str = "sum", team: TeamAxes = "data",
                   algo: str = "ring",
                   active_set: Optional[ActiveSet] = None) -> jax.Array:
    """PE v receives chunk v of the reduction.  x is split along axis 0
    into n equal chunks (axis length must be divisible by n)."""
    t, aset = _resolve(team, active_set)
    n = aset.size
    if x.shape[0] % n:
        raise ValueError(f"reduce_scatter axis0 {x.shape[0]} not divisible by {n}")
    with safety.collective_guard(t.axes, f"reduce_scatter[{algo},{op}]"):
        if n == 1:
            return x
        if algo == "xla":
            if op != "sum":
                raise ValueError("xla reduce_scatter supports sum only")
            return jax.lax.psum_scatter(x, t.axis_name, scatter_dimension=0,
                                        tiled=True)
        if algo != "ring":
            raise ValueError(f"unknown reduce_scatter algo '{algo}'")
        member, vr = _member_mask(t, aset)
        combine = _OPS[op]
        k = x.shape[0] // n
        d = x.reshape((n, k) + x.shape[1:])
        for s in range(n - 1):
            send_idx = (vr - s - 1) % n
            payload = jax.lax.dynamic_index_in_dim(d, send_idx, 0, keepdims=False)
            pv = [(v, (v + 1) % n) for v in range(n)]
            recv = p2p.put(payload, _vpairs(aset, pv), t)
            acc_idx = (vr - s - 2) % n
            cur = jax.lax.dynamic_index_in_dim(d, acc_idx, 0, keepdims=False)
            d = jax.lax.dynamic_update_index_in_dim(
                d, combine(cur, recv.astype(cur.dtype)), acc_idx, 0)
        own = jax.lax.dynamic_index_in_dim(d, vr, 0, keepdims=False)
        return _masked(member, own, x[:k])


# ======================================================================
# alltoall (§4.5)
# ======================================================================
def alltoall(x: jax.Array, team: TeamAxes = "model", algo: str = "pairwise",
             active_set: Optional[ActiveSet] = None) -> jax.Array:
    """x has shape (n, ...): slot j goes to PE j; output slot j holds
    what PE j sent here.  ``pairwise``: n-1 rounds of disjoint pair
    exchanges built from puts."""
    t, aset = _resolve(team, active_set)
    n = aset.size
    if x.shape[0] != n:
        raise ValueError(f"alltoall leading dim {x.shape[0]} != set size {n}")
    with safety.collective_guard(t.axes, f"alltoall[{algo}]"):
        if n == 1:
            return x
        if algo == "xla":
            return jax.lax.all_to_all(x, t.axis_name, split_axis=0,
                                      concat_axis=0, tiled=False)
        if algo != "pairwise":
            raise ValueError(f"unknown alltoall algo '{algo}'")
        member, vr = _member_mask(t, aset)
        out = jnp.zeros_like(x)
        own = jax.lax.dynamic_index_in_dim(x, vr, 0, keepdims=False)
        out = jax.lax.dynamic_update_index_in_dim(out, own, vr, 0)
        for s in range(1, n):
            dst_v = (vr + s) % n
            payload = jax.lax.dynamic_index_in_dim(x, dst_v, 0, keepdims=False)
            pv = [(v, (v + s) % n) for v in range(n)]
            recv = p2p.put(payload, _vpairs(aset, pv), t)
            src_v = (vr - s) % n
            out = jax.lax.dynamic_update_index_in_dim(out, recv.astype(x.dtype),
                                                      src_v, 0)
        return _masked(member, out, x)
