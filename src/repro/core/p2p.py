"""One-sided put/get (paper §3.2, §4.4) as collective-permute schedules.

POSH implements ``put``/``get`` as memory copies into a mapped remote
heap.  On a TPU pod there is no asymmetric one-sided runtime visible
from XLA — every inter-chip move is a compiler-scheduled ICI DMA.  The
faithful adaptation keeps the paper's *addressing* (symmetric offsets)
and *schedule hoisting* (remote handles resolved once, not per call) but
expresses the data motion as rounds of ``jax.lax.ppermute`` with
**static (src → dst) pair lists**:

  * put-based ("push"): the source computes the pairs and the payload;
  * get-based ("pull"): the reader computes the pairs ``(owner, reader)``
    and the combine happens on the reader side.

Under SPMD both lower to the same collective-permute primitive — the
distinction is which side's schedule drives the round, which matters for
the collective algorithms built on top (ring direction, combine side)
and is preserved there.

All functions here are designed to be called INSIDE ``shard_map`` over
the team's mesh axes; array arguments are per-PE shards.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import safety
from .heap import HeapState, SymHandle
from .teams import Team, TeamAxes

Pairs = Sequence[tuple[int, int]]

# ----------------------------------------------------------------------
# payload staging hook — the §4.4 memcpy seam
# ----------------------------------------------------------------------
# POSH's put/get copy between private and symmetric memory through a
# selected memcpy engine.  Here the seam is explicit: a transport
# backend (e.g. the Pallas symm_copy backend in repro.comm) installs a
# stager for the duration of a collective, and EVERY payload moved by a
# put/get round inside that scope passes through it.  Thread-local,
# trace-time — the staged copy is baked into the jaxpr, zero run-time
# branches, exactly like the paper's compile-time memcpy selection.
_stage_state = threading.local()


def _current_stager() -> Optional[Callable]:
    return getattr(_stage_state, "stager", None)


@contextlib.contextmanager
def staged_payloads(stager: Callable[[jax.Array], jax.Array]):
    """Route every put/get payload inside this scope through ``stager``
    (which must be a value-preserving copy, e.g. the Pallas symm_copy
    engine).  Nests: the innermost stager wins."""
    prev = _current_stager()
    _stage_state.stager = stager
    try:
        yield
    finally:
        _stage_state.stager = prev


def _stage(x: jax.Array) -> jax.Array:
    s = _current_stager()
    return x if s is None else s(x)


def _check_pairs(pairs: Pairs, n: int, tag: str) -> list[tuple[int, int]]:
    pairs = [(int(s), int(d)) for s, d in pairs]
    srcs = [s for s, _ in pairs]
    dsts = [d for _, d in pairs]
    if len(set(srcs)) != len(srcs) or len(set(dsts)) != len(dsts):
        raise ValueError(f"{tag}: sources and destinations must be unique: {pairs}")
    if any(not (0 <= s < n and 0 <= d < n) for s, d in pairs):
        raise ValueError(f"{tag}: pair out of range for team size {n}: {pairs}")
    return pairs


def put(x: jax.Array, pairs: Pairs, team: TeamAxes) -> jax.Array:
    """Push ``x`` along ``pairs``; returns what arrived here (zeros if
    this PE is not a destination).  One POSH ``put`` round."""
    t = Team.of(team)
    safety.check_symmetric_arg(x, "put")
    pairs = _check_pairs(pairs, t.size(), "put")
    if not pairs:
        return jnp.zeros_like(x)
    return jax.lax.ppermute(_stage(x), t.axis_name, pairs)


def get(x: jax.Array, pairs: Pairs, team: TeamAxes) -> jax.Array:
    """Pull: ``pairs`` are (owner, reader).  The reader receives the
    owner's ``x``.  Same primitive as ``put`` — initiative documented by
    the caller's schedule, per DESIGN.md hardware-adaptation note."""
    t = Team.of(team)
    safety.check_symmetric_arg(x, "get")
    pairs = _check_pairs(pairs, t.size(), "get")
    if not pairs:
        return jnp.zeros_like(x)
    return jax.lax.ppermute(_stage(x), t.axis_name, pairs)


def ring_shift(x: jax.Array, team: TeamAxes, delta: int = 1) -> jax.Array:
    """Uniform shift: PE i's value moves to PE (i+delta) mod n."""
    t = Team.of(team)
    n = t.size()
    d = delta % n
    if d == 0:
        return x
    return jax.lax.ppermute(x, t.axis_name, [(i, (i + d) % n) for i in range(n)])


def _dst_mask(pairs: Pairs, team: Team):
    rank = team.my_pe()
    dsts = jnp.asarray([d for _, d in pairs], dtype=jnp.int32)
    return jnp.any(dsts == rank)


# ----------------------------------------------------------------------
# Heap-addressed one-sided ops (Corollary 1 in action)
# ----------------------------------------------------------------------
def heap_put(state: HeapState, handle: SymHandle, data: jax.Array,
             pairs: Pairs, team: TeamAxes, offset=0) -> HeapState:
    """``shmem_put``: write ``data`` into the *destination* PE's
    symmetric object at element ``offset`` — the same offset the source
    would use locally (Corollary 1: the offset IS the remote address).

    ``data`` must be a prefix-contiguous slice along axis 0 of the
    object.  ``offset`` may be traced (dynamic_update_slice) or static.
    """
    t = Team.of(team)
    safety.check_same_size(data, data, "heap_put")
    incoming = put(data, pairs, t)
    buf = state[handle.name]
    start = (jnp.asarray(offset, jnp.int32),) + (jnp.int32(0),) * (buf.ndim - 1)
    updated = jax.lax.dynamic_update_slice(buf, incoming.astype(buf.dtype), start)
    new = jnp.where(_dst_mask(pairs, t), updated.ravel(), buf.ravel()).reshape(buf.shape) \
        if pairs else buf
    out = dict(state)
    out[handle.name] = new
    return out


def heap_get(state: HeapState, handle: SymHandle, pairs: Pairs,
             team: TeamAxes, offset=0, size: int | None = None) -> jax.Array:
    """``shmem_get``: fetch ``size`` rows at ``offset`` from the owner's
    symmetric object.  Pairs are (owner, reader).  ``size=None`` reads
    the rest of the object from ``offset``; a traced offset cannot
    shape the slice, so it requires an explicit ``size`` (matching
    ``CommQueue.get_nbi`` — silent dynamic_slice clamping would return
    rows from the wrong offset)."""
    t = Team.of(team)
    buf = state[handle.name]
    if size is None:
        if not isinstance(offset, (int, np.integer)):
            raise ValueError(
                f"heap_get[{handle.name}]: explicit size required when "
                "offset is traced")
        size = buf.shape[0] - int(offset)
    start = (jnp.asarray(offset, jnp.int32),) + (jnp.int32(0),) * (buf.ndim - 1)
    local_slice = jax.lax.dynamic_slice(buf, start, (size,) + buf.shape[1:])
    return get(local_slice, pairs, t)


def heap_p(state: HeapState, handle: SymHandle, value, pairs: Pairs,
           team: TeamAxes, index=0) -> HeapState:
    """``shmem_p`` — single-element put (the datatype-specific
    ``shmem_<type>_p`` family collapses to one polymorphic function; the
    paper needs C++ templates for this, §4.3 — JAX gives it for free)."""
    val = jnp.asarray(value)[None] if jnp.asarray(value).ndim == 0 else jnp.asarray(value)
    data = val.reshape((1,) + state[handle.name].shape[1:])
    return heap_put(state, handle, data, pairs, team, offset=index)


def heap_g(state: HeapState, handle: SymHandle, pairs: Pairs,
           team: TeamAxes, index=0) -> jax.Array:
    """``shmem_g`` — single-element get."""
    return heap_get(state, handle, pairs, team, offset=index, size=1)[0]
