"""The symmetric heap (paper §3.1, §4.1) on a TPU mesh.

POSH's central object is the per-PE *symmetric heap*: a shared-memory
segment in which every allocation is collective, so that any object
lives at the **same offset on every PE** (Fact 1) and a remote address
is just ``heap_remote + (addr_local - heap_local)`` (Corollary 1).

On a TPU pod the analogue is a registry of arrays whose *per-device
shard* has identical shape/dtype on every PE — which SPMD sharding
guarantees by construction.  What remains worth implementing faithfully
is the **allocator**: a linear symmetric address space with first-fit
allocation, alignment (``shmemalign``), coalescing free, and the
offset-based remote addressing formula.  The allocator runs at trace
time (allocations must be collective ⇒ in SPMD they are *the same
Python code on every PE*, so symmetry cannot be violated by a correct
program — the compiler plays the role of the paper's post-``shmalloc``
barrier).

Heap *state* (the actual arrays) is a plain dict pytree so it can flow
through ``jax.jit`` / ``shard_map`` functionally.
"""
from __future__ import annotations

import bisect
import dataclasses
import hashlib
import math
import os
from contextlib import contextmanager
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .teams import Team, TeamAxes

HeapState = dict  # name -> per-PE array (inside shard_map) or global array

# repro.analysis.shmemcheck hook slot (see repro.core.ordering): None
# when the checker is off; REPRO_SHMEMCHECK=1 arms it lazily at first
# heap construction (one-shot).
_checker = None
_AUTOENV = os.environ.get("REPRO_SHMEMCHECK") == "1"


def _autoenable() -> None:
    global _AUTOENV
    if _AUTOENV:
        _AUTOENV = False
        from repro.analysis import shmemcheck
        shmemcheck.enable()


def _nbytes(shape, dtype) -> int:
    return int(np.prod(shape, dtype=np.int64)) * np.dtype(dtype).itemsize if shape else np.dtype(dtype).itemsize


@dataclasses.dataclass(frozen=True)
class SymHandle:
    """A symmetric object: same shape, dtype and *offset* on every PE."""

    name: str
    shape: tuple[int, ...]
    dtype: np.dtype
    offset: int          # byte offset in the symmetric address space
    nbytes: int
    align: int = 0       # alignment the object was allocated with
                         # (0 = heap default); realloc's move path
                         # re-places with the same guarantee

    @property
    def addr(self) -> int:
        """The symmetric 'address' — identical on every PE (Fact 1)."""
        return self.offset


@dataclasses.dataclass
class _Block:
    offset: int
    nbytes: int
    free: bool
    name: Optional[str] = None


class SymmetricHeap:
    """Trace-time symmetric allocator + functional heap state factory."""

    DEFAULT_ALIGN = 512  # bytes; TPU-friendly (≥ one (8,128) f32 lane row)

    def __init__(self, team: TeamAxes = ("data", "model"),
                 capacity_bytes: int = 1 << 40):
        if _AUTOENV:
            _autoenable()
        self.team = Team.of(team)
        self.capacity = int(capacity_bytes)
        self._blocks: list[_Block] = [_Block(0, self.capacity, True)]
        self.registry: dict[str, SymHandle] = {}
        self._scratch_seq = 0
        # sorted (offset, handle) index over live objects: resolve() is
        # a bisect, not a registry scan (Corollary 1 stays O(log n)
        # even with thousands of symmetric objects)
        self._sorted_offsets: list[int] = []
        self._sorted_handles: list[SymHandle] = []

    # ------------------------------------------------------------------
    # allocation — shmalloc / shmemalign / shfree (§4.1.1)
    # ------------------------------------------------------------------
    def alloc(self, name: str, shape, dtype, align: int | None = None) -> SymHandle:
        """Symmetric allocation.  Collective by construction: under SPMD
        every PE executes this same trace-time call, which is the
        OpenSHMEM requirement ("all PEs must call with identical args",
        paper §4.1.1) enforced rather than assumed."""
        if name in self.registry:
            raise ValueError(f"symmetric object '{name}' already allocated")
        align = align or self.DEFAULT_ALIGN
        if align & (align - 1):
            raise ValueError(f"alignment must be a power of two, got {align}")
        shape = tuple(int(d) for d in shape)
        dtype = np.dtype(dtype)
        need = max(_nbytes(shape, dtype), 1)
        for i, blk in enumerate(self._blocks):
            if not blk.free:
                continue
            start = _align_up(blk.offset, align)
            pad = start - blk.offset
            if blk.nbytes >= pad + need:
                self._carve(i, pad, need, name)
                h = SymHandle(name, shape, dtype, start, need, align)
                self.registry[name] = h
                j = bisect.bisect_left(self._sorted_offsets, start)
                self._sorted_offsets.insert(j, start)
                self._sorted_handles.insert(j, h)
                if _checker is not None:
                    _checker.on_alloc(self, h)
                return h
        raise MemoryError(
            f"symmetric heap exhausted: need {need}B aligned {align} "
            f"(capacity {self.capacity}B)")

    def align_alloc(self, name, shape, dtype, align) -> SymHandle:
        """``shmemalign`` (§4.1.1)."""
        return self.alloc(name, shape, dtype, align=align)

    def free(self, handle_or_name) -> None:
        """``shfree`` — symmetric deallocation with coalescing."""
        name = handle_or_name.name if isinstance(handle_or_name, SymHandle) else handle_or_name
        if _checker is not None:
            _checker.on_free(self, name, self.registry.get(name))
        h = self.registry.pop(name, None)
        if h is None:
            raise KeyError(f"no symmetric object named '{name}'")
        j = bisect.bisect_left(self._sorted_offsets, h.offset)
        del self._sorted_offsets[j]
        del self._sorted_handles[j]
        for blk in self._blocks:
            if blk.name == name:
                blk.free, blk.name = True, None
                break
        self._coalesce()

    def realloc(self, handle_or_name, shape, dtype=None,
                align: int | None = None) -> SymHandle:
        """``shrealloc`` (§4.1.1): resize a live symmetric object.

        Like the paper's realloc this is collective (all PEs call with
        identical args — enforced by SPMD, like ``alloc``) and keeps the
        offset whenever the resize fits in place:

          * shrink: the block is split and the tail returned to the
            free list (offset preserved);
          * grow into an adjacent free block: the block absorbs as much
            of its right neighbour as it needs (offset preserved);
          * otherwise: free + first-fit alloc — the object MAY move, and
            since the move is the same deterministic decision on every
            PE the new offset is still symmetric (Fact 1).

        Size 0 (``realloc(h, 0)`` or any shape with a zero dimension)
        follows the §4.1.1 shrealloc contract: the block is FREED and
        the null handle (``None``) returned — resizing to nothing is
        deallocation, not a 1-byte stub.

        Content preservation is the *state* layer's job (heap state is a
        functional pytree): callers carry rows over themselves, e.g.
        ``repro.serve.kv_cache.PagedKVCache.grow``.
        """
        name = handle_or_name.name if isinstance(handle_or_name, SymHandle) else handle_or_name
        old = self.registry.get(name)
        if old is None:
            raise KeyError(f"no symmetric object named '{name}'")
        if isinstance(shape, (int, np.integer)):
            shape = (int(shape),)
        shape = tuple(int(d) for d in shape)
        if shape and int(np.prod(shape, dtype=np.int64)) == 0:
            # shrealloc(ptr, 0) == shfree(ptr): release the block and
            # hand back the null handle
            self.free(name)
            return None
        dtype = old.dtype if dtype is None else np.dtype(dtype)
        # validate BEFORE any mutation: once the block is freed, a bad
        # argument must not be able to lose the object
        align = align or old.align or None   # keep the original guarantee
        if align is not None and align & (align - 1):
            raise ValueError(f"alignment must be a power of two, got {align}")
        need = max(_nbytes(shape, dtype), 1)
        i = next(k for k, blk in enumerate(self._blocks) if blk.name == name)
        blk = self._blocks[i]

        # a STRONGER explicit align than the current offset satisfies
        # rules out resizing in place — fall through to the move path
        in_place_ok = old.offset % (align or self.DEFAULT_ALIGN) == 0

        if in_place_ok and need <= blk.nbytes:       # in place (shrink/equal)
            rest = blk.nbytes - need
            blk.nbytes = need
            if rest:
                self._blocks.insert(i + 1,
                                    _Block(blk.offset + need, rest, True))
                self._coalesce()
            return self._replace_handle(old, shape, dtype, old.offset, need,
                                        align)

        nxt = self._blocks[i + 1] if i + 1 < len(self._blocks) else None
        grow = need - blk.nbytes
        if in_place_ok and grow > 0 and nxt is not None and nxt.free \
                and nxt.nbytes >= grow:              # absorb neighbour
            blk.nbytes = need
            nxt.offset += grow
            nxt.nbytes -= grow
            if nxt.nbytes == 0:
                del self._blocks[i + 1]
            return self._replace_handle(old, shape, dtype, old.offset, need,
                                        align)

        # move: free then first-fit alloc under the same name.  Freeing
        # first lets the new allocation reuse (part of) the old extent.
        self.free(name)
        try:
            return self.alloc(name, shape, dtype, align=align)
        except MemoryError:
            # failed realloc must not lose OR move the object
            # (shrealloc's unchanged-on-failure contract): carve the
            # exact old extent back out — it was just freed, so it is
            # inside a free block — and re-raise
            self._alloc_at(old)
            raise

    def _alloc_at(self, h: SymHandle) -> None:
        """Re-carve a just-freed extent at its original offset."""
        for i, blk in enumerate(self._blocks):
            if (blk.free and blk.offset <= h.offset
                    and h.offset + h.nbytes <= blk.offset + blk.nbytes):
                self._carve(i, h.offset - blk.offset, h.nbytes, h.name)
                self.registry[h.name] = h
                j = bisect.bisect_left(self._sorted_offsets, h.offset)
                self._sorted_offsets.insert(j, h.offset)
                self._sorted_handles.insert(j, h)
                if _checker is not None:
                    _checker.on_alloc(self, h)
                return
        raise AssertionError(
            f"extent of '{h.name}' not free during realloc restore")

    def _replace_handle(self, old: SymHandle, shape, dtype, offset: int,
                        nbytes: int, align) -> SymHandle:
        """Swap the registry/index entry for a resized-in-place object."""
        j = bisect.bisect_left(self._sorted_offsets, old.offset)
        del self._sorted_offsets[j]
        del self._sorted_handles[j]
        h = SymHandle(old.name, shape, np.dtype(dtype), offset, nbytes,
                      align or 0)
        self.registry[old.name] = h
        j = bisect.bisect_left(self._sorted_offsets, offset)
        self._sorted_offsets.insert(j, offset)
        self._sorted_handles.insert(j, h)
        if _checker is not None:
            _checker.on_realloc(self, old, h)
        return h

    def _carve(self, i: int, pad: int, need: int, name: str) -> None:
        blk = self._blocks[i]
        pieces = []
        if pad:
            pieces.append(_Block(blk.offset, pad, True))
        pieces.append(_Block(blk.offset + pad, need, False, name))
        rest = blk.nbytes - pad - need
        if rest:
            pieces.append(_Block(blk.offset + pad + need, rest, True))
        self._blocks[i:i + 1] = pieces

    def _coalesce(self) -> None:
        out: list[_Block] = []
        for blk in self._blocks:
            if out and out[-1].free and blk.free:
                out[-1].nbytes += blk.nbytes
            else:
                out.append(blk)
        self._blocks = out

    # ------------------------------------------------------------------
    # Corollary 1 — offset-based remote addressing
    # ------------------------------------------------------------------
    def addr_of(self, name: str) -> int:
        """Symmetric address of an object (same on every PE, Fact 1)."""
        return self.registry[name].offset

    def resolve(self, addr: int) -> tuple[SymHandle, int]:
        """Inverse mapping: symmetric address -> (object, byte offset).

        ``addr_remote = heap_remote + (addr_local − heap_local)``: since
        our symmetric address space *is* the offset, resolution is a
        bisect over the sorted live-object offsets — O(log n) in the
        number of symmetric objects (the paper gets O(1) from raw
        pointer arithmetic; a log factor over the *object index* is the
        faithful analogue when objects are named arrays).
        """
        j = bisect.bisect_right(self._sorted_offsets, addr) - 1
        if j >= 0:
            h = self._sorted_handles[j]
            if h.offset <= addr < h.offset + h.nbytes:
                return h, addr - h.offset
        raise KeyError(f"address {addr} not inside any symmetric object")

    # ------------------------------------------------------------------
    # state — the actual arrays (functional pytree)
    # ------------------------------------------------------------------
    def zeros_state(self) -> HeapState:
        """Per-PE heap contents, to be created inside (or passed into)
        ``shard_map``.  One array per live symmetric object."""
        return {h.name: jnp.zeros(h.shape, h.dtype)
                for h in self.registry.values()}

    def spec_state(self) -> dict:
        """ShapeDtypeStructs for the per-PE state (dry-run use)."""
        return {h.name: jax.ShapeDtypeStruct(h.shape, h.dtype)
                for h in self.registry.values()}

    # ------------------------------------------------------------------
    # Lemma 1 — temporary scratch inside collectives
    # ------------------------------------------------------------------
    @contextmanager
    def scratch(self, shape, dtype, tag: str = "scratch") -> Iterator[SymHandle]:
        """Temporary symmetric allocation used inside a collective.

        Lemma 1 (paper §4.5.3): such allocations do not break heap
        symmetry *provided they are released before the collective
        returns*.  The context manager enforces exactly that, and the
        property test drives random collective sequences checking that
        the registry fingerprint is unchanged afterwards.
        """
        name = f"__{tag}_{len(self.registry)}_{self._scratch_counter()}"
        h = self.alloc(name, shape, dtype)
        try:
            yield h
        finally:
            self.free(h)

    def _scratch_counter(self) -> int:
        """Per-instance sequence so two heaps (or repeated test runs)
        produce identical scratch names — class-level state would leak
        counts across instances and break name determinism."""
        self._scratch_seq += 1
        return self._scratch_seq

    # ------------------------------------------------------------------
    def fingerprint(self) -> str:
        """Stable digest of the registry — equal across PEs iff the heap
        is symmetric.  Used by tests for Fact 1 / Lemma 1."""
        m = hashlib.sha256()
        for name in sorted(self.registry):
            h = self.registry[name]
            m.update(f"{name}:{h.shape}:{h.dtype}:{h.offset}".encode())
        return m.hexdigest()

    def used_bytes(self) -> int:
        return sum(b.nbytes for b in self._blocks if not b.free)

    def frag_blocks(self) -> int:
        return sum(1 for b in self._blocks if b.free)


def _align_up(x: int, a: int) -> int:
    return (x + a - 1) & ~(a - 1)
