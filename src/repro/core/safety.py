"""POSH ``_SAFE`` / ``_DEBUG`` compile modes, re-realized as trace-time flags.

The paper compiles safety checks in or out with cpp macros so that the
release binary has zero branches (§4.7).  The JAX analogue is exact:
checks guarded by a Python-level flag either appear in the jaxpr or do
not exist at all.  ``safe_mode(True)`` enables:

  * static shape/dtype symmetry checks on every collective argument
    (the paper's "buffer size equals data size" check, §4.5.5),
  * a collective nesting guard — a PE must not start a collective while
    another is in progress on the same team (§4.7 safe mode),
  * op-tag matching: all PEs of a team must run the *same* collective
    (trivially true under SPMD, but the tag is still recorded so that
    hand-written schedules composed from p2p rounds can be audited).

``debug_mode(True)`` additionally inserts ``jax.debug.print`` progress
lines (the analogue of POSH's ``_DEBUG`` logging).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any

import jax
import jax.numpy as jnp

_state = threading.local()


def _flags():
    if not hasattr(_state, "safe"):
        _state.safe = False
        _state.debug = False
        _state.in_progress = []  # stack of (team_axes, op_tag)
    return _state


def safe_mode(enabled: bool = True) -> None:
    _flags().safe = enabled


def debug_mode(enabled: bool = True) -> None:
    _flags().debug = enabled


def is_safe() -> bool:
    return _flags().safe


def is_debug() -> bool:
    return _flags().debug


class PoshSafetyError(RuntimeError):
    pass


@contextlib.contextmanager
def collective_guard(team_axes: tuple[str, ...], op_tag: str):
    """Trace-time re-entrancy guard (paper §4.7: "check that when a process
    wants to run a collective communication, it is not already
    participating to another collective communication").

    Exception-safe by construction: exit removes exactly THIS guard's
    frame (by identity, searched from the top) rather than blind-popping
    the stack tail — a raise out of a nested collective, or a misbehaved
    inner guard, can therefore never strip someone else's frame and
    poison every later ``safe_mode`` check on the thread."""
    st = _flags()
    if st.safe:
        for axes, tag in st.in_progress:
            if set(axes) & set(team_axes):
                raise PoshSafetyError(
                    f"collective '{op_tag}' on {team_axes} started while "
                    f"'{tag}' on {axes} is in progress"
                )
    entry = (team_axes, op_tag)
    st.in_progress.append(entry)
    try:
        if st.debug:
            jax.debug.print("posh: >> {} on " + str(team_axes), op_tag)
        yield
        if st.debug:
            jax.debug.print("posh: << {} on " + str(team_axes), op_tag)
    finally:
        for i in range(len(st.in_progress) - 1, -1, -1):
            if st.in_progress[i] is entry:
                del st.in_progress[i]
                break


def check_symmetric_arg(x: Any, op_tag: str) -> None:
    """Static checks — free at run time, exactly like POSH's ``_SAFE``."""
    if not is_safe():
        return
    if not isinstance(x, (jax.Array, jnp.ndarray)) and not hasattr(x, "shape"):
        raise PoshSafetyError(f"{op_tag}: argument is not an array: {type(x)}")
    if any(d <= 0 for d in getattr(x, "shape", ())):
        raise PoshSafetyError(f"{op_tag}: degenerate buffer shape {x.shape}")


def check_same_size(a, b, op_tag: str) -> None:
    if not is_safe():
        return
    if a.size != b.size:
        raise PoshSafetyError(
            f"{op_tag}: buffer size mismatch {a.shape} vs {b.shape} "
            "(paper §4.5.5 run-time error checking)"
        )
