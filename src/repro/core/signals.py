"""Put-with-signal: per-transfer completion on the CommQueue.

POSH's §3.2 model has exactly two drain points — ``fence`` (ordering
per destination) and ``quiet`` (the full completion barrier) — so any
consumer that wants ONE producer's payload must today pay for everyone
else's outstanding traffic too.  Modern OpenSHMEM extensions (see
"Toward a Unified GPU-Aware OpenSHMEM Specification" and "Intel SHMEM:
GPU-initiated OpenSHMEM using SYCL" in PAPERS.md) close that gap with
``shmem_put_signal`` / ``shmem_signal_wait_until``: the put carries a
*signal word* update that the target delivers only after the payload,
and the consumer spins on just that word.

This module is the API surface for that extension over
:class:`repro.core.ordering.CommQueue`:

  * ``put_signal_nbi(queue, handle, data, pairs, sig_handle, value)``
    enqueues the payload put plus the guarded signal update.  Within
    any drain the signal is delivered AFTER the payload — the single
    ordering edge added to the otherwise-unordered delivery shuffle
    (``CommQueue._signal_fixup``).
  * ``signal_wait_until(queue, sig_handle, cmp, value)`` drains exactly
    the puts guarding that word — payloads first — and nothing else.
    A satisfied wait therefore implies the guarded payload is visible,
    and ONLY that payload (the property ``tests/test_ordering.py``
    checks against the PR-2 maximal-write oracle).

Signal words are ordinary symmetric objects: :class:`SignalPad` carves
``n`` of them from a :class:`~repro.core.heap.SymmetricHeap` (one word
per handoff ticket in ``repro.serve.disagg``), so Fact 1 gives every PE
the pad at the same offset and a ticket index IS the remote address of
its word.
"""
from __future__ import annotations

import operator
from typing import TYPE_CHECKING, Optional

import numpy as np

from .heap import SymHandle, SymmetricHeap

if TYPE_CHECKING:                         # avoid a runtime cycle
    from .ordering import CommQueue, HeapState, Pairs

# comparison spellings (SHMEM_CMP_*)
CMP_EQ = "eq"
CMP_NE = "ne"
CMP_GT = "gt"
CMP_GE = "ge"
CMP_LT = "lt"
CMP_LE = "le"

# signal-update ops (SHMEM_SIGNAL_*)
SIGNAL_SET = "set"
SIGNAL_ADD = "add"

_CMPS = {CMP_EQ: operator.eq, CMP_NE: operator.ne, CMP_GT: operator.gt,
         CMP_GE: operator.ge, CMP_LT: operator.lt, CMP_LE: operator.le}


def cmp_ok(cur, cmp: str, value) -> bool:
    """Evaluate one SHMEM_CMP_* comparison against a signal word."""
    try:
        fn = _CMPS[cmp]
    except KeyError:
        raise ValueError(f"unknown signal comparison {cmp!r} "
                         f"(want one of {sorted(_CMPS)})") from None
    return bool(fn(cur, value))


# ======================================================================
# free-function OpenSHMEM spellings
# ======================================================================
def put_signal_nbi(queue: "CommQueue", handle: SymHandle, data,
                   pairs: "Pairs", sig_handle: SymHandle, sig_value, *,
                   offset=0, sig_offset=0, sig_op: str = SIGNAL_SET) -> int:
    """``shmem_put_signal_nbi`` — payload put + guarded signal update
    onto ``queue``.  Drained per-transfer by ``signal_wait_until`` on
    the same word (or by any covering fence/quiet)."""
    return queue.put_signal_nbi(  # shmem: deferred-drain
        handle, data, pairs, sig_handle, sig_value, offset=offset,
        sig_offset=sig_offset, sig_op=sig_op)


def signal_wait_until(queue: "CommQueue", sig_handle: SymHandle,
                      cmp: str, value, *, sig_offset=0,
                      pe: Optional[int] = None) -> "HeapState":
    """``shmem_signal_wait_until`` — per-transfer drain point: delivers
    exactly the puts guarding the named signal word, then checks the
    settled word against ``cmp``/``value`` (raising where the real call
    would spin forever)."""
    return queue.signal_wait_until(sig_handle, cmp, value,
                                   sig_offset=sig_offset, pe=pe)


# ======================================================================
# signal words as symmetric objects
# ======================================================================
class SignalPad:
    """``n`` signal words carved from the symmetric heap — one per
    in-flight handoff ticket.  The pad is one symmetric allocation, so
    a ticket's word lives at the same offset on every PE (Fact 1) and
    ``word(ticket)`` is its remote address on any of them.  Tickets
    recycle words round-robin; callers must retire (wait on) a word
    before its slot comes around again — ``repro.serve.disagg`` sizes
    the pad past its in-flight bound, so recycling never outruns the
    waits."""

    def __init__(self, heap: SymmetricHeap, n: int, *,
                 name: str = "sig_words", dtype=np.int64):
        if n < 1:
            raise ValueError("SignalPad needs at least one word")
        self.n = int(n)
        self.handle: SymHandle = heap.alloc(name, (self.n,),
                                            np.dtype(dtype))

    def word(self, ticket: int) -> int:
        """The pad offset of ``ticket``'s signal word."""
        return int(ticket) % self.n

    def zeros(self) -> np.ndarray:
        """A cleared pad object (initial heap-state value)."""
        return np.zeros((self.n,), self.handle.dtype)
