"""repro.core — the paper's contribution: POSH (Paris OpenSHMEM) re-built
as a TPU-native one-sided communication layer.

Public API (mirrors OpenSHMEM 1.0 naming where meaningful):

    SymmetricHeap, SymHandle        symmetric heap + allocator (§3.1, §4.1)
    put, get, ring_shift            one-sided p2p rounds (§3.2)
    heap_put, heap_get, heap_p/g    offset-addressed remote access (Cor. 1)
    CommQueue, put_nbi, get_nbi,
    fence, quiet                    ordered nonblocking pipeline (§3.2
                                    completion model: puts complete
                                    locally at issue; delivery is
                                    unordered until fence — per-dst —
                                    or quiet — full barrier)
    put_signal_nbi,
    signal_wait_until, SignalPad    put-with-signal per-transfer
                                    completion (the shmem_put_signal
                                    extension; see core.signals)
    barrier_all, broadcast,
    fcollect, reduce, allreduce,
    reduce_scatter, alltoall        collectives on p2p (§4.5)
    atomic_fadd/swap/cswap,
    TicketLock                      §4.6 adaptation (owner-computes)
    atomic_*_nbi, amo_wait          §4.6 on the queue path: nonblocking
                                    fetch-&-op, its own linearization
                                    point, drained like a signal
    Team, ActiveSet                 PE addressing (§4.7)
    safe_mode, debug_mode           _SAFE/_DEBUG compile modes (§4.7)
"""
from .atomics import (TicketLock, amo_wait, atomic_cswap,
                      atomic_cswap_nbi, atomic_fadd, atomic_fadd_nbi,
                      atomic_fetch_nbi, atomic_swap, atomic_swap_nbi)
from .collectives import (allreduce, alltoall, barrier_all, broadcast,
                          fcollect, reduce, reduce_scatter)
from .heap import HeapState, SymHandle, SymmetricHeap
from .ordering import (CommQueue, LocalTransport, NbiValue, PermuteTransport,
                       Transport, fence, get_nbi, put_nbi, quiet)
from .p2p import get, heap_g, heap_get, heap_p, heap_put, put, ring_shift
from .safety import (PoshSafetyError, debug_mode, is_debug, is_safe,
                     safe_mode)
from .signals import (CMP_EQ, CMP_GE, CMP_GT, CMP_LE, CMP_LT, CMP_NE,
                      SIGNAL_ADD, SIGNAL_SET, SignalPad, cmp_ok,
                      put_signal_nbi, signal_wait_until)
from .teams import ActiveSet, Team, TeamAxes, my_pe, team_size

__all__ = [
    "SymmetricHeap", "SymHandle", "HeapState",
    "put", "get", "ring_shift", "heap_put", "heap_get", "heap_p", "heap_g",
    "CommQueue", "NbiValue", "Transport", "PermuteTransport",
    "LocalTransport", "put_nbi", "get_nbi", "fence", "quiet",
    "put_signal_nbi", "signal_wait_until", "SignalPad", "cmp_ok",
    "CMP_EQ", "CMP_NE", "CMP_GT", "CMP_GE", "CMP_LT", "CMP_LE",
    "SIGNAL_SET", "SIGNAL_ADD",
    "barrier_all", "broadcast", "fcollect", "reduce", "allreduce",
    "reduce_scatter", "alltoall",
    "atomic_fadd", "atomic_swap", "atomic_cswap", "TicketLock",
    "atomic_fetch_nbi", "atomic_fadd_nbi", "atomic_swap_nbi",
    "atomic_cswap_nbi", "amo_wait",
    "Team", "ActiveSet", "TeamAxes", "my_pe", "team_size",
    "safe_mode", "debug_mode", "is_safe", "is_debug", "PoshSafetyError",
]
