"""The ordered one-sided pipeline: nonblocking put/get with fence/quiet.

This module puts the paper's *formal* contribution — its communication
and memory model (§3.2) — into code.  POSH proves that

  * ``put`` completes **locally** as soon as the call returns: the
    source buffer may be reused immediately, the payload is a snapshot
    taken at issue time;
  * remote **delivery** is unordered until an ordering point: two puts
    to the same destination may land in either order;
  * ``shmem_fence`` orders delivery *per destination*: every put issued
    before the fence is delivered before any put issued after it;
  * ``shmem_quiet`` is the full completion barrier: on return, every
    outstanding put is delivered and every outstanding get has its
    value.

The pipeline here realizes exactly that model.  ``put_nbi``/``get_nbi``
enqueue :class:`PendingPut`/:class:`PendingGet` records onto a
:class:`CommQueue`; nothing moves until a drain point.  ``fence(dst)``
drains the puts targeting ``dst`` (all destinations when ``dst`` is
None) — delivering them *now* is the strongest valid implementation of
the ordering guarantee.  ``quiet()`` drains everything.  Within one
drain the delivery order is deliberately **not** program order: it is a
deterministic shuffle keyed by ``delivery_seed``, so tests can replay
the same issue sequence under many legal delivery interleavings and
check that only the orderings the paper actually promises hold (see
``tests/test_ordering.py`` — the property test enumerates the model's
maximal-write candidate sets and asserts the implementation always
lands inside them).

Local completion is automatic in JAX: traced arrays are immutable, so
the value captured at ``put_nbi`` time *is* the snapshot — later writes
produce new arrays and cannot retroactively change the payload.  What
the queue adds on top is the scheduling freedom: between issue and
drain the ppermutes do not exist yet, and at the drain they materialize
as a batch of independent collective-permutes with no serializing data
dependencies between different destinations — which is what lets XLA
overlap them with compute (the training loop exploits this through
``allreduce_nbi``; see ``repro.train.grad.overlapped_grad_sync``).

Data motion is pluggable through a :class:`Transport`:

  PermuteTransport   the real thing — ``p2p.heap_put``/``heap_get``
                     collective-permute rounds, for use inside
                     ``shard_map`` (default).
  LocalTransport     a whole-system numpy simulation (state arrays
                     carry a leading PE axis) used by the property
                     tests and by single-process reasoning about the
                     model — the oracle the permute transport is
                     checked against in ``tests/multipe/run_ordering.py``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import random
from typing import Any, Callable, Optional, Sequence

import numpy as np

from . import p2p
from .heap import HeapState, SymHandle
from .teams import Team, TeamAxes

Pairs = Sequence[tuple[int, int]]

# repro.analysis.shmemcheck hook slot.  None when the checker is off —
# an instrumented call site then costs one global load plus an is-None
# test, the trace-time analogue of compiling POSH without _SAFE (§4.7).
# ``shmemcheck.enable()`` installs a checker here; REPRO_SHMEMCHECK=1
# does the same lazily at first queue construction (one-shot, so
# ``shmemcheck.suspended()`` is not silently re-armed).
_checker = None
_AUTOENV = os.environ.get("REPRO_SHMEMCHECK") == "1"


def _autoenable() -> None:
    global _AUTOENV
    if _AUTOENV:
        _AUTOENV = False
        from repro.analysis import shmemcheck
        shmemcheck.enable()


# ======================================================================
# pending-op records
# ======================================================================
@dataclasses.dataclass
class PendingPut:
    """One issued-but-undelivered put.  ``data`` is the issue-time
    snapshot (local completion); ``seq`` is the global issue index.

    A put-with-signal (``CommQueue.put_signal_nbi``) enqueues TWO of
    these: the payload put, and a signal-word update whose ``signal``
    field carries ``(op, value)`` (``data`` is None) and whose
    ``signal_of`` names the payload's seq — the one delivery-order
    constraint the model adds: within any drain the signal lands
    after its payload (see ``_drain_order``)."""

    seq: int
    handle: SymHandle
    data: Any
    pairs: list[tuple[int, int]]
    offset: Any
    signal: Optional[tuple] = None        # (op, value) for signal words
    signal_of: Optional[int] = None       # payload seq this signal guards

    def dsts(self) -> set[int]:
        return {d for _, d in self.pairs}


@dataclasses.dataclass
class PendingGet:
    seq: int
    handle: SymHandle
    pairs: list[tuple[int, int]]
    offset: Any
    size: Optional[int]
    result: "NbiValue"


@dataclasses.dataclass
class PendingAmo:
    """One issued-but-undelivered atomic memory operation
    (``CommQueue.amo_nbi`` — the §4.6 fetch-&-op family on the queue
    path).  An AMO is its own linearization point: within a drain it is
    shuffled with the puts like any other op, and the drain order IS
    the linearization order — two AMOs on the same word are never a
    race, whichever lands first simply linearizes first.  ``result``
    receives the fetched (pre-op) value at delivery.  Drained like a
    signal: ``amo_wait`` on the word retires exactly the AMOs guarding
    it (or any covering fence/quiet).

    ``signal``/``signal_of`` exist only so the drain machinery
    (shuffle fixup, coalescer) can treat the three op classes
    uniformly; an AMO never participates in either."""

    seq: int
    handle: SymHandle
    offset: int
    pairs: list[tuple[int, int]]
    op: str                               # "fadd"|"swap"|"cswap"|"fetch"
    value: Any = None
    cond: Any = None
    result: Optional["NbiValue"] = None
    signal: Optional[tuple] = None        # never set; drain-shape parity
    signal_of: Optional[int] = None

    def dsts(self) -> set[int]:
        return {d for _, d in self.pairs}


@dataclasses.dataclass
class PendingReduce:
    """A nonblocking collective reduction (the train-loop user of the
    queue).  Delivered at ``quiet()`` in issue order — reductions are
    collectives, not one-sided writes, so the paper's unordered-delivery
    freedom does not apply to them; issue order keeps the float
    reduction bit-identical to the blocking path."""

    seq: int
    data: Any
    deliver: Callable[[Any], Any]
    result: "NbiValue"


class NbiValue:
    """Deferred result of a nonblocking get/reduction.  ``value()`` is
    legal only after the owning queue's ``quiet()`` — reading earlier is
    the programming error the paper's model forbids, and raising is the
    safe-mode analogue of the undefined behaviour you would get from a
    real NIC."""

    __slots__ = ("_value", "_ready", "_tag")

    def __init__(self, tag: str = "nbi"):
        self._value = None
        self._ready = False
        self._tag = tag

    def _deliver(self, value) -> None:
        self._value = value
        self._ready = True

    @property
    def ready(self) -> bool:
        return self._ready

    def value(self):
        if not self._ready:
            raise RuntimeError(
                f"{self._tag}: nonblocking result read before quiet() — "
                "the paper's model leaves this undefined; call "
                "CommQueue.quiet() first")
        return self._value


# ======================================================================
# transports — who actually moves the bytes at a drain point
# ======================================================================
class Transport:
    """Delivery mechanism for drained ops.  ``state`` is a HeapState;
    array layout is transport-defined (per-PE shard for the permute
    transport, full (n_pe, ...) system state for the local one).

    ``put_rows``/``concat_puts`` describe the transport's payload layout
    to the queue's drain-time coalescer: how many object rows one put
    covers, and how two payloads concatenate into one.  A transport that
    returns ``None`` from ``concat_puts`` opts out of coalescing."""

    def put(self, state: HeapState, handle: SymHandle, data, pairs: Pairs,
            team: Team, offset) -> HeapState:
        raise NotImplementedError

    def get(self, state: HeapState, handle: SymHandle, pairs: Pairs,
            team: Team, offset, size: Optional[int]):
        raise NotImplementedError

    def put_signal(self, state: HeapState, handle: SymHandle, value,
                   pairs: Pairs, team: Team, offset, op: str) -> HeapState:
        """Deliver one signal-word update (``shmem_put_signal``'s
        second half).  ``op`` is ``"set"`` (overwrite) or ``"add"``
        (fetch-accumulate, SHMEM_SIGNAL_ADD)."""
        raise NotImplementedError

    def amo(self, state: HeapState, handle: SymHandle, op: str, value,
            cond, pairs: Pairs, team: Team, offset):
        """Deliver one atomic memory operation on ``handle[offset]`` of
        the owner PE (the ``dst`` of the single pair) and return
        ``(new_state, old_value)`` — the fetched pre-op value the
        requester observes.  ``op``: ``"fadd"``/``"swap"``/``"cswap"``
        (``cond`` used)/``"fetch"`` (read-only)."""
        raise NotImplementedError

    def put_rows(self, data) -> Optional[int]:
        return None                       # unknown layout: no coalescing

    def concat_puts(self, datas):
        return None


class PermuteTransport(Transport):
    """The real data path: one collective-permute round per delivery,
    addressed through the symmetric heap (Corollary 1).  Must run
    inside ``shard_map`` over the team's axes."""

    def put(self, state, handle, data, pairs, team, offset):
        return p2p.heap_put(state, handle, data, pairs, team, offset=offset)

    def get(self, state, handle, pairs, team, offset, size):
        return p2p.heap_get(state, handle, pairs, team, offset=offset,
                            size=size)

    def put_signal(self, state, handle, value, pairs, team, offset, op):
        import jax.numpy as jnp
        if op == "add":
            # fetch-accumulate needs a remote read; the permute path is
            # write-only one round, so additive signals stay local-only
            raise NotImplementedError(
                "PermuteTransport delivers 'set' signals only")
        data = jnp.full((1,), value, handle.dtype)
        return p2p.heap_put(state, handle, data, pairs, team, offset=offset)

    def amo(self, state, handle, op, value, cond, pairs, team, offset):
        # a queue AMO is a remote read-modify-write round trip; the
        # permute path is write-only one round.  The SPMD mesh gets its
        # linearizable atomics from the owner-computes collectives in
        # core.atomics (same precedent as the 'add' signal above).
        raise NotImplementedError(
            "PermuteTransport has no AMO round — use the owner-computes "
            "atomics in repro.core.atomics inside shard_map")

    def put_rows(self, data):
        shape = getattr(data, "shape", None)
        return int(shape[0]) if shape else 1

    def concat_puts(self, datas):
        import jax.numpy as jnp
        return jnp.concatenate([jnp.asarray(d) for d in datas], axis=0)


class LocalTransport(Transport):
    """Whole-system simulation: every state array carries a leading PE
    axis, so one process sees all ``n_pe`` heaps at once.  This is the
    oracle the property tests replay interleavings against — numpy,
    no tracing, hundreds of examples per second."""

    def __init__(self, n_pe: int):
        self.n_pe = int(n_pe)

    def put(self, state, handle, data, pairs, team, offset):
        out = dict(state)
        out[handle.name] = buf = np.array(state[handle.name])
        data = np.asarray(data)
        rows = data.shape[1] if data.ndim > 1 else 1
        for s, d in pairs:
            buf[d, offset:offset + rows] = data[s]
        return out

    def get(self, state, handle, pairs, team, offset, size):
        buf = np.asarray(state[handle.name])
        size = buf.shape[1] - offset if size is None else size
        out = np.zeros((self.n_pe, size) + buf.shape[2:], buf.dtype)
        for owner, reader in pairs:
            out[reader] = buf[owner, offset:offset + size]
        return out

    def put_signal(self, state, handle, value, pairs, team, offset, op):
        out = dict(state)
        out[handle.name] = buf = np.array(state[handle.name])
        for _, d in pairs:
            if op == "add":
                buf[d, offset] += value
            else:
                buf[d, offset] = value
        return out

    def amo(self, state, handle, op, value, cond, pairs, team, offset):
        out = dict(state)
        out[handle.name] = buf = np.array(state[handle.name])
        flat = buf.reshape(buf.shape[0], -1)
        (_, owner), = pairs               # one requester, one owner
        old = flat[owner, offset].item()
        if op == "fadd":
            flat[owner, offset] = old + value
        elif op == "swap":
            flat[owner, offset] = value
        elif op == "cswap":
            if old == cond:
                flat[owner, offset] = value
        elif op != "fetch":
            raise ValueError(f"unknown AMO op {op!r}")
        return out, old

    def put_rows(self, data):
        data = np.asarray(data)
        return int(data.shape[1]) if data.ndim > 1 else 1

    def concat_puts(self, datas):
        datas = [np.asarray(d) for d in datas]
        datas = [d[:, None] if d.ndim == 1 else d for d in datas]
        return np.concatenate(datas, axis=1)


# ======================================================================
# the queue
# ======================================================================
class CommQueue:
    """Ordered communication pipeline over a team.

    ``put_nbi``/``get_nbi`` enqueue; ``fence``/``quiet`` are the
    drain points (the paper's §3.2 ordering model), plus
    ``signal_wait_until`` as the per-transfer completion the
    put-with-signal extension adds (``core.signals``).  The queue owns
    the heap state between drains::

        q = CommQueue(team, heap.zeros_state())
        q.put_nbi(h, x, pairs)            # returns immediately
        q.put_nbi(h, y, pairs2)           # unordered wrt the first ...
        q.fence()                         # ... until here
        q.put_nbi(h, z, pairs)            # ordered after x and y
        state = q.quiet()                 # everything delivered

    ``delivery_seed`` keys the intra-drain delivery shuffle: every seed
    is a legal execution under the model; ``None`` means issue order.
    Tests sweep seeds to check that programs relying only on fence/quiet
    ordering are seed-invariant and that anything stronger is not
    accidentally guaranteed.
    """

    def __init__(self, team: TeamAxes, state: Optional[HeapState] = None,
                 *, transport: Optional[Transport] = None,
                 delivery_seed: Optional[int] = None):
        if _AUTOENV:
            _autoenable()
        self.team = Team.of(team)
        self._state: HeapState = dict(state or {})
        self.transport = transport or PermuteTransport()
        self.delivery_seed = delivery_seed
        self._puts: list[PendingPut] = []
        self._gets: list[PendingGet] = []
        self._reduces: list[PendingReduce] = []
        # signal-word guard map: (sig object name, word offset) -> the
        # pending seqs (payload AND signal updates) a wait on that word
        # retires.  signal_wait_until pops its key — per-transfer
        # completion, the third drain class next to fence/quiet.
        self._sig_guards: dict[tuple[str, int], list[int]] = {}
        # AMO guard map, same shape: (object name, word offset) -> the
        # pending AMO seqs an amo_wait on that word retires.
        self._amo_guards: dict[tuple[str, int], list[int]] = {}
        self._seq = 0
        self._stats = {"puts": 0, "gets": 0, "reduces": 0, "fences": 0,
                       "quiets": 0, "drained": 0, "max_pending": 0,
                       "coalesced": 0, "signal_puts": 0,
                       "signal_waits": 0, "signal_resets": 0,
                       "amos": 0, "amo_waits": 0}
        # named counter windows (``phase``): accumulated stat deltas per
        # phase name, e.g. the weight hot-swap attributing its traffic
        self._phase_stats: dict[str, dict] = {}
        self._phase: Optional[tuple] = None

    # ------------------------------------------------------------------
    # issue side — returns immediately (local completion)
    # ------------------------------------------------------------------
    def put_nbi(self, handle: SymHandle, data, pairs: Pairs,
                offset=0) -> int:
        """``shmem_put_nbi``: enqueue a put.  Completes locally now —
        ``data`` is snapshotted by value; remote delivery waits for the
        next ``fence``/``quiet`` covering its destinations.  Returns the
        issue sequence number (for debugging/stats)."""
        pairs = [(int(s), int(d)) for s, d in pairs]
        if isinstance(data, np.ndarray):
            # numpy is mutable: snapshot now so the caller may reuse the
            # buffer immediately (traced jax arrays are immutable and
            # already have snapshot semantics by construction)
            data = data.copy()
        op = PendingPut(self._next_seq(), handle, data, pairs, offset)
        self._puts.append(op)
        self._stats["puts"] += 1
        self._track_pending()
        if _checker is not None:
            _checker.on_put_nbi(self, handle, data, pairs, offset, op.seq)
        return op.seq

    def put_signal_nbi(self, handle: SymHandle, data, pairs: Pairs,
                       sig_handle: SymHandle, sig_value, *, offset=0,
                       sig_offset=0, sig_op: str = "set") -> int:
        """``shmem_put_signal_nbi``: enqueue the payload put PLUS a
        signal-word update that is delivered only AFTER the payload —
        the one intra-drain ordering edge the model adds on top of
        §3.2's unordered delivery.  ``sig_handle``/``sig_offset`` name
        one word of a symmetric signal object (see ``core.signals``);
        ``sig_op`` is ``"set"`` or ``"add"`` (SHMEM_SIGNAL_SET/ADD).
        The pair is drained by ``signal_wait_until`` on that word (or
        by any fence/quiet covering it).  Returns the payload's issue
        seq."""
        pairs = [(int(s), int(d)) for s, d in pairs]
        if sig_op not in ("set", "add"):
            raise ValueError(f"put_signal_nbi: bad sig_op {sig_op!r} "
                             "(want 'set' or 'add')")
        if isinstance(data, np.ndarray):
            data = data.copy()            # local completion (see put_nbi)
        payload = PendingPut(self._next_seq(), handle, data, pairs, offset)
        self._puts.append(payload)
        sig = PendingPut(self._next_seq(), sig_handle, None, pairs,
                         int(sig_offset), signal=(sig_op, sig_value),
                         signal_of=payload.seq)
        self._puts.append(sig)
        self._stats["puts"] += 1
        self._stats["signal_puts"] += 1
        key = (sig_handle.name, int(sig_offset))
        self._sig_guards.setdefault(key, []).extend((payload.seq, sig.seq))
        self._track_pending()
        if _checker is not None:
            _checker.on_put_signal(self, handle, data, pairs, offset,
                                   payload.seq, sig_handle,
                                   int(sig_offset), sig.seq)
        return payload.seq

    def amo_nbi(self, handle: SymHandle, op: str, pairs: Pairs, *,
                value=None, cond=None, offset=0) -> NbiValue:
        """Enqueue one atomic memory operation (§4.6 fetch-&-op on the
        queue path): ``op`` is ``"fadd"`` (add ``value``), ``"swap"``
        (write ``value``), ``"cswap"`` (write ``value`` iff the word
        equals ``cond``) or ``"fetch"`` (read only).  ``pairs`` is ONE
        ``(requester, owner)`` pair — the word ``handle[offset]`` on
        the owner's heap is the linearization cell.

        Completion semantics: the AMO is its own linearization point.
        It is delivered — atomically, at one place in the intra-drain
        shuffle — by the next ``amo_wait`` on its word, or by any
        covering ``fence``/``quiet``; the returned :class:`NbiValue`
        then holds the fetched pre-op value.  Two pending AMOs on one
        word are NOT a race (the drain order linearizes them); an AMO
        overlapping a plain ``put_nbi`` IS (shmemcheck's ``amo-race``).
        """
        pairs = [(int(s), int(d)) for s, d in pairs]
        if len(pairs) != 1:
            raise ValueError(
                f"amo_nbi[{handle.name}]: an AMO targets exactly one "
                f"(requester, owner) pair, got {len(pairs)}")
        if op not in ("fadd", "swap", "cswap", "fetch"):
            raise ValueError(f"amo_nbi: unknown op {op!r} (want fadd/"
                             "swap/cswap/fetch)")
        if op == "cswap" and cond is None:
            raise ValueError("amo_nbi: cswap needs cond")
        if op in ("fadd", "swap", "cswap") and value is None:
            raise ValueError(f"amo_nbi: {op} needs value")
        res = NbiValue(f"amo_nbi[{handle.name}:{op}]")
        amo = PendingAmo(self._next_seq(), handle, int(offset), pairs,
                         op, value, cond, res)
        self._puts.append(amo)
        self._stats["amos"] += 1
        self._amo_guards.setdefault((handle.name, int(offset)),
                                    []).append(amo.seq)
        self._track_pending()
        if _checker is not None:
            _checker.on_amo(self, handle, int(offset), pairs, amo.seq, op)
        return res

    def get_nbi(self, handle: SymHandle, pairs: Pairs, offset=0,
                size: Optional[int] = None) -> NbiValue:
        """``shmem_get_nbi``: enqueue a get.  The returned
        :class:`NbiValue` becomes readable after ``quiet()``; it
        observes every put delivered by that quiet (gets are satisfied
        after the put drain, the conservative reading of the model).

        ``size=None`` means "the rest of the object from ``offset``" —
        resolved here (statically) so both transports see the same
        concrete extent; a traced offset therefore needs an explicit
        size."""
        pairs = [(int(s), int(d)) for s, d in pairs]
        if size is None:
            if not isinstance(offset, (int, np.integer)):
                raise ValueError(
                    f"get_nbi[{handle.name}]: explicit size required "
                    "when offset is traced")
            size = int(handle.shape[0]) - int(offset)
            if size <= 0:
                raise ValueError(
                    f"get_nbi[{handle.name}]: offset {offset} leaves no "
                    f"rows in object of {handle.shape[0]}")
        res = NbiValue(f"get_nbi[{handle.name}]")
        op = PendingGet(self._next_seq(), handle, pairs, offset, size, res)
        self._gets.append(op)
        self._stats["gets"] += 1
        self._track_pending()
        if _checker is not None:
            _checker.on_get_nbi(self, handle, pairs, offset, size, op.seq)
        return res

    def allreduce_nbi(self, x, deliver: Callable[[Any], Any]) -> NbiValue:
        """Nonblocking collective reduction: ``deliver`` (e.g. a bound
        ``Communicator.psum``) runs at ``quiet()``.  Issue order is
        preserved across reductions so the drained program is
        bit-identical to the blocking sequence of the same calls —
        the property the overlapped training path is tested for."""
        res = NbiValue("allreduce_nbi")
        op = PendingReduce(self._next_seq(), x, deliver, res)
        self._reduces.append(op)
        self._stats["reduces"] += 1
        self._track_pending()
        return res

    # ------------------------------------------------------------------
    # drain side — fence / quiet, the only ordering points
    # ------------------------------------------------------------------
    def fence(self, dst: Optional[int] = None) -> None:
        """``shmem_fence``: order puts per destination.  Every pending
        put targeting ``dst`` (every destination when None) is delivered
        before this call returns, hence before anything issued later —
        delivery-at-fence is the strongest legal implementation of the
        paper's ordering-only guarantee."""
        if _checker is not None:
            _checker.on_fence(self, dst)
        self._stats["fences"] += 1
        if dst is None:
            todo, keep = self._puts, []
        else:
            todo = [p for p in self._puts if dst in p.dsts()]
            keep = [p for p in self._puts if dst not in p.dsts()]
        self._puts = keep
        self._deliver_puts(todo)

    def quiet(self) -> HeapState:
        """``shmem_quiet``: the full completion barrier.  Delivers every
        pending put (shuffled within the drain — they are mutually
        unordered), then satisfies gets against the settled state, then
        runs nonblocking reductions in issue order.  Returns the heap
        state; afterwards the queue is empty and every NbiValue is
        readable."""
        if _checker is not None:
            _checker.on_quiet(self)
            with _checker.draining(self):
                return self._quiet_impl()
        return self._quiet_impl()

    def _quiet_impl(self) -> HeapState:
        self._stats["quiets"] += 1
        todo, self._puts = self._puts, []
        self._sig_guards.clear()          # everything delivers below
        self._amo_guards.clear()
        self._deliver_puts(todo)
        gets, self._gets = self._gets, []
        for g in gets:
            val = self.transport.get(self._state, g.handle, g.pairs,
                                     self.team, g.offset, g.size)
            g.result._deliver(val)
            self._stats["drained"] += 1
        reduces, self._reduces = self._reduces, []
        for r in sorted(reduces, key=lambda r: r.seq):
            r.result._deliver(r.deliver(r.data))
            self._stats["drained"] += 1
        return self._state

    def signal_wait_until(self, sig_handle: SymHandle, cmp: str, value,
                          *, sig_offset=0, pe: Optional[int] = None
                          ) -> HeapState:
        """``shmem_signal_wait_until``: the per-transfer drain point.
        Delivers EXACTLY the pending puts guarding the named signal
        word — each payload before its signal update — and nothing
        else: every unrelated pending put stays pending, which is what
        makes this cheaper than a quiet (and what the property test
        pins: a satisfied wait implies the guarded payload is visible,
        and ONLY that payload).

        ``cmp`` is one of ``core.signals``'s CMP_* spellings; ``pe``
        names whose heap to check under a whole-system transport
        (LocalTransport).  When the settled word still fails the
        comparison — nothing pending could ever satisfy it — the real
        call would spin forever, so this raises instead.  Returns the
        heap state."""
        if _checker is not None:
            _checker.on_signal_wait(self, sig_handle, int(sig_offset))
        self._stats["signal_waits"] += 1
        key = (sig_handle.name, int(sig_offset))
        seqs = set(self._sig_guards.pop(key, ()))
        if seqs:
            todo = [p for p in self._puts if p.seq in seqs]
            self._puts = [p for p in self._puts if p.seq not in seqs]
            self._deliver_puts(todo)
        buf = self._state.get(sig_handle.name)
        word = None
        if isinstance(buf, np.ndarray):
            if isinstance(self.transport, LocalTransport):
                word = buf[int(pe)] if pe is not None else None
            else:
                word = buf
        if word is not None:
            from .signals import cmp_ok
            cur = word[int(sig_offset)]
            if not cmp_ok(int(cur), cmp, int(value)):
                raise RuntimeError(
                    f"signal_wait_until[{sig_handle.name}+{sig_offset}]: "
                    f"word is {int(cur)}, fails {cmp} {int(value)} with "
                    "no guarded put pending — this wait would block "
                    "forever")
        return self._state

    def amo_wait(self, handle: SymHandle, *, offset=0) -> HeapState:
        """The AMO drain point, ``signal_wait_until``'s sibling:
        delivers EXACTLY the pending AMOs targeting the named word —
        shuffled among themselves, each one an atomic linearization
        point — and nothing else.  Every unrelated pending op stays
        pending, so completing an allocator's counter traffic never
        costs a tick-global quiet (the lock-free-scheduling contract:
        ``stats()["quiets"]`` stays 0 on an allocator queue).  After
        the call every retired AMO's :class:`NbiValue` is readable.
        Returns the heap state."""
        if _checker is not None:
            _checker.on_amo_wait(self, handle, int(offset))
        self._stats["amo_waits"] += 1
        seqs = set(self._amo_guards.pop((handle.name, int(offset)), ()))
        if seqs:
            todo = [p for p in self._puts if p.seq in seqs]
            self._puts = [p for p in self._puts if p.seq not in seqs]
            self._deliver_puts(todo)
        return self._state

    def signal_reset(self, sig_handle: SymHandle, pairs: Pairs, *,
                     sig_offset=0, value=0) -> HeapState:
        """Recycle a retired signal/counter word: write ``value``
        (default 0) THROUGH the transport, immediately — not by
        host-side mutation of the state dict, so the write exists in
        the queue's memory model and shmemcheck sees it.  Only legal
        once the word's guarded transfers are all retired (resetting
        under in-flight guards is the signal-race shmemcheck flags).
        Counted under ``signal_resets``, never ``signal_puts`` — a
        reset is word housekeeping, not a transfer."""
        pairs = [(int(s), int(d)) for s, d in pairs]
        if _checker is not None:
            _checker.on_signal_reset(self, sig_handle, int(sig_offset),
                                     pairs)
        self._stats["signal_resets"] += 1
        self._state = self.transport.put_signal(
            self._state, sig_handle, value, pairs, self.team,
            int(sig_offset), "set")
        return self._state

    # ------------------------------------------------------------------
    def _deliver_puts(self, ops: list[PendingPut]) -> None:
        for op in self._coalesce(self._drain_order(ops)):
            if isinstance(op, PendingAmo):
                self._state, old = self.transport.amo(
                    self._state, op.handle, op.op, op.value, op.cond,
                    op.pairs, self.team, op.offset)
                op.result._deliver(old)
            elif op.signal is not None:
                sig_op, val = op.signal
                self._state = self.transport.put_signal(
                    self._state, op.handle, val, op.pairs, self.team,
                    op.offset, sig_op)
            else:
                self._state = self.transport.put(
                    self._state, op.handle, op.data, op.pairs, self.team,
                    op.offset)
            self._stats["drained"] += 1

    def _coalesce(self, ops: list[PendingPut]) -> list[PendingPut]:
        """Drain-time coalescing: merge runs of *adjacent-in-delivery-
        order* puts that target the same object through the same pair
        list and cover contiguous row ranges into ONE transport round.
        Merging only adjacent ops is semantics-preserving under any
        delivery order (nothing can interleave inside a run), so the
        fence/quiet model is untouched — the drain just issues fewer,
        larger permute rounds (the batch is already in hand here).
        Traced offsets opt out (contiguity is not statically known)."""
        if len(ops) < 2:
            return ops
        out: list[PendingPut] = []
        run: list[PendingPut] = []
        run_rows = 0

        def flush():
            nonlocal run, run_rows
            if len(run) > 1:
                merged = self.transport.concat_puts([o.data for o in run])
                if merged is not None:
                    self._stats["coalesced"] += len(run) - 1
                    out.append(PendingPut(run[0].seq, run[0].handle, merged,
                                          run[0].pairs, run[0].offset))
                else:
                    out.extend(run)
            else:
                out.extend(run)
            run, run_rows = [], 0

        for op in ops:
            if isinstance(op, PendingAmo) or op.signal is not None:
                flush()                   # AMOs and signal words are
                out.append(op)            # their own rounds, never merged
                continue
            rows = (self.transport.put_rows(op.data)
                    if isinstance(op.offset, (int, np.integer)) else None)
            if rows is None:
                flush()
                out.append(op)
                continue
            if (run and op.handle.name == run[0].handle.name
                    and op.pairs == run[0].pairs
                    and int(op.offset) == int(run[0].offset) + run_rows):
                run.append(op)
                run_rows += rows
            else:
                flush()
                run, run_rows = [op], rows
        flush()
        return out

    def _drain_order(self, ops: list[PendingPut]) -> list[PendingPut]:
        """Intra-drain delivery order: mutually unordered by the model,
        so any permutation is legal — EXCEPT that a signal-word update
        lands after the payload it guards (put-with-signal's one
        promise, restored by ``_signal_fixup`` after the shuffle).
        ``delivery_seed`` picks one deterministically; None keeps issue
        order (also legal, and payload-before-signal by issue)."""
        if self.delivery_seed is None or len(ops) < 2:
            return ops
        ops = list(ops)
        random.Random(self.delivery_seed).shuffle(ops)
        return self._signal_fixup(ops)

    @staticmethod
    def _signal_fixup(ops: list[PendingPut]) -> list[PendingPut]:
        """Move every signal update whose payload is in the same drain
        to just after that payload, preserving the shuffled order of
        everything else (the minimal repair: any shuffle with the
        constraint applied is still a legal delivery order)."""
        present = {op.seq for op in ops}
        emitted: set[int] = set()
        held: dict[int, list[PendingPut]] = {}
        out: list[PendingPut] = []

        def emit(op: PendingPut) -> None:
            out.append(op)
            emitted.add(op.seq)
            for sig in held.pop(op.seq, ()):
                emit(sig)

        for op in ops:
            if (op.signal_of is not None and op.signal_of in present
                    and op.signal_of not in emitted):
                held.setdefault(op.signal_of, []).append(op)
            else:
                emit(op)
        return out

    def _next_seq(self) -> int:
        self._seq += 1
        return self._seq

    def _track_pending(self) -> None:
        self._stats["max_pending"] = max(self._stats["max_pending"],
                                         self.pending_ops())

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------
    @property
    def state(self) -> HeapState:
        """The heap state as of the last drain.  Pending (undelivered)
        ops are NOT visible here — that is the point (and reading it
        with puts in flight is the wr-race shmemcheck flags)."""
        if _checker is not None:
            _checker.on_state_read(self)
        return self._state

    def pending_ops(self) -> int:
        return len(self._puts) + len(self._gets) + len(self._reduces)

    @contextlib.contextmanager
    def phase(self, name: str):
        """Attribute this queue's counter deltas to a named phase while
        the context is open.  Phases accumulate across entries, so a
        caller that re-enters once per serving tick (the weight
        hot-swap streamer) gets ONE running account of the traffic and
        drains it issued — ``stats()["phases"][name]["quiets"]`` is the
        authoritative "did this subsystem pay a global drain" counter
        (the ``swap_extra_quiets == 0`` pin).  Nesting is rejected: a
        delta can only be attributed once."""
        if self._phase is not None:
            raise RuntimeError(
                f"CommQueue.phase({name!r}): phase "
                f"{self._phase[0]!r} is still open — phases do not nest")
        before = dict(self._stats)
        self._phase = (name, before)
        try:
            yield self
        finally:
            self._phase = None
            acc = self._phase_stats.setdefault(
                name, {k: 0 for k in self._stats})
            for k, v in self._stats.items():
                acc[k] = acc.get(k, 0) + (v - before.get(k, 0))

    def phase_stats(self, name: str) -> dict:
        """The accumulated counter deltas of one named phase (all zeros
        if the phase never ran)."""
        base = {k: 0 for k in self._stats}
        base.update(self._phase_stats.get(name, {}))
        return base

    def stats(self) -> dict:
        """Counter snapshot.  On top of the raw counters, exposes the
        derived fields analysis tooling keys on: ``drains`` (fences +
        quiets — total happens-before edges inserted) and
        ``pending_by_dst`` (undelivered put count per destination PE,
        the live racy-window footprint)."""
        out = dict(self._stats)
        out["drains"] = out["fences"] + out["quiets"]
        out["phases"] = {n: dict(d) for n, d in self._phase_stats.items()}
        by_dst: dict[int, int] = {}
        for p in self._puts:
            for d in p.dsts():
                by_dst[d] = by_dst.get(d, 0) + 1
        out["pending_by_dst"] = by_dst
        return out


# ======================================================================
# free-function OpenSHMEM spellings
# ======================================================================
def put_nbi(queue: CommQueue, handle: SymHandle, data, pairs: Pairs,
            offset=0) -> int:
    """``shmem_put_nbi`` — nonblocking put onto ``queue``."""
    return queue.put_nbi(handle, data, pairs, offset=offset)  # shmem: deferred-drain


def get_nbi(queue: CommQueue, handle: SymHandle, pairs: Pairs, offset=0,
            size: Optional[int] = None) -> NbiValue:
    """``shmem_get_nbi`` — nonblocking get from ``queue``."""
    return queue.get_nbi(handle, pairs, offset=offset, size=size)  # shmem: deferred-drain


def fence(queue: CommQueue, dst: Optional[int] = None) -> None:
    """``shmem_fence`` — per-destination ordering point."""
    queue.fence(dst)


def quiet(queue: CommQueue) -> HeapState:
    """``shmem_quiet`` — full completion barrier."""
    return queue.quiet()
