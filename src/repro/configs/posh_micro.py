"""posh_micro — the paper's own 'architecture': the communication
microbenchmark configuration used for Tables 1–3 (buffer-size sweeps
for put/get/collectives).  Not an LM; exercised by benchmarks/.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class PoshMicroConfig:
    name: str = "posh-micro"
    family: str = "micro"
    buffer_sizes: tuple = tuple(4 ** i for i in range(2, 12))  # 16 B .. 4 MiB elems
    dtypes: tuple = ("float32", "bfloat16", "int32")
    repeats: int = 20            # paper: 20 reps after warm-up
    warmup: int = 3


def config() -> PoshMicroConfig:
    return PoshMicroConfig()


def smoke_config() -> PoshMicroConfig:
    return PoshMicroConfig(buffer_sizes=(16, 256), repeats=2, warmup=1)
