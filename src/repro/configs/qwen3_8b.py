"""Qwen3-8B [hf:Qwen/Qwen3-8B] — qk_norm, GQA kv=8.

36L, d_model 4096, 32 heads (head_dim 128), d_ff 12288, vocab 151936.
"""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-8b", family="dense",
        n_layers=36, d_model=4096, n_heads=32, n_kv=8, head_dim=128,
        d_ff=12288, vocab=151936, act="swiglu", qk_norm=True,
        rope_theta=1000000.0,
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-8b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=96, vocab=128, act="swiglu", qk_norm=True, max_seq=32,
    )
