"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B] — 128 experts top-8.

48L, d_model 2048, 32 heads (GQA kv=4, head_dim 128), expert_ff 768,
vocab 151936, qk_norm.  128 experts / 16 TP = 8 per rank.
"""
from .base import ArchConfig, MoEConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-moe-30b-a3b", family="moe",
        n_layers=48, d_model=2048, n_heads=32, n_kv=4, head_dim=128,
        d_ff=768, vocab=151936, act="swiglu", qk_norm=True,
        rope_theta=1000000.0,
        moe=MoEConfig(num_experts=128, top_k=8, expert_ff=768),
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-moe-30b-a3b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=48, vocab=128, act="swiglu", qk_norm=True, max_seq=32,
        moe=MoEConfig(num_experts=8, top_k=2, expert_ff=48,
                      capacity_factor=8.0),
    )
