"""Llama-3.2-Vision-90B [hf:meta-llama/Llama-3.2-90B-Vision; unverified]
— text backbone with cross-attention image layers.

100L, d_model 8192, 64 heads (GQA kv=8, head_dim 128), d_ff 28672,
vocab 128256.  Cross-attention to STUB patch embeddings every 5th layer
(20 cross-attn layers).  The vision tower is a stub: input_specs
provides precomputed patch embeddings.
"""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="llama-3.2-vision-90b", family="vlm",
        n_layers=100, d_model=8192, n_heads=64, n_kv=8, head_dim=128,
        d_ff=28672, vocab=128256, act="swiglu", rope_theta=500000.0,
        cross_attn_every=5, img_tokens=1601,
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="llama-3.2-vision-90b-smoke", family="vlm",
        n_layers=4, d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=96, vocab=128, act="swiglu",
        cross_attn_every=2, img_tokens=12, max_seq=32,
    )
