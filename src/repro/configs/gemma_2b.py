"""Gemma-2B [arXiv:2403.08295; hf] — GeGLU, head_dim 256, MQA (kv=1).

18L, d_model 2048, 8 heads, d_ff 16384 (GeGLU hidden), vocab 256000.
8 heads % 16 TP ⇒ ctx attention layout; MQA KV replicated.
"""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="gemma-2b", family="dense",
        n_layers=18, d_model=2048, n_heads=8, n_kv=1, head_dim=256,
        d_ff=16384, vocab=256000, act="geglu", tie_embeddings=True,
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="gemma-2b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv=1, head_dim=32,
        d_ff=128, vocab=128, act="geglu", tie_embeddings=True, max_seq=32,
    )
