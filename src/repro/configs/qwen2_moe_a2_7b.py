"""Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L, d_model 2048, 16 heads (kv=16, head_dim 128), vocab 151936.
MoE: 60 routed experts top-4 (expert_ff 1408) + 4 shared experts
(fused shared hidden 5632).  60 experts padded to 64 for 16-way EP.
"""
from .base import ArchConfig, MoEConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen2-moe-a2.7b", family="moe",
        n_layers=24, d_model=2048, n_heads=16, n_kv=16, head_dim=128,
        d_ff=1408, vocab=151936, act="swiglu",
        moe=MoEConfig(num_experts=60, top_k=4, expert_ff=1408,
                      shared_ff=5632, padded_experts=64),
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="qwen2-moe-a2.7b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv=4, head_dim=16,
        d_ff=64, vocab=128, act="swiglu", max_seq=32,
        moe=MoEConfig(num_experts=6, top_k=2, expert_ff=64, shared_ff=96,
                      padded_experts=8, capacity_factor=8.0),
    )
