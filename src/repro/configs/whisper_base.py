"""Whisper-base [arXiv:2212.04356] — encoder-decoder backbone.

6 encoder + 6 decoder layers, d_model 512, 8 heads (head_dim 64),
d_ff 2048, vocab 51865 (padded to 51872 for vocab-parallel TP).
Conv frontend is a STUB: input_specs provides precomputed frame
embeddings (1500 frames after 2x conv downsampling).
"""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="whisper-base", family="encdec",
        n_layers=6, enc_layers=6, enc_frames=1500,
        d_model=512, n_heads=8, n_kv=8, head_dim=64,
        d_ff=2048, vocab=51865, act="gelu", use_rope=False,
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="whisper-base-smoke", family="encdec",
        n_layers=2, enc_layers=2, enc_frames=24,
        d_model=64, n_heads=4, n_kv=4, head_dim=16,
        d_ff=96, vocab=128, act="gelu", use_rope=False, max_seq=32,
    )
