"""ArchConfig — the single config schema every architecture instantiates."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_ff: int               # per-expert hidden size
    shared_ff: int = 0           # fused shared-expert hidden size (0 = none)
    capacity_factor: float = 1.25
    padded_experts: Optional[int] = None  # EP divisibility padding

    def experts_padded(self, tp: int) -> int:
        if self.padded_experts:
            return self.padded_experts
        e = self.num_experts
        return -(-e // tp) * tp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    d_ff: int
    vocab: int
    act: str = "swiglu"          # swiglu | geglu | gelu
    qk_norm: bool = False
    swa_window: Optional[int] = None     # sliding-window attention
    rope_theta: float = 10000.0
    use_rope: bool = True                # whisper: absolute positions
    tie_embeddings: bool = False
    norm_eps: float = 1e-6

    # MoE
    moe: Optional[MoEConfig] = None

    # SSM / RWKV
    ssm_state: int = 0                  # Mamba2 state size (0 = no ssm)
    ssm_expand: int = 2
    ssm_conv: int = 4
    rwkv_head_dim: int = 0              # >0 => RWKV6 time-mix layers
    rwkv_padded_heads: Optional[int] = None

    # hybrid (zamba2): shared attention block every k mamba layers
    shared_attn_every: int = 0

    # enc-dec (whisper): encoder layers (n_layers = decoder layers)
    enc_layers: int = 0
    enc_frames: int = 1500              # stub frontend output length

    # vlm: cross-attention to image embeddings every k layers
    cross_attn_every: int = 0
    img_tokens: int = 1601              # stub patch embeddings

    # training defaults
    max_seq: int = 4096

    # --- derived -----------------------------------------------------
    def padded_vocab(self, tp: int) -> int:
        return -(-self.vocab // tp) * tp

    def attn_layout(self, tp: int) -> str:
        """'head' when query heads divide TP; otherwise 'ctx'
        (sequence-parallel attention with gathered KV) — see DESIGN.md."""
        if self.rwkv_head_dim or (self.ssm_state and not self.shared_attn_every):
            return "head"  # attention-free: layout handled by the block
        return "head" if self.n_heads % tp == 0 else "ctx"

    def kv_per_rank(self, tp: int) -> int:
        return max(self.n_kv // tp, 1)

    def heads_per_rank(self, tp: int) -> int:
        if self.n_heads % tp:
            raise ValueError(f"{self.name}: {self.n_heads} heads not divisible "
                             f"by tp={tp} (ctx layout keeps all heads)")
        return self.n_heads // tp

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks), for
        MODEL_FLOPS accounting."""
        d, l = self.d_model, self.n_layers
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.rwkv_head_dim:
            att = 6 * d * d       # r,k,v,g,w,out (+ small time-mix params)
            ff = 2 * d * self.d_ff
            return emb + l * (att + ff)
        attn_q = d * self.n_heads * self.head_dim
        attn_kv = 2 * d * self.n_kv * self.head_dim
        attn_o = self.n_heads * self.head_dim * d
        if self.moe:
            gl = 3 if self.act in ("swiglu", "geglu") else 2
            routed = self.moe.num_experts * gl * d * self.moe.expert_ff
            shared = gl * d * self.moe.shared_ff
            ff = routed + shared + d * self.moe.num_experts  # + router
        else:
            gl = 3 if self.act in ("swiglu", "geglu") else 2
            ff = gl * d * self.d_ff
        blocks = l * (attn_q + attn_kv + attn_o + ff)
        if self.ssm_state:
            d_in = self.ssm_expand * d
            mamba = l * (2 * d * d_in + d_in * d + d_in * (2 * self.ssm_state))
            n_shared = (l // self.shared_attn_every) if self.shared_attn_every else 0
            shared_blk = (attn_q + attn_kv + attn_o + gl * d * self.d_ff)
            blocks = mamba + n_shared * 0 + (shared_blk if n_shared else 0)
        if self.enc_layers:
            blocks += self.enc_layers * (attn_q + attn_kv + attn_o + ff) \
                + self.n_layers * (attn_q + attn_kv + attn_o)  # cross-attn
        if self.cross_attn_every:
            blocks += (l // self.cross_attn_every) * (attn_q + attn_kv + attn_o)
        return emb + blocks

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        if not self.moe:
            return self.param_count()
        d, l = self.d_model, self.n_layers
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        attn = d * (self.n_heads + 2 * self.n_kv) * self.head_dim \
            + self.n_heads * self.head_dim * d
        gl = 3
        ff_active = self.moe.top_k * gl * d * self.moe.expert_ff \
            + gl * d * self.moe.shared_ff
        return emb + l * (attn + ff_active)
