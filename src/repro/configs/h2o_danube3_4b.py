"""H2O-Danube3-4B [arXiv:2401.16818-family; unverified] — llama+mistral
mix with sliding-window attention.

24L, d_model 3840, 32 heads (GQA kv=8, head_dim 120), d_ff 10240,
vocab 32000, SWA window 4096.  SWA ⇒ long_500k runs (sub-quadratic).
head_dim 3840/32 = 120 (not 128-aligned; noted for MXU padding).
"""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="h2o-danube-3-4b", family="dense",
        n_layers=24, d_model=3840, n_heads=32, n_kv=8, head_dim=120,
        d_ff=10240, vocab=32000, act="swiglu", swa_window=4096,
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="h2o-danube-3-4b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=96, vocab=128, act="swiglu", swa_window=16, max_seq=32,
    )
