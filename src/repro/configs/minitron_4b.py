"""Minitron-4B — width-pruned Nemotron [arXiv:2407.14679; hf].

32L, d_model 3072, 24 query heads (GQA kv=8, head_dim 128), d_ff 9216
(squared-ReLU in the paper's base model; public HF config uses
squared-relu — we use swiglu-free 'relu2'), vocab 256000.
24 heads % 16 TP ⇒ ctx attention layout.
"""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="minitron-4b", family="dense",
        n_layers=32, d_model=3072, n_heads=24, n_kv=8, head_dim=128,
        d_ff=9216, vocab=256000, act="relu2", rope_theta=10000.0,
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="minitron-4b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=6, n_kv=2, head_dim=16,
        d_ff=96, vocab=128, act="relu2", max_seq=32,
    )
