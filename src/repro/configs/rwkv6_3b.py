"""RWKV6-3B "Finch" [arXiv:2404.05892; hf] — data-dependent decay linear
recurrence, attention-free.

32L, d_model 2560, head_dim 64 (40 heads, padded to 48 for 16-way TP —
ghost heads carry zero output-projection rows; see DESIGN.md), d_ff 8960
(ReLU² channel-mix in RWKV6; we follow the published relu-squared),
vocab 65536.
"""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="rwkv6-3b", family="ssm",
        n_layers=32, d_model=2560, n_heads=40, n_kv=40, head_dim=64,
        d_ff=8960, vocab=65536, act="relu2",
        rwkv_head_dim=64, rwkv_padded_heads=48,
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="rwkv6-3b-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=4, n_kv=4, head_dim=16,
        d_ff=96, vocab=128, act="relu2",
        rwkv_head_dim=16, rwkv_padded_heads=4, max_seq=32,
    )
