"""Zamba2-7B [arXiv:2411.15242; unverified] — Mamba2 backbone + shared
attention blocks.

81 Mamba2 layers (d_model 3584, ssm_state 64, expand 2 ⇒ d_inner 7168,
112 ssm heads of 64) with a weight-shared attention+MLP block applied
every 6 layers (32 heads, kv=32, head_dim 112, d_ff 14336).
At 500k context the shared attention uses SWA(4096) — DESIGN.md §risks.
"""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="zamba2-7b", family="hybrid",
        n_layers=81, d_model=3584, n_heads=32, n_kv=32, head_dim=112,
        d_ff=14336, vocab=32000, act="swiglu",
        ssm_state=64, ssm_expand=2, ssm_conv=4,
        shared_attn_every=6, swa_window=4096,
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="zamba2-7b-smoke", family="hybrid",
        n_layers=4, d_model=128, n_heads=4, n_kv=4, head_dim=32,
        d_ff=192, vocab=128, act="swiglu",
        ssm_state=8, ssm_expand=2, ssm_conv=4,
        shared_attn_every=2, swa_window=16, max_seq=32,
    )
