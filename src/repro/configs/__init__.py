"""Architecture configs — one module per assigned architecture.

``get(name)`` returns the exact published config; ``get_smoke(name)``
returns a reduced same-family config for CPU tests.
"""
from __future__ import annotations

import importlib

ARCHS = (
    "minitron_4b",
    "gemma_2b",
    "qwen3_8b",
    "h2o_danube3_4b",
    "whisper_base",
    "rwkv6_3b",
    "qwen2_moe_a2_7b",
    "qwen3_moe_30b_a3b",
    "llama32_vision_90b",
    "zamba2_7b",
    "posh_micro",
)

_ALIASES = {
    "minitron-4b": "minitron_4b",
    "gemma-2b": "gemma_2b",
    "qwen3-8b": "qwen3_8b",
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "whisper-base": "whisper_base",
    "rwkv6-3b": "rwkv6_3b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "llama-3.2-vision-90b": "llama32_vision_90b",
    "zamba2-7b": "zamba2_7b",
}


def canon(name: str) -> str:
    return _ALIASES.get(name, name.replace("-", "_").replace(".", "_"))


def get(name: str):
    mod = importlib.import_module(f"repro.configs.{canon(name)}")
    return mod.config()


def get_smoke(name: str):
    mod = importlib.import_module(f"repro.configs.{canon(name)}")
    return mod.smoke_config()
