#!/usr/bin/env python
"""Bench-regression gate: compare freshly-written BENCH_serve.json rows
against the committed baseline and fail loudly on real regressions.

Run by ``scripts/verify.sh`` right after the smoke bench refreshes
``BENCH_serve.json`` (and by CI on every push), so a PR that tanks
serving latency or throughput fails the gate instead of silently
rewriting the trajectory file.

Rows are matched by ``case`` name — the full sweep includes the smoke
cases under the same names, so the fresh ``--smoke`` rows always find
their committed counterparts.  Per matched row:

  * p99 latency (``latency_p99_s``, ``decode_p99_s``) may not grow by
    more than ``--factor`` (default 2x) — small absolute values are
    exempt below ``--floor-s`` (CPU timer noise, default 50 ms);
  * throughput (``throughput_tok_s``) may not fall by more than the
    same factor;
  * speculative rows must stay structurally healthy: committed
    ``spec_accept_rate > 0`` must stay ``> 0``, and committed
    ``spec_tokens_per_tick > 1`` must stay ``> 1`` (these are
    deterministic given the seed, not timing-noise-bound).

The baseline defaults to ``git show HEAD:BENCH_serve.json``;
``--baseline PATH`` overrides it (verify.sh passes a pre-bench
snapshot, which also covers dirty working trees).

    python scripts/check_bench.py
    python scripts/check_bench.py --baseline /tmp/bench.snap --factor 2
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)
FRESH = os.path.join(ROOT, "BENCH_serve.json")

P99_KEYS = ("latency_p99_s", "decode_p99_s")


def load_baseline(path: str | None) -> dict:
    if path:
        with open(path) as f:
            return json.load(f)
    out = subprocess.run(["git", "show", "HEAD:BENCH_serve.json"],
                         capture_output=True, text=True, cwd=ROOT)
    if out.returncode != 0:
        raise SystemExit(
            "check_bench: no --baseline given and 'git show "
            "HEAD:BENCH_serve.json' failed:\n" + out.stderr)
    return json.loads(out.stdout)


def by_case(payload: dict) -> dict:
    return {r["case"]: r for r in payload.get("results", [])}


def compare(base: dict, fresh: dict, *, factor: float,
            floor_s: float) -> list:
    """Returns the list of failure strings (empty = gate passes)."""
    bases, freshes = by_case(base), by_case(fresh)
    common = sorted(set(bases) & set(freshes))
    fails = []
    if not common:
        fails.append(
            f"no common case names between baseline "
            f"({sorted(bases)}) and fresh ({sorted(freshes)}) rows — "
            f"the gate compared nothing, which is itself a failure")
        return fails
    for case in common:
        b, f = bases[case], freshes[case]
        for key in P99_KEYS:
            if key not in b or key not in f:
                continue
            bound = max(float(b[key]) * factor, floor_s)
            if float(f[key]) > bound:
                fails.append(
                    f"{case}: {key} {f[key]:.4f}s > {factor:g}x "
                    f"baseline {b[key]:.4f}s (floor {floor_s:g}s)")
        bt, ft = b.get("throughput_tok_s"), f.get("throughput_tok_s")
        if bt and float(bt) > 0 and float(ft or 0) < float(bt) / factor:
            fails.append(
                f"{case}: throughput {ft} tok/s < baseline "
                f"{bt} / {factor:g}")
        # structural spec-decode health (deterministic, not timing)
        if float(b.get("spec_accept_rate") or 0) > 0 \
                and float(f.get("spec_accept_rate") or 0) <= 0:
            fails.append(f"{case}: spec_accept_rate fell to "
                         f"{f.get('spec_accept_rate')} (baseline "
                         f"{b['spec_accept_rate']})")
        if float(b.get("spec_tokens_per_tick") or 0) > 1 \
                and float(f.get("spec_tokens_per_tick") or 0) <= 1:
            fails.append(f"{case}: spec_tokens_per_tick fell to "
                         f"{f.get('spec_tokens_per_tick')} (baseline "
                         f"{b['spec_tokens_per_tick']})")
    return fails


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", default=FRESH,
                    help="freshly-written bench file (default: repo "
                         "root BENCH_serve.json)")
    ap.add_argument("--baseline", default=None,
                    help="baseline bench file (default: git show "
                         "HEAD:BENCH_serve.json)")
    ap.add_argument("--factor", type=float, default=2.0,
                    help="max tolerated regression factor (default 2)")
    ap.add_argument("--floor-s", type=float, default=0.05,
                    help="p99 regressions below this absolute value "
                         "are timer noise, not regressions")
    args = ap.parse_args()

    with open(args.fresh) as f:
        fresh = json.load(f)
    base = load_baseline(args.baseline)
    fails = compare(base, fresh, factor=args.factor,
                    floor_s=args.floor_s)
    n = len(set(by_case(base)) & set(by_case(fresh)))
    if fails:
        print(f"CHECK_BENCH_FAIL ({len(fails)} regressions over "
              f"{n} compared cases):")
        for line in fails:
            print(f"  {line}")
        return 1
    print(f"CHECK_BENCH_PASS ({n} cases within {args.factor:g}x)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
