#!/usr/bin/env python
"""Bench-regression gate: compare freshly-written BENCH_serve.json rows
against the committed baseline and fail loudly on real regressions.

Run by ``scripts/verify.sh`` right after the smoke bench refreshes
``BENCH_serve.json`` (and by CI on every push), so a PR that tanks
serving latency or throughput fails the gate instead of silently
rewriting the trajectory file.

Rows are matched by ``case`` name — the full sweep includes the smoke
cases under the same names, so the fresh ``--smoke`` rows always find
their committed counterparts.  Per matched row:

  * p99 latency (``latency_p99_s``, ``decode_p99_s``) may not grow by
    more than ``--factor`` (default 2x) — small absolute values are
    exempt below ``--floor-s`` (CPU timer noise, default 50 ms);
  * throughput (``throughput_tok_s``) may not fall by more than the
    same factor;
  * speculative rows must stay structurally healthy: committed
    ``spec_accept_rate > 0`` must stay ``> 0``, and committed
    ``spec_tokens_per_tick > 1`` must stay ``> 1`` (these are
    deterministic given the seed, not timing-noise-bound).

A disaggregation gate rides along: payloads whose rows carry
``topology`` must keep the ``colocated``/``disagg_2p2d`` pair, and
every disaggregated row must report ``handoff_quiets == 0`` with
``handoff_signals > 0`` — the put-with-signal page handoff completing
per transfer, never through a tick-global quiet.

A control-plane (router) gate rides along too: payloads whose rows
carry ``router`` must keep the ``router_host``/``router_amo`` pair
(same topology and trace, the router the only knob), the pair's token
counts must be EQUAL (the streams are bit-identical by contract, so
``tokens_out``/``requests`` moving apart means the lock-free control
plane changed a scheduling decision), and the amo row must show real
lock-free work (``router_amos > 0``) with ``router_quiets == 0`` and
``handoff_quiets == 0`` — neither the CAS admission rings, the page
pools, nor the mailbox may fall back to a tick-global barrier.

SLO gates (PR 10, also runnable alone via ``--slo-only`` as
verify.sh's dedicated slo-gate phase): payloads whose rows carry
``slo_attained_interactive`` must keep the ``sat_low``/``sat_overload``
endpoints, hold interactive attainment >= 0.99 on EVERY SLO row, shed
only best_effort traffic, and shed at least once somewhere (the ramp
actually reached overload); payloads whose rows carry ``hot_swap``
must keep the ``hot_swap_off``/``hot_swap_on`` pair with equal token
counts, a real flip (``swap_flips > 0``, ``swap_bytes > 0``) and
``swap_extra_quiets == 0`` on the on row.  A STALE-CASE gate rides on
``meta["sweep_cases"]``: committed case names the sweep can no longer
emit fail loudly unless allowlisted in ``RETIRED_CASES`` — zombie rows
would otherwise merge forward through every smoke refresh with numbers
nothing can update.

Two attention-kernel gates ride along:

  * serve rows must still carry the smoke ``attn_impl`` kernel/ref PAIR
    (``smoke`` + ``smoke_kernel``) — losing either row would silently
    drop the serving hot path's kernel-vs-ref trajectory (only enforced
    on payloads that carry ``attn_impl`` fields, i.e. real serve-bench
    files);
  * with ``--attn-fresh BENCH_attn.json`` the microbench trajectory is
    gated too: every fresh ``*_kernel`` row must have its ``*_ref``
    partner, kernel ``max_err_vs_ref`` may not exceed the row's
    ``err_tol`` (parity is absolute, not baseline-relative), and
    ``us_per_call`` may not grow past ``--factor`` x baseline above
    ``--attn-floor-us`` (interpreter rows off-TPU sit under the floor).

The baseline defaults to ``git show HEAD:BENCH_serve.json``;
``--baseline PATH`` overrides it (verify.sh passes a pre-bench
snapshot, which also covers dirty working trees; same for
``--attn-baseline``).

    python scripts/check_bench.py
    python scripts/check_bench.py --baseline /tmp/bench.snap --factor 2
    python scripts/check_bench.py --attn-fresh BENCH_attn.json
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)
FRESH = os.path.join(ROOT, "BENCH_serve.json")

P99_KEYS = ("latency_p99_s", "decode_p99_s")

# the serve-bench attn_impl kernel/ref row pairs the smoke refresh must
# always re-emit: (case, required attn_impl)
SERVE_ATTN_PAIR = (("smoke", "ref"), ("smoke_kernel", "kernel"))

# the disaggregation topology pair the full sweep must keep benching:
# (case, required topology)
SERVE_DISAGG_PAIR = (("colocated", "colocated"), ("disagg_2p2d", "2+2"))

# the control-plane pair: same shape/trace, router is the only knob
SERVE_ROUTER_PAIR = (("router_host", "host"), ("router_amo", "amo"))

# the saturation endpoints the SLO gate must always find benched: the
# same class mix under light load and under overload
SERVE_SAT_PAIR = ("sat_low", "sat_overload")

# the hot-swap pair: same shape/trace, the in-flight weight swap the
# only knob — (case, expected hot_swap flag)
SERVE_SWAP_PAIR = (("hot_swap_off", 0), ("hot_swap_on", 1))

# full-sweep case names that were DELIBERATELY retired: committed rows
# under these names may outlive the sweep (the stale-case gate's
# allowlist — add a name here when a case is intentionally removed,
# with a PR explaining why its trajectory ends)
RETIRED_CASES: frozenset = frozenset()


def load_baseline(path: str | None, fname: str = "BENCH_serve.json") -> dict:
    if path:
        with open(path) as f:
            return json.load(f)
    out = subprocess.run(["git", "show", f"HEAD:{fname}"],
                         capture_output=True, text=True, cwd=ROOT)
    if out.returncode != 0:
        raise SystemExit(
            f"check_bench: no baseline given and 'git show "
            f"HEAD:{fname}' failed:\n" + out.stderr)
    return json.loads(out.stdout)


def by_case(payload: dict) -> dict:
    return {r["case"]: r for r in payload.get("results", [])}


def compare(base: dict, fresh: dict, *, factor: float,
            floor_s: float) -> list:
    """Returns the list of failure strings (empty = gate passes)."""
    bases, freshes = by_case(base), by_case(fresh)
    common = sorted(set(bases) & set(freshes))
    fails = []
    if not common:
        fails.append(
            f"no common case names between baseline "
            f"({sorted(bases)}) and fresh ({sorted(freshes)}) rows — "
            f"the gate compared nothing, which is itself a failure")
        return fails
    for case in common:
        b, f = bases[case], freshes[case]
        for key in P99_KEYS:
            if key not in b or key not in f:
                continue
            bound = max(float(b[key]) * factor, floor_s)
            if float(f[key]) > bound:
                fails.append(
                    f"{case}: {key} {f[key]:.4f}s > {factor:g}x "
                    f"baseline {b[key]:.4f}s (floor {floor_s:g}s)")
        bt, ft = b.get("throughput_tok_s"), f.get("throughput_tok_s")
        if bt and float(bt) > 0 and float(ft or 0) < float(bt) / factor:
            fails.append(
                f"{case}: throughput {ft} tok/s < baseline "
                f"{bt} / {factor:g}")
        # structural spec-decode health (deterministic, not timing)
        if float(b.get("spec_accept_rate") or 0) > 0 \
                and float(f.get("spec_accept_rate") or 0) <= 0:
            fails.append(f"{case}: spec_accept_rate fell to "
                         f"{f.get('spec_accept_rate')} (baseline "
                         f"{b['spec_accept_rate']})")
        if float(b.get("spec_tokens_per_tick") or 0) > 1 \
                and float(f.get("spec_tokens_per_tick") or 0) <= 1:
            fails.append(f"{case}: spec_tokens_per_tick fell to "
                         f"{f.get('spec_tokens_per_tick')} (baseline "
                         f"{b['spec_tokens_per_tick']})")
    return fails


def attn_pair_fails(fresh: dict) -> list:
    """The serve sweep must keep benching the smoke attn_impl
    kernel/ref pair.  Only enforced on payloads that look like real
    serve-bench output (rows carrying ``attn_impl``), so unit fixtures
    with synthetic case names are unaffected."""
    rows = by_case(fresh)
    if not any("attn_impl" in r for r in rows.values()):
        return []
    fails = []
    for case, impl in SERVE_ATTN_PAIR:
        r = rows.get(case)
        if r is None:
            fails.append(
                f"attn pair: serve case '{case}' missing — the "
                f"attn_impl={impl} half of the smoke kernel/ref pair "
                f"must always be benched")
        elif r.get("attn_impl") != impl:
            fails.append(
                f"attn pair: serve case '{case}' has attn_impl="
                f"{r.get('attn_impl')!r}, expected {impl!r}")
    return fails


def disagg_pair_fails(fresh: dict) -> list:
    """The sweep must keep benching the colocated/disagg_2p2d topology
    pair, and every disaggregated row must show a handoff that drained
    through ``signal_wait_until`` ALONE — a single tick-global quiet on
    the mailbox queue means the per-transfer completion contract broke.
    Only enforced on payloads whose rows carry ``topology`` (real
    serve-bench files); synthetic unit fixtures are unaffected."""
    rows = by_case(fresh)
    if not any("topology" in r for r in rows.values()):
        return []
    fails = []
    for case, topo in SERVE_DISAGG_PAIR:
        r = rows.get(case)
        if r is None:
            fails.append(
                f"disagg pair: serve case '{case}' missing — the "
                f"topology={topo} half of the colocated/disagg pair "
                f"must always be benched")
        elif r.get("topology") != topo:
            fails.append(
                f"disagg pair: serve case '{case}' has topology="
                f"{r.get('topology')!r}, expected {topo!r}")
    for case, r in sorted(rows.items()):
        if r.get("topology", "colocated") == "colocated":
            continue
        if int(r.get("handoff_quiets", 0)) != 0:
            fails.append(
                f"{case}: handoff_quiets={r['handoff_quiets']} — the "
                f"page handoff must drain via signal_wait_until alone, "
                f"never a tick-global quiet/fence")
        if int(r.get("handoff_signals", 0)) <= 0:
            fails.append(
                f"{case}: handoff_signals="
                f"{r.get('handoff_signals')} — a disaggregated row "
                f"that moved no pages benched nothing")
    return fails


def router_pair_fails(fresh: dict) -> list:
    """The sweep must keep benching the ``router_host``/``router_amo``
    control-plane pair, their token counts must match (streams are
    bit-identical by contract — tier-1 pins the streams, this pins the
    row-level evidence), and the amo half must have done real lock-free
    work without a single global barrier: ``router_amos > 0`` and
    ``router_quiets == 0`` (CAS rings + page pools) on top of the
    ``handoff_quiets == 0`` the disagg gate already pins.  Only
    enforced on payloads whose rows carry ``router`` (real serve-bench
    files); synthetic unit fixtures are unaffected."""
    rows = by_case(fresh)
    if not any("router" in r for r in rows.values()):
        return []
    fails = []
    for case, mode in SERVE_ROUTER_PAIR:
        r = rows.get(case)
        if r is None:
            fails.append(
                f"router pair: serve case '{case}' missing — the "
                f"router={mode} half of the host/amo control-plane "
                f"pair must always be benched")
        elif r.get("router") != mode:
            fails.append(
                f"router pair: serve case '{case}' has router="
                f"{r.get('router')!r}, expected {mode!r}")
    host = rows.get("router_host")
    amo = rows.get("router_amo")
    if host is not None and amo is not None:
        for key in ("tokens_out", "requests"):
            if host.get(key) != amo.get(key):
                fails.append(
                    f"router pair: {key} differs — host "
                    f"{host.get(key)} vs amo {amo.get(key)}; the "
                    f"control plane must not change token streams")
    for case, r in sorted(rows.items()):
        if r.get("router", "host") != "amo":
            continue
        if int(r.get("router_quiets", 0)) != 0:
            fails.append(
                f"{case}: router_quiets={r['router_quiets']} — the "
                f"lock-free control plane (admission rings + page "
                f"pools) must never fall back to a global quiet/fence")
        if int(r.get("router_amos", 0)) <= 0:
            fails.append(
                f"{case}: router_amos={r.get('router_amos')} — an amo "
                f"row whose router issued no AMOs benched the host "
                f"loop twice")
        if int(r.get("handoff_quiets", 0)) != 0:
            fails.append(
                f"{case}: handoff_quiets={r['handoff_quiets']} on the "
                f"AMO path — claim-word mailbox slots must complete "
                f"per transfer, never via a tick-global quiet")
    return fails


def slo_fails(fresh: dict) -> list:
    """The saturation/SLO gate: every SLO row (presence-keyed on
    ``slo_attained_interactive``) must hold the protected class's TTFT
    SLO — attainment >= 0.99 — and sheds may land on best_effort ONLY.
    At least one row must actually shed (the sweep reached overload;
    a ramp that never saturates exercises no admission policy), and
    the sat_low/sat_overload endpoints must both be benched.  The
    numbers are deterministic (tick clock), so these are hard pins,
    not noise-tolerant bands.  Synthetic unit fixtures without SLO
    fields are unaffected."""
    rows = by_case(fresh)
    slo_rows = {c: r for c, r in rows.items()
                if "slo_attained_interactive" in r}
    if not slo_rows:
        return []
    fails = []
    for case in SERVE_SAT_PAIR:
        if case not in slo_rows:
            fails.append(
                f"slo: saturation case '{case}' missing — both the "
                f"light-load and overload endpoints of the ramp must "
                f"always be benched")
    for case, r in sorted(slo_rows.items()):
        att = float(r.get("slo_attained_interactive", 0.0))
        if att < 0.99:
            fails.append(
                f"slo: {case}: slo_attained_interactive={att:g} < "
                f"0.99 — the protected class's TTFT SLO must hold "
                f"through overload (priority admission broke)")
        for cls in ("interactive", "batch"):
            shed = int(r.get(f"shed_{cls}", 0))
            if shed != 0:
                fails.append(
                    f"slo: {case}: shed_{cls}={shed} — load shedding "
                    f"may only ever hit best_effort traffic")
    if not any(int(r.get("shed_best_effort", 0)) > 0
               for r in slo_rows.values()):
        fails.append(
            "slo: no saturation row shed any best_effort traffic — "
            "the ramp never reached overload, so the admission policy "
            "went unexercised")
    return fails


def hot_swap_pair_fails(fresh: dict) -> list:
    """The sweep must keep benching the ``hot_swap_off``/``hot_swap_on``
    pair (presence-keyed on rows carrying ``hot_swap``): equal token
    counts across the pair (a live weight swap must not drop, shed or
    stall a single request), and the on row must show a real swap —
    ``swap_flips > 0``, ``swap_bytes > 0`` — that retired on
    per-transfer signal/AMO waits alone: ``swap_extra_quiets == 0``.
    Synthetic unit fixtures without swap fields are unaffected."""
    rows = by_case(fresh)
    if not any("hot_swap" in r for r in rows.values()):
        return []
    fails = []
    for case, on in SERVE_SWAP_PAIR:
        r = rows.get(case)
        if r is None:
            fails.append(
                f"hot-swap pair: serve case '{case}' missing — the "
                f"hot_swap={on} half of the off/on pair must always "
                f"be benched")
        elif int(r.get("hot_swap", -1)) != on:
            fails.append(
                f"hot-swap pair: serve case '{case}' has hot_swap="
                f"{r.get('hot_swap')!r}, expected {on}")
    off, on_row = rows.get("hot_swap_off"), rows.get("hot_swap_on")
    if off is not None and on_row is not None:
        for key in ("tokens_out", "requests"):
            if off.get(key) != on_row.get(key):
                fails.append(
                    f"hot-swap pair: {key} differs — off "
                    f"{off.get(key)} vs on {on_row.get(key)}; an "
                    f"in-flight weight swap must not change how many "
                    f"requests/tokens the engine serves")
    for case, r in sorted(rows.items()):
        if not r.get("hot_swap"):
            continue
        if int(r.get("swap_flips", 0)) <= 0:
            fails.append(
                f"{case}: swap_flips={r.get('swap_flips')} — a "
                f"hot_swap row whose generation never flipped benched "
                f"the off row twice")
        if int(r.get("swap_bytes", 0)) <= 0:
            fails.append(
                f"{case}: swap_bytes={r.get('swap_bytes')} — the swap "
                f"row streamed no weight bytes")
        if int(r.get("swap_extra_quiets", 0)) != 0:
            fails.append(
                f"{case}: swap_extra_quiets={r['swap_extra_quiets']} "
                f"— the weight stream must retire on per-transfer "
                f"signal/AMO waits, never a tick-global quiet/fence")
    return fails


def stale_case_fails(base: dict, fresh: dict) -> list:
    """Committed rows the sweep can no longer emit are ZOMBIE rows:
    every later smoke refresh would keep merging them forward and the
    regression gates would keep 'checking' numbers nothing can ever
    update.  The fresh payload's ``meta.sweep_cases`` (the full-sweep
    case roster, emitted under --smoke too) is the source of truth;
    a retired name must be allowlisted in ``RETIRED_CASES``.  Payloads
    without the roster (unit fixtures, pre-PR-10 files) are exempt."""
    sweep = (fresh.get("meta") or {}).get("sweep_cases")
    if not sweep:
        return []
    known = set(sweep) | set(by_case(fresh)) | set(RETIRED_CASES)
    return [
        f"stale case: committed row '{c}' is no longer in the sweep's "
        f"case roster (meta.sweep_cases) — restore the case or retire "
        f"it explicitly via RETIRED_CASES"
        for c in sorted(set(by_case(base)) - known)]


def compare_attn(base: dict, fresh: dict, *, factor: float,
                 floor_us: float) -> list:
    """Gate the BENCH_attn.json microbench trajectory: kernel/ref row
    pairing, absolute kernel parity (``max_err_vs_ref <= err_tol``),
    and ``us_per_call`` regression vs baseline above the floor."""
    bases, freshes = by_case(base), by_case(fresh)
    fails = []
    common = sorted(set(bases) & set(freshes))
    if not common:
        fails.append(
            f"attn: no common case names between baseline "
            f"({sorted(bases)}) and fresh ({sorted(freshes)}) rows — "
            f"the gate compared nothing, which is itself a failure")
        return fails
    for case, row in sorted(freshes.items()):
        if case.endswith("_kernel"):
            partner = case[:-len("_kernel")] + "_ref"
            if partner not in freshes:
                fails.append(
                    f"attn: {case} has no {partner} partner row — "
                    f"kernel rows are only meaningful as a pair")
        if row.get("impl") == "kernel":
            err, tol = row.get("max_err_vs_ref"), row.get("err_tol")
            if err is not None and tol and float(err) > float(tol):
                fails.append(
                    f"attn: {case} kernel-vs-ref parity error "
                    f"{float(err):.3e} > tol {float(tol):g}")
    for case in common:
        bu = bases[case].get("us_per_call")
        fu = freshes[case].get("us_per_call")
        if bu is None or fu is None:
            continue
        bound = max(float(bu) * factor, floor_us)
        if float(fu) > bound:
            fails.append(
                f"attn: {case} us_per_call {float(fu):.1f} > "
                f"{factor:g}x baseline {float(bu):.1f} "
                f"(floor {floor_us:g}us)")
    return fails


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", default=FRESH,
                    help="freshly-written bench file (default: repo "
                         "root BENCH_serve.json)")
    ap.add_argument("--baseline", default=None,
                    help="baseline bench file (default: git show "
                         "HEAD:BENCH_serve.json)")
    ap.add_argument("--factor", type=float, default=2.0,
                    help="max tolerated regression factor (default 2)")
    ap.add_argument("--floor-s", type=float, default=0.05,
                    help="p99 regressions below this absolute value "
                         "are timer noise, not regressions")
    ap.add_argument("--attn-fresh", default=None,
                    help="freshly-written BENCH_attn.json to gate "
                         "alongside the serve rows (pairing + parity "
                         "+ us_per_call regression)")
    ap.add_argument("--attn-baseline", default=None,
                    help="baseline attn bench file (default: git show "
                         "HEAD:BENCH_attn.json)")
    ap.add_argument("--attn-floor-us", type=float, default=50000.0,
                    help="us_per_call regressions below this absolute "
                         "value are interpreter/timer noise")
    ap.add_argument("--slo-only", action="store_true",
                    help="run ONLY the SLO gates — saturation "
                         "attainment/shed, the hot-swap pair, and the "
                         "stale-case roster — as verify.sh's dedicated "
                         "slo-gate phase (distinct exit path from the "
                         "regression compare)")
    args = ap.parse_args()

    with open(args.fresh) as f:
        fresh = json.load(f)
    base = load_baseline(args.baseline)
    if args.slo_only:
        fails = slo_fails(fresh)
        fails += hot_swap_pair_fails(fresh)
        fails += stale_case_fails(base, fresh)
        n = sum(1 for r in fresh.get("results", [])
                if "slo_attained_interactive" in r or "hot_swap" in r)
        if fails:
            print(f"CHECK_BENCH_SLO_FAIL ({len(fails)} violations "
                  f"over {n} slo/hot-swap rows):")
            for line in fails:
                print(f"  {line}")
            return 1
        print(f"CHECK_BENCH_SLO_PASS ({n} slo/hot-swap rows gated)")
        return 0
    fails = compare(base, fresh, factor=args.factor,
                    floor_s=args.floor_s)
    fails += attn_pair_fails(fresh)
    fails += disagg_pair_fails(fresh)
    fails += router_pair_fails(fresh)
    fails += slo_fails(fresh)
    fails += hot_swap_pair_fails(fresh)
    fails += stale_case_fails(base, fresh)
    n = len(set(by_case(base)) & set(by_case(fresh)))
    if args.attn_fresh:
        with open(args.attn_fresh) as f:
            fresh_a = json.load(f)
        base_a = load_baseline(args.attn_baseline, "BENCH_attn.json")
        fails += compare_attn(base_a, fresh_a, factor=args.factor,
                              floor_us=args.attn_floor_us)
        n += len(set(by_case(base_a)) & set(by_case(fresh_a)))
    if fails:
        print(f"CHECK_BENCH_FAIL ({len(fails)} regressions over "
              f"{n} compared cases):")
        for line in fails:
            print(f"  {line}")
        return 1
    print(f"CHECK_BENCH_PASS ({n} cases within {args.factor:g}x)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
