#!/usr/bin/env python
"""shmemlint — static comm-API lint over the source tree.

Usage:
    python scripts/shmemlint.py [PATH ...]     (default: src/)

Exit 0 and print ``SHMEMLINT_PASS`` when clean; exit 1 and print one
``path:line: [rule] message`` line per finding plus ``SHMEMLINT_FAIL``
otherwise.  Rules live in ``repro.analysis.lint``.
"""
from __future__ import annotations

import os
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
_SRC = os.path.join(os.path.dirname(_HERE), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

from repro.analysis.lint import lint_paths  # noqa: E402


def main(argv: list[str]) -> int:
    paths = argv or [_SRC]
    errors = lint_paths(paths)
    for e in errors:
        print(e)
    if errors:
        print(f"SHMEMLINT_FAIL findings={len(errors)}")
        return 1
    print("SHMEMLINT_PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
