#!/usr/bin/env bash
# The PR gate: every change runs this exact sequence (also `make
# verify`; CI runs it on every PR/push — .github/workflows/ci.yml).
#
#   1. tier-1 pytest (the suite the driver enforces), then
#   2. each tests/multipe/run_*.py worker under 8 fake CPU PEs, run
#      directly so their full stdout is visible.  During phase 1 the
#      pytest subprocess wrappers for those same workers are skipped
#      (REPRO_MULTIPE_EXPLICIT) so each suite runs exactly once
#      (tier-1 pins that invariant: tests/test_ci_gate.py), then
#   3. the smoke serving bench refreshes BENCH_serve.json and the
#      smoke attention microbench refreshes BENCH_attn.json, then
#   4. the SLO gate (scripts/check_bench.py --slo-only) pins the
#      deterministic serving-quality rows — saturation attainment >=
#      0.99 on interactive, sheds on best_effort only, the hot-swap
#      pair's equal token counts + zero extra drains, and the
#      stale-case roster — and
#   5. scripts/check_bench.py gates the fresh rows of BOTH files
#      against their pre-bench snapshots (>2x p99/throughput/us_per_call
#      regression, missing attn kernel/ref pair rows, or a kernel
#      parity error over tolerance all fail).
#
# Every phase is timed, and each phase fails with its OWN exit code +
# a "VERIFY_FAIL phase=<name>" line (annotated in CI by
# .github/problem-matcher.json), so a bench crash (exit 3), a bench
# regression (exit 4), a lint finding (exit 5) or an SLO/hot-swap
# violation (exit 6) is distinguishable from a tier-1 (exit 1) or
# multipe (exit 2) failure straight from the log.  A per-phase summary
# table (phase, seconds, pass/FAIL) prints on EVERY exit, pass or
# fail, so a long CI log ends with the one screen that matters.
#
# The lint phase (scripts/shmemlint.py, static comm-API invariants)
# runs first in BOTH modes — it is seconds-cheap and fails fastest.
# In full mode the tier-1 + multipe phases additionally run under
# REPRO_SHMEMCHECK=1: the happens-before checker is live in every
# CommQueue/SymmetricHeap, and any finding fails the owning test
# (tests/conftest.py).  The bench phases stay checker-free so the
# check_bench p99 gate measures the shipped hot path.
#
# Usage: scripts/verify.sh [--fast]
#   --fast: lint + tier-1 only (the CI pull-request job); the multipe
#   workers then run through their normal pytest wrappers instead of
#   the explicit loop, and the bench phases are skipped.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
FAST=0
[[ "${1:-}" == "--fast" ]] && FAST=1
[[ ${FAST} == 0 ]] && export REPRO_MULTIPE_EXPLICIT=1

T_START=$(date +%s)
PHASE_ROWS=()          # "name|seconds|status" per completed phase
BENCH_SNAP=""
ATTN_SNAP=""
phase_begin() { PHASE_NAME="$1"; PHASE_T0=$(date +%s); echo "== ${PHASE_NAME} =="; }
phase_end() {
    local dt=$(( $(date +%s) - PHASE_T0 ))
    PHASE_ROWS+=("${PHASE_NAME}|${dt}|pass")
    echo "-- phase ${PHASE_NAME}: ${dt}s"
}
fail() {  # fail <exit-code> — named, coded, greppable
    PHASE_ROWS+=("${PHASE_NAME}|$(( $(date +%s) - PHASE_T0 ))|FAIL")
    echo "VERIFY_FAIL phase=${PHASE_NAME}"
    exit "$1"
}
summary() {  # the per-phase table, printed on EVERY exit path
    if [[ -n "${BENCH_SNAP}" ]]; then rm -f "${BENCH_SNAP}"; fi
    if [[ -n "${ATTN_SNAP}" ]]; then rm -f "${ATTN_SNAP}"; fi
    echo "== phase summary =="
    printf '  %-22s %8s  %s\n' "phase" "seconds" "status"
    local row
    for row in "${PHASE_ROWS[@]:-}"; do
        [[ -z "${row}" ]] && continue
        IFS='|' read -r p s st <<<"${row}"
        printf '  %-22s %8s  %s\n' "${p}" "${s}" "${st}"
    done
    echo "  total: $(( $(date +%s) - T_START ))s"
}
trap summary EXIT

phase_begin "lint"
python scripts/shmemlint.py || fail 5
phase_end

if [[ ${FAST} == 0 ]]; then
    phase_begin "tier-1 pytest"
    REPRO_SHMEMCHECK=1 python -m pytest -x -q || fail 1
    phase_end

    phase_begin "multipe (8 PEs)"
    export XLA_FLAGS="--xla_force_host_platform_device_count=8"
    for script in tests/multipe/run_*.py; do
        echo "-- multipe: ${script}"
        REPRO_SHMEMCHECK=1 python "${script}" || fail 2
    done
    unset XLA_FLAGS
    phase_end
else
    phase_begin "tier-1 pytest"
    python -m pytest -x -q || fail 1
    phase_end
fi

if [[ ${FAST} == 0 ]]; then

    # keep repo-root BENCH_serve.json fresh without a full sweep; the
    # pre-bench snapshot is the regression baseline (covers dirty
    # trees where HEAD's copy is not what this run started from)
    phase_begin "serve bench (smoke)"
    BENCH_SNAP=$(mktemp) || fail 3
    ATTN_SNAP=$(mktemp) || fail 3
    cp BENCH_serve.json "${BENCH_SNAP}" || fail 3
    python benchmarks/serve_bench.py --smoke || fail 3
    phase_end

    # same freshness contract for the attention microbench: the smoke
    # pairs (decode + chunk + verify windows, kernel vs ref) refresh in
    # place and are gated against the pre-bench snapshot
    phase_begin "attn bench (smoke)"
    cp BENCH_attn.json "${ATTN_SNAP}" || fail 3
    python benchmarks/attn_microbench.py --smoke || fail 3
    phase_end

    # the deterministic serving-quality pins get their OWN phase and
    # exit code: an SLO/hot-swap violation is a behavior change in the
    # admission policy or swap path, not a performance regression, and
    # the log should say which one broke
    phase_begin "slo gate"
    python scripts/check_bench.py --slo-only \
        --baseline "${BENCH_SNAP}" || fail 6
    phase_end

    phase_begin "check_bench"
    python scripts/check_bench.py --baseline "${BENCH_SNAP}" \
        --attn-fresh BENCH_attn.json --attn-baseline "${ATTN_SNAP}" \
        || fail 4
    phase_end
fi

echo "VERIFY_PASS"
