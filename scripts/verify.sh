#!/usr/bin/env bash
# The PR gate: every change runs this exact sequence (also `make verify`).
#
#   1. tier-1 pytest (the suite the driver enforces), then
#   2. each tests/multipe/run_*.py worker under 8 fake CPU PEs, run
#      directly so their full stdout is visible.  During phase 1 the
#      pytest subprocess wrappers for those same workers are skipped
#      (REPRO_MULTIPE_EXPLICIT) so each suite runs exactly once.
#
# Usage: scripts/verify.sh [--fast]
#   --fast: tier-1 only; the multipe workers then run through their
#   normal pytest wrappers instead of the explicit loop.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
FAST=0
[[ "${1:-}" == "--fast" ]] && FAST=1
[[ ${FAST} == 0 ]] && export REPRO_MULTIPE_EXPLICIT=1

echo "== tier-1: pytest =="
python -m pytest -x -q

if [[ ${FAST} == 0 ]]; then
    export XLA_FLAGS="--xla_force_host_platform_device_count=8"
    for script in tests/multipe/run_*.py; do
        echo "== multipe: ${script} =="
        python "${script}"
    done
    unset XLA_FLAGS

    # keep repo-root BENCH_serve.json fresh without a full sweep
    echo "== serve bench (smoke) =="
    python benchmarks/serve_bench.py --smoke
fi

echo "VERIFY_PASS"
