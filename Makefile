# Single gate for every PR: `make verify` (shmemlint + tier-1 pytest
# and the tests/multipe/ workers under REPRO_SHMEMCHECK=1 with 8 fake
# CPU PEs + smoke serve bench + check_bench regression gate — see
# scripts/verify.sh; CI runs the same script,
# .github/workflows/ci.yml).
.PHONY: verify verify-fast test lint multipe bench bench-serve bench-attn check-bench

verify:
	scripts/verify.sh

# tier-1 only (the multipe suites still run via their pytest wrappers)
verify-fast:
	scripts/verify.sh --fast

test:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m pytest -x -q

# static comm-API lint (nbi-drain, raw-collective, handle-after-free,
# drain-callback) — the verify gate's first phase
lint:
	python scripts/shmemlint.py

multipe:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} \
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	sh -c 'for s in tests/multipe/run_*.py; do echo "== $$s =="; python "$$s" || exit 1; done'

# refresh the repo-root BENCH_comm.json (quick sweep)
bench:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} \
	python benchmarks/comm_microbench.py --quick

# refresh the repo-root BENCH_serve.json (full serving sweep; `make
# verify` already refreshes the --smoke rows)
bench-serve:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} \
	python benchmarks/serve_bench.py

# refresh the repo-root BENCH_attn.json (paged decode + prefill-window
# kernel/ref sweep with the choose_block candidate cross-check; `make
# verify` already refreshes the --smoke rows)
bench-attn:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} \
	python benchmarks/attn_microbench.py

# compare BENCH_serve.json + BENCH_attn.json against the committed
# copies (what verify/CI run after the smoke benches)
check-bench:
	python scripts/check_bench.py --attn-fresh BENCH_attn.json
