"""The CI gate's own invariants (tier-1).

Two things CI leans on that nothing else pins:

  * RUN-EACH-SUITE-ONCE — `make verify` runs every tests/multipe/
    run_*.py worker explicitly and exports REPRO_MULTIPE_EXPLICIT so
    the pytest subprocess wrappers for those same workers skip.  If a
    wrapper loses its guard (or a new worker ships without one), the
    8-PE suite runs twice (or zero times) per gate — this test counts
    workers and wrappers and asserts every wrapper skips under the
    flag.

  * scripts/check_bench.py — the bench-regression comparison `make
    verify` and the main-branch CI job enforce.

  * the LINT phase — scripts/verify.sh runs scripts/shmemlint.py with
    its own exit code (5) before everything else; a seeded
    nbi-without-drain violation must turn the gate red.
"""
import importlib.util
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ======================================================================
# multipe wrappers skip exactly once under REPRO_MULTIPE_EXPLICIT
# ======================================================================
def _workers():
    d = os.path.join(ROOT, "tests", "multipe")
    return sorted(f for f in os.listdir(d)
                  if f.startswith("run_") and f.endswith(".py"))


def _pytest(env_extra, *args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_MULTIPE_EXPLICIT", None)
    env.update(env_extra)
    return subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
         os.path.join(ROOT, "tests"), "-k", "8pe", *args],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=600)


def test_every_worker_has_exactly_one_wrapper():
    """Every tests/multipe/run_*.py is invoked by exactly one pytest
    wrapper (the `-k 8pe` convention), so tier-1 coverage and the
    explicit verify loop stay in one-to-one correspondence."""
    workers = _workers()
    assert workers, "no multipe workers found"
    counts = {w: 0 for w in workers}
    tests_dir = os.path.join(ROOT, "tests")
    for fn in os.listdir(tests_dir):
        if not (fn.startswith("test_") and fn.endswith(".py")) \
                or fn == os.path.basename(__file__):
            continue
        with open(os.path.join(tests_dir, fn)) as f:
            src = f.read()
        for w in workers:
            counts[w] += src.count(f'"{w}"')
    assert all(c == 1 for c in counts.values()), counts


def test_wrappers_skip_exactly_once_under_explicit_flag():
    """With REPRO_MULTIPE_EXPLICIT set (what scripts/verify.sh exports
    before the explicit worker loop) every 8-PE pytest wrapper SKIPS —
    one skip per worker, nothing passes or fails — so each multipe
    suite runs exactly once per `make verify`."""
    n = len(_workers())
    r = _pytest({"REPRO_MULTIPE_EXPLICIT": "1"}, "-rs")
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    # count skips carrying the wrappers' own reason (other suites may
    # contribute unrelated collection skips, e.g. optional imports)
    wrapper_skips = sum(
        int(line.split("[", 1)[1].split("]", 1)[0])
        for line in r.stdout.splitlines()
        if line.startswith("SKIPPED") and "multipe workers" in line)
    assert wrapper_skips == n, (n, r.stdout)
    tail = r.stdout.strip().splitlines()[-1]
    assert "passed" not in tail and "failed" not in tail, tail


def test_wrappers_collected_without_flag():
    """Without the flag the same wrappers are real tests (collect-only:
    nothing executes here) — the suites DO run when pytest is the only
    driver, e.g. the CI pull-request job's verify --fast."""
    n = len(_workers())
    r = _pytest({}, "--collect-only")
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert f"{n}/" in r.stdout and "tests collected" in r.stdout, \
        (n, r.stdout)


# ======================================================================
# the lint phase: exists in verify.sh, fires on a seeded violation
# ======================================================================
def test_verify_sh_has_lint_phase_with_exit_code_5():
    """The gate script runs shmemlint as its own phase with the
    distinct exit code the CI log taxonomy documents."""
    with open(os.path.join(ROOT, "scripts", "verify.sh")) as f:
        src = f.read()
    assert 'phase_begin "lint"' in src
    assert "shmemlint.py" in src
    lint_line = next(line for line in src.splitlines()
                     if "shmemlint.py" in line and "fail" in line)
    assert "fail 5" in lint_line


def test_shmemlint_fires_on_seeded_nbi_violation(tmp_path):
    """End to end: shmemlint exits 0 on the shipped src/ and nonzero
    when a seeded nbi-without-drain violation is introduced."""
    script = os.path.join(ROOT, "scripts", "shmemlint.py")
    clean = subprocess.run([sys.executable, script],
                           capture_output=True, text=True, timeout=300)
    assert clean.returncode == 0, clean.stdout + clean.stderr
    assert "SHMEMLINT_PASS" in clean.stdout
    seeded = tmp_path / "repro" / "serve" / "seeded.py"
    seeded.parent.mkdir(parents=True)
    seeded.write_text(
        "def migrate_and_leak(queue, handle, page, pairs):\n"
        "    queue.put_nbi(handle, page, pairs)\n"
        "    return queue.state\n")
    bad = subprocess.run([sys.executable, script, str(tmp_path)],
                         capture_output=True, text=True, timeout=300)
    assert bad.returncode == 1, bad.stdout + bad.stderr
    assert "SHMEMLINT_FAIL" in bad.stdout and "nbi-drain" in bad.stdout


def test_ci_workflow_wires_lint_and_checker():
    """Both CI jobs run verify.sh (hence the lint phase); the full job
    runs the checker-enabled suites and uploads the checker report on
    failure."""
    with open(os.path.join(ROOT, ".github", "workflows", "ci.yml")) as f:
        ci = f.read()
    assert "verify.sh --fast" in ci and "make verify" in ci
    assert "shmemcheck-report" in ci
    with open(os.path.join(ROOT, "scripts", "verify.sh")) as f:
        vs = f.read()
    assert "REPRO_SHMEMCHECK=1 python -m pytest" in vs
    assert 'REPRO_SHMEMCHECK=1 python "${script}"' in vs


# ======================================================================
# check_bench: the regression comparison itself
# ======================================================================
def _load_check_bench():
    spec = importlib.util.spec_from_file_location(
        "check_bench", os.path.join(ROOT, "scripts", "check_bench.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _payload(**rows):
    return {"meta": {}, "results": [dict(case=c, **r)
                                    for c, r in rows.items()]}


ROW = dict(latency_p99_s=0.2, decode_p99_s=0.1, throughput_tok_s=100.0)


def test_check_bench_passes_identical_rows():
    cb = _load_check_bench()
    base = _payload(smoke=dict(ROW))
    assert cb.compare(base, base, factor=2.0, floor_s=0.05) == []


def test_check_bench_fails_p99_regression_over_factor():
    cb = _load_check_bench()
    base = _payload(smoke=dict(ROW))
    bad = _payload(smoke=dict(ROW, latency_p99_s=0.5))   # > 2 x 0.2
    fails = cb.compare(base, bad, factor=2.0, floor_s=0.05)
    assert len(fails) == 1 and "latency_p99_s" in fails[0]
    # exactly at the bound: allowed
    edge = _payload(smoke=dict(ROW, latency_p99_s=0.4))
    assert cb.compare(base, edge, factor=2.0, floor_s=0.05) == []


def test_check_bench_floor_absorbs_timer_noise():
    cb = _load_check_bench()
    base = _payload(smoke=dict(ROW, latency_p99_s=0.001,
                               decode_p99_s=0.001))
    noisy = _payload(smoke=dict(ROW, latency_p99_s=0.04,
                                decode_p99_s=0.03))      # 30-40x, tiny
    assert cb.compare(base, noisy, factor=2.0, floor_s=0.05) == []
    over = _payload(smoke=dict(ROW, latency_p99_s=0.06,
                               decode_p99_s=0.001))
    assert len(cb.compare(base, over, factor=2.0, floor_s=0.05)) == 1


def test_check_bench_fails_throughput_collapse():
    cb = _load_check_bench()
    base = _payload(smoke=dict(ROW))
    slow = _payload(smoke=dict(ROW, throughput_tok_s=40.0))  # < 100/2
    fails = cb.compare(base, slow, factor=2.0, floor_s=0.05)
    assert len(fails) == 1 and "throughput" in fails[0]


def test_check_bench_guards_spec_health():
    cb = _load_check_bench()
    base = _payload(spec=dict(ROW, spec_accept_rate=0.5,
                              spec_tokens_per_tick=1.4))
    dead = _payload(spec=dict(ROW, spec_accept_rate=0.0,
                              spec_tokens_per_tick=1.0))
    fails = cb.compare(base, dead, factor=2.0, floor_s=0.05)
    assert len(fails) == 2
    assert any("spec_accept_rate" in f for f in fails)
    assert any("spec_tokens_per_tick" in f for f in fails)


def test_check_bench_fails_when_nothing_matches():
    """An empty intersection must FAIL — a renamed case set silently
    comparing zero rows would neuter the gate."""
    cb = _load_check_bench()
    fails = cb.compare(_payload(a=dict(ROW)), _payload(b=dict(ROW)),
                       factor=2.0, floor_s=0.05)
    assert len(fails) == 1 and "compared nothing" in fails[0]


def test_check_bench_cli_end_to_end(tmp_path):
    base = tmp_path / "base.json"
    fresh = tmp_path / "fresh.json"
    base.write_text(json.dumps(_payload(smoke=dict(ROW))))
    fresh.write_text(json.dumps(_payload(smoke=dict(ROW))))
    script = os.path.join(ROOT, "scripts", "check_bench.py")
    ok = subprocess.run(
        [sys.executable, script, "--fresh", str(fresh),
         "--baseline", str(base)], capture_output=True, text=True)
    assert ok.returncode == 0 and "CHECK_BENCH_PASS" in ok.stdout
    fresh.write_text(json.dumps(
        _payload(smoke=dict(ROW, latency_p99_s=9.9))))
    bad = subprocess.run(
        [sys.executable, script, "--fresh", str(fresh),
         "--baseline", str(base)], capture_output=True, text=True)
    assert bad.returncode == 1 and "CHECK_BENCH_FAIL" in bad.stdout


# ======================================================================
# check_bench: the attention kernel/ref gates
# ======================================================================
SERVE_ROW = dict(ROW, attn_impl="ref")


def test_attn_pair_gate_requires_smoke_kernel_row():
    """A real serve-bench payload (rows carry attn_impl) must keep both
    halves of the smoke kernel/ref pair; synthetic unit payloads
    without attn_impl fields are exempt."""
    cb = _load_check_bench()
    ok = _payload(smoke=dict(SERVE_ROW),
                  smoke_kernel=dict(ROW, attn_impl="kernel"))
    assert cb.attn_pair_fails(ok) == []
    missing = _payload(smoke=dict(SERVE_ROW))
    fails = cb.attn_pair_fails(missing)
    assert len(fails) == 1 and "smoke_kernel" in fails[0]
    wrong = _payload(smoke=dict(SERVE_ROW),
                     smoke_kernel=dict(ROW, attn_impl="ref"))
    assert any("expected 'kernel'" in f
               for f in cb.attn_pair_fails(wrong))
    # fixtures without attn_impl anywhere: gate stays silent
    assert cb.attn_pair_fails(_payload(smoke=dict(ROW))) == []


def test_disagg_pair_gate_requires_topology_pair():
    """Real serve payloads (rows carry ``topology``) must keep both
    halves of the colocated/disagg_2p2d pair; synthetic fixtures
    without the field are exempt."""
    cb = _load_check_bench()
    disagg = dict(ROW, topology="2+2", handoff_signals=11,
                  handoff_waits=8, handoff_quiets=0)
    ok = _payload(colocated=dict(ROW, topology="colocated"),
                  disagg_2p2d=dict(disagg))
    assert cb.disagg_pair_fails(ok) == []
    missing = _payload(colocated=dict(ROW, topology="colocated"))
    fails = cb.disagg_pair_fails(missing)
    assert len(fails) == 1 and "disagg_2p2d" in fails[0]
    wrong = _payload(colocated=dict(ROW, topology="1+1"),
                     disagg_2p2d=dict(disagg))
    assert any("expected 'colocated'" in f
               for f in cb.disagg_pair_fails(wrong))
    # fixtures without topology anywhere: gate stays silent
    assert cb.disagg_pair_fails(_payload(smoke=dict(ROW))) == []


def test_disagg_gate_pins_zero_handoff_quiets():
    """The acceptance bar's drain contract, enforced on every disagg
    row: one tick-global quiet on the handoff queue fails the gate, as
    does a disagg row that moved no pages."""
    cb = _load_check_bench()
    base = dict(ROW, topology="2+2", handoff_signals=11,
                handoff_quiets=0)
    ok = _payload(colocated=dict(ROW, topology="colocated"),
                  disagg_2p2d=dict(base),
                  smoke_disagg=dict(ROW, topology="1+1",
                                    handoff_signals=3,
                                    handoff_quiets=0))
    assert cb.disagg_pair_fails(ok) == []
    quiety = _payload(colocated=dict(ROW, topology="colocated"),
                      disagg_2p2d=dict(base, handoff_quiets=2))
    fails = cb.disagg_pair_fails(quiety)
    assert len(fails) == 1 and "signal_wait_until" in fails[0]
    idle = _payload(colocated=dict(ROW, topology="colocated"),
                    disagg_2p2d=dict(base, handoff_signals=0))
    fails = cb.disagg_pair_fails(idle)
    assert len(fails) == 1 and "handoff_signals" in fails[0]


ROUTER_HOST = dict(ROW, topology="2+2", router="host", tokens_out=96,
                   requests=6)
ROUTER_AMO = dict(ROW, topology="2+2", router="amo", tokens_out=96,
                  requests=6, router_amos=200, router_quiets=0,
                  handoff_quiets=0, steals=1, alloc_cas_retries=0)


def test_router_pair_gate_requires_host_amo_pair():
    """Real serve payloads (rows carry ``router``) must keep both
    halves of the router_host/router_amo control-plane pair; synthetic
    fixtures without the field are exempt."""
    cb = _load_check_bench()
    ok = _payload(router_host=dict(ROUTER_HOST),
                  router_amo=dict(ROUTER_AMO))
    assert cb.router_pair_fails(ok) == []
    missing = _payload(router_host=dict(ROUTER_HOST))
    fails = cb.router_pair_fails(missing)
    assert len(fails) == 1 and "router_amo" in fails[0]
    wrong = _payload(router_host=dict(ROUTER_HOST, router="amo"),
                     router_amo=dict(ROUTER_AMO))
    assert any("expected 'host'" in f
               for f in cb.router_pair_fails(wrong))
    # fixtures without router anywhere: gate stays silent
    assert cb.router_pair_fails(_payload(smoke=dict(ROW))) == []


def test_router_gate_pins_streams_and_zero_quiets():
    """The lock-free control plane may not move a token stream or fall
    back to a global barrier: unequal pair token counts, any
    router_quiets, an idle router, or a mailbox quiet on the AMO path
    each fail the gate."""
    cb = _load_check_bench()
    moved = _payload(router_host=dict(ROUTER_HOST),
                     router_amo=dict(ROUTER_AMO, tokens_out=95))
    fails = cb.router_pair_fails(moved)
    assert len(fails) == 1 and "tokens_out" in fails[0]
    quiety = _payload(router_host=dict(ROUTER_HOST),
                      router_amo=dict(ROUTER_AMO, router_quiets=3))
    fails = cb.router_pair_fails(quiety)
    assert len(fails) == 1 and "router_quiets" in fails[0]
    idle = _payload(router_host=dict(ROUTER_HOST),
                    router_amo=dict(ROUTER_AMO, router_amos=0))
    fails = cb.router_pair_fails(idle)
    assert len(fails) == 1 and "router_amos" in fails[0]
    mailbox = _payload(router_host=dict(ROUTER_HOST),
                       router_amo=dict(ROUTER_AMO, handoff_quiets=1))
    fails = cb.router_pair_fails(mailbox)
    assert len(fails) == 1 and "handoff_quiets" in fails[0]


ATTN_ROW = dict(impl="kernel", us_per_call=500.0, max_err_vs_ref=1e-7,
                err_tol=1e-5)


def _attn_payload(**rows):
    return {"meta": {}, "results": [dict(case=c, **r)
                                    for c, r in rows.items()]}


def _attn_pair(**kernel_over):
    return _attn_payload(
        x_kernel=dict(ATTN_ROW, **kernel_over),
        x_ref=dict(impl="ref", us_per_call=100.0, max_err_vs_ref=0.0,
                   err_tol=1e-5))


def test_check_bench_attn_passes_identical_rows():
    cb = _load_check_bench()
    base = _attn_pair()
    assert cb.compare_attn(base, base, factor=2.0, floor_us=5e4) == []


def test_check_bench_attn_fails_parity_over_tol():
    cb = _load_check_bench()
    base = _attn_pair()
    bad = _attn_pair(max_err_vs_ref=1e-3)
    fails = cb.compare_attn(base, bad, factor=2.0, floor_us=5e4)
    assert len(fails) == 1 and "parity error" in fails[0]


def test_check_bench_attn_fails_missing_ref_partner():
    cb = _load_check_bench()
    base = _attn_pair()
    lonely = _attn_payload(x_kernel=dict(ATTN_ROW))
    fails = cb.compare_attn(base, lonely, factor=2.0, floor_us=5e4)
    assert any("partner" in f for f in fails)


def test_check_bench_attn_timing_floor_and_factor():
    cb = _load_check_bench()
    base = _attn_pair()
    # 100x slower but under the floor: interpreter noise, not a fail
    noisy = _attn_pair(us_per_call=4.9e4)
    assert cb.compare_attn(base, noisy, factor=2.0, floor_us=5e4) == []
    slow = _attn_pair(us_per_call=2e5)         # over floor AND factor
    fails = cb.compare_attn(base, slow, factor=2.0, floor_us=5e4)
    assert len(fails) == 1 and "us_per_call" in fails[0]


def test_check_bench_attn_fails_when_nothing_matches():
    cb = _load_check_bench()
    fails = cb.compare_attn(_attn_payload(a=dict(ATTN_ROW)),
                            _attn_payload(b=dict(ATTN_ROW)),
                            factor=2.0, floor_us=5e4)
    assert len(fails) == 1 and "compared nothing" in fails[0]


def test_check_bench_attn_cli_end_to_end(tmp_path):
    """--attn-fresh/--attn-baseline gate the microbench trajectory in
    the same invocation that gates the serve rows."""
    cb_script = os.path.join(ROOT, "scripts", "check_bench.py")
    sb = tmp_path / "serve_base.json"
    sf = tmp_path / "serve_fresh.json"
    ab = tmp_path / "attn_base.json"
    af = tmp_path / "attn_fresh.json"
    serve_ok = _payload(smoke=dict(ROW))
    sb.write_text(json.dumps(serve_ok))
    sf.write_text(json.dumps(serve_ok))
    ab.write_text(json.dumps(_attn_pair()))
    af.write_text(json.dumps(_attn_pair()))
    args = [sys.executable, cb_script, "--fresh", str(sf),
            "--baseline", str(sb), "--attn-fresh", str(af),
            "--attn-baseline", str(ab)]
    ok = subprocess.run(args, capture_output=True, text=True)
    assert ok.returncode == 0 and "CHECK_BENCH_PASS" in ok.stdout, \
        ok.stdout + ok.stderr
    af.write_text(json.dumps(_attn_pair(max_err_vs_ref=1.0)))
    bad = subprocess.run(args, capture_output=True, text=True)
    assert bad.returncode == 1 and "parity error" in bad.stdout


def test_verify_sh_has_attn_bench_phase():
    """The gate refreshes BENCH_attn.json smoke rows and hands both
    snapshots to one check_bench call."""
    with open(os.path.join(ROOT, "scripts", "verify.sh")) as f:
        src = f.read()
    assert 'phase_begin "attn bench (smoke)"' in src
    assert "attn_microbench.py --smoke" in src
    assert "--attn-fresh BENCH_attn.json" in src
    assert "--attn-baseline" in src


# ======================================================================
# check_bench: the SLO / hot-swap / stale-case gates
# ======================================================================
SLO_ROW = dict(ROW, slo_attained_interactive=1.0, slo_attained_batch=1.0,
               slo_attained_best_effort=0.5, shed_interactive=0,
               shed_batch=0, shed_best_effort=0)


def _slo_payload(**rows):
    """A healthy saturation ramp: clean under light load, best-effort
    shedding under overload, interactive protected on both."""
    base = dict(sat_low=dict(SLO_ROW),
                sat_overload=dict(SLO_ROW, shed_best_effort=2,
                                  slo_attained_best_effort=0.3))
    base.update(rows)
    return _payload(**base)


def test_slo_gate_passes_healthy_ramp():
    """Both endpoints present, interactive attained everywhere, sheds
    on best_effort only, overload actually reached; fixtures without
    SLO fields are exempt."""
    cb = _load_check_bench()
    assert cb.slo_fails(_slo_payload()) == []
    assert cb.slo_fails(_payload(smoke=dict(ROW))) == []


def test_slo_gate_fails_interactive_attainment_below_bar():
    cb = _load_check_bench()
    broken = _slo_payload(sat_overload=dict(
        SLO_ROW, shed_best_effort=2, slo_attained_interactive=0.9))
    fails = cb.slo_fails(broken)
    assert len(fails) == 1 and "slo_attained_interactive" in fails[0]
    # exactly at the bar: allowed
    edge = _slo_payload(sat_overload=dict(
        SLO_ROW, shed_best_effort=2, slo_attained_interactive=0.99))
    assert cb.slo_fails(edge) == []


def test_slo_gate_fails_shed_on_protected_classes():
    """Sheds may only ever land on best_effort — a single shed
    interactive or batch request fails the gate."""
    cb = _load_check_bench()
    for cls in ("interactive", "batch"):
        bad = _slo_payload(sat_overload=dict(
            SLO_ROW, shed_best_effort=2, **{f"shed_{cls}": 1}))
        fails = cb.slo_fails(bad)
        assert len(fails) == 1 and f"shed_{cls}" in fails[0], fails


def test_slo_gate_requires_endpoints_and_real_overload():
    cb = _load_check_bench()
    half = _payload(sat_low=dict(SLO_ROW, shed_best_effort=1))
    fails = cb.slo_fails(half)
    assert len(fails) == 1 and "sat_overload" in fails[0]
    # a ramp where nothing ever sheds exercised no admission policy
    lazy = _slo_payload(sat_overload=dict(SLO_ROW))
    fails = cb.slo_fails(lazy)
    assert len(fails) == 1 and "never reached overload" in fails[0]


SWAP_OFF = dict(ROW, tokens_out=30, requests=6, hot_swap=0,
                swap_flips=0, swap_bytes=0, swap_extra_quiets=0)
SWAP_ON = dict(ROW, tokens_out=30, requests=6, hot_swap=1,
               swap_flips=1, swap_bytes=312832, swap_extra_quiets=0)


def test_hot_swap_gate_requires_pair_and_equal_tokens():
    """Real payloads (rows carry ``hot_swap``) must keep the off/on
    pair with byte-for-byte equal serve volume; fixtures without the
    field are exempt."""
    cb = _load_check_bench()
    ok = _payload(hot_swap_off=dict(SWAP_OFF), hot_swap_on=dict(SWAP_ON))
    assert cb.hot_swap_pair_fails(ok) == []
    missing = _payload(hot_swap_off=dict(SWAP_OFF))
    fails = cb.hot_swap_pair_fails(missing)
    assert len(fails) == 1 and "hot_swap_on" in fails[0]
    moved = _payload(hot_swap_off=dict(SWAP_OFF),
                     hot_swap_on=dict(SWAP_ON, tokens_out=29))
    fails = cb.hot_swap_pair_fails(moved)
    assert len(fails) == 1 and "tokens_out" in fails[0]
    assert cb.hot_swap_pair_fails(_payload(smoke=dict(ROW))) == []


def test_hot_swap_gate_pins_flip_and_zero_extra_drains():
    """The on row must show a real streamed flip that never fell back
    to a global drain: no flip, no bytes, or any extra quiet each
    fail."""
    cb = _load_check_bench()
    unflipped = _payload(hot_swap_off=dict(SWAP_OFF),
                         hot_swap_on=dict(SWAP_ON, swap_flips=0))
    fails = cb.hot_swap_pair_fails(unflipped)
    assert len(fails) == 1 and "swap_flips" in fails[0]
    empty = _payload(hot_swap_off=dict(SWAP_OFF),
                     hot_swap_on=dict(SWAP_ON, swap_bytes=0))
    fails = cb.hot_swap_pair_fails(empty)
    assert len(fails) == 1 and "swap_bytes" in fails[0]
    drained = _payload(hot_swap_off=dict(SWAP_OFF),
                       hot_swap_on=dict(SWAP_ON, swap_extra_quiets=1))
    fails = cb.hot_swap_pair_fails(drained)
    assert len(fails) == 1 and "swap_extra_quiets" in fails[0]


def test_stale_case_gate_catches_zombie_rows():
    """A committed case the sweep no longer emits fails unless
    allowlisted in RETIRED_CASES; payloads without meta.sweep_cases
    (unit fixtures, old files) are exempt."""
    cb = _load_check_bench()
    base = _payload(smoke=dict(ROW), old_case=dict(ROW))
    fresh = _payload(smoke=dict(ROW))
    fresh["meta"]["sweep_cases"] = ["smoke"]
    fails = cb.stale_case_fails(base, fresh)
    assert len(fails) == 1 and "old_case" in fails[0]
    # the allowlist: an explicitly retired case may keep its history
    cb.RETIRED_CASES = frozenset({"old_case"})
    assert cb.stale_case_fails(base, fresh) == []
    # no roster in the fresh meta: gate stays silent
    assert cb.stale_case_fails(base, _payload(smoke=dict(ROW))) == []


def test_check_bench_slo_only_cli(tmp_path):
    """--slo-only is verify.sh's dedicated slo-gate phase: it runs the
    SLO/hot-swap/stale gates alone with its own PASS/FAIL tag."""
    script = os.path.join(ROOT, "scripts", "check_bench.py")
    base = tmp_path / "base.json"
    fresh = tmp_path / "fresh.json"
    payload = _slo_payload(hot_swap_off=dict(SWAP_OFF),
                           hot_swap_on=dict(SWAP_ON))
    base.write_text(json.dumps(payload))
    fresh.write_text(json.dumps(payload))
    args = [sys.executable, script, "--slo-only", "--fresh", str(fresh),
            "--baseline", str(base)]
    ok = subprocess.run(args, capture_output=True, text=True)
    assert ok.returncode == 0 and "CHECK_BENCH_SLO_PASS" in ok.stdout, \
        ok.stdout + ok.stderr
    broken = _slo_payload(
        sat_overload=dict(SLO_ROW, shed_best_effort=2,
                          slo_attained_interactive=0.5),
        hot_swap_off=dict(SWAP_OFF), hot_swap_on=dict(SWAP_ON))
    fresh.write_text(json.dumps(broken))
    bad = subprocess.run(args, capture_output=True, text=True)
    assert bad.returncode == 1 and "CHECK_BENCH_SLO_FAIL" in bad.stdout


def test_verify_sh_has_slo_gate_phase_with_exit_code_6():
    """The SLO gate is its own verify phase with the distinct exit
    code the log taxonomy documents, ordered before the regression
    compare so a policy violation reads as exit 6, not 4."""
    with open(os.path.join(ROOT, "scripts", "verify.sh")) as f:
        src = f.read()
    assert 'phase_begin "slo gate"' in src
    assert "--slo-only" in src
    slo_idx = src.index('phase_begin "slo gate"')
    assert "fail 6" in src[slo_idx:src.index('phase_begin "check_bench"')]
    assert slo_idx < src.index('phase_begin "check_bench"')


def test_verify_sh_prints_phase_summary_on_every_exit():
    """The per-phase (name, seconds, status) table prints from an EXIT
    trap — so it lands on failures too — and fail() records the dying
    phase as FAIL before exiting."""
    with open(os.path.join(ROOT, "scripts", "verify.sh")) as f:
        src = f.read()
    assert "trap summary EXIT" in src
    assert "phase summary" in src
    fail_body = src[src.index("fail()"):src.index("summary()")]
    assert "FAIL" in fail_body and "PHASE_ROWS" in fail_body


def test_ci_workflow_has_nightly_and_problem_matcher():
    """CI runs the full verify + full (non-smoke) sweeps on a cron
    schedule with the bench trajectories uploaded, and every job
    registers the problem matcher that annotates VERIFY_FAIL lines."""
    with open(os.path.join(ROOT, ".github", "workflows", "ci.yml")) as f:
        ci = f.read()
    assert "schedule:" in ci and "cron:" in ci
    assert "github.event_name == 'schedule'" in ci
    assert "bench-trajectories" in ci
    # the nightly sweeps run WITHOUT --smoke
    nightly = ci[ci.index("nightly:"):]
    assert "python benchmarks/serve_bench.py 2>&1" in nightly
    assert "python benchmarks/attn_microbench.py 2>&1" in nightly
    assert ci.count("::add-matcher::.github/problem-matcher.json") >= 3


def test_problem_matcher_matches_verify_fail_lines():
    import re
    with open(os.path.join(ROOT, ".github", "problem-matcher.json")) as f:
        pm = json.load(f)
    pats = [p["regexp"] for m in pm["problemMatcher"]
            for p in m["pattern"]]
    assert any(re.search(p, "VERIFY_FAIL phase=slo gate")
               for p in pats)
    assert any(re.search(p, "CHECK_BENCH_SLO_FAIL (2 violations over "
                            "4 slo/hot-swap rows):") for p in pats)
    assert any(re.search(p, "CHECK_BENCH_FAIL (1 regressions over 9 "
                            "compared cases):") for p in pats)
