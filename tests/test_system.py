"""End-to-end behaviour tests for the paper's system: the POSH layer
driving a real (tiny) training job, checkpoint-restart included."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat, configs
from repro.ckpt import Checkpointer
from repro.data import SyntheticLM
from repro.ft import run_with_restarts
from repro.models import registry
from repro.parallel.ctx import ParallelCtx, smap
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.step import make_train_step, train_state_specs

CTX = ParallelCtx(dp_size=1, tp_size=1, sp=False, remat=True,
                  param_dtype=jnp.float32, compute_dtype=jnp.float32,
                  backend="posh")


def _mesh():
    return compat.make_mesh((1, 1), ("data", "model"))


def test_e2e_train_posh_backend_with_restart(tmp_path):
    """Tiny LM trained for 24 steps THROUGH the posh collective backend,
    with an injected failure at step 11 and checkpoint-restart: training
    completes, loss decreases, restart count recorded."""
    cfg = configs.get_smoke("minitron-4b")
    api = registry.build(cfg)
    opt = AdamWConfig(lr=5e-3, zero=0)
    mesh = _mesh()
    sspecs = train_state_specs(cfg, CTX, api, opt)
    step_raw = make_train_step(cfg, CTX, api, opt)
    fn = jax.jit(smap(step_raw, mesh, (sspecs, {"tokens": P("data")}),
                      (sspecs, {"loss": P(), "grad_norm": P(),
                                "step": P()})))
    data = SyntheticLM(vocab=cfg.vocab, seq_len=cfg.max_seq, global_batch=8)

    def init_state(attempt):
        params = api.init(jax.random.PRNGKey(0), cfg, CTX)
        opt_state = smap(
            lambda p: adamw_init(p, CTX, opt), mesh,
            (api.specs(cfg, CTX),), sspecs["opt"])(params)
        return {"params": params, "opt": opt_state,
                "step": jnp.zeros((), jnp.int32)}

    def make_step(attempt):
        def step(state, step_id):
            return fn(state, data.batch(step_id))
        return step

    ck = Checkpointer(str(tmp_path), keep=2)
    ck.save_async(0, init_state(0))
    ck.wait()
    state, info = run_with_restarts(
        make_step, init_state, ck, n_steps=24,
        failure_schedule={11: RuntimeError("injected pod loss")},
        ckpt_every=6)
    assert info["restarts"] == 1
    assert info["final_step"] == 24
    losses = info["losses"]
    assert np.mean(losses[-4:]) < np.mean(losses[:4]) - 0.05
