"""Directed true-positive / true-negative corpus for `repro.analysis`.

Every checker rule gets a seeded violation that MUST be flagged and a
drain-correct twin that MUST be clean (acceptance criterion: zero false
positives on correct programs); every lint rule gets one fixture each
way, including the ``# shmem: deferred-drain`` suppression path.
"""
import contextlib
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.analysis import lint as shmemlint
from repro.analysis import shmemcheck
from repro.core import CommQueue, LocalTransport, SymmetricHeap
from repro.core.heap import SymHandle

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
N_PE = 3
HANDLE = SymHandle("buf", (8,), np.dtype(np.float32), 0, 32)


@contextlib.contextmanager
def fresh_checker():
    """A private checker instance installed into the core hooks — keeps
    these deliberate violations out of the suite-wide conftest checker
    when the whole run is under REPRO_SHMEMCHECK=1."""
    was = shmemcheck.is_enabled()
    chk = shmemcheck.ShmemChecker()
    shmemcheck._install(chk)
    try:
        yield chk
    finally:
        shmemcheck._install(None)
        if was:
            shmemcheck.enable()


def _queue(state=None, seed=7):
    state = state if state is not None else {
        "buf": np.zeros((N_PE, 8), np.float32)}
    return CommQueue("pe", state, transport=LocalTransport(N_PE),
                     delivery_seed=seed)


def _payload(value, rows=1):
    data = np.full((N_PE, rows), float(value), np.float32)
    return data


def _rules(chk):
    return [f.rule for f in chk.report()]


# ======================================================================
# ww-race: unordered overlapping puts
# ======================================================================
def test_ww_race_flagged_and_carries_both_locations():
    with fresh_checker() as chk:
        q = _queue()
        q.put_nbi(HANDLE, _payload(1.0, rows=3), [(0, 1)], offset=0)
        q.put_nbi(HANDLE, _payload(2.0, rows=3), [(0, 1)], offset=2)
        q.quiet()
    assert _rules(chk) == ["ww-race"]
    f = chk.report()[0]
    assert "test_analysis.py" in f.loc and "test_analysis.py" in f.other_loc
    assert "PE 1" in f.message


def test_fence_separated_puts_are_clean():
    with fresh_checker() as chk:
        q = _queue()
        q.put_nbi(HANDLE, _payload(1.0, rows=3), [(0, 1)], offset=0)
        q.fence()
        q.put_nbi(HANDLE, _payload(2.0, rows=3), [(0, 1)], offset=2)
        q.quiet()
    assert chk.report() == []


def test_quiet_separated_puts_are_clean():
    with fresh_checker() as chk:
        q = _queue()
        q.put_nbi(HANDLE, _payload(1.0, rows=3), [(0, 1)], offset=0)
        q.quiet()
        q.put_nbi(HANDLE, _payload(2.0, rows=3), [(0, 1)], offset=0)
        q.quiet()
    assert chk.report() == []


def test_disjoint_ranges_and_destinations_are_clean():
    with fresh_checker() as chk:
        q = _queue()
        q.put_nbi(HANDLE, _payload(1.0, rows=2), [(0, 1)], offset=0)
        q.put_nbi(HANDLE, _payload(2.0, rows=2), [(0, 1)], offset=2)  # gap ok
        q.put_nbi(HANDLE, _payload(3.0, rows=2), [(0, 2)], offset=0)  # other PE
        q.quiet()
    assert chk.report() == []


def test_per_dst_fence_only_retires_that_destination():
    with fresh_checker() as chk:
        q = _queue()
        q.put_nbi(HANDLE, _payload(1.0, rows=2), [(0, 1), (0, 2)], offset=0)
        q.fence(dst=1)
        # overlaps the still-pending dst-2 copy, not the fenced dst-1 one
        q.put_nbi(HANDLE, _payload(2.0, rows=2), [(0, 2)], offset=1)
        q.quiet()
    assert _rules(chk) == ["ww-race"]


# ======================================================================
# wr-race: heap state read with puts in flight
# ======================================================================
def test_state_read_before_drain_flagged():
    with fresh_checker() as chk:
        q = _queue()
        q.put_nbi(HANDLE, _payload(4.0), [(0, 1)])
        _ = q.state                      # target range still undefined
        q.quiet()
    assert _rules(chk) == ["wr-race"]


def test_state_read_after_drain_clean():
    with fresh_checker() as chk:
        q = _queue()
        q.put_nbi(HANDLE, _payload(4.0), [(0, 1)])
        q.quiet()
        _ = q.state
    assert chk.report() == []


# ======================================================================
# heap lifetime: use-after-free / stale handle / double free
# ======================================================================
def test_put_through_freed_handle_flagged():
    with fresh_checker() as chk:
        heap = SymmetricHeap(("pe",), capacity_bytes=1 << 20)
        h = heap.alloc("x", (8,), np.float32)
        q = _queue({"x": np.zeros((N_PE, 8), np.float32)})
        heap.free(h)
        q.put_nbi(h, _payload(1.0), [(0, 1)])
        q.quiet()
    assert "use-after-free" in _rules(chk)


def test_live_handle_roundtrip_clean():
    with fresh_checker() as chk:
        heap = SymmetricHeap(("pe",), capacity_bytes=1 << 20)
        h = heap.alloc("x", (8,), np.float32)
        q = _queue({"x": np.zeros((N_PE, 8), np.float32)})
        q.put_nbi(h, _payload(1.0), [(0, 1)])
        q.quiet()
        heap.free(h)
    assert chk.report() == []


def test_stale_handle_after_realloc_move_flagged():
    with fresh_checker() as chk:
        heap = SymmetricHeap(("pe",), capacity_bytes=1 << 20)
        old = heap.alloc("x", (8,), np.float32)
        heap.alloc("blocker", (8,), np.float32)   # forbids in-place grow
        new = heap.realloc("x", (4096,))
        assert new.offset != old.offset           # it moved
        q = _queue({"x": np.zeros((N_PE, 8), np.float32)})
        q.put_nbi(old, _payload(1.0), [(0, 1)])   # through the old extent
        q.quiet()
    assert "stale-handle" in _rules(chk)


def test_double_free_flagged():
    with fresh_checker() as chk:
        heap = SymmetricHeap(("pe",), capacity_bytes=1 << 20)
        heap.alloc("x", (8,), np.float32)
        heap.free("x")
        with pytest.raises(KeyError):
            heap.free("x")
    assert "double-free" in _rules(chk)


def test_free_of_never_allocated_name_not_flagged():
    # the heap's own KeyError is the right error; the checker only
    # escalates frees of names it saw retired
    with fresh_checker() as chk:
        heap = SymmetricHeap(("pe",), capacity_bytes=1 << 20)
        with pytest.raises(KeyError):
            heap.free("ghost")
    assert chk.report() == []


# ======================================================================
# Fact 1: cross-PE offset symmetry
# ======================================================================
def test_offset_asymmetry_flagged():
    with fresh_checker() as chk:
        ha = SymmetricHeap(("pe",), capacity_bytes=1 << 20)
        hb = SymmetricHeap(("pe",), capacity_bytes=1 << 20)
        ha.alloc("w", (8,), np.float32)
        hb.alloc("w", (16,), np.float32)          # PE-dependent size
        bad = chk.compare_heaps(ha, hb)
    assert [f.rule for f in bad] == ["offset-asymmetry"]
    assert "offset-asymmetry" in _rules(chk)


def test_symmetric_heaps_compare_clean():
    with fresh_checker() as chk:
        heaps = [SymmetricHeap(("pe",), capacity_bytes=1 << 20)
                 for _ in range(3)]
        for h in heaps:                           # same SPMD call sequence
            h.alloc("w", (8,), np.float32)
            h.alloc("kv", (4, 2), np.int32)
        assert chk.compare_heaps(*heaps) == []
    assert chk.report() == []


def test_alloc_count_divergence_flagged():
    with fresh_checker() as chk:
        ha = SymmetricHeap(("pe",), capacity_bytes=1 << 20)
        hb = SymmetricHeap(("pe",), capacity_bytes=1 << 20)
        for h in (ha, hb):
            h.alloc("w", (8,), np.float32)
        hb.alloc("extra", (8,), np.float32)       # branch ran on one PE
        bad = chk.compare_heaps(ha, hb)
    assert [f.rule for f in bad] == ["offset-asymmetry"]
    assert "extra" in bad[0].message


# ======================================================================
# nested drain
# ======================================================================
def test_drain_callback_calling_fence_flagged():
    with fresh_checker() as chk:
        q = _queue()
        q.allreduce_nbi(np.ones(3), lambda x: (q.fence(), x)[1])
        q.quiet()
    assert "nested-drain" in _rules(chk)


def test_plain_reduce_callback_clean():
    with fresh_checker() as chk:
        q = _queue()
        r = q.allreduce_nbi(np.ones(3), lambda x: x * 2)
        q.quiet()
        np.testing.assert_allclose(r.value(), 2.0)
    assert chk.report() == []


# ======================================================================
# enable/suspend machinery
# ======================================================================
def test_disabled_checker_records_nothing():
    before = shmemcheck.is_enabled()
    q = _queue()
    with shmemcheck.suspended():
        q.put_nbi(HANDLE, _payload(1.0, rows=3), [(0, 1)], offset=0)
        q.put_nbi(HANDLE, _payload(2.0, rows=3), [(0, 1)], offset=1)
        _ = q.state
        q.quiet()
        assert not shmemcheck.is_enabled()
    assert shmemcheck.is_enabled() == before


def test_suspended_restores_installed_checker():
    with fresh_checker() as chk:
        q = _queue()
        with shmemcheck.suspended():
            q.put_nbi(HANDLE, _payload(1.0, rows=3), [(0, 1)], offset=0)
            q.put_nbi(HANDLE, _payload(2.0, rows=3), [(0, 1)], offset=1)
            q.quiet()                    # racy, but the checker is off
        assert chk.report() == []
        # NOTE: suspended() re-installs the global checker, not ours —
        # mirror what matters: hooks are live again afterwards
        assert shmemcheck.is_enabled()


def test_env_autoenable_in_subprocess():
    """REPRO_SHMEMCHECK=1 arms the checker lazily at first queue/heap
    construction — the path the multipe worker scripts rely on."""
    prog = textwrap.dedent("""
        import numpy as np
        from repro.core import CommQueue, LocalTransport
        from repro.core.heap import SymHandle
        from repro.analysis import shmemcheck
        h = SymHandle("buf", (8,), np.dtype(np.float32), 0, 32)
        q = CommQueue("pe", {"buf": np.zeros((2, 8), np.float32)},
                      transport=LocalTransport(2))
        assert shmemcheck.is_enabled()
        q.put_nbi(h, np.ones((2, 2), np.float32), [(0, 1)], offset=0)
        q.put_nbi(h, np.ones((2, 2), np.float32), [(0, 1)], offset=1)
        q.quiet()
        rules = [f.rule for f in shmemcheck.report()]
        assert rules == ["ww-race"], rules
        print("AUTOENABLE_OK")
    """)
    env = dict(os.environ, REPRO_SHMEMCHECK="1",
               PYTHONPATH=os.path.join(ROOT, "src"))
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, env=env, timeout=300)
    assert r.returncode == 0, r.stderr
    assert "AUTOENABLE_OK" in r.stdout


def test_findings_cap_bounds_memory():
    with fresh_checker() as chk:
        q = _queue()
        for _ in range(shmemcheck.MAX_FINDINGS + 50):
            _ = q.state                  # cheap repeated wr-race source
            q.put_nbi(HANDLE, _payload(1.0, rows=8), [(0, 1)], offset=0)
        q.quiet()
    assert len(chk.report()) == shmemcheck.MAX_FINDINGS
    assert chk.dropped > 0


# ======================================================================
# put-with-signal rules: signal-race / raw-signal
# ======================================================================
SIG = SymHandle("sig", (4,), np.dtype(np.int64), 32, 32)


def _sig_queue(seed=7):
    return CommQueue("pe", {"buf": np.zeros((N_PE, 8), np.float32),
                            "sig": np.zeros((N_PE, 4), np.int64)},
                     transport=LocalTransport(N_PE), delivery_seed=seed)


def test_signal_race_read_before_wait_flagged():
    """Reading state while a guarded transfer is in flight is a
    SIGNAL-race, not a generic wr-race: the fix is the wait, and the
    message says so.  Both the payload and its signal word are
    undefined until the wait returns."""
    with fresh_checker() as chk:
        q = _sig_queue()
        q.put_signal_nbi(HANDLE, _payload(5.0), [(0, 1)], SIG, 1,
                         offset=2, sig_offset=0)
        _ = q.state
        q.signal_wait_until(SIG, "eq", 1, sig_offset=0, pe=1)
    assert _rules(chk) == ["signal-race", "signal-race"]
    assert "signal_wait_until" in chk.report()[0].message


def test_signal_read_after_wait_clean():
    with fresh_checker() as chk:
        q = _sig_queue()
        q.put_signal_nbi(HANDLE, _payload(5.0), [(0, 1)], SIG, 1,
                         offset=2, sig_offset=0)
        q.signal_wait_until(SIG, "eq", 1, sig_offset=0, pe=1)
        _ = q.state
    assert chk.report() == []


def test_wait_retires_exactly_its_guards():
    """A wait on word 0 leaves word 1's ticket pending: a read after
    it still races with ticket B (and ONLY ticket B)."""
    with fresh_checker() as chk:
        q = _sig_queue()
        q.put_signal_nbi(HANDLE, _payload(1.0), [(0, 1)], SIG, 1,
                         offset=0, sig_offset=0)
        q.put_signal_nbi(HANDLE, _payload(2.0), [(0, 1)], SIG, 2,
                         offset=4, sig_offset=1)
        q.signal_wait_until(SIG, "eq", 1, sig_offset=0, pe=1)
        _ = q.state
        q.signal_wait_until(SIG, "eq", 2, sig_offset=1, pe=1)
    rules = _rules(chk)
    assert rules == ["signal-race", "signal-race"]
    # both findings belong to ticket B (word 1), none to the retired A
    assert all("'sig'+1" in f.message for f in chk.report())


def test_raw_signal_put_on_signal_word_flagged():
    """A plain put_nbi to a word that put_signal traffic guards races
    with signal delivery no wait can see — its own rule."""
    with fresh_checker() as chk:
        q = _sig_queue()
        q.put_signal_nbi(HANDLE, _payload(1.0), [(0, 1)], SIG, 1,
                         offset=0, sig_offset=2)
        q.signal_wait_until(SIG, "eq", 1, sig_offset=2, pe=1)
        q.put_nbi(SIG, np.ones((N_PE, 1), np.int64), [(0, 1)], offset=2)
        q.quiet()
    assert "raw-signal" in _rules(chk)


def test_raw_signal_other_offset_clean():
    """Plain puts to the REST of a signal pad are ordinary data."""
    with fresh_checker() as chk:
        q = _sig_queue()
        q.put_signal_nbi(HANDLE, _payload(1.0), [(0, 1)], SIG, 1,
                         offset=0, sig_offset=2)
        q.signal_wait_until(SIG, "eq", 1, sig_offset=2, pe=1)
        q.put_nbi(SIG, np.ones((N_PE, 1), np.int64), [(0, 1)], offset=0)
        q.quiet()
    assert chk.report() == []


def test_multi_page_ticket_same_word_no_ww_race():
    """The handoff idiom — several put_signal_nbi guarded by ONE word
    (same SET value) — must not be read as the signal word ww-racing
    itself; and a fence covering the pairs retires the guards too."""
    with fresh_checker() as chk:
        q = _sig_queue()
        for i in range(3):
            q.put_signal_nbi(HANDLE, _payload(float(i)), [(0, 1)], SIG,
                             7, offset=i, sig_offset=3)
        q.signal_wait_until(SIG, "eq", 7, sig_offset=3, pe=1)
        q.put_signal_nbi(HANDLE, _payload(9.0), [(0, 2)], SIG, 8,
                         offset=0, sig_offset=3)
        q.fence()                        # covering drain is also legal
        _ = q.state
    assert chk.report() == []


# ======================================================================
# AMO rules: amo-race, both directions, and the per-word retire
# ======================================================================
def test_amo_race_plain_put_on_atomic_word_flagged():
    """A blind put onto a word carrying AMO traffic races the
    read-modify-write cycle — its own rule, naming amo_nbi as the fix."""
    with fresh_checker() as chk:
        q = _sig_queue()
        q.amo_nbi(SIG, "fadd", [(0, 1)], value=1, offset=2)
        q.amo_wait(SIG, offset=2)
        q.put_nbi(SIG, np.ones((N_PE, 1), np.int64), [(0, 1)], offset=2)
        q.quiet()
    assert "amo-race" in _rules(chk)
    assert "amo_nbi" in chk.report()[0].message


def test_amo_race_amo_over_pending_put_flagged():
    """The mirror: an AMO issued while a plain put covering the word is
    still pending — the shuffle decides which side of the
    read-modify-write the blind write lands on.  Both locations carried."""
    with fresh_checker() as chk:
        q = _sig_queue()
        q.put_nbi(SIG, np.ones((N_PE, 1), np.int64), [(0, 1)], offset=2)
        q.amo_nbi(SIG, "fadd", [(0, 1)], value=1, offset=2)
        q.amo_wait(SIG, offset=2)
        q.quiet()
    assert _rules(chk) == ["amo-race"]
    assert chk.report()[0].other_loc is not None


def test_amo_plain_put_other_word_clean():
    """Plain puts to the REST of an atomic-word pad are ordinary data."""
    with fresh_checker() as chk:
        q = _sig_queue()
        q.amo_nbi(SIG, "fadd", [(0, 1)], value=1, offset=2)
        q.amo_wait(SIG, offset=2)
        q.put_nbi(SIG, np.ones((N_PE, 1), np.int64), [(0, 1)], offset=0)
        q.quiet()
    assert chk.report() == []


def test_concurrent_amos_same_word_clean():
    """Pending AMOs on one word are NOT races — each is its own
    linearization point; the shuffle only picks the order."""
    with fresh_checker() as chk:
        q = _sig_queue()
        for src in range(3):
            q.amo_nbi(SIG, "fadd", [(src, 1)], value=1, offset=2)
        q.amo_wait(SIG, offset=2)
    assert chk.report() == []


def test_amo_wait_retires_exactly_its_word():
    """amo_wait on word 2 must leave word 3's pending AMO alone: a
    plain put over word 3 afterwards still finds it pending (mirror
    amo-race), while word 2 is fully retired."""
    with fresh_checker() as chk:
        q = _sig_queue()
        q.amo_nbi(SIG, "fadd", [(0, 1)], value=1, offset=2)
        q.amo_nbi(SIG, "fadd", [(0, 1)], value=1, offset=3)
        q.amo_wait(SIG, offset=2)
        pend = chk._pending[id(q)]
        assert [w.amo_key for w in pend] == [("sig", 3)]
        q.amo_wait(SIG, offset=3)
        assert chk._pending[id(q)] == []
    assert chk.report() == []


# ======================================================================
# lint fixtures — one per rule, both polarities
# ======================================================================
def _lint(src, relpath="repro/serve/fixture.py"):
    return shmemlint.lint_source(textwrap.dedent(src), relpath, relpath)


def test_lint_nbi_without_drain_flagged():
    errs = _lint("""
        def leak(q, h, x, pairs):
            q.put_nbi(h, x, pairs)
            return q.state
    """)
    assert [e.rule for e in errs] == ["nbi-drain"]


def test_lint_nbi_with_quiet_clean():
    errs = _lint("""
        def ok(q, h, x, pairs):
            q.put_nbi(h, x, pairs)
            return q.quiet()
    """)
    assert errs == []


def test_lint_nbi_in_loop_drained_after_clean():
    errs = _lint("""
        def ok(q, h, pages, pairs):
            for i, x in enumerate(pages):
                q.put_nbi(h, x, pairs, offset=i)
            q.quiet()
    """)
    assert errs == []


def test_lint_branch_missing_drain_flagged():
    errs = _lint("""
        def half(q, h, x, pairs, flush):
            q.put_nbi(h, x, pairs)
            if flush:
                q.quiet()
            return q.state
    """)
    assert [e.rule for e in errs] == ["nbi-drain"]


def test_lint_both_branches_drained_clean():
    errs = _lint("""
        def ok(q, h, x, pairs, last):
            q.put_nbi(h, x, pairs)
            if last:
                q.quiet()
            else:
                q.fence()
            return q.state
    """)
    assert errs == []


def test_lint_deferred_drain_annotation_on_call_suppresses():
    errs = _lint("""
        def pipeline_issue(q, h, x, pairs):
            return q.put_nbi(h, x, pairs)  # shmem: deferred-drain
    """)
    assert errs == []


def test_lint_deferred_drain_annotation_on_def_suppresses():
    errs = _lint("""
        def pipeline_issue(q, h, x, pairs):  # shmem: deferred-drain
            q.put_nbi(h, x, pairs)
            q.put_nbi(h, x, pairs, offset=1)
    """)
    assert errs == []


def test_lint_raise_is_accepted_exit():
    errs = _lint("""
        def ok(q, h, x, pairs):
            q.put_nbi(h, x, pairs)
            if x is None:
                raise ValueError("bad payload")
            q.quiet()
    """)
    assert errs == []


def test_lint_raw_collective_flagged_outside_comm():
    errs = _lint("""
        import jax

        def reduce_me(x):
            return jax.lax.psum(x, "model")
    """)
    assert [e.rule for e in errs] == ["raw-collective"]


def test_lint_raw_collective_allowed_in_comm_and_core():
    src = """
        import jax

        def impl(x):
            return jax.lax.psum(x, "model")
    """
    assert _lint(src, "repro/comm/communicator.py") == []
    assert _lint(src, "repro/core/p2p.py") == []
    assert _lint(src, "repro/compat.py") == []


def test_lint_axis_index_is_not_a_collective():
    errs = _lint("""
        import jax

        def my_rank():
            return jax.lax.axis_index("model")
    """)
    assert errs == []


def test_lint_handle_after_free_flagged():
    errs = _lint("""
        def leak(heap, q, x, pairs):
            h = heap.alloc("tmp", (8,), "float32")
            heap.free(h)
            q.put_nbi(h, x, pairs)  # shmem: deferred-drain
    """)
    assert [e.rule for e in errs] == ["handle-after-free"]


def test_lint_handle_rebound_after_free_clean():
    errs = _lint("""
        def ok(heap, q, x, pairs):
            h = heap.alloc("tmp", (8,), "float32")
            heap.free(h)
            h = heap.alloc("tmp", (16,), "float32")
            q.put_nbi(h, x, pairs)
            q.quiet()
    """)
    assert errs == []


def test_lint_drain_in_callback_flagged():
    errs = _lint("""
        def bad(q, g):
            r = q.allreduce_nbi(g, lambda x: (q.quiet(), x)[1])
            q.quiet()
            return r
    """)
    assert [e.rule for e in errs] == ["drain-callback"]


def test_lint_plain_callback_clean():
    errs = _lint("""
        def ok(q, g, comm):
            r = q.allreduce_nbi(g, comm.psum)
            q.quiet()
            return r
    """)
    assert errs == []


def test_lint_put_signal_drained_by_wait_clean():
    """signal_wait_until is a first-class drain for the nbi rule — the
    put-with-signal idiom needs no quiet."""
    errs = _lint("""
        def handoff(q, h, x, pairs, sig):
            q.put_signal_nbi(h, x, pairs, sig, 1, sig_offset=0)
            q.signal_wait_until(sig, "eq", 1, sig_offset=0, pe=1)
            return q.state
    """)
    assert errs == []


def test_lint_put_signal_without_wait_flagged():
    errs = _lint("""
        def leak(q, h, x, pairs, sig):
            q.put_signal_nbi(h, x, pairs, sig, 1, sig_offset=0)
            return q.state
    """)
    assert [e.rule for e in errs] == ["nbi-drain"]


def test_lint_put_signal_deferred_drain_suppresses():
    """The producer/consumer split: issue here, wait elsewhere — the
    annotation carries that contract (disagg's _put_pages idiom)."""
    errs = _lint("""
        def issue(q, h, x, pairs, sig, t):
            q.put_signal_nbi(  # shmem: deferred-drain
                h, x, pairs, sig, t + 1, sig_offset=0)
    """)
    assert errs == []


def test_lint_signal_wait_in_callback_flagged():
    """A blocking signal wait inside completion handling deadlocks the
    same way quiet does — drain-callback covers it."""
    errs = _lint("""
        def bad(q, g, sig):
            r = q.allreduce_nbi(
                g, lambda x: (q.signal_wait_until(sig, "eq", 1), x)[1])
            q.quiet()
            return r
    """)
    assert [e.rule for e in errs] == ["drain-callback"]


def test_lint_amo_drained_by_amo_wait_clean():
    """amo_wait is a first-class drain for the nbi rule — the queue-AMO
    idiom needs no quiet."""
    errs = _lint("""
        def bump(q, h, pairs):
            q.amo_nbi(h, "fadd", pairs, value=1, offset=0)
            q.amo_wait(h, offset=0)
            return q.state
    """)
    assert errs == []


def test_lint_amo_without_drain_flagged():
    errs = _lint("""
        def leak(q, h, pairs):
            q.amo_nbi(h, "fadd", pairs, value=1, offset=0)
            return q.state
    """)
    assert [e.rule for e in errs] == ["nbi-drain"]


def test_lint_amo_wait_in_callback_flagged():
    """A blocking AMO drain inside completion handling deadlocks the
    same way quiet does — drain-callback covers it."""
    errs = _lint("""
        def bad(q, g, h):
            r = q.allreduce_nbi(
                g, lambda x: (q.amo_wait(h, offset=0), x)[1])
            q.quiet()
            return r
    """)
    assert [e.rule for e in errs] == ["drain-callback"]


def test_lint_src_tree_is_clean():
    """The acceptance criterion: shmemlint exits 0 on the shipped
    source tree."""
    errs = shmemlint.lint_paths([os.path.join(ROOT, "src")])
    assert errs == [], "\n".join(str(e) for e in errs)


def test_shmemlint_cli_exit_codes(tmp_path):
    script = os.path.join(ROOT, "scripts", "shmemlint.py")
    r = subprocess.run([sys.executable, script], capture_output=True,
                       text=True, timeout=300)
    assert r.returncode == 0 and "SHMEMLINT_PASS" in r.stdout
    bad = tmp_path / "bad.py"
    bad.write_text("def f(q, h, x, p):\n    q.put_nbi(h, x, p)\n")
    r = subprocess.run([sys.executable, script, str(bad)],
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 1 and "SHMEMLINT_FAIL" in r.stdout
    assert "nbi-drain" in r.stdout
