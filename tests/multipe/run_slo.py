"""SLO scheduling + weight hot-swap on a real 8-PE mesh — subprocess
worker (mesh wiring shared with the serving worker).

Three checks:

  1. SLO TRAFFIC PARITY — a seeded mixed-class trace (interactive /
     batch / best_effort, tick deadlines, two tenants) served under an
     attached SLOPolicy produces IDENTICAL token streams AND identical
     shed/attainment summaries across xla / posh / pallas: the policy
     is host-side deterministic state, so priority admission, deadline
     shedding and degradation cannot introduce backend divergence.

  2. HOT-SWAP FLIP = COLD START — generation 2 streams into the live
     mesh engine between serving ticks (put-with-signal batches over
     the 8-PE staging heap) and flips via an atomic compare-and-swap on
     the generation word; a trace served AFTER the flip is bit-
     identical to a cold-started engine on the new weights, greedy and
     sampled, on every backend.

  3. ZERO EXTRA DRAINS — the swap queue retires its transfers with
     per-word/per-transfer waits only: ``swap_extra_quiets == 0``
     (quiets + fences inside the ``phase("swap")`` stat window), the
     same pin the bench gate enforces on the hot_swap row pair.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax

from repro import configs, serve
from repro.models import registry
from repro.parallel.ctx import ParallelCtx
from run_serve import DP, TP, SAMPLED, build

N_PE = DP * TP


def _init_params(key):
    cfg = configs.get_smoke("qwen3-8b")
    ctx1 = ParallelCtx(dp_size=1, tp_size=1, sp=False, remat=False,
                       param_dtype=jax.numpy.float32,
                       compute_dtype=jax.numpy.float32)
    return registry.build(cfg).init(jax.random.PRNGKey(key), cfg, ctx1)


def _slo_scfg():
    return serve.ServeConfig(page_tokens=4, n_pages=24, max_batch=3,
                             max_seq=32, prefill_chunk=3,
                             attn_impl="ref", slo=serve.SLOConfig())


def _slo_reqs(vocab):
    # mixed classes on the tick clock: everything arrives at t=0, the
    # best-effort deadline (4 ticks) cannot survive the backlog
    reqs = []
    for i in range(8):
        prio = ("interactive", "best_effort", "batch")[i % 3]
        reqs.append(serve.Request(
            rid=i, prompt=[(5 * i + j) % vocab for j in range(5)],
            max_new=5, t_arrive=0.0, priority=prio,
            deadline={"interactive": 200.0, "batch": 400.0,
                      "best_effort": 4.0}[prio],
            tenant=i % 2))
    return reqs


def check_slo_parity():
    got = {}
    for backend in ("xla", "posh", "pallas"):
        eng, cfg = build(backend, scfg=_slo_scfg())
        done = eng.run(_slo_reqs(cfg.vocab), clock="tick")
        m = eng.metrics()["slo"]
        got[backend] = ({r.rid: list(r.out) for r in done}, m)
        print(f"  [{backend}] finished={m['finished']} shed={m['shed']} "
              f"attained={m['attained']}")
    assert got["xla"] == got["posh"] == got["pallas"], got
    _, m = got["xla"]
    assert m["shed"]["best_effort"] > 0, m
    assert m["shed"]["interactive"] == 0, m
    assert m["attained"]["interactive"] == 1.0, m
    print("  SLO streams + shed/attainment identical across "
          "xla/posh/pallas")


def _swap_reqs(vocab, rids, sampling=None):
    return [serve.Request(rid=r, prompt=[(7 * r + k) % vocab
                                         for k in range(5)],
                          max_new=5, sampling=sampling or serve.GREEDY)
            for r in rids]


def check_hot_swap_cold_start_identity():
    new_params = _init_params(7)
    for tag, sampling in (("greedy", None), ("sampled", SAMPLED)):
        for backend in ("xla", "posh", "pallas"):
            eng, cfg = build(backend)
            eng.begin_hot_swap(new_params, n_pe=N_PE, chunk_rows=2)
            eng.run(_swap_reqs(cfg.vocab, range(3), sampling),
                    clock="tick")
            assert eng.swap_stats["flips"] == 1, eng.swap_stats
            assert eng.swap_stats["swap_extra_quiets"] == 0, \
                eng.swap_stats
            eng.run(_swap_reqs(cfg.vocab, range(10, 13), sampling),
                    clock="tick")
            post = {r.rid: list(r.out) for r in eng.finished
                    if r.rid >= 10}
            cold, _ = build(backend)
            cold.exec.set_params(new_params)
            cold.run(_swap_reqs(cfg.vocab, range(10, 13), sampling),
                     clock="tick")
            want = {r.rid: list(r.out) for r in cold.finished}
            assert post == want, (backend, tag, post, want)
        print(f"  {tag} post-flip streams == cold start on new "
              f"weights across xla/posh/pallas")


def main():
    check_slo_parity()
    check_hot_swap_cold_start_identity()
    print("SLO_PASS")


if __name__ == "__main__":
    main()
