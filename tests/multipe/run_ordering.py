"""Ordered-pipeline validation on a real 8-PE mesh — subprocess worker
(8 fake CPU devices), invoked by tests/test_ordering.py.

Three suites:

  1. PermuteTransport == LocalTransport: random nbi-op sequences are
     replayed through the CommQueue twice — once inside shard_map with
     real collective-permute delivery, once on the whole-system numpy
     oracle — with identical delivery seeds; final heap states must be
     exactly equal.  Payload sizes include the posh_micro smoke sweep
     (the paper's own buffer-size microbench config).
  2. Fence/quiet directed checks on the mesh (per-destination ordering,
     pending invisibility, get_nbi after the barrier).
  3. Overlapped gradient sync: a tiny LM trained over dp=8 with
     blocking vs nonblocking (single-quiet) DP reduction, unbucketed
     and bucketed — loss trajectories and final params must be
     BIT-identical (np.array_equal, no tolerance).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import random

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat, configs
from repro.analysis import shmemcheck
from repro.core import CommQueue, LocalTransport, SymmetricHeap
from repro.data import SyntheticLM
from repro.models import registry
from repro.parallel.ctx import ParallelCtx, smap as ctx_smap
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.step import make_train_step, train_state_specs

N = 8
OBJ_LEN = 8
mesh1d = compat.make_mesh((N,), ("pe",))


def smap(fn, in_specs, out_specs):
    return compat.shard_map(fn, mesh=mesh1d, in_specs=in_specs,
                            out_specs=out_specs, check_vma=False)


# ======================================================================
# 1. permute transport vs the numpy oracle, same delivery schedule
# ======================================================================
def gen_sequence(rng, n_events=12):
    # Deliberately independent of tests/test_ordering.py's generator
    # (3-PE oracle model there, 8-PE mesh here; different payload
    # encodings): drift between the drivers is caught by the exact
    # mesh==oracle equality below, not by sharing code.
    events = []
    val = 0
    for j in range(rng.randint(2, n_events)):
        kind = rng.choices(["put", "fence", "fence_all"],
                           weights=[6, 2, 1])[0]
        if j == 0 or kind == "put":      # at least one put per sequence
            k = rng.randint(1, N)
            pairs = list(zip(rng.sample(range(N), k),
                             rng.sample(range(N), k)))
            offset = rng.randint(0, OBJ_LEN - 1)
            rows = rng.randint(1, OBJ_LEN - offset)
            val += 1
            events.append(("put", pairs, offset, rows, float(val)))
        elif kind == "fence":
            events.append(("fence", rng.randrange(N)))
        else:
            events.append(("fence", None))
    return events


def payloads(events):
    """Global (N, rows) payload per put; row s = 100*val + s + col/16."""
    out = []
    for e in events:
        if e[0] != "put":
            continue
        _, pairs, _, rows, val = e
        data = np.zeros((N, rows), np.float32)
        for s, _ in pairs:
            data[s] = 100.0 * val + s + np.arange(rows) / 16.0
        out.append(data)
    return out


def run_mesh(events, seed, heap, handle):
    datas = payloads(events)

    def body(datas):
        q = CommQueue("pe", {"buf": jnp.zeros((OBJ_LEN,), jnp.float32)},
                      delivery_seed=seed)
        it = iter(datas)
        for e in events:
            if e[0] == "put":
                _, pairs, offset, rows, _ = e
                q.put_nbi(handle, next(it)[0], pairs, offset=offset)
            else:
                q.fence(e[1])
        state = q.quiet()
        assert q.pending_ops() == 0
        return state["buf"][None]

    fn = smap(body, ([P("pe")] * len(datas),), P("pe", None))
    return np.asarray(fn(datas))


def run_local(events, seed, handle):
    state = {"buf": np.zeros((N, OBJ_LEN), np.float32)}
    q = CommQueue("pe", state, transport=LocalTransport(N),
                  delivery_seed=seed)
    it = iter(payloads(events))
    for e in events:
        if e[0] == "put":
            _, pairs, offset, rows, _ = e
            q.put_nbi(handle, next(it), pairs, offset=offset)
        else:
            q.fence(e[1])
    return np.asarray(q.quiet()["buf"])


def check_transport_equivalence():
    heap = SymmetricHeap(("pe",))
    handle = heap.alloc("buf", (OBJ_LEN,), jnp.float32)
    # The generated sequences deliberately include unordered overlapping
    # puts (that is the property under test: the delivery shuffle must
    # agree between transports) — the script analogue of the
    # @pytest.mark.shmem_racy opt-out.
    with shmemcheck.suspended():
        for i in range(6):
            events = gen_sequence(random.Random(i))
            for seed in (None, 0, 11):
                got = run_mesh(events, seed, heap, handle)
                want = run_local(events, seed, handle)
                np.testing.assert_array_equal(
                    got, want, err_msg=f"seq {i} seed {seed}")
    print("  permute transport == local oracle (6 sequences x 3 seeds)")


def check_posh_micro_sweep():
    """put_nbi at the paper's microbench buffer sizes: ring-neighbour
    nonblocking puts, one fence per size, delivery checked exactly."""
    micro = configs.get_smoke("posh_micro")
    heap = SymmetricHeap(("pe",))
    pairs = [(i, (i + 1) % N) for i in range(N)]
    for elems in micro.buffer_sizes:
        h = heap.alloc(f"sweep{elems}", (elems,), jnp.float32)

        def body(x):
            q = CommQueue("pe", {h.name: jnp.zeros((elems,), jnp.float32)})
            q.put_nbi(h, x[0], pairs)
            q.fence()                      # ordering point delivers
            return q.state[h.name][None]

        x = (jnp.arange(N * elems, dtype=jnp.float32)
             .reshape(N, elems))
        out = np.asarray(smap(body, P("pe"), P("pe", None))(x))
        want = np.roll(np.asarray(x), 1, axis=0)
        np.testing.assert_array_equal(out, want)
    print(f"  posh_micro nbi sweep ok: sizes {micro.buffer_sizes}")


# ======================================================================
# 2. directed fence/quiet semantics on the mesh
# ======================================================================
def check_fence_semantics_mesh():
    heap = SymmetricHeap(("pe",))
    h = heap.alloc("cell", (1,), jnp.float32)

    def body(x):
        # A then fence then B to the same destination: B must win for
        # every delivery seed (here: one that would reorder A/B if the
        # fence were ignored)
        q = CommQueue("pe", {"cell": jnp.zeros((1,), jnp.float32)},
                      delivery_seed=1)
        q.put_nbi(h, x[0] * 0 + 1.0, [(0, 3)])
        q.fence(dst=3)
        q.put_nbi(h, x[0] * 0 + 2.0, [(1, 3)])
        st = q.quiet()
        g = q.get_nbi(h, [(3, 0)], size=1)   # PE0 reads PE3 post-quiet
        q.quiet()
        return jnp.concatenate([st["cell"], g.value()])[None]

    out = np.asarray(smap(body, P("pe"), P("pe", None))(
        jnp.ones((N, 1), jnp.float32)))
    assert out[3, 0] == 2.0, out            # fence ordered A before B
    assert out[0, 1] == 2.0, out            # the get observed the quiet
    print("  mesh fence/quiet semantics ok")


# ======================================================================
# 3. overlapped grad sync: bit-identical to the blocking path
# ======================================================================
def check_overlapped_training():
    mesh = compat.make_mesh((N, 1), ("data", "model"))
    ctx = ParallelCtx(dp_size=N, tp_size=1, sp=False, remat=False,
                      param_dtype=jnp.float32, compute_dtype=jnp.float32)
    cfg = configs.get_smoke("qwen3-8b")
    api = registry.build(cfg)
    opt = AdamWConfig(lr=5e-3, zero=0)
    sspecs = train_state_specs(cfg, ctx, api, opt)
    params = api.init(jax.random.PRNGKey(0), cfg, ctx)
    opt0 = ctx_smap(lambda p: adamw_init(p, ctx, opt), mesh,
                    (api.specs(cfg, ctx),), sspecs["opt"])(params)
    data = SyntheticLM(vocab=cfg.vocab, seq_len=cfg.max_seq,
                       global_batch=N)

    def run(steps, **kw):
        step = make_train_step(cfg, ctx, api, opt, **kw)
        fn = jax.jit(ctx_smap(step, mesh, (sspecs, {"tokens": P("data")}),
                              (sspecs, {"loss": P(), "grad_norm": P(),
                                        "step": P()})))
        state = {"params": params, "opt": opt0,
                 "step": jnp.zeros((), jnp.int32)}
        losses = []
        for s in range(steps):
            state, m = fn(state, data.batch(s))
            losses.append(np.asarray(m["loss"]))
        return np.stack(losses), state

    for kw in ({}, {"bucket_bytes": 2048}):
        l_block, s_block = run(4, **kw)
        l_over, s_over = run(4, overlap_grad_sync=True, **kw)
        np.testing.assert_array_equal(
            l_block, l_over,
            err_msg=f"loss trajectory diverged (kw={kw})")
        for a, b in zip(jax.tree.leaves(s_block["params"]),
                        jax.tree.leaves(s_over["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print(f"  overlapped == blocking, bit-identical "
              f"(kw={kw or 'per-leaf'}; losses {l_over.ravel().round(4)})")


def main():
    # Under REPRO_SHMEMCHECK=1 (verify.sh full mode) the checker arms
    # before the first queue; enabling up front makes suspended() above
    # restore it correctly and lets us fail on residual findings.
    checked = os.environ.get("REPRO_SHMEMCHECK") == "1"
    if checked:
        shmemcheck.enable().reset()
    check_transport_equivalence()
    check_posh_micro_sweep()
    check_fence_semantics_mesh()
    check_overlapped_training()
    if checked:
        findings = shmemcheck.report()
        for f in findings:
            print(f"  SHMEMCHECK {f}")
        assert not findings, f"{len(findings)} memory-model finding(s)"
    print("ORDERING_PASS")


if __name__ == "__main__":
    main()
