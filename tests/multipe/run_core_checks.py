"""Multi-PE validation of the POSH core — executed as a SUBPROCESS by
test_collectives.py with 8 fake CPU devices (the main pytest process
keeps 1 device per the dry-run isolation requirement)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro import core as posh

mesh = compat.make_mesh((8,), ("pe",))
n = 8
xs = jnp.arange(n, dtype=jnp.float32).reshape(n, 1) + 1.0


def smap(fn, in_specs=P("pe"), out_specs=P("pe")):
    return compat.shard_map(fn, mesh=mesh, in_specs=in_specs,
                            out_specs=out_specs, check_vma=False)


def main():
    # --- broadcast, all algorithms, two roots
    for algo in ["binomial", "binomial_pull", "linear", "xla"]:
        for root in [0, 3]:
            out = smap(lambda x: posh.broadcast(x, root, "pe", algo))(xs)
            np.testing.assert_allclose(np.asarray(out).ravel(),
                                       [root + 1.0] * n)
    # --- fcollect
    for algo in ["ring", "ring_pull", "recursive_doubling", "xla"]:
        out = smap(lambda x: posh.fcollect(x, "pe", algo),
                   out_specs=P("pe", None))(xs)
        got = np.asarray(out).reshape(n, n)
        np.testing.assert_allclose(
            got, np.tile(np.arange(1, n + 1, dtype=np.float32), (n, 1)))
    # --- allreduce over odd sizes (padding path) and ops
    big = jnp.arange(n * 13, dtype=jnp.float32).reshape(n, 13)
    for algo in ["ring", "tree", "recursive_doubling", "xla"]:
        for op in ["sum", "max", "min"]:
            out = smap(lambda x: posh.allreduce(x, op, "pe", algo))(big)
            red = {"sum": np.sum, "max": np.max, "min": np.min}[op](
                np.asarray(big), axis=0)
            np.testing.assert_allclose(np.asarray(out),
                                       np.tile(red, (n, 1)), rtol=1e-6)
    # --- reduce_scatter
    rs = jnp.arange(n * n, dtype=jnp.float32)
    out = smap(lambda x: posh.reduce_scatter(x, "sum", "pe", "ring"))(rs)
    np.testing.assert_allclose(np.asarray(out).reshape(n),
                               np.asarray(rs).reshape(n, n).sum(0))
    # --- alltoall
    a2a = jnp.arange(n * n, dtype=jnp.float32).reshape(n * n, 1)
    for algo in ["pairwise", "xla"]:
        out = smap(lambda x: posh.alltoall(x, "pe", algo),
                   in_specs=P("pe", None), out_specs=P("pe", None))(a2a)
        np.testing.assert_allclose(np.asarray(out).reshape(n, n),
                                   np.arange(n * n).reshape(n, n).T)
    # --- barrier token
    tok = smap(lambda x: posh.barrier_all("pe") * jnp.ones_like(x))(xs)
    np.testing.assert_allclose(np.asarray(tok).ravel(), [8.0] * n)
    # --- active set (PEs 1,3,5,7)
    aset = posh.ActiveSet(1, 1, 4)
    out = smap(lambda x: posh.broadcast(x, 2, "pe", "binomial", aset))(xs)
    got = np.asarray(out).ravel()
    np.testing.assert_allclose(got[1::2], [6.0] * 4)
    np.testing.assert_allclose(got[0::2], [1., 3., 5., 7.])
    out = smap(lambda x: posh.allreduce(x, "sum", "pe", "ring", aset))(xs)
    got = np.asarray(out).ravel()
    np.testing.assert_allclose(got[1::2], [20.0] * 4)
    # --- atomics: fadd linearized by rank
    heap = posh.SymmetricHeap(("pe",))
    h = heap.alloc("cells", (4,), jnp.float32)

    def fadd_all(x):
        state = {"cells": jnp.zeros((4,), jnp.float32) + 10.0}
        st, old = posh.atomic_fadd(state, h, 1, x[0, 0], "pe", owner=2)
        return old[None, None], st["cells"][None]

    old, cells = smap(fadd_all, out_specs=(P("pe"), P("pe")))(xs)
    np.testing.assert_allclose(np.asarray(old).ravel(),
                               [10, 11, 13, 16, 20, 25, 31, 38])
    cells = np.asarray(cells).reshape(n, 4)
    np.testing.assert_allclose(cells[2], [10, 46, 10, 10])
    np.testing.assert_allclose(cells[3], [10, 10, 10, 10])
    # --- atomic swap chain
    def swap_all(x):
        state = {"cells": jnp.zeros((4,), jnp.float32) + 5.0}
        st, old = posh.atomic_swap(state, h, 0, x[0, 0], "pe", owner=0)
        return old[None, None], st["cells"][None]
    old, cells = smap(swap_all, out_specs=(P("pe"), P("pe")))(xs)
    np.testing.assert_allclose(np.asarray(old).ravel(),
                               [5, 1, 2, 3, 4, 5, 6, 7])
    np.testing.assert_allclose(np.asarray(cells).reshape(n, 4)[0, 0], 8.0)
    # --- heap put at offset (Corollary 1)
    h2 = heap.alloc("buf", (8, 1), jnp.float32)

    def hp(x):
        state = {"cells": jnp.zeros((4,), jnp.float32),
                 "buf": jnp.zeros((8, 1), jnp.float32)}
        st = posh.heap_put(state, h2, x,
                           [(i, (i + 1) % 8) for i in range(8)],
                           "pe", offset=3)
        return st["buf"]

    out = smap(hp)(xs)
    np.testing.assert_allclose(np.asarray(out).reshape(n, 8)[:, 3],
                               [8, 1, 2, 3, 4, 5, 6, 7])
    # --- ticket lock order
    order = smap(lambda x: posh.TicketLock("pe").acquire_order()[None, None]
                 .astype(jnp.float32))(xs)
    np.testing.assert_allclose(np.asarray(order).ravel(), np.arange(8.0))
    # --- grad through posh ring (differentiability of schedules)
    def lossfn(x):
        y = posh.allreduce(x, "sum", "pe", "ring")
        return (y ** 2).sum()
    g = smap(jax.grad(lossfn))(xs)
    expect = 2 * np.asarray(xs).sum() * 8  # d/dx_i sum_j (sum_k x_k)^2
    np.testing.assert_allclose(np.asarray(g).ravel(), [expect] * 8,
                               rtol=1e-6)
    print("CORE_CHECKS_PASS")


if __name__ == "__main__":
    main()
