"""Queue-AMO substrate at 8-PE scale — subprocess worker (8 fake CPU
devices), invoked by tests/test_page_pool.py.

Four suites:

  1. AMO linearization with 8 requesters: concurrent fetch-add chains
     and competing cswaps on one word, swept over 40+ delivery seeds —
     the fetched pre-op values must always form a valid linearization
     (and the shuffle must actually produce different ones).
  2. The two §4.6 substrates agree: the owner-computes ``atomic_fadd``
     on the REAL 8-PE mesh (rank-order linearization inside shard_map)
     and the queue AMO path (issue-order drain) produce identical
     fetched values and final cell — the bridge between the SPMD and
     the host-control-plane atomics.
  3. SymmetricPagePool with 8 actors: random alloc/free interleavings
     never double-grant or leak, pages conserve exactly, and the pool
     queue finishes with zero quiets/fences.
  4. Single-actor pool traces at serving scale (32 pages) stay
     bit-identical to the host LIFO free list (the attach_pool
     contract run_disagg.py leans on).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import random

import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro import core as posh
from repro.analysis import shmemcheck
from repro.core import CommQueue, LocalTransport
from repro.core.heap import SymHandle
from repro.serve.page_pool import SymmetricPagePool

N = 8
CTR = SymHandle("ctr", (2,), np.dtype(np.int64), 0, 16)
mesh1d = compat.make_mesh((N,), ("pe",))


def smap(fn, in_specs=P("pe"), out_specs=P("pe")):
    return compat.shard_map(fn, mesh=mesh1d, in_specs=in_specs,
                            out_specs=out_specs, check_vma=False)


def _ctr_queue(seed):
    state = {"ctr": np.zeros((N, 2), np.int64)}
    return CommQueue("pe", state, transport=LocalTransport(N),
                     delivery_seed=seed)


# ======================================================================
# 1. 8-requester linearization under the delivery shuffle
# ======================================================================
def check_amo_linearization():
    fadd_orders, cswap_winners = set(), set()
    for seed in list(range(40)) + [None]:
        q = _ctr_queue(seed)
        adds = [q.amo_nbi(CTR, "fadd", [(s, 5)], value=1)
                for s in range(N)]
        cas = [q.amo_nbi(CTR, "cswap", [(s, 5)], value=100 + s, cond=0,
                         offset=1) for s in range(N)]
        q.amo_wait(CTR)
        q.amo_wait(CTR, offset=1)
        olds = [int(r.value()) for r in adds]
        assert sorted(olds) == list(range(N)), (seed, olds)
        assert int(np.asarray(q.state["ctr"])[5, 0]) == N
        fadd_orders.add(tuple(olds))
        wins = [s for s in range(N) if int(cas[s].value()) == 0]
        assert len(wins) == 1, (seed, wins)
        w = wins[0]
        assert int(np.asarray(q.state["ctr"])[5, 1]) == 100 + w
        assert all(int(cas[s].value()) == 100 + w
                   for s in range(N) if s != w)
        cswap_winners.add(w)
        st = q.stats()
        assert st["quiets"] == 0 and st["amos"] == 2 * N
        assert st["amo_waits"] == 2
    assert len(fadd_orders) > 1          # the shuffle linearizes
    assert len(cswap_winners) > 1        # ... and moves the CAS winner
    print(f"  8-PE AMO linearization ok ({len(fadd_orders)} fadd "
          f"orders, winners {sorted(cswap_winners)})")


# ======================================================================
# 2. owner-computes (mesh) == queue AMO path, §4.6 both ways
# ======================================================================
def check_substrates_agree():
    heap = posh.SymmetricHeap(("pe",))
    h = heap.alloc("cells", (2,), jnp.float32)
    xs = (jnp.arange(N, dtype=jnp.float32) + 1.0).reshape(N, 1)

    def fadd_all(x):
        state = {"cells": jnp.zeros((2,), jnp.float32)}
        st, old = posh.atomic_fadd(state, h, 0, x[0, 0], "pe", owner=2)
        return old[None, None], st["cells"][None]

    old, cells = smap(fadd_all, out_specs=(P("pe"), P("pe")))(xs)
    mesh_olds = [int(v) for v in np.asarray(old).ravel()]
    mesh_final = int(np.asarray(cells).reshape(N, 2)[2, 0])
    # queue path: issue in rank order, seed None = issue-order drain —
    # the same linearization the mesh fixes by rank
    q = _ctr_queue(None)
    rs = [q.amo_nbi(CTR, "fadd", [(s, 2)], value=s + 1)
          for s in range(N)]
    q.amo_wait(CTR)
    assert [int(r.value()) for r in rs] == mesh_olds, mesh_olds
    assert int(np.asarray(q.state["ctr"])[2, 0]) == mesh_final == 36
    print(f"  owner-computes == queue AMOs (olds {mesh_olds})")


# ======================================================================
# 3. pool invariants with 8 actors
# ======================================================================
def check_pool_invariants():
    for case in range(12):
        rng = random.Random(1000 + case)
        n = rng.randint(9, 24)
        pool = SymmetricPagePool(n, n_actors=N, delivery_seed=case)
        held = {a: [] for a in range(N)}
        for _ in range(rng.randint(20, 80)):
            a = rng.randrange(N)
            if rng.random() < 0.6:
                p = pool.pop_page(actor=a)
                if p is not None:
                    held[a].append(p)
            elif held[a]:
                k = rng.randint(1, len(held[a]))
                back, held[a] = held[a][:k], held[a][k:]
                pool.push_pages(back, actor=a)
            out = [p for ps in held.values() for p in ps]
            assert len(out) == len(set(out)), out       # no double grant
            assert pool.n_free() == (n - 1) - len(out)  # no leak
        for a, ps in held.items():
            pool.push_pages(ps, actor=a)
        got = sorted(iter(lambda: pool.pop_page(
            actor=rng.randrange(N)), None))
        assert got == list(range(1, n))                 # conservation
        qs = pool.queue_stats()
        assert qs["quiets"] == 0 and qs["fences"] == 0
    print("  8-actor pool invariants ok (12 interleavings)")


# ======================================================================
# 4. serving-scale host-LIFO parity (the attach_pool contract)
# ======================================================================
def check_pool_host_parity():
    n = 32
    pool = SymmetricPagePool(n, delivery_seed=7)
    free = list(range(n - 1, 0, -1))                    # host oracle
    held = []
    rng = random.Random(99)
    for _ in range(300):
        if rng.random() < 0.55:
            want = free.pop() if free else None
            got = pool.pop_page()
            assert got == want, (got, want)
            if got is not None:
                held.append(got)
        elif held:
            k = rng.randint(1, min(4, len(held)))
            back, held = held[:k], held[k:]
            pool.push_pages(back)
            free.extend(reversed(back))
        assert pool.n_free() == len(free)
    qs = pool.queue_stats()
    assert qs["quiets"] == 0 and qs["fences"] == 0
    print(f"  pool == host LIFO over 300 ops ({qs['amos']} AMOs, "
          f"0 quiets)")


def main():
    checked = os.environ.get("REPRO_SHMEMCHECK") == "1"
    if checked:
        shmemcheck.enable().reset()
    check_amo_linearization()
    check_substrates_agree()
    check_pool_invariants()
    check_pool_host_parity()
    if checked:
        findings = shmemcheck.report()
        for f in findings:
            print(f"  SHMEMCHECK {f}")
        assert not findings, f"{len(findings)} memory-model finding(s)"
    print("ATOMICS_PASS")


if __name__ == "__main__":
    main()
