"""Communicator parity checks — subprocess worker with 8 fake CPU PEs.

Constructs two communicators (backend "xla" and backend "posh") over
the SAME mesh/team and asserts numerical parity on every op, across
dtypes and layouts; then asserts the posh communicator's dispatch table
actually switched algorithms with payload size (eager below the
threshold, chunked ring above).  Also covers the
``all_gather(tiled=False)`` stacked-axis placement for gather_axis != 0
(the bug fixed with the Communicator redesign) and the pinned
``DispatchTable.fixed`` path that replaced the deleted CommConfig
shims.

The third backend, "pallas" (posh schedules with every p2p payload
routed through the Pallas symm_copy engine), is parity-checked for
psum / all_gather / psum_scatter across float32 and bfloat16 — both at
small sizes (stock staging) and at a payload large enough that the
ring rounds move whole VMEM tiles through the kernel path, with and
without a bound symmetric heap (Lemma-1 staging buffers).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import comm as C
from repro import compat

N = 8
ROWS, COLS = 8, 4          # per-PE shard shape; ROWS divisible by N
mesh = compat.make_mesh((N,), ("pe",))


def smap(fn, in_specs=P("pe"), out_specs=P("pe")):
    return compat.shard_map(fn, mesh=mesh, in_specs=in_specs,
                            out_specs=out_specs, check_vma=False)


def mk(backend, dispatch=None):
    return C.make_communicator("pe", size=N, backend=backend,
                               dispatch=dispatch)


def assert_close(a, b, what, dtype):
    a, b = np.asarray(a), np.asarray(b)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(a.astype(np.float64), b.astype(np.float64),
                               rtol=tol, atol=tol, err_msg=what)


def _global_input(dtype):
    if dtype == jnp.int32:
        return (jnp.arange(N * ROWS * COLS, dtype=dtype)
                .reshape(N * ROWS, COLS) % 13)
    return (jnp.linspace(-2, 2, N * ROWS * COLS, dtype=jnp.float32)
            .reshape(N * ROWS, COLS).astype(dtype))


CASES = [
    ("psum", lambda c: lambda v: c.psum(v), P("pe")),
    ("pmax", lambda c: lambda v: c.pmax(v), P("pe")),
    ("all_gather_tiled0",
     lambda c: lambda v: c.all_gather(v, axis=0, tiled=True),
     P("pe", None)),
    ("all_gather_tiled1",
     lambda c: lambda v: c.all_gather(v, axis=1, tiled=True),
     P("pe", None)),
    ("all_gather_stacked0",
     lambda c: lambda v: c.all_gather(v, axis=0, tiled=False),
     P("pe", None, None)),
    ("all_gather_stacked1",
     lambda c: lambda v: c.all_gather(v, axis=1, tiled=False),
     P("pe", None, None)),
    ("all_gather_stacked2",
     lambda c: lambda v: c.all_gather(v, axis=2, tiled=False),
     P("pe", None, None)),
    ("psum_scatter",
     lambda c: lambda v: c.psum_scatter(v, axis=0), P("pe")),
    ("all_to_all",
     lambda c: lambda v: c.all_to_all(v, split_axis=0, concat_axis=1),
     P("pe")),
    ("pbroadcast3", lambda c: lambda v: c.pbroadcast(v, root=3), P("pe")),
]


def check_parity():
    for dtype in (jnp.float32, jnp.bfloat16, jnp.int32):
        xg = _global_input(dtype)
        xla, posh = mk("xla"), mk("posh")
        for name, body, ospec in CASES:
            ox = smap(body(xla), out_specs=ospec)(xg)
            op = smap(body(posh), out_specs=ospec)(xg)
            assert ox.shape == op.shape, (name, dtype, ox.shape, op.shape)
            assert_close(ox, op, f"{name}/{jnp.dtype(dtype).name}", dtype)
        print(f"  parity ok: dtype={jnp.dtype(dtype).name}")


def check_stacked_matches_lax():
    """comm.all_gather(tiled=False) == lax.all_gather(tiled=False) for
    every gather_axis (the old shim misplaced the stacked axis for
    gather_axis != 0); tiled=True covered for symmetry."""
    x = _global_input(jnp.float32)
    for tiled in (True, False):
        ndim_out = 2 if tiled else 3
        ospec = P(*(("pe",) + (None,) * (ndim_out - 1)))
        for ax in range(ndim_out):
            ref = smap(lambda v: jax.lax.all_gather(v, "pe", axis=ax,
                                                    tiled=tiled),
                       out_specs=ospec)(x)
            for backend in ("xla", "posh"):
                got = smap(lambda v: mk(backend).all_gather(v, axis=ax,
                                                            tiled=tiled),
                           out_specs=ospec)(x)
                np.testing.assert_allclose(
                    np.asarray(got), np.asarray(ref),
                    err_msg=f"all_gather tiled={tiled} ax={ax} {backend}")
    print("  all_gather (tiled & stacked) matches lax on every axis")


def check_size_dispatch():
    """The posh communicator must report a size-dependent algorithm
    switch: tiny payloads -> eager, large -> chunked ring."""
    posh = mk("posh")
    table = posh.dispatch
    big_ar = table.allreduce_small_bytes // 4 + 64     # f32 elems, > thresh
    big_ag = table.allgather_small_bytes // 4 + 64

    def body(v):
        s = posh.psum(jnp.full((16,), v[0, 0]))            # 64 B -> eager
        b = posh.psum(jnp.full((big_ar,), v[0, 0]))        # -> chunked
        gs = posh.all_gather(jnp.full((8,), v[0, 0]))      # 32 B -> eager
        gb = posh.all_gather(jnp.full((big_ag,), v[0, 0]))  # -> chunked
        return v + s[0] + b[0] + gs[0] + gb[0]

    smap(body)(jnp.ones((N, 1), jnp.float32))

    st = posh.stats()
    ar = st["psum"]
    assert table.allreduce_eager in ar["algos"] \
        and table.allreduce_chunked in ar["algos"], f"no psum switch: {ar}"
    assert ar["calls"] == 2 and ar["bytes"] == 64 + big_ar * 4
    ag = st["all_gather"]
    assert len(ag["algos"]) == 2, f"no all_gather switch: {ag}"
    print(f"  dispatch switch ok: psum={ar['algos']} "
          f"all_gather={ag['algos']}")


def check_pallas_backend():
    """backend="pallas" numerical parity with "xla" on the ops that
    carry training traffic, across two dtypes, plus the kernel-path
    payload and heap-staged variants."""
    assert "pallas" in C.available_backends()
    for dtype in (jnp.float32, jnp.bfloat16):
        xg = _global_input(dtype)
        xla, pal = mk("xla"), mk("pallas")
        for name, body, ospec in CASES:
            if name.split("_stacked")[0].split("_tiled")[0] not in (
                    "psum", "all_gather", "psum_scatter"):
                continue
            ox = smap(body(xla), out_specs=ospec)(xg)
            op = smap(body(pal), out_specs=ospec)(xg)
            assert ox.shape == op.shape, (name, dtype, ox.shape, op.shape)
            assert_close(ox, op, f"pallas/{name}/{jnp.dtype(dtype).name}",
                         dtype)
        print(f"  pallas parity ok: dtype={jnp.dtype(dtype).name}")

    # payload big enough that the chunked-ring rounds stage whole VMEM
    # tiles through the kernel (8192 f32/PE -> 4 KiB chunks/round), and
    # a heap-bound communicator so the staged chunks belong to the ring
    # schedule's Lemma-1 symmetric scratch
    from repro import core as posh
    heap = posh.SymmetricHeap(("pe",))
    fp = heap.fingerprint()
    big = jnp.linspace(-1, 1, N * 8192, dtype=jnp.float32).reshape(N, 8192)
    ref = smap(lambda v: mk("xla").psum(v))(big)
    for heap_arg in (None, heap):
        pal = C.make_communicator("pe", size=N, backend="pallas",
                                  heap=heap_arg)
        got = smap(lambda v: pal.psum(v))(big)
        assert_close(got, ref, f"pallas big psum (heap={heap_arg})",
                     jnp.float32)
    assert heap.fingerprint() == fp       # Lemma 1: staging left no trace
    print("  pallas kernel-path + heap staging ok")


def check_fixed_dispatch():
    """A pinned table (the old CommConfig semantics) agrees with the
    size-aware default — same schedules, different selection."""
    x = _global_input(jnp.float32)
    pinned = smap(lambda v: mk(
        "posh", dispatch=C.DispatchTable.fixed(allreduce="tree")).psum(v))(x)
    sized = smap(lambda v: mk("posh").psum(v))(x)
    np.testing.assert_allclose(np.asarray(pinned), np.asarray(sized))
    print("  fixed dispatch == sized dispatch")


def main():
    check_parity()
    check_stacked_matches_lax()
    check_size_dispatch()
    check_pallas_backend()
    check_fixed_dispatch()
    print("COMM_PARITY_PASS")


if __name__ == "__main__":
    main()
