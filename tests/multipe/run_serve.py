"""Serving on a real 8-PE mesh — subprocess worker.

Mesh (2, 4) = ("data", "model"): a 2-replica serving cell, each replica
tensor-parallel over 4 PEs.  Five checks:

  1. BACKEND PARITY — the same seeded request trace served with the
     engine's collectives routed through each registered communicator
     backend (xla / posh / pallas) produces IDENTICAL token streams,
     for GREEDY requests and for SAMPLED ones (temperature > 0,
     top-p < 1): the TP-aware two-phase sampler merges per-shard
     candidates with a deterministic tie-break and draws from
     counter-based per-(rid, position) RNG streams, so any divergence
     is a numerical bug in a backend's schedules.

  2. BATCH-COMPOSITION INVARIANCE — a sampled request served ALONE
     yields the same token stream as the same request packed into a
     full batch (the RNG stream is keyed by (rid, position), never by
     batch slot or tick).

  3. TP-ARGMAX TIE-BREAK — manufactured equal-logit ties spanning
     vocab shards resolve to the LOWEST global vocab index on every
     backend (regression: the old pmax-of-candidate-index merge picked
     the highest tied shard).

  4. PAGE MIGRATION — a KV page moves replica 0 -> replica 1 as ONE
     put_nbi round over the flattened ("data","model") team (one
     (src, dst) pair per TP rank: each rank's page shard moves to its
     counterpart) drained by one quiet(), through the REAL
     PermuteTransport.  Replica-distinct scribbles prove actual cross-
     PE data motion, not SPMD replication.

  5. PREFIX-RESUME VIA MIGRATION — request A finishes and registers its
     full prompt pages in the prefix index (owner: replica 0).  A
     second serving cell (my_pe = replica 1) admits an identical-prompt
     request as RESUMED: the scheduler tick plans page migrations, the
     engine drains them with one quiet(), and the request CHUNK-
     prefills only the uncovered suffix (>= 2 tokens per tick) — its
     token stream must equal the from-scratch stream.

  6. SPECULATIVE DECODING PARITY — the same traces served with
     spec_k=3 (n-gram self-draft verified through the (B, k+1) window,
     exact counter-RNG prefix acceptance) produce the IDENTICAL token
     streams as non-speculative serving, greedy AND sampled, on every
     backend; a replay-oracle run then pins the multi-accept path
     (accept-rate 1, > 1 token per sequence per verify pass) and the
     rejection/rewind path runs under an adversarial proposer.

  7. ATTENTION-IMPL PARITY — the same traces served with
     attn_impl="kernel" (the Pallas paged decode + prefill-window grid
     kernels, interpret mode off-TPU) produce the IDENTICAL token
     streams as attn_impl="ref" on xla/posh/pallas, greedy and
     sampled, plus a spec_k run where the verify window itself runs
     the grid kernel.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat, configs, serve
from repro.core import CommQueue, SymmetricHeap
from repro.core.ordering import PermuteTransport
from repro.models import embed as emb
from repro.models import registry
from repro.parallel.ctx import ParallelCtx, smap

DP, TP = 2, 4
mesh = compat.make_mesh((DP, TP), ("data", "model"))
POOL_SPEC = P("data", "model")


class MeshExec:
    """ServeEngine execution substrate over the (data, model) mesh.
    The pool rides with leading (dp, tp) axes so shard_map hands each
    PE its own (rank-varying) page shard; host-visible tokens are
    replicated."""

    def __init__(self, params, pspecs, cfg, ctx, scfg, kv, my_pe=0):
        self.params, self.kv = params, kv
        self.my_pe = int(my_pe)       # which replica this cell reads
        pf = serve.make_prefill(cfg, ctx, scfg)
        dc = serve.make_decode_step(cfg, ctx, scfg)
        vf = serve.make_verify(cfg, ctx, scfg)

        # tokens are replica-varying once pages migrate (replica 1 may
        # hold pages replica 0 does not), so they come back stacked per
        # replica — the host reads its own cell's row
        def pf_w(params, pool, ids, start, n_tok, bt, samp):
            toks, kvo = pf(params, pool[0, 0], ids, start, n_tok, bt,
                           samp)
            return toks, kvo[None, None]

        def dc_w(params, pool, toks, pos, bt, lens, samp):
            nxt, kvo = dc(params, pool[0, 0], toks, pos, bt, lens, samp)
            return nxt, kvo[None, None]

        def vf_w(params, pool, ids, start, n_tok, bt, samp):
            toks, kvo = vf(params, pool[0, 0], ids, start, n_tok, bt,
                           samp)
            return toks, kvo[None, None]

        self._prefill = jax.jit(smap(
            pf_w, mesh, (pspecs, POOL_SPEC, P(), P(), P(), P(), P()),
            (P("data"), POOL_SPEC)))
        self._decode = jax.jit(smap(
            dc_w, mesh, (pspecs, POOL_SPEC, P(), P(), P(), P(), P()),
            (P("data"), POOL_SPEC)))
        self._verify = jax.jit(smap(
            vf_w, mesh, (pspecs, POOL_SPEC, P(), P(), P(), P(), P()),
            (P("data"), POOL_SPEC)))
        self._migrate_cache = {}

    def _my_row(self, toks):
        # (DP*b,) token vectors and (DP*b, C) verify windows alike
        t = np.asarray(toks)
        return t.reshape((DP, -1) + t.shape[1:])[self.my_pe]

    def init_pool(self):
        return jnp.zeros((DP, TP) + self.kv.handle.shape,
                         self.kv.handle.dtype)

    def prefill(self, pool, ids, start, n_tok, bt, samp):
        toks, pool = self._prefill(self.params, pool, jnp.asarray(ids),
                                   jnp.asarray(start),
                                   jnp.asarray(n_tok), jnp.asarray(bt),
                                   samp)
        return self._my_row(toks), pool

    def decode(self, pool, tokens, pos, bt, lens, samp):
        toks, pool = self._decode(self.params, pool,
                                  jnp.asarray(tokens), jnp.asarray(pos),
                                  jnp.asarray(bt), jnp.asarray(lens),
                                  samp)
        return self._my_row(toks), pool

    def verify(self, pool, ids, start, n_tok, bt, samp):
        toks, pool = self._verify(self.params, pool, jnp.asarray(ids),
                                  jnp.asarray(start),
                                  jnp.asarray(n_tok), jnp.asarray(bt),
                                  samp)
        return self._my_row(toks), pool

    def set_params(self, params) -> None:
        # weight hot-swap flip: the smap-wrapped step functions take
        # params as an explicit argument, so the next tick's forwards
        # run the new generation with no re-trace (same as LocalExec)
        self.params = params

    def migrate(self, pool, migrations):
        migs = tuple(migrations)
        if migs not in self._migrate_cache:
            kv, name = self.kv, self.kv.handle.name

            def mg(pool):
                local = pool[0, 0]
                q = CommQueue(("data", "model"), {name: local},
                              transport=PermuteTransport())
                st = kv.issue_migrations(
                    q, local, migs,
                    pairs_of=lambda m: [(m.src_pe * TP + t,
                                         m.dst_pe * TP + t)
                                        for t in range(TP)])
                assert q.stats()["quiets"] == 1
                return st[name][None, None]

            self._migrate_cache[migs] = jax.jit(
                smap(mg, mesh, (POOL_SPEC,), POOL_SPEC))
        return self._migrate_cache[migs](pool)


def build(backend, *, prefix_keep=False, my_pe=0, kv=None, scfg=None,
          spec_k=0, proposer=None):
    cfg = configs.get_smoke("qwen3-8b")
    ctx = ParallelCtx(dp_size=DP, tp_size=TP, sp=False, remat=False,
                      backend=backend, param_dtype=jnp.float32,
                      compute_dtype=jnp.float32)
    api = registry.build(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg,
                      ParallelCtx(dp_size=1, tp_size=1, sp=False,
                                  remat=False,
                                  param_dtype=jnp.float32,
                                  compute_dtype=jnp.float32))
    scfg = scfg or serve.ServeConfig(page_tokens=4, n_pages=24,
                                     max_batch=3, max_seq=32,
                                     prefill_chunk=3, attn_impl="ref",
                                     prefix_keep=prefix_keep,
                                     spec_k=spec_k)
    if kv is None:
        heap = SymmetricHeap(("data", "model"), capacity_bytes=1 << 30)
        kv = serve.PagedKVCache(
            heap, n_layers=cfg.n_layers,
            kv_heads=cfg.kv_per_rank(TP), head_dim=cfg.head_dim,
            n_pages=scfg.n_pages, page_tokens=scfg.page_tokens)
    exec_ = MeshExec(params, api.specs(cfg, ctx), cfg, ctx, scfg, kv,
                     my_pe=my_pe)
    eng = serve.ServeEngine(params, cfg, ctx, scfg, kv=kv, exec_=exec_,
                            proposer=proposer, my_pe=my_pe)
    return eng, cfg


PROMPTS = [list(range(3, 11)), list(range(40, 46)), [7, 3, 99, 12, 55]]
SAMPLED = serve.SamplingParams(temperature=0.8, top_k=5, top_p=0.9)


def serve_trace(backend, sampling=None):
    eng, cfg = build(backend)
    reqs = [serve.Request(rid=i, prompt=list(p), max_new=6,
                          sampling=sampling or serve.GREEDY)
            for i, p in enumerate(PROMPTS)]
    done = eng.run(reqs, clock="tick")
    return {r.rid: list(r.out) for r in done}, eng


def check_backend_parity():
    for tag, sampling in (("greedy", None), ("sampled", SAMPLED)):
        streams = {}
        for backend in ("xla", "posh", "pallas"):
            streams[backend], _ = serve_trace(backend, sampling)
            print(f"  [{backend}/{tag}] streams: "
                  f"{ {k: v[:4] for k, v in streams[backend].items()} }")
        assert streams["xla"] == streams["posh"] == streams["pallas"], \
            (tag, streams)
        print(f"  {tag} token streams identical across xla/posh/pallas")


def check_batch_invariance():
    """The same sampled request, alone vs packed in a full batch, draws
    the identical token stream — on the mesh, through the TP sampler."""
    full, _ = serve_trace("xla", SAMPLED)
    eng, _ = build("xla")
    alone = eng.run([serve.Request(rid=1, prompt=list(PROMPTS[1]),
                                   max_new=6, sampling=SAMPLED)],
                    clock="tick")
    assert list(alone[0].out) == full[1], (alone[0].out, full[1])
    print(f"  sampled stream batch-composition-invariant "
          f"(rid 1: {full[1]})")


def check_tp_argmax_ties():
    """Manufactured equal-logit ties across vocab shards: every backend
    must resolve to the LOWEST global vocab index (the old merge used
    pmax over candidate indices, i.e. the HIGHEST tied shard won)."""
    V, vloc = 32, 32 // TP
    logits = np.zeros((2, V), np.float32)
    # row 0: the global max value 3.0 appears in shard 1 (idx 9) AND
    # shard 3 (idx 25) -> must pick 9.  row 1: tie inside shard 0
    # (idx 2, 5) AND shard 2 (idx 17) -> must pick 2.
    logits[0, 9] = logits[0, 25] = 3.0
    logits[1, 2] = logits[1, 5] = logits[1, 17] = 7.0
    for backend in ("xla", "posh", "pallas"):
        ctx = ParallelCtx(dp_size=DP, tp_size=TP, sp=False, remat=False,
                          backend=backend, param_dtype=jnp.float32,
                          compute_dtype=jnp.float32)

        def am(lg):
            return emb.tp_argmax(lg, ctx)

        out = jax.jit(smap(am, mesh, (P(None, "model"),), P()))(
            jnp.asarray(logits))
        got = list(np.asarray(out))
        assert got == [9, 2], (backend, got)
    print("  tp_argmax ties -> lowest global index on every backend")


def check_page_migration():
    """One put_nbi + one quiet() moves a page replica0 -> replica1 over
    the real permute transport; replica-distinct scribbles prove the
    bytes crossed PEs."""
    eng, cfg = build("xla")
    pool = np.asarray(eng.exec.init_pool())
    rng = np.random.RandomState(7)
    # distinct content per (replica, tp-rank): migration must copy
    # replica 0's shards, per rank, into replica 1
    pool = rng.randn(*pool.shape).astype(np.float32)
    src_page, dst_page = 3, 9
    before = pool.copy()
    out = np.asarray(eng.exec.migrate(
        jnp.asarray(pool),
        [serve.PageMigration(src_pe=0, dst_pe=1, src_page=src_page,
                             dst_page=dst_page)]))
    for t in range(TP):
        np.testing.assert_array_equal(out[1, t, dst_page],
                                      before[0, t, src_page])
    # sources and unrelated rows untouched
    np.testing.assert_array_equal(out[0], before[0])
    mask = np.ones(pool.shape[2], bool)
    mask[dst_page] = False
    np.testing.assert_array_equal(out[1][:, mask], before[1][:, mask])
    print("  page migration replica0 -> replica1 (put_nbi + 1 quiet) ok")


def check_prefix_resume_migration():
    """Scheduler-planned migration: an identical prompt re-served on
    replica 1 resumes from replica 0's registered prefix pages (moved
    by the tick's put_nbi/quiet) and CHUNK-prefills the uncovered
    suffix — >= 2 tokens per tick — to the same token stream."""
    prompt = list(range(3, 14))                # 2 full pages + 3 extra

    # from-scratch stream for this prompt
    eng0, _ = build("xla")
    scratch = eng0.run([serve.Request(rid=0, prompt=list(prompt),
                                      max_new=6)], clock="tick")
    want = list(scratch[0].out)

    # cell A (replica 0) serves and registers the prefix
    eng, cfg = build("xla", prefix_keep=True, my_pe=0)
    done = eng.run([serve.Request(rid=0, prompt=list(prompt),
                                  max_new=6)], clock="tick")
    assert list(done[0].out) == want
    assert eng.kv.lookup_prefix(prompt) is not None

    # cell B (replica 1) shares the symmetric pool + prefix index
    eng2, _ = build("xla", prefix_keep=False, my_pe=1, kv=eng.kv,
                    scfg=eng.scfg)
    eng2.pool = eng.pool                       # the shared heap state
    eng2.submit(serve.Request(rid=100, prompt=list(prompt), max_new=6))
    while eng2.sched.has_work():
        eng2.tick()
    (resumed,) = eng2.finished
    assert eng2.sched.stats["resumed"] == 1, eng2.sched.stats
    assert eng2.kv.stats["migrations"] >= 2    # 2 prefix pages moved
    # the uncovered suffix (3 tokens past the 2 migrated pages) went
    # through chunked prefill in >= 2-token chunks, not token-by-token
    assert resumed.prefill_chunks and max(resumed.prefill_chunks) >= 2, \
        resumed.prefill_chunks
    assert list(resumed.out) == want, (resumed.out, want)
    print(f"  prefix resume via migration ok "
          f"(migrated {eng2.kv.stats['migrations']} pages, suffix "
          f"chunks {resumed.prefill_chunks}, stream {resumed.out})")


def check_spec_parity():
    """Speculation is lossless on the mesh: spec_k=3 streams equal the
    non-speculative ones for greedy AND sampled traffic on every
    backend (the n-gram proposer drafts, the verify window scores, the
    counter-RNG prefix match accepts)."""
    for tag, sampling in (("greedy", None), ("sampled", SAMPLED)):
        want, _ = serve_trace("xla", sampling)   # == posh == pallas
        for backend in ("xla", "posh", "pallas"):
            eng, _ = build(backend, spec_k=3)
            done = eng.run(
                [serve.Request(rid=i, prompt=list(p), max_new=6,
                               sampling=sampling or serve.GREEDY)
                 for i, p in enumerate(PROMPTS)], clock="tick")
            got = {r.rid: list(r.out) for r in done}
            assert got == want, (backend, tag, got, want)
            assert eng.spec_stats["verify_ticks"] > 0
        print(f"  spec {tag} streams identical to non-spec across "
              f"xla/posh/pallas")


def check_spec_accept_and_rewind():
    """The two ends of the acceptance spectrum, on the real mesh: a
    replay oracle accepts every draft (multi-token verify emits), an
    adversarial proposer rejects every draft (page rewind), and both
    leave the streams untouched."""
    want, _ = serve_trace("xla")
    eng, _ = build("xla", spec_k=3,
                   proposer=serve.ReplayProposer(want))
    done = eng.run([serve.Request(rid=i, prompt=list(p), max_new=6)
                    for i, p in enumerate(PROMPTS)], clock="tick")
    assert {r.rid: list(r.out) for r in done} == want
    sp = eng.metrics()["spec"]
    assert sp["accept_rate"] == 1.0 and sp["tokens_per_tick"] > 1, sp
    eng2, _ = build("xla", spec_k=3,
                    proposer=serve.FixedProposer([101, 102, 103]))
    done2 = eng2.run([serve.Request(rid=i, prompt=list(p), max_new=6)
                      for i, p in enumerate(PROMPTS)], clock="tick")
    assert {r.rid: list(r.out) for r in done2} == want
    assert eng2.spec_stats["accepted"] == 0
    assert eng2.kv.stats["rewound_pages"] > 0
    print(f"  spec oracle accept-rate 1.0 "
          f"({sp['tokens_per_tick']:.2f} tok/seq/tick); adversarial "
          f"rewind {eng2.kv.stats['rewound_pages']} pages, streams "
          f"unchanged")


def _kernel_scfg(spec_k=0):
    return serve.ServeConfig(page_tokens=4, n_pages=24, max_batch=3,
                             max_seq=32, prefill_chunk=3,
                             attn_impl="kernel", spec_k=spec_k)


def check_attn_impl_parity():
    """attn_impl is a per-call impl choice, never a numerical one, on
    the real mesh too: kernel-served streams (Pallas paged decode +
    prefill-window grid kernels, interpret mode off-TPU) equal the ref
    streams on every backend, greedy AND sampled — and with spec_k=3
    the verify window itself runs the grid kernel to the same
    streams."""
    for tag, sampling in (("greedy", None), ("sampled", SAMPLED)):
        want, _ = serve_trace("xla", sampling)   # ref == posh == pallas
        for backend in ("xla", "posh", "pallas"):
            eng, _ = build(backend, scfg=_kernel_scfg())
            done = eng.run(
                [serve.Request(rid=i, prompt=list(p), max_new=6,
                               sampling=sampling or serve.GREEDY)
                 for i, p in enumerate(PROMPTS)], clock="tick")
            got = {r.rid: list(r.out) for r in done}
            assert got == want, (backend, tag, got, want)
        print(f"  attn kernel {tag} streams == ref streams across "
              f"xla/posh/pallas")
    want, _ = serve_trace("xla")
    eng, _ = build("xla", scfg=_kernel_scfg(spec_k=3))
    done = eng.run([serve.Request(rid=i, prompt=list(p), max_new=6)
                    for i, p in enumerate(PROMPTS)], clock="tick")
    assert {r.rid: list(r.out) for r in done} == want
    assert eng.spec_stats["verify_ticks"] > 0
    print("  attn kernel verify window (spec_k=3) streams unchanged")


def main():
    check_backend_parity()
    check_batch_invariance()
    check_tp_argmax_ties()
    check_page_migration()
    check_prefix_resume_migration()
    check_spec_parity()
    check_spec_accept_and_rewind()
    check_attn_impl_parity()
    print("SERVE_PASS")


if __name__ == "__main__":
    main()
