"""DP2×TP4 equivalence vs single-device — subprocess worker.

Covers one arch per structural family (ctx layout, head layout with KV
replication, EP/MoE, SSM recurrence, hybrid), each against both
collective backends.  The full 10-arch version of this check was run
during bring-up; this subset keeps CI time sane.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat, configs
from repro.models import registry
from repro.parallel.ctx import ParallelCtx, smap
from repro.train.grad import loss_and_grad

mesh1 = compat.make_mesh((1, 1), ("data", "model"),
                        devices=jax.devices()[:1])
mesh4 = compat.make_mesh((2, 4), ("data", "model"))


def batch_specs(batch):
    return {k: P("data") if k == "tokens" else P("data", None, None)
            for k in batch}


def check(arch, backend, moe_dispatch="einsum"):
    cfg = configs.get_smoke(arch)
    api = registry.build(cfg)
    ctx1 = ParallelCtx(dp_size=1, tp_size=1, sp=False, remat=True,
                       param_dtype=jnp.float32, compute_dtype=jnp.float32)
    ctx4 = ParallelCtx(dp_size=2, tp_size=4, sp=True, remat=True,
                       backend=backend,
                       param_dtype=jnp.float32, compute_dtype=jnp.float32,
                       moe_dispatch=moe_dispatch)
    params = api.init(jax.random.PRNGKey(0), cfg, ctx1)
    b, t = 4, cfg.max_seq
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                          (b, t + 1), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["img_embeds"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(2), (b, cfg.img_tokens, cfg.d_model))
    if cfg.family == "encdec":
        batch["frames"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(2), (b, cfg.enc_frames, cfg.d_model))

    def lg(ctx):
        def fn(p, bt):
            l, g, _ = loss_and_grad(api.loss_fn, p, bt, ctx, cfg,
                                    api.specs(cfg, ctx))
            return l, g
        return fn

    l1, g1 = jax.jit(smap(lg(ctx1), mesh1,
                          (api.specs(cfg, ctx1), batch_specs(batch)),
                          (P(), api.specs(cfg, ctx1))))(params, batch)
    l4, g4 = jax.jit(smap(lg(ctx4), mesh4,
                          (api.specs(cfg, ctx4), batch_specs(batch)),
                          (P(), api.specs(cfg, ctx4))))(params, batch)
    np.testing.assert_allclose(float(l1), float(l4), rtol=2e-5)
    worst = 0.0
    for a, c in zip(jax.tree.leaves(g1), jax.tree.leaves(g4)):
        a, c = np.asarray(a), np.asarray(c)
        worst = max(worst, np.abs(a - c).max()
                    / max(np.abs(a).max(), 1e-6))
    assert worst < 5e-4, f"{arch}/{backend}: grad rel err {worst:.2e}"
    print(f"  equiv ok: {arch} [{backend}] gradrel={worst:.1e}")


def main():
    cases = [
        ("minitron-4b", "xla"), ("minitron-4b", "posh"),   # ctx layout
        ("qwen3-8b", "posh"),                              # head + kv-repl
        ("qwen3-moe-30b-a3b", "posh"),                     # EP
        ("rwkv6-3b", "posh"),                              # linear recurrence
        ("zamba2-7b", "xla"),                              # hybrid
        ("whisper-base", "xla"),                           # enc-dec
    ]
    for arch, backend in cases:
        check(arch, backend)
    check("qwen2-moe-a2.7b", "posh", moe_dispatch="alltoall")
    print("TP_EQUIV_PASS")


if __name__ == "__main__":
    main()
