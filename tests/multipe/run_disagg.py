"""Disaggregated prefill/decode cells on a real 8-PE mesh — subprocess
worker.

Mesh (4, 2) = ("data", "model"): four serving CELLS (replicas), each
tensor-parallel over 2 PEs.  Cells 0-1 are PREFILL, cells 2-3 DECODE —
the 2P x 2D topology of the acceptance bar.  Each cell's engine runs
the SPMD step functions over the whole mesh and reads its own replica
row (the run_serve.py pattern); a finished prefill hands its pages off
through the host-side put-with-signal mailbox, each page carried as
its stacked per-TP-rank shards, the consumer draining with ONE
``signal_wait_until`` per ticket.

Checks:

  1. TOPOLOGY PARITY — the same seeded request trace served 2P+2D
     produces the IDENTICAL token streams as the colocated engine, for
     every communicator backend (xla / posh / pallas), GREEDY and
     SAMPLED requests, speculation off and on (spec_k=3 n-gram drafts
     verified on the decode cells).

  2. SIGNALS-ONLY DRAIN — across every run, the handoff queue records
     one put-with-signal per page and one wait per ticket, and ZERO
     tick-global quiets/fences: per-transfer completion carried the
     whole handoff load.

  3. REAL SHARD MOTION — per-TP-rank page shards land intact: after a
     handoff the consumer cell's pool rows equal the producer cell's
     source rows shard-for-shard (replica-distinct scribbles prove the
     bytes moved between replica rows, not SPMD-replicated).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat, configs, serve
from repro.core import SymmetricHeap
from repro.models import registry
from repro.parallel.ctx import ParallelCtx, smap

N_CELLS, TP = 4, 2
N_PREFILL, N_DECODE = 2, 2
mesh = compat.make_mesh((N_CELLS, TP), ("data", "model"))
POOL_SPEC = P("data", "model")


_STEP_CACHE = {}


def jitted_steps(backend, cfg, ctx, scfg, pspecs):
    """The three smap-wrapped step functions, compiled ONCE per
    backend and shared by every cell (the traces only depend on the
    backend's communicator schedules — page geometry and batch shape
    are constant across cells)."""
    if backend in _STEP_CACHE:
        return _STEP_CACHE[backend]
    pf = serve.make_prefill(cfg, ctx, scfg)
    dc = serve.make_decode_step(cfg, ctx, scfg)
    vf = serve.make_verify(cfg, ctx, scfg)

    def pf_w(params, pool, ids, start, n_tok, bt, samp):
        toks, kvo = pf(params, pool[0, 0], ids, start, n_tok, bt, samp)
        return toks, kvo[None, None]

    def dc_w(params, pool, toks, pos, bt, lens, samp):
        nxt, kvo = dc(params, pool[0, 0], toks, pos, bt, lens, samp)
        return nxt, kvo[None, None]

    def vf_w(params, pool, ids, start, n_tok, bt, samp):
        toks, kvo = vf(params, pool[0, 0], ids, start, n_tok, bt, samp)
        return toks, kvo[None, None]

    steps = tuple(
        jax.jit(smap(f, mesh,
                     (pspecs, POOL_SPEC, P(), P(), P(), P(), P()),
                     (P("data"), POOL_SPEC)))
        for f in (pf_w, dc_w, vf_w))
    _STEP_CACHE[backend] = steps
    return steps


class CellMeshExec:
    """Per-cell execution substrate over the (cells, model) mesh: the
    run_serve.py MeshExec with the replica axis read as the CELL axis,
    plus the page-row hooks the disagg mailbox streams through (a page
    row is the cell's stacked per-TP-rank shards)."""

    def __init__(self, params, pspecs, cfg, ctx, scfg, kv, my_pe=0, *,
                 backend="xla"):
        self.params, self.kv = params, kv
        self.my_pe = int(my_pe)            # this cell's replica row
        self._prefill, self._decode, self._verify = jitted_steps(
            backend, cfg, ctx, scfg, pspecs)

    def _my_row(self, toks):
        t = np.asarray(toks)
        return t.reshape((N_CELLS, -1) + t.shape[1:])[self.my_pe]

    def init_pool(self):
        return jnp.zeros((N_CELLS, TP) + self.kv.handle.shape,
                         self.kv.handle.dtype)

    def prefill(self, pool, ids, start, n_tok, bt, samp):
        toks, pool = self._prefill(self.params, pool, jnp.asarray(ids),
                                   jnp.asarray(start),
                                   jnp.asarray(n_tok), jnp.asarray(bt),
                                   samp)
        return self._my_row(toks), pool

    def decode(self, pool, tokens, pos, bt, lens, samp):
        toks, pool = self._decode(self.params, pool,
                                  jnp.asarray(tokens), jnp.asarray(pos),
                                  jnp.asarray(bt), jnp.asarray(lens),
                                  samp)
        return self._my_row(toks), pool

    def verify(self, pool, ids, start, n_tok, bt, samp):
        toks, pool = self._verify(self.params, pool, jnp.asarray(ids),
                                  jnp.asarray(start),
                                  jnp.asarray(n_tok), jnp.asarray(bt),
                                  samp)
        return self._my_row(toks), pool

    def migrate(self, pool, migrations):
        raise NotImplementedError(
            "disagg cells move pages via the put-signal handoff")

    # ---- disagg page-row hooks: rows are (tp, page-geometry) stacks
    def read_pages(self, pool, pages):
        mine = np.asarray(pool)[self.my_pe]         # (TP, n_pages, ...)
        return np.swapaxes(mine[:, np.asarray(pages, np.int64)], 0, 1)

    def write_pages(self, pool, pages, rows):
        idx = jnp.asarray(np.asarray(pages, np.int64))
        # x[int, :, idx] hoists the page axis FIRST (the advanced
        # indices are separated by the slice), so (k, TP, ...) rows
        # assign as-is — no swap back
        return pool.at[self.my_pe, :, idx].set(jnp.asarray(rows))


def build_cfg_ctx(backend):
    cfg = configs.get_smoke("qwen3-8b")
    ctx = ParallelCtx(dp_size=N_CELLS, tp_size=TP, sp=False, remat=False,
                      backend=backend, param_dtype=jnp.float32,
                      compute_dtype=jnp.float32)
    api = registry.build(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg,
                      ParallelCtx(dp_size=1, tp_size=1, sp=False,
                                  remat=False,
                                  param_dtype=jnp.float32,
                                  compute_dtype=jnp.float32))
    return cfg, ctx, api, params


def make_scfg(spec_k=0):
    return serve.ServeConfig(page_tokens=4, n_pages=24, max_batch=3,
                             max_seq=32, prefill_chunk=3,
                             attn_impl="ref", spec_k=spec_k)


def build_cell_engine(cfg, ctx, api, params, scfg, role, my_pe, backend):
    heap = SymmetricHeap(("data", "model"), capacity_bytes=1 << 30)
    kv = serve.PagedKVCache(
        heap, n_layers=cfg.n_layers, kv_heads=cfg.kv_per_rank(TP),
        head_dim=cfg.head_dim, n_pages=scfg.n_pages,
        page_tokens=scfg.page_tokens)
    exec_ = CellMeshExec(params, api.specs(cfg, ctx), cfg, ctx, scfg,
                         kv, my_pe=my_pe, backend=backend)
    return serve.ServeEngine(params, cfg, ctx, scfg, kv=kv, exec_=exec_,
                             my_pe=my_pe, role=role)


def build_disagg(backend, spec_k=0, router="host"):
    cfg, ctx, api, params = build_cfg_ctx(backend)
    scfg = make_scfg(spec_k)
    cells = serve.make_cells(N_PREFILL, N_DECODE, pes_per_cell=TP)
    engines = [build_cell_engine(cfg, ctx, api, params, scfg, c.role,
                                 c.cell, backend)
               for c in cells]
    return serve.DisaggEngine(params, cfg, ctx, scfg,
                              n_prefill=N_PREFILL, n_decode=N_DECODE,
                              pes_per_cell=TP, engines=engines,
                              router=router)


def build_colocated(backend, spec_k=0):
    cfg, ctx, api, params = build_cfg_ctx(backend)
    scfg = make_scfg(spec_k)
    return build_cell_engine(cfg, ctx, api, params, scfg, "both", 0,
                             backend)


PROMPTS = [list(range(3, 11)), list(range(40, 46)), [7, 3, 99, 12, 55],
           [5, 17, 42] * 3]
SAMPLED = serve.SamplingParams(temperature=0.8, top_k=5, top_p=0.9)


def make_reqs(sampling=None):
    return [serve.Request(rid=i, prompt=list(p), max_new=6,
                          sampling=sampling or serve.GREEDY,
                          t_arrive=i // 2)
            for i, p in enumerate(PROMPTS)]


def check_topology_parity():
    for spec_k in (0, 3):
        for tag, sampling in (("greedy", None), ("sampled", SAMPLED)):
            want = None
            for backend in ("xla", "posh", "pallas"):
                colo = build_colocated(backend, spec_k)
                ref = {r.rid: list(r.out)
                       for r in colo.run(make_reqs(sampling),
                                         clock="tick")}
                for router in ("host", "amo"):
                    eng = build_disagg(backend, spec_k, router)
                    done = eng.run(make_reqs(sampling), clock="tick")
                    got = {r.rid: list(r.out) for r in done}
                    assert got == ref, (backend, tag, spec_k, router,
                                        got, ref)
                    if want is None:
                        want = got
                    assert got == want, (backend, tag, spec_k, router)
                    hs = eng.stats()
                    assert hs["handoff_quiets"] == 0, hs
                    # the lock-free control plane never issues a
                    # tick-global barrier either
                    assert hs["router_quiets"] == 0, hs
                    assert hs["handoff_signals"] == hs["handoff_pages"] > 0
                    assert hs["handoff_waits"] == hs["handoff_tickets"] \
                        == len(PROMPTS)
                    assert eng.hq.pending_ops() == 0
                    if router == "amo":
                        assert hs["router_amos"] > 0, hs
                        assert hs["handoff_amos"] > 0, hs
                        for p in eng.pools:
                            ps = p.queue_stats()
                            assert ps["quiets"] == ps["fences"] == 0
                    if spec_k:
                        dec = [eng.engines[c] for c in eng.router.decode]
                        assert sum(e.spec_stats["verify_ticks"]
                                   for e in dec) > 0
            print(f"  2P+2D {tag} spec_k={spec_k} streams == colocated "
                  f"across xla/posh/pallas x router host/amo "
                  f"(signals-only drain, zero router quiets)")


def check_shard_motion():
    """Replica-distinct page contents land shard-for-shard: scribble
    the producer cell's pool, hand one sequence off, and compare the
    consumer's landed rows against the producer's source rows per TP
    rank."""
    eng = build_disagg("xla")
    prod = eng.engines[0]
    rng = np.random.RandomState(7)
    pool = rng.randn(*np.asarray(prod.pool).shape).astype(np.float32)
    prod.pool = jnp.asarray(pool)
    assert prod.kv.alloc_seq(123, 7)           # 2 pages on the producer
    req = serve.Request(rid=123, prompt=[1, 2, 3, 4, 5, 6, 7], max_new=4)
    req.n_done = req.n_prompt
    req.out.append(9)
    prod.handoff_ready.append(req)
    src_pages = list(prod.kv.tables[123])
    eng._issue_handoffs(0)
    (ticket,) = eng._inbox[eng.router.decode[0]]
    dst_cell, dst_pages = ticket.dst_cell, list(ticket.dst_pages)
    eng._drain_inbox(dst_cell, now=0.0)
    got = np.asarray(eng.engines[dst_cell].pool)
    for sp, dp in zip(src_pages, dst_pages):
        for t in range(TP):
            np.testing.assert_array_equal(
                got[dst_cell, t, dp], pool[0, t, sp],
                err_msg=f"page {sp}->{dp} shard {t}")
    assert eng.stats()["handoff_quiets"] == 0
    print(f"  per-TP-rank shards intact across the handoff "
          f"(cell 0 pages {src_pages} -> cell {dst_cell} {dst_pages})")


def main():
    check_shard_motion()
    check_topology_parity()
    print("DISAGG_PASS")


if __name__ == "__main__":
    main()
