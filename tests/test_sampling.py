"""repro.serve.sampling: the TP-aware two-phase sampler — candidate
merge tie-breaking, top-k/top-p truncation, counter-based RNG stream
invariance — plus the traffic prefix-stability and Request-identity
regressions.  (The mesh-sharded phases run in tests/multipe/
run_serve.py; here the merge and the draw are pinned as pure
functions, and the engine end-to-end on 1 PE.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, serve
from repro.comm import merge_candidates
from repro.comm.communicator import DispatchTable
from repro.models import embed as emb
from repro.models import registry
from repro.parallel.ctx import ParallelCtx
from repro.serve import Request, SamplingParams, TickPlan
from repro.serve.sampling import batch_state, sample_from_candidates


# ======================================================================
# candidate merge — the tie-break every backend must agree on
# ======================================================================
def test_merge_candidates_tie_breaks_to_lowest_global_index():
    """Manufactured ties ACROSS shard candidate lists: the merged
    winner must be the lowest global vocab index regardless of which
    shard (list position) holds the tie."""
    # two shards' (value, global-index) lists, value-sorted descending;
    # the max 5.0 appears at global idx 70 (shard hi) and 12 (shard lo)
    vals = jnp.asarray([[5.0, 1.0, 5.0, 0.5]])
    idxs = jnp.asarray([[70, 71, 12, 13]], jnp.int32)
    mv, mi = merge_candidates(vals, idxs, 3)
    assert list(np.asarray(mi[0])) == [12, 70, 71]
    assert list(np.asarray(mv[0])) == [5.0, 5.0, 1.0]


def test_merge_candidates_is_order_invariant():
    rng = np.random.RandomState(0)
    vals = rng.randint(0, 4, size=(2, 8)).astype(np.float32)  # many ties
    idxs = np.stack([rng.permutation(100)[:8] for _ in range(2)])
    perm = rng.permutation(8)
    a = merge_candidates(jnp.asarray(vals), jnp.asarray(idxs), 4)
    b = merge_candidates(jnp.asarray(vals[:, perm]),
                         jnp.asarray(idxs[:, perm]), 4)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


def test_tp_argmax_single_rank_tie_lowest_index():
    ctx = ParallelCtx(dp_size=1, tp_size=1, sp=False, remat=False,
                      param_dtype=jnp.float32, compute_dtype=jnp.float32)
    logits = jnp.asarray([[0.0, 3.0, 1.0, 3.0],
                          [2.0, 2.0, 2.0, 2.0]])
    got = np.asarray(emb.tp_argmax(logits, ctx))
    assert list(got) == [1, 0]


def test_tp_sample_candidates_sorted_and_tied():
    ctx = ParallelCtx(dp_size=1, tp_size=1, sp=False, remat=False,
                      param_dtype=jnp.float32, compute_dtype=jnp.float32)
    logits = jnp.asarray([[1.0, 4.0, 4.0, 0.0, 4.0]])
    vals, idxs = emb.tp_sample_candidates(logits, ctx, 4)
    assert list(np.asarray(idxs[0])) == [1, 2, 4, 0]
    assert list(np.asarray(vals[0])) == [4.0, 4.0, 4.0, 1.0]


def test_dispatch_table_routes_top_k_merge_like_all_gather():
    t = DispatchTable()
    for nbytes in (64, 1 << 20):
        assert t.choose("top_k_merge", nbytes, 8) \
            == t.choose("all_gather", nbytes, 8)


# ======================================================================
# the draw — truncation + counter-based RNG streams
# ======================================================================
def _mk_state(**kw):
    b = kw.pop("b", 2)
    st = {"temperature": np.zeros(b, np.float32),
          "top_k": np.zeros(b, np.int32),
          "top_p": np.ones(b, np.float32),
          "rid": np.arange(b, dtype=np.int32),
          "seed": np.int32(0)}
    for k, v in kw.items():
        st[k] = np.asarray(v, st[k].dtype) if k != "seed" else np.int32(v)
    return st


CAND_V = jnp.asarray([[3.0, 2.0, 1.0, 0.0]] * 2)
CAND_I = jnp.asarray([[7, 11, 13, 17]] * 2, jnp.int32)
POS = jnp.asarray([4, 4], jnp.int32)


def test_greedy_rows_take_candidate_zero():
    st = _mk_state(temperature=[0.0, 0.0])
    tok = sample_from_candidates(CAND_V, CAND_I, st, POS)
    assert list(np.asarray(tok)) == [7, 7]


def test_top_k_one_and_tiny_top_p_reduce_to_greedy():
    st = _mk_state(temperature=[5.0, 5.0], top_k=[1, 0],
                   top_p=[1.0, 1e-6])
    tok = sample_from_candidates(CAND_V, CAND_I, st, POS)
    assert list(np.asarray(tok)) == [7, 7]


def test_top_k_never_selects_beyond_cut():
    st = _mk_state(b=1, temperature=[100.0], top_k=[2])
    seen = set()
    for pos in range(64):
        tok = sample_from_candidates(
            CAND_V[:1], CAND_I[:1], st, jnp.asarray([pos], jnp.int32))
        seen.add(int(tok[0]))
    assert seen <= {7, 11} and len(seen) == 2


def test_stream_keyed_by_rid_position_seed_only():
    """The draw is a pure function of (seed, rid, position) — batch
    slot, batch size and neighbouring rows must not matter."""
    st2 = _mk_state(temperature=[2.0, 2.0], rid=[5, 9])
    both = sample_from_candidates(CAND_V, CAND_I, st2, POS)
    # rid 9 alone in a size-1 batch, same position
    st1 = _mk_state(b=1, temperature=[2.0], rid=[9])
    alone = sample_from_candidates(CAND_V[:1], CAND_I[:1], st1, POS[:1])
    assert int(alone[0]) == int(both[1])
    # swapped slots -> swapped tokens
    sts = _mk_state(temperature=[2.0, 2.0], rid=[9, 5])
    swapped = sample_from_candidates(CAND_V, CAND_I, sts, POS)
    assert list(np.asarray(swapped)) == list(np.asarray(both))[::-1]
    # a different seed or position moves the stream somewhere
    tokens = {(0, 4): int(both[1])}
    for seed, pos in ((1, 4), (0, 5)):
        st = _mk_state(b=1, temperature=[2.0], rid=[9], seed=seed)
        tokens[(seed, pos)] = int(sample_from_candidates(
            CAND_V[:1], CAND_I[:1], st, jnp.asarray([pos], jnp.int32))[0])
    assert len(set(tokens.values())) > 1


def test_sampling_params_validate():
    with pytest.raises(ValueError):
        SamplingParams(temperature=-1.0)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(top_k=-2)


def test_batch_state_packs_per_request_params():
    reqs = [Request(rid=3, prompt=[1], max_new=1,
                    sampling=SamplingParams(temperature=0.5, top_k=4,
                                            top_p=0.9)),
            Request(rid=8, prompt=[2], max_new=1)]
    st = batch_state(reqs, 4, seed=42)
    assert list(st["rid"]) == [3, 8, 0, 0]
    assert st["temperature"][0] == np.float32(0.5)
    assert st["top_k"][0] == 4 and st["top_p"][1] == 1.0
    assert st["temperature"][1] == 0.0          # greedy default
    assert st["seed"] == 42


# ======================================================================
# engine end-to-end (1 PE): sampled streams
# ======================================================================
def _engine(params, cfg, ctx, **kw):
    scfg = serve.ServeConfig(page_tokens=4, n_pages=32, max_batch=3,
                             max_seq=32, attn_impl="ref", **kw)
    return serve.ServeEngine(params, cfg, ctx, scfg)


@pytest.fixture(scope="module")
def smoke_model():
    cfg = configs.get_smoke("qwen3-8b")
    ctx = ParallelCtx(dp_size=1, tp_size=1, sp=False, remat=False,
                      param_dtype=jnp.float32, compute_dtype=jnp.float32)
    api = registry.build(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg, ctx)
    return params, cfg, ctx


SP = SamplingParams(temperature=0.9, top_k=5, top_p=0.9)


def test_engine_sampled_streams_batch_invariant(smoke_model):
    params, cfg, ctx = smoke_model
    prompts = [list(range(3, 9)), list(range(4, 10)), [7, 3, 99, 12]]
    eng = _engine(params, cfg, ctx)
    full = {r.rid: list(r.out) for r in eng.run(
        [Request(rid=i, prompt=list(p), max_new=5, sampling=SP)
         for i, p in enumerate(prompts)], clock="tick")}
    eng2 = _engine(params, cfg, ctx)
    alone = eng2.run([Request(rid=1, prompt=list(prompts[1]), max_new=5,
                              sampling=SP)], clock="tick")
    assert list(alone[0].out) == full[1]


def test_engine_sampled_stream_depends_on_seed(smoke_model):
    params, cfg, ctx = smoke_model
    prompt = list(range(4, 10))
    outs = []
    for seed in (0, 1):
        eng = _engine(params, cfg, ctx, sample_seed=seed)
        outs.append(list(eng.run(
            [Request(rid=1, prompt=list(prompt), max_new=6,
                     sampling=SP)], clock="tick")[0].out))
    assert outs[0] != outs[1]


def test_engine_greedy_requests_unaffected_by_sampled_neighbours(
        smoke_model):
    params, cfg, ctx = smoke_model
    g = Request(rid=0, prompt=list(range(3, 9)), max_new=5)
    eng = _engine(params, cfg, ctx)
    ref = list(eng.run([Request(rid=0, prompt=list(range(3, 9)),
                                max_new=5)], clock="tick")[0].out)
    eng2 = _engine(params, cfg, ctx)
    mixed = eng2.run([g, Request(rid=1, prompt=list(range(4, 10)),
                                 max_new=5, sampling=SP)], clock="tick")
    got = next(r for r in mixed if r.rid == 0)
    assert list(got.out) == ref


def test_engine_rejects_top_k_over_candidate_bound(smoke_model):
    params, cfg, ctx = smoke_model
    eng = _engine(params, cfg, ctx, sample_candidates=4)
    with pytest.raises(ValueError):
        eng.submit(Request(rid=0, prompt=[1, 2], max_new=2,
                           sampling=SamplingParams(temperature=1.0,
                                                   top_k=9)))


def test_engine_itl_state_cleared_on_finish_and_rid_reuse(smoke_model):
    """Inter-token-latency bookkeeping must not leak across requests:
    after a request finishes its rid leaves the gap tracker, so a
    second trace reusing rids on the SAME engine measures its own gaps
    (a stale last-token timestamp would fabricate a giant gap spanning
    the two traces)."""
    params, cfg, ctx = smoke_model
    eng = _engine(params, cfg, ctx)
    reqs = [Request(rid=i, prompt=[3 + i, 4 + i], max_new=3)
            for i in range(2)]
    eng.run(reqs, clock="tick")
    assert eng._last_tok == {}             # all finished -> tracker empty
    eng.run([Request(rid=0, prompt=[9, 8], max_new=3)], clock="tick")
    # uncontended tick-clock decodes advance one token per tick: every
    # true gap is exactly 1; a stale rid-0 entry from the first trace
    # would fabricate a >= 2-tick gap bridging the two traces
    assert eng.itl and set(eng.itl) == {1}


# ======================================================================
# Request identity (bugfix regression)
# ======================================================================
def test_request_identity_not_field_equality():
    """Two requests holding equal field values are DISTINCT schedulable
    entities: membership in plans and skip sets must never conflate
    them (the old dataclass __eq__ compared field values, so
    ``req in plan.preempted`` / ``running.remove`` could hit the wrong
    object)."""
    a = Request(rid=0, prompt=[1, 2], max_new=2)
    b = Request(rid=0, prompt=[1, 2], max_new=2)
    assert a != b
    assert b not in [a]
    plan = TickPlan(preempted=[a])
    assert a in plan.preempted and b not in plan.preempted
    running = [a, b]
    running.remove(b)                 # identity remove: b, not a
    assert running == [a] and running[0] is a


# ======================================================================
# traffic prefix stability (bugfix regression)
# ======================================================================
def test_traffic_prefix_stable_in_n_requests():
    """Growing n_requests must extend the trace, not reshuffle it:
    request i is a pure function of (config, i)."""
    small = serve.make_requests(serve.TrafficConfig(n_requests=6))
    big = serve.make_requests(serve.TrafficConfig(n_requests=16))
    for a, b in zip(small, big):
        assert a.rid == b.rid
        assert a.prompt == b.prompt
        assert a.max_new == b.max_new
        assert a.t_arrive == b.t_arrive
        assert a.sampling == b.sampling


def test_traffic_seed_and_params_flow_to_requests():
    t1 = serve.make_requests(serve.TrafficConfig(n_requests=8, seed=1))
    t2 = serve.make_requests(serve.TrafficConfig(n_requests=8, seed=2))
    assert [r.prompt for r in t1] != [r.prompt for r in t2]
    sampled = serve.make_requests(serve.TrafficConfig(
        n_requests=8, temperature=0.7, top_k=6, top_p=0.85))
    assert all(r.sampling == SamplingParams(0.7, 6, 0.85)
               for r in sampled)
    mixed = serve.make_requests(serve.TrafficConfig(
        n_requests=32, temperature=0.7, greedy_frac=0.5))
    kinds = {r.sampling.temperature for r in mixed}
    assert kinds == {0.0, np.float32(0.7).item()} or kinds == {0.0, 0.7}
