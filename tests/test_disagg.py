"""repro.serve.disagg: disaggregated prefill/decode cells with
put-with-signal page handoff.

The acceptance bar: topology is a placement choice, never a numerical
one — token streams from a P+D cell split are bit-identical to the
colocated engine's (greedy AND sampled, speculation off and on), while
the handoff path drains ONLY through ``signal_wait_until`` (zero
tick-global quiets, pinned via ``CommQueue`` stats).  Plus the
cross-pool page export/adopt paths on ``PagedKVCache`` and the
least-loaded ``CellRouter``.  The real 8-PE mesh run is
``tests/multipe/run_disagg.py``.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, serve
from repro.core import SymmetricHeap
from repro.models import registry
from repro.parallel.ctx import ParallelCtx
from repro.serve import (CellRouter, DisaggEngine, PagedKVCache, Request,
                         ServeConfig, ServeEngine, make_cells)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def make_kv(n_pages=8, page_tokens=4, n_layers=2, kv_heads=2, head_dim=4):
    heap = SymmetricHeap(("data",), capacity_bytes=1 << 24)
    return PagedKVCache(heap, n_layers=n_layers, kv_heads=kv_heads,
                        head_dim=head_dim, n_pages=n_pages,
                        page_tokens=page_tokens)


@pytest.fixture(scope="module")
def smoke_model():
    cfg = configs.get_smoke("qwen3-8b")
    ctx = ParallelCtx(dp_size=1, tp_size=1, sp=False, remat=False,
                      param_dtype=jnp.float32, compute_dtype=jnp.float32)
    params = registry.build(cfg).init(jax.random.PRNGKey(0), cfg, ctx)
    return params, cfg, ctx


# ======================================================================
# PagedKVCache: the cross-pool handoff paths
# ======================================================================
def test_export_seq_detaches_without_freeing():
    kv = make_kv()
    assert kv.alloc_seq("s", 7)              # 2 pages
    pages = list(kv.tables["s"])
    free_before = kv.n_free()
    exported = kv.export_seq("s")
    assert exported == pages
    assert "s" not in kv.tables
    # the pages are NOT back in the pool — they stay resident as the
    # handoff payload source until the consumer acknowledges
    assert kv.n_free() == free_before
    assert not set(exported) & set(kv._free)
    assert kv.stats["exported_pages"] == 2
    # ack: the producer returns them
    kv.release_pages(exported)
    assert kv.n_free() == free_before + 2


def test_adopt_seq_remaps_block_table_on_consumer():
    """The landing ids are the CONSUMER pool's own — a handoff remaps
    the block table, it never forwards producer page ids."""
    prod, cons = make_kv(), make_kv()
    # skew the consumer's free list so ids cannot accidentally match
    assert cons.alloc_seq("skew", 9)         # eats pages 7, 6, 5
    assert prod.alloc_seq("s", 7)
    src = prod.export_seq("s")
    dst = cons.adopt_seq("s", len(src))
    assert dst is not None and len(dst) == len(src)
    assert set(dst).isdisjoint(src)
    bt = cons.block_table(["s"], 4)
    assert list(bt[0, :2]) == dst and bt[0, 2] == 0
    assert cons.stats["adopted_pages"] == 2
    # all-or-nothing when the pool is dry
    assert cons.adopt_seq("t", 99) is None
    assert "t" not in cons.tables


def test_adopted_sequence_truncates_and_grows_like_native():
    """truncate (spec rewind) and ensure (decode growth) on an adopted
    table behave exactly as on a natively-allocated one — rewound tail
    pages return to the CONSUMER's free list."""
    prod, cons = make_kv(), make_kv()
    assert prod.alloc_seq("s", 12)           # 3 pages
    dst = cons.adopt_seq("s", len(prod.export_seq("s")))
    assert cons.ensure("s", 14)              # grow into page 4
    assert len(cons.tables["s"]) == 4
    freed = cons.truncate("s", 6)            # rewind to 2 pages
    assert freed == 2
    assert cons.tables["s"] == dst[:2]
    assert cons.stats["rewound_pages"] == 2
    assert set(cons._free) >= {dst[2]}


def test_exported_pages_stay_out_of_prefix_pin_circulation():
    """A handed-off sequence's pages cannot be prefix-pinned by the
    producer (export pops the table finish would pin from), and the
    consumer can pin the ADOPTED copy under its own budget."""
    prod, cons = make_kv(n_pages=16), make_kv(n_pages=16)
    prompt = list(range(8))                  # 2 full pages
    assert prod.alloc_seq("s", 9)
    src = prod.export_seq("s")
    with pytest.raises(KeyError):
        prod.tables["s"]                     # nothing left to pin
    dst = cons.adopt_seq("s", len(src))
    assert cons.register_prefix(prompt, 1, dst[:2])
    assert cons.lookup_prefix(prompt + [77]) == (1, dst[:2])
    assert cons.pinned_pages == 2
    prod.release_pages(src)
    assert prod.pinned_pages == 0


# ======================================================================
# topology: cells + router
# ======================================================================
def test_make_cells_carves_active_sets():
    cells = make_cells(2, 2, pes_per_cell=2)
    assert [c.role for c in cells] == ["prefill"] * 2 + ["decode"] * 2
    assert [c.pes for c in cells] == [(0, 1), (2, 3), (4, 5), (6, 7)]
    with pytest.raises(ValueError):
        make_cells(0, 2)


def test_router_least_loaded_admission(smoke_model):
    params, cfg, ctx = smoke_model
    scfg = ServeConfig(page_tokens=4, n_pages=32, max_batch=2, max_seq=32)
    eng = DisaggEngine(params, cfg, ctx, scfg, n_prefill=2, n_decode=1)
    r0 = Request(rid=0, prompt=list(range(3, 11)), max_new=2)
    r1 = Request(rid=1, prompt=[5, 6, 7], max_new=2)
    eng.submit(r0)                           # cell 0 (both empty, tie)
    assert r0 in eng.engines[0].sched.waiting
    eng.submit(r1)                           # cell 1 is now lighter
    assert r1 in eng.engines[1].sched.waiting
    router = eng.router
    assert router.prefill_load(0) == 8 and router.prefill_load(1) == 3


def test_router_handoff_backpressure(smoke_model):
    """route_handoff gates on live + INBOUND sequences per decode
    cell; a full topology defers (ticket stays with the producer)."""
    params, cfg, ctx = smoke_model
    scfg = ServeConfig(page_tokens=4, n_pages=32, max_batch=2, max_seq=32)
    eng = DisaggEngine(params, cfg, ctx, scfg, n_prefill=1, n_decode=2)
    router = eng.router
    req = Request(rid=9, prompt=[1, 2], max_new=2)
    assert router.route_handoff(req) == 1    # both empty -> lowest
    router.inbound[1] = 1
    assert router.route_handoff(req) == 2
    router.inbound[2] = 2                    # cell 2 full
    assert router.route_handoff(req) == 1
    router.inbound[1] = 2                    # everything full
    assert router.route_handoff(req) is None


# ======================================================================
# end-to-end: disagg == colocated, signals-only handoff drain
# ======================================================================
def _mixed_requests():
    sp = serve.SamplingParams(temperature=0.9, top_k=5, top_p=0.9)
    return [Request(rid=0, prompt=[5, 17, 42] * 4, max_new=8),
            Request(rid=1, prompt=[5, 17, 42] * 3, max_new=8,
                    sampling=sp),
            Request(rid=2, prompt=[7, 3, 99, 12], max_new=8, t_arrive=1),
            Request(rid=3, prompt=list(range(30, 39)), max_new=6,
                    sampling=sp, t_arrive=2),
            Request(rid=4, prompt=[11, 12], max_new=1, t_arrive=2)]


@pytest.mark.parametrize("spec_k", [0, 2])
@pytest.mark.parametrize("topology", [(1, 1), (2, 2)])
def test_disagg_streams_match_colocated(smoke_model, topology, spec_k):
    """The tentpole bar: P+D cell splits produce the colocated engine's
    exact token streams — greedy and sampled in one trace, speculation
    off and on — and the handoff path completes through
    ``signal_wait_until`` alone (zero quiets/fences on the mailbox
    queue)."""
    params, cfg, ctx = smoke_model
    n_prefill, n_decode = topology

    def scfg():
        return ServeConfig(page_tokens=4, n_pages=48, max_batch=3,
                           max_seq=48, spec_k=spec_k, attn_impl="ref")

    colo = ServeEngine(params, cfg, ctx, scfg())
    ref = {r.rid: list(r.out)
           for r in colo.run(_mixed_requests(), clock="tick")}
    eng = DisaggEngine(params, cfg, ctx, scfg(), n_prefill=n_prefill,
                       n_decode=n_decode)
    done = eng.run(_mixed_requests(), clock="tick")
    got = {r.rid: list(r.out) for r in done}
    assert got == ref, (topology, spec_k)
    hs = eng.stats()
    assert hs["handoff_quiets"] == 0
    assert hs["handoff_signals"] == hs["handoff_pages"] > 0
    assert hs["handoff_waits"] == hs["handoff_tickets"]
    # rid 4 (max_new=1) finishes AT prefill: no decode cell ever saw it
    assert hs["handoff_tickets"] == len(ref) - 1
    assert eng.hq.pending_ops() == 0


def test_handoff_frees_producer_pages_after_ack(smoke_model):
    """Conservation: after a full trace every cell's pool is whole
    again — producers freed their exported pages on ack, consumers
    freed the adopted tables on finish."""
    params, cfg, ctx = smoke_model
    scfg = ServeConfig(page_tokens=4, n_pages=32, max_batch=2, max_seq=32)
    eng = DisaggEngine(params, cfg, ctx, scfg, n_prefill=1, n_decode=1)
    done = eng.run(_mixed_requests(), clock="tick")
    assert len(done) == 5
    for e in eng.engines:
        assert e.kv.n_free() == e.kv.n_pages - 1 - e.kv.pinned_pages
        assert not e.kv.tables
    prod = eng.engines[0].kv
    assert prod.stats["exported_pages"] > 0
    assert prod.stats["page_frees"] >= prod.stats["exported_pages"]


@pytest.mark.parametrize("spec_k", [0, 2])
@pytest.mark.parametrize("topology", [(1, 1), (2, 2)])
def test_amo_router_streams_match_host(smoke_model, topology, spec_k):
    """PR-9 tentpole bar: ``--router amo`` (CAS admission rings +
    claim-word mailbox + symmetric page pools) produces the host
    router's exact token streams — greedy and sampled, speculation off
    and on — while the entire control plane drains without ONE
    tick-global quiet (router queue AND every cell's pool queue)."""
    params, cfg, ctx = smoke_model
    n_prefill, n_decode = topology

    def build(router):
        scfg = ServeConfig(page_tokens=4, n_pages=48, max_batch=3,
                           max_seq=48, spec_k=spec_k, attn_impl="ref")
        return DisaggEngine(params, cfg, ctx, scfg, n_prefill=n_prefill,
                            n_decode=n_decode, router=router)

    host = build("host")
    ref = {r.rid: list(r.out)
           for r in host.run(_mixed_requests(), clock="tick")}
    eng = build("amo")
    got = {r.rid: list(r.out)
           for r in eng.run(_mixed_requests(), clock="tick")}
    assert got == ref, (topology, spec_k)
    hs = eng.stats()
    assert hs["handoff_quiets"] == 0
    assert hs["router_quiets"] == 0          # router + pool queues
    assert hs["router_amos"] > 0 and hs["handoff_amos"] > 0
    assert hs["handoff_signals"] == hs["handoff_pages"] > 0
    assert hs["handoff_waits"] == hs["handoff_tickets"]
    for pool in eng.pools:
        qs = pool.queue_stats()
        assert qs["quiets"] == 0 and qs["fences"] == 0
        assert qs["amos"] > 0
    # host mode reports the amo counters as zeros (one stats schema)
    hh = host.stats()
    assert hh["router_amos"] == hh["router_quiets"] == 0
    assert hh["steals"] == hh["alloc_cas_retries"] == 0


def test_colocated_amo_pool_is_invisible(smoke_model):
    """``--router amo`` without cells attaches a SymmetricPagePool to
    the single engine's cache: identical page grants, identical
    streams, zero quiets on the pool queue."""
    params, cfg, ctx = smoke_model

    def scfg():
        return ServeConfig(page_tokens=4, n_pages=48, max_batch=3,
                           max_seq=48, attn_impl="ref")

    host = ServeEngine(params, cfg, ctx, scfg())
    ref = {r.rid: list(r.out)
           for r in host.run(_mixed_requests(), clock="tick")}
    eng = ServeEngine(params, cfg, ctx, scfg())
    eng.kv.attach_pool(serve.SymmetricPagePool(eng.kv.n_pages))
    got = {r.rid: list(r.out)
           for r in eng.run(_mixed_requests(), clock="tick")}
    assert got == ref
    qs = eng.kv._pool.queue_stats()
    assert qs["quiets"] == 0 and qs["fences"] == 0 and qs["amos"] > 0


def test_disagg_cli_spec_and_builder():
    from repro.launch.serve import build_engine, parse_disagg
    assert parse_disagg("2+2") == (2, 2)
    assert parse_disagg("1+3") == (1, 3)
    for bad in ("2", "0+2", "2+0", "a+b"):
        with pytest.raises(SystemExit):
            parse_disagg(bad)
    eng, cfg = build_engine("qwen3-8b", n_pages=32, max_batch=2,
                            disagg="1+1")
    assert isinstance(eng, DisaggEngine)
    assert [c.role for c in eng.cells] == ["prefill", "decode"]
    # --router wiring: amo builds the lock-free control plane
    eng, _ = build_engine("qwen3-8b", n_pages=32, max_batch=2,
                          disagg="1+1", router="amo")
    assert eng.router_mode == "amo"
    assert isinstance(eng.router, serve.AmoCellRouter)
    assert len(eng.pools) == len(eng.engines)
    eng, _ = build_engine("qwen3-8b", n_pages=32, max_batch=2,
                          router="amo")          # colocated: pool only
    assert isinstance(eng, ServeEngine)
    assert isinstance(eng.kv._pool, serve.SymmetricPagePool)
    with pytest.raises(SystemExit):
        build_engine("qwen3-8b", router="bogus")


# ======================================================================
# the 8-PE mesh suite (subprocess, like the other multipe workers)
# ======================================================================
def test_disagg_mesh_8pe():
    if os.environ.get("REPRO_MULTIPE_EXPLICIT"):
        pytest.skip("multipe workers run explicitly (scripts/verify.sh)")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable,
         os.path.join(ROOT, "tests", "multipe", "run_disagg.py")],
        capture_output=True, text=True, env=env, timeout=2400)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "DISAGG_PASS" in r.stdout
