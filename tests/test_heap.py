"""Symmetric heap: allocator behaviour + the paper's memory-model
properties (Fact 1, Corollary 1, Lemma 1)."""
import random

import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                       # deterministic fallback driver
    HAVE_HYPOTHESIS = False

    def settings(**kw):
        return lambda fn: fn

    def given(strategy):
        def deco(fn):
            def run():
                for ex in strategy:
                    fn(ex)
            return run
        return deco

from repro.core.heap import SymmetricHeap


def _fallback_op_sequences(n_cases=60, seed=7, kinds=("alloc", "free")):
    """Seeded stand-in for the hypothesis strategy: n_cases random
    alloc/free(/realloc) sequences."""
    rng = random.Random(seed)
    out = []
    for _ in range(n_cases):
        out.append([(rng.choice(kinds), rng.randint(0, 7),
                     rng.randint(1, 96)) for _ in range(rng.randint(0, 24))])
    return out


if HAVE_HYPOTHESIS:
    _ops_af = st.lists(st.tuples(st.sampled_from(["alloc", "free"]),
                                 st.integers(0, 7), st.integers(1, 64)),
                       max_size=24)
    _ops_afr = st.lists(st.tuples(
        st.sampled_from(["alloc", "free", "realloc"]),
        st.integers(0, 5), st.integers(1, 96)), max_size=24)
    _sizes = st.lists(st.integers(1, 128), min_size=1, max_size=8)
else:
    _ops_af = _fallback_op_sequences()
    _ops_afr = _fallback_op_sequences(kinds=("alloc", "free", "realloc"))

    def _mixed_sizes(n_cases=40, seed=11):
        rng = random.Random(seed)
        return [[rng.randint(1, 128) for _ in range(rng.randint(1, 8))]
                for _ in range(n_cases)]

    _sizes = _mixed_sizes()


def make_heap():
    return SymmetricHeap(("data", "model"), capacity_bytes=1 << 20)


def test_alloc_free_roundtrip():
    h = make_heap()
    a = h.alloc("a", (16, 4), jnp.float32)
    b = h.alloc("b", (8,), jnp.int32)
    assert a.offset % SymmetricHeap.DEFAULT_ALIGN == 0
    assert b.offset >= a.offset + a.nbytes
    h.free("a")
    c = h.alloc("c", (16, 4), jnp.float32)
    assert c.offset == a.offset  # first-fit reuses the hole
    h.free("b")
    h.free("c")
    assert h.used_bytes() == 0
    assert h.frag_blocks() == 1  # fully coalesced


def test_shmemalign():
    h = make_heap()
    a = h.align_alloc("a", (3,), jnp.int8, align=4096)
    assert a.offset % 4096 == 0
    with pytest.raises(ValueError):
        h.align_alloc("b", (3,), jnp.int8, align=100)  # not a power of two


def test_double_alloc_rejected():
    h = make_heap()
    h.alloc("x", (4,), jnp.float32)
    with pytest.raises(ValueError):
        h.alloc("x", (4,), jnp.float32)


def test_oom():
    h = SymmetricHeap(("data",), capacity_bytes=1024)
    with pytest.raises(MemoryError):
        h.alloc("big", (10_000,), jnp.float32)


def test_corollary1_addressing():
    """addr -> (object, offset) resolution: the symmetric address IS the
    offset, so resolution must be exact and total."""
    h = make_heap()
    a = h.alloc("a", (16,), jnp.float32)
    b = h.alloc("b", (4, 4), jnp.int32)
    for handle in (a, b):
        for byte in (0, handle.nbytes - 1):
            got, off = h.resolve(handle.addr + byte)
            assert got.name == handle.name and off == byte
    with pytest.raises(KeyError):
        h.resolve(10**9)


@settings(max_examples=60, deadline=None)
@given(_ops_af)
def test_fact1_registry_symmetry(ops):
    """Fact 1: the same (trace-time) allocation sequence produces the
    same offsets — two heaps driven identically have identical
    fingerprints (the SPMD guarantee the paper's barrier provides)."""
    h1, h2 = make_heap(), make_heap()
    for h in (h1, h2):
        live = set()
        for op, slot, n in ops:
            name = f"buf{slot}"
            try:
                if op == "alloc" and name not in live:
                    h.alloc(name, (n,), jnp.float32)
                    live.add(name)
                elif op == "free" and name in live:
                    h.free(name)
                    live.discard(name)
            except MemoryError:
                pass
    assert h1.fingerprint() == h2.fingerprint()


@settings(max_examples=40, deadline=None)
@given(_sizes)
def test_lemma1_scratch_invariance(sizes):
    """Lemma 1: temporary symmetric allocations inside a collective do
    not change the heap outside it."""
    h = make_heap()
    h.alloc("persistent", (32,), jnp.float32)
    before = h.fingerprint()
    used_before = h.used_bytes()
    import contextlib
    with contextlib.ExitStack() as stack:
        for i, n in enumerate(sizes):
            stack.enter_context(h.scratch((n,), jnp.float32, tag=f"s{i}"))
        assert h.used_bytes() > used_before  # scratch is really allocated
    assert h.fingerprint() == before
    assert h.used_bytes() == used_before


def test_state_factories():
    h = make_heap()
    h.alloc("a", (4, 2), jnp.bfloat16)
    st_ = h.zeros_state()
    assert st_["a"].shape == (4, 2) and st_["a"].dtype == jnp.bfloat16
    spec = h.spec_state()
    assert spec["a"].shape == (4, 2)


# ----------------------------------------------------------------------
# realloc (shrealloc, §4.1.1) — in-place shrink/grow, move fallback
# ----------------------------------------------------------------------
def test_realloc_shrink_in_place():
    h = make_heap()
    a = h.alloc("a", (64,), jnp.float32)
    b = h.alloc("b", (8,), jnp.float32)
    used = h.used_bytes()
    a2 = h.realloc("a", (16,))
    assert a2.offset == a.offset           # offset preserved
    assert a2.shape == (16,) and a2.nbytes == 64
    assert h.used_bytes() == used - (a.nbytes - a2.nbytes)
    # the freed tail is allocatable (a hole between a and b)
    c = h.alloc("c", (4,), jnp.float32, align=64)
    assert a2.offset < c.offset < b.offset


def test_realloc_grow_absorbs_adjacent_free():
    h = make_heap()
    a = h.alloc("a", (16,), jnp.float32)
    b = h.alloc("b", (8,), jnp.float32)
    h.free("b")                            # free block right after a
    a2 = h.realloc("a", (64,))
    assert a2.offset == a.offset           # grew in place
    assert a2.shape == (64,)
    got, off = h.resolve(a2.offset + a2.nbytes - 1)
    assert got.name == "a" and off == a2.nbytes - 1


def test_realloc_size_zero_frees_and_returns_null_handle():
    """shrealloc(ptr, 0) == shfree(ptr): the block is released and the
    null handle comes back — not a 1-byte stub allocation (§4.1.1)."""
    h = make_heap()
    a = h.alloc("a", (64,), jnp.float32)
    b = h.alloc("b", (8,), jnp.float32)
    assert h.realloc("a", 0) is None           # int size, like the paper
    assert "a" not in h.registry
    with pytest.raises(KeyError):
        h.resolve(a.offset)                    # address no longer mapped
    c = h.alloc("c", (64,), jnp.float32)
    assert c.offset == a.offset                # extent truly free again
    h.free("b")
    h.free("c")
    assert h.used_bytes() == 0 and h.frag_blocks() == 1


def test_realloc_zero_dim_shapes_free_too():
    h = make_heap()
    h.alloc("a", (16, 4), jnp.float32)
    assert h.realloc("a", (0,)) is None
    assert "a" not in h.registry
    h.alloc("b", (16, 4), jnp.float32)
    assert h.realloc("b", (4, 0, 2)) is None   # any zero dim is size 0
    assert h.used_bytes() == 0
    # but a SCALAR shape () is one element, not zero: stays live
    h.alloc("c", (4,), jnp.float32)
    c2 = h.realloc("c", ())
    assert c2 is not None and c2.shape == () and "c" in h.registry


def test_realloc_zero_on_missing_name_still_raises():
    h = make_heap()
    with pytest.raises(KeyError):
        h.realloc("ghost", 0)


def test_realloc_move_when_blocked():
    h = make_heap()
    a = h.alloc("a", (16,), jnp.float32)
    b = h.alloc("b", (8,), jnp.float32)    # pins the space after a
    a2 = h.realloc("a", (1024,))
    assert a2.shape == (1024,)
    assert a2.offset != a.offset           # had to move...
    assert "a" in h.registry               # ...but stayed registered
    got, _ = h.resolve(a2.offset)
    assert got.name == "a"
    # old extent is free again: a small alloc first-fits into it
    c = h.alloc("c", (4,), jnp.float32)
    assert c.offset == a.offset


def test_realloc_same_size_and_dtype_change():
    h = make_heap()
    a = h.alloc("a", (16,), jnp.float32)
    a2 = h.realloc("a", (8, 2))            # same bytes, new shape
    assert a2.offset == a.offset and a2.shape == (8, 2)
    a3 = h.realloc("a", (32,), jnp.int16)  # same bytes, new dtype
    assert a3.offset == a.offset and a3.dtype == jnp.dtype(jnp.int16)


def test_realloc_missing_raises():
    h = make_heap()
    with pytest.raises(KeyError):
        h.realloc("ghost", (4,))


def test_realloc_align_validated_before_mutation():
    """A bad align must fail BEFORE the object is freed (the move path
    frees first), and a stronger align than the offset satisfies forces
    a move to an offset that honours it."""
    h = make_heap()
    h.alloc("a", (16,), jnp.float32)
    h.alloc("b", (16,), jnp.float32)       # blocks in-place growth
    with pytest.raises(ValueError, match="power of two"):
        h.realloc("a", (64,), align=3)
    assert h.registry["a"].shape == (16,)  # untouched
    # align_alloc'd objects keep their alignment through a moving grow
    c = h.align_alloc("c", (4,), jnp.float32, align=4096)
    h.alloc("d", (16,), jnp.float32)       # pins the space after c
    c2 = h.realloc("c", (8192,))
    assert c2.offset % 4096 == 0 and c2.align == 4096


def test_realloc_oom_keeps_object_at_its_offset():
    """A failed grow must leave the object untouched (shrealloc's
    unchanged-on-failure contract): same offset, even when first-fit
    would have preferred an earlier hole."""
    h = SymmetricHeap(("data",), capacity_bytes=4096)
    h.alloc("pad", (64,), jnp.float32)     # hole-to-be before 'a'
    a = h.alloc("a", (64,), jnp.float32)
    h.alloc("b", (64,), jnp.float32)       # blocks in-place growth
    h.free("pad")                          # first-fit bait at offset 0
    with pytest.raises(MemoryError):
        h.realloc("a", (100_000,))
    assert h.registry["a"].shape == (64,)
    assert h.registry["a"].offset == a.offset   # did NOT move to 0
    got, _ = h.resolve(a.offset)
    assert got.name == "a"


def test_realloc_free_list_coalesces():
    """alloc/free/realloc churn must end fully coalesced: one free
    block, zero used bytes."""
    h = make_heap()
    h.alloc("a", (32,), jnp.float32)
    h.alloc("b", (32,), jnp.float32)
    h.alloc("c", (32,), jnp.float32)
    h.free("b")
    h.realloc("a", (128,))                 # moves or absorbs
    h.realloc("c", (4,))                   # shrinks
    h.free("a")
    h.free("c")
    assert h.used_bytes() == 0
    assert h.frag_blocks() == 1


@settings(max_examples=40, deadline=None)
@given(_ops_afr)
def test_fact1_offsets_identical_across_pes_with_realloc(ops):
    """Lemma 1 / Fact 1 for the full allocator surface: two PEs (two
    heap instances) driven through the same alloc/free/REALLOC sequence
    hold every object at identical offsets — block tables built from
    those offsets are valid on either PE without translation."""
    h1, h2 = make_heap(), make_heap()
    for h in (h1, h2):
        live = set()
        for op, slot, n in ops:
            name = f"buf{slot}"
            try:
                if op == "alloc" and name not in live:
                    h.alloc(name, (n,), jnp.float32)
                    live.add(name)
                elif op == "free" and name in live:
                    h.free(name)
                    live.discard(name)
                elif op == "realloc" and name in live:
                    h.realloc(name, (n,))
            except MemoryError:
                pass
    assert h1.fingerprint() == h2.fingerprint()
    for name in h1.registry:
        assert h1.registry[name].offset == h2.registry[name].offset
