"""Symmetric heap: allocator behaviour + the paper's memory-model
properties (Fact 1, Corollary 1, Lemma 1)."""
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.heap import SymmetricHeap


def make_heap():
    return SymmetricHeap(("data", "model"), capacity_bytes=1 << 20)


def test_alloc_free_roundtrip():
    h = make_heap()
    a = h.alloc("a", (16, 4), jnp.float32)
    b = h.alloc("b", (8,), jnp.int32)
    assert a.offset % SymmetricHeap.DEFAULT_ALIGN == 0
    assert b.offset >= a.offset + a.nbytes
    h.free("a")
    c = h.alloc("c", (16, 4), jnp.float32)
    assert c.offset == a.offset  # first-fit reuses the hole
    h.free("b")
    h.free("c")
    assert h.used_bytes() == 0
    assert h.frag_blocks() == 1  # fully coalesced


def test_shmemalign():
    h = make_heap()
    a = h.align_alloc("a", (3,), jnp.int8, align=4096)
    assert a.offset % 4096 == 0
    with pytest.raises(ValueError):
        h.align_alloc("b", (3,), jnp.int8, align=100)  # not a power of two


def test_double_alloc_rejected():
    h = make_heap()
    h.alloc("x", (4,), jnp.float32)
    with pytest.raises(ValueError):
        h.alloc("x", (4,), jnp.float32)


def test_oom():
    h = SymmetricHeap(("data",), capacity_bytes=1024)
    with pytest.raises(MemoryError):
        h.alloc("big", (10_000,), jnp.float32)


def test_corollary1_addressing():
    """addr -> (object, offset) resolution: the symmetric address IS the
    offset, so resolution must be exact and total."""
    h = make_heap()
    a = h.alloc("a", (16,), jnp.float32)
    b = h.alloc("b", (4, 4), jnp.int32)
    for handle in (a, b):
        for byte in (0, handle.nbytes - 1):
            got, off = h.resolve(handle.addr + byte)
            assert got.name == handle.name and off == byte
    with pytest.raises(KeyError):
        h.resolve(10**9)


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["alloc", "free"]),
                          st.integers(0, 7),
                          st.integers(1, 64)), max_size=24))
def test_fact1_registry_symmetry(ops):
    """Fact 1: the same (trace-time) allocation sequence produces the
    same offsets — two heaps driven identically have identical
    fingerprints (the SPMD guarantee the paper's barrier provides)."""
    h1, h2 = make_heap(), make_heap()
    for h in (h1, h2):
        live = set()
        for op, slot, n in ops:
            name = f"buf{slot}"
            try:
                if op == "alloc" and name not in live:
                    h.alloc(name, (n,), jnp.float32)
                    live.add(name)
                elif op == "free" and name in live:
                    h.free(name)
                    live.discard(name)
            except MemoryError:
                pass
    assert h1.fingerprint() == h2.fingerprint()


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(1, 128), min_size=1, max_size=8))
def test_lemma1_scratch_invariance(sizes):
    """Lemma 1: temporary symmetric allocations inside a collective do
    not change the heap outside it."""
    h = make_heap()
    h.alloc("persistent", (32,), jnp.float32)
    before = h.fingerprint()
    used_before = h.used_bytes()
    import contextlib
    with contextlib.ExitStack() as stack:
        for i, n in enumerate(sizes):
            stack.enter_context(h.scratch((n,), jnp.float32, tag=f"s{i}"))
        assert h.used_bytes() > used_before  # scratch is really allocated
    assert h.fingerprint() == before
    assert h.used_bytes() == used_before


def test_state_factories():
    h = make_heap()
    h.alloc("a", (4, 2), jnp.bfloat16)
    st_ = h.zeros_state()
    assert st_["a"].shape == (4, 2) and st_["a"].dtype == jnp.bfloat16
    spec = h.spec_state()
    assert spec["a"].shape == (4, 2)
