"""Communicator API: dispatch-table selection, instrumentation,
registry, error paths (in-process), and xla/posh numerical parity over
a real 8-PE mesh (subprocess, like the other multi-PE suites)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import comm
from repro.comm import Communicator, DispatchTable, make_communicator

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ----------------------------------------------------------------------
# multi-PE parity (subprocess: main process must keep 1 device)
# ----------------------------------------------------------------------
def test_comm_parity_8pe():
    if os.environ.get("REPRO_MULTIPE_EXPLICIT"):
        pytest.skip("multipe workers run explicitly (scripts/verify.sh)")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable,
         os.path.join(ROOT, "tests", "multipe", "run_comm_parity.py")],
        capture_output=True, text=True, env=env, timeout=2400)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "COMM_PARITY_PASS" in r.stdout


# ----------------------------------------------------------------------
# dispatch table: size thresholds at the documented boundaries
# ----------------------------------------------------------------------
def test_dispatch_thresholds():
    t = DispatchTable()
    n = 8
    # at the boundary -> eager; one byte over -> chunked
    assert t.choose("psum", t.allreduce_small_bytes, n) == t.allreduce_eager
    assert t.choose("psum", t.allreduce_small_bytes + 1, n) \
        == t.allreduce_chunked
    assert t.choose("all_gather", t.allgather_small_bytes, n) \
        == t.allgather_eager
    assert t.choose("all_gather", t.allgather_small_bytes + 1, n) \
        == t.allgather_chunked
    # pmax shares the allreduce rule
    assert t.choose("pmax", 1, n) == t.allreduce_eager
    # tiny teams are always eager, regardless of bytes
    huge = t.allreduce_small_bytes * 100
    assert t.choose("psum", huge, 2) == t.allreduce_eager
    assert t.choose("all_gather", huge, 2) == t.allgather_eager
    # recursive doubling degrades honestly on non-power-of-two teams —
    # to the chunked ring (what repro.core itself falls back to), even
    # when the table pins rd everywhere
    assert t.choose("all_gather", 1, 6) == t.allgather_chunked
    assert t.choose("all_gather", 1, 8) == "recursive_doubling"
    pinned = DispatchTable.fixed(allreduce="recursive_doubling",
                                 allgather="recursive_doubling")
    assert pinned.choose("psum", 1 << 20, 6) == "ring"
    assert pinned.choose("all_gather", 1 << 20, 6) == "ring"
    assert pinned.choose("psum", 1 << 20, 8) == "recursive_doubling"
    # un-sized ops are fixed
    assert t.choose("psum_scatter", 1, n) == "ring"
    assert t.choose("all_to_all", 1, n) == "pairwise"
    assert t.choose("pbroadcast", 1, n) == "binomial"
    with pytest.raises(KeyError):
        t.choose("not_an_op", 1, n)


def test_dispatch_fixed_ignores_size():
    t = DispatchTable.fixed(allreduce="ring", allgather="ring")
    assert t.choose("psum", 1, 8) == "ring"
    assert t.choose("psum", 1 << 30, 8) == "ring"
    assert t.choose("all_gather", 1, 8) == "ring"


def test_shims_removed():
    """The deprecated free-function shims and CommConfig were deleted on
    schedule (two PRs after the ordered pipeline).  The removal must be
    total: no attribute survives to silently shadow the method API."""
    for name in ("CommConfig", "psum", "pmax", "all_gather",
                 "psum_scatter", "all_to_all", "pbroadcast",
                 "axis_index", "axis_size"):
        assert not hasattr(comm, name), f"shim '{name}' still exported"
    # the pinned-algorithm behaviour lives on as DispatchTable.fixed
    t = DispatchTable.fixed(allreduce="tree",
                            allgather="recursive_doubling")
    assert t.choose("psum", 1 << 30, 8) == "tree"
    assert t.choose("all_gather", 1 << 30, 8) == "recursive_doubling"


def test_tuned_from_bench():
    bench = {"results": [
        {"op": "psum", "algo": "tree", "nbytes": 1024, "us_per_call": 10.0},
        {"op": "psum", "algo": "ring", "nbytes": 1024, "us_per_call": 20.0},
        {"op": "psum", "algo": "tree", "nbytes": 1 << 20,
         "us_per_call": 900.0},
        {"op": "psum", "algo": "ring", "nbytes": 1 << 20,
         "us_per_call": 300.0},
    ]}
    t = DispatchTable.tuned_from_bench(bench)
    assert t.allreduce_small_bytes == 1024
    assert t.choose("psum", 1024, 8) == "tree"
    assert t.choose("psum", 1 << 20, 8) == "ring"
    # no psum rows with both algos -> default kept
    assert t.allgather_small_bytes == DispatchTable().allgather_small_bytes


def test_tuned_from_bench_eager_never_wins():
    bench = {"results": [
        {"op": "psum", "algo": "tree", "nbytes": 256, "us_per_call": 50.0},
        {"op": "psum", "algo": "ring", "nbytes": 256, "us_per_call": 10.0},
        {"op": "psum", "algo": "tree", "nbytes": 65536,
         "us_per_call": 500.0},
        {"op": "psum", "algo": "ring", "nbytes": 65536,
         "us_per_call": 100.0},
    ]}
    t = DispatchTable.tuned_from_bench(bench)
    assert t.allreduce_small_bytes == 0       # measurements say: always ring
    assert t.choose("psum", 1, 8) == "ring"


# ----------------------------------------------------------------------
# registry
# ----------------------------------------------------------------------
def test_backend_registry():
    assert set(comm.available_backends()) >= {"xla", "posh"}
    with pytest.raises(ValueError):
        comm.get_backend("no_such_backend")
    with pytest.raises(ValueError):
        Communicator("model", size=2, backend="no_such_backend")

    class EchoBackend(comm.CommBackend):
        name = "echo"

        def psum(self, x, team, algo, heap=None):
            return x

    comm.register_backend("echo", EchoBackend, overwrite=True)
    c = Communicator("model", size=4, backend="echo")
    # direct backend dispatch (no mesh needed: never touches lax)
    assert c.backend.psum(1.5, c.team, "whatever") == 1.5
    with pytest.raises(ValueError):
        comm.register_backend("echo", EchoBackend)   # duplicate, no overwrite


# ----------------------------------------------------------------------
# error paths (static checks run before any collective is traced)
# ----------------------------------------------------------------------
def test_all_to_all_non_divisible_raises():
    c = Communicator("model", size=4, backend="posh")
    with pytest.raises(ValueError, match="not divisible"):
        c.all_to_all(jnp.ones((6, 3)), split_axis=0, concat_axis=0)
    cx = Communicator("model", size=4, backend="xla")
    with pytest.raises(ValueError, match="not divisible"):
        cx.all_to_all(jnp.ones((7, 2)), split_axis=0, concat_axis=1)


def test_psum_scatter_non_divisible_raises():
    c = Communicator("model", size=4, backend="posh")
    with pytest.raises(ValueError, match="not divisible"):
        c.psum_scatter(jnp.ones((6, 3)), axis=0)


def test_broadcast_root_range():
    c = Communicator("model", size=4, backend="posh")
    with pytest.raises(ValueError, match="out of range"):
        c.pbroadcast(jnp.ones(3), root=4)


def test_bad_team_size():
    with pytest.raises(ValueError):
        Communicator("model", size=0)


# ----------------------------------------------------------------------
# degenerate (1-PE) semantics + instrumentation
# ----------------------------------------------------------------------
def test_identity_shortcut_shapes_and_stats():
    c = make_communicator("model", size=1, backend="posh")
    x = jnp.arange(12.0).reshape(3, 4)
    np.testing.assert_array_equal(np.asarray(c.psum(x)), np.asarray(x))
    np.testing.assert_array_equal(np.asarray(c.pmean(x)), np.asarray(x))
    assert c.all_gather(x, axis=0, tiled=True).shape == (3, 4)
    assert c.all_gather(x, axis=0, tiled=False).shape == (1, 3, 4)
    assert c.all_gather(x, axis=1, tiled=False).shape == (3, 1, 4)
    assert c.psum_scatter(x, axis=0).shape == (3, 4)
    assert c.all_to_all(x, split_axis=0, concat_axis=1).shape == (3, 4)
    st = c.stats()
    assert st["psum"]["calls"] == 2          # psum + pmean
    assert st["all_gather"]["calls"] == 3
    assert st["psum"]["algos"] == {"identity": 2}
    assert st["psum"]["bytes"] == 2 * x.size * 4
    c.reset_stats()
    assert c.stats() == {}


def test_stats_is_isolated_copy():
    c = make_communicator("model", size=1)
    c.psum(jnp.ones(3))
    st = c.stats()
    st["psum"]["calls"] = 999
    assert c.stats()["psum"]["calls"] == 1


# ----------------------------------------------------------------------
# hashing / equality (static part only -> usable as nondiff_argnums)
# ----------------------------------------------------------------------
def test_communicator_hash_eq():
    a = Communicator("model", size=4, backend="posh")
    b = Communicator("model", size=4, backend="posh")
    assert a == b and hash(a) == hash(b)
    a._record("psum", 4, "tree")   # stats divergence must not affect eq
    assert a == b and hash(a) == hash(b)
    assert a != Communicator("model", size=8, backend="posh")
    assert a != Communicator("model", size=4, backend="xla")
    assert a != Communicator("data", size=4, backend="posh")
    # the heap participates by identity: its allocations are baked into
    # the traced program, so heap-distinct communicators must not share
    # a jit/custom_vjp cache entry
    from repro.core import SymmetricHeap
    h1 = SymmetricHeap(("model",))
    h2 = SymmetricHeap(("model",))
    ah1 = Communicator("model", size=4, backend="posh", heap=h1)
    assert ah1 != Communicator("model", size=4, backend="posh", heap=h2)
    assert ah1 == Communicator("model", size=4, backend="posh", heap=h1)
    assert ah1 != a


def test_pbroadcast_accepts_pytrees():
    c = make_communicator("model", size=1, backend="posh")
    tree = {"a": jnp.ones((3,)), "b": jnp.zeros((2, 2))}
    out = c.pbroadcast(tree, root=0)
    assert jax.tree.structure(out) == jax.tree.structure(tree)


# ----------------------------------------------------------------------
# ParallelCtx threading
# ----------------------------------------------------------------------
def test_ctx_builds_communicators():
    from repro.parallel.ctx import ParallelCtx
    ctx = ParallelCtx(dp_size=1, tp_size=1, backend="posh")
    assert ctx.tp_comm.backend_name == "posh"
    assert ctx.dp_comm.team.axes == ("data",)
    # with_ rebuilds communicators when their inputs change
    ctx2 = ctx.with_(tp_size=4, backend="xla")
    assert ctx2.tp_comm.size == 4 and ctx2.tp_comm.backend_name == "xla"
    # ...and keeps them when unrelated fields change
    ctx3 = ctx.with_(remat=False)
    assert ctx3.tp_comm is ctx.tp_comm
    # per-team invalidation: changing dp_size keeps the SAME tp_comm
    # object (so instrumentation recorded on it is not lost) but
    # rebuilds dp_comm
    ctx5 = ctx.with_(dp_size=1)
    assert ctx5.tp_comm is ctx.tp_comm
    assert ctx5.dp_comm is not ctx.dp_comm
    # a pinned dispatch table (the old CommConfig semantics) threads
    # through to the built communicators
    ctx4 = ParallelCtx(backend="posh",
                       dispatch=comm.DispatchTable.fixed(allreduce="ring"))
    assert ctx4.dispatch is ctx4.tp_comm.dispatch
    assert ctx4.tp_comm.dispatch.choose("psum", 1, 8) == "ring"
    # the deprecated comm=CommConfig field is gone, loudly
    with pytest.raises(TypeError):
        ParallelCtx(comm=object())


def test_ctx_from_mesh_overrides(monkeypatch):
    from repro.parallel.ctx import ParallelCtx

    class FakeMesh:
        axis_names = ("data", "model")

        class devices:
            shape = (2, 4)

    ctx = ParallelCtx.from_mesh(FakeMesh, backend="posh")
    assert (ctx.dp_size, ctx.tp_size) == (2, 4)
    # explicit sizes still win over the mesh-derived ones
    ctx = ParallelCtx.from_mesh(FakeMesh, dp_size=1, tp_size=1)
    assert (ctx.dp_size, ctx.tp_size) == (1, 1)


def test_ctx_backend_override_rebuilds():
    """with_(backend=...) rebuilds the communicators on the new
    transport (the invalidation logic the removed CommConfig field used
    to complicate)."""
    from repro.parallel.ctx import ParallelCtx
    ctx = ParallelCtx(backend="posh")
    ctx2 = ctx.with_(backend="xla")
    assert ctx2.backend == "xla" and ctx2.tp_comm.backend_name == "xla"
    assert ctx.tp_comm.backend_name == "posh"   # original untouched


def test_pmean_and_layout_ops_accept_pytrees():
    """pmean/all_gather/psum_scatter/all_to_all are pytree-polymorphic
    like the lax collectives the shims replaced (pmean's division used
    to TypeError on a dict once size > 1)."""
    c = Communicator("model", size=4, backend="posh")
    tree = {"a": jnp.ones((8, 2)), "b": jnp.ones((4,))}
    # static-shape checks run per leaf, before any collective traces
    with pytest.raises(ValueError, match="not divisible"):
        c.psum_scatter({"bad": jnp.ones((6, 2))}, axis=0)
    with pytest.raises(ValueError, match="not divisible"):
        c.all_to_all({"bad": jnp.ones((6, 2))}, split_axis=0, concat_axis=0)
    c1 = Communicator("model", size=1, backend="posh")
    out = c1.pmean(tree)
    assert jax.tree.structure(out) == jax.tree.structure(tree)
    assert c1.all_gather(tree, axis=0, tiled=False)["a"].shape == (1, 8, 2)
    assert c1.psum_scatter(tree, axis=0)["b"].shape == (4,)
    assert c1.all_to_all(tree, split_axis=0, concat_axis=0)["a"].shape \
        == (8, 2)


def test_make_ctx_overrides():
    """make_ctx honours dp_size/tp_size/dp_axes/tp_axis overrides
    (used to TypeError with 'multiple values for keyword')."""
    from repro.launch.mesh import make_ctx

    class FakeMesh:
        axis_names = ("data", "model")

        class devices:
            shape = (2, 4)

    ctx = make_ctx(FakeMesh, dp_size=8, tp_axis="model")
    assert ctx.dp_size == 8 and ctx.tp_size == 4
    ctx = make_ctx(FakeMesh, dp_axes=("data",))
    assert ctx.dp_axes == ("data",)


def test_psum_pmax_accept_pytrees():
    c = make_communicator("model", size=1, backend="xla")
    tree = {"a": jnp.ones((3,)), "b": (jnp.ones((2, 2)), jnp.ones(()))}
    out = c.psum(tree)
    assert jax.tree.structure(out) == jax.tree.structure(tree)
    assert c.stats()["psum"]["calls"] == 3    # one record per leaf
    assert c.pmax(tree)["a"].shape == (3,)
    # a bare axis through as_communicator keeps the pytree polymorphism
    # the deleted free functions had (lax.psum accepts pytrees)
    from jax.sharding import PartitionSpec as P

    from repro import compat
    mesh = compat.make_mesh((1,), ("data",))
    specs = jax.tree.map(lambda _: P(), tree)
    out = compat.shard_map(
        lambda t: comm.as_communicator("data").psum(t),
        mesh=mesh, in_specs=(specs,), out_specs=specs,
        check_vma=False)(tree)
    assert jax.tree.structure(out) == jax.tree.structure(tree)


def test_heap_scratch_deterministic_across_instances():
    """Per-instance scratch counters: two heaps built the same way hand
    out identical scratch names (class-level state used to leak)."""
    from repro.core.heap import SymmetricHeap
    names = []
    for _ in range(2):
        h = SymmetricHeap(("data", "model"), capacity_bytes=1 << 20)
        with h.scratch((4, 4), jnp.float32) as s1:
            with h.scratch((2,), jnp.float32) as s2:
                names.append((s1.name, s2.name))
        assert h.fingerprint() == SymmetricHeap(
            ("data", "model"), capacity_bytes=1 << 20).fingerprint()
    assert names[0] == names[1]
