"""serve.slo + ckpt.hotswap: priority admission, inverse-priority
preemption, deadline shedding before interactive degradation,
per-tenant token-rate fairness, and the zero-downtime weight hot-swap
(post-flip streams bit-identical to a cold start on the new weights —
greedy and sampled, speculation and disaggregation composing).

The scheduler-level tests drive FCFSScheduler + SLOPolicy directly
(like test_serve's scheduler block); the engine-level tests use the
smoke model.  The real-mesh run is tests/multipe/run_slo.py."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, serve
from repro.core import SymmetricHeap
from repro.models import registry
from repro.parallel.ctx import ParallelCtx
from repro.serve import (FCFSScheduler, PagedKVCache, Request,
                         SLOConfig, SLOPolicy, ServeConfig, ServeEngine)
from repro.serve.slo import rank

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def mk_sched(n_pages=8, page_tokens=4, max_batch=4, max_seq=32,
             slo_cfg=None, **kw):
    heap = SymmetricHeap(("data",), capacity_bytes=1 << 24)
    kv = PagedKVCache(heap, n_layers=2, kv_heads=2, head_dim=4,
                      n_pages=n_pages, page_tokens=page_tokens)
    slo = SLOPolicy(slo_cfg or SLOConfig())
    return FCFSScheduler(kv, max_batch=max_batch, max_seq=max_seq,
                         slo=slo, **kw), kv, slo


# ======================================================================
# policy basics
# ======================================================================
def test_priority_rank_and_validation():
    assert rank("interactive") < rank("batch") < rank("best_effort")
    with pytest.raises(ValueError):
        rank("urgent")
    with pytest.raises(ValueError):
        SLOConfig().ttft_target("urgent")


def test_priority_admission_jumps_the_backlog():
    """An interactive arrival admits ahead of an earlier best-effort
    backlog (the anti-head-of-line property plain FCFS lacks)."""
    s, kv, _ = mk_sched(n_pages=32, max_batch=2)
    be = [Request(rid=i, prompt=[1, 2, 3], max_new=4,
                  priority="best_effort") for i in (0, 1)]
    hi = Request(rid=2, prompt=[4, 5, 6], max_new=4)
    for r in be + [hi]:
        s.submit(r)
    plan = s.tick()
    assert [r.rid for r in plan.admitted] == [2, 0]    # class, then arrival
    assert s.waiting[0].rid == 1


def test_preemption_is_inverse_priority_not_youngest():
    """Pool dry -> the BEST-EFFORT sequence evicts even though it is
    the OLDER admission; plain FCFS would have evicted the younger
    interactive one."""
    s, kv, _ = mk_sched(n_pages=6, page_tokens=2, max_batch=3,
                        max_seq=16)
    be = Request(rid=0, prompt=[1, 2, 3], max_new=6,
                 priority="best_effort")
    hi = Request(rid=1, prompt=[4, 5, 6], max_new=6)
    s.submit(be)
    s.tick()                                 # be admitted first (older)
    s.submit(hi)
    s.tick()
    assert [r.rid for r in s.running] == [0, 1]
    for r in (be, hi):
        s.note_prefilled(r, 9)
        s.advance(r, 9)                      # out: 2 tokens, next needs
    plan = s.tick()                          # a 3rd page each; 1 free
    assert [r.rid for r in plan.preempted] == [0]
    assert [r.rid for r in s.running] == [1]
    assert be.preemptions == 1 and be.out == []


def test_deadline_shed_only_best_effort_and_before_admission():
    """An expired best-effort waiter sheds (never holds pages); an
    expired interactive waiter keeps its place — lateness there is an
    attainment miss, not a drop."""
    s, kv, slo = mk_sched(n_pages=32, max_batch=4)
    be = Request(rid=0, prompt=[1, 2], max_new=2, priority="best_effort",
                 deadline=1.0, t_arrive=0.0)
    hi = Request(rid=1, prompt=[3, 4], max_new=2, deadline=1.0,
                 t_arrive=0.0)
    s.submit(be)
    s.submit(hi)
    plan = s.tick(now=5.0)
    assert plan.shed == [be] and be.shed and be.t_finish == 5.0
    assert s.stats["shed"] == 1 and slo.stats["shed"] == 1
    assert [r.rid for r in plan.admitted] == [1]
    assert "0" not in kv.tables and 0 not in kv.tables  # never paged


def test_best_effort_degrades_under_pressure():
    """While an interactive request waits (unmet higher-class demand),
    a prefilling best-effort sequence's chunk shrinks to degrade_chunk
    — and the pressure signal clears when the demand is met."""
    s, kv, slo = mk_sched(n_pages=4, page_tokens=4, max_batch=2,
                          max_seq=16, prefill_chunk=4)
    be = Request(rid=0, prompt=list(range(10)), max_new=2,
                 priority="best_effort")
    s.submit(be)
    plan = s.tick()                          # alone: no pressure
    assert plan.prefill == [(be, 4)] and not slo.pressure
    s.note_chunk(be, 4, 9)
    hi = Request(rid=1, prompt=[1, 2, 3], max_new=2)
    s.submit(hi)                             # pool is dry: hi must wait
    plan = s.tick()
    assert slo.pressure and plan.admitted == []
    assert plan.prefill == [(be, 2)]         # degraded from 4
    assert slo.stats["degraded_chunks"] == 1


def test_pressure_strips_best_effort_drafts():
    s, kv, slo = mk_sched(n_pages=32, max_batch=4, spec_k=2)
    be = Request(rid=0, prompt=[1, 2], max_new=6,
                 priority="best_effort")
    s.submit(be)
    s.tick()
    s.note_prefilled(be, 9)
    assert s.draft_allowance(be) == 2        # no pressure: full window
    s.submit(Request(rid=1, prompt=list(range(20)), max_new=8))
    slo.update_pressure(s.waiting, s.running, kv)
    assert s.draft_allowance(be) == 0        # degraded to plain decode
    assert slo.stats["degraded_drafts"] >= 1
    hi = Request(rid=2, prompt=[5, 6], max_new=6)
    s.submit(hi)
    s.tick()
    s.note_prefilled(hi, 9)
    assert s.draft_allowance(hi) > 0         # only best_effort degrades


def test_per_tenant_token_rate_fairness():
    """A tenant over its token rate defers ITS next request; the line
    behind it (another tenant) is not blocked."""
    cfg = SLOConfig(tenant_rate=20.0, tenant_burst=20.0)
    s, kv, slo = mk_sched(n_pages=32, max_batch=3, slo_cfg=cfg)
    r0 = Request(rid=0, prompt=[1] * 4, max_new=8, tenant=0)   # cost 12
    r1 = Request(rid=1, prompt=[2] * 4, max_new=8, tenant=0)
    r2 = Request(rid=2, prompt=[3] * 4, max_new=8, tenant=1)
    for r in (r0, r1, r2):
        s.submit(r)
    plan = s.tick()
    assert [r.rid for r in plan.admitted] == [0, 2]    # r1 deferred only
    assert s.stats["rate_deferred"] == 1
    assert slo.stats["rate_deferred"] == 1
    plan = s.tick()                          # bucket refilled: r1 admits
    assert [r.rid for r in plan.admitted] == [1]


def test_slo_off_is_plain_fcfs():
    """slo=None keeps the pre-SLO scheduler: admission strictly FCFS
    regardless of class labels."""
    heap = SymmetricHeap(("data",), capacity_bytes=1 << 24)
    kv = PagedKVCache(heap, n_layers=2, kv_heads=2, head_dim=4,
                      n_pages=32, page_tokens=4)
    s = FCFSScheduler(kv, max_batch=2, max_seq=32)
    s.submit(Request(rid=0, prompt=[1, 2], max_new=2,
                     priority="best_effort"))
    s.submit(Request(rid=1, prompt=[3, 4], max_new=2))
    plan = s.tick()
    assert [r.rid for r in plan.admitted] == [0, 1]


# ======================================================================
# traffic: SLO draws ride a separate stream
# ======================================================================
def test_slo_traffic_never_shifts_classic_draws():
    plain = serve.TrafficConfig(n_requests=12, seed=3)
    mixed = serve.TrafficConfig(n_requests=12, seed=3,
                                interactive_frac=0.4, batch_frac=0.3,
                                deadline_interactive=5.0,
                                deadline_best_effort=20.0, n_tenants=3)
    a, b = serve.make_requests(plain), serve.make_requests(mixed)
    for ra, rb in zip(a, b):
        assert ra.prompt == rb.prompt
        assert ra.t_arrive == rb.t_arrive and ra.max_new == rb.max_new
    # the mix actually produced multiple classes and tenants
    assert len({r.priority for r in b}) >= 2
    assert len({r.tenant for r in b}) >= 2
    assert all(r.priority == "interactive" and r.tenant == 0 for r in a)


def test_slo_traffic_is_prefix_stable():
    big = serve.TrafficConfig(n_requests=16, seed=1,
                              interactive_frac=0.5, batch_frac=0.25,
                              n_tenants=2)
    small = serve.make_requests(
        serve.TrafficConfig(n_requests=8, seed=1, interactive_frac=0.5,
                            batch_frac=0.25, n_tenants=2))
    for ra, rb in zip(small, serve.make_requests(big)):
        assert (ra.priority, ra.deadline, ra.tenant) == \
            (rb.priority, rb.deadline, rb.tenant)


# ======================================================================
# engine end-to-end under SLO traffic
# ======================================================================
@pytest.fixture(scope="module")
def smoke_model():
    cfg = configs.get_smoke("qwen3-8b")
    ctx = ParallelCtx(dp_size=1, tp_size=1, sp=False, remat=False,
                      param_dtype=jnp.float32, compute_dtype=jnp.float32)
    params = registry.build(cfg).init(jax.random.PRNGKey(0), cfg, ctx)
    return params, cfg, ctx


def test_engine_sheds_best_effort_keeps_interactive(smoke_model):
    """Overload on the tick clock: best-effort traffic sheds while
    every interactive request keeps its TTFT deadline — the property
    the bench saturation gate (scripts/check_bench.py) enforces."""
    params, cfg, ctx = smoke_model
    scfg = ServeConfig(page_tokens=4, n_pages=16, max_batch=2,
                       max_seq=32, prefill_chunk=4, attn_impl="ref",
                       slo=SLOConfig())
    eng = ServeEngine(params, cfg, ctx, scfg)
    reqs = []
    for i in range(10):
        hi = i % 2 == 0
        reqs.append(Request(
            rid=i, prompt=[(3 * i + j) % cfg.vocab for j in range(6)],
            max_new=6, t_arrive=0.0,
            priority="interactive" if hi else "best_effort",
            deadline=200.0 if hi else 4.0))
    done = eng.run(reqs, clock="tick")
    m = eng.metrics()
    assert m["slo"]["shed"]["best_effort"] > 0
    assert m["slo"]["shed"]["interactive"] == 0
    assert m["slo"]["attained"]["interactive"] == 1.0
    assert len(done) + len(eng.shed) == 10


# ======================================================================
# weight hot-swap
# ======================================================================
def _mk_reqs(rids, vocab, sampled=True):
    sp = serve.SamplingParams(temperature=0.8, top_k=5, top_p=0.9)
    out = []
    for j, rid in enumerate(rids):
        out.append(Request(
            rid=rid, prompt=[(7 * rid + k) % vocab for k in range(5)],
            max_new=6,
            sampling=sp if (sampled and j % 2) else serve.GREEDY))
    return out


def test_hot_swap_flip_is_cold_start_bit_identical(smoke_model):
    """The tentpole pin: stream generation 2 in DURING live serving,
    then serve a second trace — its streams (greedy AND sampled) must
    equal a cold-started engine on the new weights, and the swap queue
    must have paid ZERO global drains."""
    params, cfg, ctx = smoke_model
    new_params = registry.build(cfg).init(jax.random.PRNGKey(7), cfg, ctx)
    scfg = ServeConfig(page_tokens=4, n_pages=32, max_batch=3,
                       max_seq=32, attn_impl="ref")
    eng = ServeEngine(params, cfg, ctx, scfg)
    eng.begin_hot_swap(new_params, chunk_rows=2)
    eng.run(_mk_reqs(range(3), cfg.vocab), clock="tick")
    assert not eng.swap_in_flight()
    assert eng.swap_stats["flips"] == 1
    assert eng.swap_stats["generation"] == 1
    assert eng.swap_stats["swap_extra_quiets"] == 0
    assert eng.swap_stats["swap_bytes"] > 0
    # post-flip serving on the SAME engine...
    eng.run(_mk_reqs(range(10, 13), cfg.vocab), clock="tick")
    post = {r.rid: list(r.out) for r in eng.finished if r.rid >= 10}
    # ...vs a cold start on the new weights
    cold = ServeEngine(new_params, cfg, ctx, scfg)
    cold.run(_mk_reqs(range(10, 13), cfg.vocab), clock="tick")
    assert post == {r.rid: list(r.out) for r in cold.finished}
    # and the pre-flip trace really used the OLD weights
    old = ServeEngine(params, cfg, ctx, scfg)
    old.run(_mk_reqs(range(3), cfg.vocab), clock="tick")
    pre = {r.rid: list(r.out) for r in eng.finished if r.rid < 3}
    assert pre == {r.rid: list(r.out) for r in old.finished}


def test_hot_swap_overlaps_serving_ticks(smoke_model):
    """The stream really interleaves: with small batches the flip
    lands strictly AFTER the first serving tick (no stop-the-world),
    and double-starting a swap is refused."""
    params, cfg, ctx = smoke_model
    new_params = registry.build(cfg).init(jax.random.PRNGKey(8), cfg, ctx)
    scfg = ServeConfig(page_tokens=4, n_pages=32, max_batch=2,
                       max_seq=32, attn_impl="ref")
    eng = ServeEngine(params, cfg, ctx, scfg)
    eng.begin_hot_swap(new_params, chunk_rows=1, row_bytes=1 << 12)
    with pytest.raises(RuntimeError):
        eng.begin_hot_swap(new_params)
    eng.submit(Request(rid=0, prompt=[5, 17, 42], max_new=4))
    eng.tick()
    assert eng.swap_in_flight()              # still streaming after t1
    while eng.sched.has_work() or eng.swap_in_flight():
        eng.tick()
    assert eng.swap_stats["flips"] == 1
    assert eng.swap_stats["swap_ticks"] > 2  # spread over many ticks
    # a second generation can follow the first
    eng.begin_hot_swap(params, chunk_rows=64)
    while eng.swap_in_flight():
        eng.tick()
    assert eng.swap_stats["generation"] == 2


def test_hot_swap_composes_with_spec(smoke_model):
    """Flip mid-run with speculation on: post-flip spec streams equal
    a cold-start SPEC engine on the new weights (lossless twice over)."""
    params, cfg, ctx = smoke_model
    new_params = registry.build(cfg).init(jax.random.PRNGKey(9), cfg, ctx)
    scfg = ServeConfig(page_tokens=4, n_pages=48, max_batch=3,
                       max_seq=48, spec_k=2, attn_impl="ref")

    def reqs():
        return [Request(rid=i, prompt=[5, 17, 42] * 3, max_new=8)
                for i in (0, 1)]

    eng = ServeEngine(params, cfg, ctx, scfg)
    eng.begin_hot_swap(new_params, chunk_rows=4)
    eng.run(reqs(), clock="tick")
    assert eng.swap_stats["flips"] == 1
    eng.run([Request(rid=5, prompt=[5, 17, 42] * 3, max_new=8)],
            clock="tick")
    post = {r.rid: list(r.out) for r in eng.finished if r.rid == 5}
    cold = ServeEngine(new_params, cfg, ctx, scfg)
    cold.run([Request(rid=5, prompt=[5, 17, 42] * 3, max_new=8)],
             clock="tick")
    assert post == {r.rid: list(r.out) for r in cold.finished}
    assert cold.spec_stats["drafted"] > 0


def test_hot_swap_composes_with_disagg(smoke_model):
    """One streamer spans the cell space: every cell flips on the same
    topology tick, handoff and swap queues both stay barrier-free, and
    post-flip streams equal a cold colocated engine on new weights."""
    params, cfg, ctx = smoke_model
    new_params = registry.build(cfg).init(jax.random.PRNGKey(11), cfg,
                                          ctx)
    scfg = ServeConfig(page_tokens=4, n_pages=24, max_batch=3,
                       max_seq=32, prefill_chunk=4, attn_impl="ref")
    dis = serve.DisaggEngine(params, cfg, ctx, scfg, n_prefill=1,
                             n_decode=1)
    dis.begin_hot_swap(new_params, chunk_rows=2)
    dis.run(_mk_reqs(range(3), cfg.vocab, sampled=False), clock="tick")
    assert dis.swap_stats["flips"] == 1
    assert dis.swap_stats["swap_extra_quiets"] == 0
    assert dis.stats()["handoff_quiets"] == 0
    dis.run(_mk_reqs(range(10, 12), cfg.vocab, sampled=False),
            clock="tick")
    post = {r.rid: list(r.out) for r in dis.finished if r.rid >= 10}
    cold = ServeEngine(new_params, cfg, ctx, scfg)
    cold.run(_mk_reqs(range(10, 12), cfg.vocab, sampled=False),
             clock="tick")
    assert post == {r.rid: list(r.out) for r in cold.finished}
    assert "swap" in dis.metrics()


def test_swap_metrics_reset_keeps_generation(smoke_model):
    params, cfg, ctx = smoke_model
    scfg = ServeConfig(page_tokens=4, n_pages=16, max_batch=2,
                       max_seq=32, attn_impl="ref")
    eng = ServeEngine(params, cfg, ctx, scfg)
    eng.begin_hot_swap(params, chunk_rows=64)
    while eng.swap_in_flight():
        eng.tick()
    eng.reset_metrics()
    assert eng.swap_stats["flips"] == 0
    assert eng.swap_stats["generation"] == 1     # monotone across resets
    assert eng.metrics()["slo"]["attained"]["interactive"] == 1.0


# ======================================================================
# the 8-PE mesh suite (subprocess, like the other multipe workers)
# ======================================================================
def test_slo_mesh_8pe():
    if os.environ.get("REPRO_MULTIPE_EXPLICIT"):
        pytest.skip("multipe workers run explicitly (scripts/verify.sh)")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable,
         os.path.join(ROOT, "tests", "multipe", "run_slo.py")],
        capture_output=True, text=True, env=env, timeout=2400)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "SLO_PASS" in r.stdout
