"""Pallas kernels vs pure-jnp oracles (interpret mode), with
shape/dtype sweeps per the deliverable spec."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis only powers the property-based sweep below; the directed
# corpus must still run (tier-1) when it isn't installed
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:        # pragma: no cover - env-dependent
    HAVE_HYPOTHESIS = False

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("variant", ops.COPY_VARIANTS)
@pytest.mark.parametrize("shape", [(17,), (300, 7), (1024, 129), (5, 3, 11)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_symm_copy(variant, shape, dtype):
    n = int(np.prod(shape))
    if dtype == jnp.int32:
        x = jnp.arange(n, dtype=dtype).reshape(shape)
    else:
        x = jax.random.normal(KEY, shape).astype(dtype)
    y = ops.symm_copy(x, variant)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(ref.copy_ref(x)))


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(n=st.integers(1, 5000),
           variant=st.sampled_from(list(ops.COPY_VARIANTS)))
    def test_symm_copy_property(n, variant):
        x = jnp.arange(n, dtype=jnp.float32) * 0.5 - 100.0
        y = ops.symm_copy(x, variant)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


@pytest.mark.parametrize("op", ["sum", "max", "min", "prod"])
@pytest.mark.parametrize("variant", ops.COMBINE_VARIANTS)
def test_combine(op, variant):
    a = jax.random.normal(KEY, (333, 5))
    b = jax.random.normal(jax.random.PRNGKey(1), (333, 5))
    y = ops.combine(a, b, op, variant)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(ref.combine_ref(a, b, op)),
                               rtol=1e-6)


def test_combine_shape_mismatch():
    with pytest.raises(ValueError):
        ops.combine(jnp.zeros((4,)), jnp.zeros((5,)))


@pytest.mark.parametrize(
    "b,h,hkv,t,s,d,causal,window",
    [(2, 4, 2, 128, 128, 64, True, None),
     (1, 8, 1, 100, 100, 32, True, None),     # MQA, ragged seq
     (2, 4, 4, 128, 128, 64, False, None),
     (1, 4, 2, 256, 256, 64, True, 96),       # sliding window
     (1, 2, 2, 64, 64, 128, True, None)])
def test_flash_attention_kernel(b, h, hkv, t, s, d, causal, window):
    q = jax.random.normal(KEY, (b, h, t, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(2), (b, hkv, s, d), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(3), (b, hkv, s, d), jnp.float32)
    y = ops.attention(q, k, v, causal=causal, window=window,
                      block_q=64, block_kv=64)
    yr = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    q = jax.random.normal(KEY, (1, 4, 64, 32)).astype(dtype)
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 64, 32)).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(3), (1, 2, 64, 32)).astype(dtype)
    y = ops.attention(q, k, v, block_q=32, block_kv=32)
    yr = ref.attention_ref(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               rtol=tol, atol=tol)


def test_model_flash_vs_ref_with_grads():
    """The jnp blocked attention (model-side) — fwd and custom-VJP bwd."""
    from repro.models.flash import blocked_attention
    b, h, hkv, t, d = 1, 4, 2, 96, 32
    q = jax.random.normal(KEY, (b, t, h, d))
    k = jax.random.normal(jax.random.PRNGKey(2), (b, t, hkv, d))
    v = jax.random.normal(jax.random.PRNGKey(3), (b, t, hkv, d))

    def f_blocked(q, k, v):
        return (blocked_attention(q, k, v, causal=True, block_q=32,
                                  block_kv=32) ** 2).sum()

    def f_ref(q, k, v):
        r = ref.attention_ref(jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2),
                              jnp.moveaxis(v, 1, 2), causal=True)
        return (jnp.moveaxis(r, 1, 2) ** 2).sum()

    g1 = jax.grad(f_blocked, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=5e-4, atol=5e-4)


# ======================================================================
# prefill-window kernel vs its jnp oracle (directed parity corpus)
# ======================================================================
from repro.kernels import paged_attention as pa  # noqa: E402


def _window_case(seed, B, C, H, Hkv, D, P, slots, dtype=jnp.float32,
                 start=None, n_tok=None):
    """A random paged window: every sequence gets its own live pages
    (null-padded table past them), `start` placed so the window fits
    inside the paged span."""
    rng = np.random.RandomState(seed)
    n_pages = B * slots + 1
    q = jnp.asarray(rng.randn(B, C, H, D)).astype(dtype)
    kp = jnp.asarray(rng.randn(n_pages, P, Hkv, D)).astype(dtype)
    vp = jnp.asarray(rng.randn(n_pages, P, Hkv, D)).astype(dtype)
    bt = jnp.asarray(
        rng.permutation(np.arange(1, n_pages)).reshape(B, slots)
        .astype(np.int32))
    if start is None:
        start = rng.randint(0, max(P * slots - C, 0) + 1, B)
    if n_tok is None:
        n_tok = rng.randint(0, C + 1, B)
    return (q, kp, vp, bt, jnp.asarray(start, jnp.int32),
            jnp.asarray(n_tok, jnp.int32))


def _assert_window_parity(case, dtype=jnp.float32, block_q=None,
                          msg=""):
    q, kp, vp, bt, start, n_tok = case
    out = pa.paged_prefill_attention(q, kp, vp, bt, start, n_tok,
                                     block_q=block_q, interpret=True)
    ref_out = pa.paged_prefill_attention_ref(q, kp, vp, bt, start, n_tok)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref_out, np.float32),
                               atol=tol, rtol=tol, err_msg=msg)
    # padded rows (j >= n_tok) are exactly zero, both impls
    mask = np.arange(q.shape[1])[None] >= np.asarray(n_tok)[:, None]
    assert np.all(np.asarray(out)[mask] == 0.0), msg
    return out


def test_prefill_window_kernel_midpage_starts():
    """Windows whose start sits mid-page (resumed chunked prefill):
    the causal frontier crosses a page interior, not a boundary."""
    for seed, start in ((10, [1, 5, 3]), (11, [7, 2, 6])):
        case = _window_case(seed, B=3, C=8, H=4, Hkv=2, D=16, P=8,
                            slots=3, start=start, n_tok=[8, 8, 5])
        _assert_window_parity(case, msg=f"seed={seed} start={start}")


def test_prefill_window_kernel_full_final_page():
    """Windows that END exactly on a page boundary — the final page
    completely full, no partial-page mask on the last kv block."""
    case = _window_case(20, B=2, C=8, H=4, Hkv=2, D=16, P=4, slots=4,
                        start=[0, 8], n_tok=[8, 8])   # ends at 8 and 16
    _assert_window_parity(case, msg="full final page")


def test_prefill_window_kernel_padded_and_inactive_rows():
    """Right-padded short chunks and fully-inactive (n_tok=0) slots:
    padded rows exact zero, live rows still match the oracle."""
    case = _window_case(30, B=4, C=8, H=4, Hkv=2, D=16, P=8, slots=2,
                        start=[0, 3, 5, 0], n_tok=[8, 4, 1, 0])
    _assert_window_parity(case, msg="padded rows")


def test_prefill_window_kernel_verify_shape():
    """The speculative-verify window: (B, spec_k+1) tiny windows at
    deep, unaligned positions — the shape make_verify hands the op."""
    for spec_k in (1, 3):
        case = _window_case(40 + spec_k, B=3, C=spec_k + 1, H=4, Hkv=1,
                            D=16, P=8, slots=4,
                            start=[13, 26, 7],
                            n_tok=[spec_k + 1] * 3)
        _assert_window_parity(case, msg=f"spec_k={spec_k}")


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_prefill_window_kernel_dtypes(dtype):
    case = _window_case(50, B=2, C=16, H=8, Hkv=2, D=16, P=4, slots=8,
                        dtype=dtype)
    _assert_window_parity(case, dtype=dtype, msg=str(dtype))


def test_prefill_window_kernel_block_not_dividing_window():
    """block_q that doesn't divide the window (C=7 with block 8, C=13
    with block 8): the padded q rows must not leak into the output."""
    for C, bq in ((7, 8), (13, 8), (5, 16)):
        case = _window_case(60 + C, B=2, C=C, H=4, Hkv=2, D=16, P=8,
                            slots=4)
        _assert_window_parity(case, block_q=bq, msg=f"C={C} bq={bq}")


def test_prefill_window_kernel_gqa_mqa_groups():
    for H, Hkv in ((4, 1), (6, 2), (4, 4)):
        case = _window_case(70 + H * 10 + Hkv, B=2, C=8, H=H, Hkv=Hkv,
                            D=16, P=8, slots=3)
        _assert_window_parity(case, msg=f"H={H} Hkv={Hkv}")


def test_prefill_window_choose_block_dispatch():
    """The §4.5.4 size/dtype ladder: sublane-aligned, never wider than
    the padded window, monotone in window length."""
    for w in (1, 3, 8, 16, 64, 256, 1024):
        blk = pa.choose_block(w, jnp.float32)
        assert blk % 8 == 0
        assert blk <= -(-w // 8) * 8
    assert pa.choose_block(4, jnp.float32) == 8      # verify window
    assert pa.choose_block(64, jnp.float32) == 16
    assert pa.choose_block(1024, jnp.float32) == 64
    assert pa.choose_block(3, jnp.bfloat16) == 16    # bf16 sublane 16
    # ladder choices all agree with the ref on a real case
    for bq in (8, 16, 32):
        case = _window_case(80, B=2, C=32, H=4, Hkv=2, D=16, P=8,
                            slots=4)
        _assert_window_parity(case, block_q=bq, msg=f"ladder bq={bq}")


def test_prefill_window_unknown_impl_raises():
    case = _window_case(90, B=1, C=4, H=4, Hkv=2, D=16, P=8, slots=2)
    q, kp, vp, bt, start, n_tok = case
    with pytest.raises(ValueError, match="paged_prefill_attention"):
        ops.paged_prefill_attention(q, kp, vp, bt, start, n_tok,
                                    impl="nope")
    with pytest.raises(ValueError, match="paged_attention"):
        ops.paged_attention(q[:, 0], kp, vp, bt,
                            jnp.asarray([1], jnp.int32), impl="nope")
    assert "kernel" in ops.PAGED_PREFILL_IMPLS
    assert "ref" in ops.PAGED_PREFILL_IMPLS


def test_prefill_window_ops_kernel_route():
    """ops.paged_prefill_attention(impl='kernel') actually reaches the
    grid kernel and matches the ref route at 1e-5."""
    case = _window_case(91, B=3, C=8, H=4, Hkv=2, D=16, P=8, slots=3)
    q, kp, vp, bt, start, n_tok = case
    k_out = ops.paged_prefill_attention(q, kp, vp, bt, start, n_tok,
                                        impl="kernel")
    r_out = ops.paged_prefill_attention(q, kp, vp, bt, start, n_tok,
                                        impl="ref")
    np.testing.assert_allclose(np.asarray(k_out), np.asarray(r_out),
                               atol=1e-5, rtol=1e-5)
