"""Pallas kernels vs pure-jnp oracles (interpret mode), with
shape/dtype sweeps per the deliverable spec."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("variant", ops.COPY_VARIANTS)
@pytest.mark.parametrize("shape", [(17,), (300, 7), (1024, 129), (5, 3, 11)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_symm_copy(variant, shape, dtype):
    n = int(np.prod(shape))
    if dtype == jnp.int32:
        x = jnp.arange(n, dtype=dtype).reshape(shape)
    else:
        x = jax.random.normal(KEY, shape).astype(dtype)
    y = ops.symm_copy(x, variant)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(ref.copy_ref(x)))


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 5000),
       variant=st.sampled_from(list(ops.COPY_VARIANTS)))
def test_symm_copy_property(n, variant):
    x = jnp.arange(n, dtype=jnp.float32) * 0.5 - 100.0
    y = ops.symm_copy(x, variant)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


@pytest.mark.parametrize("op", ["sum", "max", "min", "prod"])
@pytest.mark.parametrize("variant", ops.COMBINE_VARIANTS)
def test_combine(op, variant):
    a = jax.random.normal(KEY, (333, 5))
    b = jax.random.normal(jax.random.PRNGKey(1), (333, 5))
    y = ops.combine(a, b, op, variant)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(ref.combine_ref(a, b, op)),
                               rtol=1e-6)


def test_combine_shape_mismatch():
    with pytest.raises(ValueError):
        ops.combine(jnp.zeros((4,)), jnp.zeros((5,)))


@pytest.mark.parametrize(
    "b,h,hkv,t,s,d,causal,window",
    [(2, 4, 2, 128, 128, 64, True, None),
     (1, 8, 1, 100, 100, 32, True, None),     # MQA, ragged seq
     (2, 4, 4, 128, 128, 64, False, None),
     (1, 4, 2, 256, 256, 64, True, 96),       # sliding window
     (1, 2, 2, 64, 64, 128, True, None)])
def test_flash_attention_kernel(b, h, hkv, t, s, d, causal, window):
    q = jax.random.normal(KEY, (b, h, t, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(2), (b, hkv, s, d), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(3), (b, hkv, s, d), jnp.float32)
    y = ops.attention(q, k, v, causal=causal, window=window,
                      block_q=64, block_kv=64)
    yr = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    q = jax.random.normal(KEY, (1, 4, 64, 32)).astype(dtype)
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 64, 32)).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(3), (1, 2, 64, 32)).astype(dtype)
    y = ops.attention(q, k, v, block_q=32, block_kv=32)
    yr = ref.attention_ref(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               rtol=tol, atol=tol)


def test_model_flash_vs_ref_with_grads():
    """The jnp blocked attention (model-side) — fwd and custom-VJP bwd."""
    from repro.models.flash import blocked_attention
    b, h, hkv, t, d = 1, 4, 2, 96, 32
    q = jax.random.normal(KEY, (b, t, h, d))
    k = jax.random.normal(jax.random.PRNGKey(2), (b, t, hkv, d))
    v = jax.random.normal(jax.random.PRNGKey(3), (b, t, hkv, d))

    def f_blocked(q, k, v):
        return (blocked_attention(q, k, v, causal=True, block_q=32,
                                  block_kv=32) ** 2).sum()

    def f_ref(q, k, v):
        r = ref.attention_ref(jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2),
                              jnp.moveaxis(v, 1, 2), causal=True)
        return (jnp.moveaxis(r, 1, 2) ** 2).sum()

    g1 = jax.grad(f_blocked, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=5e-4, atol=5e-4)
