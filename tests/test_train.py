"""Training substrate: loss decreases, microbatching is exact, ZeRO-1
matches ZeRO-0, comm transforms are lossless."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import comm, compat, configs
from repro.data import SyntheticLM
from repro.models import registry
from repro.parallel.ctx import ParallelCtx, smap
from repro.train.optimizer import AdamWConfig
from repro.train.step import make_train_step, train_state_specs

CTX = ParallelCtx(dp_size=1, tp_size=1, sp=False, remat=True,
                  param_dtype=jnp.float32, compute_dtype=jnp.float32)


def _mesh():
    return compat.make_mesh((1, 1), ("data", "model"))


def _setup(arch="qwen3-8b", zero=0, microbatches=1):
    cfg = configs.get_smoke(arch)
    api = registry.build(cfg)
    opt = AdamWConfig(lr=5e-3, zero=zero)
    params = api.init(jax.random.PRNGKey(0), cfg, CTX)
    from repro.train.optimizer import adamw_init
    mesh = _mesh()
    state = {"params": params,
             "opt": smap(
                 lambda p: adamw_init(p, CTX, opt), mesh,
                 (api.specs(cfg, CTX),),
                 train_state_specs(cfg, CTX, api, opt)["opt"])(params),
             "step": jnp.zeros((), jnp.int32)}
    step = make_train_step(cfg, CTX, api, opt, microbatches=microbatches)
    sspecs = train_state_specs(cfg, CTX, api, opt)
    fn = jax.jit(smap(step, mesh,
                      (sspecs, {"tokens": P("data")}),
                      (sspecs, {"loss": P(), "grad_norm": P(),
                                "step": P()})))
    data = SyntheticLM(vocab=cfg.vocab, seq_len=cfg.max_seq, global_batch=8)
    return cfg, fn, state, data


def test_loss_decreases():
    cfg, fn, state, data = _setup()
    losses = []
    for s in range(40):
        state, m = fn(state, data.batch(s))
        losses.append(float(m["loss"]))
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    assert last < first - 0.15, f"no learning: {first:.3f} -> {last:.3f}"
    assert np.isfinite(losses).all()


def test_microbatch_equivalence():
    """grad accumulation over 4 microbatches == single batch step."""
    cfg, fn1, state1, data = _setup(microbatches=1)
    _, fn4, state4, _ = _setup(microbatches=4)
    b = data.batch(0)
    s1, m1 = fn1(state1, b)
    s4, m4 = fn4(state4, b)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5)
    for a, c in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s4["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=2e-4, atol=2e-5)


def test_zero1_matches_zero0_single_device():
    cfg, fn0, state0, data = _setup(zero=0)
    _, fn1, state1, _ = _setup(zero=1)
    for s in range(3):
        b = data.batch(s)
        state0, m0 = fn0(state0, b)
        state1, m1 = fn1(state1, b)
        np.testing.assert_allclose(float(m0["loss"]), float(m1["loss"]),
                                   rtol=1e-5)
    for a, c in zip(jax.tree.leaves(state0["params"]),
                    jax.tree.leaves(state1["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=2e-5, atol=2e-6)


def test_bucketed_allreduce_identity_on_1dev():
    tree = {"a": jnp.arange(100.0), "b": jnp.ones((7, 3)),
            "c": jnp.arange(5, dtype=jnp.int32)}
    mesh = _mesh()

    def run(t):
        return comm.bucketed_allreduce(t, "data", bucket_bytes=128)

    out = compat.shard_map(run, mesh=mesh,
                           in_specs=(jax.tree.map(lambda _: P(), tree),),
                           out_specs=jax.tree.map(lambda _: P(), tree),
                           check_vma=False)(tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_compression_bf16_and_ef():
    g = {"w": jnp.linspace(-1, 1, 1000, dtype=jnp.float32)}
    mesh = _mesh()

    def run(t):
        out, st = comm.compressed_allreduce(t, "data", scheme="bf16",
                                            mean=True)
        return out

    out = compat.shard_map(run, mesh=mesh, in_specs=(
        {"w": P()},), out_specs={"w": P()}, check_vma=False)(g)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]),
                               atol=4e-3)
    # error feedback reduces the *accumulated* bias over steps
    st = comm.CompressionState.init(g, enabled=True)
    acc_ef = jnp.zeros_like(g["w"])
    acc_raw = jnp.zeros_like(g["w"])

    def run_ef(t, res):
        st = comm.CompressionState(residual=res)
        out, st2 = comm.compressed_allreduce(t, "data", scheme="bf16",
                                             state=st, mean=True)
        return out, st2.residual

    f = compat.shard_map(run_ef, mesh=mesh,
                         in_specs=({"w": P()}, {"w": P()}),
                         out_specs=({"w": P()}, {"w": P()}), check_vma=False)
    res = st.residual
    for _ in range(20):
        out, res = f(g, res)
        acc_ef = acc_ef + out["w"]
    del acc_raw
    # with error feedback the accumulated bias vanishes: the mean of 20
    # compressed steps matches the true gradient far below bf16 eps
    np.testing.assert_allclose(np.asarray(acc_ef) / 20,
                               np.asarray(g["w"]), atol=1e-4)
