"""repro.serve: paged KV cache allocator, FCFS scheduler, paged
attention parity, end-to-end engine vs the contiguous decode path, and
put_nbi/quiet page migration (LocalTransport oracle; the real-mesh run
is tests/multipe/run_serve.py)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, serve
from repro.core import CommQueue, LocalTransport, SymmetricHeap
from repro.kernels import ops
from repro.kernels.paged_attention import (paged_decode_attention,
                                           paged_decode_attention_ref)
from repro.models import registry
from repro.parallel.ctx import ParallelCtx
from repro.serve import (NULL_PAGE, FCFSScheduler, PagedKVCache,
                         PageMigration, Request, ServeConfig, ServeEngine)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def make_kv(n_pages=8, page_tokens=4, n_layers=2, kv_heads=2, head_dim=4,
            heap=None):
    heap = heap or SymmetricHeap(("data",), capacity_bytes=1 << 24)
    return PagedKVCache(heap, n_layers=n_layers, kv_heads=kv_heads,
                        head_dim=head_dim, n_pages=n_pages,
                        page_tokens=page_tokens)


# ======================================================================
# allocator
# ======================================================================
def test_kv_pool_is_symmetric_heap_object():
    heap = SymmetricHeap(("data",), capacity_bytes=1 << 24)
    kv = make_kv(heap=heap)
    assert kv.handle.name in heap.registry
    assert heap.registry["kv_pages"].shape == (8, 2, 2, 4, 2, 4)
    # page id -> pool row: the symmetric address of page p is the pool
    # offset + p rows (Corollary 1 at page granularity)
    got, off = heap.resolve(kv.handle.offset)
    assert got.name == "kv_pages" and off == 0


def test_page_alloc_free_reuse():
    kv = make_kv(n_pages=6, page_tokens=4)     # 5 usable pages
    assert kv.n_free() == 5
    assert kv.alloc_seq("a", 6)                # 2 pages
    assert kv.alloc_seq("b", 9)                # 3 pages
    assert kv.n_free() == 0
    assert not kv.alloc_seq("c", 1)            # pool dry -> refused whole
    assert "c" not in kv.tables
    pages_a = list(kv.tables["a"])
    kv.free_seq("a")
    assert kv.n_free() == 2
    assert kv.alloc_seq("d", 5)                # 2 pages, LIFO reuse
    assert set(kv.tables["d"]) == set(pages_a)
    with pytest.raises(ValueError):
        kv.alloc_seq("b", 1)                   # double alloc


def test_ensure_grows_by_page():
    kv = make_kv(n_pages=4, page_tokens=4)     # 3 usable
    assert kv.alloc_seq("a", 3)                # 1 page covers 3 tokens
    assert len(kv.tables["a"]) == 1
    assert kv.ensure("a", 4)                   # still page 1
    assert len(kv.tables["a"]) == 1
    assert kv.ensure("a", 5)                   # boundary -> page 2
    assert len(kv.tables["a"]) == 2
    assert kv.ensure("a", 12)
    assert len(kv.tables["a"]) == 3
    assert not kv.ensure("a", 13)              # pool dry


def test_block_table_padding_and_null_page():
    kv = make_kv(n_pages=8, page_tokens=4)
    kv.alloc_seq("a", 7)
    bt = kv.block_table(["a", None], n_slots=4)
    assert bt.shape == (2, 4) and bt.dtype == np.int32
    assert list(bt[0][:2]) == kv.tables["a"]
    assert (bt[0][2:] == NULL_PAGE).all()
    assert (bt[1] == NULL_PAGE).all()
    assert NULL_PAGE not in kv.tables["a"]     # page 0 never handed out


def test_truncate_rewinds_across_page_boundary():
    """Speculative rewind: shrinking 10 -> 5 tokens over 4-token pages
    frees exactly the fully-rejected page(s); the partial final page
    stays; freed pages are immediately reusable (LIFO)."""
    kv = make_kv(n_pages=8, page_tokens=4)
    assert kv.alloc_seq("a", 10)               # 3 pages
    pages = list(kv.tables["a"])
    freed = kv.truncate("a", 5)                # 2 pages cover 5 tokens
    assert freed == 1
    assert kv.tables["a"] == pages[:2]
    assert kv.stats["rewound_pages"] == 1
    assert kv.n_free() == 7 - 2
    assert kv.alloc_seq("b", 1)
    assert kv.tables["b"] == [pages[2]]        # LIFO reuse of the freed page
    # exact page multiple: nothing to free
    assert kv.truncate("a", 8) == 0
    assert kv.tables["a"] == pages[:2]


def test_truncate_to_zero_frees_all_pages():
    kv = make_kv(n_pages=8, page_tokens=4)
    assert kv.alloc_seq("a", 9)                # 3 pages
    assert kv.truncate("a", 0) == 3
    assert kv.tables["a"] == []                # attached, but empty
    assert kv.n_free() == 7
    bt = kv.block_table(["a"], n_slots=3)
    assert (bt == NULL_PAGE).all()             # all-null row
    kv.free_seq("a")                           # still detachable
    assert kv.n_free() == 7


def test_truncate_never_touches_null_page():
    """The null page is never in a table, so no rewind can free it —
    even a rewind-to-zero across every sequence."""
    kv = make_kv(n_pages=6, page_tokens=4)
    kv.alloc_seq("a", 8)
    kv.alloc_seq("b", 12)
    for sid in ("a", "b"):
        kv.truncate(sid, 0)
    assert NULL_PAGE not in kv._free
    assert kv.n_free() == 5                    # pages 1..5 back, page 0 out
    kv.alloc_seq("c", 20)                      # reuse everything
    assert NULL_PAGE not in kv.tables["c"]


def test_pool_grow_via_realloc_preserves_pages():
    heap = SymmetricHeap(("data",), capacity_bytes=1 << 24)
    kv = make_kv(n_pages=4, heap=heap)
    pool = kv.zeros().at[1].set(7.0)
    pool = kv.grow(4, pool)
    assert kv.n_pages == 8 and pool.shape[0] == 8
    assert heap.registry["kv_pages"].shape[0] == 8
    np.testing.assert_allclose(np.asarray(pool[1]), 7.0)  # contents kept
    np.testing.assert_allclose(np.asarray(pool[5]), 0.0)
    assert kv.n_free() == 3 + 4


# ======================================================================
# scheduler
# ======================================================================
def mk_sched(n_pages=8, page_tokens=4, max_batch=4, max_seq=32, **kw):
    kv = make_kv(n_pages=n_pages, page_tokens=page_tokens)
    return FCFSScheduler(kv, max_batch=max_batch, max_seq=max_seq,
                         **kw), kv


def test_fcfs_admission_order_and_batch_cap():
    s, kv = mk_sched(n_pages=16, max_batch=2)
    reqs = [Request(rid=i, prompt=[1, 2, 3], max_new=4) for i in range(4)]
    for r in reqs:
        s.submit(r)
    plan = s.tick()
    assert [r.rid for r in plan.admitted] == [0, 1]    # FCFS, capped
    assert [r.rid for r in s.running] == [0, 1]
    s.finish(reqs[0])
    plan = s.tick()
    assert [r.rid for r in plan.admitted] == [2]       # next in line


def test_admission_blocks_on_pages_not_slots():
    s, kv = mk_sched(n_pages=4, page_tokens=4, max_batch=4)  # 3 usable
    s.submit(Request(rid=0, prompt=list(range(10)), max_new=2))  # 3 pages
    s.submit(Request(rid=1, prompt=[1], max_new=1))
    plan = s.tick()
    assert [r.rid for r in plan.admitted] == [0]
    assert s.waiting[0].rid == 1                       # blocked, waiting


def test_preempt_youngest_and_requeue_at_head():
    s, kv = mk_sched(n_pages=6, page_tokens=2, max_batch=3, max_seq=16)
    r0 = Request(rid=0, prompt=[1, 2, 3], max_new=6)   # 2 pages
    r1 = Request(rid=1, prompt=[4, 5, 6], max_new=6)   # 2 pages
    for r in (r0, r1):
        s.submit(r)
    s.tick()
    assert len(s.running) == 2 and kv.n_free() == 1
    # drive r0/r1 forward until a page is needed and the pool is dry
    s.note_prefilled(r0, 9)
    s.note_prefilled(r1, 9)
    s.advance(r0, 9)                                   # out: 2 tokens
    s.advance(r1, 9)
    plan = s.tick()   # r0 takes the last page; r1 (youngest) evicted
    assert [r.rid for r in plan.preempted] == [1]
    assert r1.out == [] and r1.n_done == 0             # progress reset
    assert s.waiting[0].rid == 1                       # head of the line
    assert r1.preemptions == 1
    assert [r.rid for r in s.running] == [0]


def test_no_spurious_preemption_on_final_token():
    """Page demand is exact: a sequence writing its last token at a
    page boundary must not evict a neighbour for a page it will never
    write."""
    s, kv = mk_sched(n_pages=5, page_tokens=2, max_batch=2, max_seq=16)
    r0 = Request(rid=0, prompt=[1, 2], max_new=3)
    r1 = Request(rid=1, prompt=[3, 4], max_new=3)
    for r in (r0, r1):
        s.submit(r)
    s.tick()
    assert len(s.running) == 2 and kv.n_free() == 0   # pool exactly full
    for r in (r0, r1):
        s.note_prefilled(r, 9)
    for _ in range(2):                # tokens 2 and 3: positions 2, 3
        plan = s.tick()
        assert plan.preempted == [], "evicted for an unwritten page"
        for r in (r0, r1):
            s.advance(r, 9)
    assert r0.finished() and r1.finished()


def test_tick_token_budget_chunk_cap_and_fcfs_split():
    """Fresh prompts split the tick budget FCFS, each capped at
    prefill_chunk."""
    s, kv = mk_sched(n_pages=32, page_tokens=4, max_batch=4, max_seq=64,
                     prefill_chunk=4, tick_tokens=6)
    s.submit(Request(rid=0, prompt=list(range(20)), max_new=2))
    s.submit(Request(rid=1, prompt=list(range(100, 120)), max_new=2))
    plan = s.tick()
    # 6 tokens: rid 0 gets a full chunk (4), rid 1 the remaining 2
    assert [(r.rid, n) for r, n in plan.prefill] == [(0, 4), (1, 2)]


def test_tick_token_budget_decode_claims_first():
    """Decoding sequences claim their token before any prefill chunk
    is granted — a long prompt can never starve running decodes — and
    the oldest prefilling sequence always makes >= 1 token progress."""
    s, kv = mk_sched(n_pages=32, page_tokens=4, max_batch=4, max_seq=64,
                     prefill_chunk=4, tick_tokens=5)
    shorts = [Request(rid=i, prompt=[i, i + 1], max_new=4)
              for i in (1, 2, 3)]
    for r in shorts:
        s.submit(r)
    plan = s.tick()                 # budget 5 over three 2-token prompts
    assert [(r.rid, n) for r, n in plan.prefill] == [(1, 2), (2, 2),
                                                     (3, 1)]
    for req, n in plan.prefill:
        s.note_chunk(req, n, 42)
    assert not shorts[0].is_prefilling() and not shorts[1].is_prefilling()
    assert shorts[2].is_prefilling()            # 1 of 2 tokens done
    long = Request(rid=9, prompt=list(range(20)), max_new=2)
    s.submit(long)
    plan = s.tick()
    # 2 decoding seqs claim 2 of the 5; rid 3 finishes its prompt (1),
    # the long newcomer gets what is left (2) — not a full chunk
    assert [(r.rid, n) for r, n in plan.prefill] == [(3, 1), (9, 2)]
    # starved budget: decode eats everything, yet the oldest prefilling
    # sequence is still guaranteed one token per tick
    for req, n in plan.prefill:
        s.note_chunk(req, n, 42)
    s.tick_tokens = 2
    plan = s.tick()
    assert [(r.rid, n) for r, n in plan.prefill] == [(9, 1)]


def test_chunked_prefill_tracks_chunks_and_budget():
    s, kv = mk_sched(n_pages=32, page_tokens=4, max_batch=2, max_seq=64,
                     prefill_chunk=3, tick_tokens=8)
    r = Request(rid=0, prompt=list(range(8)), max_new=2)
    s.submit(r)
    while r.is_prefilling():
        plan = s.tick()
        for req, n in plan.prefill:
            s.note_chunk(req, n, 42)
    assert r.prefill_chunks == [3, 3, 2]
    assert r.out == [42] and r.t_first is not None
    assert s.stats["prefill_tokens"] == 8


def test_preempted_request_eventually_completes():
    cfg = configs.get_smoke("qwen3-8b")
    ctx = ParallelCtx(dp_size=1, tp_size=1, sp=False, remat=False,
                      param_dtype=jnp.float32, compute_dtype=jnp.float32)
    api = registry.build(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg, ctx)
    tight = ServeConfig(page_tokens=4, n_pages=8, max_batch=3,
                        max_seq=32, max_prompt=16, attn_impl="ref")
    roomy = ServeConfig(page_tokens=4, n_pages=32, max_batch=3,
                        max_seq=32, max_prompt=16, attn_impl="ref")
    streams = {}
    for tag, scfg in (("tight", tight), ("roomy", roomy)):
        eng = ServeEngine(params, cfg, ctx, scfg)
        reqs = [Request(rid=i, prompt=list(range(2 + i, 10 + i)),
                        max_new=8) for i in range(3)]
        done = eng.run(reqs, clock="tick")
        assert len(done) == 3
        streams[tag] = {r.rid: r.out for r in done}
        if tag == "tight":
            assert eng.sched.stats["preempted"] > 0
    # eviction + re-prefill must not change any token stream
    assert streams["tight"] == streams["roomy"]


# ======================================================================
# paged attention parity (the tier-1 acceptance bar)
# ======================================================================
def _paged_case(seed=0, B=3, H=4, Hkv=2, D=16, P=4, n_pages=10, slots=3):
    rng = np.random.RandomState(seed)
    q = jnp.asarray(rng.randn(B, H, D).astype(np.float32))
    kp = jnp.asarray(rng.randn(n_pages, P, Hkv, D).astype(np.float32))
    vp = jnp.asarray(rng.randn(n_pages, P, Hkv, D).astype(np.float32))
    bt = jnp.asarray(rng.permutation(np.arange(1, 10))
                     .reshape(B, slots).astype(np.int32))
    lens = jnp.asarray(np.array([P * slots, 5, 0], np.int32))
    return q, kp, vp, bt, lens


def test_paged_attention_kernel_matches_ref():
    q, kp, vp, bt, lens = _paged_case()
    ref = paged_decode_attention_ref(q, kp, vp, bt, lens)
    ker = paged_decode_attention(q, kp, vp, bt, lens, interpret=True)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               atol=1e-6, rtol=1e-6)
    # inactive sequence (len 0) -> exactly zero output
    assert float(jnp.abs(ker[2]).max()) == 0.0


def test_paged_attention_matches_contiguous_ops_attention():
    """Gathering K/V through the block table must be numerically equal
    to contiguous ops.attention on the same sequences."""
    q, kp, vp, bt, lens = _paged_case()
    for impl in ("kernel", "ref"):
        out = ops.paged_attention(q, kp, vp, bt, lens, impl=impl)
        for b in range(q.shape[0]):
            L = int(lens[b])
            if L == 0:
                continue
            kc = kp[bt[b]].reshape(-1, kp.shape[2], kp.shape[3])[:L]
            vc = vp[bt[b]].reshape(-1, vp.shape[2], vp.shape[3])[:L]
            # ops.attention wants (B, H, T, D) / (B, Hkv, S, D)
            ref = ops.attention(q[b][None, :, None, :],
                                kc[None].transpose(0, 2, 1, 3),
                                vc[None].transpose(0, 2, 1, 3),
                                causal=False)
            np.testing.assert_allclose(
                np.asarray(out[b]), np.asarray(ref[0, :, 0]),
                atol=1e-5, rtol=1e-5,
                err_msg=f"impl={impl} seq={b}")


def test_paged_attention_full_final_page():
    """Sequence lengths that are EXACT multiples of page_tokens (the
    final page completely full, no partial-page mask) — with the block
    table null-padded past the live pages, exactly the shape the engine
    hands the kernel at a page boundary."""
    rng = np.random.RandomState(3)
    B, H, Hkv, D, P, n_pages, slots = 3, 4, 2, 16, 4, 12, 6
    q = jnp.asarray(rng.randn(B, H, D).astype(np.float32))
    kp = jnp.asarray(rng.randn(n_pages, P, Hkv, D).astype(np.float32))
    vp = jnp.asarray(rng.randn(n_pages, P, Hkv, D).astype(np.float32))
    bt = np.zeros((B, slots), np.int32)          # null-padded
    bt[0, :2] = [1, 2]
    bt[1, :3] = [3, 4, 5]
    bt[2, :6] = [6, 7, 8, 9, 10, 11]
    bt = jnp.asarray(bt)
    lens = jnp.asarray([2 * P, 3 * P, 6 * P], np.int32)  # all full pages
    for impl in ("kernel", "ref"):
        out = ops.paged_attention(q, kp, vp, bt, lens, impl=impl)
        for b in range(B):
            L = int(lens[b])
            kc = kp[bt[b]].reshape(-1, Hkv, D)[:L]
            vc = vp[bt[b]].reshape(-1, Hkv, D)[:L]
            ref = ops.attention(q[b][None, :, None, :],
                                kc[None].transpose(0, 2, 1, 3),
                                vc[None].transpose(0, 2, 1, 3),
                                causal=False)
            np.testing.assert_allclose(
                np.asarray(out[b]), np.asarray(ref[0, :, 0]),
                atol=1e-5, rtol=1e-5, err_msg=f"impl={impl} seq={b}")


def test_paged_attention_first_decode_after_midpage_prefill():
    """Decode position 0 of the OUTPUT right after a chunked prefill
    that ended mid-page: the query at position L attends to L+1 tokens
    where L+1 is NOT page-aligned (the partial final page holds both
    the prompt tail and this step's write)."""
    rng = np.random.RandomState(4)
    B, H, Hkv, D, P = 1, 4, 2, 16, 4
    q = jnp.asarray(rng.randn(B, H, D).astype(np.float32))
    kp = rng.randn(8, P, Hkv, D).astype(np.float32)
    vp = rng.randn(8, P, Hkv, D).astype(np.float32)
    bt = jnp.asarray([[1, 2, 0, 0]], jnp.int32)
    for L in (5, 6, 7):          # prompt ended mid-page at L-1
        lens = jnp.asarray([L + 1], np.int32)    # after this write
        for impl in ("kernel", "ref"):
            out = ops.paged_attention(q, jnp.asarray(kp),
                                      jnp.asarray(vp), bt, lens,
                                      impl=impl)
            kc = kp[np.asarray(bt[0])].reshape(-1, Hkv, D)[:L + 1]
            vc = vp[np.asarray(bt[0])].reshape(-1, Hkv, D)[:L + 1]
            ref = ops.attention(q[0][None, :, None, :],
                                jnp.asarray(kc[None].transpose(0, 2, 1, 3)),
                                jnp.asarray(vc[None].transpose(0, 2, 1, 3)),
                                causal=False)
            np.testing.assert_allclose(
                np.asarray(out[0]), np.asarray(ref[0, :, 0]),
                atol=1e-5, rtol=1e-5, err_msg=f"impl={impl} L={L}")


def test_paged_prefill_window_matches_per_position_decode():
    """The fused chunk-window attention equals C per-position calls of
    the decode oracle (same mask, same scale) — including padded rows
    (zeros) and windows whose last position fills a page exactly."""
    rng = np.random.RandomState(5)
    B, C, H, Hkv, D, P, n_pages, slots = 3, 4, 4, 2, 16, 4, 10, 4
    q = jnp.asarray(rng.randn(B, C, H, D).astype(np.float32))
    kp = jnp.asarray(rng.randn(n_pages, P, Hkv, D).astype(np.float32))
    vp = jnp.asarray(rng.randn(n_pages, P, Hkv, D).astype(np.float32))
    bt = jnp.asarray([[1, 2, 0, 0], [3, 4, 5, 0], [6, 7, 8, 9]],
                     jnp.int32)
    start = jnp.asarray([0, 4, 2], jnp.int32)   # mid-page + page starts
    n_tok = jnp.asarray([4, 3, 0], np.int32)    # full, padded, inactive
    out = ops.paged_prefill_attention(q, kp, vp, bt, start, n_tok)
    for b in range(B):
        for j in range(C):
            if j >= int(n_tok[b]):
                assert float(jnp.abs(out[b, j]).max()) == 0.0
                continue
            lens = np.zeros(B, np.int32)
            lens[b] = int(start[b]) + j + 1
            ref = paged_decode_attention_ref(q[:, j], kp, vp, bt,
                                             jnp.asarray(lens))
            np.testing.assert_allclose(
                np.asarray(out[b, j]), np.asarray(ref[b]),
                atol=1e-6, rtol=1e-6, err_msg=f"b={b} j={j}")


def test_paged_attention_gqa_and_mqa_groups():
    for H, Hkv in ((4, 1), (6, 2), (4, 4)):
        q, kp, vp, bt, lens = _paged_case(seed=H * 10 + Hkv, H=H,
                                          Hkv=Hkv)
        ref = paged_decode_attention_ref(q, kp, vp, bt, lens)
        ker = paged_decode_attention(q, kp, vp, bt, lens, interpret=True)
        np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                                   atol=1e-6, rtol=1e-6,
                                   err_msg=f"H={H} Hkv={Hkv}")


# ======================================================================
# engine end-to-end vs the contiguous decode path
# ======================================================================
def test_engine_streams_match_contiguous_decode():
    cfg = configs.get_smoke("qwen3-8b")
    ctx = ParallelCtx(dp_size=1, tp_size=1, sp=False, remat=False,
                      param_dtype=jnp.float32, compute_dtype=jnp.float32)
    api = registry.build(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg, ctx)

    def ref_decode(prompt, max_new):
        state = api.init_decode_state(cfg, ctx, 1, max_len=32)
        step = jax.jit(lambda p, t, s: api.decode_step(p, t, s, ctx, cfg))
        tok = None
        for t in prompt:
            tok, state = step(params, jnp.asarray([t], jnp.int32), state)
        out = [int(tok[0])]
        for _ in range(max_new - 1):
            tok, state = step(params, tok, state)
            out.append(int(tok[0]))
        return out

    prompts = [list(range(3, 9)), list(range(4, 10)), [7, 3, 99, 12]]
    scfg = ServeConfig(page_tokens=4, n_pages=32, max_batch=3,
                       max_seq=32, max_prompt=16, attn_impl="kernel")
    eng = ServeEngine(params, cfg, ctx, scfg)
    reqs = [Request(rid=i, prompt=p, max_new=5)
            for i, p in enumerate(prompts)]
    done = sorted(eng.run(reqs, clock="tick"), key=lambda r: r.rid)
    for r in done:
        assert r.out == ref_decode(r.prompt, 5), f"req {r.rid}"


def test_engine_streams_invariant_to_prefill_chunking():
    """Chunked prefill is a scheduling choice, not a numerical one:
    any (prefill_chunk, tick_tokens) setting must produce the token
    streams of the monolithic whole-prompt run.  Covers chunks that end
    mid-page (prompt 6 over 4-token pages, chunk 3) and the first
    decode right after such a chunk."""
    cfg = configs.get_smoke("qwen3-8b")
    ctx = ParallelCtx(dp_size=1, tp_size=1, sp=False, remat=False,
                      param_dtype=jnp.float32, compute_dtype=jnp.float32)
    api = registry.build(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg, ctx)
    prompts = [list(range(3, 9)), list(range(4, 10)), [7, 3, 99, 12]]

    def run(chunk, tick_tokens=0):
        scfg = ServeConfig(page_tokens=4, n_pages=32, max_batch=3,
                           max_seq=32, prefill_chunk=chunk,
                           tick_tokens=tick_tokens, attn_impl="ref")
        eng = ServeEngine(params, cfg, ctx, scfg)
        done = eng.run([Request(rid=i, prompt=list(p), max_new=5)
                        for i, p in enumerate(prompts)], clock="tick")
        return {r.rid: list(r.out) for r in done}, \
            {r.rid: list(r.prefill_chunks) for r in done}

    mono, mono_chunks = run(chunk=16)
    assert mono_chunks[0] == [6]               # one whole-prompt chunk
    for chunk, tick_tokens in ((1, 0), (2, 0), (3, 0), (3, 4), (5, 7)):
        streams, chunks = run(chunk, tick_tokens)
        assert streams == mono, (chunk, tick_tokens, streams, mono)
        assert all(max(c) <= chunk for c in chunks.values())
    _, c3 = run(3)
    assert c3[0] == [3, 3]                     # mid-page chunk boundary


# ======================================================================
# page migration: put_nbi + one quiet() (LocalTransport oracle)
# ======================================================================
def test_page_migration_put_nbi_one_quiet():
    """Pages move between PEs as one-sided writes: N migrations issue N
    put_nbi and drain with exactly ONE quiet(); the destination PE's
    pool rows equal the source PE's pages afterwards."""
    heap = SymmetricHeap(("pe",), capacity_bytes=1 << 24)
    kv = make_kv(n_pages=8, heap=heap)
    n_pe = 2
    rng = np.random.RandomState(0)
    system = rng.randn(n_pe, *kv.handle.shape).astype(np.float32)
    state = {kv.handle.name: system.copy()}
    q = CommQueue("pe", state, transport=LocalTransport(n_pe))
    migs = [PageMigration(src_pe=0, dst_pe=1, src_page=3, dst_page=5),
            PageMigration(src_pe=0, dst_pe=1, src_page=4, dst_page=6)]
    out = kv.issue_migrations(q, state[kv.handle.name], migs,
                              system=True)
    st = q.stats()
    assert st["puts"] == 2 and st["quiets"] == 1
    got = np.asarray(out[kv.handle.name])
    np.testing.assert_array_equal(got[1, 5], system[0, 3])
    np.testing.assert_array_equal(got[1, 6], system[0, 4])
    # adjacent dst pages, same pair -> drain coalesced them into one
    # permute round (the ROADMAP item working for serving traffic)
    assert st["coalesced"] == 1
    # everything else untouched
    untouched = np.ones(8, bool)
    untouched[[5, 6]] = False
    np.testing.assert_array_equal(got[1][untouched], system[1][untouched])
    np.testing.assert_array_equal(got[0], system[0])


def test_local_prefix_hit_resumes_via_self_pair_copy():
    """A same-PE prefix hit reuses the pinned pages through the SAME
    put_nbi path with self-pairs (0-hop copy into fresh pages): the
    re-served prompt must produce the identical stream while the
    pinned originals stay registered — and the uncovered suffix
    prefills in >= 2-token chunks, not token-by-token."""
    cfg = configs.get_smoke("qwen3-8b")
    ctx = ParallelCtx(dp_size=1, tp_size=1, sp=False, remat=False,
                      param_dtype=jnp.float32, compute_dtype=jnp.float32)
    api = registry.build(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg, ctx)
    scfg = ServeConfig(page_tokens=4, n_pages=32, max_batch=2,
                       max_seq=32, prefill_chunk=4, attn_impl="ref",
                       prefix_keep=True)
    eng = ServeEngine(params, cfg, ctx, scfg)
    prompt = list(range(5, 16))                # 2 full pages + 3 extra
    first = eng.run([Request(rid=0, prompt=list(prompt), max_new=5)],
                    clock="tick")[0]
    assert eng.kv.pinned_pages == 2
    eng.submit(Request(rid=1, prompt=list(prompt), max_new=5))
    while eng.sched.has_work():
        eng.tick()
    resumed = next(r for r in eng.finished if r.rid == 1)
    assert eng.sched.stats["resumed"] == 1
    assert eng.kv.stats["migrations"] == 2     # 2 pages, self-pair copy
    # 8 of 11 prompt tokens arrived by migration; the 3-token suffix
    # went through chunked prefill in one >= 2-token chunk
    assert resumed.prefill_chunks and max(resumed.prefill_chunks) >= 2
    assert sum(resumed.prefill_chunks) == 3
    assert resumed.out == first.out
    assert eng.kv.lookup_prefix(prompt) is not None   # originals intact


def test_prefix_pin_budget_bounds_the_cache():
    """Pinning stops at the budget: the pool can never be starved by
    the prefix index (the cache is bounded, not a leak)."""
    kv = make_kv(n_pages=9, page_tokens=4)     # budget = 8 // 4 = 2
    assert kv.pin_budget == 2
    assert kv.alloc_seq("a", 8)
    assert kv.register_prefix(list(range(8)), 0, kv.tables["a"][:2])
    assert kv.pinned_pages == 2
    assert kv.alloc_seq("b", 8)
    assert not kv.register_prefix(list(range(20, 28)), 0,
                                  kv.tables["b"][:2])   # over budget
    assert kv.pinned_pages == 2


def test_prefix_cache_registration_and_lookup():
    kv = make_kv(n_pages=10, page_tokens=4)
    prompt = list(range(11))                   # 2 full pages + 3 tokens
    assert kv.alloc_seq("a", len(prompt) + 1)
    pages = kv.tables["a"]
    assert kv.register_prefix(prompt, owner_pe=0, pages=pages[:2])
    assert not kv.register_prefix(prompt, owner_pe=1, pages=pages[:2])
    owner, src = kv.lookup_prefix(prompt + [99, 98])   # longest prefix
    assert owner == 0 and src == pages[:2]
    assert kv.lookup_prefix([5, 5, 5, 5]) is None


# ======================================================================
# the 8-PE mesh suite (subprocess, like the other multipe workers)
# ======================================================================
def test_serve_mesh_8pe():
    if os.environ.get("REPRO_MULTIPE_EXPLICIT"):
        pytest.skip("multipe workers run explicitly (scripts/verify.sh)")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable,
         os.path.join(ROOT, "tests", "multipe", "run_serve.py")],
        capture_output=True, text=True, env=env, timeout=2400)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "SERVE_PASS" in r.stdout


# ======================================================================
# attn_impl: end-to-end threading + ref/kernel stream identity
# ======================================================================
@pytest.fixture(scope="module")
def smoke_model():
    cfg = configs.get_smoke("qwen3-8b")
    ctx = ParallelCtx(dp_size=1, tp_size=1, sp=False, remat=False,
                      param_dtype=jnp.float32, compute_dtype=jnp.float32)
    params = registry.build(cfg).init(jax.random.PRNGKey(0), cfg, ctx)
    return params, cfg, ctx


def test_attn_impl_threads_through_all_three_call_sites(
        smoke_model, monkeypatch):
    """Regression: ServeConfig.attn_impl used to be silently dropped on
    the window trunk (engine hardcoded the ref for prefill AND verify).
    Spy on the ops layer and assert the CONFIGURED impl is what every
    call site — decode, prefill window, verify window — actually
    passes."""
    params, cfg, ctx = smoke_model
    calls = []
    real_window = ops.paged_prefill_attention
    real_decode = ops.paged_attention

    def spy_window(q, *a, **kw):
        calls.append(("window", int(q.shape[1]), kw.get("impl", "ref")))
        return real_window(q, *a, **kw)

    def spy_decode(q, *a, **kw):
        calls.append(("decode", 1, kw.get("impl", "kernel")))
        return real_decode(q, *a, **kw)

    monkeypatch.setattr(ops, "paged_prefill_attention", spy_window)
    monkeypatch.setattr(ops, "paged_attention", spy_decode)

    def run(spec_k):
        scfg = ServeConfig(page_tokens=4, n_pages=32, max_batch=2,
                           max_seq=32, prefill_chunk=4, spec_k=spec_k,
                           attn_impl="kernel")
        eng = ServeEngine(params, cfg, ctx, scfg)
        eng.run([Request(rid=0, prompt=[5, 17, 42] * 3, max_new=6)],
                clock="tick")

    run(spec_k=0)            # prefill window (C=4) + plain decode
    run(spec_k=2)            # + verify windows (C=spec_k+1=3)
    widths = {c for kind, c, _ in calls if kind == "window"}
    assert 4 in widths, "prefill window never traced"
    assert 3 in widths, "verify window never traced"
    assert any(kind == "decode" for kind, _, _ in calls)
    bad = [c for c in calls if c[2] != "kernel"]
    assert not bad, f"attn_impl not threaded: {bad}"


@pytest.mark.parametrize("spec_k", [0, 2])
def test_streams_bit_identical_across_attn_impl(smoke_model, spec_k):
    """The acceptance bar: attn_impl is a performance choice, never a
    numerical one — greedy AND sampled token streams, spec off and on,
    alone and batched, are bit-identical between ref and kernel."""
    params, cfg, ctx = smoke_model
    sp = serve.SamplingParams(temperature=0.9, top_k=5, top_p=0.9)

    def mixed_reqs():
        # greedy + sampled in ONE batch; prompts repeat so the n-gram
        # proposer earns accepts when spec is on
        return [Request(rid=0, prompt=[5, 17, 42] * 4, max_new=8),
                Request(rid=1, prompt=[5, 17, 42] * 3, max_new=8,
                        sampling=sp),
                Request(rid=2, prompt=[7, 3, 99, 12], max_new=8)]

    def alone_reqs():
        return [Request(rid=0, prompt=[5, 17, 42] * 3, max_new=8,
                        sampling=sp)]

    def run(attn_impl, mk):
        scfg = ServeConfig(page_tokens=4, n_pages=48, max_batch=3,
                           max_seq=48, spec_k=spec_k,
                           attn_impl=attn_impl)
        eng = ServeEngine(params, cfg, ctx, scfg)
        done = eng.run(mk(), clock="tick")
        return {r.rid: list(r.out) for r in done}, eng

    for mk in (mixed_reqs, alone_reqs):
        ref_streams, _ = run("ref", mk)
        ker_streams, eng = run("kernel", mk)
        assert ref_streams == ker_streams, (spec_k, mk.__name__)
        if spec_k:
            assert eng.spec_stats["drafted"] > 0
