"""Per-architecture smoke tests: reduced config, one forward/train step
on CPU, output shapes + no NaNs.  (The FULL configs are exercised only
via the dry-run.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat, configs
from repro.models import registry
from repro.parallel.ctx import ParallelCtx, smap

ARCHS = ["minitron-4b", "gemma-2b", "qwen3-8b", "h2o-danube-3-4b",
         "whisper-base", "rwkv6-3b", "qwen2-moe-a2.7b",
         "qwen3-moe-30b-a3b", "llama-3.2-vision-90b", "zamba2-7b"]

CTX = ParallelCtx(dp_size=1, tp_size=1, sp=False, remat=True,
                  param_dtype=jnp.float32, compute_dtype=jnp.float32)


def _mesh():
    return compat.make_mesh((1, 1), ("data", "model"))


def _batch(cfg, b=2):
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                          (b, cfg.max_seq + 1), 0,
                                          cfg.vocab)}
    if cfg.family == "vlm":
        batch["img_embeds"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(2), (b, cfg.img_tokens, cfg.d_model))
    if cfg.family == "encdec":
        batch["frames"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(2), (b, cfg.enc_frames, cfg.d_model))
    return batch


def _bspecs(batch):
    return {k: P("data") if k == "tokens" else P("data", None, None)
            for k in batch}


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_loss_and_grads(arch):
    cfg = configs.get_smoke(arch)
    api = registry.build(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg, CTX)
    batch = _batch(cfg)
    mesh = _mesh()

    def run(p, bt):
        l, g = jax.value_and_grad(
            lambda pp: api.loss_fn(pp, bt, CTX, cfg))(p)
        return l, g

    loss, grads = jax.jit(smap(run, mesh,
                               (api.specs(cfg, CTX), _bspecs(batch)),
                               (P(), api.specs(cfg, CTX))))(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss {loss}"
    assert 3.0 < float(loss) < 7.0, f"{arch}: implausible init loss {loss}"
    for leaf in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(leaf)).all(), f"{arch}: NaN grads"


@pytest.mark.parametrize("arch", ["qwen3-8b", "rwkv6-3b", "zamba2-7b",
                                  "h2o-danube-3-4b", "gemma-2b"])
def test_smoke_decode(arch):
    """decode_step: shapes, finite outputs, cache updates advance."""
    cfg = configs.get_smoke(arch)
    api = registry.build(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg, CTX)
    b = 2
    state = api.init_decode_state(cfg, CTX, b, max_len=16)
    tok = jnp.zeros((b,), jnp.int32)
    for i in range(3):
        tok, state = api.decode_step(params, tok, state, CTX, cfg)
        assert tok.shape == (b,)
        assert int(state["pos"]) == i + 1
        assert ((0 <= np.asarray(tok)) &
                (np.asarray(tok) < cfg.padded_vocab(1))).all()


def test_smoke_decode_whisper():
    from repro.models import encdec
    cfg = configs.get_smoke("whisper-base")
    api = registry.build(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg, CTX)
    b = 2
    frames = 0.1 * jax.random.normal(jax.random.PRNGKey(2),
                                     (b, cfg.enc_frames, cfg.d_model))
    enc = encdec.encode(params, frames, CTX, cfg)
    enc_kv = encdec.encoder_cross_kv(params, enc, CTX, cfg)
    state = api.init_decode_state(cfg, CTX, b, max_len=16)
    tok = jnp.zeros((b,), jnp.int32)
    tok, state = api.decode_step(params, tok, state, enc_kv, CTX, cfg)
    assert tok.shape == (b,)


def test_prefill_matches_forward_last_token():
    cfg = configs.get_smoke("qwen3-8b")
    api = registry.build(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg, CTX)
    ids = jax.random.randint(jax.random.PRNGKey(1), (2, cfg.max_seq),
                             0, cfg.vocab)
    out = api.prefill(params, ids, CTX, cfg)
    assert out.shape == (2, cfg.d_model)
    assert np.isfinite(np.asarray(out)).all()


def test_param_count_sanity():
    """Exact configs: derived param counts in the published ballpark."""
    expect = {"minitron-4b": (4.0e9, 0.4), "gemma-2b": (2.5e9, 0.45),
              "qwen3-8b": (8.2e9, 0.3), "llama-3.2-vision-90b": (9.0e10, 0.3),
              "rwkv6-3b": (3.1e9, 0.4)}
    for arch, (target, tol) in expect.items():
        cfg = configs.get(arch)
        n = cfg.param_count()
        assert abs(n - target) / target < tol, \
            f"{arch}: {n/1e9:.2f}B vs expected {target/1e9:.1f}B"
