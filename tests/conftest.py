"""Suite-wide wiring for the shmem memory-model checker.

With ``REPRO_SHMEMCHECK=1`` every test runs under
``repro.analysis.shmemcheck``: the checker is enabled with fresh state
before each test, and any finding it accumulated fails the owning test
at teardown — so a race is attributed to the test that raced, not to a
global end-of-session report.  All findings are additionally written to
``shmemcheck-report.json`` under pytest's session tmp dir — never the
CWD — with the full path overridable via ``REPRO_SHMEMCHECK_REPORT``
for CI artifact upload.

Tests that *deliberately* exercise racy or pending-state behaviour —
the ordering property tests replay many legal interleavings of
unordered puts, which is the checker's definition of a ww-race — opt
out with ``@pytest.mark.shmem_racy``.
"""
import json
import os

import pytest

_ENABLED = os.environ.get("REPRO_SHMEMCHECK") == "1"
_ALL: list[dict] = []


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "shmem_racy: test deliberately explores racy/pending-state "
        "interleavings; the shmemcheck happens-before checker is "
        "suspended for it")
    config.addinivalue_line("markers", "slow: long-running test")


def pytest_runtest_setup(item):
    if not _ENABLED:
        return
    from repro.analysis import shmemcheck
    if item.get_closest_marker("shmem_racy"):
        shmemcheck.disable()
        return
    chk = shmemcheck.enable()
    chk.reset()


def pytest_runtest_teardown(item, nextitem):
    if not _ENABLED:
        return
    from repro.analysis import shmemcheck
    if item.get_closest_marker("shmem_racy"):
        return
    chk = shmemcheck.get_checker()
    if chk is None:
        return
    findings = chk.report()
    if not findings:
        return
    _ALL.extend({"test": item.nodeid, "rule": f.rule, "loc": f.loc,
                 "other_loc": f.other_loc, "message": f.message}
                for f in findings)
    lines = "\n".join(f"  {f}" for f in findings)
    chk.reset()
    pytest.fail(
        f"shmemcheck: {len(findings)} memory-model finding(s):\n{lines}",
        pytrace=False)


def _report_path(config) -> str:
    override = os.environ.get("REPRO_SHMEMCHECK_REPORT")
    if override:
        return override
    try:
        base = str(config._tmp_path_factory.getbasetemp())
    except Exception:
        import tempfile
        base = tempfile.gettempdir()
    return os.path.join(base, "shmemcheck-report.json")


def pytest_sessionfinish(session, exitstatus):
    if not _ENABLED:
        return
    path = _report_path(session.config)
    try:
        with open(path, "w", encoding="utf-8") as fh:
            json.dump({"findings": _ALL, "count": len(_ALL)}, fh, indent=2)
    except OSError:
        pass
