"""The promoted symm_copy engine — hypothesis-free coverage (the
kernel sweeps in test_kernels.py sit behind a module-level hypothesis
skip; the copy engine is load-bearing for the pallas comm backend, so
it gets a suite that always runs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import symm_copy as sc

SHAPES = [(17,), (300, 7), (1024, 129)]
DTYPES = [jnp.float32, jnp.bfloat16, jnp.int32]
SPOT_VARIANTS = ["stock", "auto", "vmem_8x128", "vmem_256x256"]


def _input(shape, dtype):
    n = int(np.prod(shape))
    return (jnp.arange(n) % 251).astype(dtype).reshape(shape)


@pytest.mark.parametrize("variant", SPOT_VARIANTS)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("shape", SHAPES)
def test_copy_exact(shape, dtype, variant):
    x = _input(shape, dtype)
    y = ops.symm_copy(x, variant)
    assert y.shape == x.shape and y.dtype == x.dtype
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


def test_choose_variant_ladder():
    """Size dispatch: tiny -> stock, then monotonically larger blocks."""
    assert sc.choose_variant(64) == "stock"
    assert sc.choose_variant(8 << 10) == "vmem_8x128"
    assert sc.choose_variant(128 << 10) == "vmem_32x128"
    assert sc.choose_variant(1 << 20) == "vmem_64x256"
    assert sc.choose_variant(4 << 20) == "vmem_256x256"
    assert sc.choose_variant(64 << 20) == "vmem_512x512"
    # the stock cutoff is dtype-aware (one minimal tile)
    assert sc.choose_variant(8 * 128 * 4, jnp.float32) != "stock"
    assert sc.choose_variant(8 * 128 * 2, jnp.bfloat16) == "stock"


def test_block_shape_dtype_tiling():
    """Sublane rounding per dtype: f32 8, bf16 16, int8 32 rows."""
    assert sc.block_shape("vmem_8x128", jnp.float32) == (8, 128)
    assert sc.block_shape("vmem_8x128", jnp.bfloat16) == (16, 128)
    assert sc.block_shape("vmem_8x128", jnp.int8) == (32, 128)
    assert sc.block_shape("vmem_256x256", jnp.bfloat16) == (256, 256)


def test_default_interpret_matches_platform():
    assert sc.default_interpret() == (jax.default_backend() != "tpu")


def test_vmem_bytes_reflects_dtype_tiling():
    # bf16's rounded-up sublane keeps the byte estimate honest
    f32 = sc.vmem_bytes("vmem_8x128", "float32")   # 8x128 blocks
    bf16 = sc.vmem_bytes("vmem_8x128", "bfloat16")  # 16x128 blocks
    assert f32 == 2 * 2 * 8 * 128 * 4
    assert bf16 == 2 * 2 * 16 * 128 * 2


def test_grid_is_2d_for_wide_payloads():
    """Large payloads panelize into several column panels (the 2-D
    pipelined grid); correctness is exact regardless."""
    x = _input((640, 512), jnp.float32)            # 320K elems
    y = sc.copy_blocked(x, "vmem_8x128", interpret=True)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


def test_copy_variants_registry():
    assert set(("stock", "auto")) <= set(ops.COPY_VARIANTS)
    assert set(sc.VARIANTS) <= set(ops.COPY_VARIANTS)
    with pytest.raises(KeyError):
        sc.copy_blocked(jnp.zeros(8), "no_such_variant", interpret=True)
